"""Fault-injection plane: determinism, recovery, and serve resilience.

Covers ``repro.cluster.faults`` end-to-end through the simulator —
the determinism contract (``faults=None`` == empty ``FaultPlan()``),
each fault kind's blast radius, detection latency, retry budgets with
the FAILED terminal state, regrow-after-repair, planned-drain notices,
and the serve-side timeout/retry/health-failover stack.  Satellite
coverage for the ``(t_down, t_up, n)`` failure rows lives here too.
"""
import dataclasses
import json

import pytest

from repro.cluster.faults import FAULT_KINDS, FaultPlan, FaultSpec
from repro.cluster.scheduler import FAILED
from repro.cluster.simulator import (ClusterSimulator, JobTemplate,
                                     ServiceConfig, TraceConfig, run_trace)


def _canon(rep):
    return json.dumps(rep, sort_keys=True, default=str)


def _cfg(**kw):
    kw.setdefault("failures", ())
    kw.setdefault("n_jobs", 8)
    kw.setdefault("arrival_rate_hz", 0.2)
    kw.setdefault("seed", 3)
    return TraceConfig(**kw)


# one long-running 16-chip job on a single-pod 32-device pool: small
# enough that a scripted fault can take out the *whole* pool, which is
# the only way to force the preempt -> retry path (spares on the big
# default pool absorb same-shape recompositions for free)
def _tiny(steps=40, chips=16, **kw):
    kw.setdefault("n_local", 16)
    kw.setdefault("n_switch", 16)
    kw.setdefault("pods", 1)
    return _cfg(
        n_jobs=0,
        arrivals=((0.0, JobTemplate("qwen2-0.5b", "train_4k",
                                    chips, steps)),),
        **kw)


# ---------------------------------------------------------------- plan ----

def test_fault_kinds_cover_the_composable_failure_units():
    assert set(FAULT_KINDS) == {
        "device_down", "device_flaky", "link_degrade", "domain_outage",
        "tranche_brownout", "tranche_fail", "pod_loss"}


def test_unknown_fault_kind_rejected_at_construction():
    with pytest.raises(ValueError):
        FaultSpec(kind="gamma_ray", t=1.0)


def test_empty_plan_is_bit_identical_to_none():
    base = run_trace(_cfg())
    empty = run_trace(_cfg(faults=FaultPlan()))
    assert _canon(base) == _canon(empty)


def test_same_seed_fault_trace_is_deterministic():
    cfg = _cfg(faults=FaultPlan(mtbf_s=60.0, mttr_s=40.0,
                                horizon_s=200.0, mtbf_n=16))
    assert _canon(run_trace(cfg)) == _canon(run_trace(cfg))


# ---------------------------------------------------- device faults ------

def test_device_down_recovers_via_retry_backoff():
    rep = run_trace(_tiny(faults=FaultPlan(
        faults=(FaultSpec(kind="device_down", t=30.0, n=32,
                          t_clear=60.0, detect_s=2.0),),
        retry_backoff_s=5.0)))
    jobs, faults = rep["jobs"], rep["faults"]
    assert faults["injected"] == 1
    assert jobs["failed"] == 0 and jobs["stranded"] == 0
    assert jobs["completed"] == jobs["submitted"]
    assert faults["recovery"]["samples"] >= 1
    # recovery = detect + decide + restore, so detection latency is a
    # hard floor on every sample
    assert faults["recovery"]["mean_s"] >= 2.0
    assert faults["detect_s_mean"] == pytest.approx(2.0)
    assert 0.0 < faults["availability"] < 1.0


def test_retry_budget_exhaustion_reaches_failed_terminal_state():
    # the whole pool flaps down/up faster than the job can finish;
    # max_retries=1 means the second fault-driven preemption is fatal
    sim = ClusterSimulator(_tiny(steps=200, faults=FaultPlan(
        faults=(FaultSpec(kind="device_flaky", t=10.0, n=32, flaps=4,
                          period_s=30.0, detect_s=1.0),),
        retry_backoff_s=1.0, max_retries=1)))
    rep = sim.run()
    assert rep["jobs"]["failed"] == 1
    assert rep["jobs"]["stranded"] == 0
    assert rep["jobs"]["completed"] + rep["jobs"]["rejected"] \
        + rep["jobs"]["failed"] == rep["jobs"]["submitted"]
    failed = sim.scheduler.failed
    assert len(failed) == 1 and failed[0].state == FAILED
    assert "retry budget exhausted" in failed[0].why_rejected
    kinds = [e.kind for e in sim.telemetry.events]
    assert "retry" in kinds and "fail" in kinds


def test_domain_outage_all_surviving_jobs_recover():
    rep = run_trace(_cfg(n_jobs=12, faults=FaultPlan(
        faults=(FaultSpec(kind="domain_outage", t=90.0, domain=1,
                          t_clear=130.0, detect_s=2.0),),
        retry_backoff_s=5.0)))
    jobs = rep["jobs"]
    assert jobs["failed"] == 0 and jobs["stranded"] == 0
    assert jobs["completed"] + jobs["rejected"] == jobs["submitted"]
    assert rep["faults"]["availability"] > 0.5


def test_regrow_after_repair_beats_staying_shrunk():
    # half the tiny pool dies while the 32-chip job runs; it shrinks in
    # place.  With regrow the post-repair recomposition restores full
    # width, so the makespan must beat the stay-shrunk plan.
    def mk(regrow):
        sim = ClusterSimulator(_tiny(steps=120, chips=32, faults=FaultPlan(
            faults=(FaultSpec(kind="device_down", t=20.0, n=16,
                              t_clear=80.0, detect_s=1.0),),
            regrow=regrow)))
        rep = sim.run()
        assert rep["jobs"]["completed"] == rep["jobs"]["submitted"]
        events = [e for e in sim.telemetry.events
                  if e.kind == "recompose" and "regrow" in e.detail]
        return sim.scheduler.done[0].end_t, len(events)
    (grown_t, grown_regrows), (shrunk_t, shrunk_regrows) = mk(True), mk(False)
    assert grown_regrows >= 1 and shrunk_regrows == 0
    assert grown_t < shrunk_t


# --------------------------------------------- graceful degradation ------

def test_link_degrade_is_graceful_and_clears():
    # a 32-chip job on the 32-device pool spans the host/switch
    # boundary, so its gradient allreduce actually rides the degraded
    # link class (a 16-chip job would compose all-LOCAL and not notice)
    def end_t(faults):
        sim = ClusterSimulator(_tiny(steps=40, chips=32, faults=faults))
        rep = sim.run()
        assert rep["jobs"]["preempted"] == 0
        assert rep["jobs"]["failed"] == 0
        assert rep["jobs"]["completed"] == rep["jobs"]["submitted"]
        return sim.scheduler.done[0].end_t
    clean = end_t(None)
    forever = end_t(FaultPlan(faults=(
        FaultSpec(kind="link_degrade", t=10.0, link="host", frac=0.1),)))
    cleared = end_t(FaultPlan(faults=(
        FaultSpec(kind="link_degrade", t=10.0, link="host", frac=0.1,
                  t_clear=clean / 2),)))
    # degraded the whole way > degraded half the way > untouched
    assert forever > cleared > clean


def test_tranche_brownout_reprices_without_eviction():
    clean = run_trace(_cfg(n_jobs=10))
    rep = run_trace(_cfg(n_jobs=10, faults=FaultPlan(faults=(
        FaultSpec(kind="tranche_brownout", t=30.0,
                  tranche="local-nvme-0", frac=0.25),))))
    assert rep["jobs"]["preempted"] == 0
    assert rep["jobs"]["evicted"] == clean["jobs"]["evicted"]
    assert rep["jobs"]["completed"] == clean["jobs"]["completed"]
    assert rep["makespan_s"] >= clean["makespan_s"]


def test_tranche_fail_evacuates_holders_and_they_restart():
    sim = ClusterSimulator(_cfg(n_jobs=10, faults=FaultPlan(
        faults=(FaultSpec(kind="tranche_fail", t=30.0,
                          tranche="local-nvme-0", t_clear=90.0,
                          detect_s=2.0),),
        retry_backoff_s=2.0)))
    rep = sim.run()
    jobs = rep["jobs"]
    assert jobs["preempted"] >= 1
    assert jobs["failed"] == 0 and jobs["stranded"] == 0
    assert jobs["completed"] + jobs["rejected"] == jobs["submitted"]
    assert rep["faults"]["recovery"]["samples"] >= 1


# ------------------------------------------------ serve resilience -------

def _serve_cfg(*, retries, health_s, timeout_s, fault=None):
    fault = fault or FaultSpec(kind="device_down", t=15.0, n=64,
                               t_clear=120.0, detect_s=10.0)
    return TraceConfig(
        n_jobs=0, seed=11, failures=(),
        services=(ServiceConfig(
            name="chat", arch="llama3.2-3b", shape_name="decode_32k",
            n_replicas=3, chips_per_replica=64, n_requests=80,
            arrival_rate_hz=4.0, prompt_len=2048, max_new=128,
            request_timeout_s=timeout_s, max_request_retries=retries,
            retry_backoff_s=0.5, health_check_s=health_s),),
        faults=FaultPlan(faults=(fault,)))


def test_serve_failover_keeps_failed_request_rate_low():
    res = run_trace(_serve_cfg(retries=2, health_s=2.0, timeout_s=15.0))
    bare = run_trace(_serve_cfg(retries=0, health_s=0.0, timeout_s=0.0))
    sv = res["serving"]["chat"]
    assert sv["failed_request_rate"] < 0.01
    assert sv["requests"]["stranded"] == 0
    assert sv["requests"]["retries"] >= 1
    # without timeouts/health checks the requests on the dead replica
    # hang forever: stranded or failed, never completed
    bv = bare["serving"]["chat"]
    assert (bv["requests"]["stranded"] > 0
            or bv["failed_request_rate"] > sv["failed_request_rate"])


def test_serve_timeout_without_retries_fails_requests():
    rep = run_trace(_serve_cfg(retries=0, health_s=0.0, timeout_s=15.0))
    sv = rep["serving"]["chat"]
    assert sv["requests"]["timed_out"] >= 1
    assert sv["failed_request_rate"] > 0.0
    assert sv["requests"]["stranded"] == 0


def test_planned_detach_drains_before_the_hit():
    # a drain notice only works when the victims are knowable in
    # advance — a locality domain, not randomly-sampled devices
    sim = ClusterSimulator(_serve_cfg(
        retries=2, health_s=2.0, timeout_s=15.0,
        fault=FaultSpec(kind="domain_outage", t=15.0, domain=0,
                        t_clear=120.0, detect_s=2.0, notice_s=5.0)))
    sim.run()
    kinds = [e.kind for e in sim.telemetry.events]
    assert "drain" in kinds
    drain_t = min(e.t for e in sim.telemetry.events if e.kind == "drain")
    fault_t = min(e.t for e in sim.telemetry.events if e.kind == "fault")
    assert drain_t < fault_t     # the notice lands before the fault


# ------------------------------------- (t_down, t_up, n) failure rows ----

def test_three_tuple_failure_matches_equivalent_legacy_row():
    legacy = run_trace(_cfg(n_jobs=10, failures=((60.0, 8),),
                            repair_after_s=90.0))
    explicit = run_trace(_cfg(n_jobs=10, failures=((60.0, 150.0, 8),),
                              repair_after_s=90.0))
    # identical behavior; only the config echo differs
    for rep in (legacy, explicit):
        rep["config"].pop("failures")
    assert _canon(legacy) == _canon(explicit)


@pytest.mark.parametrize("t_up", [None, float("inf")])
def test_t_up_none_or_inf_means_never_repaired(t_up):
    def repairs(failures):
        sim = ClusterSimulator(_tiny(steps=60, failures=failures))
        rep = sim.run()
        assert rep["jobs"]["completed"] == 1
        return sum(1 for e in sim.telemetry.events if e.kind == "repair")
    assert repairs(((10.0, 40.0, 16),)) == 1
    assert repairs(((10.0, t_up, 16),)) == 0


def test_repaired_devices_are_releasable_again():
    # regression: 24 of 32 devices die at t=10 and repair at t=60; a
    # 16-chip job arriving at t=80 only fits if the repaired devices
    # rejoin the leasable pool
    late = (80.0, JobTemplate("qwen2-0.5b", "train_4k", 16, 10))
    ok = run_trace(TraceConfig(
        n_jobs=0, n_local=16, n_switch=16, pods=1, seed=3,
        failures=((10.0, 60.0, 24),), arrivals=(late,)))
    assert ok["jobs"]["completed"] == 1
    assert ok["jobs"]["stranded"] == 0
    dead = run_trace(TraceConfig(
        n_jobs=0, n_local=16, n_switch=16, pods=1, seed=3,
        failures=((10.0, None, 24),), arrivals=(late,)))
    assert dead["jobs"]["completed"] == 0
