"""Integrity gate over the shipped dry-run artifacts (results/).

These are the §Dry-run / §Roofline deliverables; the suite fails if the
artifact set regresses (missing cells, OOM cells, malformed reports).
"""
import glob
import json
import os

import pytest

from repro.configs import ASSIGNED_ARCHS, applicable_shapes, get_config

RESULTS_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results")
RESULTS = os.path.join(RESULTS_DIR, "dryrun")

needs_dryrun = pytest.mark.skipif(
    not os.path.isdir(RESULTS),
    reason="dry-run artifacts not generated (run repro.launch.dryrun)")

HBM = 16 * 2 ** 30


def _cells():
    out = []
    for arch in ASSIGNED_ARCHS:
        for s in applicable_shapes(get_config(arch)):
            for mesh in ("single", "multi"):
                out.append((arch, s.name, mesh))
    return out


@needs_dryrun
def test_every_assigned_cell_has_an_artifact():
    missing = [c for c in _cells()
               if not os.path.exists(os.path.join(
                   RESULTS, f"{c[0]}__{c[1]}__{c[2]}.json"))]
    assert not missing, missing
    assert len(_cells()) == 64


@needs_dryrun
@pytest.mark.parametrize("path", sorted(glob.glob(
    os.path.join(RESULTS, "*.json"))))
def test_artifact_well_formed_and_fits_hbm(path):
    with open(path) as f:
        js = json.load(f)
    rl = js["roofline"]
    for k in ("compute_s", "memory_s", "collective_s", "dominant",
              "roofline_fraction", "useful_ratio", "step_time_s"):
        assert k in rl, (path, k)
    assert rl["step_time_s"] >= max(rl["compute_s"], rl["collective_s"]) \
        - 1e-12
    assert 0 <= rl["roofline_fraction"] <= 1.0 + 1e-9
    # argument bytes per device must fit the 16 GiB HBM
    args = js["memory_analysis"].get("argument_size_in_bytes", 0)
    assert args <= HBM, (path, args / 2**30)
    # mesh coherence
    n = 1
    for v in js["mesh"].values():
        n *= v
    assert n in (256, 512)


@needs_dryrun
def test_multi_pod_cells_exercise_the_pod_axis():
    """At least the training cells must put traffic on the pod (DCN) axis
    — that is what the multi-pod dry-run proves."""
    hits = 0
    for path in glob.glob(os.path.join(RESULTS, "*train_4k__multi.json")):
        with open(path) as f:
            js = json.load(f)
        if js["per_axis_wire_bytes"].get("pod", 0) > 0:
            hits += 1
    assert hits >= 8, hits


# ---------------------------------------------------------------------------
# cluster-sim artifact (results/cluster_sim.json)
# ---------------------------------------------------------------------------
CLUSTER_SIM = os.path.join(RESULTS_DIR, "cluster_sim.json")

_SIM_REPORT_KEYS = ("span_s", "pool_utilization", "auu",
                    "accelerator_utilization", "link_traffic_gb",
                    "recomposition", "job_wait_s", "jobs", "gangs",
                    "fairness", "lease_conflicts", "storage", "policy",
                    "faults")


@pytest.mark.skipif(
    not os.path.exists(CLUSTER_SIM),
    reason="cluster_sim artifact not generated "
           "(run benchmarks/run.py --bench cluster_sim)")
def test_cluster_sim_artifact_schema():
    with open(CLUSTER_SIM) as f:
        js = json.load(f)
    assert js["bench"] == "cluster_sim"
    # base trace: the PR-1 regression anchor stays healthy
    jobs = js["jobs"]
    assert jobs["completed"] + jobs["rejected"] == jobs["submitted"]
    assert jobs["stranded"] == 0
    assert jobs["failed"] == 0                  # no faults in the base trace
    assert js["lease_conflicts"] == 0
    assert js["faults"]["injected"] == 0
    # per-policy sweep: every policy ran the gang scenario
    assert set(js["policies"]) == {"easy", "fair_share", "priority_preempt"}
    for name, rep in js["policies"].items():
        for k in _SIM_REPORT_KEYS:
            assert k in rep, (name, k)
        assert rep["policy"] == name
        assert rep["gangs"]["started"] >= 1, name
        assert rep["jobs"]["stranded"] == 0, name
        ten = rep["fairness"]["tenants"]
        assert set(ten) >= {"heavy", "blue", "green", "gang"}, name
        for row in ten.values():
            for q in ("p50", "p95", "p99", "mean"):
                assert row["wait_s"][q] >= 0
    # acceptance: the headline policy claims hold in the shipped artifact
    acc = js["acceptance"]
    assert acc["fair_share_improves_tenant_p95_wait"] is True
    assert acc["fair_share_tenant_p95_wait_mean_s"] < \
        acc["easy_tenant_p95_wait_mean_s"]
    assert acc["priority_preempt_evictions"] >= 1
    assert acc["priority_preempt_starts_gang_sooner"] is True
    assert all(n >= 1 for n in acc["gangs_started_per_policy"].values())


# ---------------------------------------------------------------------------
# serving benchmark artifact (results/serve_bench.json)
# ---------------------------------------------------------------------------
SERVE_BENCH = os.path.join(RESULTS_DIR, "serve_bench.json")

_DIST_KEYS = ("p50", "p99", "mean")
_SCENARIO_KEYS = ("requests", "ttft_s", "tpot_s", "queue_wait_s",
                  "slo_attainment", "throughput_tok_s", "cache_hit_rate",
                  "output_tokens")


@pytest.mark.skipif(
    not os.path.exists(SERVE_BENCH),
    reason="serve_bench artifact not generated "
           "(run benchmarks/run.py --bench serve_bench)")
def test_serve_bench_artifact_schema():
    with open(SERVE_BENCH) as f:
        js = json.load(f)
    assert js["bench"] == "serve_bench"
    # engine layer: burst/paced plus the continuous-batching comparison
    assert set(js["engine"]) >= {"burst", "paced", "burst_unfused"}
    for name, sc in js["engine"].items():
        for k in _SCENARIO_KEYS:
            assert k in sc, (name, k)
        for dist in ("ttft_s", "tpot_s", "queue_wait_s"):
            for q in _DIST_KEYS:
                assert sc[dist][q] >= 0, (name, dist, q)
        assert sc["requests"]["completed"] == sc["requests"]["submitted"]
        assert sc["compile_s"] >= 0           # warmup reported separately
        kv = sc["kv_pages"]
        assert 0.0 <= kv["hit_rate"] <= 1.0
        assert kv["in_use"] == 0              # all pages recycled
        assert 0.0 < kv["peak_utilization"] <= 1.0
        assert 0.0 < kv["mean_utilization"] <= kv["peak_utilization"]
    assert js["engine"]["burst"]["fused"] is True
    assert js["engine"]["burst_unfused"]["fused"] is False
    # the headline: continuous batching takes burst SLO attainment to ~1
    assert js["engine"]["burst"]["slo_attainment"] >= 0.9
    # cluster layer: ServeJob replicas simulated alongside training jobs
    assert set(js["cluster"]) >= {"poisson", "burst",
                                  "overload_fixed_2x",
                                  "overload_autoscale_2x"}
    for name, sc in js["cluster"].items():
        if not name.startswith("overload"):
            jobs = sc["jobs"]
            assert jobs["completed"] + jobs["rejected"] == jobs["submitted"]
        for svc in sc["serving"].values():
            assert svc["requests"]["stranded"] == 0
            assert svc["ttft_s"]["p99"] > 0
            assert svc["tpot_s"]["p50"] > 0
            assert svc["throughput_tok_s"] > 0
            assert len(svc["replicas"]) >= 1
            for row in svc["replicas"].values():
                assert "cache_hit_rate" in row
                assert 0.0 <= row["cache_hit_rate"] <= 1.0
    # SLO-driven autoscaling: grows under load, beats the fixed fleet
    fixed = js["cluster"]["overload_fixed_2x"]["serving"]["chat"]
    auto = js["cluster"]["overload_autoscale_2x"]["serving"]["chat"]
    assert "autoscale" not in fixed
    scale = auto["autoscale"]
    assert scale["scale_ups"] >= 1
    assert scale["peak_replicas"] > 1
    assert len(scale["windows"]) >= 1
    assert auto["slo_attainment"] >= fixed["slo_attainment"]
    assert auto["ttft_s"]["p99"] <= fixed["ttft_s"]["p99"]


# ---------------------------------------------------------------------------
# chaos benchmark artifact (results/chaos_bench.json)
# ---------------------------------------------------------------------------
CHAOS_BENCH = os.path.join(RESULTS_DIR, "chaos_bench.json")


@pytest.mark.skipif(
    not os.path.exists(CHAOS_BENCH),
    reason="chaos_bench artifact not generated "
           "(run benchmarks/run.py --bench chaos_bench)")
def test_chaos_bench_artifact_schema():
    with open(CHAOS_BENCH) as f:
        js = json.load(f)
    assert js["bench"] == "chaos_bench"
    # the fault plane must be free when unused
    assert js["baseline_identical"] is True
    assert 0.0 <= js["availability"] <= 1.0
    assert 0.0 <= js["goodput_fraction"] <= 1.0
    assert js["recovery"]["samples"] >= 1
    assert js["recovery"]["p95_s"] >= js["recovery"]["mean_s"] - 1e-9
    assert set(js["scenarios"]) >= {"domain_outage", "degradation", "churn"}
    for name, sc in js["scenarios"].items():
        jobs = sc["jobs"]
        assert (jobs["completed"] + jobs["rejected"] + jobs["failed"]
                == jobs["submitted"]), name
        assert jobs["stranded"] == 0, name
        assert sc["faults"]["injected"] >= 1, name
    acc = js["acceptance"]
    assert acc["outage_availability_above_0_9"] is True
    assert acc["outage_all_jobs_recovered"] is True
    assert acc["degradation_graceful"] is True
    assert acc["serve_failed_rate_below_1pct"] is True
    assert acc["serve_unbounded_without_retries"] is True
    # the serve comparison: resilience on beats resilience off
    sv = js["serve"]
    assert sv["resilient"]["failed_request_rate"] < 0.01
    assert (sv["no_retries"]["failed_request_rate"]
            > sv["resilient"]["failed_request_rate"]
            or sv["no_resilience"]["requests"]["stranded"] > 0)


# ---------------------------------------------------------------------------
# fabric-topology scaling artifact (results/fabric_bench.json)
# ---------------------------------------------------------------------------
FABRIC_BENCH = os.path.join(RESULTS_DIR, "fabric_bench.json")


@pytest.mark.skipif(
    not os.path.exists(FABRIC_BENCH),
    reason="fabric_bench artifact not generated "
           "(run benchmarks/run.py --bench fabric_bench)")
def test_fabric_bench_artifact_schema():
    with open(FABRIC_BENCH) as f:
        js = json.load(f)
    assert js["bench"] == "fabric_bench"
    assert set(js["curves"]) == {"single_switch", "pcie_cascade",
                                 "oversubscribed_spine"}
    sizes = js["config"]["sizes"]
    for name, curve in js["curves"].items():
        assert [p["devices"] for p in curve] == sizes, name
        for p in curve:
            assert p["step_s"] > 0
            assert 0.0 < p["efficiency"] <= 1.0 + 1e-9, (name, p)
            assert set(p["axis_links"]) == set(p["axis_hops"]) \
                == set(p["axis_bw_scale"])
        # efficiency at the smallest size is 1.0 by construction
        assert curve[0]["efficiency"] == pytest.approx(1.0)
    acc = js["acceptance"]
    assert acc["single_switch_matches_flat_model"] is True
    assert acc["oversub_knee_ge_10pct"] is True
    assert acc["oversub_knee_drop_32"] >= 0.10
    assert acc["cross_domain_never_beats_dcn"] is True
    # the spine degrades fastest: its 32-device efficiency trails both
    eff32 = {n: c[-1]["efficiency"] for n, c in js["curves"].items()}
    assert eff32["oversubscribed_spine"] <= eff32["pcie_cascade"] \
        <= eff32["single_switch"]


# ---------------------------------------------------------------------------
# storage benchmark artifact (results/storage_bench.json)
# ---------------------------------------------------------------------------
STORAGE_BENCH = os.path.join(RESULTS_DIR, "storage_bench.json")

_TRANCHE_KEYS = ("attach", "leases_granted", "peak_lessees", "mean_lessees",
                 "read_gb", "write_gb", "input_stall_s")


@pytest.mark.skipif(
    not os.path.exists(STORAGE_BENCH),
    reason="storage_bench artifact not generated "
           "(run benchmarks/run.py --bench storage_bench)")
def test_storage_bench_artifact_schema():
    with open(STORAGE_BENCH) as f:
        js = json.load(f)
    assert js["bench"] == "storage_bench"
    # analytic sweep: 1..4 tenants, shared-switch vs local-per-tenant
    sweep = js["sweep"]
    assert set(sweep) >= {f"tenants_{n}" for n in (1, 2, 3, 4)}
    for name, row in sweep.items():
        for side in ("shared_switch", "local_per_tenant"):
            assert row[side]["input_stall_s"] >= 0
            assert row[side]["per_tenant_read_bw_gbps"] > 0
        assert row["contention_slowdown"] >= 1.0 - 1e-9
    # contention must be visible from 2 tenants on
    assert sweep["tenants_2"]["shared_switch"]["input_stall_s"] > \
        sweep["tenants_2"]["local_per_tenant"]["input_stall_s"]
    assert sweep["tenants_4"]["contention_slowdown"] > \
        sweep["tenants_2"]["contention_slowdown"]
    # cluster layer: the simulator shows the same ordering end-to-end
    cl = js["cluster"]
    assert cl["n_tenants"] >= 2
    for side in ("shared_switch_tranche", "separate_local_tranches"):
        sc = cl[side]
        jobs = sc["jobs"]
        assert jobs["completed"] + jobs["rejected"] == jobs["submitted"]
        assert sc["storage"], side
        for st in sc["storage"].values():
            for k in _TRANCHE_KEYS:
                assert k in st, (side, k)
    acc = cl["acceptance"]
    assert acc["contention_visible"] is True
    assert acc["shared_stall_s"] > acc["separate_stall_s"]


# ---------------------------------------------------------------------------
# schema_version + run provenance (the tracking plane's artifact stamp)
# ---------------------------------------------------------------------------
def _shipped_results():
    return sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json")))


@pytest.mark.parametrize("path", _shipped_results() or
                         [pytest.param("", marks=pytest.mark.skip(
                             reason="no shipped results/*.json"))])
def test_every_result_artifact_is_schema_versioned(path):
    with open(path) as f:
        js = json.load(f)
    assert js.get("schema_version") == 1, os.path.basename(path)


@pytest.mark.parametrize("bench", ["cluster_sim", "serve_bench",
                                   "storage_bench", "kernel_tune",
                                   "chaos_bench", "fabric_bench"])
def test_bench_artifacts_record_their_run_id(bench):
    path = os.path.join(RESULTS_DIR, f"{bench}.json")
    if not os.path.exists(path):
        pytest.skip(f"{bench} artifact not generated")
    with open(path) as f:
        js = json.load(f)
    assert js["run_id"].startswith(f"{bench}-"), js["run_id"]


@pytest.mark.skipif(
    not os.path.exists(CLUSTER_SIM),
    reason="cluster_sim artifact not generated")
def test_cluster_sim_reports_eviction_suppression_telemetry():
    with open(CLUSTER_SIM) as f:
        js = json.load(f)
    assert js["jobs"]["evictions_suppressed"] >= 0
    for name, rep in js["policies"].items():
        assert "evictions_suppressed" in rep["jobs"], name


# ---------------------------------------------------------------------------
# BENCH_<bench>.json perf trajectories (docs/tracking.md)
# ---------------------------------------------------------------------------
def _trajectories():
    return sorted(glob.glob(os.path.join(RESULTS_DIR, "BENCH_*.json")))


@pytest.mark.parametrize("path", _trajectories() or
                         [pytest.param("", marks=pytest.mark.skip(
                             reason="no BENCH_*.json trajectories shipped"))])
def test_bench_trajectory_schema(path):
    with open(path) as f:
        js = json.load(f)
    fname = os.path.basename(path)
    assert js["schema_version"] == 1
    assert fname == f"BENCH_{js['bench']}.json"
    assert js["baseline_run_id"] is None or \
        isinstance(js["baseline_run_id"], str)
    assert js["metrics"], fname
    for name, spec in js["metrics"].items():
        assert spec["direction"] in ("up", "down", "info"), (fname, name)
    assert js["rows"], f"{fname}: trajectory shipped with no baseline row"
    gated = {k for k, m in js["metrics"].items()
             if m["direction"] in ("up", "down")}
    for row in js["rows"]:
        assert row["run_id"] and row["ts"] > 0
        assert "git_sha" in row
        missing = gated - set(row["metrics"])
        assert not missing, (fname, row["run_id"], missing)
        for v in row["metrics"].values():
            assert isinstance(v, (int, float)), (fname, row["run_id"])
    # run ids are unique (appends are idempotent per run id)
    ids = [r["run_id"] for r in js["rows"]]
    assert len(ids) == len(set(ids)), fname


@pytest.mark.parametrize("bench", ["cluster_sim", "serve_bench",
                                   "storage_bench", "kernel_tune",
                                   "chaos_bench", "fabric_bench"])
def test_each_shipped_bench_has_a_seeded_trajectory(bench):
    art = os.path.join(RESULTS_DIR, f"{bench}.json")
    traj = os.path.join(RESULTS_DIR, f"BENCH_{bench}.json")
    if not os.path.exists(art):
        pytest.skip(f"{bench} artifact not generated")
    assert os.path.exists(traj), \
        f"{bench}.json shipped without its BENCH_{bench}.json trajectory"
    with open(art) as f:
        run_id = json.load(f)["run_id"]
    with open(traj) as f:
        rows = json.load(f)["rows"]
    # the artifact's producing run appears in its own trajectory
    assert any(r["run_id"] == run_id for r in rows)


def test_shipped_trajectories_pass_the_perf_gate():
    if not _trajectories():
        pytest.skip("no BENCH_*.json trajectories shipped")
    from repro.tracking import gate, trajectory
    for path in _trajectories():
        verdicts = gate.check_trajectory(trajectory.load(path))
        bad = [v for v in verdicts if v.regressed]
        assert not bad, gate.format_table(bad)
