"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real single CPU device; only launch/dryrun.py forces 512."""
import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
