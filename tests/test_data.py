"""Data pipeline: determinism, sharding, storage-tier pricing."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import get_config, reduced
from repro.configs.base import ShapeConfig
from repro.core.topology import (DEFAULT_LINKS, LOCAL_NVME, SWITCH_NVME,
                                 LinkClass)
from repro.data import (Prefetcher, StorageModel, SyntheticDataset,
                        input_stall)

CFG = reduced(get_config("qwen2-0.5b"))
SHAPE = ShapeConfig("t", 64, 8, "train")


def test_batches_deterministic():
    ds = SyntheticDataset(CFG, SHAPE, seed=1)
    a = ds.batch_at(3)
    b = ds.batch_at(3)
    np.testing.assert_array_equal(a["inputs"], b["inputs"])
    c = ds.batch_at(4)
    assert not np.array_equal(a["inputs"], c["inputs"])


def test_shards_are_disjoint_and_deterministic():
    """Hosts generate their shard without coordination: same (step, shard)
    -> same data; different shards -> different data."""
    ds = SyntheticDataset(CFG, SHAPE, seed=1)
    s0 = ds.batch_at(5, shard=0, n_shards=4)
    s0b = ds.batch_at(5, shard=0, n_shards=4)
    s1 = ds.batch_at(5, shard=1, n_shards=4)
    np.testing.assert_array_equal(s0["inputs"], s0b["inputs"])
    assert not np.array_equal(s0["inputs"], s1["inputs"])
    assert s0["inputs"].shape[0] == SHAPE.global_batch // 4


def test_labels_are_shifted_inputs():
    ds = SyntheticDataset(CFG, SHAPE, seed=0)
    b = ds.batch_at(0)
    np.testing.assert_array_equal(b["inputs"][:, 1:], b["labels"][:, :-1])


def test_tokens_in_vocab():
    ds = SyntheticDataset(CFG, SHAPE, seed=0)
    b = ds.batch_at(0)
    assert b["inputs"].min() >= 0
    assert b["inputs"].max() < CFG.vocab_size


# ---------------------------------------------------------------------------
# storage tiers (Fig 15's instrument)
# ---------------------------------------------------------------------------
def test_switch_nvme_slower_than_local():
    local = StorageModel(LOCAL_NVME)
    falcon = StorageModel(SWITCH_NVME)
    nbytes = 1e9
    assert falcon.read_time(nbytes) > local.read_time(nbytes)


def test_switch_nvme_capped_by_fabric():
    bw = SWITCH_NVME.effective_read_bw(DEFAULT_LINKS)
    assert bw <= DEFAULT_LINKS[LinkClass.SWITCH].bandwidth
    assert bw <= SWITCH_NVME.read_bw


@given(read=st.floats(1e-4, 10), step=st.floats(1e-4, 10))
@settings(max_examples=50, deadline=None)
def test_input_stall_overlap_law(read, step):
    """Prefetch hides reads up to the step time; never negative."""
    stall = input_stall(read, step, prefetch=2)
    assert stall >= 0
    assert stall == pytest.approx(max(0.0, read - step))
    assert input_stall(read, step, prefetch=0) == read


def test_prefetcher_iterates():
    ds = SyntheticDataset(CFG, SHAPE, seed=0)
    pf = Prefetcher(ds, StorageModel(LOCAL_NVME), shard=1, n_shards=4)
    b = next(pf)
    assert b["inputs"].shape[0] == SHAPE.global_batch // 4
    assert pf.read_time_s > 0
