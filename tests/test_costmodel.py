"""Cost-model unit tests: HLO parsing, trip counts, roofline pricing."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, SHAPES
from repro.configs.base import PolicyConfig, ShapeConfig
from repro.core import costmodel, compose


# ---------------------------------------------------------------------------
# HLO text parsing
# ---------------------------------------------------------------------------
SAMPLE_HLO = """
HloModule jit_step

%region_0.10 (a: f32[], b: f32[]) -> f32[] {
  ROOT %add = f32[] add(f32[] %a, f32[] %b)
}

%cond.5 (arg: (s32[], f32[128])) -> pred[] {
  %c = s32[] constant(48)
  ROOT %lt = pred[] compare(s32[] %x, s32[] %c), direction=LT
}

%body.7 (arg: (s32[], f32[128])) -> (s32[], f32[128]) {
  %ar = f32[128]{0} all-reduce(f32[128]{0} %g), channel_id=2, replica_groups={{0,1,2,3},{4,5,6,7}}, use_global_device_ids=true, to_apply=%region_0.10
  ROOT %t = (s32[], f32[128]) tuple(%i, %ar)
}

ENTRY %main (p: f32[512,256]) -> f32[512,256] {
  %ag = f32[512,256]{1,0} all-gather(f32[32,256]{1,0} %p), channel_id=1, replica_groups=[16,16]<=[256], dimensions={0}, use_global_device_ids=true
  %w = (s32[], f32[128]) while((s32[], f32[128]) %init), condition=%cond.5, body=%body.7
  ROOT %r = f32[512,256]{1,0} copy(%ag)
}
"""


def test_parse_collectives_and_trip_counts():
    mesh_axes = {"data": 16, "model": 16}
    ops = costmodel.parse_hlo_collectives(SAMPLE_HLO, mesh_axes)
    kinds = {o.kind for o in ops}
    assert kinds == {"all-gather", "all-reduce"}
    ar = next(o for o in ops if o.kind == "all-reduce")
    ag = next(o for o in ops if o.kind == "all-gather")
    # the all-reduce sits in a while body with trip count 48
    assert ar.trip_count == 48
    assert ag.trip_count == 1
    # group {0..3} varies only the model (innermost) axis
    assert ar.axes == ("model",)
    # iota groups [16,16]<=[256]: 16 consecutive ids -> model axis
    assert ag.axes == ("model",)
    # wire bytes: all-reduce 2(n-1)/n * payload * trips
    assert math.isclose(ar.wire_bytes,
                        2 * 3 / 4 * 128 * 4 * 48, rel_tol=1e-6)


def test_iota_replica_group_transpose():
    hlo = """
ENTRY %main (p: f32[64]) -> f32[64] {
  ROOT %ar = f32[64]{0} all-reduce(f32[64]{0} %p), replica_groups=[16,16]<=[16,16]T(1,0), to_apply=%add
}
"""
    ops = costmodel.parse_hlo_collectives(hlo, {"data": 16, "model": 16})
    assert len(ops) == 1
    # transposed iota: groups stride 16 -> data (outer) axis
    assert ops[0].axes == ("data",)


def test_shape_bytes_tuple():
    assert costmodel._shape_bytes("(f32[128], bf16[64,2])") == \
        128 * 4 + 64 * 2 * 2


# ---------------------------------------------------------------------------
# analytic FLOPs vs XLA cost analysis (single device, no sharding)
# ---------------------------------------------------------------------------
def test_analytic_flops_close_to_hlo_on_dense_matmul():
    """XLA's flops for a pure matmul == 2*M*N*K; our conventions match."""
    M, N, K = 128, 256, 512
    f = jax.jit(lambda a, b: a @ b)
    lowered = f.lower(jax.ShapeDtypeStruct((M, K), jnp.float32),
                      jax.ShapeDtypeStruct((K, N), jnp.float32))
    ca = lowered.compile().cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    assert math.isclose(float(ca["flops"]), 2 * M * N * K, rel_tol=0.01)


def test_model_flops_6nd():
    cfg = get_config("llama3.2-3b")
    sh = SHAPES["train_4k"]
    mf = costmodel.model_flops(cfg, sh)
    assert math.isclose(mf, 6 * cfg.active_param_count() * sh.tokens,
                        rel_tol=1e-9)


def test_step_flops_remat_multiplier():
    cfg = get_config("qwen2-0.5b")
    sh = SHAPES["train_4k"]
    p_none = PolicyConfig(remat="none")
    p_blk = PolicyConfig(remat="block")
    f0 = costmodel.step_flops(cfg, sh, p_none)
    f1 = costmodel.step_flops(cfg, sh, p_blk)
    assert math.isclose(f1 / f0, 4.0 / 3.0, rel_tol=1e-9)


# ---------------------------------------------------------------------------
# fabric pricing reproduces the paper's orderings
# ---------------------------------------------------------------------------
def _report_with_collectives(frac_collective: float) -> costmodel.CostReport:
    r = costmodel.CostReport(
        arch="x", shape="train_4k", mesh={"data": 16, "model": 16},
        flops_hlo=1e12, flops_analytic=256e12, model_flops=200e12,
        hbm_bytes=1e9, peak_memory=None)
    wire = frac_collective * 1e9
    r.collectives = [costmodel.CollectiveOp("all-reduce", wire, 16,
                                            ("data",))]
    return r


def test_fabric_pricing_order_local_hybrid_falcon():
    """Fig 11's ordering: localGPUs <= hybridGPUs <= falconGPUs, and the
    overhead grows with communication fraction (model size proxy)."""
    systems = {name: compose.preset(name)
               for name in ("localGPUs", "hybridGPUs", "falconGPUs")}
    small = costmodel.price_on_fabrics(_report_with_collectives(0.1),
                                       systems, overlap=0.0)
    large = costmodel.price_on_fabrics(_report_with_collectives(30.0),
                                       systems, overlap=0.0)
    assert small["localGPUs"] <= small["hybridGPUs"] + 1e-12
    assert small["hybridGPUs"] <= small["falconGPUs"] + 1e-12
    ovh_small = small["falconGPUs"] / small["localGPUs"]
    ovh_large = large["falconGPUs"] / large["localGPUs"]
    assert ovh_large > ovh_small          # overhead grows with comm volume


def test_roofline_dominant_term():
    sys_ = compose.preset("localGPUs")
    r = _report_with_collectives(1e5)     # huge collective volume
    rl = costmodel.roofline(r, sys_)
    assert rl.dominant == "collective"
    assert rl.collective_s > rl.compute_s
    r2 = _report_with_collectives(0.0)
    rl2 = costmodel.roofline(r2, sys_)
    assert rl2.dominant in ("compute", "memory")
