"""End-to-end system behaviour: training convergence, optimizer ladder,
sharded lowering on a small in-process mesh, serving consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.base import PolicyConfig, ShapeConfig
from repro.core import policy as pol
from repro.data import make_batch
from repro.models import lm
from repro.models.transformer import RunCtx
from repro.optim import AdamWConfig, ScheduleConfig, lr_at
from repro.serve import Request, ServeEngine
from repro.train import trainer

SHAPE = ShapeConfig("t", 64, 4, "train")
BASE = PolicyConfig(compute_dtype="float32", remat="none",
                    attn_impl="full", zero_stage=0)


def test_training_reduces_loss(rng):
    cfg = reduced(get_config("llama3.2-3b"))
    state = trainer.init_state(rng, cfg, BASE, AdamWConfig(lr=1e-3))
    step = jax.jit(trainer.make_train_step(cfg, BASE, AdamWConfig(lr=1e-3)))
    losses = []
    for i in range(8):
        state, m = step(state, make_batch(cfg, SHAPE, step=i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses


def test_grad_accum_matches_full_batch(rng):
    """2-way accumulation == single large batch (same data)."""
    cfg = reduced(get_config("qwen2-0.5b"))
    p1 = BASE
    p2 = dataclasses.replace(BASE, grad_accum=2)
    s1 = trainer.init_state(rng, cfg, p1, AdamWConfig(lr=1e-3))
    s2 = trainer.init_state(rng, cfg, p2, AdamWConfig(lr=1e-3))
    batch = make_batch(cfg, SHAPE)
    f1 = jax.jit(trainer.make_train_step(cfg, p1, AdamWConfig(lr=1e-3)))
    f2 = jax.jit(trainer.make_train_step(cfg, p2, AdamWConfig(lr=1e-3)))
    s1, m1 = f1(s1, batch)
    s2, m2 = f2(s2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               atol=1e-5)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_remat_does_not_change_loss(rng):
    cfg = reduced(get_config("llama3.2-3b"))
    batch = make_batch(cfg, SHAPE)
    out = {}
    for remat in ("none", "block"):
        p = dataclasses.replace(BASE, remat=remat)
        state = trainer.init_state(rng, cfg, p, AdamWConfig(lr=1e-3))
        f = jax.jit(trainer.make_train_step(cfg, p, AdamWConfig(lr=1e-3)))
        _, m = f(state, batch)
        out[remat] = float(m["loss"])
    assert out["none"] == pytest.approx(out["block"], abs=1e-5)


def test_bf16_close_to_fp32(rng):
    cfg = reduced(get_config("qwen2-0.5b"))
    batch = make_batch(cfg, SHAPE)
    losses = {}
    for dt in ("float32", "bfloat16"):
        p = dataclasses.replace(BASE, compute_dtype=dt)
        state = trainer.init_state(rng, cfg, p, AdamWConfig(lr=1e-3))
        f = jax.jit(trainer.make_train_step(cfg, p, AdamWConfig(lr=1e-3)))
        _, m = f(state, batch)
        losses[dt] = float(m["loss"])
    assert abs(losses["bfloat16"] - losses["float32"]) < 0.05


def test_schedule_shapes():
    cfg = ScheduleConfig(kind="cosine", peak_lr=1e-3, warmup_steps=10,
                         total_steps=100, min_ratio=0.1)
    assert float(lr_at(0, cfg)) == 0.0
    assert float(lr_at(10, cfg)) == pytest.approx(1e-3)
    assert float(lr_at(100, cfg)) == pytest.approx(1e-4, rel=1e-2)
    assert float(lr_at(55, cfg)) < 1e-3


def test_sharded_lowering_tiny_mesh(rng):
    """The full policy pipeline lowers under a real (1,1) mesh in-process —
    the same code path the 512-device dry-run exercises."""
    cfg = reduced(get_config("llama3.2-3b"))
    policy = PolicyConfig(compute_dtype="float32", remat="block",
                          attn_impl="xla", zero_stage=3)
    mesh = jax.sharding.Mesh(
        np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    state = trainer.init_state(rng, cfg, policy, AdamWConfig())
    step = trainer.make_train_step(cfg, policy, AdamWConfig(), mesh=mesh)
    jitted = trainer.jit_train_step(step, state, cfg, policy, mesh,
                                    make_batch(cfg, SHAPE))
    with mesh:
        new_state, m = jitted(state, make_batch(cfg, SHAPE))
    assert bool(jnp.isfinite(m["loss"]))


def test_serve_greedy_matches_teacher_forcing(rng):
    """Engine's greedy continuation == argmax of the full forward pass."""
    cfg = reduced(get_config("qwen2-0.5b"))
    params = lm.init_lm(rng, cfg)
    policy = PolicyConfig(compute_dtype="float32", remat="none",
                          attn_impl="full")
    eng = ServeEngine(cfg, params, policy, n_slots=1, max_seq=64)
    prompt = jax.random.randint(rng, (16,), 0, cfg.vocab_size)
    req = Request(0, prompt, max_new=4)
    eng.add_request(req)
    while not req.done:
        eng.step()
    ctx = RunCtx(compute_dtype=jnp.float32, attn_impl="full", remat="none")
    toks = list(np.asarray(prompt))
    for t, expect in enumerate(req.out):
        logits, _, _ = lm.forward(params, jnp.asarray([toks]), cfg, ctx)
        nxt = int(jnp.argmax(logits[0, -1]))
        assert nxt == expect, (t, nxt, expect)
        toks.append(nxt)
