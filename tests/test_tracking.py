"""Tracking-plane coverage: run streams, trajectories, and the perf gate.

Pins the tentpole contracts of the ``repro.tracking`` plane:

  * JSONL round-trip — every record kind survives a write/read cycle
    with ``schema_version`` stamped and steps monotonic;
  * deterministic run ids under clock + seed injection;
  * trajectory appends are idempotent per run id and atomic;
  * the gate passes inside the noise band, catches a 20% regression in
    either direction, and never gates ``info`` metrics;
  * ``scripts/check_perf.py`` exits 0 on a healthy history, non-zero on
    a regression (naming the metric), and its ``--demo-regression``
    self-test passes.
"""
import json
import os
import subprocess
import sys

import pytest

import repro.tracking as tracking
from repro.tracking import gate, trajectory

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CHECK_PERF = os.path.join(ROOT, "scripts", "check_perf.py")


def _clock(t0=1_754_000_000.0, dt=1.0):
    """Deterministic injectable clock: t0, t0+dt, t0+2dt, ..."""
    state = {"n": -1}

    def tick():
        state["n"] += 1
        return t0 + state["n"] * dt
    return tick


# ---------------------------------------------------------------------------
# run ids + event stream round-trip
# ---------------------------------------------------------------------------
def test_run_id_deterministic_under_seed():
    a = tracking.make_run_id("cluster_sim", 1_754_000_000.0, seed=7)
    b = tracking.make_run_id("cluster_sim", 1_754_000_000.0, seed=7)
    assert a == b
    assert a.startswith("cluster_sim-")
    assert tracking.make_run_id("cluster_sim", 1_754_000_000.0, seed=8) != a
    # slashes/spaces never leak into the directory name
    assert "/" not in tracking.make_run_id("a/b c", 0.0, seed=1)


def test_event_stream_roundtrip(tmp_path):
    run = tracking.Run("demo", config={"lr": 3e-4}, tags=("t1",),
                       dir=str(tmp_path), run_id="demo-0", sha="abc1234",
                       clock=_clock())
    run.log({"loss": 2.5})
    run.log({"loss": 2.1}, step=5)
    run.log_event("evict", {"job": "j0"}, sim_t=12.5)
    run.log_system({"sim.auu": 0.4})
    run.log_summary({"final_loss": 2.1})
    run.finish()
    events = tracking.read_events(run.path)
    kinds = [e["kind"] for e in events]
    assert kinds == ["run", "metrics", "metrics", "event", "system",
                     "summary", "summary", "finish"]
    head = events[0]
    assert head["schema_version"] == tracking.SCHEMA_VERSION == 1
    assert head["run_id"] == "demo-0"
    assert head["git_sha"] == "abc1234"
    assert head["config"] == {"lr": 3e-4}
    assert events[1]["step"] == 1
    assert events[2]["step"] == 5            # explicit step honoured
    assert events[3]["sim_t"] == 12.5
    assert events[4]["metrics"] == {"sim.auu": 0.4}
    assert events[-2]["summary"] == {"final_loss": 2.1}
    assert events[-1]["status"] == "ok"
    # injected clock: strictly increasing wall-clock per record
    ts = [e["t"] for e in events if "t" in e]
    assert ts == sorted(ts)


def test_steps_are_monotonic(tmp_path):
    run = tracking.Run("m", dir=str(tmp_path), run_id="m-0", sha="")
    assert run.log({"x": 1.0}, step=10) == 10
    assert run.log({"x": 2.0}, step=3) == 11   # backwards step -> +1
    assert run.log({"x": 3.0}) == 12
    run.finish()


def test_log_after_finish_is_noop_and_current_run_cleared(tmp_path):
    run = tracking.init("p", dir=str(tmp_path), run_id="p-0", sha="")
    assert tracking.current_run() is run
    run.finish()
    assert tracking.current_run() is None
    run.log({"x": 1.0})                        # silently dropped
    assert [e["kind"] for e in tracking.read_events(run.path)] == \
        ["run", "finish"]


def test_context_manager_records_error_status(tmp_path):
    with pytest.raises(RuntimeError):
        with tracking.Run("e", dir=str(tmp_path), run_id="e-0", sha="") as r:
            r.log({"x": 1.0})
            raise RuntimeError("boom")
    assert tracking.read_events(r.path)[-1]["status"] == "error"


def test_crashed_stream_leaves_readable_prefix(tmp_path):
    run = tracking.Run("c", dir=str(tmp_path), run_id="c-0", sha="")
    run.log({"x": 1.0})                        # never finish()ed
    events = tracking.read_events(run.path)    # flushed per line
    assert [e["kind"] for e in events] == ["run", "metrics"]
    run.finish()


# ---------------------------------------------------------------------------
# samplers
# ---------------------------------------------------------------------------
def test_proc_sampler_reports_rss_and_cpu():
    s = tracking.ProcSampler()
    out = s.sample()
    if not out:                                # no procfs on this host
        pytest.skip("procfs unavailable")
    assert out["proc.rss_mb"] > 0
    assert out["proc.cpu_s"] >= 0


def test_counter_sampler_prefixes(tmp_path):
    s = tracking.CounterSampler(prefix="sim", initial={"auu": 0.5})
    s.update({"pool_utilization": 0.9})
    assert s.sample() == {"sim.auu": 0.5, "sim.pool_utilization": 0.9}
    run = tracking.Run("s", dir=str(tmp_path), run_id="s-0", sha="",
                       samplers=[s])
    merged = run.log_system({"extra": 1.0})
    assert merged == {"sim.auu": 0.5, "sim.pool_utilization": 0.9,
                      "extra": 1.0}
    run.finish()


# ---------------------------------------------------------------------------
# trajectories: idempotent append, spec refresh
# ---------------------------------------------------------------------------
SPEC = {"makespan_s": {"direction": "down"},
        "throughput": {"direction": "up"},
        "wall_s": {"direction": "info"}}


def _append(path, run_id, ts, metrics, spec=SPEC):
    return trajectory.append_summary(
        str(path), "toy", spec, run_id=run_id, git_sha="cafe123",
        ts=ts, metrics=metrics)


def test_append_is_idempotent_per_run_id(tmp_path):
    p = tmp_path / "BENCH_toy.json"
    _append(p, "r1", 1.0, {"makespan_s": 100.0, "throughput": 10.0})
    _append(p, "r2", 2.0, {"makespan_s": 101.0, "throughput": 10.1})
    traj = _append(p, "r2", 3.0, {"makespan_s": 99.0, "throughput": 10.2})
    rows = traj["rows"]
    assert [r["run_id"] for r in rows] == ["r1", "r2"]   # replaced, not dup
    assert rows[1]["metrics"]["makespan_s"] == 99.0
    assert traj["schema_version"] == trajectory.SCHEMA_VERSION
    assert traj["bench"] == "toy"
    # no .tmp litter from the atomic write
    assert sorted(os.listdir(tmp_path)) == ["BENCH_toy.json"]


def test_append_refreshes_spec_and_filters_unknown_metrics(tmp_path):
    p = tmp_path / "BENCH_toy.json"
    _append(p, "r1", 1.0, {"makespan_s": 100.0, "bogus": 1.0})
    spec2 = {"makespan_s": {"direction": "down", "band": 0.25}}
    traj = _append(p, "r2", 2.0, {"makespan_s": 90.0}, spec=spec2)
    assert traj["metrics"] == spec2            # spec ships with the code
    assert "bogus" not in traj["rows"][0]["metrics"]


# ---------------------------------------------------------------------------
# gate semantics
# ---------------------------------------------------------------------------
def _traj(rows, spec=SPEC, baseline=None):
    return {"schema_version": 1, "bench": "toy", "metrics": spec,
            "baseline_run_id": baseline,
            "rows": [{"run_id": f"r{i}", "git_sha": "", "ts": float(i),
                      "metrics": m} for i, m in enumerate(rows)]}


def test_gate_fresh_baseline_and_in_band_pass():
    # single row: nothing to regress against
    one = gate.check_trajectory(_traj(
        [{"makespan_s": 100.0, "throughput": 10.0}]))
    assert not any(v.regressed for v in one)
    # 5% drift on a down-metric stays inside the ±10% band
    vs = gate.check_trajectory(_traj(
        [{"makespan_s": 100.0, "throughput": 10.0}] * 5
        + [{"makespan_s": 105.0, "throughput": 9.5}]))
    assert not any(v.regressed for v in vs)


def test_gate_catches_20pct_regression_both_directions():
    vs = gate.check_trajectory(_traj(
        [{"makespan_s": 100.0, "throughput": 10.0, "wall_s": 1.0}] * 5
        + [{"makespan_s": 120.0, "throughput": 8.0, "wall_s": 99.0}]))
    bad = {v.metric for v in vs if v.regressed}
    assert bad == {"makespan_s", "throughput"}   # wall_s is info: never
    mk = next(v for v in vs if v.metric == "makespan_s")
    assert mk.baseline == pytest.approx(100.0)
    assert mk.delta_pct == pytest.approx(20.0)
    # improvements never trip the direction-aware gate
    ok = gate.check_trajectory(_traj(
        [{"makespan_s": 100.0, "throughput": 10.0}] * 5
        + [{"makespan_s": 80.0, "throughput": 12.0}]))
    assert not any(v.regressed for v in ok)


def test_gate_uses_median_of_trailing_window():
    # one noisy historical run must not poison the baseline
    rows = [{"makespan_s": 100.0}, {"makespan_s": 1000.0},
            {"makespan_s": 100.0}, {"makespan_s": 100.0},
            {"makespan_s": 100.0}, {"makespan_s": 105.0}]
    vs = gate.check_trajectory(_traj(rows))
    mk = next(v for v in vs if v.metric == "makespan_s")
    assert mk.baseline == pytest.approx(100.0)   # median, not mean
    assert not mk.regressed


def test_gate_missing_gated_metric_regresses():
    vs = gate.check_trajectory(_traj(
        [{"makespan_s": 100.0, "throughput": 10.0}] * 3
        + [{"makespan_s": 100.0}]))              # throughput vanished
    bad = next(v for v in vs if v.regressed)
    assert bad.metric == "throughput"
    assert "missing" in bad.note


def test_gate_per_metric_band_override():
    spec = {"makespan_s": {"direction": "down", "band": 0.50}}
    vs = gate.check_trajectory(_traj(
        [{"makespan_s": 100.0}] * 3 + [{"makespan_s": 130.0}], spec=spec))
    assert not any(v.regressed for v in vs)      # +30% < the 50% band


def test_update_baseline_anchors_window():
    # a 2x intentional change: regressed against the old history...
    rows = [{"makespan_s": 100.0}] * 5 + [{"makespan_s": 200.0}]
    traj = _traj(rows, spec={"makespan_s": {"direction": "down"}})
    assert any(v.regressed for v in gate.check_trajectory(traj))
    # ...anchoring at the newest row accepts it
    gate.update_baseline(traj)
    assert traj["baseline_run_id"] == "r5"
    assert not any(v.regressed for v in gate.check_trajectory(traj))
    # and the next in-band row gates against the new anchor only
    traj["rows"].append({"run_id": "r6", "git_sha": "", "ts": 6.0,
                         "metrics": {"makespan_s": 205.0}})
    vs = gate.check_trajectory(traj)
    mk = next(v for v in vs if v.metric == "makespan_s")
    assert mk.baseline == pytest.approx(200.0) and not mk.regressed


# ---------------------------------------------------------------------------
# scripts/check_perf.py end-to-end
# ---------------------------------------------------------------------------
def _check_perf(results_dir, *argv):
    return subprocess.run(
        [sys.executable, CHECK_PERF, "--results-dir", str(results_dir),
         *argv], capture_output=True, text=True)


def test_check_perf_cli_gate_and_demo(tmp_path):
    p = tmp_path / "BENCH_toy.json"
    for i in range(5):
        _append(p, f"r{i}", float(i),
                {"makespan_s": 100.0 + i, "throughput": 10.0, "wall_s": 1.0})
    out = _check_perf(tmp_path)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "check_perf: OK" in out.stdout
    # a 20% regression exits non-zero and names the metric
    _append(p, "bad", 9.0,
            {"makespan_s": 125.0, "throughput": 10.0, "wall_s": 1.0})
    out = _check_perf(tmp_path)
    assert out.returncode == 1
    assert "toy/makespan_s" in out.stdout
    assert "REGRESSED" in out.stdout
    # --update-baseline accepts the change; the gate is green again
    assert _check_perf(tmp_path, "--update-baseline").returncode == 0
    assert trajectory.load(str(p))["baseline_run_id"] == "bad"
    assert _check_perf(tmp_path).returncode == 0
    # the self-test proves the gate still trips on synthetic data
    out = _check_perf(tmp_path, "--demo-regression")
    assert out.returncode == 0, out.stdout + out.stderr
    assert "demo OK" in out.stdout
    # ... without touching the real trajectory
    assert trajectory.load(str(p))["rows"][-1]["run_id"] == "bad"


def test_check_perf_cli_empty_dir_passes(tmp_path):
    assert _check_perf(tmp_path).returncode == 0


def test_check_perf_demo_survives_spiky_rows_and_wide_bands(tmp_path):
    """Self-test regression: the synthetic degradation must beat the
    gate's *median* baseline and each metric's *own* band.  A newest
    row sitting above the median (kernel_tune's 6,6,7 case counts) or
    a wide custom band (serve_bench's wall-clock throughput at 0.5)
    used to absorb the flat 20%-off-the-last-row nudge and falsely
    fail the demo."""
    spec = {"n_cases": {"direction": "up"},
            "throughput": {"direction": "up", "band": 0.5}}
    p = tmp_path / "BENCH_toy.json"
    for i, n in enumerate((6.0, 6.0, 7.0)):
        _append(p, f"r{i}", float(i),
                {"n_cases": n, "throughput": 40.0}, spec=spec)
    out = _check_perf(tmp_path, "--demo-regression")
    assert out.returncode == 0, out.stdout + out.stderr
    assert "demo OK" in out.stdout and "demo FAIL" not in out.stdout


# ---------------------------------------------------------------------------
# producer integration: bench specs + the simulator telemetry mirror
# ---------------------------------------------------------------------------
def test_bench_trajectory_specs_are_wellformed():
    from benchmarks import cluster_sim, storage_bench
    for mod in (cluster_sim, storage_bench):
        assert mod.TRAJECTORY
        for name, m in mod.TRAJECTORY.items():
            assert m["direction"] in ("up", "down", "info"), (mod, name)


def test_cluster_sim_trajectory_row_from_shipped_artifact():
    path = os.path.join(ROOT, "results", "cluster_sim.json")
    if not os.path.exists(path):
        pytest.skip("cluster_sim artifact not generated")
    from benchmarks import cluster_sim
    with open(path) as f:
        row = cluster_sim.trajectory_row(json.load(f))
    assert set(row) == set(cluster_sim.TRAJECTORY)
    assert all(isinstance(v, float) for v in row.values())
    assert row["makespan_s"] > 0


def test_simulator_mirrors_telemetry_into_current_run(tmp_path):
    from repro.cluster import ClusterSimulator, TraceConfig
    cfg = TraceConfig(n_jobs=4, arrival_rate_hz=0.5, seed=3, failures=())
    baseline = ClusterSimulator(cfg).run()
    run = tracking.init("sim-test", dir=str(tmp_path), run_id="sim-0",
                        sha="")
    tracked = ClusterSimulator(cfg).run()
    run.finish()
    # the mirror never perturbs the deterministic report
    assert tracked == baseline
    events = tracking.read_events(run.path)
    metrics = [e for e in events if e["kind"] == "metrics"]
    assert metrics and metrics[-1]["metrics"]["makespan_s"] == \
        pytest.approx(baseline["makespan_s"])
    system = [e for e in events if e["kind"] == "system"]
    assert any("sim.auu" in e["metrics"] for e in system)
