"""Int8 error-feedback gradient exchange: unit + small-mesh integration.

The 512-virtual-device compile of this path segfaults inside XLA:CPU's
compilation cache (environment limitation, not a program error — noted in
EXPERIMENTS.md §Dry-run); the sharded semantics are validated here on an
8-device (2,2,2) host mesh in a subprocess.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.compress import ef_compress_leaf, int8_decode, int8_encode


def test_int8_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (1024,)) * 3.0
    q, scale = int8_encode(x)
    err = jnp.abs(int8_decode(q, scale) - x)
    assert float(jnp.max(err)) <= float(scale) / 2 + 1e-6


def test_error_feedback_carries_residual():
    """Sum of (quantized + residual) over steps tracks the true sum."""
    key = jax.random.PRNGKey(1)
    r = jnp.zeros((256,))
    true_sum = jnp.zeros((256,))
    sent_sum = jnp.zeros((256,))
    for i in range(20):
        g = jax.random.normal(jax.random.fold_in(key, i), (256,)) * 0.01
        true_sum = true_sum + g
        q, scale, r = ef_compress_leaf(g, r)
        sent_sum = sent_sum + int8_decode(q, scale)
    # residual bounds the drift: |true - sent| == |final residual|
    np.testing.assert_allclose(np.asarray(true_sum - sent_sum),
                               np.asarray(r), atol=1e-5)


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp
    import dataclasses
    from repro.configs import get_config, reduced
    from repro.configs.base import PolicyConfig, ShapeConfig
    from repro.data import make_batch
    from repro.optim import AdamWConfig
    from repro.train import trainer
    from repro.core import policy as pol

    cfg = reduced(get_config("qwen2-0.5b"))
    shape = ShapeConfig("t", 32, 8, "train")
    mesh = jax.sharding.Mesh(
        np.asarray(jax.devices()).reshape(2, 2, 2), ("pod", "data", "model"))
    base = PolicyConfig(compute_dtype="float32", remat="none",
                        attn_impl="full", zero_stage=0,
                        dp_axes=("pod", "data"))
    comp = dataclasses.replace(base, grad_compression="int8_ef")
    batch = make_batch(cfg, shape)
    out = {}
    for name, policy in (("plain", base), ("int8", comp)):
        state = trainer.init_state(jax.random.PRNGKey(0), cfg, policy,
                                   AdamWConfig(lr=1e-3), n_pods=2)
        step = trainer.make_train_step(cfg, policy, AdamWConfig(lr=1e-3),
                                       mesh=mesh)
        jitted = trainer.jit_train_step(step, state, cfg, policy, mesh,
                                        batch)
        with mesh:
            for i in range(3):
                state, m = jitted(state, make_batch(cfg, shape, step=i))
        out[name] = float(m["loss"])
    print("LOSSES", out["plain"], out["int8"])
    assert abs(out["plain"] - out["int8"]) < 0.05, out
    print("INT8_POD_EXCHANGE_OK")
""")


# The known XLA C++-level abort (not a Python exception) seen on some
# jax/XLA:CPU builds when compiling the partially-manual pod exchange.
# ONLY this fingerprint counts as the environment limitation — any other
# crash (new segfault, Python exception) still fails the test, so real
# regressions stay visible.
_XLA_ABORT_SIG = "Check failed: sharding.IsManualSubgroup()"


@pytest.mark.slow
def test_int8_pod_exchange_small_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                       capture_output=True, text=True, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    if "INT8_POD_EXCHANGE_OK" not in r.stdout \
            and _XLA_ABORT_SIG in r.stderr:
        pytest.xfail("XLA:CPU aborts compiling the manual-pod exchange on "
                     "this jax build (environment limitation): "
                     f"rc={r.returncode} "
                     + (r.stderr.strip().splitlines() or ["<no stderr>"]
                        )[-1][:200])
    assert "INT8_POD_EXCHANGE_OK" in r.stdout, (r.stdout[-2000:],
                                                r.stderr[-2000:])
