"""Paper-benchmark fidelity: Table II parameter counts + trainability."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.paper_bench import (BERT_BASE, BERT_LARGE, MOBILENETV2,
                                       PAPER_WORKLOADS, RESNET50, YOLOV5L)
from repro.models import bert, vision
from repro.models.transformer import RunCtx

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("cfg,expected,tol", [
    (MOBILENETV2, 3.4e6, 0.05), (RESNET50, 25.6e6, 0.01),
    (YOLOV5L, 47e6, 0.02)])
def test_vision_param_counts_table2(cfg, expected, tol):
    params = vision.init_vision(KEY, cfg)
    n = vision.param_count(params)
    assert abs(n - expected) / expected < tol, (cfg.name, n)


@pytest.mark.parametrize("cfg,expected", [
    (BERT_BASE, 110e6), (BERT_LARGE, 340e6)])
def test_bert_param_counts_table2(cfg, expected):
    assert abs(cfg.param_count() - expected) / expected < 0.03


@pytest.mark.parametrize("cfg", [MOBILENETV2, RESNET50])
def test_vision_train_step(cfg):
    params = vision.init_vision(KEY, cfg)
    imgs = jax.random.normal(KEY, (2, 64, 64, 3))
    labels = jnp.asarray([1, 2])
    loss, grads = jax.value_and_grad(vision.vision_loss)(
        params, {"images": imgs, "labels": labels}, cfg)
    assert bool(jnp.isfinite(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert gn > 0


def test_yolo_forward_scales():
    params = vision.init_yolov5l(KEY, num_classes=80)
    imgs = jax.random.normal(KEY, (1, 128, 128, 3))
    outs = vision.apply_yolov5l(params, imgs)
    assert len(outs) == 3
    # strides 8, 16, 32
    assert outs[0].shape[1] == 16 and outs[1].shape[1] == 8 \
        and outs[2].shape[1] == 4
    assert all(o.shape[-1] == 3 * 85 for o in outs)


def test_bert_qa_loss():
    import dataclasses
    cfg = dataclasses.replace(BERT_BASE, n_layers=2, d_model=64, n_heads=4,
                              n_kv_heads=4, d_ff=128, vocab_size=512,
                              block_pattern=("attn",) * 2, max_seq=64)
    params = bert.init_bert_qa(KEY, cfg)
    ctx = RunCtx(compute_dtype=jnp.float32, attn_impl="full", remat="none")
    B, S = 2, 32
    batch = {
        "tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size),
        "start": jnp.asarray([3, 7]), "end": jnp.asarray([5, 9]),
        "segments": jnp.zeros((B, S), jnp.int32),
    }
    loss, _ = bert.qa_loss(params, batch, cfg, ctx)
    assert bool(jnp.isfinite(loss))
    g = jax.grad(lambda p: bert.qa_loss(p, batch, cfg, ctx)[0])(params)
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(g))


def test_workloads_table_complete():
    names = {w.name for w in PAPER_WORKLOADS}
    assert names == {"mobilenetv2", "resnet50", "yolov5l", "bert-base",
                     "bert-large"}
