"""Storage composability: tranche leasing, bandwidth partitioning, the
MLPerf-Storage-style trace generator, and the simulator's input-stall
telemetry.  (No hypothesis dependency — this file must collect
everywhere.)"""
import json

import numpy as np
import pytest

from repro.cluster.lease import LeaseManager, plan_tranche
from repro.cluster.scheduler import Job, Scheduler
from repro.cluster.simulator import (ClusterSimulator, JobTemplate,
                                     TraceConfig)
from repro.core import compose
from repro.core.compose import CompositionError
from repro.core.topology import DEFAULT_LINKS, LinkClass, make_pool
from repro.data.pipeline import (IOTraceGenerator, IOWorkload, StorageModel,
                                 lm_io_workload, workload_stall)
from repro.data.storage import (StoragePool, StorageTranche,
                                make_storage_pool)

HEAVY_IO = IOWorkload("heavy", 1e6, 0.3e6, batch_size=512,
                      samples_per_epoch=1 << 14,
                      checkpoint_bytes=2e9, checkpoint_every=20)


def _pool(n_local=2, n_switch=1):
    return make_storage_pool(n_local=n_local, n_switch=n_switch)


# ---------------------------------------------------------------------------
# tranche lease lifecycle
# ---------------------------------------------------------------------------
def test_tranche_lease_round_trip():
    pool = _pool()
    lease = pool.lease("local-nvme-0", "job-a", capacity_bytes=1e12)
    assert lease.tranche == "local-nvme-0"
    assert pool.n_lessees("local-nvme-0") == 1
    assert pool.lessees("local-nvme-0") == ("job-a",)
    assert pool.tranches_of("job-a") == ["local-nvme-0"]
    assert pool.capacity_used("local-nvme-0") == 1e12
    assert pool.release("job-a") == ["local-nvme-0"]
    assert pool.n_lessees("local-nvme-0") == 0
    assert pool.release("job-a") == []       # idempotent


def test_double_claim_raises_composition_error():
    pool = _pool()
    pool.lease("local-nvme-0", "job-a")
    with pytest.raises(CompositionError):
        pool.lease("local-nvme-0", "job-a")  # leases don't stack
    # a different tranche for the same holder is fine (e.g. data + ckpt)
    pool.lease("local-nvme-1", "job-a")
    assert sorted(pool.tranches_of("job-a")) == ["local-nvme-0",
                                                 "local-nvme-1"]
    with pytest.raises(CompositionError):
        pool.lease("no-such-tranche", "job-a")


def test_exclusive_claims_conflict_both_ways():
    pool = _pool()
    pool.lease("falcon-nvme-0", "a")
    with pytest.raises(CompositionError):
        pool.lease("falcon-nvme-0", "b", exclusive=True)
    pool.lease("local-nvme-0", "c", exclusive=True)
    with pytest.raises(CompositionError):
        pool.lease("local-nvme-0", "d")      # shared under exclusive
    pool.check_invariants()


def test_capacity_oversubscription_raises_atomically():
    pool = StoragePool([StorageTranche("t", capacity_bytes=10e9)])
    pool.lease("t", "a", capacity_bytes=8e9)
    with pytest.raises(CompositionError):
        pool.lease("t", "b", capacity_bytes=4e9)
    assert pool.n_lessees("t") == 1          # failed claim left no trace
    pool.lease("t", "b", capacity_bytes=2e9)
    pool.check_invariants()


# ---------------------------------------------------------------------------
# bandwidth partitioning
# ---------------------------------------------------------------------------
def test_bandwidth_partitioned_across_lessees():
    pool = _pool()
    tr = "falcon-nvme-0"
    solo = pool.read_bw(tr)
    pool.lease(tr, "a")
    pool.lease(tr, "b")
    assert pool.read_bw(tr) == pytest.approx(solo / 2)
    for h in ("c", "d"):
        pool.lease(tr, h)
    assert pool.read_bw(tr) == pytest.approx(solo / 4)
    pool.release("a")
    assert pool.read_bw(tr) == pytest.approx(solo / 3)


def test_attach_link_ceiling_applies_before_partitioning():
    """A tranche faster than its attach fabric is fabric-bound."""
    fast = StorageTranche("fast", read_bw=1e12, attach=LinkClass.SWITCH)
    switch_bw = DEFAULT_LINKS[LinkClass.SWITCH].bandwidth
    assert fast.effective_read_bw(DEFAULT_LINKS) == pytest.approx(switch_bw)
    assert fast.effective_read_bw(DEFAULT_LINKS, 2) == \
        pytest.approx(switch_bw / 2)


def test_contended_stall_grows_with_lessees():
    step_s = 0.25
    stalls = []
    for n in (1, 2, 4):
        model = StorageModel(
            StorageTranche("t", attach=LinkClass.SWITCH).spec(),
            dict(DEFAULT_LINKS), n_lessees=n)
        stalls.append(workload_stall(HEAVY_IO, model, step_s))
    assert stalls[0] < stalls[1] < stalls[2]
    # 4-way sharing cannot be better than 4x the read time of 1-way
    assert stalls[2] > stalls[0]


# ---------------------------------------------------------------------------
# trace generator (per-epoch shuffled reads, record distributions, bursts)
# ---------------------------------------------------------------------------
def test_generator_deterministic_per_seed():
    a = IOTraceGenerator(HEAVY_IO, seed=3).read_trace(40)
    b = IOTraceGenerator(HEAVY_IO, seed=3).read_trace(40)
    np.testing.assert_array_equal(a, b)
    c = IOTraceGenerator(HEAVY_IO, seed=4).read_trace(40)
    assert not np.array_equal(a, c)


def test_generator_epochs_reshuffle_same_dataset():
    gen = IOTraceGenerator(HEAVY_IO, seed=0)
    e0, e1 = gen.epoch_order(0), gen.epoch_order(1)
    assert not np.array_equal(e0, e1)            # shuffled
    np.testing.assert_array_equal(np.sort(e0), np.sort(e1))  # same samples
    # record sizes are a dataset property: epoch totals are identical
    spe = HEAVY_IO.steps_per_epoch
    t0 = gen.read_trace(spe).sum()
    t1 = gen.read_trace(spe, start=spe).sum()
    assert t0 == pytest.approx(t1, rel=1e-3)
    # per-step bytes vary (record-size distribution, not a flat constant)
    assert np.std(gen.read_trace(32)) > 0


def test_checkpoint_write_bursts():
    gen = IOTraceGenerator(HEAVY_IO, seed=0)
    writes = [gen.step_write_bytes(t) for t in range(45)]
    assert writes[19] == HEAVY_IO.checkpoint_bytes
    assert writes[39] == HEAVY_IO.checkpoint_bytes
    assert sum(1 for w in writes if w > 0) == 2
    no_ckpt = IOTraceGenerator(IOWorkload("x", 1e3, 0, 4, 64), seed=0)
    assert all(no_ckpt.step_write_bytes(t) == 0 for t in range(40))


def test_lm_io_workload_shapes():
    from repro.configs import get_config
    from repro.configs.base import SHAPES
    cfg = get_config("qwen2-0.5b")
    train = lm_io_workload(cfg, SHAPES["train_4k"])
    assert train.record_bytes == (4096 + 1) * 4
    assert train.batch_size == 256
    assert train.checkpoint_bytes == pytest.approx(cfg.param_count() * 4.0)
    decode = lm_io_workload(cfg, SHAPES["decode_32k"])
    assert decode.record_bytes == 4.0            # per-token
    assert decode.checkpoint_every == 0


# ---------------------------------------------------------------------------
# compose() integration: a composition = devices + storage
# ---------------------------------------------------------------------------
def test_compose_leases_tranche_and_release_frees_it():
    dev = make_pool(n_local=8, n_switch=0, pods=1)
    st = _pool()
    sys_ = compose.compose(dev, "j", ("data",), (4,),
                           {"data": LinkClass.LOCAL},
                           storage_pool=st, tranche="falcon-nvme-0",
                           storage_capacity=1e12)
    assert sys_.tranche == "falcon-nvme-0"
    assert sys_.fabric.storage.name == "falcon-nvme-0"
    assert sys_.fabric.storage.attach == LinkClass.SWITCH
    assert st.lessees("falcon-nvme-0") == ("j",)
    compose.release(dev, sys_, storage_pool=st)
    assert st.n_lessees("falcon-nvme-0") == 0 and not dev.leases


def test_compose_storage_conflict_rolls_back_device_claim():
    dev = make_pool(n_local=8, n_switch=0, pods=1)
    st = StoragePool([StorageTranche("only", capacity_bytes=1e9)])
    st.lease("only", "other", exclusive=True)
    with pytest.raises(CompositionError):
        compose.compose(dev, "j", ("data",), (4,),
                        {"data": LinkClass.LOCAL},
                        storage_pool=st, tranche="only")
    assert not dev.leases                        # atomic rollback


def test_never_fitting_dataset_rejected_at_submit():
    """A dataset no tranche can EVER host must reject at submit (like an
    over-pool chip request), not livelock at the head of the queue
    raising a storage conflict on every poll."""
    dev = make_pool(n_local=256, n_switch=0, pods=1)
    sched = Scheduler(dev, storage=_pool())
    big = IOWorkload("big", 1e9, 0, batch_size=64,
                     samples_per_epoch=100_000)          # 100 PB dataset
    job = Job(name="j", arch="qwen2-0.5b", shape_name="train_4k",
              n_chips=16, steps=5, io=big)
    assert not sched.submit(job, 0.0)
    assert job.state == "rejected"
    assert "tranche" in job.why_rejected
    assert sched.poll(0.0) == [] and sched.manager.conflicts == 0


def test_plan_tranche_skips_exclusively_held():
    """An exclusively-held tranche must never be planned even when it has
    the fewest lessees — otherwise the claim raises on every poll and
    the job never starts despite a shareable alternative."""
    from repro.data.storage import StoragePool
    st = StoragePool([StorageTranche("a"), StorageTranche("b")])
    st.lease("a", "owner", exclusive=True)               # 1 lessee
    st.lease("b", "x")
    st.lease("b", "y")                                   # 2 lessees
    assert plan_tranche(st).name == "b"
    st.lease("b", "z", exclusive=False)
    with pytest.raises(CompositionError):
        # both unusable: a is exclusive, b lacks the capacity headroom
        plan_tranche(st, capacity_bytes=st.tranches["b"].capacity_bytes + 1)


def test_stall_dirty_stays_bounded_without_simulator():
    """A Scheduler driven directly (no simulator draining) must not grow
    stall_dirty without bound or pin completed jobs."""
    dev = make_pool(n_local=64, n_switch=0, pods=1)
    one = StoragePool([StorageTranche("shared", attach=LinkClass.SWITCH)])
    sched = Scheduler(dev, storage=one)
    for i in range(6):
        job = Job(name=f"j{i}", arch="qwen2-0.5b", shape_name="train_4k",
                  n_chips=16, steps=5, io=HEAVY_IO)
        sched.submit(job, float(i))
        sched.poll(float(i))
        if i % 2:
            sched.on_complete(sched.running[0], float(i) + 0.5)
    done_names = {j.name for j in sched.done}
    assert not done_names & set(sched.stall_dirty)       # no pinning
    assert len(sched.stall_dirty) <= len(sched.running)


def test_plan_tranche_prefers_idle_local_then_shares():
    st = make_storage_pool(n_local=1, n_switch=1)
    first = plan_tranche(st)
    assert first.attach == LinkClass.LOCAL
    st.lease(first.name, "a")
    second = plan_tranche(st)                    # idle switch > shared local
    assert second.name == "falcon-nvme-0"
    st.lease(second.name, "b")
    third = plan_tranche(st)                     # all busy: least-loaded
    assert st.n_lessees(third.name) == 1


def test_lease_manager_pools_storage_with_devices():
    dev = make_pool(n_local=16, n_switch=0, pods=1)
    st = _pool()
    mgr = LeaseManager(dev, st)
    sys_ = compose.compose(dev, "j", ("data",), (4,),
                           {"data": LinkClass.LOCAL})
    mgr.adopt(sys_, now=1.0)
    mgr.acquire_tranche("j", "local-nvme-0", capacity_bytes=1e12, now=1.0)
    with pytest.raises(CompositionError):
        mgr.acquire_tranche("j", "local-nvme-0")     # double claim
    mgr.check_exclusive()
    mgr.release("j")                             # devices AND storage
    assert not dev.leases and st.n_lessees("local-nvme-0") == 0


# ---------------------------------------------------------------------------
# scheduler: admission-to-run requires a storage lease; stalls follow
# contention
# ---------------------------------------------------------------------------
def test_scheduler_start_acquires_and_complete_releases_tranche():
    dev = make_pool(n_local=32, n_switch=0, pods=1)
    sched = Scheduler(dev, storage=_pool())
    job = Job(name="j", arch="qwen2-0.5b", shape_name="train_4k",
              n_chips=16, steps=5)
    assert sched.submit(job, 0.0)
    assert job.io is not None                    # defaulted from the cell
    sched.poll(0.0)
    assert job.system.tranche is not None
    assert sched.storage.tranches_of("j") == [job.system.tranche]
    sched.manager.check_exclusive()
    sched.on_complete(job, 10.0)
    assert sched.storage.tranches_of("j") == []


def test_scheduler_co_tenants_stall_more_than_solo():
    dev = make_pool(n_local=64, n_switch=0, pods=1)
    one_tranche = StoragePool([StorageTranche("shared",
                                              attach=LinkClass.SWITCH)])
    sched = Scheduler(dev, storage=one_tranche)
    jobs = [Job(name=f"j{i}", arch="qwen2-0.5b", shape_name="train_4k",
                n_chips=16, steps=5, io=HEAVY_IO) for i in range(2)]
    sched.submit(jobs[0], 0.0)
    sched.poll(0.0)
    solo_stall = jobs[0].input_stall_s
    assert solo_stall > 0                        # heavy reads don't hide
    sched.submit(jobs[1], 1.0)
    sched.poll(1.0)
    assert one_tranche.n_lessees("shared") == 2
    assert jobs[0].input_stall_s > solo_stall    # co-tenant slows it down
    assert jobs[1].input_stall_s == pytest.approx(jobs[0].input_stall_s)
    assert jobs[0].step_s == pytest.approx(
        jobs[0].plan.step_s + jobs[0].input_stall_s)
    sched.on_complete(jobs[1], 5.0)
    assert jobs[0].input_stall_s == pytest.approx(solo_stall)


def test_restore_priced_against_contended_tranche():
    """Regression (ROADMAP storage follow-up): a checkpoint restore
    reads through the tranche the job holds, at the *contended*
    per-lessee bandwidth — not the uncontended tier rate Job.est_restore_s
    assumes.  With 2 lessees on one tranche the restore takes 2x."""
    dev = make_pool(n_local=64, n_switch=0, pods=1)
    one_tranche = StoragePool([StorageTranche("shared")])
    sched = Scheduler(dev, storage=one_tranche)
    jobs = [Job(name=f"j{i}", arch="qwen2-0.5b", shape_name="train_4k",
                n_chips=16, steps=10) for i in range(2)]
    for j in jobs:
        sched.submit(j, 0.0)
    sched.poll(0.0)
    assert one_tranche.n_lessees("shared") == 2
    job = jobs[0]
    job.steps_done = 4.0                 # a resume has progress to restore
    uncontended = job.est_restore_s()
    contended = sched.restore_s(job)
    assert uncontended > 0
    assert contended == pytest.approx(2.0 * uncontended)
    # the simulator prices restores through the scheduler's view
    from repro.cluster.simulator import restore_overhead_s
    assert restore_overhead_s(job, sched) == pytest.approx(contended)
    assert restore_overhead_s(job) == pytest.approx(uncontended)
    # a job with no progress restores nothing; a queued job (no tranche)
    # falls back to the uncontended placement-unknown estimate
    job.steps_done = 0.0
    assert sched.restore_s(job) == 0.0
    queued = Job(name="q", arch="qwen2-0.5b", shape_name="train_4k",
                 n_chips=16, steps=10, steps_done=4.0)
    assert sched.restore_s(queued) == pytest.approx(queued.est_restore_s())


def test_preempt_restart_pays_contended_restore_in_simulator():
    """End-to-end: preempted jobs resume later when their restores are
    priced on a shared (contended) tranche than on idle per-tenant
    tranches.  The I/O is deliberately stall-free and both configs use
    the same LOCAL attach tier, so the *only* difference between the
    runs is the per-lessee restore bandwidth — the pre-fix uncontended
    pricing made these makespans identical."""
    # reads so small the prefetcher always hides them (zero steady-state
    # stall at any lessee count), no checkpoint write bursts
    tiny_io = IOWorkload("tiny", 1.0, 0.0, batch_size=1,
                         samples_per_epoch=1024)
    tmpl = (JobTemplate("qwen2-0.5b", "train_4k", 16, 30, io=tiny_io),)

    def makespan(tranches):
        cfg = TraceConfig(n_jobs=4, arrival_rate_hz=5.0, seed=1,
                          n_local=64, n_switch=0, pods=1, templates=tmpl,
                          failures=((5.0, 64),), repair_after_s=20.0,
                          storage_tranches=tranches)
        rep = ClusterSimulator(cfg).run()
        assert rep["jobs"]["completed"] == 4
        assert rep["jobs"]["preempted"] >= 1     # the wave hit everyone
        for st in rep["storage"].values():
            assert st["input_stall_s"] == 0.0    # restores only
        return rep["makespan_s"]

    shared = (StorageTranche("shared"),)         # 4 lessees, LOCAL attach
    separate = tuple(StorageTranche(f"local-{i}") for i in range(4))
    assert makespan(shared) > makespan(separate)


def test_preempt_releases_tranche_and_clears_stall():
    dev = make_pool(n_local=8, n_switch=0, pods=1)
    sched = Scheduler(dev, storage=_pool())
    job = Job(name="j", arch="qwen2-0.5b", shape_name="train_4k",
              n_chips=8, steps=10, io=HEAVY_IO)
    sched.submit(job, 0.0)
    sched.poll(0.0)
    tranche = job.system.tranche
    assert tranche is not None
    sched.on_failure(list(job.system.device_uids), now=1.0)
    assert job.state == "queued"
    assert job.input_stall_s == 0.0
    assert sched.storage.n_lessees(tranche) == 0


# ---------------------------------------------------------------------------
# simulator: per-tranche occupancy + input-stall telemetry
# ---------------------------------------------------------------------------
def _sim_cfg(tranches, n_jobs=3):
    tmpl = (JobTemplate("qwen2-0.5b", "train_4k", 16, 30, io=HEAVY_IO),)
    return TraceConfig(n_jobs=n_jobs, arrival_rate_hz=5.0, seed=1,
                       n_local=64, n_switch=0, pods=1, templates=tmpl,
                       failures=(), storage_tranches=tranches)


def test_simulator_reports_storage_stats():
    shared = (StorageTranche("falcon-0", attach=LinkClass.SWITCH),)
    rep = ClusterSimulator(_sim_cfg(shared)).run()
    assert rep["jobs"]["completed"] == 3
    st = rep["storage"]["falcon-0"]
    assert st["attach"] == "switch"
    assert st["leases_granted"] == 3
    assert st["peak_lessees"] >= 2
    assert st["input_stall_s"] > 0
    # exact byte accounting: 3 jobs x 30 steps x batch x mean record
    assert st["read_gb"] == pytest.approx(
        3 * 30 * HEAVY_IO.mean_step_read_bytes() / 1e9, rel=1e-6)
    assert st["write_gb"] == pytest.approx(
        3 * 30 * HEAVY_IO.mean_step_write_bytes() / 1e9, rel=1e-6)
    json.dumps(rep)


def test_shared_switch_tranche_stalls_more_than_separate_local():
    """The acceptance property: >=2 tenants co-located on one
    switch-attached tranche stall harder (and finish later) than the
    same tenants on their own local tranches."""
    shared = ClusterSimulator(_sim_cfg(
        (StorageTranche("falcon-0", attach=LinkClass.SWITCH),))).run()
    separate = ClusterSimulator(_sim_cfg(
        tuple(StorageTranche(f"local-{i}") for i in range(3)))).run()
    stall_sh = sum(s["input_stall_s"] for s in shared["storage"].values())
    stall_se = sum(s["input_stall_s"] for s in separate["storage"].values())
    assert stall_sh > stall_se > 0
    assert shared["makespan_s"] > separate["makespan_s"]
    # contention surfaces as accelerator under-utilization (MLPerf AU)
    assert shared["auu"] >= separate["auu"]


def test_simulator_storage_deterministic_per_seed():
    cfg = _sim_cfg((StorageTranche("falcon-0", attach=LinkClass.SWITCH),))
    a = ClusterSimulator(cfg).run()
    b = ClusterSimulator(cfg).run()
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)


def test_default_trace_still_completes_with_storage_layer():
    """The storage layer rides along under the stock trace mix: every job
    holds a tranche while running, nothing strands, leases drain."""
    from repro.cluster.simulator import run_trace
    rep = run_trace(TraceConfig(n_jobs=10, arrival_rate_hz=0.3, seed=11))
    assert rep["jobs"]["completed"] + rep["jobs"]["rejected"] == 10
    assert rep["jobs"]["stranded"] == 0
    assert rep["storage"]                        # per-tranche stats present
    granted = sum(s["leases_granted"] for s in rep["storage"].values())
    assert granted >= rep["jobs"]["completed"]


# ---------------------------------------------------------------------------
# recomposition plane: tranche leases across shape changes and migrates
# ---------------------------------------------------------------------------
def test_tranche_lease_survives_recompose_and_shrink():
    """Device-side recompose/shrink must carry the storage lease by name
    without re-leasing it — the holder keeps exactly one lease on the
    same tranche through spare-swap AND halving (a double-lease here
    would halve the job's own effective bandwidth)."""
    dev = make_pool(n_local=40, n_switch=0, pods=1)
    st = _pool()
    sys_ = compose.compose(dev, "j", ("data",), (32,),
                           {"data": LinkClass.LOCAL},
                           storage_pool=st, tranche="local-nvme-0",
                           storage_capacity=1e12)
    dev.mark_failed(list(sys_.device_uids[:8]))
    swapped = compose.recompose(dev, sys_)       # 8 spares cover the loss
    assert swapped.tranche == "local-nvme-0"
    assert st.lessees("local-nvme-0") == ("j",)  # one lease, not two
    dev.mark_failed(list(swapped.device_uids[:16]))
    shrunk = compose.shrink_to_pool(dev, swapped, "data")
    assert shrunk.axis_sizes == (16,)
    assert shrunk.tranche == "local-nvme-0"
    assert st.lessees("local-nvme-0") == ("j",)
    assert st.capacity_used("local-nvme-0") == 1e12
    st.check_invariants()


def test_release_tranche_pops_only_the_named_lease():
    pool = _pool()
    pool.lease("local-nvme-0", "j", capacity_bytes=1e12)
    pool.lease("local-nvme-1", "j")              # e.g. data + checkpoint
    assert pool.release_tranche("j", "local-nvme-0")
    assert pool.tranches_of("j") == ["local-nvme-1"]
    assert pool.n_lessees("local-nvme-0") == 0
    assert not pool.release_tranche("j", "local-nvme-0")   # idempotent
    assert not pool.release_tranche("ghost", "local-nvme-1")
    assert pool.tranches_of("j") == ["local-nvme-1"]
    pool.check_invariants()


def test_migrate_tranche_reprices_per_lessee_bandwidth():
    """``migrate_tranche`` moves the lease atomically and re-derives the
    contended stalls on BOTH tranches: the stayer gets its solo
    bandwidth back, the mover streams at the target's lessee count."""
    dev = make_pool(n_local=64, n_switch=0, pods=1)
    st = StoragePool([
        StorageTranche("a", attach=LinkClass.SWITCH),
        StorageTranche("b", attach=LinkClass.SWITCH)])
    sched = Scheduler(dev, storage=st)
    # park an exclusive blocker on b so both jobs admit onto a
    st.lease("b", "blocker", exclusive=True)
    jobs = [Job(name=f"j{i}", arch="qwen2-0.5b", shape_name="train_4k",
                n_chips=16, steps=50, io=HEAVY_IO) for i in range(2)]
    for j in jobs:
        sched.submit(j, 0.0)
    sched.poll(0.0)
    assert st.n_lessees("a") == 2
    contended = jobs[0].input_stall_s
    assert contended > 0
    solo_bw = st.read_bw("a") * 2                # 2-way split today
    st.release("blocker")
    assert sched.migrate_tranche(jobs[1], 5.0, "b")
    assert st.n_lessees("a") == st.n_lessees("b") == 1
    assert st.tranches_of("j1") == ["b"]
    assert jobs[1].system.tranche == "b"
    assert jobs[1].system.fabric.storage.name == "b"
    # per-lessee bandwidth re-priced on both sides
    assert st.read_bw("a") == pytest.approx(solo_bw)
    assert jobs[0].input_stall_s < contended
    assert jobs[1].input_stall_s == pytest.approx(jobs[0].input_stall_s)
    assert sched.telemetry.migrations == 1
    # both jobs changed stall: the simulator will re-price their events
    assert {"j0", "j1"} <= set(sched.stall_dirty)
    # migrating onto the tranche already held is a no-op
    assert not sched.migrate_tranche(jobs[1], 6.0, "b")
    st.check_invariants()


def test_migrate_tranche_conflict_leaves_old_lease_untouched():
    dev = make_pool(n_local=32, n_switch=0, pods=1)
    st = StoragePool([StorageTranche("a"),
                      StorageTranche("b", capacity_bytes=1e9)])
    sched = Scheduler(dev, storage=st)
    job = Job(name="j", arch="qwen2-0.5b", shape_name="train_4k",
              n_chips=16, steps=50, io=HEAVY_IO)   # ~16 GB dataset
    sched.submit(job, 0.0)
    sched.poll(0.0)
    assert st.tranches_of("j") == ["a"]
    # target lacks capacity: the migrate must fail atomically
    assert not sched.migrate_tranche(job, 1.0, "b")
    assert st.tranches_of("j") == ["a"]
    assert job.system.tranche == "a"
    assert sched.telemetry.migrations == 0
    st.check_invariants()


# ---------------------------------------------------------------------------
# backfill guard: queued restores priced at the *contended* tranche rate
# ---------------------------------------------------------------------------
def test_est_restore_for_prices_queued_restore_contended():
    """``est_restore_for`` must see through a queued job to the tranche
    its restart would lease: two co-tenants already stream from the only
    tranche, so the restore read runs at a 3-way split, not the
    uncontended tier rate ``Job.est_restore_s`` assumes."""
    dev = make_pool(n_local=64, n_switch=0, pods=1)
    shared = StoragePool([StorageTranche("shared")])
    sched = Scheduler(dev, storage=shared)
    for i in range(2):
        j = Job(name=f"t{i}", arch="qwen2-0.5b", shape_name="train_4k",
                n_chips=16, steps=200)
        sched.submit(j, 0.0)
    sched.poll(0.0)
    assert shared.n_lessees("shared") == 2
    queued = Job(name="q", arch="qwen2-0.5b", shape_name="train_4k",
                 n_chips=16, steps=10, steps_done=4.0)
    uncontended = queued.est_restore_s()
    assert uncontended > 0
    # existing lessees + the restarting job itself = 3-way bandwidth split
    assert sched.est_restore_for(queued) == pytest.approx(3 * uncontended)
    # no progress -> nothing to restore; holding a tranche -> restore_s
    fresh = Job(name="f", arch="qwen2-0.5b", shape_name="train_4k",
                n_chips=16, steps=10)
    assert sched.est_restore_for(fresh) == 0.0
    running = next(j for j in sched.running)
    running.steps_done = 4.0
    assert sched.est_restore_for(running) == \
        pytest.approx(sched.restore_s(running))


def test_backfill_guard_rejects_restore_that_overruns_reservation():
    """Regression for the backfill guard at the contended-restore
    boundary: a preempted job whose *uncontended* restore estimate fits
    inside the head's reservation — but whose actual (contended-tranche)
    restore does not — must not backfill.  The pre-fix guard priced the
    restore with ``Job.est_restore_s`` and started exactly this job."""
    dev = make_pool(n_local=64, n_switch=0, pods=1)
    shared = StoragePool([StorageTranche("shared")])
    sched = Scheduler(dev, storage=shared)
    runners = [Job(name=f"t{i}", arch="qwen2-0.5b", shape_name="train_4k",
                   n_chips=16, steps=400) for i in range(2)]
    for j in runners:
        sched.submit(j, 0.0)
    sched.poll(0.0)
    head = Job(name="head", arch="qwen2-0.5b", shape_name="train_4k",
               n_chips=64, steps=10)
    cand = Job(name="cand", arch="qwen2-0.5b", shape_name="train_4k",
               n_chips=16, steps=10)
    now = 1.0
    sched.submit(head, now)
    sched.submit(cand, now)
    # shape the candidate so its duration leaves a margin of exactly
    # 2x the uncontended restore before the head's reservation: the
    # uncontended guard would admit it (margin 2u >= u), the contended
    # one must not (3-way split restore = 3u > 2u)
    reserve_t = sched._reservation_t(head.n_chips, now)
    assert reserve_t < float("inf")
    cand.steps_done = 1.0
    u = cand.est_restore_s()
    cand.steps = cand.steps_done + \
        (reserve_t - now - 2.0 * u) / cand.plan.step_s
    assert now + cand.est_restore_s() + cand.est_duration_s() <= reserve_t
    assert now + sched.est_restore_for(cand) + cand.est_duration_s() \
        > reserve_t
    assert sched.poll(now) == []             # contended pricing: no jump
    assert cand.state == "queued"
    # control: shrink the candidate until even the contended restore
    # fits, and backfill admits it again
    cand.steps = cand.steps_done + \
        (reserve_t - now - 4.0 * u) / cand.plan.step_s
    assert [j.name for j in sched.poll(now)] == ["cand"]
