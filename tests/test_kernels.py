"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)
K1, K2, K3, K4, K5 = jax.random.split(KEY, 5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
ATTN_CASES = [
    # B, S, T, H, K, D, causal, window, dtype
    (2, 128, 128, 8, 2, 32, True, 0, jnp.float32),
    (1, 256, 256, 4, 4, 64, True, 0, jnp.float32),
    (2, 128, 128, 6, 1, 32, False, 0, jnp.float32),   # MQA, bidirectional
    (1, 256, 256, 8, 2, 32, True, 64, jnp.float32),   # sliding window
    (1, 128, 128, 4, 2, 64, True, 0, jnp.bfloat16),
    (1, 64, 64, 2, 2, 128, True, 32, jnp.float32),    # head_dim 128
]


@pytest.mark.parametrize(
    "B,S,T,H,K,D,causal,window,dtype", ATTN_CASES)
def test_flash_attention_vs_oracle(B, S, T, H, K, D, causal, window, dtype):
    q = jax.random.normal(K1, (B, S, H, D), jnp.float32).astype(dtype)
    k = jax.random.normal(K2, (B, T, K, D), jnp.float32).astype(dtype)
    v = jax.random.normal(K3, (B, T, K, D), jnp.float32).astype(dtype)
    out = ops.attention(q, k, v, causal=causal, window=window,
                        impl="pallas", block_q=64, block_k=64)
    oracle = ref.attention_ref(q.astype(jnp.float32),
                               k.astype(jnp.float32),
                               v.astype(jnp.float32),
                               causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(out.astype(jnp.float32), oracle,
                               atol=tol, rtol=tol)


def test_flash_block_shape_independence():
    """Result must not depend on the BlockSpec tiling."""
    q = jax.random.normal(K1, (1, 256, 4, 32))
    k = jax.random.normal(K2, (1, 256, 2, 32))
    v = jax.random.normal(K3, (1, 256, 2, 32))
    a = ops.attention(q, k, v, impl="pallas", block_q=32, block_k=64)
    b = ops.attention(q, k, v, impl="pallas", block_q=128, block_k=128)
    np.testing.assert_allclose(a, b, atol=2e-5, rtol=2e-5)


def test_flash_xla_matches_pallas():
    """The dry-run (xla) path and the TPU (pallas) path agree."""
    q = jax.random.normal(K1, (2, 128, 8, 32))
    k = jax.random.normal(K2, (2, 128, 4, 32))
    v = jax.random.normal(K3, (2, 128, 4, 32))
    a = ops.attention(q, k, v, impl="pallas", block_q=64, block_k=64)
    b = ops.attention(q, k, v, impl="xla", block_q=64, block_k=64)
    np.testing.assert_allclose(a, b, atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# SSD (Mamba-2)
# ---------------------------------------------------------------------------
SSD_CASES = [
    # B, S, H, P, G, N, chunk
    (2, 128, 4, 16, 1, 32, 32),
    (1, 64, 8, 32, 2, 16, 16),
    (1, 256, 2, 64, 1, 64, 64),
    (3, 96, 4, 16, 4, 16, 32),    # chunk doesn't divide S in oracle pad
]


@pytest.mark.parametrize("B,S,H,P,G,N,chunk", SSD_CASES)
def test_ssd_vs_oracle(B, S, H, P, G, N, chunk):
    x = jax.random.normal(K1, (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(K2, (B, S, H)))
    A = -jnp.exp(jax.random.normal(K3, (H,)))
    Bm = jax.random.normal(K4, (B, S, G, N)) * 0.5
    Cm = jax.random.normal(K5, (B, S, G, N)) * 0.5
    if S % chunk == 0:
        y1, h1 = ops.ssd(x, dt, A, Bm, Cm, chunk=chunk, impl="pallas")
        y2, h2 = ref.ssd_ref(x, dt, A, Bm, Cm, chunk=chunk)
        np.testing.assert_allclose(y1, y2, atol=2e-4, rtol=2e-4)
        np.testing.assert_allclose(h1, h2, atol=2e-4, rtol=2e-4)
    else:
        # oracle handles padding; kernel requires divisibility
        y2, h2 = ref.ssd_ref(x, dt, A, Bm, Cm, chunk=chunk)
        assert y2.shape == (B, S, H, P)


def test_ssd_chunk_independence():
    """SSD semantics must be chunk-size invariant (duality property)."""
    B, S, H, P, G, N = 1, 128, 4, 16, 1, 32
    x = jax.random.normal(K1, (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(K2, (B, S, H)))
    A = -jnp.exp(jax.random.normal(K3, (H,)))
    Bm = jax.random.normal(K4, (B, S, G, N)) * 0.5
    Cm = jax.random.normal(K5, (B, S, G, N)) * 0.5
    y32, h32 = ops.ssd(x, dt, A, Bm, Cm, chunk=32, impl="pallas")
    y128, h128 = ops.ssd(x, dt, A, Bm, Cm, chunk=128, impl="pallas")
    np.testing.assert_allclose(y32, y128, atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(h32, h128, atol=2e-4, rtol=2e-4)


def test_ssd_matches_sequential_recurrence():
    """Chunked dual form == step-by-step linear recurrence."""
    from repro.models.ssm import ssd_decode_step
    B, S, H, P, G, N = 1, 16, 2, 8, 1, 4
    x = jax.random.normal(K1, (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(K2, (B, S, H)))
    A = -jnp.exp(jax.random.normal(K3, (H,)))
    Bm = jax.random.normal(K4, (B, S, G, N)) * 0.5
    Cm = jax.random.normal(K5, (B, S, G, N)) * 0.5
    y_k, h_k = ops.ssd(x, dt, A, Bm, Cm, chunk=8, impl="pallas")
    h = jnp.zeros((B, H, N, P))
    ys = []
    for t in range(S):
        y, h = ssd_decode_step(x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t], h)
        ys.append(y)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(y_k, y_seq, atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(h_k, h, atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,S,W,bs", [
    (2, 128, 64, 32), (1, 64, 256, 64), (3, 96, 32, 32), (1, 128, 8, 16)])
def test_rglru_vs_oracle(B, S, W, bs):
    log_a = -jax.nn.softplus(jax.random.normal(K1, (B, S, W)))
    gated = jax.random.normal(K2, (B, S, W))
    h1 = ops.rglru(log_a, gated, block_seq=bs, impl="pallas")
    h2 = ref.rglru_ref(log_a, gated)
    np.testing.assert_allclose(h1, h2, atol=2e-5, rtol=2e-5)


def test_rglru_block_independence():
    log_a = -jax.nn.softplus(jax.random.normal(K1, (1, 128, 32)))
    gated = jax.random.normal(K2, (1, 128, 32))
    a = ops.rglru(log_a, gated, block_seq=16, impl="pallas")
    b = ops.rglru(log_a, gated, block_seq=128, impl="pallas")
    np.testing.assert_allclose(a, b, atol=2e-5, rtol=2e-5)
