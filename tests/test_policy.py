"""Property tests for the sharding-policy engine."""
import jax
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, get_config, reduced
from repro.configs.base import PolicyConfig
from repro.core import policy as pol
from repro.models import lm

MESHES = [{"data": 16, "model": 16}, {"pod": 2, "data": 16, "model": 16},
          {"data": 8, "model": 4}]


def _leaves_with_specs(params, specs):
    ps = jax.tree_util.tree_flatten_with_path(params)[0]
    ss = jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P))
    assert len(ps) == len(ss)
    return [(p, leaf, spec) for (p, leaf), spec in zip(ps, ss)]


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
@pytest.mark.parametrize("mesh_axes", MESHES)
def test_param_specs_always_divisible(arch, mesh_axes, rng):
    """Every sharded dim divides by the product of its axis sizes — for
    every arch x mesh (this is what makes one policy serve all 40 cells)."""
    cfg = get_config(arch)
    policy = PolicyConfig(zero_stage=3,
                          dp_axes=tuple(a for a in ("pod", "data")
                                        if a in mesh_axes))
    params = jax.eval_shape(lambda: lm.init_lm(rng, cfg))
    specs = pol.param_specs(params, cfg, policy, mesh_axes)
    for path, leaf, spec in _leaves_with_specs(params, specs):
        for d, entry in enumerate(spec):
            if entry is None:
                continue
            size = pol.axis_entry_size(entry, mesh_axes)
            assert leaf.shape[d] % size == 0, (path, leaf.shape, spec)


def test_zero_stages_shard_progressively(rng):
    """stage0: params+opt replicated-ish; stage1: opt sharded over fsdp;
    stage3: params sharded over fsdp too."""
    cfg = get_config("llama3.2-3b")
    mesh_axes = {"data": 16, "model": 16}
    params = jax.eval_shape(lambda: lm.init_lm(rng, cfg))

    def frac_fsdp(specs):
        total = hit = 0
        for s in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)):
            total += 1
            if any(e == "data" or (isinstance(e, tuple) and "data" in e)
                   for e in s):
                hit += 1
        return hit / max(total, 1)

    p0 = pol.param_specs(params, cfg,
                         PolicyConfig(zero_stage=0), mesh_axes)
    p3 = pol.param_specs(params, cfg,
                         PolicyConfig(zero_stage=3), mesh_axes)
    o0 = pol.opt_state_specs(params, cfg,
                             PolicyConfig(zero_stage=0), mesh_axes)
    o1 = pol.opt_state_specs(params, cfg,
                             PolicyConfig(zero_stage=1), mesh_axes)
    assert frac_fsdp(p0) == 0.0
    assert frac_fsdp(p3) > 0.5
    assert frac_fsdp(o0) == 0.0
    assert frac_fsdp(o1) > 0.5


@given(batch=st.integers(1, 512))
@settings(max_examples=50, deadline=None)
def test_batch_spec_divisibility(batch):
    """dp axes drop (outermost first) until the batch divides."""
    mesh_axes = {"pod": 2, "data": 16, "model": 16}
    policy = PolicyConfig(dp_axes=("pod", "data"))
    entry = pol.dp_spec_for_batch(batch, policy, mesh_axes)
    if entry is None:
        assert batch % 16 or batch % 32
    else:
        axes = entry if isinstance(entry, tuple) else (entry,)
        n = 1
        for a in axes:
            n *= mesh_axes[a]
        assert batch % n == 0


def test_cache_specs_shard_length_not_heads(rng):
    """32k decode caches shard the *length* dim over model (flash-decode
    layout); kv-head counts (8, 2, 1...) rarely divide 16."""
    cfg = get_config("command-r-35b")
    from repro.models import transformer
    caches = jax.eval_shape(
        lambda: transformer.init_stack_cache(cfg, 128, 32768, jnp.bfloat16))
    specs = pol.cache_specs(caches, PolicyConfig(), {"data": 16, "model": 16})
    found_len_shard = False
    for leaf, spec in zip(jax.tree.leaves(caches),
                          jax.tree.leaves(specs,
                                          is_leaf=lambda s: isinstance(s, P))):
        for d, entry in enumerate(spec):
            if entry == "model" and leaf.shape[d] == 32768:
                found_len_shard = True
            if entry is not None:
                size = pol.axis_entry_size(entry, {"data": 16, "model": 16})
                assert leaf.shape[d] % size == 0
    assert found_len_shard


def test_ladder_matches_paper_fig16():
    ladder = pol.ladder(PolicyConfig())
    assert list(ladder) == ["DP", "DDP", "DDP+mixed", "DDP+mixed+sharded"]
    assert ladder["DP"].compute_dtype == "float32"
    assert ladder["DDP+mixed"].compute_dtype == "bfloat16"
    assert ladder["DDP+mixed+sharded"].zero_stage == 3
