"""Topology recommendation (the paper's future-work feature)."""
import pytest

from repro.core.recommend import (Candidate, _estimate, candidates,
                                  recommend)
from repro.configs import get_config, SHAPES


def test_candidates_factorize_chip_budget():
    for dp, tp in candidates(256):
        assert dp * tp == 256


def test_batch_divisibility_is_enforced():
    """The measured regression: command-r prefill (B=32) at dp=64."""
    cfg = get_config("command-r-35b")
    c = _estimate(cfg, SHAPES["prefill_32k"], dp=64, tp=4)
    assert not c.feasible
    assert "batch" in c.why
    c2 = _estimate(cfg, SHAPES["prefill_32k"], dp=32, tp=8)
    assert c2.feasible


def test_moe_ep_divisibility():
    cfg = get_config("llama4-scout-17b-a16e")   # 16 experts
    c = _estimate(cfg, SHAPES["train_4k"], dp=8, tp=32)
    assert not c.feasible and "experts" in c.why


def test_memory_feasibility_rejects_tiny_tp_serving():
    """107B bf16 weights cannot sit TP-2 on 16 GiB chips."""
    cfg = get_config("llama4-scout-17b-a16e")
    c = _estimate(cfg, SHAPES["decode_32k"], dp=128, tp=2)
    assert not c.feasible and "memory" in c.why


@pytest.mark.parametrize("arch,shape,measured_best,rank_tol", [
    ("mamba2-780m", "train_4k", "128x2", 2),
    ("recurrentgemma-2b", "train_4k", "128x2", 2),
    ("command-r-35b", "train_4k", "64x4", 3),
    ("command-r-35b", "prefill_32k", "32x8", 2),
])
def test_analytic_ranking_matches_measured_winners(arch, shape,
                                                   measured_best,
                                                   rank_tol):
    """The analytic pre-screen places the dry-run-measured winner within
    the top few candidates (EXPERIMENTS.md §Perf recompose table)."""
    labels = [c.label for c in recommend(arch, shape, top=rank_tol)]
    assert measured_best in labels, labels


def test_production_default_is_suboptimal_for_small_models():
    """The quantitative composability thesis: (16,16) is never the
    analytic winner for the small/dense training cells."""
    for arch in ("mamba2-780m", "qwen2-0.5b", "recurrentgemma-2b"):
        top = recommend(arch, "train_4k", top=3)
        assert "16x16" not in [c.label for c in top]
