"""Flash-attention backward Pallas kernels vs jax.grad of the oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention_bwd import flash_attention_vjp
from repro.kernels.ref import attention_ref

KEY = jax.random.PRNGKey(11)
K1, K2, K3, K4 = jax.random.split(KEY, 4)

CASES = [
    # B, S, H, K, D, causal, window
    (2, 128, 4, 2, 32, True, 0),
    (1, 128, 4, 4, 64, True, 0),      # MHA
    (1, 128, 6, 1, 32, False, 0),     # MQA, bidirectional
    (1, 256, 4, 2, 32, True, 64),     # sliding window
]


@pytest.mark.parametrize("B,S,H,K,D,causal,window", CASES)
def test_flash_bwd_matches_oracle_grads(B, S, H, K, D, causal, window):
    q = jax.random.normal(K1, (B, S, H, D))
    k = jax.random.normal(K2, (B, S, K, D))
    v = jax.random.normal(K3, (B, S, K, D))
    ct = jax.random.normal(K4, (B, S, H, D))   # upstream cotangent

    def loss_kernel(q, k, v):
        out = flash_attention_vjp(q, k, v, causal, window, 0.0, 64, 64,
                                  True)
        return jnp.sum(out * ct)

    def loss_oracle(q, k, v):
        out = attention_ref(q, k, v, causal=causal, window=window)
        return jnp.sum(out * ct)

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    go = jax.grad(loss_oracle, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(gk, go, ("dq", "dk", "dv")):
        np.testing.assert_allclose(a, b, atol=5e-4, rtol=5e-4,
                                   err_msg=name)


def test_flash_bwd_forward_matches_fwd_kernel():
    from repro.kernels.flash_attention import flash_attention
    q = jax.random.normal(K1, (1, 128, 4, 32))
    k = jax.random.normal(K2, (1, 128, 2, 32))
    v = jax.random.normal(K3, (1, 128, 2, 32))
    a = flash_attention_vjp(q, k, v, True, 0, 0.0, 64, 64, True)
    b = flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    np.testing.assert_allclose(a, b, atol=2e-5, rtol=2e-5)


def test_flash_bwd_block_independence():
    q = jax.random.normal(K1, (1, 128, 2, 32))
    k = jax.random.normal(K2, (1, 128, 2, 32))
    v = jax.random.normal(K3, (1, 128, 2, 32))
    ct = jnp.ones((1, 128, 2, 32))

    def g(bq, bk):
        return jax.grad(lambda q: jnp.sum(
            flash_attention_vjp(q, k, v, True, 0, 0.0, bq, bk, True)
            * ct))(q)

    np.testing.assert_allclose(g(32, 64), g(128, 32), atol=5e-4, rtol=5e-4)
