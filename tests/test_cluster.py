"""Scheduler + simulator coverage: exclusivity, backfill, admission,
failure -> shrink -> resume, and end-to-end trace invariants."""
import json

import pytest

from repro.cluster import (ClusterSimulator, Job, JobTemplate, Scheduler,
                           ServeJob, ServiceConfig, TraceConfig, run_trace)
from repro.cluster.scheduler import DONE, QUEUED, REJECTED, RUNNING
from repro.core.topology import make_pool


def _job(name, n_chips, steps=20, arch="qwen2-0.5b", shape="train_4k"):
    return Job(name=name, arch=arch, shape_name=shape, n_chips=n_chips,
               steps=steps)


# ---------------------------------------------------------------------------
# lease exclusivity under concurrency
# ---------------------------------------------------------------------------
def test_concurrent_jobs_hold_disjoint_leases():
    pool = make_pool(n_local=64, n_switch=0, pods=1)
    sched = Scheduler(pool)
    for i in range(3):
        assert sched.submit(_job(f"j{i}", 32), now=0.0)
    started = sched.poll(0.0)
    assert [j.name for j in started] == ["j0", "j1"]   # 64 chips -> 2 fit
    assert len(pool.leases) == 64
    uids0 = set(started[0].system.device_uids)
    uids1 = set(started[1].system.device_uids)
    assert not uids0 & uids1
    sched.manager.check_exclusive()
    # completing one frees exactly its slice; the queued job then starts
    sched.on_complete(started[0], now=10.0)
    assert len(pool.leases) == 32
    started2 = sched.poll(10.0)
    assert [j.name for j in started2] == ["j2"]
    assert not set(started2[0].system.device_uids) & uids1


# ---------------------------------------------------------------------------
# backfill ordering (EASY: don't delay the head's reservation)
# ---------------------------------------------------------------------------
def test_backfill_lets_short_job_jump_but_not_long():
    pool = make_pool(n_local=32, n_switch=0, pods=1)
    sched = Scheduler(pool)
    a = _job("a", 16, steps=20)              # occupies half the pool ~31s
    sched.submit(a, 0.0)
    assert sched.poll(0.0) == [a]
    head = _job("head", 32, steps=10)        # needs the whole pool: blocked
    short = _job("short", 16, steps=10)      # fits & finishes before a does
    long_ = _job("long", 16, steps=40)       # fits but would delay head
    for j in (head, short, long_):
        sched.submit(j, 1.0)
    started = sched.poll(1.0)
    assert [j.name for j in started] == ["short"]
    assert head.state == QUEUED and long_.state == QUEUED
    # with backfill disabled nothing may jump the head
    pool2 = make_pool(n_local=32, n_switch=0, pods=1)
    sched2 = Scheduler(pool2, backfill=False)
    a2 = _job("a", 16, steps=20)
    sched2.submit(a2, 0.0)
    sched2.poll(0.0)
    sched2.submit(_job("head", 32, steps=10), 1.0)
    sched2.submit(_job("short", 16, steps=10), 1.0)
    assert sched2.poll(1.0) == []


def test_est_end_anchors_at_progress_not_start():
    """Backfill reservations must not drift earlier as a running job's
    steps_done accrues (est_end was start_t + remaining, shrinking with
    progress)."""
    pool = make_pool(n_local=16, n_switch=0, pods=1)
    sched = Scheduler(pool)
    job = _job("j", 16, steps=100)
    sched.submit(job, 0.0)
    sched.poll(0.0)
    end0 = job.est_end_t
    # half the work done, clock at the halfway point: estimate unchanged
    job.steps_done = 50.0
    job.progress_t = 50.0 * job.step_s
    assert job.est_end_t == pytest.approx(end0, rel=1e-6)


def test_priority_orders_queue():
    pool = make_pool(n_local=16, n_switch=0, pods=1)
    sched = Scheduler(pool)
    lo = _job("lo", 16)
    hi = _job("hi", 16)
    hi.priority = 5
    blocker = _job("blocker", 16)
    sched.submit(blocker, 0.0)
    sched.poll(0.0)
    sched.submit(lo, 1.0)
    sched.submit(hi, 2.0)                    # later but higher priority
    sched.on_complete(blocker, 3.0)
    assert [j.name for j in sched.poll(3.0)] == ["hi"]


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------
def test_infeasible_job_rejected_on_memory():
    pool = make_pool(n_local=256, n_switch=0, pods=1)
    sched = Scheduler(pool)
    job = _job("oom", 2, arch="command-r-35b")   # 35B params on 2 chips
    assert not sched.submit(job, 0.0)
    assert job.state == REJECTED
    assert "HBM" in job.why_rejected or "memory" in job.why_rejected


def test_infeasible_job_rejected_on_kv_cache():
    pool = make_pool(n_local=256, n_switch=0, pods=1)
    sched = Scheduler(pool)
    # decode_32k batch 128 with 16 chips: every (dp, tp) split blows HBM
    job = _job("kv", 16, arch="llama3.2-3b", shape="decode_32k")
    assert not sched.submit(job, 0.0)
    assert job.state == REJECTED


def test_divisibility_infeasibility_is_surfaced():
    """The analytic model's divisibility constraints flow into admission:
    candidates that don't divide the batch (or MoE experts) are marked
    infeasible with the reason, and planning picks around them."""
    pool = make_pool(n_local=256, n_switch=0, pods=1)
    sched = Scheduler(pool)
    from repro.configs import get_config
    from repro.configs.base import SHAPES
    from repro.core import recommend
    cfg = get_config("moonshot-v1-16b-a3b")          # 64 experts
    bad = recommend._estimate(cfg, SHAPES["train_4k"], dp=2, tp=96)
    assert not bad.feasible and "% tp" in bad.why
    odd = recommend._estimate(get_config("qwen2-0.5b"),
                              SHAPES["prefill_32k"], dp=3, tp=1)
    assert not odd.feasible and "% dp" in odd.why
    plan = sched.plan_job(_job("m", 64, arch="moonshot-v1-16b-a3b"))
    assert plan is not None and plan.feasible


def test_oversized_request_rejected():
    pool = make_pool(n_local=16, n_switch=0, pods=1)
    sched = Scheduler(pool)
    job = _job("big", 64)
    assert not sched.submit(job, 0.0)
    assert "pool has" in job.why_rejected


# ---------------------------------------------------------------------------
# failure -> shrink_to_pool -> resume, end-to-end on a small pool
# ---------------------------------------------------------------------------
def test_failure_shrinks_running_job_and_it_completes():
    pool = make_pool(n_local=32, n_switch=0, pods=1)
    sched = Scheduler(pool)
    a = _job("a", 16, steps=10)
    b = _job("b", 16, steps=10)
    sched.submit(a, 0.0)
    sched.submit(b, 0.0)
    sched.poll(0.0)
    assert a.state == RUNNING and b.state == RUNNING
    old_epoch = a.epoch
    changed = sched.on_failure(list(a.system.device_uids[:4]), now=5.0)
    # no spares (b holds the rest): a must shrink its data axis
    assert changed == [a]
    assert a.state == RUNNING
    assert a.system.shape["data"] == 8
    assert a.epoch == old_epoch + 1
    assert a.plan.shape == (8, 1)            # plan re-estimated for the
    assert a.plan.feasible                   # shrunken mesh
    assert a.recompositions == 1
    assert [e.kind for e in a.run.events] == ["failure", "recompose"]
    # b untouched, leases still exclusive, dead devices unleased
    assert b.system.shape["data"] == 16
    sched.manager.check_exclusive()
    assert not set(a.system.device_uids) & set(b.system.device_uids)
    sched.on_complete(a, 20.0)
    sched.on_complete(b, 20.0)
    assert a.state == DONE and b.state == DONE
    assert not pool.leases


def test_total_loss_preempts_then_repair_resumes():
    pool = make_pool(n_local=8, n_switch=0, pods=1)
    sched = Scheduler(pool)
    job = _job("j", 8, steps=10)
    sched.submit(job, 0.0)
    sched.poll(0.0)
    job.steps_done = 4.5
    uids = list(job.system.device_uids)
    sched.on_failure(uids, now=5.0)
    assert job.state == QUEUED
    assert not pool.leases                   # everything returned
    assert sched.telemetry.jobs_preempted == 1
    assert job.steps_done == 4.0             # back to checkpoint boundary
    assert sched.poll(5.0) == []             # nothing healthy to run on
    pool.repair(uids)
    assert sched.poll(6.0) == [job]
    assert job.state == RUNNING


def test_infeasible_shrink_preempts_instead_of_running_at_inf():
    """A halved mesh that fits the pool by count but not by HBM must not
    be installed (its step_s is inf); the job is preempted instead."""
    pool = make_pool(n_local=16, n_switch=0, pods=1)
    sched = Scheduler(pool)
    job = _job("s", 16, steps=10, arch="stablelm-12b")
    sched.submit(job, 0.0)
    sched.poll(0.0)
    assert job.state == RUNNING
    changed = sched.on_failure(list(job.system.device_uids[:8]), now=5.0)
    assert changed == [job]
    assert job.state == QUEUED               # not running at step_s = inf
    assert not pool.leases
    assert job.plan.feasible and job.plan.step_s != float("inf")


def test_recompose_onto_other_fabric_rederives_links():
    """Spare devices on the switch fabric must show up in the rebuilt
    composition's axis link classes (pricing + traffic attribution)."""
    from repro.core.topology import LinkClass
    pool = make_pool(n_local=16, n_switch=16, pods=1)
    sched = Scheduler(pool)
    job = _job("j", 16, steps=10)
    sched.submit(job, 0.0)
    sched.poll(0.0)
    assert job.system.fabric.axis_links["data"] == LinkClass.LOCAL
    sched.on_failure(list(job.system.device_uids[:8]), now=1.0)
    assert job.state == RUNNING
    assert job.system.shape["data"] == 16    # same-shape, switch spares
    fabrics = {d.fabric for d in pool.devices
               if d.uid in job.system.device_uids}
    assert LinkClass.SWITCH in fabrics
    # mixed local+switch claim crosses fabrics through the host complex
    assert job.system.fabric.axis_links["data"] == LinkClass.HOST
    # ... and the re-priced plan reflects the slower fabric
    assert job.plan.terms["collective"] > 0


def test_placement_fabric_reprices_step_time():
    """The same collective-bound job must simulate slower on the composed
    switch fabric than inside a LOCAL clique (the paper's Fig-11 gap)."""
    job_l = _job("l", 128, arch="moonshot-v1-16b-a3b", steps=5)
    job_s = _job("s", 128, arch="moonshot-v1-16b-a3b", steps=5)
    sl = Scheduler(make_pool(n_local=128, n_switch=0, pods=1))
    ss = Scheduler(make_pool(n_local=0, n_switch=128, pods=1))
    sl.submit(job_l, 0.0)
    ss.submit(job_s, 0.0)
    sl.poll(0.0)
    ss.poll(0.0)
    assert job_l.system.fabric.axis_links["data"].value == "local"
    assert job_s.system.fabric.axis_links["data"].value == "switch"
    assert job_s.step_s > job_l.step_s * 2


def test_preempted_shrunk_job_is_replanned_at_full_budget():
    """A job shrunk to (8,1) then preempted must requeue with a plan
    matching its requested 16 chips, or poll()'s gate strands it."""
    pool = make_pool(n_local=32, n_switch=0, pods=1)
    sched = Scheduler(pool)
    a = _job("a", 16, steps=10)
    b = _job("b", 16, steps=10)
    sched.submit(a, 0.0)
    sched.submit(b, 0.0)
    sched.poll(0.0)
    sched.on_failure(list(a.system.device_uids[:4]), now=1.0)
    assert a.system.shape["data"] == 8       # first wave: shrink
    dead = list(a.system.device_uids) + [d.uid for d in pool.available()]
    sched.on_failure(dead, now=2.0)
    assert a.state == QUEUED                 # second wave: preempt
    assert a.plan.shape == (16, 1)           # re-planned at full budget
    pool.repair([d.uid for d in pool.devices if not d.healthy])
    assert sched.poll(3.0) == [a]
    assert a.system.n_devices == 16


# ---------------------------------------------------------------------------
# simulator end-to-end
# ---------------------------------------------------------------------------
def test_trace_completes_with_zero_conflicts():
    rep = run_trace(TraceConfig(n_jobs=12, arrival_rate_hz=0.2, seed=3))
    jobs = rep["jobs"]
    assert jobs["submitted"] == 12
    assert jobs["completed"] + jobs["rejected"] == 12
    assert jobs["stranded"] == 0
    assert rep["lease_conflicts"] == 0
    assert 0.0 < rep["pool_utilization"] <= 1.0
    assert 0.0 <= rep["auu"] < 1.0
    assert sum(rep["link_traffic_gb"].values()) > 0
    json.dumps(rep)                          # must be JSON-serializable


def test_trace_is_deterministic_per_seed():
    cfg = TraceConfig(n_jobs=10, arrival_rate_hz=0.3, seed=11)
    assert json.dumps(run_trace(cfg)) == json.dumps(run_trace(cfg))
    other = TraceConfig(n_jobs=10, arrival_rate_hz=0.3, seed=12)
    assert json.dumps(run_trace(other)) != json.dumps(run_trace(cfg))


def test_trace_failure_wave_drives_recomposition():
    cfg = TraceConfig(n_jobs=24, arrival_rate_hz=0.2, seed=7,
                      failures=((120.0, 12),), repair_after_s=180.0)
    rep = run_trace(cfg)
    assert rep["recomposition"]["count"] >= 1
    assert rep["recomposition"]["overhead_s"] > 0
    assert rep["jobs"]["completed"] == 24
    assert rep["lease_conflicts"] == 0


def test_trace_heavy_contention_queues_jobs():
    """Tiny pool + bursty arrivals: jobs must wait, none may strand."""
    tmpl = (JobTemplate("qwen2-0.5b", "train_4k", 16, 10),)
    cfg = TraceConfig(n_jobs=8, arrival_rate_hz=2.0, seed=5,
                      n_local=32, n_switch=0, pods=1, templates=tmpl,
                      failures=())
    rep = run_trace(cfg)
    assert rep["jobs"]["completed"] == 8
    assert rep["jobs"]["stranded"] == 0
    assert rep["job_wait_s"]["p99"] > 0
    assert rep["lease_conflicts"] == 0


# ---------------------------------------------------------------------------
# serving tenants (ServeJob + serving-trace mode)
# ---------------------------------------------------------------------------
def test_serve_job_admitted_and_priced():
    pool = make_pool(n_local=128, n_switch=0, pods=1)
    sched = Scheduler(pool)
    job = ServeJob(name="svc/r0", arch="llama3.2-3b",
                   shape_name="decode_32k", n_chips=64, steps=100,
                   service="svc")
    assert sched.submit(job, 0.0)
    assert sched.poll(0.0) == [job]
    assert job.state == RUNNING
    tp = job.throughput()
    assert tp["tokens_per_s"] > 0
    assert tp["kv_write_bytes_per_s"] > 0
    # throughput is priced from the placed (re-priced) plan
    assert tp["tokens_per_s"] == pytest.approx(128 / job.step_s)


def _serve_trace(arrival="poisson", **kw):
    svc = ServiceConfig(name="chat", arch="llama3.2-3b",
                        shape_name="decode_32k", n_replicas=2,
                        chips_per_replica=64, n_requests=80,
                        arrival_rate_hz=2.0, arrival=arrival,
                        prompt_len=2048, max_new=64, n_prefixes=4,
                        prefix_len=1024)
    return TraceConfig(n_jobs=8, arrival_rate_hz=0.2, seed=5,
                       failures=(), services=(svc,), **kw)


def test_serving_trace_alongside_training_tenants():
    rep = ClusterSimulator(_serve_trace()).run()
    jobs = rep["jobs"]
    # 8 batch jobs + 2 replicas all accounted for, nothing stranded
    assert jobs["submitted"] == 10
    assert jobs["completed"] + jobs["rejected"] == 10
    assert jobs["stranded"] == 0
    svc = rep["serving"]["chat"]
    assert svc["requests"]["completed"] == 80
    assert svc["requests"]["stranded"] == 0
    assert svc["ttft_s"]["p99"] > 0
    assert svc["tpot_s"]["p50"] > 0
    assert svc["throughput_tok_s"] > 0
    assert len(svc["replicas"]) == 2
    for row in svc["replicas"].values():
        assert row["served"] > 0
        assert 0.0 <= row["cache_hit_rate"] < 1.0
    # prefix caches warm up: some hits across the trace
    assert svc["cache_hit_rate"] > 0
    json.dumps(rep)


def test_serving_trace_deterministic_and_arrival_sensitive():
    a = ClusterSimulator(_serve_trace()).run()
    b = ClusterSimulator(_serve_trace()).run()
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)
    burst = ClusterSimulator(_serve_trace(arrival="burst")).run()
    # a burst at t=0 must queue harder than paced poisson arrivals
    assert burst["serving"]["chat"]["queue_wait_s"]["p99"] >= \
        a["serving"]["chat"]["queue_wait_s"]["p99"]
    # and cold-prefix requests prefilling concurrently must not count as
    # cache hits — a prefix is reusable only after a prefill finishes
    assert burst["serving"]["chat"]["cache_hit_rate"] <= \
        a["serving"]["chat"]["cache_hit_rate"]


def test_preempted_replica_completes_when_trace_drains():
    """Regression: a replica preempted by a failure wave and still queued
    when the request trace drains must complete with full accounting
    (jobs.completed + jobs.rejected == jobs.submitted)."""
    svc = ServiceConfig(name="chat", arch="llama3.2-3b",
                        shape_name="decode_32k", n_replicas=2,
                        chips_per_replica=64, n_requests=120,
                        arrival_rate_hz=2.0, prompt_len=2048, max_new=64,
                        n_prefixes=4, prefix_len=1024)
    cfg = TraceConfig(n_jobs=0, seed=0, n_local=192, n_switch=0, pods=1,
                      failures=((20.0, 90),), repair_after_s=1e9,
                      services=(svc,))
    rep = ClusterSimulator(cfg).run()
    jobs = rep["jobs"]
    assert jobs["completed"] + jobs["rejected"] == jobs["submitted"] == 2
    assert rep["serving"]["chat"]["requests"]["stranded"] == 0


def test_serving_replicas_release_pool_for_training():
    """When the request trace drains, replicas complete and give their
    chips back — the re-aggregation loop composability exists for."""
    sim = ClusterSimulator(_serve_trace())
    rep = sim.run()
    for row in rep["serving"]["chat"]["replicas"].values():
        assert row["state"] == DONE
    assert rep["jobs"]["stranded"] == 0      # batch jobs finished too
    assert not sim.pool.leases               # every chip returned


# ---------------------------------------------------------------------------
# SLO-driven replica autoscaling
# ---------------------------------------------------------------------------
def _overload_trace(autoscale, rate_hz=40.0, n_requests=320):
    extra = dict(autoscale=True, autoscale_interval_s=0.5,
                 max_replicas=8, scale_up_queue=1.0,
                 scale_down_queue=0.25) if autoscale else {}
    svc = ServiceConfig(name="chat", arch="llama3.2-3b",
                        shape_name="decode_32k", n_replicas=1,
                        chips_per_replica=64, n_requests=n_requests,
                        arrival_rate_hz=rate_hz, arrival="poisson",
                        prompt_len=2048, max_new=256, n_prefixes=6,
                        prefix_len=1024, prefill_chunk=512,
                        ttft_slo_s=2.0, tpot_slo_s=0.5, **extra)
    return TraceConfig(n_jobs=0, failures=(), seed=3, services=(svc,))


def test_autoscale_absorbs_overload():
    """One replica past saturation: the fixed service blows its TTFT SLO,
    the autoscaled one leases extra replicas and holds attainment."""
    fixed = ClusterSimulator(_overload_trace(False)).run()["serving"]["chat"]
    auto = ClusterSimulator(_overload_trace(True)).run()["serving"]["chat"]
    assert "autoscale" not in fixed           # report key gated on cfg
    scale = auto["autoscale"]
    assert scale["scale_ups"] >= 1
    assert scale["peak_replicas"] > 1
    assert len(scale["windows"]) >= 1
    assert auto["slo_attainment"] > fixed["slo_attainment"]
    assert auto["ttft_s"]["p99"] < fixed["ttft_s"]["p99"]
    assert auto["requests"]["completed"] == 320
    assert auto["requests"]["stranded"] == 0


def test_autoscale_idle_when_capacity_suffices():
    """Below saturation the autoscaler never fires, and the serving
    metrics are identical to the fixed service (no rng perturbation)."""
    fixed = ClusterSimulator(
        _overload_trace(False, rate_hz=10.0, n_requests=80)).run()
    auto = ClusterSimulator(
        _overload_trace(True, rate_hz=10.0, n_requests=80)).run()
    scale = auto["serving"]["chat"].pop("autoscale")
    assert scale["scale_ups"] == 0 and scale["scale_downs"] == 0
    assert scale["peak_replicas"] == 1
    assert json.dumps(fixed["serving"], sort_keys=True) == \
        json.dumps(auto["serving"], sort_keys=True)


def test_autoscale_trace_is_deterministic():
    a = ClusterSimulator(_overload_trace(True)).run()
    b = ClusterSimulator(_overload_trace(True)).run()
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)


def test_autoscale_drains_and_releases_leases():
    """Scaled-up replicas drain when pressure drops and give every chip
    back — a scale-up is an ordinary scheduler lease, not a carve-out."""
    sim = ClusterSimulator(_overload_trace(True))
    rep = sim.run()
    scale = rep["serving"]["chat"]["autoscale"]
    assert scale["scale_downs"] >= 1
    assert scale["final_replicas"] == 0       # trace drained fully
    assert not sim.pool.leases
    kinds = {e.kind for e in sim.telemetry.events}
    assert "autoscale" in kinds
