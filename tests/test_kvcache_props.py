"""Property tests for the paged KV cache (serve/kvcache.py).

Random alloc / free / prefix-reuse sequences must preserve the pool
invariants that keep serving correct under load:

  * pages are never leaked — releasing every live sequence returns the
    pool to fully-free;
  * a page is never double-assigned — its refcount equals the number of
    live block tables holding it (shared prefix pages count once per
    holder), and unreferenced pages live in exactly one of free/retained;
  * free-list size + retained LRU + live pages always equals pool size.
"""
import dataclasses

import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

import numpy as np

from repro.configs.base import ModelConfig
from repro.serve import PageError, PagePool

TINY = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=16,
                   n_heads=2, n_kv_heads=1, d_ff=32, vocab_size=64)

N_PAGES = 8
PAGE_SIZE = 4
SHARED = list(np.random.RandomState(1234).randint(0, 64, 32))


def _prompt(seed: int, shared_pages: int, tail: int):
    rng = np.random.RandomState(seed)
    return SHARED[:shared_pages * PAGE_SIZE] + \
        list(rng.randint(0, 64, tail + 1))


def _check_invariants(pool: PagePool, live):
    # partition: every page is free, retained, or referenced — exactly one
    free = set(pool.free)
    retained = set(pool.retained.values())
    assert not free & retained
    referenced = {p for p in range(pool.n_pages) if pool.ref[p] > 0}
    assert not referenced & free
    assert not referenced & retained
    assert free | retained | referenced == set(range(pool.n_pages))
    # free-list + retained + live pages == pool size
    assert len(free) + len(retained) + len(referenced) == pool.n_pages
    assert pool.in_use == len(referenced)
    # refcount == number of live tables holding the page (no silent
    # double-assignment: an exclusive page appears in exactly one table)
    held = {}
    for _, table in live:
        for p in table.pages:
            held[p] = held.get(p, 0) + 1
    for p in range(pool.n_pages):
        assert pool.ref[p] == held.get(p, 0), (p, pool.ref[p], held)
    # every live table's pages are distinct (one slot, one page)
    for _, table in live:
        assert len(set(table.pages)) == len(table.pages)


action = st.one_of(
    st.tuples(st.just("open"), st.integers(0, 5), st.integers(0, 2),
              st.integers(0, 10), st.integers(0, 6)),
    st.tuples(st.just("close"), st.integers(0, 7)),
    st.tuples(st.just("drop"), st.integers(0, 7)),
)


@settings(max_examples=25, deadline=None)
@given(st.lists(action, min_size=1, max_size=30))
def test_random_sequences_preserve_pool_invariants(actions):
    pool = PagePool(TINY, n_pages=N_PAGES, page_size=PAGE_SIZE)
    live = []
    for act in actions:
        if act[0] == "open":
            _, seed, shared_pages, tail, max_new = act
            prompt = _prompt(seed, shared_pages, tail)
            try:
                table, cached = pool.open_sequence(prompt, max_new)
            except PageError:
                pass                     # full pool: rollback must be clean
            else:
                assert cached <= len(prompt) - 1
                live.append((prompt, table))
        elif act[0] == "close" and live:
            prompt, table = live.pop(act[1] % len(live))
            pool.close_sequence(prompt, table)   # register + release
        elif act[0] == "drop" and live:
            _, table = live.pop(act[1] % len(live))
            pool.release(table)                  # release without hashing
        _check_invariants(pool, live)
    while live:                                  # never leak: drain to zero
        prompt, table = live.pop()
        pool.close_sequence(prompt, table)
    _check_invariants(pool, live)
    assert pool.in_use == 0
    assert len(pool.free) + len(pool.retained) == pool.n_pages


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1_000_000))
def test_shared_prefix_pages_referenced_once_per_holder(seed):
    pool = PagePool(TINY, n_pages=N_PAGES, page_size=PAGE_SIZE)
    prompt = _prompt(seed, shared_pages=2, tail=2)
    t1, c1 = pool.open_sequence(prompt, 1)
    pool.register_prefix(prompt, t1)             # prefill finished
    t2, c2 = pool.open_sequence(prompt, 1)
    assert c1 == 0 and c2 == 2 * PAGE_SIZE
    shared = set(t1.pages) & set(t2.pages)
    assert len(shared) == 2                      # both full pages re-linked
    for p in shared:
        assert pool.ref[p] == 2
    _check_invariants(pool, [(prompt, t1), (prompt, t2)])
    pool.release(t1)
    for p in shared:
        assert pool.ref[p] == 1                  # still owned by t2
    pool.release(t2)
    assert pool.in_use == 0
