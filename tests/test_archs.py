"""Per-architecture smoke tests: REDUCED same-family configs, one forward
+ one train step on CPU, asserting output shapes and finiteness.

The FULL configs are exercised only by the dry-run (ShapeDtypeStructs)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, reduced
from repro.configs.base import PolicyConfig, ShapeConfig
from repro.data import make_batch
from repro.models import lm
from repro.models.transformer import RunCtx
from repro.optim import AdamWConfig
from repro.train import trainer

SMOKE_SHAPE = ShapeConfig("smoke", 64, 2, "train")
POLICY = PolicyConfig(compute_dtype="float32", remat="none",
                      attn_impl="full", zero_stage=0)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_shapes_and_finite(arch, rng):
    cfg = reduced(get_config(arch))
    params = lm.init_lm(rng, cfg)
    batch = make_batch(cfg, SMOKE_SHAPE)
    ctx = RunCtx(compute_dtype=jnp.float32, attn_impl="full", remat="none")
    logits, _, aux = lm.forward(params, batch["inputs"], cfg, ctx)
    B, S = batch["labels"].shape
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_one_train_step(arch, rng):
    cfg = reduced(get_config(arch))
    state = trainer.init_state(rng, cfg, POLICY, AdamWConfig(lr=1e-3))
    step = jax.jit(trainer.make_train_step(cfg, POLICY,
                                           AdamWConfig(lr=1e-3)))
    batch = make_batch(cfg, SMOKE_SHAPE)
    new_state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually moved
    moved = jax.tree.map(
        lambda a, b: bool(jnp.any(a != b)), state.params, new_state.params)
    assert any(jax.tree.leaves(moved))


@pytest.mark.parametrize("arch", ["mamba2-780m", "recurrentgemma-2b",
                                  "llama3.2-3b", "musicgen-large"])
def test_prefill_then_decode_matches_full(arch, rng):
    """Greedy decode consistency: decode(t=S) == full forward at t=S."""
    cfg = reduced(get_config(arch))
    params = lm.init_lm(rng, cfg)
    B, S = 2, 32
    ctx = RunCtx(compute_dtype=jnp.float32, attn_impl="full", remat="none",
                 cache_capacity=S + 8)
    if cfg.input_mode == "embeddings":
        inputs = jax.random.normal(rng, (B, S, cfg.d_model))
        nxt = jax.random.normal(jax.random.PRNGKey(9), (B, 1, cfg.d_model))
    else:
        inputs = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
        nxt = jax.random.randint(jax.random.PRNGKey(9), (B, 1), 0,
                                 cfg.vocab_size)
    _, caches, _ = lm.forward(params, inputs, cfg, ctx, caches="init",
                              return_hidden=True)
    pos = jnp.full((B, 1), S, jnp.int32)
    step_logits, _, _ = lm.forward(params, nxt, cfg, ctx, positions=pos,
                                   caches=caches)
    full_in = jnp.concatenate([inputs, nxt], 1)
    full_logits, _, _ = lm.forward(params, full_in, cfg, ctx)
    err = float(jnp.max(jnp.abs(step_logits[:, 0] - full_logits[:, -1])))
    assert err < 5e-4, err


def test_param_counts_match_published():
    """Full configs land on the published parameter counts."""
    expected = {
        "mamba2-780m": 0.780e9,
        "llama4-scout-17b-a16e": 17.17e9,     # active
        "moonshot-v1-16b-a3b": 4.8e9,         # active (3B activated + attn)
        "llama3.2-3b": 3.2e9,
        "qwen2-0.5b": 0.494e9,
        "stablelm-12b": 12.1e9,
        "llava-next-mistral-7b": 7.24e9,
        "recurrentgemma-2b": 2.7e9,
    }
    for arch, n in expected.items():
        cfg = get_config(arch)
        got = cfg.active_param_count()
        assert abs(got - n) / n < 0.08, (arch, got, n)


def test_long_context_skip_list():
    """long_500k applies exactly to sub-quadratic archs."""
    from repro.configs import applicable_shapes
    runs_long = {a for a in ASSIGNED_ARCHS
                 if any(s.name == "long_500k"
                        for s in applicable_shapes(get_config(a)))}
    assert runs_long == {"mamba2-780m", "recurrentgemma-2b"}
