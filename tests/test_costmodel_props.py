"""Property tests for the analytic roofline terms and fabric pricing."""
import dataclasses

import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import SHAPES, get_config
from repro.configs.base import PolicyConfig, ShapeConfig
from repro.core import compose, costmodel


MESHES = [{"data": 16, "model": 16}, {"data": 64, "model": 4},
          {"pod": 2, "data": 16, "model": 16}]


@pytest.mark.parametrize("arch", ["llama3.2-3b", "mamba2-780m",
                                  "moonshot-v1-16b-a3b"])
@pytest.mark.parametrize("shape_name", ["train_4k", "prefill_32k",
                                        "decode_32k"])
def test_analytic_hbm_positive_and_scales_down_with_devices(arch,
                                                            shape_name):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    policy = PolicyConfig()
    small = costmodel.analytic_hbm_bytes(cfg, shape, policy,
                                         {"data": 4, "model": 4})
    big = costmodel.analytic_hbm_bytes(cfg, shape, policy,
                                       {"data": 16, "model": 16})
    assert small > 0 and big > 0
    assert big <= small  # more devices -> less per-device traffic


def test_forward_flops_ordering():
    """prefill(32k x 32) > train fwd per token parity; decode << prefill."""
    cfg = get_config("llama3.2-3b")
    f_train = costmodel.forward_flops(cfg, SHAPES["train_4k"])
    f_prefill = costmodel.forward_flops(cfg, SHAPES["prefill_32k"])
    f_decode = costmodel.forward_flops(cfg, SHAPES["decode_32k"])
    assert f_decode < f_prefill
    # same token count (1M), prefill has more attention work (longer S)
    assert f_prefill > f_train


def test_remat_increases_step_flops_only_for_train():
    cfg = get_config("qwen2-0.5b")
    p0 = PolicyConfig(remat="none")
    p1 = PolicyConfig(remat="block")
    assert costmodel.step_flops(cfg, SHAPES["train_4k"], p1) > \
        costmodel.step_flops(cfg, SHAPES["train_4k"], p0)
    assert costmodel.step_flops(cfg, SHAPES["decode_32k"], p1) == \
        costmodel.step_flops(cfg, SHAPES["decode_32k"], p0)


@given(bw_scale=st.floats(0.05, 1.0))
@settings(max_examples=30, deadline=None)
def test_roofline_collective_term_inversely_scales_with_bandwidth(
        bw_scale):
    """Pricing the same program on a slower fabric raises exactly the
    collective term (the paper's core experiment)."""
    r = costmodel.CostReport(
        arch="x", shape="train_4k", mesh={"data": 16, "model": 16},
        flops_hlo=1e12, flops_analytic=256e12, model_flops=200e12,
        hbm_bytes=1e9, peak_memory=None)
    r.collectives = [costmodel.CollectiveOp("all-reduce", 1e9, 16,
                                            ("data",))]
    fast = compose.preset("localGPUs")
    slow_links = dict(fast.fabric.links)
    from repro.core.topology import LinkClass, LinkSpec
    slow_links[LinkClass.LOCAL] = LinkSpec(
        LinkClass.LOCAL,
        fast.fabric.links[LinkClass.LOCAL].bandwidth * bw_scale, 2e-6)
    slow = dataclasses.replace(
        fast, fabric=dataclasses.replace(fast.fabric, links=slow_links))
    rl_fast = costmodel.roofline(r, fast)
    rl_slow = costmodel.roofline(r, slow)
    assert rl_slow.collective_s == pytest.approx(
        rl_fast.collective_s / bw_scale, rel=1e-6)
    assert rl_slow.compute_s == rl_fast.compute_s
    assert rl_slow.memory_s == rl_fast.memory_s


def test_wire_bytes_ring_factors():
    for kind, factor in (("all-reduce", 2 * 15 / 16),
                         ("all-gather", 15 / 16),
                         ("reduce-scatter", 15 / 16),
                         ("collective-permute", 1.0)):
        op = costmodel.CollectiveOp(kind, 1e6, 16, ("data",))
        assert op.wire_bytes == pytest.approx(factor * 1e6)
    op = costmodel.CollectiveOp("all-reduce", 1e6, 16, ("data",),
                                trip_count=48)
    assert op.wire_bytes == pytest.approx(48 * 2 * 15 / 16 * 1e6)
