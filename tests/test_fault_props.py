"""Property tests: simulator invariants under dense fault interleavings.

The event loop's staleness armor (per-job epochs invalidating scheduled
completions) has to hold no matter how faults, repairs, evictions, and
retries interleave.  ``_check_invariants`` states the contract:

  * conservation — every submitted job ends in exactly one terminal
    bucket (completed / rejected / failed) or is still accounted as
    stranded; nothing completes twice;
  * no negative progress — a completed job ran forward in time and did
    at least its configured step count;
  * determinism — the same seed replays to a bit-identical report.

A seeded sweep below always runs; the ``hypothesis`` fuzz on top is
skipped when the package isn't installed (the container doesn't ship
it), so CI environments with hypothesis get the dense search for free.
"""
import json

import pytest

from repro.cluster.faults import FaultPlan, FaultSpec
from repro.cluster.simulator import ClusterSimulator, TraceConfig

_KINDS = ("device_down", "device_flaky", "domain_outage", "link_degrade",
          "tranche_brownout", "tranche_fail")


def _plan(choices):
    """(kind_idx, t, n, clear_dt) quadruples -> a scripted FaultPlan."""
    faults = []
    for kind_idx, t, n, clear_dt in choices:
        kind = _KINDS[kind_idx % len(_KINDS)]
        faults.append(FaultSpec(
            kind=kind, t=float(t), n=int(n), domain=kind_idx % 2,
            frac=0.3, tranche="local-nvme-0", flaps=2, period_s=25.0,
            detect_s=1.0,
            t_clear=float(t + clear_dt) if clear_dt > 0 else float("inf")))
    return FaultPlan(faults=tuple(faults), retry_backoff_s=2.0)


def _check_invariants(cfg: TraceConfig) -> None:
    sim = ClusterSimulator(cfg)
    rep = sim.run()
    jobs = rep["jobs"]
    sched = sim.scheduler

    # conservation: one terminal bucket per job, no double-counting
    assert jobs["completed"] + jobs["rejected"] + jobs["failed"] \
        + jobs["stranded"] == jobs["submitted"]
    done_names = [j.name for j in sched.done]
    assert len(done_names) == len(set(done_names)) == jobs["completed"]
    assert len(sched.failed) == jobs["failed"]

    # no negative progress, no phantom completions from stale events
    for j in sched.done:
        assert j.end_t >= j.start_t >= 0.0
        assert j.steps_done >= j.steps - 1e-9
    for j in sched.failed:
        assert j.state == "failed" and j.end_t >= 0.0

    # determinism: an identical replay is bit-identical
    rep2 = ClusterSimulator(cfg).run()
    assert json.dumps(rep, sort_keys=True, default=str) \
        == json.dumps(rep2, sort_keys=True, default=str)


# --------------------------------------------- always-on seeded sweep ----

_DENSE_CASES = [
    # overlapping device + domain faults with repairs mid-flight
    [(0, 20, 24, 30), (2, 35, 0, 25), (1, 50, 16, 0)],
    # storage churn stacked on a link brownout
    [(4, 15, 0, 40), (5, 30, 0, 30), (3, 45, 0, 0)],
    # everything at nearly the same instant
    [(0, 30, 12, 10), (2, 30, 0, 10), (5, 31, 0, 10), (3, 31, 0, 10)],
    # repeated flaps with a permanent outage underneath
    [(1, 10, 32, 0), (2, 25, 0, 0), (0, 40, 8, 20)],
]


@pytest.mark.parametrize("case", range(len(_DENSE_CASES)))
@pytest.mark.parametrize("seed", [0, 7])
def test_invariants_hold_for_dense_scripted_interleavings(case, seed):
    _check_invariants(TraceConfig(
        n_jobs=10, arrival_rate_hz=0.3, seed=seed, failures=(),
        faults=_plan(_DENSE_CASES[case])))


def test_invariants_hold_with_legacy_failures_and_faults_combined():
    _check_invariants(TraceConfig(
        n_jobs=10, arrival_rate_hz=0.3, seed=3,
        failures=((40.0, 8), (60.0, 90.0, 12), (70.0, None, 6)),
        faults=_plan([(0, 45, 16, 25), (4, 55, 0, 30)])))


def test_invariants_hold_under_mtbf_churn():
    _check_invariants(TraceConfig(
        n_jobs=12, arrival_rate_hz=0.3, seed=5, failures=(),
        faults=FaultPlan(mtbf_s=40.0, mttr_s=30.0, horizon_s=240.0,
                         mtbf_n=32, detect_s=1.0, retry_backoff_s=2.0)))


# ------------------------------------------------------ hypothesis fuzz --

def test_invariants_hold_for_random_fault_interleavings():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    choice = st.tuples(
        st.integers(min_value=0, max_value=len(_KINDS) - 1),
        st.integers(min_value=1, max_value=120),     # fault time
        st.integers(min_value=1, max_value=48),      # victim count
        st.integers(min_value=0, max_value=60))      # 0 = never clears

    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**16),
           choices=st.lists(choice, min_size=1, max_size=5))
    def prop(seed, choices):
        _check_invariants(TraceConfig(
            n_jobs=8, arrival_rate_hz=0.3, seed=seed, failures=(),
            faults=_plan(choices)))

    prop()
