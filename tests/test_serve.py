"""Serving stack: paged KV cache, chunked prefill, scheduler, engine.

The acceptance bar for the paged/chunked path is *exactness*: chunked
prefill over a paged pool must reproduce the one-shot dense-cache logits
(same greedy continuation) at fp32.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.base import PolicyConfig
from repro.models import lm
from repro.serve import (SLO, AsyncServeEngine, PageError, PagePool,
                         Request, RequestScheduler, ServeEngine,
                         ServeRequest)
from repro.serve.scheduler import DECODE
from repro.train.trainer import make_run_ctx

POLICY = PolicyConfig(compute_dtype="float32", remat="none",
                      attn_impl="full")


@pytest.fixture(scope="module")
def small_lm(rng):
    cfg = reduced(get_config("qwen2-0.5b"))
    return cfg, lm.init_lm(rng, cfg)


def _prompt(seed: int, n: int, vocab: int):
    return list(np.random.RandomState(seed).randint(0, vocab, n))


# ---------------------------------------------------------------------------
# page pool unit behaviour
# ---------------------------------------------------------------------------
def _pool(cfg, n_pages=12, page_size=8):
    return PagePool(cfg, n_pages=n_pages, page_size=page_size)


def test_page_alloc_free_recycles(small_lm):
    cfg, _ = small_lm
    pool = _pool(cfg)
    t, cached = pool.open_sequence(_prompt(0, 20, 100), max_new=4)
    assert cached == 0
    assert len(t) == pool.pages_for(24) == 3
    assert pool.in_use == 3
    pool.release(t)
    assert pool.in_use == 0 and len(t) == 0


def test_page_pool_exhaustion_raises(small_lm):
    cfg, _ = small_lm
    pool = _pool(cfg, n_pages=4)
    pool.open_sequence(_prompt(0, 20, 100), max_new=4)    # 3 pages
    with pytest.raises(PageError):
        pool.open_sequence(_prompt(1, 20, 100), max_new=4)
    assert pool.in_use == 3                   # failed open rolled back


def test_prefix_hash_hits_and_retention(small_lm):
    cfg, _ = small_lm
    pool = _pool(cfg)
    prompt = _prompt(7, 20, 100)              # 2 full pages + tail
    t1, c1 = pool.open_sequence(prompt, max_new=4)
    assert c1 == 0
    pool.close_sequence(prompt, t1)           # registers + retains
    t2, c2 = pool.open_sequence(prompt, max_new=4)
    assert c2 == 2 * pool.page_size           # both full pages reused
    assert pool.hit_tokens == 16
    # a different prompt shares nothing
    other = _prompt(8, 20, 100)
    _, c3 = pool.open_sequence(other, max_new=4)
    assert c3 == 0


def test_reused_prefix_page_is_not_evictable(small_lm):
    """Regression: a by_hash prefix hit must pull the page out of the
    retained LRU — otherwise eviction under pool pressure hands a page
    that a live sequence still references to a new sequence (silent KV
    corruption + later double-free)."""
    cfg, _ = small_lm
    pool = _pool(cfg, n_pages=6, page_size=8)
    prompt = _prompt(5, 17, 100)              # 3 pages, 2 hashable
    t1, _ = pool.open_sequence(prompt, max_new=4)
    pool.close_sequence(prompt, t1)           # 2 retained, 1 free
    t2, c2 = pool.open_sequence(prompt, max_new=4)   # reuse both pages
    assert c2 == 16
    assert not pool.retained                  # live pages left the LRU
    assert pool.in_use == 3                   # accounting sees them live
    with pytest.raises(PageError):            # only 3 pages truly free
        pool.open_sequence(_prompt(6, 28, 100), max_new=4)
    # the live table was never cannibalized
    assert all(pool.ref[p] == 1 for p in t2.pages)


def test_prefix_hit_verifies_token_content(small_lm):
    """A chain-hash collision must degrade to a miss, never re-link
    another prompt's KV pages."""
    cfg, _ = small_lm
    pool = _pool(cfg)
    prompt = _prompt(9, 20, 100)
    t1, _ = pool.open_sequence(prompt, max_new=4)
    pool.close_sequence(prompt, t1)
    page = next(p for p in range(pool.n_pages)
                if pool.page_hash[p] is not None)
    pool.page_key[page] = (0, ("collision",))    # same hash, other tokens
    _, cached = pool.open_sequence(prompt, max_new=4)
    assert cached == 0


def test_retained_pages_evicted_lru(small_lm):
    cfg, _ = small_lm
    pool = _pool(cfg, n_pages=6, page_size=8)
    p1 = _prompt(1, 17, 100)                  # 3 pages, 2 hashable
    t1, _ = pool.open_sequence(p1, max_new=4)
    pool.close_sequence(p1, t1)               # 2 retained + 1 free
    assert len(pool.retained) == 2
    p2 = _prompt(2, 40, 100)                  # needs 6 pages -> evicts
    t2, _ = pool.open_sequence(p2, max_new=4)
    assert len(t2) == 6 and pool.evictions >= 2


# ---------------------------------------------------------------------------
# chunked prefill == one-shot prefill (model level)
# ---------------------------------------------------------------------------
def test_chunked_prefill_matches_one_shot(small_lm):
    cfg, params = small_lm
    ctx = dataclasses.replace(
        make_run_ctx(cfg, POLICY, None, seq_len=32), cache_capacity=32)
    toks = jnp.asarray([_prompt(3, 21, cfg.vocab_size)])
    h1, c1, _ = lm.forward(params, toks, cfg, ctx, caches="init",
                           return_hidden=True)
    caches = None
    h = None
    for s, e in ((0, 8), (8, 16), (16, 21)):      # uneven chunks
        pos = jnp.arange(s, e)[None, :]
        h, caches, _ = lm.forward(
            params, toks[:, s:e], cfg, ctx, positions=pos,
            caches=("init" if caches is None else caches),
            return_hidden=True)
    np.testing.assert_allclose(np.asarray(h1[:, -1]), np.asarray(h[:, -1]),
                               atol=1e-5)
    # every cache leaf identical too (positions, K, V)
    for a, b in zip(jax.tree.leaves(c1), jax.tree.leaves(caches)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_chunked_prefill_matches_windowed(small_lm):
    """Sliding-window layers: chunks larger than the window stay exact."""
    cfg, _ = small_lm
    cfg = dataclasses.replace(
        cfg, block_pattern=("attn_local",) * cfg.n_layers, local_window=6)
    params = lm.init_lm(jax.random.PRNGKey(1), cfg)
    ctx = dataclasses.replace(
        make_run_ctx(cfg, POLICY, None, seq_len=32), cache_capacity=32)
    toks = jnp.asarray([_prompt(4, 20, cfg.vocab_size)])
    h1, _, _ = lm.forward(params, toks, cfg, ctx, caches="init",
                          return_hidden=True)
    h, caches = None, None
    for s, e in ((0, 8), (8, 16), (16, 20)):
        pos = jnp.arange(s, e)[None, :]
        h, caches, _ = lm.forward(
            params, toks[:, s:e], cfg, ctx, positions=pos,
            caches=("init" if caches is None else caches),
            return_hidden=True)
    np.testing.assert_allclose(np.asarray(h1[:, -1]), np.asarray(h[:, -1]),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# engine end-to-end
# ---------------------------------------------------------------------------
def _engine(cfg, params, **kw):
    kw.setdefault("n_slots", 3)
    kw.setdefault("max_seq", 96)
    kw.setdefault("page_size", 8)
    kw.setdefault("prefill_chunk", 16)
    return AsyncServeEngine(cfg, params, POLICY, **kw)


def test_paged_engine_matches_teacher_forcing(small_lm):
    cfg, params = small_lm
    eng = _engine(cfg, params)
    assert eng.mode == "paged"
    reqs = [ServeRequest(i, _prompt(10 + i, 20 + 5 * i, cfg.vocab_size),
                         max_new=5) for i in range(4)]
    for r in reqs:
        assert eng.submit(r)
    eng.run()
    assert all(r.done for r in reqs)
    ctx = make_run_ctx(cfg, POLICY, None)
    for r in reqs[:2]:
        toks = list(r.prompt)
        for expect in r.out:
            logits, _, _ = lm.forward(params, jnp.asarray([toks]), cfg, ctx)
            assert int(jnp.argmax(logits[0, -1])) == expect
            toks.append(expect)


def test_paged_engine_output_invariant_under_reuse(small_lm):
    """Prefix-cache hits change TTFT, never tokens."""
    cfg, params = small_lm
    shared = _prompt(42, 33, cfg.vocab_size)

    def run(slots):
        eng = _engine(cfg, params, n_slots=slots)
        reqs = [ServeRequest(i, shared + _prompt(50 + i, 5, cfg.vocab_size),
                             max_new=4) for i in range(4)]
        for r in reqs:
            eng.submit(r)
        eng.run()
        return eng, reqs

    e1, r1 = run(2)
    e2, r2 = run(3)
    assert e1.pool.hit_tokens > 0             # later requests reuse prefix
    for a, b in zip(r1, r2):
        assert a.out == b.out
    assert e1.pool.in_use == 0                # full recycling


def test_engine_rejects_overlong_prompt(small_lm):
    cfg, params = small_lm
    eng = _engine(cfg, params)
    bad = ServeRequest(0, _prompt(0, 95, cfg.vocab_size), max_new=8)
    assert not eng.submit(bad)
    assert bad.state == "rejected" and "capacity" in bad.why_rejected


def test_dense_mode_serves_recurrent_arch(rng):
    cfg = reduced(get_config("recurrentgemma-2b"))
    params = lm.init_lm(rng, cfg)
    eng = AsyncServeEngine(cfg, params, POLICY, n_slots=2, max_seq=64)
    assert eng.mode == "dense"
    reqs = [ServeRequest(i, _prompt(i, 12, cfg.vocab_size), max_new=3)
            for i in range(3)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done for r in reqs)


def test_dense_prefill_traces_constant_across_prompt_lengths(rng):
    """Regression (ROADMAP open item): the dense fallback's one-shot
    prefill pads prompts to pow2 buckets — four distinct lengths in one
    bucket compile ONE trace, and a second bucket adds exactly one."""
    cfg = reduced(get_config("recurrentgemma-2b"))
    params = lm.init_lm(rng, cfg)
    eng = AsyncServeEngine(cfg, params, POLICY, n_slots=2, max_seq=64)
    assert eng.mode == "dense"
    reqs = [ServeRequest(i, _prompt(i, n, cfg.vocab_size), max_new=2)
            for i, n in enumerate((9, 11, 13, 15))]   # all bucket to 16
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done for r in reqs)
    assert eng.prefill._cache_size() == 1
    eng.submit(ServeRequest(9, _prompt(9, 25, cfg.vocab_size), max_new=2))
    eng.run()
    assert eng.prefill._cache_size() == 2             # one new bucket


@pytest.mark.parametrize("arch", ["recurrentgemma-2b", "mamba2-780m"])
def test_bucketed_prefill_exact_for_recurrent_archs(arch):
    """Padded columns must not leak into recurrent/conv/ring state: the
    pow2-padded prefill reproduces the exact-length prefill bit-for-bit
    (fp32 tolerance) — logits, recurrent states, conv tails, and the
    masked attention cache slots."""
    from repro.serve.engine import make_prefill_step
    cfg = reduced(get_config(arch))
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    exact = make_prefill_step(cfg, POLICY, cache_capacity=32)
    bucket = make_prefill_step(cfg, POLICY, cache_capacity=32,
                               bucketed=True)
    L = 21
    toks = _prompt(3, L, cfg.vocab_size)
    lo, c1 = exact(params, jnp.asarray([toks]))
    lb, c2 = bucket(params, jnp.asarray([toks + [0] * (32 - L)]),
                    jnp.asarray([L], jnp.int32))
    np.testing.assert_allclose(np.asarray(lo), np.asarray(lb), atol=1e-5)
    flat1 = jax.tree_util.tree_flatten_with_path(c1)[0]
    flat2 = jax.tree_util.tree_flatten_with_path(c2)[0]
    for (path, a), (_, b) in zip(flat1, flat2):
        a, b = np.asarray(a), np.asarray(b)
        assert a.shape == b.shape, path
        key = str(getattr(path[-1], "key", ""))
        if key == "pos":
            np.testing.assert_array_equal(a, b, err_msg=str(path))
        elif key in ("k", "v"):
            # padded slots are masked by pos = -1; real slots must match
            pos = next(np.asarray(x) for p, x in flat2
                       if p[:-1] == path[:-1]
                       and str(getattr(p[-1], "key", "")) == "pos")
            np.testing.assert_allclose(a[pos >= 0], b[pos >= 0],
                                       atol=1e-5, err_msg=str(path))
        else:          # recurrent state / conv tails: exact everywhere
            np.testing.assert_allclose(a, b, atol=1e-5, err_msg=str(path))


def test_engine_telemetry_report(small_lm):
    cfg, params = small_lm
    eng = _engine(cfg, params)
    for i in range(3):
        eng.submit(ServeRequest(i, _prompt(i, 20, cfg.vocab_size),
                                max_new=4))
    eng.run()
    rep = eng.report()
    assert rep["requests"]["completed"] == 3
    assert rep["ttft_s"]["p50"] > 0
    assert rep["output_tokens"] == 12
    assert rep["kv_pages"]["in_use"] == 0


def test_legacy_dense_engine_still_serves(small_lm):
    """The dense baseline ServeEngine keeps working (and is what the
    paged path is equivalence-tested against)."""
    cfg, params = small_lm
    eng = ServeEngine(cfg, params, POLICY, n_slots=2, max_seq=64)
    req = Request(0, jnp.asarray(_prompt(1, 16, cfg.vocab_size)), max_new=4)
    assert eng.add_request(req)
    while not req.done:
        eng.step()
    assert len(req.out) == 4


# ---------------------------------------------------------------------------
# fused continuous batching
# ---------------------------------------------------------------------------
def test_fused_engine_matches_unfused_tokens(small_lm):
    """Continuous batching changes latency, never tokens: the fused
    mixed-batch iteration and the legacy alternating prefill/decode
    iterations produce identical greedy streams."""
    cfg, params = small_lm

    def serve(fused):
        eng = _engine(cfg, params, n_slots=3, fused=fused)
        reqs = [ServeRequest(i, _prompt(20 + i, 12 + 7 * i, cfg.vocab_size),
                             max_new=5) for i in range(5)]
        for r in reqs:
            assert eng.submit(r)
        eng.run()
        return eng, reqs

    ef, rf = serve(True)
    eu, ru = serve(False)
    assert ef.fused and not eu.fused
    for a, b in zip(rf, ru):
        assert a.done and a.out == b.out
    assert ef.pool.in_use == 0 and eu.pool.in_use == 0


def test_fused_mixed_batch_rows_bit_exact(small_lm):
    """A decode row and a prefill-chunk row fused into ONE batch produce
    logits bit-identical to the same rows run alone (same row width, same
    table width, padding row in place) — rows in the mixed batch must not
    interact."""
    cfg, params = small_lm
    C = 8
    eng = _engine(cfg, params, n_slots=2, prefill_chunk=C)
    r0 = ServeRequest(0, _prompt(60, 12, cfg.vocab_size), max_new=4)
    assert eng.submit(r0)
    eng.step()                         # chunk 1 (8 tokens)
    eng.step()                         # chunk 2 -> DECODE, first token
    assert r0.state == DECODE and len(r0.out) == 1
    r1 = ServeRequest(1, _prompt(61, 20, cfg.vocab_size), max_new=4)
    assert eng.submit(r1)
    eng.sched.admit(eng.now(), eng._try_open)
    plan = eng.sched.iteration_plan()
    assert [(r.rid, n) for r, n in plan] == [(0, 1), (1, C)]

    # build the fused rows exactly as _paged_fused does (W = chunk)
    p0 = r0.prompt_len + len(r0.out) - 1
    toks = [[r0.out[-1]] + [0] * (C - 1),
            [int(t) for t in r1.prompt[:C]]]
    poss = [[p0 + i for i in range(C)], list(range(C))]
    vals = [[True] + [False] * (C - 1), [True] * C]
    last = [0, C - 1]
    P = eng._table_width([r0, r1])
    tables = jnp.stack([eng.pool.padded_table(r.table, P) for r in (r0, r1)])
    snap = jax.tree.map(jnp.array, eng.pool.pages)   # _paged_step donates

    def step(tab, tk, ps, vl, lx):
        _, logits, _ = eng._paged_step(
            eng.params, jax.tree.map(jnp.array, snap), tab,
            jnp.asarray(tk, jnp.int32), jnp.asarray(ps, jnp.int32),
            jnp.asarray(vl, bool), jnp.asarray(lx, jnp.int32))
        return np.asarray(logits)

    mixed = step(tables, toks, poss, vals, last)
    pad_t = jnp.full((P,), eng.pool.trash, jnp.int32)
    zrow = [0] * C
    for i in range(2):
        alone = step(jnp.stack([tables[i], pad_t]),
                     [toks[i], zrow], [poss[i], zrow],
                     [vals[i], [False] * C], [last[i], 0])
        assert np.array_equal(mixed[i], alone[0]), f"row {i} diverged"


def test_iteration_plan_packs_token_budget():
    """Decode rows always ride (1 token each); prefill chunks pack the
    remaining budget in policy order, the last clipped to fit."""
    sched = RequestScheduler(max_slots=8, max_prompt=64, prefill_chunk=8,
                             prefill_batch=2, token_budget=7)
    dec = [ServeRequest(i, [1] * 4, max_new=4) for i in (0, 1)]
    pre = [ServeRequest(i, [1] * 20, max_new=4) for i in (2, 3)]
    for r in dec:
        r.state = DECODE
        r.out = [1]
    for r in pre:
        r.state = "prefill"
    sched.active = dec + pre
    plan = [(r.rid, n) for r, n in sched.iteration_plan()]
    # budget 7: 2 decode tokens, then ONE chunk clipped 8 -> 5
    assert plan == [(0, 1), (1, 1), (2, 5)]
    sched.token_budget = 100           # roomy: both chunks, unclipped,
    plan = [(r.rid, n) for r, n in sched.iteration_plan()]
    assert plan == [(0, 1), (1, 1), (2, 8), (3, 8)]


def test_warmup_is_pure_and_reports_compile(small_lm):
    """warmup() compiles the paged step without touching pool accounting,
    stats, or the served tokens; compile time lands in ``compile_s`` (and
    report()), not in the latency percentiles."""
    cfg, params = small_lm
    eng = _engine(cfg, params)
    dt = eng.warmup()
    assert dt > 0 and eng.compile_s == dt
    kv = eng.pool.stats()
    assert kv["in_use"] == 0 and kv["allocations"] == 0
    assert kv["hit_tokens"] == 0 and kv["peak_in_use"] == 0
    assert eng.stats.requests_submitted == 0

    def serve(e):
        reqs = [ServeRequest(i, _prompt(70 + i, 14, cfg.vocab_size),
                             max_new=4) for i in range(3)]
        for r in reqs:
            e.submit(r)
        e.run()
        return [r.out for r in reqs]

    cold = _engine(cfg, params)        # never warmed
    assert serve(eng) == serve(cold)
    assert eng.report()["compile_s"] == dt
    assert cold.report()["compile_s"] == 0.0


def test_report_peak_and_mean_utilization(small_lm):
    """kv_pages reports the high-water mark and the per-iteration mean,
    not just the post-drain sample (always 0 once requests finish)."""
    cfg, params = small_lm
    eng = _engine(cfg, params)
    for i in range(4):
        eng.submit(ServeRequest(i, _prompt(80 + i, 20, cfg.vocab_size),
                                max_new=4))
    eng.run()
    kv = eng.report()["kv_pages"]
    assert kv["in_use"] == 0                       # drained
    assert 0 < kv["peak_utilization"] <= 1
    assert 0 < kv["mean_utilization"] <= kv["peak_utilization"]
    assert kv["peak_in_use"] == round(kv["peak_utilization"] * kv["n_pages"])


# ---------------------------------------------------------------------------
# request scheduler policies
# ---------------------------------------------------------------------------
def test_scheduler_slo_orders_by_deadline():
    sched = RequestScheduler(max_slots=4, max_prompt=64, policy="slo")
    lax_ = ServeRequest(0, [1] * 8, slo=SLO(ttft_s=9.0))
    tight = ServeRequest(1, [1] * 8, slo=SLO(ttft_s=0.5))
    sched.submit(lax_, now=0.0)
    sched.submit(tight, now=0.1)
    admitted = sched.admit(0.2, lambda r: True)
    for r in admitted:
        r.state = DECODE
    assert admitted[0].rid == 1               # tighter deadline first


def test_scheduler_priority_and_fcfs():
    for policy, first in (("priority", 1), ("fcfs", 0)):
        sched = RequestScheduler(max_slots=1, max_prompt=64, policy=policy)
        sched.submit(ServeRequest(0, [1] * 8, priority=0), now=0.0)
        sched.submit(ServeRequest(1, [1] * 8, priority=5), now=0.1)
        admitted = sched.admit(0.2, lambda r: True)
        assert admitted[0].rid == first, policy


def test_scheduler_rejects_oversized_and_interleaves_chunks():
    sched = RequestScheduler(max_slots=4, max_prompt=32, prefill_chunk=8,
                             prefill_batch=2)
    assert not sched.submit(ServeRequest(0, [1] * 40), now=0.0)
    # a 0-token decode budget can't be honored (first token comes from
    # the prefill's last hidden state)
    assert not sched.submit(ServeRequest(9, [1] * 8, max_new=0), now=0.0)
    long_req = ServeRequest(1, [1] * 24, max_new=4)
    sched.submit(long_req, now=0.0)
    sched.admit(0.0, lambda r: True)
    assert sched.chunk_for(long_req) == 8     # chunked, not all 24
    sched.note_prefilled(long_req, 8, 0.1)
    assert long_req.state != DECODE
    sched.note_prefilled(long_req, 16, 0.2)
    assert long_req.state == DECODE
