"""Sharded execution paths == unsharded math (8-device subprocess mesh),
plus an end-to-end dry-run of one cell at reduced device count."""
import os
import subprocess
import sys
import textwrap

import pytest


def _run(src: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", src], env=env,
                       capture_output=True, text=True, timeout=1200,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-3000:])
    return r.stdout


@pytest.mark.slow
def test_sharded_flash_and_ssd_match_unsharded():
    out = _run(textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np
        import jax, jax.numpy as jnp
        from repro.models.attention import sharded_flash, full_attention
        from repro.models.ssm import ssd_sharded, ssd_chunked
        mesh = jax.sharding.Mesh(
            np.asarray(jax.devices()).reshape(4, 2), ("data", "model"))
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)

        # flash: GQA with tp | H (H=4, tp=2) under the mesh
        q = jax.random.normal(k1, (4, 128, 4, 32))
        k = jax.random.normal(k2, (4, 128, 2, 32))
        v = jax.random.normal(k3, (4, 128, 2, 32))
        with mesh:
            got = jax.jit(lambda q, k, v: sharded_flash(
                q, k, v, mesh=mesh, dp_axes=("data",), tp_axis="model",
                q_block=64, kv_block=64))(q, k, v)
        want = full_attention(q, k, v, causal=True)
        np.testing.assert_allclose(got, want, atol=3e-5, rtol=3e-5)
        print("FLASH_SHARDED_OK")

        # flash with tp NOT dividing H (H=6 -> pad to 8)
        q6 = jax.random.normal(k1, (4, 128, 6, 32))
        k6 = jax.random.normal(k2, (4, 128, 3, 32))
        v6 = jax.random.normal(k3, (4, 128, 3, 32))
        with mesh:
            got6 = jax.jit(lambda q, k, v: sharded_flash(
                q, k, v, mesh=mesh, dp_axes=("data",), tp_axis="model",
                q_block=64, kv_block=64))(q6, k6, v6)
        want6 = full_attention(q6, k6, v6, causal=True)
        np.testing.assert_allclose(got6, want6, atol=3e-5, rtol=3e-5)
        print("FLASH_PADDED_OK")

        # SSD: H=4 over tp=2
        x = jax.random.normal(k1, (4, 64, 4, 16))
        dt = jax.nn.softplus(jax.random.normal(k2, (4, 64, 4)))
        A = -jnp.exp(jax.random.normal(k3, (4,)))
        Bm = jax.random.normal(k2, (4, 64, 1, 32)) * 0.5
        Cm = jax.random.normal(k3, (4, 64, 1, 32)) * 0.5
        with mesh:
            y1, h1 = jax.jit(lambda *a: ssd_sharded(
                *a, chunk=32, mesh=mesh, dp_axes=("data",),
                tp_axis="model"))(x, dt, A, Bm, Cm)
        y2, h2 = ssd_chunked(x, dt, A, Bm, Cm, chunk=32)
        np.testing.assert_allclose(y1, y2, atol=2e-4, rtol=2e-4)
        np.testing.assert_allclose(h1, h2, atol=2e-4, rtol=2e-4)
        print("SSD_SHARDED_OK")
    """))
    assert "FLASH_SHARDED_OK" in out
    assert "FLASH_PADDED_OK" in out
    assert "SSD_SHARDED_OK" in out


@pytest.mark.slow
def test_dryrun_cell_end_to_end_small_mesh():
    """The full lower_cell machinery (policy shardings + cost extraction)
    on an in-CI 4x4 mesh with a reduced-but-real arch cell."""
    out = _run(textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
        import argparse, jax
        from repro.launch.dryrun import lower_cell, make_policy
        from repro.launch.mesh import make_mesh
        args = argparse.Namespace(zero=3, dtype="bfloat16", remat="block",
                                  grad_accum=1, compress="none",
                                  param_dtype="float32")
        mesh = make_mesh((4, 4), ("data", "model"))
        policy = make_policy(args, False)
        lowered, compiled, report = lower_cell(
            "qwen2-0.5b", "train_4k", mesh, policy)
        assert report.flops_hlo > 0
        assert report.hbm_bytes > 0
        assert len(report.collectives) > 0
        axes = {a for op in report.collectives for a in op.axes}
        assert axes <= {"data", "model"} and axes
        print("DRYRUN_CELL_OK", len(report.collectives))
    """))
    assert "DRYRUN_CELL_OK" in out
