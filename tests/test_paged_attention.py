"""Pallas paged-attention decode kernel vs the dense-gather reference.

The acceptance bar: the kernel reads K/V straight from the paged pool
through scalar-prefetched block tables and must match the dense
``decode_attention`` math (gather + masked softmax) to fp32 tolerance
across page boundaries, ragged lengths, GQA/MQA groupings, and any
``block_k`` tiling — and it must be reachable through the registry's
``decode_attention`` bucket vocabulary.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref, registry
from repro.kernels.paged_attention import paged_decode_attention

KEY = jax.random.PRNGKey(11)
KQ, KKV, KP = jax.random.split(KEY, 3)


@pytest.fixture(autouse=True)
def _isolate_registry():
    registry.set_registry(None)
    yield
    registry.reset_registry()


def _paged_inputs(B, T, D, G, K, ps, lengths, seed=0):
    """Random q + paged K/V pool with per-row exclusive, shuffled tables."""
    H = G * K
    P = T // ps
    n_pages = B * P + 1                      # +1 unreferenced page
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(kq, (B, H, D), jnp.float32)
    k_pages = jax.random.normal(kk, (n_pages, ps, K, D), jnp.float32)
    v_pages = jax.random.normal(kv, (n_pages, ps, K, D), jnp.float32)
    # deterministic shuffle: non-contiguous gather is the point
    perm = np.random.RandomState(seed).permutation(B * P)
    tables = jnp.asarray(perm.reshape(B, P), jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    return q, k_pages, v_pages, tables, lengths


CASES = [
    # B, T, D, G, K, page_size, block_k, lengths
    (2, 64, 32, 2, 2, 16, 32, [64, 40]),       # ragged, mid-page end
    (1, 128, 64, 1, 4, 16, 48, [96]),          # non-pow2 ppb=3, MHA
    (4, 64, 32, 4, 1, 8, 256, [64, 8, 17, 33]),  # MQA, block_k > T clamps
    (2, 64, 32, 2, 2, 16, 16, [16, 32]),       # exact page boundaries
    (3, 32, 64, 2, 2, 8, 8, [1, 31, 32]),      # single-token history
]


@pytest.mark.parametrize("B,T,D,G,K,ps,bk,lengths", CASES)
def test_paged_kernel_matches_ref(B, T, D, G, K, ps, bk, lengths):
    q, kp, vp, tables, lens = _paged_inputs(B, T, D, G, K, ps, lengths)
    out = paged_decode_attention(q, kp, vp, tables, lens, block_k=bk,
                                 interpret=True)
    oracle = ref.paged_attention_ref(q, kp, vp, tables, lens)
    np.testing.assert_allclose(out, oracle, atol=1e-5, rtol=1e-5)


def test_paged_block_shape_independence():
    """The result must not depend on pages-per-block tiling."""
    q, kp, vp, tables, lens = _paged_inputs(2, 128, 32, 2, 2, 16, [128, 70])
    outs = [paged_decode_attention(q, kp, vp, tables, lens, block_k=bk,
                                   interpret=True)
            for bk in (16, 48, 64, 128)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=1e-5, rtol=1e-5)


def test_paged_zero_length_row_is_finite():
    """An empty history (freshly opened slot) must not NaN the batch."""
    q, kp, vp, tables, lens = _paged_inputs(2, 64, 32, 2, 2, 16, [0, 64])
    out = paged_decode_attention(q, kp, vp, tables, lens, block_k=32,
                                 interpret=True)
    assert bool(jnp.isfinite(out).all())
    oracle = ref.paged_attention_ref(q, kp, vp, tables, lens)
    np.testing.assert_allclose(out[1], oracle[1], atol=1e-5, rtol=1e-5)


def test_paged_softcap_matches_ref():
    q, kp, vp, tables, lens = _paged_inputs(2, 64, 32, 2, 2, 16, [64, 50])
    out = paged_decode_attention(q, kp, vp, tables, lens, block_k=32,
                                 softcap=30.0, interpret=True)
    oracle = ref.paged_attention_ref(q, kp, vp, tables, lens, softcap=30.0)
    np.testing.assert_allclose(out, oracle, atol=1e-5, rtol=1e-5)


def test_ops_dispatch_pallas_matches_xla():
    """The jitted ops wrapper: both impls agree on the same inputs."""
    q, kp, vp, tables, lens = _paged_inputs(2, 64, 32, 2, 2, 16, [64, 40])
    a = ops.paged_attention(q, kp, vp, tables, lens, impl="pallas",
                            interpret=True)
    b = ops.paged_attention(q, kp, vp, tables, lens, impl="xla")
    np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)


def test_registry_selects_tuned_decode_block():
    """A tuned ``decode_attention`` cell steers the kernel's block_k and
    the tuned tiling still reproduces the reference."""
    B, T, D, G, K, ps = 2, 64, 32, 2, 2, 16
    q, kp, vp, tables, lens = _paged_inputs(B, T, D, G, K, ps, [64, 33])
    key = registry.make_key("decode_attention", dtype="float32",
                            variant="causal", b=B, t=T, d=D, g=G)
    reg = registry.Registry()
    reg.put(key, registry.TunedEntry(blocks={"block_q": 1, "block_k": 16}))
    registry.set_registry(reg)
    bq, bk = registry.decode_attention_blocks(B, T, D, G, jnp.float32)
    assert (bq, bk) == (1, 16)
    out = ops.paged_attention(q, kp, vp, tables, lens, impl="pallas",
                              interpret=True)     # block_k=None -> tuned
    oracle = ref.paged_attention_ref(q, kp, vp, tables, lens)
    np.testing.assert_allclose(out, oracle, atol=1e-5, rtol=1e-5)
