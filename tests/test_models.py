"""Model-substrate unit + property tests (MoE dispatch, segments, losses)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import get_config, reduced
from repro.configs.base import (ATTN, ATTN_LOCAL, MoEConfig, ModelConfig,
                                RGLRU, SSM)
from repro.models import layers, moe
from repro.models.transformer import plan_segments

KEY = jax.random.PRNGKey(3)


# ---------------------------------------------------------------------------
# segment planning (scan-over-layers)
# ---------------------------------------------------------------------------
@given(st.lists(st.sampled_from([ATTN, ATTN_LOCAL, SSM, RGLRU]),
                min_size=1, max_size=60))
@settings(max_examples=200, deadline=None)
def test_plan_segments_reconstructs_pattern(pattern):
    """Invariant: concatenating unit*repeats over segments == pattern."""
    segs = plan_segments(tuple(pattern))
    flat = []
    for unit, k in segs:
        flat.extend(list(unit) * k)
    assert tuple(flat) == tuple(pattern)
    assert len(segs) <= 2


def test_plan_segments_griffin_pattern():
    pat = (RGLRU, RGLRU, ATTN_LOCAL) * 8 + (RGLRU, RGLRU)
    segs = plan_segments(pat)
    assert segs[0] == ((RGLRU, RGLRU, ATTN_LOCAL), 8)
    assert segs[1] == ((RGLRU, RGLRU), 1)


# ---------------------------------------------------------------------------
# MoE: dense oracle vs sorted dispatch; conservation properties
# ---------------------------------------------------------------------------
def _moe_cfg(E=8, k=2, d=64, f=128):
    return ModelConfig(
        name="moe-test", family="moe", n_layers=1, d_model=d, n_heads=4,
        n_kv_heads=4, d_ff=f, vocab_size=128,
        moe=MoEConfig(n_experts=E, top_k=k, d_ff_expert=f,
                      capacity_factor=8.0))  # high cf -> no drops


def test_moe_sorted_matches_dense_oracle():
    cfg = _moe_cfg()
    params = moe.init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (64, cfg.d_model))
    y_dense, aux_d = moe.moe_dense(params, x, cfg, jnp.float32)
    y_sorted, aux_s = moe.moe_sorted(params, x, cfg,
                                     compute_dtype=jnp.float32)
    np.testing.assert_allclose(y_sorted, y_dense, atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(aux_d, aux_s, atol=1e-6)


def test_moe_expert_slices_sum_to_full():
    """EP invariant: sum of per-slice partial outputs == full output."""
    cfg = _moe_cfg(E=8, k=2)
    params = moe.init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (32, cfg.d_model))
    full, _ = moe.moe_sorted(params, x, cfg, compute_dtype=jnp.float32,
                             capacity=64)
    parts = []
    for e0 in range(0, 8, 2):
        y, _ = moe.moe_sorted(params, x, cfg, compute_dtype=jnp.float32,
                              capacity=64, expert_slice=(e0, 2))
        parts.append(y)
    np.testing.assert_allclose(sum(parts), full, atol=2e-5, rtol=2e-5)


@given(T=st.integers(4, 64), E=st.integers(2, 16), k=st.integers(1, 4),
       cf=st.floats(0.5, 4.0))
@settings(max_examples=40, deadline=None)
def test_capacity_bounds(T, E, k, cf):
    k = min(k, E)
    C = moe.default_capacity(T, E, k, cf)
    assert 4 <= C <= T or C == T or C >= 4
    assert C <= max(T, 4)


def test_router_gates_normalized():
    cfg = _moe_cfg()
    params = moe.init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (32, cfg.d_model))
    gates, idx, aux = moe.route(x, params["router"], cfg.moe.top_k)
    np.testing.assert_allclose(jnp.sum(gates, -1), 1.0, atol=1e-5)
    assert int(jnp.max(idx)) < cfg.moe.n_experts
    assert float(aux) >= 1.0 - 1e-3   # Switch aux lower bound is ~1 at uniform


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------
def test_chunked_xent_matches_full():
    B, S, D, V = 2, 32, 16, 64
    x = jax.random.normal(KEY, (B, S, D))
    table = jax.random.normal(KEY, (V, D)) * 0.1
    labels = jax.random.randint(KEY, (B, S), 0, V)
    full = layers.softmax_xent(x @ table.T, labels)
    for chunk in (4, 8, 32):
        ch = layers.chunked_softmax_xent(x, table, labels, chunk=chunk,
                                         compute_dtype=jnp.float32)
        np.testing.assert_allclose(ch, full, atol=1e-5, rtol=1e-5)


def test_chunked_xent_mask():
    B, S, D, V = 1, 16, 8, 32
    x = jax.random.normal(KEY, (B, S, D))
    table = jax.random.normal(KEY, (V, D)) * 0.1
    labels = jax.random.randint(KEY, (B, S), 0, V)
    mask = (jnp.arange(S) < 8)[None].astype(jnp.float32)
    a = layers.softmax_xent(x @ table.T, labels, mask)
    b = layers.chunked_softmax_xent(x, table, labels, chunk=4,
                                    compute_dtype=jnp.float32, mask=mask)
    np.testing.assert_allclose(a, b, atol=1e-5)


@given(st.integers(2, 128))
@settings(max_examples=20, deadline=None)
def test_gold_logit_equals_take_along_axis(V):
    logits = jax.random.normal(KEY, (3, 5, V))
    labels = jax.random.randint(KEY, (3, 5), 0, V)
    a = layers._gold_logit(logits, labels)
    b = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    np.testing.assert_allclose(a, b, atol=1e-6)


# ---------------------------------------------------------------------------
# rope / norms
# ---------------------------------------------------------------------------
def test_rope_preserves_norm():
    x = jax.random.normal(KEY, (2, 8, 4, 32))
    pos = jnp.broadcast_to(jnp.arange(8), (2, 8))
    y = layers.apply_rope(x, pos)
    np.testing.assert_allclose(jnp.linalg.norm(x, axis=-1),
                               jnp.linalg.norm(y, axis=-1),
                               atol=1e-4, rtol=1e-4)


def test_rope_relative_position_property():
    """Attention scores depend only on relative distance under RoPE."""
    D = 32
    q = jax.random.normal(KEY, (1, 1, 1, D))
    k = jax.random.normal(jax.random.PRNGKey(8), (1, 1, 1, D))
    def score(pq, pk):
        qq = layers.apply_rope(q, jnp.full((1, 1), pq))
        kk = layers.apply_rope(k, jnp.full((1, 1), pk))
        return float(jnp.sum(qq * kk))
    assert abs(score(5, 3) - score(105, 103)) < 1e-3


def test_partial_rotary():
    x = jax.random.normal(KEY, (1, 4, 2, 64))
    pos = jnp.broadcast_to(jnp.arange(4), (1, 4))
    y = layers.apply_rope(x, pos, fraction=0.25)
    # the pass-through part is untouched
    np.testing.assert_array_equal(x[..., 16:], y[..., 16:])
    assert not np.allclose(x[..., :16][:, 1:], y[..., :16][:, 1:])
