"""Gang scheduling + pluggable policy coverage.

Pins the four tentpole behaviors of the PR-5 control-plane change:

  * ``policy="easy"`` without gangs reproduces the PR-4 scheduling
    order bit-for-bit (golden start order of the benchmark trace);
  * ``fair_share`` beats ``easy`` on the skewed-tenant scenario the
    ``cluster_sim`` artifact ships;
  * ``priority_preempt`` evicts exactly the lowest-priority gang;
  * gang leases are all-or-nothing (an induced partial-claim failure
    leaves the pool unchanged) and simulator replay is deterministic
    per policy.
"""
import json

import pytest

from benchmarks.cluster_sim import BENCH_CFG, SKEW_CFG, policy_report
from repro.cluster import (ClusterSimulator, Job, JobTemplate, LeaseManager,
                           Scheduler, TraceConfig, make_policy, plan_gang)
from repro.cluster.scheduler import DONE, POLICIES, QUEUED, RUNNING
from repro.core.compose import CompositionError
from repro.core.topology import LinkClass, make_pool


def _gang(name, n_chips=64, n_pods=2, priority=0, steps=10,
          arch="qwen2-0.5b", shape="train_4k"):
    return Job(name=name, arch=arch, shape_name=shape, n_chips=n_chips,
               steps=steps, n_pods=n_pods, priority=priority)


# ---------------------------------------------------------------------------
# easy must stay bit-compatible with the pre-policy scheduler (PR 4)
# ---------------------------------------------------------------------------
# start order of benchmarks.cluster_sim.BENCH_CFG captured on the PR-4
# code (before Policy/gangs existed); job names encode arch/shape
PR4_START_ORDER = [
    "qwen2-0.5b-train_4k", "qwen2-0.5b-train_4k", "mamba2-780m-train_4k",
    "llama3.2-3b-train_4k", "llama3.2-3b-train_4k", "qwen2-0.5b-train_4k",
    "llama3.2-3b-decode_32k", "qwen2-0.5b-train_4k",
    "moonshot-v1-16b-a3b-train_4k", "llama3.2-3b-train_4k",
    "qwen2-0.5b-train_4k", "mamba2-780m-train_4k", "qwen2-0.5b-train_4k",
    "llama3.2-3b-decode_32k", "llama3.2-3b-prefill_32k",
    "mamba2-780m-train_4k", "qwen2-0.5b-train_4k", "qwen2-0.5b-train_4k",
    "llama3.2-3b-train_4k", "llama3.2-3b-prefill_32k",
    "mamba2-780m-train_4k", "llama3.2-3b-decode_32k",
    "llama3.2-3b-prefill_32k", "stablelm-12b-prefill_32k",
]


def test_easy_reproduces_pr4_start_order():
    sim = ClusterSimulator(BENCH_CFG)
    rep = sim.run()
    assert rep["policy"] == "easy"
    starts = [e.job for e in sim.telemetry.events if e.kind == "start"]
    assert [s.split("-", 2)[2] for s in starts] == PR4_START_ORDER
    # ... and the PR-4 job names themselves still arrive in index order
    assert [int(s.split("-")[1]) for s in starts] == list(range(24))
    assert rep["jobs"]["completed"] == 24
    assert rep["lease_conflicts"] == 0


def test_make_policy_factory():
    assert set(POLICIES) == {"easy", "fair_share", "priority_preempt"}
    for name in POLICIES:
        assert make_policy(name).name == name
    with pytest.raises(ValueError):
        make_policy("srtf")


# ---------------------------------------------------------------------------
# fair_share vs easy on the skewed-tenant trace
# ---------------------------------------------------------------------------
def test_fair_share_beats_easy_on_skewed_trace():
    easy = policy_report("easy")
    fair = policy_report("fair_share")
    # all work completes under both policies (fair share is reordering,
    # not starvation)
    for rep in (easy, fair):
        assert rep["jobs"]["completed"] == rep["jobs"]["submitted"]
        assert rep["jobs"]["stranded"] == 0
        assert rep["lease_conflicts"] == 0
        assert rep["gangs"]["started"] >= 1
    # the headline artifact claim: mean per-tenant p95 queue wait drops
    assert fair["fairness"]["tenant_p95_wait_mean_s"] < \
        easy["fairness"]["tenant_p95_wait_mean_s"]
    # ... because the light tenants stop queueing behind the flood
    for tenant in ("blue", "green"):
        assert fair["fairness"]["tenants"][tenant]["wait_s"]["p95"] < \
            easy["fairness"]["tenants"][tenant]["wait_s"]["p95"]


def test_fair_share_weights_shift_the_order():
    """A tenant with a large weight is entitled to more device-seconds
    before losing its place, so it orders ahead of an equal-usage
    tenant with a smaller weight."""
    pool = make_pool(n_local=32, n_switch=0, pods=1)
    sched = Scheduler(pool, policy="fair_share",
                      tenant_weights={"vip": 8.0, "std": 1.0})
    sched.tenant_usage.update({"vip": 80.0, "std": 40.0})
    a = Job(name="a", arch="qwen2-0.5b", shape_name="train_4k",
            n_chips=16, tenant="std")
    b = Job(name="b", arch="qwen2-0.5b", shape_name="train_4k",
            n_chips=16, tenant="vip")
    sched.submit(a, 0.0)
    sched.submit(b, 1.0)
    # vip deficit 80/8=10 < std 40/1=40 -> b first despite arriving later
    order = sched.policy.order(sched, 1.0)
    assert [j.name for j in order] == ["b", "a"]


# ---------------------------------------------------------------------------
# priority preemption: evict exactly the lowest-priority gang
# ---------------------------------------------------------------------------
def test_priority_preempt_evicts_exactly_lowest_priority_gang():
    pool = make_pool(n_local=128, n_switch=128, pods=2)
    sched = Scheduler(pool, policy="priority_preempt")
    lo = _gang("gang-lo", n_chips=128, priority=1, steps=200)
    mid = _gang("gang-mid", n_chips=128, priority=2, steps=200)
    assert sched.submit(lo, 0.0) and sched.submit(mid, 0.0)
    assert {j.name for j in sched.poll(0.0)} == {"gang-lo", "gang-mid"}
    hi = Job(name="hi", arch="qwen2-0.5b", shape_name="train_4k",
             n_chips=128, steps=5, priority=5)
    sched.submit(hi, 10.0)
    started = sched.poll(10.0)
    assert [j.name for j in started] == ["hi"]
    assert lo.state == QUEUED            # exactly the lowest gang evicted
    assert mid.state == RUNNING          # higher-priority gang untouched
    assert hi.state == RUNNING
    assert sched.telemetry.jobs_evicted == 1
    assert sched.telemetry.jobs_preempted == 1
    assert [j.name for j in sched.drain_policy_victims()] == ["gang-lo"]
    sched.manager.check_exclusive()
    # the evicted gang resumes once the preemptor finishes
    sched.on_complete(hi, 20.0)
    assert [j.name for j in sched.poll(20.0)] == ["gang-lo"]
    assert lo.system.axis_sizes == (2, 64, 1)


def test_priority_preempt_shrinks_when_half_a_victim_suffices():
    pool = make_pool(n_local=32, n_switch=0, pods=1)
    sched = Scheduler(pool, policy="priority_preempt")
    lo = Job(name="lo", arch="qwen2-0.5b", shape_name="train_4k",
             n_chips=32, steps=100, priority=0)
    sched.submit(lo, 0.0)
    sched.poll(0.0)
    hi = Job(name="hi", arch="qwen2-0.5b", shape_name="train_4k",
             n_chips=16, steps=5, priority=5)
    sched.submit(hi, 5.0)
    assert [j.name for j in sched.poll(5.0)] == ["hi"]
    # the victim kept running at half width instead of losing its slot
    assert lo.state == RUNNING
    assert lo.system.shape["data"] == 16
    assert sched.telemetry.jobs_shrunk == 1
    assert sched.telemetry.jobs_evicted == 0
    sched.manager.check_exclusive()


def test_priority_preempt_defragments_domains_for_gang():
    """A gang can be blocked by domain fragmentation with a raw chip
    surplus: enough chips free in total, but no n_pods domains holding a
    full member each.  The policy must evict by member-domain deficit,
    not by chip count (which is already <= 0 here)."""
    pool = make_pool(n_local=64, n_switch=0, pods=4)     # 16 chips/domain
    sched = Scheduler(pool, policy="priority_preempt")
    lows = [Job(name=f"lo{i}", arch="qwen2-0.5b", shape_name="train_4k",
                n_chips=8, steps=200, priority=0) for i in range(3)]
    for j in lows:
        sched.submit(j, 0.0)
    sched.poll(0.0)
    assert all(j.state == RUNNING for j in lows)         # doms 0,1,2 half-full
    gang = _gang("g", n_chips=32, n_pods=2, priority=5, steps=5)
    sched.submit(gang, 1.0)
    # 40 chips free (> 32 requested) but only domain 3 holds a full
    # 16-chip member: one low job must be evicted to free a second one
    started = sched.poll(1.0)
    assert started[0].name == "g"
    assert gang.state == RUNNING
    assert sched.telemetry.jobs_evicted == 1
    # the evicted job restarts right away on the leftover fragments (8
    # free chips remain in two other domains) — nothing is stranded
    assert [j.name for j in started[1:]] == ["lo0"]
    assert all(j.state == RUNNING for j in lows)
    sched.manager.check_exclusive()


def test_gang_with_oversized_member_clique_rejected_at_submit():
    """A member clique larger than every locality domain can never
    place; it must reject at submit instead of stranding at the queue
    head forever."""
    pool = make_pool(n_local=64, n_switch=0, pods=4)     # 16 chips/domain
    sched = Scheduler(pool)
    job = _gang("g", n_chips=64, n_pods=2)               # 32-chip members
    assert not sched.submit(job, 0.0)
    assert "large enough" in job.why_rejected


def test_gang_indivisible_chips_rejected_at_submit():
    """A gang whose chip count does not divide over its pods can never
    build equal member cliques; submit() must reject it with the
    divisibility reason rather than let sizing truncate chips (10 over
    4 pods would otherwise run as 4x2=8 chips)."""
    pool = make_pool(n_local=64, n_switch=0, pods=4)
    sched = Scheduler(pool)
    job = _gang("odd", n_chips=10, n_pods=4)
    assert not sched.submit(job, 0.0)
    assert job.state == "rejected"
    assert job.why_rejected == "10 chips do not divide over 4 gang pods"
    # ... and the divisible sibling sails through the same check
    ok = _gang("even", n_chips=16, n_pods=4)
    assert sched.submit(ok, 0.0)
    assert ok.state == QUEUED


def test_no_eviction_when_head_cannot_fit_anyway():
    """Livelock regression: a head pinned by an equal-priority job must
    not trigger evictions of lower-priority work — backfill would
    restart the victim and the same poll iteration would evict it
    again, forever, at one simulated timestamp."""
    pool = make_pool(n_local=32, n_switch=0, pods=1)
    sched = Scheduler(pool, policy="priority_preempt")
    blocker = Job(name="blocker", arch="qwen2-0.5b", shape_name="train_4k",
                  n_chips=16, steps=200, priority=5)
    victim = Job(name="victim", arch="qwen2-0.5b", shape_name="train_4k",
                 n_chips=8, steps=200, priority=0)
    sched.submit(blocker, 0.0)
    sched.submit(victim, 0.0)
    sched.poll(0.0)
    assert blocker.state == RUNNING and victim.state == RUNNING
    head = Job(name="head", arch="qwen2-0.5b", shape_name="train_4k",
               n_chips=32, steps=5, priority=5)
    sched.submit(head, 1.0)
    started = sched.poll(1.0)            # must terminate, evicting nothing
    assert started == []
    assert head.state == QUEUED
    assert victim.state == RUNNING       # pointless eviction avoided
    assert sched.telemetry.jobs_evicted == 0
    assert sched.telemetry.jobs_preempted == 0


def test_no_gang_eviction_when_domains_cannot_complete_a_clique():
    """Same livelock guard on the gang path: a member domain is only a
    target if evicting every victim there completes a clique."""
    pool = make_pool(n_local=32, n_switch=0, pods=2)     # 16 chips/domain
    sched = Scheduler(pool, policy="priority_preempt")
    blocker = Job(name="blocker", arch="qwen2-0.5b", shape_name="train_4k",
                  n_chips=16, steps=200, priority=5)     # pins domain 0
    victim = Job(name="victim", arch="qwen2-0.5b", shape_name="train_4k",
                 n_chips=8, steps=200, priority=0)       # half of domain 1
    sched.submit(blocker, 0.0)
    sched.submit(victim, 0.0)
    sched.poll(0.0)
    gang = _gang("g", n_chips=32, n_pods=2, priority=5, steps=5)
    sched.submit(gang, 1.0)
    started = sched.poll(1.0)
    # domain 0 cannot reach 16 free even evicting everything evictable:
    # no eviction may happen and poll must terminate
    assert started == []
    assert gang.state == QUEUED and victim.state == RUNNING
    assert sched.telemetry.jobs_evicted == 0


def test_equal_priority_never_preempts():
    pool = make_pool(n_local=32, n_switch=0, pods=1)
    sched = Scheduler(pool, policy="priority_preempt")
    a = Job(name="a", arch="qwen2-0.5b", shape_name="train_4k",
            n_chips=32, steps=100, priority=3)
    sched.submit(a, 0.0)
    sched.poll(0.0)
    b = Job(name="b", arch="qwen2-0.5b", shape_name="train_4k",
            n_chips=32, steps=5, priority=3)
    sched.submit(b, 1.0)
    assert sched.poll(1.0) == []
    assert a.state == RUNNING and b.state == QUEUED
    assert sched.telemetry.jobs_evicted == 0


# ---------------------------------------------------------------------------
# gang leases: planning + all-or-nothing acquisition
# ---------------------------------------------------------------------------
def test_gang_plan_confines_members_and_minimizes_span():
    pool = make_pool(n_local=256, n_switch=0, pods=4)
    # domain 1 is fully busy: the closest eligible window is (2, 3)
    busy = [d.uid for d in pool.devices if d.domain == 1]
    pool.lease(busy, "blocker")
    gang = plan_gang(pool, 2, dp=16, tp=2)
    assert gang.domains == (2, 3)
    assert gang.dcn_hops == 1
    dom = {d.uid: d.domain for d in pool.devices}
    for member, want in zip(gang.members, gang.domains):
        assert {dom[u] for u in member.uids} == {want}
    assert gang.axis_links["pod"] == LinkClass.DCN


def test_gang_acquire_is_all_or_nothing():
    pool = make_pool(n_local=128, n_switch=0, pods=2)
    manager = LeaseManager(pool)
    gang = plan_gang(pool, 2, dp=8, tp=4)
    # induce a partial-claim failure: one device of the SECOND member is
    # grabbed between planning and acquisition
    intruder_uid = gang.members[1].uids[0]
    pool.lease([intruder_uid], "intruder")
    before = dict(pool.leases)
    with pytest.raises(CompositionError, match="rolled back"):
        manager.acquire_gang("gang-job", gang)
    assert pool.leases == before         # first member fully rolled back
    assert manager.conflicts == 1
    assert manager.active() == []
    # with the intruder gone, the same plan acquires atomically
    pool.release([intruder_uid])
    lease = manager.acquire_gang("gang-job", gang)
    assert set(lease.uids) == set(gang.uids)
    manager.check_exclusive()


def test_gang_needs_enough_domains():
    pool = make_pool(n_local=64, n_switch=0, pods=2)
    with pytest.raises(CompositionError, match="domains"):
        plan_gang(pool, 4, dp=8, tp=1)   # only 2 domains exist
    with pytest.raises(CompositionError):
        plan_gang(pool, 1, dp=8, tp=1)   # a gang is >= 2 pods


def test_gang_admission_prices_pod_axis_on_dcn():
    pool = make_pool(n_local=128, n_switch=128, pods=2)
    sched = Scheduler(pool)
    job = _gang("g", n_chips=64)
    assert sched.submit(job, 0.0)
    assert job.plan.shape[0] == 2            # (pod, dp, tp)
    assert job.plan.wire_bytes.get("pod", 0.0) > 0
    assert sched.poll(0.0) == [job]
    assert job.system.axis_names == ("pod", "data", "model")
    assert job.system.fabric.axis_links["pod"] == LinkClass.DCN
    assert job.gang_domains == (0, 1)
    # indivisible chip budgets are rejected at submit, not at compose
    bad = _gang("bad", n_chips=65, n_pods=2)
    assert not sched.submit(bad, 0.0)
    assert "divide" in bad.why_rejected
    # ... as is a gang spanning more pods than the pool has domains
    wide = _gang("wide", n_chips=64, n_pods=4)
    assert not sched.submit(wide, 0.0)
    assert "domains" in wide.why_rejected


def test_gang_member_failure_preempts_whole_gang():
    pool = make_pool(n_local=128, n_switch=128, pods=2)
    sched = Scheduler(pool)
    job = _gang("g", n_chips=64, steps=50)
    sched.submit(job, 0.0)
    sched.poll(0.0)
    assert job.state == RUNNING
    changed = sched.on_failure([job.system.device_uids[0]], now=5.0)
    assert changed == [job]
    assert job.state == QUEUED           # no cross-pod shrink: all or nothing
    assert not pool.leases
    assert sched.telemetry.jobs_preempted == 1


# ---------------------------------------------------------------------------
# simulator: gang traffic on the DCN + per-policy determinism
# ---------------------------------------------------------------------------
def test_gang_trace_attributes_dcn_traffic():
    tpl = JobTemplate("qwen2-0.5b", "train_4k", 64, 10, n_pods=2,
                      tenant="gang")
    cfg = TraceConfig(n_jobs=0, seed=1, failures=(),
                      arrivals=((0.0, tpl), (1.0, tpl)))
    rep = ClusterSimulator(cfg).run()
    assert rep["jobs"]["completed"] == 2
    assert rep["gangs"]["started"] == 2
    assert rep["gangs"]["max_span"] >= 1
    assert rep["link_traffic_gb"]["dcn"] > 0
    json.dumps(rep)


@pytest.mark.parametrize("policy", POLICIES)
def test_simulator_replay_is_deterministic_per_policy(policy):
    a = policy_report(policy)
    b = policy_report(policy)
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)
    assert a["policy"] == policy


def test_policies_actually_diverge_on_the_skewed_trace():
    reports = {p: json.dumps(policy_report(p), sort_keys=True)
               for p in POLICIES}
    assert len(set(reports.values())) == len(POLICIES)


def test_policy_sweep_survives_failure_waves():
    """Evictions, gang preemptions, and failure recomposition compose:
    nothing strands and leases stay exclusive under every policy."""
    import dataclasses
    cfg = dataclasses.replace(SKEW_CFG, failures=((30.0, 16),),
                              repair_after_s=60.0)
    for policy in POLICIES:
        rep = ClusterSimulator(
            dataclasses.replace(cfg, policy=policy)).run()
        jobs = rep["jobs"]
        assert jobs["completed"] + jobs["rejected"] == jobs["submitted"], \
            policy
        assert jobs["stranded"] == 0, policy
        assert rep["lease_conflicts"] == 0, policy


# ---------------------------------------------------------------------------
# anti-thrash: the per-job eviction budget pins repeat victims runnable
# ---------------------------------------------------------------------------
def test_eviction_budget_pins_victim_after_max_evictions():
    """A low-priority job repeatedly evicted by arriving high-priority
    work must eventually finish: at ``max_evictions`` it becomes a
    pinned-runnable non-candidate (counted in
    ``telemetry.jobs_evictions_suppressed``) instead of thrashing
    forever through checkpoint/restore cycles."""
    pool = make_pool(n_local=32, n_switch=0, pods=1)
    sched = Scheduler(pool, policy="priority_preempt")
    lo = Job(name="lo", arch="qwen2-0.5b", shape_name="train_4k",
             n_chips=32, steps=500, priority=0, max_evictions=2)
    sched.submit(lo, 0.0)
    sched.poll(0.0)
    now = 1.0
    for i in range(2):                       # two evictions consume budget
        hi = Job(name=f"hi{i}", arch="qwen2-0.5b", shape_name="train_4k",
                 n_chips=32, steps=5, priority=5)
        sched.submit(hi, now)
        assert [j.name for j in sched.poll(now)] == [f"hi{i}"]
        assert lo.state == QUEUED and lo.evictions == i + 1
        sched.on_complete(hi, now + 10.0)
        assert [j.name for j in sched.poll(now + 10.0)] == ["lo"]
        now += 20.0
    assert sched.telemetry.jobs_evicted == 2
    assert sched.telemetry.jobs_evictions_suppressed == 0
    # budget exhausted: the next arrival cannot displace it
    hi = Job(name="hi-final", arch="qwen2-0.5b", shape_name="train_4k",
             n_chips=32, steps=5, priority=5)
    sched.submit(hi, now)
    assert sched.poll(now) == []
    assert lo.state == RUNNING               # pinned runnable
    assert hi.state == QUEUED
    assert sched.telemetry.jobs_evicted == 2
    assert sched.telemetry.jobs_evictions_suppressed >= 1
    sched.manager.check_exclusive()
    # ... and the suppression count lands in the telemetry report
    rep = sched.telemetry.report()
    assert rep["jobs"]["evictions_suppressed"] == \
        sched.telemetry.jobs_evictions_suppressed


def test_failure_preemption_does_not_consume_eviction_budget():
    """Only *policy* evictions spend the anti-thrash budget — a device
    failure preempting the job is not scheduler-inflicted thrash."""
    pool = make_pool(n_local=32, n_switch=0, pods=1)
    sched = Scheduler(pool, policy="priority_preempt")
    job = Job(name="j", arch="qwen2-0.5b", shape_name="train_4k",
              n_chips=32, steps=100, priority=0)
    sched.submit(job, 0.0)
    sched.poll(0.0)
    sched.on_failure(list(job.system.device_uids), now=1.0)
    assert job.state == QUEUED
    assert job.evictions == 0                # budget untouched


def test_job_template_forwards_max_evictions():
    tmpl = JobTemplate("qwen2-0.5b", "train_4k", 16, 10, max_evictions=1)
    cfg = TraceConfig(n_jobs=2, arrival_rate_hz=0.5, seed=1,
                      templates=(tmpl,), failures=())
    sim = ClusterSimulator(cfg)
    sim.run()
    jobs = list(sim.jobs.values())
    assert jobs and all(j.max_evictions == 1 for j in jobs)
