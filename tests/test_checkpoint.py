"""Checkpoint atomicity, GC, restore + elastic resume."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.base import PolicyConfig, ShapeConfig
from repro.core import compose
from repro.core.topology import make_pool, LinkClass
from repro.data import make_batch
from repro.optim import AdamWConfig
from repro.train import checkpoint, elastic, trainer


def _tiny_state(rng):
    cfg = reduced(get_config("qwen2-0.5b"))
    policy = PolicyConfig(compute_dtype="float32", remat="none",
                          attn_impl="full", zero_stage=0)
    return cfg, policy, trainer.init_state(rng, cfg, policy,
                                           AdamWConfig(lr=1e-3))


def test_save_restore_roundtrip(tmp_path, rng):
    cfg, policy, state = _tiny_state(rng)
    d = str(tmp_path / "ck")
    checkpoint.save(d, 7, state)
    restored, step = checkpoint.restore(d, state)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_gc_keeps_latest_k(tmp_path, rng):
    cfg, policy, state = _tiny_state(rng)
    d = str(tmp_path / "ck")
    for s in range(6):
        checkpoint.save(d, s, state, keep=3)
    assert checkpoint.all_steps(d) == [3, 4, 5]
    assert checkpoint.latest_step(d) == 5


def test_partial_write_is_invisible(tmp_path, rng):
    """A crashed writer (tmp dir, no DONE) must not surface as a step."""
    cfg, policy, state = _tiny_state(rng)
    d = str(tmp_path / "ck")
    checkpoint.save(d, 1, state)
    # simulate a crash: step dir without DONE marker
    os.makedirs(os.path.join(d, "step_0000000002"))
    assert checkpoint.all_steps(d) == [1]
    restored, step = checkpoint.restore(d, state)
    assert step == 1


def test_restore_shape_mismatch_raises(tmp_path, rng):
    cfg, policy, state = _tiny_state(rng)
    d = str(tmp_path / "ck")
    checkpoint.save(d, 1, state)
    bad = jax.tree.map(lambda x: jnp.zeros((3,) + x.shape, x.dtype), state)
    with pytest.raises(ValueError):
        checkpoint.restore(d, bad)


def test_training_resume_bit_exact(tmp_path, rng):
    """save at t, continue to t+2 == restore at t, replay to t+2."""
    cfg, policy, state = _tiny_state(rng)
    step_fn = jax.jit(trainer.make_train_step(cfg, policy,
                                              AdamWConfig(lr=1e-3)))
    shape = ShapeConfig("t", 32, 2, "train")
    d = str(tmp_path / "ck")
    for i in range(2):
        state, _ = step_fn(state, make_batch(cfg, shape, step=i))
    checkpoint.save(d, 2, state)
    cont = state
    for i in range(2, 4):
        cont, _ = step_fn(cont, make_batch(cfg, shape, step=i))
    replay, step = checkpoint.restore(d, state)
    for i in range(step, 4):
        replay, _ = step_fn(replay, make_batch(cfg, shape, step=i))
    for a, b in zip(jax.tree.leaves(cont.params),
                    jax.tree.leaves(replay.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6)


def test_elastic_failure_recompose_restore(tmp_path, rng):
    """Kill devices -> recompose (shrink) -> restore latest checkpoint."""
    pool = make_pool(n_local=256, n_switch=0, pods=1)
    sys_ = compose.compose(pool, "prod", ("data", "model"), (16, 16),
                           {"data": LinkClass.LOCAL,
                            "model": LinkClass.LOCAL})
    run = elastic.ElasticRun(sys_, str(tmp_path / "ck"))
    cfg, policy, state = _tiny_state(rng)
    checkpoint.save(run.ckpt_dir, 5, state)
    new_sys = elastic.handle_failure(run, pool,
                                     failed_uids=list(range(20)), step=5)
    assert new_sys.n_devices <= len(pool.healthy())
    assert new_sys.shape["data"] < 16          # had to shrink
    restored, step = checkpoint.restore(run.ckpt_dir, state)
    assert step == 5
    kinds = [e.kind for e in run.events]
    assert kinds == ["failure", "recompose"]


def test_straggler_policy():
    p = elastic.StragglerPolicy(deadline_factor=2.0, max_duplicates=1)
    assert not p.should_duplicate(elapsed=1.0, median=1.0, already=0)
    assert p.should_duplicate(elapsed=2.5, median=1.0, already=0)
    assert not p.should_duplicate(elapsed=2.5, median=1.0, already=1)
    assert p.expected_tail_time(1.0, p999=10.0) == 3.0
