"""Property tests: conservation invariants under live recomposition.

The Recomposer re-shapes *running* jobs on ticks — attach widens,
detach halves, migrate swaps the storage tranche — so every lease
bookkeeping path (device pool, tranche lessees, step accounting) is
exercised mid-flight.  ``_check_invariants`` states the contract:

  * device-lease conservation — after the trace drains, every device
    lease belongs to a still-running (stranded) job; completed jobs
    hold nothing;
  * tranche-lessee conservation — each tranche's lessees are exactly
    the live jobs attached to it, and a job holds at most one tranche;
  * conservation — every submitted job ends in exactly one terminal
    bucket; no negative progress; no phantom completions;
  * determinism — the same config replays to a bit-identical report;
  * legacy opt-out — ``recompose=None`` produces no ``recompose``
    report section and no attach/detach/migrate events.

A seeded sweep below always runs; the ``hypothesis`` fuzz on top is
skipped when the package isn't installed (the container doesn't ship
it), so CI environments with hypothesis get the dense search for free.
"""
import dataclasses
import json

import pytest

from repro.cluster.recomposer import RecomposeConfig
from repro.cluster.simulator import (ClusterSimulator, JobTemplate,
                                     TraceConfig)

_ELASTIC_TEMPLATES = (
    JobTemplate("qwen2-0.5b", "train_4k", 16, 20, weight=3, elastic=True),
    JobTemplate("qwen2-0.5b", "train_4k", 32, 12, weight=2, elastic=True),
    JobTemplate("llama3.2-3b", "train_4k", 64, 8, weight=2, elastic=True),
    JobTemplate("mamba2-780m", "train_4k", 32, 10, weight=1),
)


def _cfg(seed: int, *, interval_s: float = 10.0, cooldown_s: float = 20.0,
         n_jobs: int = 10, failures=((60.0, 24),)) -> TraceConfig:
    return TraceConfig(
        n_jobs=n_jobs, arrival_rate_hz=0.3, seed=seed,
        n_local=64, n_switch=64, pods=2,
        templates=_ELASTIC_TEMPLATES,
        failures=failures, repair_after_s=90.0,
        recompose=RecomposeConfig(interval_s=interval_s,
                                  cooldown_s=cooldown_s))


def _check_invariants(cfg: TraceConfig) -> None:
    sim = ClusterSimulator(cfg)
    rep = sim.run()
    jobs = rep["jobs"]
    sched = sim.scheduler

    # conservation: one terminal bucket per job, no double-counting
    assert jobs["completed"] + jobs["rejected"] + jobs["failed"] \
        + jobs["stranded"] == jobs["submitted"]
    done_names = [j.name for j in sched.done]
    assert len(done_names) == len(set(done_names)) == jobs["completed"]

    # device-lease conservation: every lease after the trace drains is
    # held by a still-running job (stranded capacity), never a finished
    # or queued one
    live = {j.name for j in sched.running}
    for uid, holder in sched.pool.leases.items():
        assert holder in live, (
            f"device {uid} leased by {holder!r} which is not running")
    for j in sched.running:
        if j.system is not None:
            held = [u for u in j.system.device_uids
                    if sched.pool.leases.get(u) == j.name]
            assert len(held) == j.system.n_devices

    # tranche-lessee conservation: lessees are exactly the live jobs
    # attached to the tranche, and nobody holds two tranches (a migrate
    # leases the target before releasing the source, but never exits
    # the tick holding both)
    for name in sched.storage.tranches:
        for holder in sched.storage.lessees(name):
            assert holder in live
            assert sched.storage.tranches_of(holder) == [name]
    for j in sched.running:
        if j.system is not None and j.system.tranche is not None:
            assert sched.storage.tranches_of(j.name) == [j.system.tranche]

    # no negative progress, no phantom completions from stale events
    for j in sched.done:
        assert j.end_t >= j.start_t >= 0.0
        assert j.steps_done >= j.steps - 1e-9

    # determinism: an identical replay is bit-identical
    rep2 = ClusterSimulator(cfg).run()
    assert json.dumps(rep, sort_keys=True, default=str) \
        == json.dumps(rep2, sort_keys=True, default=str)


# --------------------------------------------- always-on seeded sweep ----

@pytest.mark.parametrize("seed", [0, 3, 7, 11])
def test_invariants_hold_under_live_recomposition(seed):
    _check_invariants(_cfg(seed))


def test_invariants_hold_with_aggressive_ticks():
    # tick faster than the cooldown and with two failure waves so the
    # attach/detach/migrate passes interleave with fault recomposition
    _check_invariants(_cfg(
        5, interval_s=5.0, cooldown_s=5.0,
        failures=((40.0, 32), (100.0, 16))))


def test_invariants_hold_with_permanent_capacity_loss():
    # a never-repaired failure leaves the pool short: attach must not
    # resurrect width that no longer exists
    _check_invariants(_cfg(2, failures=((50.0, None, 48),)))


def test_recompose_none_is_bit_identical_legacy():
    base_cfg = dataclasses.replace(_cfg(7), recompose=None)
    sim = ClusterSimulator(base_cfg)
    rep = sim.run()
    # no report section, no plane events, no counters
    assert "recompose" not in rep
    assert all(ev.kind not in ("attach", "detach", "migrate")
               for ev in sim.telemetry.events)
    assert sim.telemetry.attaches == sim.telemetry.detaches \
        == sim.telemetry.migrations == 0
    # and a replay is still bit-identical
    rep2 = ClusterSimulator(base_cfg).run()
    assert json.dumps(rep, sort_keys=True, default=str) \
        == json.dumps(rep2, sort_keys=True, default=str)


def test_recompose_section_present_and_consistent_when_enabled():
    sim = ClusterSimulator(_cfg(7))
    rep = sim.run()
    rc = rep["recompose"]
    assert set(rc) == {"attaches", "detaches", "migrations",
                       "devices_recomposed"}
    assert rc["attaches"] == sum(
        1 for ev in sim.telemetry.events if ev.kind == "attach")
    assert rc["detaches"] == sum(
        1 for ev in sim.telemetry.events if ev.kind == "detach")
    assert rc["migrations"] == sum(
        1 for ev in sim.telemetry.events if ev.kind == "migrate")


# ------------------------------------------------------ hypothesis fuzz --

def test_invariants_hold_for_random_recompose_schedules():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**16),
           interval=st.floats(min_value=2.0, max_value=60.0),
           cooldown=st.floats(min_value=0.0, max_value=90.0),
           fail_t=st.integers(min_value=10, max_value=150),
           fail_n=st.integers(min_value=1, max_value=64))
    def prop(seed, interval, cooldown, fail_t, fail_n):
        _check_invariants(_cfg(
            seed, interval_s=interval, cooldown_s=cooldown,
            n_jobs=8, failures=((float(fail_t), fail_n),)))

    prop()
