"""Fabric-topology tests (always-on; seeded sweeps, no hypothesis).

Covers the canonical Table IV link lookup — including the cross-domain
mixed-fabric pricing bugfix — the pool-builder remainder bugfix, the
three registered wiring models, and the path-resolution invariants
documented in ``repro.core.fabrics``.  The hypothesis renderings of the
same invariants live in tests/test_topology.py (skipped where hypothesis
is absent); these sweeps always run.
"""
import dataclasses
import random

import pytest

from repro.core.fabrics import (OversubscribedSpine, PCIeCascade,
                                TOPOLOGIES, make_topology)
from repro.core.topology import (DEFAULT_LINKS, Device, DevicePool,
                                 LinkClass, LinkSpec, Topology,
                                 link_class_between, make_pool)
from repro.data.storage import make_storage_pool

NBYTES = 1e9
ALL_TOPOS = [
    Topology(),
    PCIeCascade(tiers=2, bw_taper=0.7),
    OversubscribedSpine(oversubscription=4.0, leaf_ports=8),
]


def _dev(uid, fabric, domain):
    return Device(uid, fabric, domain)


def _mixed_pool(topology=None):
    return make_pool(n_local=6, n_switch=6, pods=3, topology=topology)


# ---------------------------------------------------------------------------
# canonical lookup — Table IV regression matrix
# ---------------------------------------------------------------------------
def test_link_class_lookup_table_iv_matrix():
    L, S = LinkClass.LOCAL, LinkClass.SWITCH
    cases = [
        ((L, 0), (L, 0), LinkClass.LOCAL),       # intra-drawer NVLink
        ((S, 0), (S, 0), LinkClass.SWITCH),      # intra-drawer falcon
        ((L, 0), (S, 0), LinkClass.HOST),        # same drawer, mixed (F-L)
        ((S, 0), (S, 1), LinkClass.SWITCH),      # composed switch spans
        ((L, 0), (L, 1), LinkClass.DCN),         # local ICI does not
        ((L, 0), (S, 1), LinkClass.DCN),         # BUGFIX: host+pod in series
        ((S, 0), (L, 1), LinkClass.DCN),         # ... symmetric
    ]
    for (fa, da), (fb, db), want in cases:
        got = link_class_between(_dev(0, fa, da), _dev(1, fb, db))
        assert got is want, f"{fa}/{da} <-> {fb}/{db}: {got} != {want}"


def test_cross_domain_mixed_fabric_priced_at_slower_path():
    """Regression for the link-pricing bug: a cross-domain mixed-fabric
    pair crosses the host complex AND the pod boundary; it must be
    priced at the slower of the two, never the faster (the old lookup
    returned HOST, ~2.2x the DCN's bandwidth)."""
    a, b = _dev(0, LinkClass.LOCAL, 0), _dev(1, LinkClass.SWITCH, 1)
    assert DEFAULT_LINKS[LinkClass.DCN].bandwidth \
        < DEFAULT_LINKS[LinkClass.HOST].bandwidth
    assert link_class_between(a, b) is LinkClass.DCN
    # slower-of semantics, not hardcoded DCN: with a link table whose
    # HOST staging path is the bottleneck, the pair prices at HOST
    slow_host = dict(DEFAULT_LINKS)
    slow_host[LinkClass.HOST] = dataclasses.replace(
        DEFAULT_LINKS[LinkClass.HOST], bandwidth=1e9)
    assert link_class_between(a, b, slow_host) is LinkClass.HOST


def test_no_cross_domain_path_beats_dcn():
    """Acceptance invariant: across every registered topology, no
    cross-domain pair that leaves the composed switch fabric is priced
    above DCN bandwidth."""
    dcn_bw = DEFAULT_LINKS[LinkClass.DCN].bandwidth
    for topo in ALL_TOPOS:
        pool = _mixed_pool(topo)
        for a in pool.devices:
            for b in pool.devices:
                if a.domain == b.domain or a is b:
                    continue
                link, _ = pool.path(a, b)
                assert link.cls is LinkClass.SWITCH \
                    or link.bandwidth <= dcn_bw, (topo.name, a, b, link)


# ---------------------------------------------------------------------------
# path-resolution invariants (seeded sweeps)
# ---------------------------------------------------------------------------
def test_path_symmetry_all_topologies():
    rng = random.Random(7)
    for topo in ALL_TOPOS:
        pool = _mixed_pool(topo)
        for _ in range(200):
            a, b = rng.sample(pool.devices, 2)
            assert pool.path(a, b) == pool.path(b, a)


def test_path_class_always_matches_canonical_lookup():
    rng = random.Random(11)
    for topo in ALL_TOPOS:
        pool = _mixed_pool(topo)
        for _ in range(200):
            a, b = rng.sample(pool.devices, 2)
            link, hops = pool.path(a, b)
            assert link.cls is link_class_between(a, b, pool.links)
            assert hops >= 1
            assert link.bandwidth <= pool.links[link.cls].bandwidth


def test_same_domain_never_slower_than_cross_domain():
    """Moving one endpoint of a pair to another drawer can only add
    cost, on every topology and fabric combination."""
    for topo in ALL_TOPOS:
        pool = DevicePool([], topology=topo)
        for fa in (LinkClass.LOCAL, LinkClass.SWITCH):
            for fb in (LinkClass.LOCAL, LinkClass.SWITCH):
                near_l, near_h = pool.path(_dev(0, fa, 0), _dev(1, fb, 0))
                for span in (1, 2, 3):
                    far_l, far_h = pool.path(_dev(0, fa, 0),
                                             _dev(1, fb, span))
                    assert far_l.time(NBYTES, far_h) \
                        >= near_l.time(NBYTES, near_h), \
                        (topo.name, fa, fb, span)


def test_single_switch_is_bit_identical_to_legacy_lookup():
    """The pluggable default must price exactly what the pre-topology
    pool priced: 1 hop of the canonical class at full bandwidth —
    both through an explicit Topology() and through topology=None."""
    rng = random.Random(3)
    legacy = _mixed_pool(None)
    explicit = _mixed_pool(Topology())
    assert [d.uid for d in legacy.devices] \
        == [d.uid for d in explicit.devices]
    for _ in range(300):
        a, b = rng.sample(legacy.devices, 2)
        want = legacy.links[link_class_between(a, b, legacy.links)]
        for pool in (legacy, explicit):
            link, hops = pool.path(a, b)
            assert link == want and hops == 1
            assert link.time(NBYTES, hops) == NBYTES / want.bandwidth \
                + want.latency


# ---------------------------------------------------------------------------
# wiring models
# ---------------------------------------------------------------------------
def test_pcie_cascade_hops_and_taper():
    t = PCIeCascade(tiers=2, bw_taper=0.7)
    assert t.hops(LinkClass.SWITCH, 0) == 1          # same drawer: flat
    assert t.hops(LinkClass.SWITCH, 3) == 7          # 1 + 2 * 3 stages
    assert t.hops(LinkClass.LOCAL, 3) == 1           # ICI never cascades
    assert t.hops(LinkClass.DCN, 3) == 1
    assert t.bw_scale(LinkClass.SWITCH, 0) == 1.0
    assert t.bw_scale(LinkClass.SWITCH, 3) == pytest.approx(0.7 ** 6)


def test_oversubscribed_spine_uplink_sharing():
    t = OversubscribedSpine(oversubscription=4.0, leaf_ports=8)
    assert t.hops(LinkClass.SWITCH, 1) == 3          # leaf-spine-leaf
    assert t.hops(LinkClass.SWITCH, 0) == 1
    # uplink = 8/4 = 2 chip-links; 1-2 flows ride free, 8 get a quarter
    assert t.bw_scale(LinkClass.SWITCH, 1, flows=1) == 1.0
    assert t.bw_scale(LinkClass.SWITCH, 1, flows=2) == 1.0
    assert t.bw_scale(LinkClass.SWITCH, 1, flows=8) == pytest.approx(0.25)
    assert t.bw_scale(LinkClass.LOCAL, 1, flows=8) == 1.0


def test_topology_registry_and_params():
    assert set(TOPOLOGIES) \
        == {"single_switch", "pcie_cascade", "oversubscribed_spine"}
    assert make_topology("single_switch").name == "single_switch"
    assert make_topology("pcie_cascade", tiers=3).tiers == 3
    with pytest.raises(KeyError):
        make_topology("torus")


def test_effective_never_raises_bandwidth():
    base = DEFAULT_LINKS[LinkClass.SWITCH]
    assert Topology.effective(base, 1.0) is base
    assert Topology.effective(base, 2.0) is base     # scale caps at 1
    half = Topology.effective(base, 0.5)
    assert half.bandwidth == pytest.approx(base.bandwidth * 0.5)
    assert half.latency == base.latency and half.cls is base.cls


# ---------------------------------------------------------------------------
# pool-builder bugfixes
# ---------------------------------------------------------------------------
def test_make_pool_keeps_every_device_on_remainder():
    """Regression: non-divisible counts used to silently drop up to
    ``pods - 1`` devices per fabric (10 local over 4 pods built 8)."""
    pool = make_pool(n_local=10, n_switch=7, pods=4)
    assert len(pool.devices) == 17
    by = {}
    for d in pool.devices:
        by.setdefault((d.fabric, d.domain), 0)
        by[(d.fabric, d.domain)] += 1
    assert [by.get((LinkClass.LOCAL, p), 0) for p in range(4)] \
        == [3, 3, 2, 2]
    assert [by.get((LinkClass.SWITCH, p), 0) for p in range(4)] \
        == [2, 2, 2, 1]
    assert len({d.uid for d in pool.devices}) == 17


def test_make_pool_divisible_layout_unchanged():
    pool = make_pool(n_local=8, n_switch=8, pods=2)
    assert [d.domain for d in pool.devices] == [0] * 4 + [1] * 4 \
        + [0] * 4 + [1] * 4
    assert [d.uid for d in pool.devices] == list(range(16))


def test_make_storage_pool_builds_exact_counts():
    """make_storage_pool round-robins domains and was never subject to
    the remainder drop — pin that it builds exactly what is asked."""
    sp = make_storage_pool(5, 3, domains=2)
    tranches = list(sp.tranches.values())
    assert len(tranches) == 8
    assert sum(t.attach is LinkClass.LOCAL for t in tranches) == 5
    assert sum(t.attach is LinkClass.SWITCH for t in tranches) == 3
    assert {t.domain for t in tranches} == {0, 1}


# ---------------------------------------------------------------------------
# bench acceptance (smoke)
# ---------------------------------------------------------------------------
def test_fabric_bench_acceptance():
    from benchmarks import fabric_bench
    rep = fabric_bench.report()
    acc = rep["acceptance"]
    assert acc["single_switch_matches_flat_model"]
    assert acc["oversub_knee_ge_10pct"]
    assert acc["oversub_knee_drop_32"] >= 0.10
    assert acc["cross_domain_never_beats_dcn"]
    # flat fabric scales ideally on this compute-bound job; the spine's
    # knee appears exactly at 32 devices (8 concurrent flows per drawer)
    assert rep["knee_devices"]["single_switch"] is None
    assert rep["knee_devices"]["oversubscribed_spine"] == 32
    row = fabric_bench.trajectory_row(rep)
    assert set(row) == set(fabric_bench.TRAJECTORY)
