"""Property tests (hypothesis) for the composable-system invariants."""
import math

import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import compose, topology
from repro.core.fabrics import OversubscribedSpine, PCIeCascade
from repro.core.topology import (Device, DevicePool, LinkClass, Topology,
                                 link_class_between, make_pool)


# ---------------------------------------------------------------------------
# pool invariants
# ---------------------------------------------------------------------------
@given(n_fail=st.integers(0, 64), n_attach=st.integers(0, 32))
@settings(max_examples=50, deadline=None)
def test_pool_mutation_conserves_devices(n_fail, n_attach):
    pool = make_pool(n_local=128, n_switch=128, pods=2)
    total = len(pool.devices)
    uids = [d.uid for d in pool.devices[:n_fail]]
    pool.mark_failed(uids)
    assert len(pool.devices) == total                       # fail != detach
    assert len(pool.healthy()) == total - len(set(uids))
    new = pool.attach(n_attach, LinkClass.SWITCH, domain=1)
    assert len(pool.healthy()) == total - len(set(uids)) + n_attach
    pool.repair(uids)
    assert len(pool.healthy()) == total + n_attach
    pool.detach(new)
    assert len(pool.devices) == total


@given(st.integers(2, 6), st.integers(2, 6))
@settings(max_examples=30, deadline=None)
def test_compose_claims_exactly_mesh_size(a, b):
    pool = make_pool(n_local=64, n_switch=64, pods=2)
    sys_ = compose.compose(pool, "t", ("data", "model"), (a, b),
                           {"data": LinkClass.LOCAL,
                            "model": LinkClass.LOCAL})
    assert len(sys_.device_uids) == a * b
    assert len(set(sys_.device_uids)) == a * b              # no double-claim


def test_compose_rejects_oversubscription():
    pool = make_pool(n_local=8, n_switch=0, pods=1)
    with pytest.raises(compose.CompositionError):
        compose.compose(pool, "big", ("data",), (64,),
                        {"data": LinkClass.LOCAL})


@given(st.integers(1, 200))
@settings(max_examples=30, deadline=None)
def test_shrink_to_pool_always_fits(n_fail):
    pool = make_pool(n_local=256, n_switch=0, pods=1)
    sys_ = compose.compose(pool, "t", ("data", "model"), (16, 16),
                           {"data": LinkClass.LOCAL,
                            "model": LinkClass.LOCAL})
    pool.mark_failed([d.uid for d in pool.devices[:n_fail]])
    if len(pool.healthy()) < 1 * 16:
        return
    new = compose.shrink_to_pool(pool, sys_, "data")
    assert new.n_devices <= len(pool.healthy())
    assert new.axis_names == sys_.axis_names


# ---------------------------------------------------------------------------
# fabric pricing invariants
# ---------------------------------------------------------------------------
def test_link_table_matches_paper_ratios():
    links = topology.DEFAULT_LINKS
    ll = links[LinkClass.LOCAL].bandwidth
    ff = links[LinkClass.SWITCH].bandwidth
    fl = links[LinkClass.HOST].bandwidth
    assert math.isclose(ff / ll, 24.47 / 72.37, rel_tol=1e-6)
    assert math.isclose(fl / ll, 19.64 / 72.37, rel_tol=1e-6)
    # ordering from the paper's Table IV
    assert ll > ff > fl > 0


@given(nbytes=st.floats(1e3, 1e12), n=st.integers(2, 512))
@settings(max_examples=50, deadline=None)
def test_collective_cost_ordering(nbytes, n):
    """allreduce costs ~2x allgather; all presets price local <= switch."""
    local = compose.preset("localGPUs")
    falcon = compose.preset("falconGPUs")
    t_local = local.collective_time("data", nbytes, "all-reduce")
    t_falcon = falcon.collective_time("data", nbytes, "all-reduce")
    assert t_falcon > t_local
    ag = local.collective_time("data", nbytes, "all-gather")
    ar = local.collective_time("data", nbytes, "all-reduce")
    assert ar > ag


def test_presets_cover_paper_table3():
    for label in compose.PRESET_LABELS:
        sys_ = compose.preset(label)
        assert sys_.n_devices == 256
        assert set(sys_.axis_names) == {"data", "model"}
    hybrid = compose.preset("hybridGPUs")
    assert hybrid.fabric.axis_links["model"] == LinkClass.LOCAL
    assert hybrid.fabric.axis_links["data"] == LinkClass.SWITCH
    fn = compose.preset("falconNVMe")
    assert fn.fabric.storage.attach == LinkClass.SWITCH


def test_multi_pod_production_system():
    sys_ = compose.production_system(multi_pod=True)
    assert sys_.shape == {"pod": 2, "data": 16, "model": 16}
    assert sys_.fabric.axis_links["pod"] == LinkClass.DCN
    assert sys_.axis_bandwidth("pod") < sys_.axis_bandwidth("data")


# ---------------------------------------------------------------------------
# fabric topologies — path-resolution properties (repro.core.fabrics)
# ---------------------------------------------------------------------------
_fabrics = st.sampled_from([LinkClass.LOCAL, LinkClass.SWITCH])
_topos = st.one_of(
    st.just(Topology()),
    st.builds(PCIeCascade, tiers=st.integers(1, 3),
              bw_taper=st.floats(0.5, 1.0)),
    st.builds(OversubscribedSpine,
              oversubscription=st.floats(1.0, 16.0),
              leaf_ports=st.integers(1, 16)))
_devices = st.builds(Device, uid=st.integers(0, 1000), fabric=_fabrics,
                     domain=st.integers(0, 7))


@given(topo=_topos, a=_devices, b=_devices)
@settings(max_examples=200, deadline=None)
def test_path_is_symmetric(topo, a, b):
    pool = DevicePool([], topology=topo)
    assert pool.path(a, b) == pool.path(b, a)


@given(topo=_topos, a=_devices, b=_devices, span=st.integers(1, 7))
@settings(max_examples=200, deadline=None)
def test_cross_domain_never_faster_than_intra_domain(topo, a, b, span):
    """Splitting a pair across drawers can only add cost — on every
    registered topology, for every fabric combination."""
    pool = DevicePool([], topology=topo)
    near_a = Device(a.uid, a.fabric, 0)
    near_b = Device(b.uid + 1, b.fabric, 0)
    far_b = Device(b.uid + 1, b.fabric, span)
    nl, nh = pool.path(near_a, near_b)
    fl, fh = pool.path(near_a, far_b)
    nbytes = 1e9
    assert fl.time(nbytes, fh) >= nl.time(nbytes, nh)


@given(topo=_topos, a=_devices, b=_devices)
@settings(max_examples=200, deadline=None)
def test_path_class_is_canonical_and_never_fast_cross_domain(topo, a, b):
    """Topologies only add hops / derate bandwidth: the link *class* is
    always the Table IV lookup, and cross-domain traffic off the
    composed switch fabric is never priced above the DCN."""
    pool = DevicePool([], topology=topo)
    link, hops = pool.path(a, b)
    assert link.cls is link_class_between(a, b, pool.links)
    assert hops >= 1
    assert link.bandwidth <= pool.links[link.cls].bandwidth
    if a.domain != b.domain and link.cls is not LinkClass.SWITCH:
        assert link.bandwidth <= pool.links[LinkClass.DCN].bandwidth


@given(n_local=st.integers(0, 40), n_switch=st.integers(0, 40),
       pods=st.integers(1, 7))
@settings(max_examples=100, deadline=None)
def test_single_switch_topology_is_identity(n_local, n_switch, pods):
    """A pool wired with the explicit default topology prices every pair
    exactly like the legacy flat pool (class lookup, 1 hop, full speed),
    and make_pool builds every requested device on any pod count."""
    legacy = make_pool(n_local, n_switch, pods)
    flat = make_pool(n_local, n_switch, pods, topology=Topology())
    assert len(legacy.devices) == n_local + n_switch
    assert [(d.uid, d.fabric, d.domain) for d in legacy.devices] \
        == [(d.uid, d.fabric, d.domain) for d in flat.devices]
    for a in legacy.devices[:12]:
        for b in legacy.devices[-12:]:
            want = legacy.links[link_class_between(a, b, legacy.links)]
            assert legacy.path(a, b) == (want, 1)
            assert flat.path(a, b) == (want, 1)


# ---------------------------------------------------------------------------
# recompose = the elastic path
# ---------------------------------------------------------------------------
def test_recompose_after_failure_excludes_dead_devices():
    pool = make_pool(n_local=300, n_switch=0, pods=1)
    sys_ = compose.compose(pool, "t", ("data", "model"), (16, 16),
                           {"data": LinkClass.LOCAL,
                            "model": LinkClass.LOCAL})
    dead = list(sys_.device_uids[:10])
    pool.mark_failed(dead)
    new = compose.recompose(pool, sys_)
    assert not set(dead) & set(new.device_uids)
    assert new.n_devices == 256
