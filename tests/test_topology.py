"""Property tests (hypothesis) for the composable-system invariants."""
import math

import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import compose, topology
from repro.core.topology import DevicePool, LinkClass, make_pool


# ---------------------------------------------------------------------------
# pool invariants
# ---------------------------------------------------------------------------
@given(n_fail=st.integers(0, 64), n_attach=st.integers(0, 32))
@settings(max_examples=50, deadline=None)
def test_pool_mutation_conserves_devices(n_fail, n_attach):
    pool = make_pool(n_local=128, n_switch=128, pods=2)
    total = len(pool.devices)
    uids = [d.uid for d in pool.devices[:n_fail]]
    pool.mark_failed(uids)
    assert len(pool.devices) == total                       # fail != detach
    assert len(pool.healthy()) == total - len(set(uids))
    new = pool.attach(n_attach, LinkClass.SWITCH, domain=1)
    assert len(pool.healthy()) == total - len(set(uids)) + n_attach
    pool.repair(uids)
    assert len(pool.healthy()) == total + n_attach
    pool.detach(new)
    assert len(pool.devices) == total


@given(st.integers(2, 6), st.integers(2, 6))
@settings(max_examples=30, deadline=None)
def test_compose_claims_exactly_mesh_size(a, b):
    pool = make_pool(n_local=64, n_switch=64, pods=2)
    sys_ = compose.compose(pool, "t", ("data", "model"), (a, b),
                           {"data": LinkClass.LOCAL,
                            "model": LinkClass.LOCAL})
    assert len(sys_.device_uids) == a * b
    assert len(set(sys_.device_uids)) == a * b              # no double-claim


def test_compose_rejects_oversubscription():
    pool = make_pool(n_local=8, n_switch=0, pods=1)
    with pytest.raises(compose.CompositionError):
        compose.compose(pool, "big", ("data",), (64,),
                        {"data": LinkClass.LOCAL})


@given(st.integers(1, 200))
@settings(max_examples=30, deadline=None)
def test_shrink_to_pool_always_fits(n_fail):
    pool = make_pool(n_local=256, n_switch=0, pods=1)
    sys_ = compose.compose(pool, "t", ("data", "model"), (16, 16),
                           {"data": LinkClass.LOCAL,
                            "model": LinkClass.LOCAL})
    pool.mark_failed([d.uid for d in pool.devices[:n_fail]])
    if len(pool.healthy()) < 1 * 16:
        return
    new = compose.shrink_to_pool(pool, sys_, "data")
    assert new.n_devices <= len(pool.healthy())
    assert new.axis_names == sys_.axis_names


# ---------------------------------------------------------------------------
# fabric pricing invariants
# ---------------------------------------------------------------------------
def test_link_table_matches_paper_ratios():
    links = topology.DEFAULT_LINKS
    ll = links[LinkClass.LOCAL].bandwidth
    ff = links[LinkClass.SWITCH].bandwidth
    fl = links[LinkClass.HOST].bandwidth
    assert math.isclose(ff / ll, 24.47 / 72.37, rel_tol=1e-6)
    assert math.isclose(fl / ll, 19.64 / 72.37, rel_tol=1e-6)
    # ordering from the paper's Table IV
    assert ll > ff > fl > 0


@given(nbytes=st.floats(1e3, 1e12), n=st.integers(2, 512))
@settings(max_examples=50, deadline=None)
def test_collective_cost_ordering(nbytes, n):
    """allreduce costs ~2x allgather; all presets price local <= switch."""
    local = compose.preset("localGPUs")
    falcon = compose.preset("falconGPUs")
    t_local = local.collective_time("data", nbytes, "all-reduce")
    t_falcon = falcon.collective_time("data", nbytes, "all-reduce")
    assert t_falcon > t_local
    ag = local.collective_time("data", nbytes, "all-gather")
    ar = local.collective_time("data", nbytes, "all-reduce")
    assert ar > ag


def test_presets_cover_paper_table3():
    for label in compose.PRESET_LABELS:
        sys_ = compose.preset(label)
        assert sys_.n_devices == 256
        assert set(sys_.axis_names) == {"data", "model"}
    hybrid = compose.preset("hybridGPUs")
    assert hybrid.fabric.axis_links["model"] == LinkClass.LOCAL
    assert hybrid.fabric.axis_links["data"] == LinkClass.SWITCH
    fn = compose.preset("falconNVMe")
    assert fn.fabric.storage.attach == LinkClass.SWITCH


def test_multi_pod_production_system():
    sys_ = compose.production_system(multi_pod=True)
    assert sys_.shape == {"pod": 2, "data": 16, "model": 16}
    assert sys_.fabric.axis_links["pod"] == LinkClass.DCN
    assert sys_.axis_bandwidth("pod") < sys_.axis_bandwidth("data")


# ---------------------------------------------------------------------------
# recompose = the elastic path
# ---------------------------------------------------------------------------
def test_recompose_after_failure_excludes_dead_devices():
    pool = make_pool(n_local=300, n_switch=0, pods=1)
    sys_ = compose.compose(pool, "t", ("data", "model"), (16, 16),
                           {"data": LinkClass.LOCAL,
                            "model": LinkClass.LOCAL})
    dead = list(sys_.device_uids[:10])
    pool.mark_failed(dead)
    new = compose.recompose(pool, sys_)
    assert not set(dead) & set(new.device_uids)
    assert new.n_devices == 256
