"""Lease bookkeeping: exclusive claims, double-claim regression, placement.

These are the control-plane invariants of ``repro.cluster``: no uid is
ever held by two compositions, compose/recompose move leases atomically,
and domain-aware placement derives each axis's link class from where the
free devices actually are.  (No hypothesis dependency — this file must
collect everywhere.)
"""
import pytest

from repro.cluster.lease import LeaseManager, plan_placement
from repro.core import compose
from repro.core.compose import CompositionError
from repro.core.topology import LeaseError, LinkClass, make_pool


# ---------------------------------------------------------------------------
# DevicePool lease bookkeeping
# ---------------------------------------------------------------------------
def test_lease_and_release_accounting():
    pool = make_pool(n_local=16, n_switch=0, pods=1)
    assert len(pool.available()) == 16
    pool.lease([0, 1, 2], "a")
    assert len(pool.available()) == 13
    assert sorted(pool.leased_by("a")) == [0, 1, 2]
    pool.release([1])
    assert len(pool.available()) == 14
    assert pool.release_holder("a") and not pool.leases
    pool.release([0, 1])                     # idempotent


def test_lease_conflict_is_atomic():
    pool = make_pool(n_local=8, n_switch=0, pods=1)
    pool.lease([0, 1], "a")
    with pytest.raises(LeaseError):
        pool.lease([2, 1], "b")              # 1 is taken
    # nothing from the failed claim may stick
    assert pool.leases == {0: "a", 1: "a"}


def test_duplicate_uids_in_claim_rejected():
    """One chip can't back two mesh slots: duplicates raise, both via the
    raw pool API and via compose(uids=...)."""
    pool = make_pool(n_local=8, n_switch=0, pods=1)
    with pytest.raises(LeaseError):
        pool.lease([5, 5], "a")
    assert not pool.leases
    with pytest.raises(CompositionError):
        compose.compose(pool, "a", ("data",), (2,),
                        {"data": LinkClass.LOCAL}, uids=[5, 5])
    assert not pool.leases


def test_failed_devices_stay_leased_but_detach_clears():
    pool = make_pool(n_local=8, n_switch=0, pods=1)
    pool.lease([0, 1], "a")
    pool.mark_failed([0])
    assert pool.leases.get(0) == "a"         # failure != release
    assert all(d.uid != 0 for d in pool.available())
    pool.detach([0])
    assert 0 not in pool.leases


# ---------------------------------------------------------------------------
# compose() exclusivity — the silent double-claim regression
# ---------------------------------------------------------------------------
def test_overlapping_compositions_raise():
    """Seed bug: two compose() calls could silently claim the same chips."""
    pool = make_pool(n_local=256, n_switch=0, pods=1)
    links = {"data": LinkClass.LOCAL, "model": LinkClass.LOCAL}
    a = compose.compose(pool, "a", ("data", "model"), (16, 16), links)
    with pytest.raises(CompositionError):
        compose.compose(pool, "b", ("data", "model"), (16, 16), links)
    compose.release(pool, a)
    b = compose.compose(pool, "b", ("data", "model"), (16, 16), links)
    assert set(b.device_uids) == set(a.device_uids) or len(b.device_uids) == 256


def test_concurrent_compositions_are_disjoint():
    pool = make_pool(n_local=64, n_switch=64, pods=2)
    links = {"data": LinkClass.LOCAL}
    systems = [compose.compose(pool, f"t{i}", ("data",), (16,), links)
               for i in range(8)]            # exactly fills the pool
    seen = set()
    for s in systems:
        assert not seen & set(s.device_uids)
        seen |= set(s.device_uids)
    assert len(seen) == 128


def test_compose_explicit_uids_rejects_unavailable():
    pool = make_pool(n_local=8, n_switch=0, pods=1)
    links = {"data": LinkClass.LOCAL}
    compose.compose(pool, "a", ("data",), (2,), links, uids=[4, 5])
    with pytest.raises(CompositionError):
        compose.compose(pool, "b", ("data",), (2,), links, uids=[5, 6])
    pool.mark_failed([7])
    with pytest.raises(CompositionError):
        compose.compose(pool, "b", ("data",), (2,), links, uids=[6, 7])
    b = compose.compose(pool, "b", ("data",), (2,), links, uids=[6, 0])
    assert b.device_uids == (6, 0)


def test_recompose_moves_lease_and_restores_on_failure():
    pool = make_pool(n_local=40, n_switch=0, pods=1)
    links = {"data": LinkClass.LOCAL}
    sys_ = compose.compose(pool, "t", ("data",), (32,), links)
    pool.mark_failed(list(sys_.device_uids[:8]))
    new = compose.recompose(pool, sys_)      # 8 spares cover the loss
    assert len(pool.leases) == 32
    assert all(pool.leases[u] == "t" for u in new.device_uids)
    # now make recompose impossible (no spares remain, so losing more of
    # the claim leaves < 32 healthy): the claim must be restored untouched
    before = dict(pool.leases)
    pool.mark_failed(list(new.device_uids[:4]))
    with pytest.raises(CompositionError):
        compose.recompose(pool, new)
    assert pool.leases == before


def test_shrink_does_not_steal_other_tenants_devices():
    pool = make_pool(n_local=64, n_switch=0, pods=1)
    links = {"data": LinkClass.LOCAL}
    a = compose.compose(pool, "a", ("data",), (32,), links)
    b = compose.compose(pool, "b", ("data",), (16,), links)
    pool.mark_failed(list(a.device_uids[:20]))
    shrunk = compose.shrink_to_pool(pool, a, "data")
    # capacity for a: 16 unleased + 12 surviving own = 28 -> data halves to 16
    assert shrunk.axis_sizes == (16,)
    assert not set(shrunk.device_uids) & set(b.device_uids)
    assert all(pool.leases[u] == "b" for u in b.device_uids)


# ---------------------------------------------------------------------------
# domain-aware placement
# ---------------------------------------------------------------------------
def test_placement_single_local_clique_rides_local():
    pool = make_pool(n_local=32, n_switch=0, pods=1)
    plan = plan_placement(pool, dp=4, tp=8)
    assert plan.axis_links == {"data": LinkClass.LOCAL,
                               "model": LinkClass.LOCAL}
    assert plan.n_domains == 1


def test_placement_spanning_domains_degrades_data_axis():
    pool = make_pool(n_local=16, n_switch=0, pods=2)   # two 8-wide cliques
    plan = plan_placement(pool, dp=4, tp=4)
    assert plan.axis_links["model"] == LinkClass.LOCAL  # tp fits one clique
    # local ICI does not span pods: the dp axis rides the DCN
    assert plan.axis_links["data"] == LinkClass.DCN


def test_placement_tp_straddling_cliques_degrades_model_axis():
    pool = make_pool(n_local=16, n_switch=0, pods=2)   # cliques of 8
    plan = plan_placement(pool, dp=1, tp=16)           # tp can't fit either
    assert plan.axis_links["model"] == LinkClass.DCN


def test_placement_mixed_fabrics_ride_host_and_switch_spans_domains():
    pool = make_pool(n_local=4, n_switch=4, pods=2)    # whole pool needed
    plan = plan_placement(pool, dp=4, tp=2)            # must mix fabrics
    assert plan.axis_links["model"] == LinkClass.SWITCH
    # the data span crosses fabrics AND domains: host complex + pod
    # boundary in series prices at the slower (DCN) — the cross-domain
    # pricing bugfix (it used to ride HOST, ~2.2x too fast)
    assert plan.axis_links["data"] == LinkClass.DCN
    pool2 = make_pool(n_local=4, n_switch=4, pods=1)   # one domain
    plan2 = plan_placement(pool2, dp=4, tp=2)          # mixed, same drawer
    assert plan2.axis_links["data"] == LinkClass.HOST  # crossing fabrics
    pool3 = make_pool(n_local=0, n_switch=16, pods=2)
    plan3 = plan_placement(pool3, dp=4, tp=4)          # all switch-attached
    assert plan3.axis_links["data"] == LinkClass.SWITCH


def test_placement_insufficient_pool_raises():
    pool = make_pool(n_local=8, n_switch=0, pods=1)
    pool.lease([0, 1, 2, 3], "other")
    with pytest.raises(CompositionError):
        plan_placement(pool, dp=8, tp=1)


def test_lease_manager_adopt_and_invariant():
    pool = make_pool(n_local=32, n_switch=0, pods=1)
    mgr = LeaseManager(pool)
    links = {"data": LinkClass.LOCAL}
    s1 = compose.compose(pool, "j1", ("data",), (8,), links)
    s2 = compose.compose(pool, "j2", ("data",), (8,), links)
    mgr.adopt(s1, now=1.0)
    mgr.adopt(s2, now=2.0)
    mgr.check_exclusive()
    assert mgr.n_leased() == 16
    assert 0.49 < mgr.utilization() < 0.51
    freed = mgr.release("j1")
    assert sorted(freed) == sorted(s1.device_uids)
    assert mgr.n_leased() == 8
    with pytest.raises(LeaseError):
        mgr.adopt(s1)                        # no longer claimed in the pool


def test_attach_prefers_idle_same_domain_over_far_drawer():
    """Live attach must re-place hop-aware: when a shrunk job's own
    drawer has idle chips, re-widening may never straddle domains by
    grabbing far-drawer devices (the naive uid-order regression —
    ``attach_job`` goes through ``plan_placement`` on the pool view)."""
    from repro.cluster.scheduler import Job, Scheduler

    pool = make_pool(n_local=32, n_switch=0, pods=2)   # two 16-chip drawers
    sched = Scheduler(pool)
    # pin half of drawer 0 so the 16-wide job can only start in drawer 1
    other = Job(name="other", arch="qwen2-0.5b", shape_name="train_4k",
                n_chips=8, steps=100)
    job = Job(name="j", arch="qwen2-0.5b", shape_name="train_4k",
              n_chips=16, steps=100, elastic=True)
    assert sched.submit(other, 0.0) and sched.submit(job, 0.0)
    assert len(sched.poll(0.0)) == 2
    domain = {d.uid: d.domain for d in pool.devices}
    homes = {domain[u] for u in job.system.device_uids}
    assert len(homes) == 1
    home = homes.pop()
    assert sched.detach_job(job, 10.0) == 8
    # free chips now: 8 beside the job in its drawer, 8 in the far one —
    # a uid-ordered pick would take the far (lower-uid) drawer and span
    assert sched.attach_job(job, 20.0)
    assert job.system.n_devices == 16
    assert {domain[u] for u in job.system.device_uids} == {home}
    assert sched.telemetry.attaches == sched.telemetry.detaches == 1
    sched.manager.check_exclusive()


def test_lease_manager_tracks_multiple_leases_per_holder():
    """adopt() + acquire() for the same holder must both stay visible
    (a job's compute claim plus its storage tranche)."""
    pool = make_pool(n_local=16, n_switch=0, pods=1)
    mgr = LeaseManager(pool)
    sys_ = compose.compose(pool, "j", ("data",), (4,),
                           {"data": LinkClass.LOCAL})
    mgr.adopt(sys_, now=1.0)
    mgr.acquire("j", [10, 11], now=2.0)      # e.g. an NVMe tranche
    held = [l for l in mgr.active() if l.holder == "j"]
    assert len(held) == 2
    mgr.check_exclusive()
    assert sorted(mgr.release("j")) == sorted(list(sys_.device_uids)
                                              + [10, 11])
    assert not mgr.active() and not pool.leases
