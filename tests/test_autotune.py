"""Autotuner + tuned-config registry + measured-cost calibration."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.costmodel import CalibratedCost
from repro.core import recommend
from repro.kernels import autotune, ops, registry

KEY = jax.random.PRNGKey(3)
K1, K2, K3 = jax.random.split(KEY, 3)


@pytest.fixture(autouse=True)
def _isolate_registry():
    """Tests control the active registry explicitly; no disk/env leakage."""
    registry.set_registry(None)
    yield
    registry.reset_registry()


# ---------------------------------------------------------------------------
# candidate enumeration
# ---------------------------------------------------------------------------
def test_candidate_enumeration_is_deterministic():
    for case in autotune.SMOKE_CASES + autotune.DEFAULT_CASES:
        a = autotune.candidates_for(case)
        b = autotune.candidates_for(case)
        assert a == b
        assert len(a) >= 1
        # deduped after clamping
        assert len({tuple(sorted(c.items())) for c in a}) == len(a)


def test_candidates_respect_divisibility():
    case = autotune.attn_case("flash_attention", S=96, D=32, G=2)
    for cand in autotune.candidates_for(case):
        assert 96 % cand["block_q"] == 0
        assert 96 % cand["block_k"] == 0


def test_ssd_rglru_candidates():
    assert autotune.candidates_for(autotune.ssd_case(S=128)) == [
        {"chunk": 32}, {"chunk": 64}, {"chunk": 128}]
    assert autotune.candidates_for(autotune.rglru_case(S=64)) == [
        {"block_seq": 16}, {"block_seq": 32}, {"block_seq": 64}]


# ---------------------------------------------------------------------------
# registry round-trip + dispatch resolution
# ---------------------------------------------------------------------------
def test_registry_round_trip(tmp_path):
    reg = registry.Registry()
    key = registry.make_key("flash_attention", dtype="float32",
                            variant="causal", s=128, t=128, d=32, g=2)
    reg.put(key, registry.TunedEntry(
        blocks={"block_q": 64, "block_k": 32}, us=10.0, default_us=20.0,
        n_candidates=9, backend="cpu"))
    path = reg.save(str(tmp_path / "tuned.json"))
    loaded = registry.Registry.load(path)
    assert len(loaded) == 1
    entry = loaded.get(key)
    assert entry.blocks == {"block_q": 64, "block_k": 32}
    assert entry.speedup == pytest.approx(2.0)
    # the resolver sees the same blocks after the round trip
    registry.set_registry(loaded)
    bq, bk = registry.attention_blocks(128, 128, 32, 2, jnp.float32,
                                       True, 0)
    assert (bq, bk) == (64, 32)


def test_seq_dims_bucket_to_pow2():
    k1 = registry.make_key("flash_attention", dtype="float32",
                           variant="causal", s=384, t=384, d=64, g=4)
    k2 = registry.make_key("flash_attention", dtype="float32",
                           variant="causal", s=512, t=512, d=64, g=4)
    assert k1 == k2
    # head/feature dims stay exact
    k3 = registry.make_key("flash_attention", dtype="float32",
                           variant="causal", s=512, t=512, d=128, g=4)
    assert k3 != k2


def test_registry_miss_falls_back_to_defaults():
    registry.set_registry(registry.Registry())      # active but empty
    # at dims the defaults divide, the miss path returns them verbatim
    assert registry.attention_blocks(256, 256, 32, 2, jnp.float32,
                                     True, 0) == ops.DEFAULT_ATTN_BLOCKS
    assert registry.ssd_chunk(256, 4, 16, 1, 32, jnp.float32) == \
        ops.DEFAULT_SSD_CHUNK
    assert registry.rglru_block(128, 64, jnp.float32) == \
        ops.DEFAULT_RGLRU_BLOCK
    # at smaller dims they are fitted (same clamp the kernels apply)
    assert registry.attention_blocks(128, 128, 32, 2, jnp.float32,
                                     True, 0) == (128, 128)


def test_tuned_blocks_fit_non_pow2_sequences():
    """Pow2 bucketing may hand back blocks tuned at a neighbouring
    length; the resolver must fit them to the actual dim so the kernels'
    divisibility asserts hold (review regression: S=192 hitting a
    128-block cell tuned at the 256 bucket)."""
    reg = registry.Registry()
    reg.put(registry.make_key("flash_attention", dtype="float32",
                              variant="causal", s=192, t=192, d=32, g=2),
            registry.TunedEntry(blocks={"block_q": 128, "block_k": 128}))
    registry.set_registry(reg)
    bq, bk = registry.attention_blocks(192, 192, 32, 2, jnp.float32,
                                       True, 0)
    assert 192 % bq == 0 and 192 % bk == 0
    q = jax.random.normal(K1, (1, 192, 4, 32))
    k = jax.random.normal(K2, (1, 192, 2, 32))
    v = jax.random.normal(K3, (1, 192, 2, 32))
    out = ops.attention(q, k, v, impl="pallas")      # must not assert
    assert out.shape == q.shape


def test_xla_flash_fits_blocks_to_runtime_length():
    """Serve prefill traces with the actual prompt length, which need
    not be divisible by the build-time tuned tile (review regression:
    96-token prompt vs kv_block=64)."""
    from repro.models.attention import flash_attention_xla
    q = jax.random.normal(K1, (1, 96, 4, 32))
    k = jax.random.normal(K2, (1, 96, 2, 32))
    v = jax.random.normal(K3, (1, 96, 2, 32))
    out = flash_attention_xla(q, k, v, causal=True,
                              q_block=64, kv_block=64)   # 96 % 64 != 0
    ref = flash_attention_xla(q, k, v, causal=True,
                              q_block=96, kv_block=96)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_default_blocks_divide_non_pow2_sequences():
    """The sweep's baseline config must be legal for every case (review
    regression: S=384 clamped default 256 crashed the fallback)."""
    for case in (autotune.attn_case("flash_attention", S=384, D=32, G=2),
                 autotune.attn_case("flash_attention_xla", S=96, D=32,
                                    G=2),
                 autotune.ssd_case(S=96), autotune.rglru_case(S=96)):
        d = autotune.default_blocks(case)
        for v in d.values():
            assert case.dim("s") % v == 0, (case.kernel, d)


def test_calibrated_utilization_stays_bounded():
    """A measured cell far below the analytic compute bound must not
    push busy fractions past 1 (review regression: AUU went negative)."""
    from repro.cluster import TraceConfig, run_trace
    cal = CalibratedCost()
    plan = recommend.recommend("qwen2-0.5b", "train_4k", n_chips=16,
                               top=1)[0]
    cal.measure_cell("qwen2-0.5b", "train_4k", plan.label,
                     plan.step_s / 100.0)
    rep = run_trace(TraceConfig(n_jobs=8, seed=2, calibration=cal))
    assert 0.0 <= rep["auu"] <= 1.0
    assert rep["accelerator_utilization"] <= 1.0


def test_fit_block():
    assert registry.fit_block(128, 192) == 96
    assert registry.fit_block(256, 256) == 256
    assert registry.fit_block(64, 64) == 64
    assert registry.fit_block(512, 100) == 100
    assert registry.fit_block(8, 97) == 1            # prime dim


def test_dispatch_keys_registry_by_impl():
    """pallas_vjp / xla lookups must hit their own kernels' cells, not
    the forward pallas cell (review regression)."""
    q = jax.random.normal(K1, (1, 64, 2, 32))
    k = jax.random.normal(K2, (1, 64, 2, 32))
    v = jax.random.normal(K3, (1, 64, 2, 32))
    reg = registry.Registry()
    # poison the forward cell with blocks that would fail if consumed
    # by the xla path's separate tuned entry
    reg.put(registry.make_key("flash_attention", dtype="float32",
                              variant="causal", s=64, t=64, d=32, g=1),
            registry.TunedEntry(blocks={"block_q": 16, "block_k": 16}))
    reg.put(registry.make_key("flash_attention_xla", dtype="float32",
                              variant="causal", s=64, t=64, d=32, g=1),
            registry.TunedEntry(blocks={"block_q": 32, "block_k": 32}))
    registry.set_registry(reg)
    a = ops.attention(q, k, v, impl="xla")
    b = ops.attention(q, k, v, impl="xla", block_q=32, block_k=32)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_no_registry_resolves_defaults():
    registry.set_registry(None)
    assert registry.attention_blocks(256, 256, 64, 4, jnp.bfloat16,
                                     True, 0) == (256, 256)


def test_malformed_registry_file_is_ignored(tmp_path, monkeypatch):
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    monkeypatch.setenv(registry.ENV_VAR, str(bad))
    registry.reset_registry()
    assert registry.get_registry() is None


# ---------------------------------------------------------------------------
# tuned configs preserve semantics
# ---------------------------------------------------------------------------
def test_tuned_rglru_bit_identical_to_default():
    """block_seq only re-tiles VMEM; the sequential recurrence order is
    unchanged, so tuned output must be bit-identical to the default."""
    log_a = -jax.nn.softplus(jax.random.normal(K1, (2, 128, 32)))
    gated = jax.random.normal(K2, (2, 128, 32))
    reg = registry.Registry()
    reg.put(registry.make_key("rglru", dtype="float32", s=128, w=32),
            registry.TunedEntry(blocks={"block_seq": 16}))
    default = ops.rglru(log_a, gated, impl="pallas")      # no registry
    registry.set_registry(reg)
    tuned = ops.rglru(log_a, gated, impl="pallas")
    np.testing.assert_array_equal(np.asarray(tuned), np.asarray(default))


def test_tuned_attention_matches_default():
    q = jax.random.normal(K1, (1, 128, 4, 32))
    k = jax.random.normal(K2, (1, 128, 2, 32))
    v = jax.random.normal(K3, (1, 128, 2, 32))
    default = ops.attention(q, k, v, impl="pallas")
    reg = registry.Registry()
    reg.put(registry.make_key("flash_attention", dtype="float32",
                              variant="causal", s=128, t=128, d=32, g=2),
            registry.TunedEntry(blocks={"block_q": 32, "block_k": 64}))
    registry.set_registry(reg)
    tuned = ops.attention(q, k, v, impl="pallas")
    np.testing.assert_allclose(tuned, default, atol=2e-5, rtol=2e-5)


def test_tuned_ssd_matches_default():
    x = jax.random.normal(K1, (1, 128, 4, 16))
    dt = jax.nn.softplus(jax.random.normal(K2, (1, 128, 4)))
    A = -jnp.exp(jax.random.normal(K3, (4,)))
    Bm = jax.random.normal(K1, (1, 128, 1, 32)) * 0.5
    Cm = jax.random.normal(K2, (1, 128, 1, 32)) * 0.5
    yd, hd = ops.ssd(x, dt, A, Bm, Cm, impl="pallas")
    reg = registry.Registry()
    reg.put(registry.make_key("ssd", dtype="float32",
                              s=128, h=4, p=16, g=1, n=32),
            registry.TunedEntry(blocks={"chunk": 32}))
    registry.set_registry(reg)
    yt, ht = ops.ssd(x, dt, A, Bm, Cm, impl="pallas")
    np.testing.assert_allclose(yt, yd, atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(ht, hd, atol=2e-4, rtol=2e-4)


def test_explicit_blocks_override_registry():
    q = jax.random.normal(K1, (1, 64, 2, 32))
    k = jax.random.normal(K2, (1, 64, 2, 32))
    v = jax.random.normal(K3, (1, 64, 2, 32))
    reg = registry.Registry()
    reg.put(registry.make_key("flash_attention", dtype="float32",
                              variant="causal", s=64, t=64, d=32, g=1),
            registry.TunedEntry(blocks={"block_q": 32, "block_k": 32}))
    registry.set_registry(reg)
    out = ops.attention(q, k, v, impl="pallas", block_q=64, block_k=64)
    ref = ops.attention(q, k, v, impl="xla", block_q=64, block_k=64)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# the sweep itself (one small real cell)
# ---------------------------------------------------------------------------
def test_tune_case_rglru_end_to_end(tmp_path):
    case = autotune.rglru_case(S=64, W=16)
    res = autotune.tune_case(case, iters=1)
    assert res.entry.us > 0 and res.entry.default_us > 0
    assert res.entry.n_candidates == len(autotune.candidates_for(case))
    assert res.entry.blocks in autotune.candidates_for(case)
    # sweep persists + reloads
    reg, results = autotune.sweep([case], iters=1,
                                  path=str(tmp_path / "t.json"))
    assert len(reg) == 1 and len(results) == 1
    loaded = registry.Registry.load(str(tmp_path / "t.json"))
    assert loaded.get(case.key).blocks == reg.get(case.key).blocks
    js = json.load(open(str(tmp_path / "t.json")))
    assert js["version"] == 1 and case.key in js["configs"]


def test_decode_case_candidates_are_page_multiples():
    """Paged-decode kv superblocks gather whole pages: every candidate
    block_k is pages-per-block x page_size, block_q pinned to the single
    query row, ppb never exceeding the cache's page count."""
    case = autotune.decode_case(B=4, T=128, D=32, G=2, page_size=16)
    assert autotune.candidates_for(case) == [
        {"block_q": 1, "block_k": 16 * ppb} for ppb in (1, 2, 4, 8)]
    # a smaller cache clips the ppb ladder
    small = autotune.decode_case(B=2, T=32, D=32, G=2, page_size=16)
    assert autotune.candidates_for(small) == [
        {"block_q": 1, "block_k": 16}, {"block_q": 1, "block_k": 32}]


def test_tune_case_decode_end_to_end(tmp_path):
    """The decode cell sweeps like any other kernel: tune, persist,
    reload — and the serving-side resolver sees the winner."""
    case = autotune.decode_case(B=2, T=64, D=32, G=2, page_size=16)
    res = autotune.tune_case(case, iters=1)
    assert res.entry.us > 0 and res.entry.default_us > 0
    assert res.entry.blocks in autotune.candidates_for(case)
    reg, _ = autotune.sweep([case], iters=1,
                            path=str(tmp_path / "t.json"))
    loaded = registry.Registry.load(str(tmp_path / "t.json"))
    won = loaded.get(case.key).blocks
    assert won == reg.get(case.key).blocks
    registry.set_registry(loaded)
    assert registry.decode_attention_blocks(2, 64, 32, 2, jnp.float32) \
        == (won["block_q"], won["block_k"])


# ---------------------------------------------------------------------------
# measured-cost calibration changes decisions
# ---------------------------------------------------------------------------
def test_calibration_changes_recommend_ranking():
    """A measured step time for a non-winning mesh must be able to
    re-rank recommend() — the ISSUE's acceptance criterion."""
    arch, shape, chips = "qwen2-0.5b", "train_4k", 64
    plain = recommend.recommend(arch, shape, n_chips=chips, top=2)
    winner, runner_up = plain[0], plain[1]
    cal = CalibratedCost()
    # measurement says the analytic runner-up actually runs 10x faster
    cal.measure_cell(arch, shape, runner_up.label,
                     winner.step_s / 10.0)
    cald = recommend.recommend(arch, shape, n_chips=chips, top=2,
                               calibration=cal)
    assert cald[0].label == runner_up.label
    assert cald[0].label != plain[0].label
    assert cald[0].terms.get("measured") == pytest.approx(
        winner.step_s / 10.0)


def test_kernel_speedup_scales_compute_term():
    from repro.configs import get_config, SHAPES
    cfg = get_config("mamba2-780m")            # pure-SSM pattern
    shape = SHAPES["train_4k"]
    cal = CalibratedCost(kernel_speedup={"ssd": 2.0})
    scale = cal.compute_scale(cfg, shape)
    # FLOPs-weighted: only the SSD core accelerates; projections, FFN,
    # and logits keep weight 1.0, so 0.5 < scale < 1.0
    assert 0.5 < scale < 1.0
    # monotone in the measured speedup
    faster = CalibratedCost(kernel_speedup={"ssd": 4.0})
    assert faster.compute_scale(cfg, shape) < scale
    # untuned kernels change nothing
    other = CalibratedCost(kernel_speedup={"flash_attention": 4.0})
    assert other.compute_scale(cfg, shape) == pytest.approx(1.0)
    plain = recommend.recommend("mamba2-780m", "train_4k", n_chips=64,
                                top=1)[0]
    cald = recommend.recommend("mamba2-780m", "train_4k", n_chips=64,
                               top=1, calibration=cal)[0]
    assert cald.terms["compute"] == pytest.approx(
        plain.terms["compute"] * scale)


def test_set_calibration_reaches_existing_scheduler():
    """Process-wide set_calibration() must be honored by schedulers
    built before the call (review regression: construction-time
    snapshot)."""
    from repro.cluster.scheduler import Scheduler
    from repro.core.topology import make_pool
    sched = Scheduler(make_pool(n_local=8, n_switch=0, pods=1))
    assert sched.calibration is None
    cal = CalibratedCost(kernel_speedup={"ssd": 2.0})
    recommend.set_calibration(cal)
    try:
        assert sched.calibration is cal
    finally:
        recommend.set_calibration(None)
    assert sched.calibration is None


def test_calibration_flows_into_scheduler_admission_pricing():
    """The scheduler's plan (and therefore simulator pricing) uses the
    measured step time, changing which mesh a job is admitted on."""
    from repro.cluster.scheduler import Job, Scheduler
    from repro.core.topology import make_pool

    def best_plan(calibration):
        pool = make_pool(n_local=64, n_switch=0, pods=1)
        sched = Scheduler(pool, calibration=calibration)
        job = Job(name="j", arch="qwen2-0.5b", shape_name="train_4k",
                  n_chips=64)
        assert sched.submit(job, 0.0)
        return job.plan

    plain = best_plan(None)
    cal = CalibratedCost()
    # measure a different factorization as dramatically faster
    alt = [c for c in recommend.recommend(
        "qwen2-0.5b", "train_4k", n_chips=64, top=5)
        if c.label != plain.label][0]
    cal.measure_cell("qwen2-0.5b", "train_4k", alt.label,
                     plain.step_s / 100.0)
    cald = best_plan(cal)
    assert cald.label == alt.label
    assert cald.label != plain.label


def test_from_registry_builds_speedups():
    reg = registry.Registry()
    reg.put(registry.make_key("ssd", dtype="float32",
                              s=128, h=4, p=16, g=1, n=32),
            registry.TunedEntry(blocks={"chunk": 32}, us=50.0,
                                default_us=100.0))
    cal = CalibratedCost.from_registry(reg)
    assert cal.kernel_speedup["ssd"] == pytest.approx(2.0)
    # json round-trip
    cal2 = CalibratedCost.from_json(cal.to_json())
    assert cal2.kernel_speedup == cal.kernel_speedup


def test_interpret_default_is_backend_derived():
    # CPU test environment: the one-place default must say "interpret"
    assert ops.default_interpret() == (jax.default_backend() != "tpu")


# ---------------------------------------------------------------------------
# decode-shape buckets (the serving engine's (B, 1, cache_len) cells)
# ---------------------------------------------------------------------------
def test_decode_bucket_keys_batch_dim():
    # batch buckets pow2 from 1; cache length buckets like seq dims;
    # S is omitted from decode cells (always 1)
    k1 = registry.make_key("decode_attention", dtype="float32",
                           variant="causal", b=3, t=300, d=64, g=4)
    k2 = registry.make_key("decode_attention", dtype="float32",
                           variant="causal", b=4, t=512, d=64, g=4)
    assert k1 == k2                       # 3->4 and 300->512 share a cell
    assert "b=4" in k1 and "t=512" in k1 and "s=" not in k1


def test_decode_attention_blocks_resolve_and_fallback():
    # miss: defaults fitted (block_q pinned to the single query row;
    # block_k fitted to divide the cache length: 150 | 300)
    assert registry.decode_attention_blocks(
        4, 300, 64, 4, jnp.float32) == (1, 150)
    reg = registry.Registry()
    reg.put(registry.make_key("decode_attention", dtype="float32",
                              variant="causal", b=4, t=512, d=64, g=4),
            registry.TunedEntry(blocks={"block_q": 1, "block_k": 128},
                                us=10.0, default_us=20.0))
    registry.set_registry(reg)
    assert registry.decode_attention_blocks(
        3, 300, 64, 4, jnp.float32) == (1, 100)   # 128 fitted to T=300


def test_resolve_attn_blocks_covers_decode_shape():
    from repro.configs import get_config, reduced
    from repro.configs.base import PolicyConfig
    from repro.train.trainer import resolve_attn_blocks
    cfg = reduced(get_config("qwen2-0.5b"))
    pol = PolicyConfig(compute_dtype="float32")
    g = max(1, cfg.n_heads // max(cfg.n_kv_heads, 1))
    reg = registry.Registry()
    reg.put(registry.make_key("decode_attention", dtype="float32",
                              variant="causal", b=4, t=128,
                              d=cfg.head_dim, g=g),
            registry.TunedEntry(blocks={"block_q": 1, "block_k": 64}))
    registry.set_registry(reg)
    assert resolve_attn_blocks(cfg, pol, 128, decode=True,
                               batch=4) == (1, 64)
    # the prefill-shaped lookup is untouched by the decode cell
    # (defaults fitted to the 128-token shape)
    assert resolve_attn_blocks(cfg, pol, 128) == (128, 128)
