"""Fused IO-aware GQA attention — Pallas TPU kernel.

TPU-native adaptation of FlashAttention: the (S, T) score matrix never
leaves VMEM.  Grid = (batch, kv_head, q_block, kv_block) with the kv axis
innermost; the online-softmax running state (m, l, acc) lives in VMEM
scratch and persists across the sequential kv iterations — the TPU idiom
replacing the GPU's per-SM shared-memory tiling.  All G query heads of a
GQA group ride in one block so each K/V tile is loaded from HBM once per
group (the arithmetic-intensity win the GPU formulation gets from warp
reuse).

Masking (causal and/or sliding-window) is positional, from program ids.
Fully-out-of-range KV tiles are skipped with ``pl.when`` (causal skips
~half the grid; sliding-window skips all tiles older than the window).

MXU layout notes:
  * last dim = head_dim (multiple of 8, <=256); second-minor multiples
    of 8; the two matmuls are (G·bq, D)x(bk, D)ᵀ and (G·bq, bk)x(bk, D).
  * fp32 accumulation (`preferred_element_type`); bf16 or f32 inputs.

Validated in interpret mode against ``repro.kernels.ref.attention_ref``
(tests/test_kernels.py sweeps shapes, dtypes, GQA ratios, windows).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  causal: bool, window: int, bq: int, bk: int, nk: int,
                  scale: float, softcap: float):
    i = pl.program_id(2)                 # q block
    j = pl.program_id(3)                 # kv block (innermost, sequential)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # tile-level skip: causal => no kv block strictly after the q block;
    # window  => no kv block entirely older than the sliding window.
    live = jnp.asarray(True)
    if causal:
        live = live & (j * bk <= i * bq + bq - 1)
    if window > 0:
        live = live & ((i * bq) - (j * bk + bk - 1) < window)

    @pl.when(live)
    def _tile():
        q = q_ref[0, 0]                  # (G, bq, D)
        k = k_ref[0, 0]                  # (bk, D)
        v = v_ref[0, 0]
        G, _, D = q.shape

        s = jax.lax.dot_general(
            q.reshape(G * bq, D), k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale      # (G*bq, bk)
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)

        q_row = jax.lax.broadcasted_iota(jnp.int32, (G * bq, bk), 0) % bq
        q_pos = i * bq + q_row
        k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (G * bq, bk), 1)
        diff = q_pos - k_pos
        mask = jnp.zeros_like(s)
        if causal:
            mask = jnp.where(diff < 0, NEG_INF, mask)
        if window > 0:
            mask = jnp.where(diff >= window, NEG_INF, mask)
        s = s + mask

        m_prev = m_ref[...]              # (G*bq,)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(j == nk - 1)
    def _flush():
        G, _, D = q_ref[0, 0].shape
        l = jnp.maximum(l_ref[...], 1e-30)
        out = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)
        o_ref[0, 0] = out.reshape(G, bq, D)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, block_q: int = 256,
                    block_k: int = 256, interpret: bool = True):
    """q (B,S,H,D); k/v (B,T,K,D) -> (B,S,H,D).  H = K·G (GQA)."""
    B, S, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    assert H % K == 0, (H, K)
    G = H // K
    bq = min(block_q, S)
    bk = min(block_k, T)
    assert S % bq == 0 and T % bk == 0, (S, T, bq, bk)
    nq, nk = S // bq, T // bk
    scale = 1.0 / math.sqrt(D)

    # (B, K, G, S, D): the G heads of a GQA group contiguous per kv head
    qg = q.reshape(B, S, K, G, D).transpose(0, 2, 3, 1, 4)
    kt = k.transpose(0, 2, 1, 3)         # (B, K, T, D)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _flash_kernel, causal=causal, window=window, bq=bq, bk=bk, nk=nk,
        scale=scale, softcap=softcap)

    out = pl.pallas_call(
        kernel,
        grid=(B, K, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, bq, D),
                         lambda b, h, i, j: (b, h, 0, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, bq, D),
                               lambda b, h, i, j: (b, h, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, G, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G * bq,), jnp.float32),       # running max
            pltpu.VMEM((G * bq,), jnp.float32),       # running denom
            pltpu.VMEM((G * bq, D), jnp.float32),     # output accumulator
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qg, kt, vt)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, D)
