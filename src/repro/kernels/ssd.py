"""Mamba-2 SSD (state-space duality) — Pallas TPU kernel.

Chunked dual form: within a chunk of length ``c`` the recurrence is
computed as a (c x c) causal attention-like matmul (MXU work); a rank-N
state (H, N, P) carries information between chunks and lives in VMEM
scratch across the sequential chunk axis of the grid.

Grid = (batch, n_chunks); chunk axis innermost/sequential ("arbitrary"
semantics).  Per-chunk VMEM working set for the mamba2-780m config
(c=256, H=48, N=128, P=64, G=1):

    x (c,H,P) 3.1MB + decay/W (c,c,H) 12.6MB x2 + state 1.5MB  ~= 30MB

comfortably inside the ~128MB v5e VMEM; block sizes are all multiples of
(8,128) in the minor dims.  All math fp32 (the recurrence is
precision-sensitive; matches the oracle exactly).

Validated in interpret mode against ``repro.kernels.ref.ssd_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.compat import CompilerParams


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref,
                h_ref, *, chunk: int, nc: int, hpg: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _reset():
        h_ref[...] = jnp.zeros_like(h_ref)

    xc = x_ref[0].astype(jnp.float32)        # (c, H, P)
    dtc = dt_ref[0].astype(jnp.float32)      # (c, H)
    A = a_ref[...].astype(jnp.float32)       # (H,)
    Bc = b_ref[0].astype(jnp.float32)        # (c, G, N)
    Cc = c_ref[0].astype(jnp.float32)        # (c, G, N)
    c, H, P = xc.shape
    G, N = Bc.shape[1], Bc.shape[2]

    a = dtc * A                              # (c, H) log-decay
    acum = jnp.cumsum(a, axis=0)

    # ---- intra-chunk (attention-like dual form) ----
    CB = jax.lax.dot_general(                # (G, c, c)
        Cc.transpose(1, 0, 2), Bc.transpose(1, 0, 2),
        (((2,), (2,)), ((0,), (0,))), preferred_element_type=jnp.float32)
    CBh = jnp.repeat(CB, hpg, axis=0)        # (H, c, c)
    diff = acum[:, None, :] - acum[None, :, :]          # (c, c, H)
    idx_l = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    idx_m = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    causal = (idx_l >= idx_m)[..., None]
    decay = jnp.exp(jnp.clip(diff, -60.0, 0.0))
    decay = jnp.where(causal, decay, 0.0)
    W = CBh.transpose(1, 2, 0) * decay * dtc[None, :, :]   # (c, c, H)
    y_intra = jnp.einsum("lmh,mhp->lhp", W, xc,
                         preferred_element_type=jnp.float32)

    # ---- inter-chunk (incoming state contribution) ----
    h = h_ref[...].astype(jnp.float32)       # (H, N, P)
    Ch = jnp.repeat(Cc, hpg, axis=1).reshape(c, H, N) if G > 1 else \
        jnp.broadcast_to(Cc, (c, H, N))
    y_inter = jnp.exp(acum)[..., None] * jnp.einsum(
        "lhn,hnp->lhp", Ch, h, preferred_element_type=jnp.float32)

    # ---- state update ----
    rest = jnp.exp(jnp.clip(acum[-1:, :] - acum, -60.0, None))   # (c, H)
    Bh = jnp.repeat(Bc, hpg, axis=1).reshape(c, H, N) if G > 1 else \
        jnp.broadcast_to(Bc, (c, H, N))
    contrib = jnp.einsum("mhn,mhp->hnp", Bh * (dtc * rest)[..., None], xc,
                         preferred_element_type=jnp.float32)
    h_new = jnp.exp(acum[-1, :])[:, None, None] * h + contrib
    h_ref[...] = h_new

    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    @pl.when(j == nc - 1)
    def _flush():
        hout_ref[0] = h_new.astype(hout_ref.dtype)


def ssd(x, dt, A, Bm, Cm, *, chunk: int = 256, interpret: bool = True):
    """Chunked SSD scan.

    x (B,S,H,P); dt (B,S,H); A (H,); B/C (B,S,G,N).
    Returns (y (B,S,H,P) fp32, h_final (B,H,N,P) fp32).
    """
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    hpg = H // G
    c = min(chunk, S)
    assert S % c == 0, (S, c)
    nc = S // c

    kernel = functools.partial(_ssd_kernel, chunk=c, nc=nc, hpg=hpg)
    y, h_final = pl.pallas_call(
        kernel,
        grid=(B, nc),
        in_specs=[
            pl.BlockSpec((1, c, H, P), lambda b, j: (b, j, 0, 0)),
            pl.BlockSpec((1, c, H), lambda b, j: (b, j, 0)),
            pl.BlockSpec((H,), lambda b, j: (0,)),
            pl.BlockSpec((1, c, G, N), lambda b, j: (b, j, 0, 0)),
            pl.BlockSpec((1, c, G, N), lambda b, j: (b, j, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, c, H, P), lambda b, j: (b, j, 0, 0)),
            pl.BlockSpec((1, H, N, P), lambda b, j: (b, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((H, N, P), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
    return y, h_final
