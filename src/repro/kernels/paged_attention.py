"""Paged-attention decode — Pallas TPU kernel over the serving page pool.

Decode attention that reads K/V *directly from the paged KV pool*
(``serve/kvcache.PagePool`` layout: ``(n_pages + 1, page_size, K, D)``
per layer) via per-sequence block tables, so the jitted decode step never
materializes the dense ``(B, W, K, D)`` cache view that
``kvcache.gather_dense`` builds for the XLA path.

Layout and grid:

  * the block tables (``(B, P)`` int32 page ids) and per-sequence token
    counts (``(B,)``) ride in as **scalar-prefetch** operands
    (``pltpu.PrefetchScalarGridSpec``) so the BlockSpec index maps can
    steer each grid step's DMA to the right physical page — the standard
    TPU paged-attention trick;
  * grid = ``(batch, kv_head, kv_superblock)`` with the kv axis innermost
    and sequential; one superblock covers ``block_k // page_size``
    (possibly non-contiguous) pages, fetched as that many single-page
    block copies of the pool (one ``in_spec`` per page slot — Pallas
    block shapes must be static, the page *ids* are not);
  * online-softmax running state (m, l, acc) lives in VMEM scratch
    exactly as in ``flash_attention.py``; all G query heads of a GQA
    group ride in one block.

Masking is positional: slot ``t`` of sequence ``b`` is live iff
``t < lengths[b]`` — the pool writes sequences contiguously from
position 0, so this is the kernel-side equivalent of the dense path's
``pos >= 0`` mask (padding rows with ``lengths == 0`` produce zeros).
Superblocks entirely past ``lengths[b]`` are skipped with ``pl.when``
(table pad entries point at the pool's scratch page and are never read
live).

Validated in interpret mode against ``repro.kernels.ref
.paged_attention_ref`` across page-boundary and ragged-length cases
(tests/test_paged_attention.py).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _paged_kernel(tables_ref, lengths_ref, q_ref, *refs, ppb: int, ps: int,
                  nb: int, scale: float, softcap: float):
    k_refs = refs[:ppb]                    # ppb x (1, ps, 1, D) page blocks
    v_refs = refs[ppb:2 * ppb]
    o_ref = refs[2 * ppb]
    m_ref, l_ref, acc_ref = refs[2 * ppb + 1:]

    b = pl.program_id(0)
    j = pl.program_id(2)                   # kv superblock (innermost, seq.)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = lengths_ref[b]
    base = j * (ppb * ps)                  # first token slot of this block

    @pl.when(base < length)
    def _tile():
        q = q_ref[0, 0]                    # (G, D)
        k = jnp.concatenate([r[0, :, 0, :] for r in k_refs], axis=0)
        v = jnp.concatenate([r[0, :, 0, :] for r in v_refs], axis=0)

        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale      # (G, bk)
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        pos = base + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)

        m_prev = m_ref[...]                # (G,)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(j == nb - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def paged_decode_attention(q, k_pages, v_pages, tables, lengths, *,
                           block_k: int = 256, softcap: float = 0.0,
                           interpret: bool = True):
    """q (B,H,D) one decode token/seq; k/v pages (N,ps,K,D); tables (B,P)
    int32 page ids; lengths (B,) valid-token counts -> (B,H,D).

    ``block_k`` is fitted down to a multiple of the page size whose
    page count divides P, so any tuned value is legal.
    """
    B, H, D = q.shape
    ps, K = k_pages.shape[1], k_pages.shape[2]
    P = tables.shape[1]
    assert H % K == 0, (H, K)
    G = H // K
    ppb = max(1, min(int(block_k) // ps, P))   # pages per superblock
    while P % ppb:
        ppb -= 1
    nb = P // ppb
    scale = 1.0 / math.sqrt(D)

    qg = q.reshape(B, K, G, D)
    tables = tables.astype(jnp.int32)
    lengths = lengths.astype(jnp.int32)

    def page_spec(t):
        # page t of superblock j: one (ps, D) tile of kv head h, DMA'd
        # from whichever physical page the table names
        return pl.BlockSpec(
            (1, ps, 1, D),
            lambda b, h, j, tab, lens, t=t: (tab[b, j * ppb + t], 0, h, 0))

    kernel = functools.partial(_paged_kernel, ppb=ppb, ps=ps, nb=nb,
                               scale=scale, softcap=softcap)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,             # tables, lengths
        grid=(B, K, nb),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, j, tab, lens:
                         (b, h, 0, 0)),
            *[page_spec(t) for t in range(ppb)],
            *[page_spec(t) for t in range(ppb)],
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, j, tab, lens:
                               (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),        # running max
            pltpu.VMEM((G,), jnp.float32),        # running denom
            pltpu.VMEM((G, D), jnp.float32),      # output accumulator
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, G, D), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(tables, lengths, qg, *([k_pages] * ppb), *([v_pages] * ppb))
    return out.reshape(B, H, D)
