"""RG-LRU gated linear recurrence — Pallas TPU kernel.

The RG-LRU is HBM-bandwidth-bound: per token it does O(W) FMA work on
O(W) bytes.  The fusion win is doing gates + recurrence + output in ONE
pass over HBM (the XLA path materializes log_a, gated, and the scan
intermediates separately).

Grid = (batch, seq_blocks), sequence axis innermost/sequential; the hidden
state h (W,) persists in VMEM scratch.  Within a block the recurrence
steps with a ``fori_loop`` of W-wide VPU FMAs — the sequential chain is
the algorithm's critical path; the kernel keeps it on-chip.

Validated in interpret mode against ``repro.kernels.ref.rglru_ref``
(associative-scan oracle).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.compat import CompilerParams


def _rglru_kernel(log_a_ref, gated_ref, y_ref, h_ref, *, bs: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _reset():
        h_ref[...] = jnp.zeros_like(h_ref)

    log_a = log_a_ref[0].astype(jnp.float32)   # (bs, W)
    gated = gated_ref[0].astype(jnp.float32)   # (bs, W)
    a = jnp.exp(log_a)

    def step(t, h):
        h = a[t] * h + gated[t]
        y_ref[0, pl.dslice(t, 1), :] = h[None].astype(y_ref.dtype)
        return h

    h_ref[...] = jax.lax.fori_loop(0, bs, step, h_ref[...])


def rglru(log_a, gated, *, block_seq: int = 128, interpret: bool = True):
    """Linear recurrence h_t = exp(log_a_t)·h_{t-1} + gated_t.

    log_a/gated (B, S, W) -> hs (B, S, W) fp32.
    """
    B, S, W = log_a.shape
    bs = min(block_seq, S)
    assert S % bs == 0, (S, bs)
    ns = S // bs

    kernel = functools.partial(_rglru_kernel, bs=bs)
    return pl.pallas_call(
        kernel,
        grid=(B, ns),
        in_specs=[
            pl.BlockSpec((1, bs, W), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, bs, W), lambda b, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bs, W), lambda b, j: (b, j, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, W), jnp.float32),
        scratch_shapes=[pltpu.VMEM((W,), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(log_a, gated)
