"""Version compatibility shims for the Pallas TPU API surface.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``
between releases; the kernels target the new name and fall back here so
the same source runs on both.
"""
from __future__ import annotations

import jax.experimental.pallas.tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams
