"""Jit'd dispatch wrappers: ``impl="pallas" | "xla"`` per kernel.

The XLA path is the lowering used on CPU (dry-run) and the differentiable
training path; the Pallas path is the TPU-target hot-spot implementation,
validated in interpret mode (tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import rglru as _rg
from repro.kernels import ssd as _ssd
from repro.models.attention import flash_attention_xla
from repro.models.rglru import rglru_scan
from repro.models.ssm import ssd_chunked


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "impl", "block_q", "block_k",
    "interpret"))
def attention(q, k, v, *, causal=True, window=0, softcap=0.0,
              impl="pallas", block_q=256, block_k=256, interpret=True):
    """impl: "pallas" (fwd kernel), "pallas_vjp" (fwd+bwd kernels,
    differentiable — the TPU training path), "xla" (pure-JAX)."""
    if impl == "pallas":
        return _fa.flash_attention(q, k, v, causal=causal, window=window,
                                   softcap=softcap, block_q=block_q,
                                   block_k=block_k, interpret=interpret)
    if impl == "pallas_vjp":
        from repro.kernels.flash_attention_bwd import flash_attention_vjp
        return flash_attention_vjp(q, k, v, causal, window, softcap,
                                   block_q, block_k, interpret)
    return flash_attention_xla(q, k, v, causal=causal, window=window,
                               softcap=softcap, q_block=block_q,
                               kv_block=block_k)


@functools.partial(jax.jit, static_argnames=("chunk", "impl", "interpret"))
def ssd(x, dt, A, Bm, Cm, *, chunk=256, impl="pallas", interpret=True):
    if impl == "pallas":
        return _ssd.ssd(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)
    return ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)


@functools.partial(jax.jit, static_argnames=("block_seq", "impl",
                                             "interpret"))
def rglru(log_a, gated, *, block_seq=128, impl="pallas", interpret=True):
    if impl == "pallas":
        return _rg.rglru(log_a, gated, block_seq=block_seq,
                         interpret=interpret)
    return rglru_scan(log_a, gated)
