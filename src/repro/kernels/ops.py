"""Jit'd dispatch wrappers: ``impl="pallas" | "xla"`` per kernel.

The XLA path is the lowering used on CPU (dry-run) and the differentiable
training path; the Pallas path is the TPU-target hot-spot implementation,
validated in interpret mode (tests/test_kernels.py).

Block sizes default to *tuned* configs when a registry is active
(``repro.kernels.registry``, populated by ``repro.kernels.autotune``):
pass ``block_q=None`` etc. (the default) to resolve per shape bucket, or
an explicit int to pin.  ``interpret`` defaults from the backend — real
compilation on TPU, interpreter everywhere else — derived once here
instead of per call site.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import flash_attention as _fa
from repro.kernels import paged_attention as _pa
from repro.kernels import registry as _reg
from repro.kernels import rglru as _rg
from repro.kernels import ssd as _sd
from repro.kernels.ref import paged_attention_ref
from repro.models.attention import flash_attention_xla
from repro.models.rglru import rglru_scan
from repro.models.ssm import ssd_chunked

# pre-registry defaults; registry misses and explicit None resolve here
DEFAULT_ATTN_BLOCKS = (256, 256)
DEFAULT_SSD_CHUNK = 256
DEFAULT_RGLRU_BLOCK = 128
DEFAULT_PAGED_BLOCK_K = 256


@functools.lru_cache(maxsize=1)
def default_interpret() -> bool:
    """Pallas kernels compile for real only on TPU; interpret elsewhere.

    Cached: the default backend cannot change within a process, and the
    answer gates jit cache keys (a flapping default would re-jit)."""
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "impl", "block_q", "block_k",
    "interpret"))
def _attention(q, k, v, *, causal, window, softcap, impl, block_q, block_k,
               interpret):
    if impl == "pallas":
        return _fa.flash_attention(q, k, v, causal=causal, window=window,
                                   softcap=softcap, block_q=block_q,
                                   block_k=block_k, interpret=interpret)
    if impl == "pallas_vjp":
        from repro.kernels.flash_attention_bwd import flash_attention_vjp
        return flash_attention_vjp(q, k, v, causal, window, softcap,
                                   block_q, block_k, interpret)
    return flash_attention_xla(q, k, v, causal=causal, window=window,
                               softcap=softcap, q_block=block_q,
                               kv_block=block_k)


def attention(q, k, v, *, causal=True, window=0, softcap=0.0,
              impl="pallas", block_q=None, block_k=None, interpret=None):
    """impl: "pallas" (fwd kernel), "pallas_vjp" (fwd+bwd kernels,
    differentiable — the TPU training path), "xla" (pure-JAX).

    ``block_q``/``block_k``=None resolve from the tuned-config registry
    (falling back to 256/256); ``interpret``=None resolves from backend.
    """
    if block_q is None or block_k is None:
        kernel = {"pallas": "flash_attention",
                  "pallas_vjp": "flash_attention_bwd",
                  "xla": "flash_attention_xla"}.get(impl, "flash_attention")
        bq, bk = _reg.attention_blocks(
            q.shape[1], k.shape[1], q.shape[3], q.shape[2] // k.shape[2],
            q.dtype, causal, window, defaults=DEFAULT_ATTN_BLOCKS,
            kernel=kernel)
        block_q = block_q if block_q is not None else bq
        block_k = block_k if block_k is not None else bk
    if interpret is None:
        interpret = default_interpret()
    return _attention(q, k, v, causal=causal, window=window,
                      softcap=softcap, impl=impl, block_q=block_q,
                      block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("softcap", "impl", "block_k",
                                             "interpret"))
def _paged_attention(q, k_pages, v_pages, tables, lengths, *, softcap, impl,
                     block_k, interpret):
    if impl == "pallas":
        return _pa.paged_decode_attention(
            q, k_pages, v_pages, tables, lengths, block_k=block_k,
            softcap=softcap, interpret=interpret)
    return paged_attention_ref(q, k_pages, v_pages, tables, lengths,
                               softcap=softcap)


def paged_attention(q, k_pages, v_pages, tables, lengths, *, softcap=0.0,
                    impl="pallas", block_k=None, interpret=None):
    """Decode attention straight off the paged KV pool.

    q (B,H,D); k/v pages (N,ps,K,D); tables (B,P) int32; lengths (B,).
    ``impl="pallas"`` is the TPU-target kernel (scalar-prefetched block
    tables, no dense view); ``"xla"`` is the dense-gather reference the
    CPU serving path uses.  ``block_k``=None resolves from the tuned
    registry through the ``decode_attention|b=…,t=…`` bucket vocabulary
    shared with the serving engine's decode-step batching.
    """
    if block_k is None:
        T = tables.shape[1] * k_pages.shape[1]     # cache length
        _, block_k = _reg.decode_attention_blocks(
            q.shape[0], T, q.shape[2], q.shape[1] // k_pages.shape[2],
            q.dtype, defaults=(1, DEFAULT_PAGED_BLOCK_K),
            kernel="decode_attention")
    if interpret is None:
        interpret = default_interpret()
    return _paged_attention(q, k_pages, v_pages, tables, lengths,
                            softcap=softcap, impl=impl, block_k=block_k,
                            interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "impl", "interpret"))
def _ssd(x, dt, A, Bm, Cm, *, chunk, impl, interpret):
    if impl == "pallas":
        return _sd.ssd(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)
    return ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)


def ssd(x, dt, A, Bm, Cm, *, chunk=None, impl="pallas", interpret=None):
    if chunk is None:
        chunk = _reg.ssd_chunk(x.shape[1], x.shape[2], x.shape[3],
                               Bm.shape[2], Bm.shape[3], x.dtype,
                               default=DEFAULT_SSD_CHUNK)
    if interpret is None:
        interpret = default_interpret()
    return _ssd(x, dt, A, Bm, Cm, chunk=chunk, impl=impl,
                interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_seq", "impl",
                                             "interpret"))
def _rglru(log_a, gated, *, block_seq, impl, interpret):
    if impl == "pallas":
        return _rg.rglru(log_a, gated, block_seq=block_seq,
                         interpret=interpret)
    return rglru_scan(log_a, gated)


def rglru(log_a, gated, *, block_seq=None, impl="pallas", interpret=None):
    if block_seq is None:
        block_seq = _reg.rglru_block(log_a.shape[1], log_a.shape[2],
                                     log_a.dtype,
                                     default=DEFAULT_RGLRU_BLOCK)
    if interpret is None:
        interpret = default_interpret()
    return _rglru(log_a, gated, block_seq=block_seq, impl=impl,
                  interpret=interpret)
