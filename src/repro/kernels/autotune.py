"""Kernel autotuner: sweep block-size candidates, persist measured winners.

The paper's method is *measure before you commit*: a composable system
lets you benchmark each configuration instead of modeling it.  This
module applies the same discipline to the kernel layer — for each
(kernel, shape-bucket, dtype, variant) cell it times every legal
(block_q, block_k) / chunk / block_seq candidate and writes the winner to
the tuned-config registry (``repro.kernels.registry``), which the
dispatch layer and step builders then resolve at call time.

Timing is interpret-mode-safe: on CPU the Pallas kernels run under the
interpreter (grid overhead dominates, so the sweep ranks configs by the
same per-tile/grid tradeoff the TPU sees at much larger scale); on TPU
the same harness wall-clocks the compiled kernels.  Every candidate is
compiled/warmed once, then timed ``iters`` times; the median is recorded.

CLI::

    PYTHONPATH=src python -m repro.kernels.autotune --smoke \
        --out results/tuned_configs.json
"""
from __future__ import annotations

import argparse
import dataclasses
import statistics
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels import registry as reg

KERNELS = ("flash_attention", "flash_attention_bwd", "flash_attention_xla",
           "decode_attention", "ssd", "rglru")

_ATTN_BLOCK_OPTS = (32, 64, 128, 256)
_SSD_CHUNK_OPTS = (32, 64, 128, 256)
_RGLRU_BLOCK_OPTS = (16, 32, 64, 128, 256)


@dataclasses.dataclass(frozen=True)
class Case:
    """One tuning cell: a kernel at a concrete shape/dtype/variant.

    ``dims`` is the kernel-specific dimension dict (sorted tuple so the
    case is hashable); it must carry exactly the names the registry
    resolvers key on (attention: s,t,d,g — ssd: s,h,p,g,n — rglru: s,w).
    Batch size is an input-construction detail, not part of the key.
    """
    kernel: str
    dims: Tuple[Tuple[str, int], ...]
    dtype: str = "float32"
    causal: bool = True
    window: int = 0
    batch: int = 1
    page_size: int = 0                # paged decode cells only

    def dim(self, name: str) -> int:
        return dict(self.dims)[name]

    @property
    def variant(self) -> str:
        if (self.kernel.startswith("flash_attention")
                or self.kernel == "decode_attention"):
            return reg.attention_variant(self.causal, self.window)
        return ""

    @property
    def key(self) -> str:
        return reg.make_key(self.kernel, dtype=self.dtype,
                            variant=self.variant, **dict(self.dims))

    def label(self) -> str:
        d = ",".join(f"{k}{v}" for k, v in self.dims)
        return f"{self.kernel}[{d},{self.dtype},{self.variant or 'na'}]"


def attn_case(kernel: str = "flash_attention", *, S: int, T: int = 0,
              D: int = 32, G: int = 2, dtype: str = "float32",
              causal: bool = True, window: int = 0, batch: int = 1) -> Case:
    T = T or S
    return Case(kernel, (("d", D), ("g", G), ("s", S), ("t", T)),
                dtype=dtype, causal=causal, window=window, batch=batch)


def decode_case(*, B: int, T: int, D: int = 32, G: int = 2,
                page_size: int = 16, dtype: str = "float32") -> Case:
    """Paged-attention decode cell: (B, 1, cache_len T) over the page
    pool.  Keys on the engine's decode bucket vocabulary
    (``decode_attention|b=…,t=…,d=…,g=…``)."""
    assert T % page_size == 0, (T, page_size)
    return Case("decode_attention", (("b", B), ("d", D), ("g", G), ("t", T)),
                dtype=dtype, batch=B, page_size=page_size)


def ssd_case(*, S: int, H: int = 4, P: int = 16, G: int = 1, N: int = 32,
             dtype: str = "float32", batch: int = 1) -> Case:
    return Case("ssd", (("g", G), ("h", H), ("n", N), ("p", P), ("s", S)),
                dtype=dtype, batch=batch)


def rglru_case(*, S: int, W: int = 64, dtype: str = "float32",
               batch: int = 1) -> Case:
    return Case("rglru", (("s", S), ("w", W)), dtype=dtype, batch=batch)


# ---------------------------------------------------------------------------
# deterministic candidate enumeration
# ---------------------------------------------------------------------------
def candidates_for(case: Case) -> List[Dict[str, int]]:
    """Every legal block config for ``case``, deduped after clamping to
    the sequence length, in sorted (deterministic) order."""
    seen = []
    if case.kernel.startswith("flash_attention"):
        S, T = case.dim("s"), case.dim("t")
        for bq in _ATTN_BLOCK_OPTS:
            cq = min(bq, S)
            if S % cq:
                continue
            for bk in _ATTN_BLOCK_OPTS:
                ck = min(bk, T)
                if T % ck:
                    continue
                cand = {"block_q": cq, "block_k": ck}
                if cand not in seen:
                    seen.append(cand)
        seen.sort(key=lambda c: (c["block_q"], c["block_k"]))
    elif case.kernel == "decode_attention":
        ps = case.page_size
        P = case.dim("t") // ps
        for ppb in (1, 2, 4, 8, 16):       # pages per kv superblock
            if ppb > P or P % ppb:
                continue
            cand = {"block_q": 1, "block_k": ppb * ps}
            if cand not in seen:
                seen.append(cand)
        seen.sort(key=lambda c: c["block_k"])
    elif case.kernel == "ssd":
        S = case.dim("s")
        for ch in _SSD_CHUNK_OPTS:
            cc = min(ch, S)
            if S % cc:
                continue
            cand = {"chunk": cc}
            if cand not in seen:
                seen.append(cand)
        seen.sort(key=lambda c: c["chunk"])
    elif case.kernel == "rglru":
        S = case.dim("s")
        for bs in _RGLRU_BLOCK_OPTS:
            cb = min(bs, S)
            if S % cb:
                continue
            cand = {"block_seq": cb}
            if cand not in seen:
                seen.append(cand)
        seen.sort(key=lambda c: c["block_seq"])
    else:
        raise ValueError(f"unknown kernel {case.kernel!r}")
    return seen


def default_blocks(case: Case) -> Dict[str, int]:
    """The pre-registry hardcoded config, fitted the way dispatch does
    (largest size <= the default that divides the sequence — a plain
    min() clamp could hand the kernels a non-dividing tile on non-pow2
    sequences and crash the sweep's baseline measurement)."""
    if case.kernel.startswith("flash_attention"):
        dq, dk = ops.DEFAULT_ATTN_BLOCKS
        if case.kernel == "flash_attention_xla":
            dq = dk = 512                      # models/attention.py default
        return {"block_q": reg.fit_block(dq, case.dim("s")),
                "block_k": reg.fit_block(dk, case.dim("t"))}
    if case.kernel == "decode_attention":
        ps = case.page_size
        P = case.dim("t") // ps
        ppb = reg.fit_block(max(ops.DEFAULT_PAGED_BLOCK_K // ps, 1), P)
        return {"block_q": 1, "block_k": ppb * ps}
    if case.kernel == "ssd":
        return {"chunk": reg.fit_block(ops.DEFAULT_SSD_CHUNK,
                                       case.dim("s"))}
    return {"block_seq": reg.fit_block(ops.DEFAULT_RGLRU_BLOCK,
                                       case.dim("s"))}


# ---------------------------------------------------------------------------
# input + callable construction
# ---------------------------------------------------------------------------
def _np_dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[name]


def build_call(case: Case, blocks: Dict[str, int]
               ) -> Tuple[Callable, tuple]:
    """(fn, args) for one candidate; fn(*args) runs the kernel once."""
    key = jax.random.PRNGKey(0)
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    dt_ = _np_dtype(case.dtype)
    B = case.batch
    if case.kernel.startswith("flash_attention"):
        S, T = case.dim("s"), case.dim("t")
        D, G = case.dim("d"), case.dim("g")
        K = 2                                  # kv heads; H = K*G
        H = K * G
        q = jax.random.normal(k1, (B, S, H, D), jnp.float32).astype(dt_)
        k = jax.random.normal(k2, (B, T, K, D), jnp.float32).astype(dt_)
        v = jax.random.normal(k3, (B, T, K, D), jnp.float32).astype(dt_)
        impl = {"flash_attention": "pallas",
                "flash_attention_bwd": "pallas_vjp",
                "flash_attention_xla": "xla"}[case.kernel]
        kwargs = dict(causal=case.causal, window=case.window, impl=impl,
                      block_q=blocks["block_q"], block_k=blocks["block_k"])
        if case.kernel == "flash_attention_bwd":
            def loss(q_, k_, v_):
                return jnp.sum(ops.attention(q_, k_, v_, **kwargs))
            return jax.jit(jax.grad(loss, argnums=(0, 1, 2))), (q, k, v)

        def fwd(q_, k_, v_):
            return ops.attention(q_, k_, v_, **kwargs)
        return fwd, (q, k, v)

    if case.kernel == "decode_attention":
        T, ps = case.dim("t"), case.page_size
        D, G = case.dim("d"), case.dim("g")
        B = case.dim("b")
        K = 2                                  # kv heads; H = K*G
        P = T // ps
        n_pages = B * P
        q = jax.random.normal(k1, (B, K * G, D), jnp.float32).astype(dt_)
        kp = jax.random.normal(
            k2, (n_pages + 1, ps, K, D), jnp.float32).astype(dt_)
        vp = jax.random.normal(
            k3, (n_pages + 1, ps, K, D), jnp.float32).astype(dt_)
        # exclusive non-contiguous tables + ragged lengths: the shapes
        # the serving pool actually produces
        tables = jnp.arange(n_pages, dtype=jnp.int32).reshape(B, P)[:, ::-1]
        lengths = T - (jnp.arange(B, dtype=jnp.int32) * (ps // 2)) % T

        def run_paged(*args):
            return ops.paged_attention(*args, impl="pallas",
                                       block_k=blocks["block_k"])
        return run_paged, (q, kp, vp, tables, lengths)

    if case.kernel == "ssd":
        S, H = case.dim("s"), case.dim("h")
        P, G, N = case.dim("p"), case.dim("g"), case.dim("n")
        x = jax.random.normal(k1, (B, S, H, P), jnp.float32).astype(dt_)
        dt = jax.nn.softplus(jax.random.normal(k2, (B, S, H))).astype(dt_)
        A = -jnp.exp(jax.random.normal(k3, (H,)))
        Bm = (jax.random.normal(k4, (B, S, G, N)) * 0.5).astype(dt_)
        Cm = (jax.random.normal(k5, (B, S, G, N)) * 0.5).astype(dt_)

        def run_ssd(*args):
            return ops.ssd(*args, chunk=blocks["chunk"], impl="pallas")
        return run_ssd, (x, dt, A, Bm, Cm)

    if case.kernel == "rglru":
        S, W = case.dim("s"), case.dim("w")
        log_a = -jax.nn.softplus(
            jax.random.normal(k1, (B, S, W))).astype(dt_)
        gated = jax.random.normal(k2, (B, S, W)).astype(dt_)

        def run_rglru(*args):
            return ops.rglru(*args, block_seq=blocks["block_seq"],
                             impl="pallas")
        return run_rglru, (log_a, gated)

    raise ValueError(f"unknown kernel {case.kernel!r}")


# ---------------------------------------------------------------------------
# timing
# ---------------------------------------------------------------------------
def time_call(fn: Callable, args: tuple, *, iters: int = 3) -> float:
    """Median wall-clock us/call; the first (untimed) call compiles."""
    out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return statistics.median(ts) * 1e6


# ---------------------------------------------------------------------------
# the sweep
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class CaseResult:
    case: Case
    entry: reg.TunedEntry
    timings: List[Tuple[Dict[str, int], float]]   # (blocks, us) per cand

    def to_json(self) -> Dict[str, object]:
        return {
            "key": self.case.key,
            "kernel": self.case.kernel,
            "best": self.entry.blocks,
            "us": self.entry.us,
            "default": default_blocks(self.case),
            "default_us": self.entry.default_us,
            "speedup": self.entry.speedup,
            "candidates": [{"blocks": b, "us": us}
                           for b, us in self.timings],
        }


def tune_case(case: Case, *, iters: int = 3) -> CaseResult:
    """Time every candidate for one cell; return the measured winner."""
    cands = candidates_for(case)
    default = default_blocks(case)
    timings: List[Tuple[Dict[str, int], float]] = []
    best: Optional[Dict[str, int]] = None
    best_us = float("inf")
    default_us = 0.0
    for blocks in cands:
        fn, args = build_call(case, blocks)
        us = time_call(fn, args, iters=iters)
        timings.append((blocks, us))
        if blocks == default:
            default_us = us
        if us < best_us:
            best, best_us = blocks, us
    if default_us == 0.0 and default not in cands:
        # default config not in the legal candidate grid (e.g. it does
        # not divide the sequence): measure it anyway for the speedup
        fn, args = build_call(case, default)
        default_us = time_call(fn, args, iters=iters)
    entry = reg.TunedEntry(blocks=dict(best or default), us=best_us,
                           default_us=default_us,
                           n_candidates=len(cands),
                           backend=jax.default_backend())
    return CaseResult(case, entry, timings)


def tune(cases: Sequence[Case], *, iters: int = 3,
         registry: Optional[reg.Registry] = None,
         verbose: bool = False) -> Tuple[reg.Registry, List[CaseResult]]:
    """Sweep every case into ``registry`` (a new one when None)."""
    registry = registry if registry is not None else reg.Registry()
    results: List[CaseResult] = []
    for case in cases:
        res = tune_case(case, iters=iters)
        registry.put(case.key, res.entry)
        results.append(res)
        if verbose:
            print(f"{case.label():60s} best={res.entry.blocks} "
                  f"{res.entry.us:9.1f}us (default "
                  f"{res.entry.default_us:9.1f}us, "
                  f"x{res.entry.speedup:.2f})")
    return registry, results


def sweep(cases: Optional[Sequence[Case]] = None, *, iters: int = 3,
          path: Optional[str] = None, merge: bool = True,
          verbose: bool = False) -> Tuple[reg.Registry, List[CaseResult]]:
    """tune() + persist: merge into the registry at ``path`` and save."""
    cases = list(cases if cases is not None else DEFAULT_CASES)
    path = path or reg.DEFAULT_PATH
    registry = None
    if merge:
        try:
            registry = reg.Registry.load(path)
        except (OSError, ValueError, KeyError):
            registry = None
    registry, results = tune(cases, iters=iters, registry=registry,
                             verbose=verbose)
    registry.save(path)
    # fresh winners take effect in THIS process too, not just after a
    # restart (get_registry caches its first disk read)
    reg.set_registry(registry)
    return registry, results


# The standing grids.  SMOKE is the CI sweep: small shapes, every kernel,
# seconds-not-minutes under the CPU interpreter.  DEFAULT adds the
# larger buckets the model zoo actually hits (4k train / 32k serve tiles
# are covered by the pow2 bucketing of s/t).
SMOKE_CASES: Tuple[Case, ...] = (
    attn_case("flash_attention", S=128, D=32, G=2),
    attn_case("flash_attention", S=128, D=32, G=2, window=64),
    attn_case("flash_attention_bwd", S=128, D=32, G=2),
    attn_case("flash_attention_xla", S=256, D=64, G=4),
    decode_case(B=4, T=128, D=32, G=2, page_size=16),
    ssd_case(S=128, H=4, P=16, G=1, N=32),
    rglru_case(S=128, W=64),
)

DEFAULT_CASES: Tuple[Case, ...] = SMOKE_CASES + (
    decode_case(B=8, T=512, D=64, G=4, page_size=16),
    attn_case("flash_attention", S=256, D=64, G=4),
    attn_case("flash_attention", S=256, D=64, G=4, dtype="bfloat16"),
    attn_case("flash_attention", S=512, D=64, G=1, causal=False),
    attn_case("flash_attention_bwd", S=256, D=64, G=2),
    attn_case("flash_attention_xla", S=512, D=64, G=4),
    ssd_case(S=256, H=8, P=32, G=1, N=64),
    rglru_case(S=256, W=128),
)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI grid instead of the default sweep")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--out", default=reg.DEFAULT_PATH)
    ap.add_argument("--no-merge", action="store_true",
                    help="overwrite instead of merging into --out")
    args = ap.parse_args(argv)
    cases = SMOKE_CASES if args.smoke else DEFAULT_CASES
    registry, _ = sweep(cases, iters=args.iters, path=args.out,
                        merge=not args.no_merge, verbose=True)
    print(f"wrote {len(registry)} tuned config(s) to {registry.path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
