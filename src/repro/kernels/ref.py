"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These delegate to the model-zoo reference implementations so the kernels
are validated against exactly the math the framework trains/serves with.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.attention import decode_attention, full_attention
from repro.models.rglru import rglru_scan
from repro.models.ssm import ssd_chunked


def attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0):
    """q (B,S,H,D); k/v (B,T,K,D) -> (B,S,H,D)."""
    return full_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap)


def paged_attention_ref(q, k_pages, v_pages, tables, lengths, *,
                        softcap=0.0):
    """Dense oracle for the paged decode kernel: gather the block tables
    into the dense ``(B, T, K, D)`` cache view (exactly what the serving
    engine's XLA path materializes), then run the model zoo's
    ``decode_attention`` with the positional mask the pool maintains.

    q (B,H,D); k/v pages (N,ps,K,D); tables (B,P) int32; lengths (B,)
    valid-token counts -> (B,H,D).
    """
    B = q.shape[0]
    ps = k_pages.shape[1]
    P = tables.shape[1]
    k = k_pages[tables].reshape((B, P * ps) + k_pages.shape[2:])
    v = v_pages[tables].reshape((B, P * ps) + v_pages.shape[2:])
    t = jnp.arange(P * ps, dtype=jnp.int32)[None, :]
    cache_pos = jnp.where(t < lengths[:, None], t, -1)
    return decode_attention(q[:, None], k, v, cache_pos,
                            softcap=softcap)[:, 0]


def ssd_ref(x, dt, A, Bm, Cm, *, chunk=64, h0=None):
    """Chunked SSD oracle: returns (y (B,S,H,P), h_final (B,H,N,P))."""
    return ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk, h0=h0)


def rglru_ref(log_a, gated, h0=None):
    """Linear recurrence oracle via associative scan: (B,S,W) -> (B,S,W)."""
    return rglru_scan(log_a, gated, h0=h0)
