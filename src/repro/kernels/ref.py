"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These delegate to the model-zoo reference implementations so the kernels
are validated against exactly the math the framework trains/serves with.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.attention import full_attention
from repro.models.rglru import rglru_scan
from repro.models.ssm import ssd_chunked


def attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0):
    """q (B,S,H,D); k/v (B,T,K,D) -> (B,S,H,D)."""
    return full_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap)


def ssd_ref(x, dt, A, Bm, Cm, *, chunk=64, h0=None):
    """Chunked SSD oracle: returns (y (B,S,H,P), h_final (B,H,N,P))."""
    return ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk, h0=h0)


def rglru_ref(log_a, gated, h0=None):
    """Linear recurrence oracle via associative scan: (B,S,W) -> (B,S,W)."""
    return rglru_scan(log_a, gated, h0=h0)
