"""Tuned-config registry: measured-best kernel block configs.

The autotuner (``repro.kernels.autotune``) sweeps block-size candidates
per (kernel, shape-bucket, dtype, variant) cell and persists the winners
here; the dispatch layer (``repro.kernels.ops``) and the step builders
(``train.trainer`` / ``serve.engine``) resolve their block sizes from
this registry instead of hardcoded defaults.

Key format (one flat string so the JSON file is greppable and diffable):

    <kernel>|<dim>=<bucket>,...|<dtype>|<variant>

e.g. ``flash_attention|d=64,g=4,s=256,t=256|float32|causal``.  Sequence
dims are bucketed to the next power of two so a 384-token prefill reuses
the 512 cell; head/feature dims are exact (they change the VMEM working
set shape, not just its size).

Registry file schema (``results/tuned_configs.json`` by default, or
``$REPRO_TUNED_CONFIGS``):

    {"version": 1,
     "schema_version": 1,
     "configs": {"<key>": {"blocks": {"block_q": 128, ...},
                           "us": 812.4,          # best measured us/call
                           "default_us": 991.2,  # default-config us/call
                           "n_candidates": 9,
                           "backend": "cpu"}}}

Lookups that miss fall back to the caller's defaults — an empty or absent
registry reproduces the pre-tuning behaviour exactly.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
from typing import Any, Dict, Mapping, Optional, Tuple

DEFAULT_PATH = os.path.join("results", "tuned_configs.json")
ENV_VAR = "REPRO_TUNED_CONFIGS"

# bucketed dims (next pow2 >= floor); others exact.  ``b`` (decode batch)
# buckets from 1 so tiny serving batches don't all collapse into one cell
_BUCKET_FLOOR = {"s": 32, "t": 32, "b": 1}


def bucket_pow2(n: int, floor: int = 32) -> int:
    """Next power of two >= n (>= floor): shape buckets for seq dims."""
    b = floor
    while b < n:
        b *= 2
    return b


def make_key(kernel: str, *, dtype: str, variant: str = "",
             **dims: int) -> str:
    """Canonical registry key; seq/batch dims (s, t, b) are bucketed to
    the next power of two, every other dim (head/feature widths) stays
    exact."""
    parts = []
    for name in sorted(dims):
        v = int(dims[name])
        if name in _BUCKET_FLOOR:
            v = bucket_pow2(v, _BUCKET_FLOOR[name])
        parts.append(f"{name}={v}")
    return f"{kernel}|{','.join(parts)}|{dtype}|{variant}"


@dataclasses.dataclass
class TunedEntry:
    """One registry cell: winning blocks + the measurement behind them."""
    blocks: Dict[str, int]
    us: float = 0.0                   # best candidate, measured us/call
    default_us: float = 0.0           # default config, measured us/call
    n_candidates: int = 0
    backend: str = ""

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, js: Mapping[str, Any]) -> "TunedEntry":
        return cls(blocks={k: int(v) for k, v in js["blocks"].items()},
                   us=float(js.get("us", 0.0)),
                   default_us=float(js.get("default_us", 0.0)),
                   n_candidates=int(js.get("n_candidates", 0)),
                   backend=str(js.get("backend", "")))

    @property
    def speedup(self) -> float:
        """Measured default/best ratio (1.0 when either side is missing)."""
        if self.us <= 0 or self.default_us <= 0:
            return 1.0
        return self.default_us / self.us


class Registry:
    """In-memory tuned-config table with JSON round-trip."""

    def __init__(self, entries: Optional[Dict[str, TunedEntry]] = None,
                 path: str = ""):
        self.entries: Dict[str, TunedEntry] = dict(entries or {})
        self.path = path

    # ------------------------------------------------------------- access --
    def get(self, key: str) -> Optional[TunedEntry]:
        return self.entries.get(key)

    def put(self, key: str, entry: TunedEntry) -> None:
        self.entries[key] = entry

    def lookup(self, kernel: str, defaults: Mapping[str, int], *,
               dtype: str, variant: str = "", **dims: int) -> Dict[str, int]:
        """Tuned blocks for the cell, or ``defaults`` on a miss."""
        entry = self.get(make_key(kernel, dtype=dtype, variant=variant,
                                  **dims))
        if entry is None:
            return dict(defaults)
        out = dict(defaults)
        out.update(entry.blocks)
        return out

    def __len__(self) -> int:
        return len(self.entries)

    # ---------------------------------------------------------- round-trip --
    def save(self, path: Optional[str] = None) -> str:
        path = path or self.path or DEFAULT_PATH
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        js = {"version": 1,
              "schema_version": 1,
              "configs": {k: e.to_json()
                          for k, e in sorted(self.entries.items())}}
        with open(path, "w") as f:
            json.dump(js, f, indent=2)
            f.write("\n")
        self.path = path
        return path

    @classmethod
    def load(cls, path: str) -> "Registry":
        with open(path) as f:
            js = json.load(f)
        entries = {k: TunedEntry.from_json(v)
                   for k, v in js.get("configs", {}).items()}
        return cls(entries, path=path)


# ---------------------------------------------------------------------------
# process-wide active registry (dispatch-time resolution)
# ---------------------------------------------------------------------------
_lock = threading.Lock()
_active: Optional[Registry] = None
_loaded = False


def set_registry(reg: Optional[Registry]) -> None:
    """Install ``reg`` as the process-wide registry (None -> defaults)."""
    global _active, _loaded
    with _lock:
        _active = reg
        _loaded = True


def reset_registry() -> None:
    """Drop the cached registry; next lookup re-reads env/disk."""
    global _active, _loaded
    with _lock:
        _active = None
        _loaded = False


def get_registry() -> Optional[Registry]:
    """The active registry: set_registry() > $REPRO_TUNED_CONFIGS >
    ``results/tuned_configs.json`` if present > None (pure defaults)."""
    global _active, _loaded
    with _lock:
        if _loaded:
            return _active
        path = os.environ.get(ENV_VAR, "") or DEFAULT_PATH
        if os.path.exists(path):
            try:
                _active = Registry.load(path)
            except (OSError, ValueError, KeyError):
                _active = None       # malformed file: behave as untuned
        _loaded = True
        return _active


# ---------------------------------------------------------------------------
# per-kernel resolvers (the shape-keyed lookups the stack calls)
# ---------------------------------------------------------------------------
def fit_block(block: int, dim: int) -> int:
    """Largest size <= ``block`` that divides ``dim``.

    Pow2 bucketing means a tuned block can come from a neighbouring
    sequence length (e.g. blocks tuned at the 256 bucket applied to
    S=192); the kernels assert divisibility, so tuned values are fitted
    to the actual dim before dispatch.  Bounded: at most ``block``
    decrements (block <= 512 everywhere)."""
    b = max(1, min(int(block), int(dim)))
    while dim % b:
        b -= 1
    return b


def _dtype_name(dtype) -> str:
    import numpy as np
    try:
        return np.dtype(dtype).name
    except TypeError:
        return getattr(dtype, "name", None) or str(dtype)


def attention_variant(causal: bool, window: int) -> str:
    if window > 0:
        return "window"
    return "causal" if causal else "full"


def attention_blocks(S: int, T: int, D: int, G: int, dtype,
                     causal: bool, window: int,
                     defaults: Tuple[int, int] = (256, 256),
                     kernel: str = "flash_attention") -> Tuple[int, int]:
    """(block_q, block_k) for an attention cell; defaults on miss."""
    reg = get_registry()
    if reg is None:
        return defaults
    out = reg.lookup(kernel, {"block_q": defaults[0], "block_k": defaults[1]},
                     dtype=_dtype_name(dtype),
                     variant=attention_variant(causal, window),
                     s=S, t=T, d=D, g=G)
    return fit_block(out["block_q"], S), fit_block(out["block_k"], T)


def decode_attention_blocks(B: int, T: int, D: int, G: int, dtype,
                            causal: bool = True, window: int = 0,
                            defaults: Tuple[int, int] = (1, 256),
                            kernel: str = "decode_attention"
                            ) -> Tuple[int, int]:
    """(block_q, block_k) for the (B, 1, cache_len) decode shape.

    Decode cells key on the *batch* bucket and the cache length — the
    working set is the KV history, not the single query token (S is
    always 1, so it is omitted from the key): the serving engine's
    decode-step batching and the autotuner share the bucket vocabulary
    ``decode_attention|b=<batch>,t=<cache_len>,d=…,g=…``.  ``block_q``
    is fitted to 1 on a miss (one query row); ``block_k`` tiles the
    cache scan.
    """
    reg = get_registry()
    if reg is None:
        return 1, fit_block(defaults[1], T)
    out = reg.lookup(kernel,
                     {"block_q": defaults[0], "block_k": defaults[1]},
                     dtype=_dtype_name(dtype),
                     variant=attention_variant(causal, window),
                     b=B, t=T, d=D, g=G)
    return fit_block(out["block_q"], 1), fit_block(out["block_k"], T)


def ssd_chunk(S: int, H: int, P: int, G: int, N: int, dtype,
              default: int = 256) -> int:
    reg = get_registry()
    if reg is None:
        return default
    return fit_block(
        reg.lookup("ssd", {"chunk": default}, dtype=_dtype_name(dtype),
                   s=S, h=H, p=P, g=G, n=N)["chunk"], S)


def rglru_block(S: int, W: int, dtype, default: int = 128) -> int:
    reg = get_registry()
    if reg is None:
        return default
    return fit_block(
        reg.lookup("rglru", {"block_seq": default},
                   dtype=_dtype_name(dtype), s=S, w=W)["block_seq"], S)


def kernel_speedups(reg: Optional[Registry] = None) -> Dict[str, float]:
    """Per-kernel measured speedup (default_us / best_us), averaged over
    every tuned cell of that kernel — the calibration signal
    ``core.costmodel.CalibratedCost`` layers onto the analytic terms.
    Uses the active registry when ``reg`` is None."""
    reg = reg if reg is not None else get_registry()
    if reg is None:
        return {}
    acc: Dict[str, Tuple[float, int]] = {}
    for key, entry in reg.entries.items():
        kernel = key.split("|", 1)[0]
        s = entry.speedup
        if s <= 0:
            continue
        tot, n = acc.get(kernel, (0.0, 0))
        acc[kernel] = (tot + s, n + 1)
    return {k: tot / n for k, (tot, n) in acc.items() if n}
