"""Backward pass for the fused GQA flash attention — Pallas TPU kernels.

FlashAttention-2 style two-kernel backward:

  * ``_bwd_dkv_kernel``  — grid (B, K, kv_block, q_block): for a fixed KV
    tile, accumulate dK/dV over the q tiles in VMEM scratch (q innermost,
    sequential).
  * ``_bwd_dq_kernel``   — grid (B, K, q_block, kv_block): for a fixed Q
    tile, accumulate dQ over kv tiles.

Both recompute the tile's softmax from the saved row statistics
(m, l) — the standard memory-optimal recipe: no (S, T) matrix is ever
materialized.  ``delta = rowsum(dO * O)`` is precomputed outside (a
cheap fused elementwise+reduce).

Exposed through ``flash_attention_vjp`` (jax.custom_vjp): the forward
runs the fwd kernel extended to also emit (m, l); gradients are exact
(validated against jax.grad of the oracle in tests/test_kernels_bwd.py).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# forward (emits row stats for the backward)
# ---------------------------------------------------------------------------
def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_out_ref, l_out_ref,
                m_ref, l_ref, acc_ref, *, causal, window, bq, bk, nk,
                scale, softcap):
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    i = pl.program_id(2)
    live = jnp.asarray(True)
    if causal:
        live = live & (j * bk <= i * bq + bq - 1)
    if window > 0:
        live = live & ((i * bq) - (j * bk + bk - 1) < window)

    @pl.when(live)
    def _tile():
        q = q_ref[0, 0]                  # (G, bq, D)
        k = k_ref[0, 0]                  # (bk, D)
        v = v_ref[0, 0]
        G, _, D = q.shape
        s = jax.lax.dot_general(
            q.reshape(G * bq, D), k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        q_row = jax.lax.broadcasted_iota(jnp.int32, (G * bq, bk), 0) % bq
        q_pos = i * bq + q_row
        k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (G * bq, bk), 1)
        diff = q_pos - k_pos
        mask = jnp.zeros_like(s)
        if causal:
            mask = jnp.where(diff < 0, NEG_INF, mask)
        if window > 0:
            mask = jnp.where(diff >= window, NEG_INF, mask)
        s = s + mask
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(j == nk - 1)
    def _flush():
        G, _, D = q_ref[0, 0].shape
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(
            o_ref.dtype).reshape(G, bq, D)
        m_out_ref[0, 0] = m_ref[...].reshape(G, bq)
        l_out_ref[0, 0] = l[...].reshape(G, bq)


def _recompute_p(q, k, i, j, bq, bk, scale, softcap, causal, window,
                 m_row, l_row):
    """Recompute the (G*bq, bk) probability tile from saved row stats."""
    G, _, D = q.shape
    s = jax.lax.dot_general(
        q.reshape(G * bq, D), k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    q_row = jax.lax.broadcasted_iota(jnp.int32, (G * bq, bk), 0) % bq
    q_pos = i * bq + q_row
    k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (G * bq, bk), 1)
    diff = q_pos - k_pos
    mask = jnp.zeros_like(s)
    if causal:
        mask = jnp.where(diff < 0, NEG_INF, mask)
    if window > 0:
        mask = jnp.where(diff >= window, NEG_INF, mask)
    s = s + mask
    return jnp.exp(s - m_row[:, None]) / l_row[:, None], s


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, m_ref, l_ref, delta_ref,
                    dk_ref, dv_ref, dk_acc, dv_acc, *, causal, window,
                    bq, bk, nq, scale, softcap):
    i = pl.program_id(3)                 # q tile (innermost)
    j = pl.program_id(2)                 # kv tile (this kernel's output)

    @pl.when(i == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    live = jnp.asarray(True)
    if causal:
        live = live & (j * bk <= i * bq + bq - 1)
    if window > 0:
        live = live & ((i * bq) - (j * bk + bk - 1) < window)

    @pl.when(live)
    def _tile():
        q = q_ref[0, 0]                  # (G, bq, D)
        k = k_ref[0, 0]                  # (bk, D)
        v = v_ref[0, 0]
        do = do_ref[0, 0].reshape(-1, v.shape[-1])   # (G*bq, D)
        m_row = m_ref[0, 0].reshape(-1)
        l_row = l_ref[0, 0].reshape(-1)
        delta = delta_ref[0, 0].reshape(-1)
        G = q.shape[0]
        p, s = _recompute_p(q, k, i, j, bq, bk, scale, softcap, causal,
                            window, m_row, l_row)
        # dV += P^T dO
        dv_acc[...] += jax.lax.dot_general(
            p, do.astype(jnp.float32), (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        # dP = dO V^T ; dS = P * (dP - delta)
        dp = jax.lax.dot_general(
            do.astype(jnp.float32), v.astype(jnp.float32),
            (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None])
        if softcap > 0:
            # d tanh-softcap: ds *= sech^2(s_pre/softcap); recover via s
            t = s / softcap
            ds = ds * (1.0 - jnp.tanh(t) ** 2)
        ds = ds * scale
        # dK += dS^T Q
        dk_acc[...] += jax.lax.dot_general(
            ds, q.reshape(-1, q.shape[-1]).astype(jnp.float32),
            (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(i == nq - 1)
    def _flush():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


def _bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, m_ref, l_ref, delta_ref,
                   dq_ref, dq_acc, *, causal, window, bq, bk, nk, scale,
                   softcap):
    j = pl.program_id(3)                 # kv tile (innermost)
    i = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    live = jnp.asarray(True)
    if causal:
        live = live & (j * bk <= i * bq + bq - 1)
    if window > 0:
        live = live & ((i * bq) - (j * bk + bk - 1) < window)

    @pl.when(live)
    def _tile():
        q = q_ref[0, 0]
        k = k_ref[0, 0]
        v = v_ref[0, 0]
        do = do_ref[0, 0].reshape(-1, v.shape[-1])
        m_row = m_ref[0, 0].reshape(-1)
        l_row = l_ref[0, 0].reshape(-1)
        delta = delta_ref[0, 0].reshape(-1)
        p, s = _recompute_p(q, k, i, j, bq, bk, scale, softcap, causal,
                            window, m_row, l_row)
        dp = jax.lax.dot_general(
            do.astype(jnp.float32), v.astype(jnp.float32),
            (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None])
        if softcap > 0:
            t = s / softcap
            ds = ds * (1.0 - jnp.tanh(t) ** 2)
        ds = ds * scale
        dq_acc[...] += jax.lax.dot_general(
            ds, k.astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(j == nk - 1)
    def _flush():
        G, bq_, D = q_ref[0, 0].shape
        dq_ref[0, 0] = dq_acc[...].astype(dq_ref.dtype).reshape(G, bq_, D)


# ---------------------------------------------------------------------------
# host-side wiring
# ---------------------------------------------------------------------------
def _layout(q, k, v, bq, bk):
    B, S, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, S, K, G, D).transpose(0, 2, 3, 1, 4)   # (B,K,G,S,D)
    kt = k.transpose(0, 2, 1, 3)                             # (B,K,T,D)
    vt = v.transpose(0, 2, 1, 3)
    return qg, kt, vt, B, S, H, D, T, K, G


def _fwd(q, k, v, *, causal, window, softcap, bq, bk, interpret):
    qg, kt, vt, B, S, H, D, T, K, G = _layout(q, k, v, bq, bk)
    nq, nk = S // bq, T // bk
    scale = 1.0 / math.sqrt(D)
    kern = functools.partial(_fwd_kernel, causal=causal, window=window,
                             bq=bq, bk=bk, nk=nk, scale=scale,
                             softcap=softcap)
    o, m, l = pl.pallas_call(
        kern,
        grid=(B, K, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, bq, D), lambda b, h, i, j: (b, h, 0, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, G, bq, D), lambda b, h, i, j: (b, h, 0, i, 0)),
            pl.BlockSpec((1, 1, G, bq), lambda b, h, i, j: (b, h, 0, i)),
            pl.BlockSpec((1, 1, G, bq), lambda b, h, i, j: (b, h, 0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, K, G, S, D), q.dtype),
            jax.ShapeDtypeStruct((B, K, G, S), jnp.float32),
            jax.ShapeDtypeStruct((B, K, G, S), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((G * bq,), jnp.float32),
            pltpu.VMEM((G * bq,), jnp.float32),
            pltpu.VMEM((G * bq, D), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qg, kt, vt)
    out = o.transpose(0, 3, 1, 2, 4).reshape(B, S, H, D)
    return out, (o, m, l)


def _bwd(q, k, v, o_blk, m, l, dout, *, causal, window, softcap, bq, bk,
         interpret):
    qg, kt, vt, B, S, H, D, T, K, G = _layout(q, k, v, bq, bk)
    nq, nk = S // bq, T // bk
    scale = 1.0 / math.sqrt(D)
    do_blk = dout.reshape(B, S, K, G, D).transpose(0, 2, 3, 1, 4)
    # delta = rowsum(dO * O) per (b, k, g, s)
    delta = jnp.sum(do_blk.astype(jnp.float32)
                    * o_blk.astype(jnp.float32), axis=-1)

    dkv = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, causal=causal, window=window,
                          bq=bq, bk=bk, nq=nq, scale=scale,
                          softcap=softcap),
        grid=(B, K, nk, nq),
        in_specs=[
            pl.BlockSpec((1, 1, G, bq, D), lambda b, h, j, i: (b, h, 0, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j, i: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j, i: (b, h, j, 0)),
            pl.BlockSpec((1, 1, G, bq, D), lambda b, h, j, i: (b, h, 0, i, 0)),
            pl.BlockSpec((1, 1, G, bq), lambda b, h, j, i: (b, h, 0, i)),
            pl.BlockSpec((1, 1, G, bq), lambda b, h, j, i: (b, h, 0, i)),
            pl.BlockSpec((1, 1, G, bq), lambda b, h, j, i: (b, h, 0, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j, i: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j, i: (b, h, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, K, T, D), jnp.float32),
            jax.ShapeDtypeStruct((B, K, T, D), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bk, D), jnp.float32),
            pltpu.VMEM((bk, D), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qg, kt, vt, do_blk, m, l, delta)
    dk_b, dv_b = dkv

    dq_b = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, causal=causal, window=window,
                          bq=bq, bk=bk, nk=nk, scale=scale,
                          softcap=softcap),
        grid=(B, K, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, bq, D), lambda b, h, i, j: (b, h, 0, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, G, bq, D), lambda b, h, i, j: (b, h, 0, i, 0)),
            pl.BlockSpec((1, 1, G, bq), lambda b, h, i, j: (b, h, 0, i)),
            pl.BlockSpec((1, 1, G, bq), lambda b, h, i, j: (b, h, 0, i)),
            pl.BlockSpec((1, 1, G, bq), lambda b, h, i, j: (b, h, 0, i)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, bq, D),
                               lambda b, h, i, j: (b, h, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, G, S, D), jnp.float32),
        scratch_shapes=[pltpu.VMEM((G * bq, D), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qg, kt, vt, do_blk, m, l, delta)

    dq = dq_b.transpose(0, 3, 1, 2, 4).reshape(B, S, H, D).astype(q.dtype)
    dk = dk_b.transpose(0, 2, 1, 3).astype(k.dtype)
    dv = dv_b.transpose(0, 2, 1, 3).astype(v.dtype)
    return dq, dk, dv


# ---------------------------------------------------------------------------
# custom_vjp wrapper
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8))
def flash_attention_vjp(q, k, v, causal=True, window=0, softcap=0.0,
                        block_q=256, block_k=256, interpret=True):
    """Differentiable fused flash attention (Pallas fwd + bwd kernels)."""
    out, _ = _fwd(q, k, v, causal=causal, window=window, softcap=softcap,
                  bq=min(block_q, q.shape[1]),
                  bk=min(block_k, k.shape[1]), interpret=interpret)
    return out


def _vjp_fwd(q, k, v, causal, window, softcap, block_q, block_k,
             interpret):
    bq = min(block_q, q.shape[1])
    bk = min(block_k, k.shape[1])
    out, (o_blk, m, l) = _fwd(q, k, v, causal=causal, window=window,
                              softcap=softcap, bq=bq, bk=bk,
                              interpret=interpret)
    return out, (q, k, v, o_blk, m, l)


def _vjp_bwd(causal, window, softcap, block_q, block_k, interpret,
             res, dout):
    q, k, v, o_blk, m, l = res
    bq = min(block_q, q.shape[1])
    bk = min(block_k, k.shape[1])
    dq, dk, dv = _bwd(q, k, v, o_blk, m, l, dout, causal=causal,
                      window=window, softcap=softcap, bq=bq, bk=bk,
                      interpret=interpret)
    return dq, dk, dv


flash_attention_vjp.defvjp(_vjp_fwd, _vjp_bwd)
