"""Parameterized fabric topologies — the pluggable wiring models.

The paper's thesis is that a composable system lets you evaluate
system-level topologies *before* committing to hardware.  The base
``Topology`` (repro.core.topology) prices the flat single-switch chassis
the paper measures; this module adds the two wiring models its
scaling-focused successors study:

  * ``pcie_cascade`` — a k-tier switch chain (GigaIO's "Scaling to 32
    GPUs" architecture): reaching a drawer ``d`` domain ids away
    traverses ``tiers * d`` extra switch stages, each adding one hop of
    link latency and tapering bandwidth by ``bw_taper``.
  * ``oversubscribed_spine`` — a two-level leaf/spine (the passive
    optical backplane rendering): each drawer's leaf switch reaches the
    spine through an uplink provisioned at ``leaf_ports /
    oversubscription`` chip-links, so per-chip bandwidth collapses once
    concurrent cross-drawer flows share the uplink.

Path-resolution invariants (property-tested in tests/test_fabrics.py):

  * symmetry — ``path(a, b) == path(b, a)``;
  * the link *class* is always the canonical Table IV lookup
    (``link_class_between``); topologies only add hops and derate
    bandwidth, so cross-domain traffic that leaves the composed fabric
    is never priced faster than the DCN;
  * a same-domain path is never slower than the same pair split across
    domains;
  * ``single_switch`` is bit-identical to the legacy flat lookup
    (1 hop, full speed, everywhere).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Type

from repro.core.topology import (  # noqa: F401  (re-exported surface)
    SINGLE_SWITCH, AxisPath, LinkClass, Topology, link_class_between)


@dataclasses.dataclass(frozen=True)
class PCIeCascade(Topology):
    """k-tier switch cascade: drawers daisy-chained through ``tiers``
    switch stages per domain-id of distance.

    Only the switched fabrics cascade (SWITCH, and HOST paths that ride
    the switch complex); local ICI never leaves its drawer and the DCN
    is its own network, so both keep the flat 1-hop model.
    """
    name: str = "pcie_cascade"
    tiers: int = 1
    bw_taper: float = 0.85            # per extra stage

    def hops(self, cls: LinkClass, span: int) -> int:
        if span > 0 and cls in (LinkClass.SWITCH, LinkClass.HOST):
            return 1 + self.tiers * span
        return 1

    def bw_scale(self, cls: LinkClass, span: int, flows: int = 1) -> float:
        return self.bw_taper ** (self.hops(cls, span) - 1)


@dataclasses.dataclass(frozen=True)
class OversubscribedSpine(Topology):
    """Two-level leaf/spine over the composed switch fabric.

    Every cross-drawer SWITCH path is leaf -> spine -> leaf (3 hops).
    The uplink of each leaf carries ``leaf_ports / oversubscription``
    chip-links of capacity; with ``flows`` chips of one drawer crossing
    concurrently, each gets ``min(1, uplink / flows)`` of its link — the
    knee the scaling-efficiency bench (benchmarks/fabric_bench.py) is
    built to expose.
    """
    name: str = "oversubscribed_spine"
    oversubscription: float = 4.0
    leaf_ports: int = 8

    def hops(self, cls: LinkClass, span: int) -> int:
        if span > 0 and cls == LinkClass.SWITCH:
            return 3                  # leaf -> spine -> leaf
        return 1

    def bw_scale(self, cls: LinkClass, span: int, flows: int = 1) -> float:
        if span > 0 and cls == LinkClass.SWITCH:
            uplink = self.leaf_ports / self.oversubscription
            return min(1.0, uplink / max(1, flows))
        return 1.0


TOPOLOGIES: Dict[str, Type[Topology]] = {
    "single_switch": Topology,
    "pcie_cascade": PCIeCascade,
    "oversubscribed_spine": OversubscribedSpine,
}


def make_topology(name: str, **params) -> Topology:
    """Build a registered topology by name (``params`` override the
    model's defaults, e.g. ``make_topology("pcie_cascade", tiers=2)``)."""
    if name not in TOPOLOGIES:
        raise KeyError(
            f"unknown topology {name!r}; known: {sorted(TOPOLOGIES)}")
    return TOPOLOGIES[name](**params)
