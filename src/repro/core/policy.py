"""Sharding-policy engine: PolicyConfig -> PartitionSpecs for every tensor.

This is the software ladder the paper measures in §V-4, rendered as
PartitionSpec generation:

  * DP   (paper "Data Parallel")            — ``zero_stage=0``: params and
    optimizer state replicated; batch over the dp axes; gradients
    all-reduced (the master-GPU broadcast of DP is priced by the cost model
    as a full-size broadcast+reduce on the fabric).
  * DDP  (paper "Distributed Data Parallel") — same placement, but gradient
    reduction is bucketed/overlappable (scan-inside psum; see trainer).
  * mixed precision                          — ``compute_dtype=bf16``.
  * sharded (paper "sharded training", ZeRO) — ``zero_stage=1``: optimizer
    state sharded over fsdp axes; ``zero_stage=3``: parameters too.

Tensor-parallel / expert-parallel / sequence-parallel sharding ride the
``model`` axis and are orthogonal knobs (beyond-paper optimizations).

The engine is rule-based: a leaf's path + shape select a TP dim and an FSDP
dim; anything small or indivisible is replicated.  Divisibility is always
checked against the mesh axis sizes so that one policy serves every
(architecture x mesh) cell.
"""
from __future__ import annotations

import re
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, PolicyConfig


# ---------------------------------------------------------------------------
# small helpers
# ---------------------------------------------------------------------------
def _prod(xs) -> int:
    out = 1
    for x in xs:
        out *= int(x)
    return out


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def axis_entry_size(entry, mesh_axes: Mapping[str, int]) -> int:
    if entry is None:
        return 1
    if isinstance(entry, str):
        return mesh_axes[entry]
    return _prod(mesh_axes[a] for a in entry)


# ---------------------------------------------------------------------------
# parameter sharding rules
# ---------------------------------------------------------------------------
# (regex over path, preferred TP dim index *after* any leading stack dim).
# -1 means last dim. None means "never TP-shard".
_TP_RULES: Tuple[Tuple[str, Optional[int]], ...] = (
    (r"moe/w[igo]$", 0),            # expert dim (EP)
    (r"moe/router$", None),
    (r"attn/wq$", 1),               # (d, H, hd) -> heads
    (r"attn/w[kv]$", 1),            # (d, K, hd) -> kv heads
    (r"attn/wo$", 0),               # (H, hd, d) -> heads
    (r"attn/b[qkv]$", 0),
    (r"(mlp|shared)/w[ig]$", -1),   # (d, F) -> hidden
    (r"(mlp|shared)/wo$", 0),       # (F, d) -> hidden
    (r"(embed|head)/table$", 0),    # (V, d) -> vocab
    (r"pos_embed$", None),
    (r"ssm/in_[zx]$", -1),          # (d, d_in) -> inner dim (heads x P)
    (r"ssm/in_(b|c|dt)$", None),    # grouped B/C + dt: small, replicate
    (r"ssm/out_proj$", 0),          # (d_in, d)
    (r"ssm/conv_\w+$", -1),
    (r"rglru/in_(gate|rec)$", -1),  # (d, W)
    (r"rglru/out_proj$", 0),
    (r"rglru/conv_[wb]$", -1),
    (r"rglru/(wa|wx)$", None),      # block-diag gates: small, replicate
                                    # (TP-sharding the bs contraction was
                                    # tried: partial-sum all-reduces of the
                                    # fp32 stream cost MORE than the gather
                                    # it saves — see EXPERIMENTS.md §Perf)
    (r"norm", None),
)

_REPLICATE_BELOW = 1 << 16          # leaves smaller than 64K elems replicate


def _pick_tp_dim(pstr: str, shape: Tuple[int, ...], skip: int,
                 tp_size: int) -> Optional[int]:
    """Dim index (absolute) to shard over the tp axis, or None."""
    for pat, dim in _TP_RULES:
        if re.search(pat, pstr):
            if dim is None:
                return None
            d = dim if dim >= 0 else len(shape) - 1
            d = d + skip if dim >= 0 else d
            if d < len(shape) and d >= skip and shape[d] % tp_size == 0:
                return d
            break   # rule matched but indivisible -> generic fallback
    # generic: largest divisible dim (excluding stack dims)
    cands = [(shape[d], d) for d in range(skip, len(shape))
             if shape[d] % tp_size == 0]
    if not cands:
        return None
    size, d = max(cands)
    return d if size >= tp_size else None


def _pick_fsdp_dim(shape: Tuple[int, ...], skip: int, taken: Optional[int],
                   fsdp_size: int) -> Optional[int]:
    cands = [(shape[d], d) for d in range(skip, len(shape))
             if d != taken and shape[d] % fsdp_size == 0]
    if not cands:
        return None
    size, d = max(cands)
    return d if size >= fsdp_size else None


def _stack_skip(pstr: str, cfg: ModelConfig) -> int:
    """1 if this param carries a leading scan-stacked layer dim."""
    m = re.match(r"stack/seg(\d+)/", pstr)
    if not m:
        return 0
    from repro.models.transformer import plan_segments
    segs = plan_segments(cfg.pattern)
    si = int(m.group(1))
    return 1 if si < len(segs) and segs[si][1] > 1 else 0


def param_spec(pstr: str, shape: Tuple[int, ...], cfg: ModelConfig,
               policy: PolicyConfig, mesh_axes: Mapping[str, int],
               *, shard_fsdp: bool, is_opt: bool = False) -> P:
    """PartitionSpec for one parameter leaf."""
    if _prod(shape) < _REPLICATE_BELOW:
        return P()
    skip = _stack_skip(pstr, cfg)
    entries: list = [None] * len(shape)

    tp = policy.tp_axis
    tp_size = mesh_axes.get(tp, 1) if tp else 1
    tp_dim = None
    if tp and tp_size > 1:
        tp_dim = _pick_tp_dim(pstr, shape, skip, tp_size)
        if tp_dim is not None:
            entries[tp_dim] = tp

    # vocab tables (params only): V over tp only — FSDP-sharding the D
    # (contraction) dim turns every logits chunk into a full fp32
    # partial-sum all-reduce over data (62 GiB/step measured on
    # command-r).  Optimizer/master states never feed a matmul, so they
    # keep the full fsdp sharding for memory.
    if (not is_opt and tp_dim is not None
            and re.search(r"(embed|head)/table$", pstr)):
        return P(*entries)

    if shard_fsdp and policy.fsdp_axes:
        fs = tuple(a for a in policy.fsdp_axes if mesh_axes.get(a, 1) > 1)
        if fs:
            fsdp_size = _prod(mesh_axes[a] for a in fs)
            fd = _pick_fsdp_dim(shape, skip, tp_dim, fsdp_size)
            if fd is not None:
                entries[fd] = fs if len(fs) > 1 else fs[0]
    return P(*entries)


def param_specs(params: Any, cfg: ModelConfig, policy: PolicyConfig,
                mesh_axes: Mapping[str, int]) -> Any:
    """Pytree of PartitionSpecs matching ``params``."""
    shard = policy.zero_stage >= 3

    def leaf(path, a):
        return param_spec(_path_str(path), tuple(a.shape), cfg, policy,
                          mesh_axes, shard_fsdp=shard)
    return jax.tree_util.tree_map_with_path(leaf, params)


def opt_state_specs(params: Any, cfg: ModelConfig, policy: PolicyConfig,
                    mesh_axes: Mapping[str, int]) -> Any:
    """Adam moment sharding: ZeRO-1+ shards optimizer state even when the
    params themselves are replicated (paper's "sharded training")."""
    shard = policy.zero_stage >= 1

    def leaf(path, a):
        return param_spec(_path_str(path), tuple(a.shape), cfg, policy,
                          mesh_axes, shard_fsdp=shard, is_opt=True)
    return jax.tree_util.tree_map_with_path(leaf, params)


# ---------------------------------------------------------------------------
# batch / activation / cache sharding
# ---------------------------------------------------------------------------
def dp_spec_for_batch(batch: int, policy: PolicyConfig,
                      mesh_axes: Mapping[str, int]):
    """The batch-dim entry: the largest prefix of dp axes that divides."""
    axes = [a for a in policy.dp_axes if mesh_axes.get(a, 1) > 1]
    while axes and batch % _prod(mesh_axes[a] for a in axes):
        axes = axes[1:]      # drop outermost (pod) first
    if not axes:
        return None
    return tuple(axes) if len(axes) > 1 else axes[0]


def batch_specs(example: Any, policy: PolicyConfig,
                mesh_axes: Mapping[str, int], *,
                seq_axis: Optional[str] = None) -> Any:
    """Specs for a batch pytree: dim0 = batch over dp axes; optional
    sequence sharding of dim1 over ``seq_axis`` (context parallel)."""
    def leaf(a):
        if a.ndim == 0:
            return P()
        dp = dp_spec_for_batch(a.shape[0], policy, mesh_axes)
        entries: list = [dp] + [None] * (a.ndim - 1)
        if (seq_axis and a.ndim >= 2
                and a.shape[1] % mesh_axes.get(seq_axis, 1) == 0
                and a.shape[1] >= 2 * mesh_axes.get(seq_axis, 1)):
            entries[1] = seq_axis
        return P(*entries)
    return jax.tree.map(leaf, example)


def cache_specs(caches: Any, policy: PolicyConfig,
                mesh_axes: Mapping[str, int]) -> Any:
    """Decode-cache sharding.

    Attention k/v (..., B, W, K, D): batch over dp; cache length W over the
    tp axis (flash-decode style — avoids materializing a gathered cache,
    which for 32k x 128 would exceed HBM).  ``pos`` (..., B, W) follows W.
    SSM/RGLRU states: batch over dp; channel dims over tp where divisible.
    Leading stacked-layer dims (scan segments) are never sharded.
    """
    tp = policy.tp_axis
    tp_size = mesh_axes.get(tp, 1) if tp else 1

    def leaf(path, a):
        pstr = _path_str(path)
        # find batch dim: stacked caches have a leading layer dim
        skip = 1 if re.search(r"seg\d+/slot\d+", pstr) and a.ndim >= 1 and \
            _is_stacked(pstr) else 0
        entries: list = [None] * a.ndim
        bdim = skip
        if a.ndim > bdim:
            entries[bdim] = dp_spec_for_batch(a.shape[bdim], policy,
                                              mesh_axes)
        if tp and tp_size > 1 and a.ndim > bdim + 1:
            if re.search(r"/(k|v|pos)$", pstr):
                wdim = bdim + 1
                if a.shape[wdim] % tp_size == 0 and a.shape[wdim] >= 2 * tp_size:
                    entries[wdim] = tp
            else:
                cands = [(a.shape[d], d) for d in range(bdim + 1, a.ndim)
                         if a.shape[d] % tp_size == 0
                         and a.shape[d] >= 2 * tp_size]
                if cands:
                    entries[max(cands)[1]] = tp
        return P(*entries)

    # stacked-ness: infer from shape bookkeeping done by the caller is
    # overkill; caches built by init_stack_cache broadcast a leading k dim
    # for scanned segments. We detect via path later if needed; default to
    # treating dim0 as layer when the sub-path has seg/slot and ndim>=3.
    return jax.tree_util.tree_map_with_path(leaf, caches)


def _is_stacked(pstr: str) -> bool:
    # caches under segN/slotM are stacked iff the segment scans (k>1); the
    # caller cannot cheaply know k here, but stacked caches always have the
    # layer dim first and batch second — and batch-first unstacked caches
    # appear only for k==1 segments whose batch dim then gets the dp spec at
    # dim 0 anyway. Treat "seg*/slot*" with >=3 dims as stacked.
    return True


def logits_spec(policy: PolicyConfig, mesh_axes: Mapping[str, int],
                batch: int) -> P:
    dp = dp_spec_for_batch(batch, policy, mesh_axes)
    tp = policy.tp_axis if mesh_axes.get(policy.tp_axis or "", 1) > 1 else None
    return P(dp, None, tp)


# ---------------------------------------------------------------------------
# activation constraints (used inside the model when a mesh is active)
# ---------------------------------------------------------------------------
def constrain(x, spec: Optional[P]):
    """with_sharding_constraint that is a no-op without a mesh context."""
    if spec is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x


# ---------------------------------------------------------------------------
# the paper's software-optimization ladder as named policies
# ---------------------------------------------------------------------------
def ladder(policy: PolicyConfig) -> Dict[str, PolicyConfig]:
    """Fig-16 ladder: DP -> DDP -> +mixed precision -> +ZeRO sharding."""
    import dataclasses
    base = dataclasses.replace(policy, zero_stage=0,
                               compute_dtype="float32",
                               hierarchical_allreduce=False)
    return {
        "DP": base,
        "DDP": dataclasses.replace(base, hierarchical_allreduce=True),
        "DDP+mixed": dataclasses.replace(base, compute_dtype="bfloat16",
                                         hierarchical_allreduce=True),
        "DDP+mixed+sharded": dataclasses.replace(
            base, compute_dtype="bfloat16", hierarchical_allreduce=True,
            zero_stage=3),
    }
