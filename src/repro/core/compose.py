"""Composition layer: DevicePool -> ComposedSystem (logical mesh + fabric).

A ``ComposedSystem`` is the paper's "host configuration" (Table III): a
selection of pool devices arranged into a named-axis logical mesh, plus the
link class each axis rides on and the storage tier feeding the input
pipeline.  The same model program runs unmodified on any composition; only
the fabric pricing (and thus the roofline collective term) changes — which
is exactly the experiment the paper runs on its Falcon chassis.

Composable operations:
  * ``compose(...)``           — build a system from the pool
  * ``recompose(...)``         — swap fabric/axes after failure or resize
  * ``PRESETS``                — the paper's five Table III configurations
  * ``ComposedSystem.mesh()``  — materialize a ``jax.Mesh`` over real
                                 (or ``xla_force_host_platform``) devices
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.topology import (
    DEFAULT_LINKS, LOCAL_NVME, SWITCH_NVME, ChipSpec, DevicePool, FabricSpec,
    LeaseError, LinkClass, LinkSpec, StorageSpec, make_pool)


@dataclasses.dataclass(frozen=True)
class ComposedSystem:
    """A logical machine composed from the pool.

    ``axis_names``/``axis_sizes`` define the logical mesh; ``fabric`` prices
    every axis; ``device_uids`` records which pool devices were claimed (for
    elastic recomposition and failure handling).
    """
    name: str
    axis_names: Tuple[str, ...]
    axis_sizes: Tuple[int, ...]
    fabric: FabricSpec
    device_uids: Tuple[int, ...] = ()
    chip: ChipSpec = ChipSpec()
    # storage tranche leased with this composition (None = legacy static
    # tier pricing only; see repro.data.storage)
    tranche: Optional[str] = None

    # ------------------------------------------------------------ derived --
    @property
    def n_devices(self) -> int:
        return int(np.prod(self.axis_sizes))

    @property
    def shape(self) -> Dict[str, int]:
        return dict(zip(self.axis_names, self.axis_sizes))

    def axis_size(self, axis: str) -> int:
        return self.shape[axis]

    # --------------------------------------------------------------- mesh --
    def mesh(self, devices=None):
        """Materialize a ``jax.Mesh``.

        ``devices``: optional explicit device list (tests); defaults to
        ``jax.devices()`` — which is 512 host devices inside the dry-run
        (XLA_FLAGS set there) and 1 CPU device elsewhere.
        """
        import jax
        if devices is None:
            return jax.make_mesh(self.axis_sizes, self.axis_names)
        arr = np.asarray(devices)[: self.n_devices].reshape(self.axis_sizes)
        return jax.sharding.Mesh(arr, self.axis_names)

    def abstract_mesh(self):
        """Mesh of abstract devices — lowering without device state."""
        import jax
        return jax.sharding.AbstractMesh(self.axis_sizes, self.axis_names)

    # ----------------------------------------------------------- pricing --
    def axis_bandwidth(self, axis: str) -> float:
        return self.fabric.bandwidth(axis)

    def collective_time(self, axis: str, nbytes: float,
                        kind: str = "all-reduce") -> float:
        """Ring-collective time for ``nbytes`` (per-device payload) on
        ``axis``. Standard ring costs on n participants; each of the
        n-1 ring steps pays the axis's full hop count of link latency
        (1 hop on the flat fabric — the legacy price)."""
        n = self.axis_size(axis)
        if n <= 1:
            return 0.0
        link = self.fabric.link(axis)
        factor = {
            "all-reduce": 2.0 * (n - 1) / n,
            "all-gather": (n - 1) / n,
            "reduce-scatter": (n - 1) / n,
            "all-to-all": (n - 1) / n,
            "collective-permute": 1.0,
        }[kind]
        return (factor * nbytes / link.bandwidth
                + (n - 1) * self.fabric.hops(axis) * link.latency)


# ---------------------------------------------------------------------------
# composition / recomposition
# ---------------------------------------------------------------------------
class CompositionError(RuntimeError):
    pass


def compose(pool: DevicePool, name: str,
            axis_names: Sequence[str], axis_sizes: Sequence[int],
            axis_links: Mapping[str, LinkClass],
            storage: StorageSpec = LOCAL_NVME,
            prefer_fabric: Optional[LinkClass] = None,
            uids: Optional[Sequence[int]] = None,
            storage_pool=None, tranche: Optional[str] = None,
            storage_capacity: float = 0.0,
            axis_hops: Optional[Mapping[str, int]] = None,
            axis_bw_scale: Optional[Mapping[str, float]] = None
            ) -> ComposedSystem:
    """Claim devices from the pool and build a ComposedSystem.

    Devices are taken domain-major so that the *innermost* (fastest-varying)
    axes land inside a single locality domain — mirroring how the paper
    keeps NVLink cliques intact and spans the falcon switch only on the
    outer axis.

    Claims are *exclusive*: the chosen devices are leased in the pool under
    the composition's name, so an overlapping ``compose()`` raises
    ``CompositionError`` instead of silently double-claiming chips.  Free
    them with ``release()`` (or ``recompose()``, which re-leases).

    ``uids``: explicit device selection (e.g. from
    ``repro.cluster.lease.plan_placement``) — claimed verbatim, so the
    caller's placement decision is exactly what the lease records.

    ``storage_pool``/``tranche``: a composition is devices **plus**
    storage.  When given, the named NVMe tranche (``repro.data.storage``)
    is leased under the composition's name — atomically with the device
    claim: a storage conflict rolls the device lease back — and the
    fabric's storage tier is priced from that tranche.

    ``axis_hops``/``axis_bw_scale``: per-axis path resolution from the
    pool's topology (``repro.cluster.lease.derive_axis_paths``); omitted
    axes ride one full-speed hop, the flat-fabric default.
    """
    n = int(np.prod(list(axis_sizes)))
    free = pool.available()
    if uids is not None:
        if len(uids) != n:
            raise CompositionError(
                f"explicit selection has {len(uids)} uids; composition "
                f"{name!r} needs {n}")
        free_uids = {d.uid for d in free}
        missing = [u for u in uids if u not in free_uids]
        if missing:
            raise CompositionError(
                f"{len(missing)} of the selected devices are failed, "
                f"leased, or absent: {sorted(missing)[:8]}")
        ordered = list(uids)
        claimed = tuple(uids)
    else:
        if prefer_fabric is not None:
            ordered = ([d for d in free if d.fabric == prefer_fabric]
                       + [d for d in free if d.fabric != prefer_fabric])
        else:
            ordered = sorted(free, key=lambda d: (d.domain, d.fabric.value,
                                                  d.uid))
        if len(ordered) < n:
            n_leased = sum(1 for d in pool.healthy() if d.uid in pool.leases)
            raise CompositionError(
                f"pool has {len(ordered)} available devices "
                f"({n_leased} healthy but leased); composition "
                f"{name!r} needs {n}")
        claimed = tuple(d.uid for d in ordered[:n])
    try:
        pool.lease(claimed, name)
    except LeaseError as e:              # e.g. duplicate uids in `uids`
        raise CompositionError(str(e)) from e
    if storage_pool is not None and tranche is not None:
        try:
            storage_pool.lease(tranche, name,
                               capacity_bytes=storage_capacity)
        except CompositionError:
            pool.release(claimed)        # atomic: no half-composition
            raise
        storage = storage_pool.tranches[tranche].spec()
    fabric = FabricSpec(dict(axis_links), dict(pool.links), storage,
                        dict(axis_hops or {}), dict(axis_bw_scale or {}))
    return ComposedSystem(name, tuple(axis_names), tuple(axis_sizes),
                          fabric, claimed, tranche=tranche)


def release(pool: DevicePool, system: ComposedSystem,
            storage_pool=None) -> None:
    """Return ``system``'s devices (and, when ``storage_pool`` is given,
    its storage tranche) to the pool (job finished / preempted)."""
    pool.release(system.device_uids)
    if storage_pool is not None:
        storage_pool.release(system.name)


def recompose(pool: DevicePool, system: ComposedSystem, *,
              axis_sizes: Optional[Sequence[int]] = None,
              axis_links: Optional[Mapping[str, LinkClass]] = None,
              storage: Optional[StorageSpec] = None) -> ComposedSystem:
    """Re-build ``system`` after pool change (failure, attach, resize).

    This is the paper's dynamic re-allocation: the logical machine is
    re-formed from whatever healthy devices remain; training resumes from
    the latest checkpoint (see ``repro.train.elastic``).  The storage
    tranche lease (held by name) survives the recompose untouched.
    """
    sizes = tuple(axis_sizes or system.axis_sizes)
    links = dict(axis_links or system.fabric.axis_links)
    st = storage or system.fabric.storage
    # release the old claim first (the new composition may reuse surviving
    # devices); restore it if the re-compose fails, so a failed recompose
    # leaves the pool exactly as it was.
    old = [u for u in system.device_uids if pool.leases.get(u) == system.name]
    pool.release(old)
    try:
        return compose(pool, system.name, system.axis_names, sizes, links,
                       st, tranche=system.tranche)
    except CompositionError:
        present = {d.uid for d in pool.devices}
        pool.lease([u for u in old if u in present], system.name)
        raise


def shrink_to_pool(pool: DevicePool, system: ComposedSystem,
                   shrink_axis: str) -> ComposedSystem:
    """Elastic downsize: halve ``shrink_axis`` until the composition fits
    the devices this system can draw on — the unleased healthy pool plus
    its own surviving claim (other tenants' leases are off-limits)."""
    sizes = dict(zip(system.axis_names, system.axis_sizes))
    own = set(system.device_uids)
    n_capacity = len(pool.available()) + sum(
        1 for d in pool.devices
        if d.healthy and d.uid in own and pool.leases.get(d.uid) == system.name)
    while int(np.prod(list(sizes.values()))) > n_capacity:
        if sizes[shrink_axis] <= 1:
            raise CompositionError("cannot shrink further")
        sizes[shrink_axis] //= 2
    return recompose(pool, system,
                     axis_sizes=[sizes[a] for a in system.axis_names])


# ---------------------------------------------------------------------------
# Table III presets (the paper's five host configurations, TPU-rendered)
# ---------------------------------------------------------------------------
def preset(label: str, *, data: int = 16, model: int = 16,
           pods: int = 1) -> ComposedSystem:
    """The paper's Table III configurations on the production mesh.

    | paper label  | rendering                                             |
    |--------------|-------------------------------------------------------|
    | localGPUs    | both axes on LOCAL ICI, local NVMe                    |
    | hybridGPUs   | model axis LOCAL, data axis SWITCH (half the machine  |
    |              | behind the composed fabric), local NVMe               |
    | falconGPUs   | both axes SWITCH (whole machine composed), local NVMe |
    | localNVMe    | localGPUs + explicit local NVMe tier                  |
    | falconNVMe   | localGPUs + switch-attached NVMe tier                 |

    ``pods=2`` adds the "pod" axis on DCN (the multi-pod production mesh).
    """
    configs: Dict[str, Tuple[Dict[str, LinkClass], StorageSpec]] = {
        "localGPUs": ({"data": LinkClass.LOCAL, "model": LinkClass.LOCAL},
                      LOCAL_NVME),
        "hybridGPUs": ({"data": LinkClass.SWITCH, "model": LinkClass.LOCAL},
                       LOCAL_NVME),
        "falconGPUs": ({"data": LinkClass.SWITCH, "model": LinkClass.SWITCH},
                       LOCAL_NVME),
        "localNVMe": ({"data": LinkClass.LOCAL, "model": LinkClass.LOCAL},
                      LOCAL_NVME),
        "falconNVMe": ({"data": LinkClass.LOCAL, "model": LinkClass.LOCAL},
                       SWITCH_NVME),
    }
    if label not in configs:
        raise KeyError(f"unknown preset {label!r}; known: {sorted(configs)}")
    axis_links, storage = configs[label]
    names: Tuple[str, ...] = ("data", "model")
    sizes: Tuple[int, ...] = (data, model)
    if pods > 1:
        names = ("pod",) + names
        sizes = (pods,) + sizes
        axis_links = dict(axis_links, pod=LinkClass.DCN)
    pool = make_pool(n_local=pods * data * model,
                     n_switch=pods * data * model, pods=max(pods, 1))
    want = (LinkClass.SWITCH if all(
        v == LinkClass.SWITCH for k, v in axis_links.items() if k != "pod")
        else LinkClass.LOCAL)
    sys_ = compose(pool, label, names, sizes, axis_links, storage,
                   prefer_fabric=want)
    return sys_


PRESET_LABELS = ("localGPUs", "hybridGPUs", "falconGPUs", "localNVMe",
                 "falconNVMe")


def production_system(multi_pod: bool = False,
                      label: str = "localGPUs") -> ComposedSystem:
    """The production mesh: 16x16 single-pod or 2x16x16 multi-pod."""
    return preset(label, data=16, model=16, pods=2 if multi_pod else 1)
