"""Compiled-artifact cost model: the framework's measurement instrument.

The paper measures DL workloads on a real Falcon chassis with Nsight/wandb.
This container is CPU-only, so the equivalent instrument here is *analysis
of the compiled XLA artifact*:

  * ``compiled.cost_analysis()``  -> HLO FLOPs and HBM bytes accessed
  * ``compiled.as_text()``        -> every collective op, its payload bytes,
                                     and (from replica groups) the mesh axis
                                     it rides on
  * analytic model FLOPs          -> 6·N·D-style "useful" compute, plus
                                     exact per-block forward FLOPs for every
                                     model family in the zoo

From these we derive the three roofline terms per (arch x shape x mesh):

    compute    = FLOPs / (chips x peak)
    memory     = bytes / (chips x HBM bw)
    collective = wire-bytes(axis) / link-bw(axis)   summed over axes

and — the paper's actual experiment — *re-price the same program on a
different composed fabric* by swapping the FabricSpec under the collective
term (localGPUs vs hybridGPUs vs falconGPUs, Table III/Fig 11).

HLO accounting notes (documented deviations):
  * XLA's HloCostAnalysis visits each while-loop body ONCE; ops inside a
    ``lax.scan`` are therefore undercounted by the trip count.  The parser
    below walks HLO computations, finds while bodies, extracts their trip
    counts from the loop-condition constant, and multiplies nested
    collectives accordingly.  FLOPs use the analytic model (exact for every
    family here), with the raw HLO figure reported alongside.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import (ATTN, ATTN_LOCAL, RGLRU, SSM, ModelConfig,
                                PolicyConfig, ShapeConfig)
from repro.core.compose import ComposedSystem
from repro.core.topology import ChipSpec, FabricSpec

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute")

# ring-collective wire factor: bytes crossing one device's link / payload
_RING_FACTOR = {
    "all-reduce": lambda n: 2.0 * (n - 1) / n,
    "all-gather": lambda n: (n - 1) / n,
    "reduce-scatter": lambda n: (n - 1) / n,
    "all-to-all": lambda n: (n - 1) / n,
    "collective-permute": lambda n: 1.0,
}


# ---------------------------------------------------------------------------
# HLO text parsing
# ---------------------------------------------------------------------------
def _shape_bytes(sig: str) -> float:
    """Total bytes of all array literals in an HLO shape signature."""
    total = 0.0
    for m in re.finditer(r"(\w+?)\[([\d,]*)\]", sig):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_group(attr: str, n_total: int) -> Optional[List[int]]:
    """First replica group from either explicit or iota replica_groups."""
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", attr)
    if m:
        return [int(x) for x in m.group(1).split(",")]
    # iota form: replica_groups=[G,S]<=[dims...](T(perm))?
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\]"
                  r"(?:T\(([\d,]+)\))?", attr)
    if m:
        n_groups, group_size = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            perm = [int(x) for x in m.group(4).split(",")]
            ids = ids.transpose(perm)
        flat = ids.reshape(n_groups, group_size)
        return list(flat[0])
    return None


def _axes_of_group(group: Sequence[int], mesh_axes: Mapping[str, int]
                   ) -> Tuple[str, ...]:
    """Which mesh axes vary within a replica group (row-major device ids)."""
    names = list(mesh_axes)
    sizes = [mesh_axes[a] for a in names]
    strides = [int(np.prod(sizes[i + 1:])) for i in range(len(sizes))]

    def coords(dev: int) -> Tuple[int, ...]:
        return tuple((dev // strides[i]) % sizes[i] for i in range(len(sizes)))

    base = coords(group[0])
    varying = set()
    for g in group[1:]:
        c = coords(g)
        for i in range(len(sizes)):
            if c[i] != base[i]:
                varying.add(names[i])
    return tuple(a for a in names if a in varying)


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    payload_bytes: float          # per-device shape bytes of the op
    group_size: int
    axes: Tuple[str, ...]         # mesh axes the group spans
    trip_count: int = 1           # multiplier from enclosing while loops
    computation: str = "main"

    @property
    def wire_bytes(self) -> float:
        """Bytes crossing one device's link, x trip count (ring cost)."""
        return (_RING_FACTOR[self.kind](max(self.group_size, 2))
                * self.payload_bytes * self.trip_count)


def _split_computations(hlo: str) -> Dict[str, str]:
    """computation name -> body text (best-effort HLO text parse)."""
    comps: Dict[str, str] = {}
    cur: Optional[str] = None
    buf: List[str] = []
    for line in hlo.splitlines():
        # a computation header starts at column 0: [ENTRY] %name (args...) ... {
        m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\{\s*$", line)
        if m is None:
            m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\{\s*$", line)
        if m:
            if cur is not None:
                comps[cur] = "\n".join(buf)
            cur = m.group(1)
            buf = []
        elif cur is not None:
            buf.append(line)
            if line.startswith("}"):
                comps[cur] = "\n".join(buf)
                cur = None
                buf = []
    if cur is not None:
        comps[cur] = "\n".join(buf)
    return comps


def _while_trip_counts(hlo: str, comps: Dict[str, str]) -> Dict[str, int]:
    """body-computation name -> trip count (from the condition constant)."""
    trips: Dict[str, int] = {}
    for m in re.finditer(
            r"while\([^)]*\)[^\n]*?condition=%?([\w\.\-]+)[^\n]*?"
            r"body=%?([\w\.\-]+)", hlo):
        cond, body = m.group(1), m.group(2)
        best = None
        cond_text = comps.get(cond, "")
        for c in re.finditer(r"constant\((\d+)\)", cond_text):
            v = int(c.group(1))
            if v > 1 and (best is None or v > best):
                best = v
        trips[body] = best if best is not None else 1
    # alternate attr order (body= before condition=)
    for m in re.finditer(
            r"while\([^)]*\)[^\n]*?body=%?([\w\.\-]+)[^\n]*?"
            r"condition=%?([\w\.\-]+)", hlo):
        body, cond = m.group(1), m.group(2)
        if body in trips:
            continue
        best = None
        for c in re.finditer(r"constant\((\d+)\)", comps.get(cond, "")):
            v = int(c.group(1))
            if v > 1 and (best is None or v > best):
                best = v
        trips[body] = best if best is not None else 1
    return trips


def _call_multipliers(hlo: str, comps: Dict[str, str]) -> Dict[str, int]:
    """computation -> total execution multiplier (nested while loops)."""
    trips = _while_trip_counts(hlo, comps)
    # build caller graph: computation A references computation B via
    # body=/condition=/to_apply=/calls=
    refs: Dict[str, List[Tuple[str, int]]] = {name: [] for name in comps}
    for name, body in comps.items():
        for m in re.finditer(r"(?:body|to_apply|calls)=%?([\w\.\-]+)", body):
            callee = m.group(1)
            mult = trips.get(callee, 1) if callee in trips else 1
            refs.setdefault(callee, [])
            refs[callee].append((name, mult))

    memo: Dict[str, int] = {}

    def total(name: str, depth=0) -> int:
        if name in memo:
            return memo[name]
        if depth > 50 or not refs.get(name):
            memo[name] = 1
            return 1
        callers = refs[name]
        # a computation may be shared; take the max chain (conservative)
        best = 1
        for caller, mult in callers:
            best = max(best, mult * total(caller, depth + 1))
        memo[name] = best
        return best

    return {name: total(name) for name in comps}


def parse_hlo_collectives(hlo: str, mesh_axes: Mapping[str, int]
                          ) -> List[CollectiveOp]:
    """Every collective in the compiled module, with axis + trip count."""
    comps = _split_computations(hlo)
    mults = _call_multipliers(hlo, comps)
    n_total = int(np.prod(list(mesh_axes.values()))) or 1
    out: List[CollectiveOp] = []
    for cname, body in comps.items():
        for line in body.splitlines():
            m = re.search(
                r"=\s*(\([^)]*\)|[\w\[\],\{\} ]+?)\s+"
                r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
                r"collective-permute)(?:-start)?\(", line)
            if not m:
                continue
            if re.search(r"(all-reduce|all-gather|reduce-scatter|"
                         r"all-to-all|collective-permute)-done", line):
                continue
            sig, kind = m.group(1), m.group(2)
            payload = _shape_bytes(sig)
            if kind == "all-gather":
                # output contains the gathered result; payload per device is
                # output/group_size (what this device contributes/receives
                # per ring step basis handled by factor over output bytes)
                pass
            group = _first_group(line, n_total)
            if kind == "collective-permute":
                pairs = re.search(r"source_target_pairs=\{\{(\d+),(\d+)\}",
                                  line)
                if pairs:
                    group = [int(pairs.group(1)), int(pairs.group(2))]
            if group is None or len(group) < 2:
                continue
            axes = _axes_of_group(group, mesh_axes)
            gsz = len(group) if kind != "collective-permute" else 2
            if kind == "all-gather":
                payload = payload  # sig is output shape: factor handles (n-1)/n
            out.append(CollectiveOp(kind, payload, gsz, axes,
                                    trip_count=mults.get(cname, 1),
                                    computation=cname))
    return out


# ---------------------------------------------------------------------------
# analytic FLOPs (exact per family; MACs x 2)
# ---------------------------------------------------------------------------
def _attn_flops(cfg: ModelConfig, B: int, S: int, *, window: int,
                kind: str, cache_len: int = 0) -> float:
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    T = B * S
    proj = 2 * T * d * (H + 2 * K) * hd + 2 * T * H * hd * d
    if kind == "decode":
        ctx = min(cache_len, window) if window else cache_len
        score = 2 * 2 * B * ctx * H * hd
    elif window and S > window + 512:
        # sliding-span flash executes window + q_block keys per query
        score = 2 * 2 * B * S * (window + 512) * H * hd
    else:
        eff = min(S, window) if window else S
        score = 2 * 2 * B * S * eff * H * hd / (2 if cfg.causal else 1)
    return proj + score


def _ffn_flops(cfg: ModelConfig, T: int) -> float:
    if cfg.moe is not None:
        m = cfg.moe
        mult = 6 if cfg.act in ("swiglu", "geglu") else 4
        expert = T * m.top_k * m.capacity_factor * mult * cfg.d_model * m.d_ff_expert
        router = 2 * T * cfg.d_model * m.n_experts
        shared = mult * T * cfg.d_model * m.n_shared_experts * m.d_ff_shared
        return expert + router + shared
    if cfg.d_ff == 0:
        return 0.0
    mult = 6 if cfg.act in ("swiglu", "geglu") else 4
    return mult * T * cfg.d_model * cfg.d_ff


def _ssm_flops(cfg: ModelConfig, B: int, S: int, kind: str) -> float:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    H = d_in // s.head_dim
    N, G, P_ = s.d_state, s.n_groups, s.head_dim
    Z = 2 * d_in + 2 * G * N + H
    T = B * S
    proj = 2 * T * d * Z + 2 * T * d_in * d
    if kind == "decode":
        core = 2 * 2 * B * H * N * P_
    else:
        c = s.chunk
        core = (2 * B * S * c * G * N          # C·Bᵀ within chunk
                + 2 * B * S * c * H * P_       # W·x
                + 2 * 2 * B * S * H * N * P_)  # inter-chunk read + update
    return proj + core


def _rglru_flops(cfg: ModelConfig, B: int, S: int, kind: str) -> float:
    r = cfg.rglru
    d = cfg.d_model
    w = r.lru_width or d
    T = B * S
    proj = 2 * T * d * w * 2 + 2 * T * w * d
    gates = 2 * 2 * T * w * (w // 8)
    scan = 10 * T * w
    return proj + gates + scan


def forward_flops(cfg: ModelConfig, shape: ShapeConfig, *,
                  with_logits: bool = True) -> float:
    """Exact forward FLOPs of one step of ``shape`` (per whole batch)."""
    B = shape.global_batch
    S = 1 if shape.kind == "decode" else shape.seq_len
    cache_len = shape.seq_len if shape.kind == "decode" else 0
    T = B * S
    total = 0.0
    for blk in cfg.pattern:
        if blk == ATTN:
            total += _attn_flops(cfg, B, S, window=0, kind=shape.kind,
                                 cache_len=cache_len)
        elif blk == ATTN_LOCAL:
            total += _attn_flops(cfg, B, S, window=cfg.local_window,
                                 kind=shape.kind, cache_len=cache_len)
        elif blk == SSM:
            total += _ssm_flops(cfg, B, S, shape.kind)
        elif blk == RGLRU:
            total += _rglru_flops(cfg, B, S, shape.kind)
        total += _ffn_flops(cfg, T)
    if with_logits:
        total += 2 * T * cfg.d_model * cfg.padded_vocab
    return total


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """The 6·N·D-style 'useful' figure required by the assignment:
    6 x active-params x tokens for training; 2 x for inference."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.tokens
    toks = shape.global_batch * (1 if shape.kind == "decode" else shape.seq_len)
    return 2.0 * n * toks


def analytic_hbm_bytes(cfg: ModelConfig, shape: ShapeConfig,
                       policy: PolicyConfig,
                       mesh_axes: Mapping[str, int]) -> float:
    """Per-device HBM bytes for one step under TPU-grade fusion.

    The CPU backend's ``cost_analysis()['bytes accessed']`` counts every
    unfused producer/consumer hop — a no-fusion UPPER bound.  On TPU, XLA
    fuses elementwise chains and flash tiles stay in VMEM, so the traffic
    that *must* cross HBM is (coarse, documented model):

      weights    : own shard, read per materialization (fwd, bwd, +remat),
                   x2 for the bf16 cast write (weights-stationary SPMD:
                   activations, not weights, ride the collectives)
      optimizer  : read+write p/m/v fp32 on the shard (ZeRO placement)
      activations: C_ACT passes over the (B_loc, S, d) residual stream per
                   layer (fwd writes + bwd reads + remat recompute)
      attention  : K/V read/write per pass (flash keeps scores in VMEM)
      logits     : chunked xent round-trips fp32 chunk logits once fwd +
                   once bwd on the (T_loc, V_loc) shard
      caches     : decode reads + writes the local cache slice once
    """
    n = max(1, int(np.prod(list(mesh_axes.values()))))
    tp = mesh_axes.get(policy.tp_axis or "", 1)
    dp_total = 1
    for a in policy.dp_axes:
        dp_total *= mesh_axes.get(a, 1)
    B = shape.global_batch
    # batch shards over dp only while it divides (mirrors batch_specs)
    B_loc = max(1, B // dp_total) if B % dp_total == 0 else \
        (max(1, B // mesh_axes.get("data", 1))
         if B % mesh_axes.get("data", 1) == 0 else B)
    S = 1 if shape.kind == "decode" else shape.seq_len
    T_loc = B_loc * S
    d = cfg.d_model
    L = cfg.n_layers
    V_loc = cfg.padded_vocab / tp
    N = cfg.param_count()
    N_shard = N / (n if policy.zero_stage >= 3 else tp)

    C_ACT = 16 if shape.kind == "train" else 6
    mats = {"none": 2, "block": 3, "full": 3}[policy.remat] \
        if shape.kind == "train" else 1

    w_bytes = mats * 2 * 2 * N_shard            # bf16 read + cast write
    opt_bytes = 6 * 4 * N_shard if shape.kind == "train" else 0.0
    act_bytes = C_ACT * 2 * T_loc * d * L
    # attention K/V traffic (flash: no S^2 HBM term)
    kv = 2 * cfg.n_kv_heads * cfg.head_dim
    n_attn = sum(1 for b in cfg.pattern if b in (ATTN, ATTN_LOCAL))
    attn_bytes = (3 if shape.kind == "train" else 1) * 2 * T_loc * kv * n_attn
    logits_bytes = (4 if shape.kind == "train" else 2) * 4 * T_loc * V_loc \
        if (shape.kind != "decode") else 2 * 4 * B_loc * V_loc
    cache_bytes = 0.0
    if shape.kind == "decode":
        W = shape.seq_len
        per_layer = {
            ATTN: W * kv, ATTN_LOCAL: min(W, cfg.local_window) * kv,
            SSM: 0.0, RGLRU: 0.0}
        cache_loc = sum(per_layer[b] for b in cfg.pattern) * B_loc * 2 / tp
        cache_bytes = 2 * cache_loc                     # read + write
    return (w_bytes + opt_bytes + act_bytes + attn_bytes + logits_bytes
            + cache_bytes)


def step_flops(cfg: ModelConfig, shape: ShapeConfig,
               policy: PolicyConfig) -> float:
    """Analytic FLOPs the hardware must actually execute for one step
    (fwd + bwd + remat recompute for training; fwd for inference)."""
    fwd = forward_flops(cfg, shape)
    if shape.kind != "train":
        return fwd
    mult = 3.0
    if policy.remat == "block":
        mult += 1.0          # one recomputed forward for the block interior
    elif policy.remat == "full":
        mult += 1.0
    return mult * fwd


# ---------------------------------------------------------------------------
# reports
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class CostReport:
    """Everything extracted from one compiled (arch x shape x mesh) cell."""
    arch: str
    shape: str
    mesh: Dict[str, int]
    flops_hlo: float                 # per-device, raw cost_analysis
    flops_analytic: float            # whole-step, analytic (exact)
    model_flops: float               # 6·N·D useful figure
    hbm_bytes: float                 # per-device bytes accessed (HLO)
    peak_memory: Optional[float]     # per-device bytes (memory_analysis)
    hbm_bytes_analytic: float = 0.0  # per-device, TPU-fusion model
    collectives: List[CollectiveOp] = dataclasses.field(default_factory=list)

    @property
    def n_devices(self) -> int:
        return int(np.prod(list(self.mesh.values())))

    def per_axis_wire_bytes(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for op in self.collectives:
            if not op.axes:
                continue
            # attribute to the single axis the group spans; multi-axis groups
            # are attributed to every spanned axis proportionally to (n-1)
            if len(op.axes) == 1:
                out[op.axes[0]] = out.get(op.axes[0], 0.0) + op.wire_bytes
            else:
                for a in op.axes:
                    out[a] = out.get(a, 0.0) + op.wire_bytes / len(op.axes)
        return out

    def collective_bytes_total(self) -> float:
        return sum(op.wire_bytes for op in self.collectives)


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float                  # analytic (TPU-fusion) when available
    memory_hlo_s: float              # no-fusion HLO upper bound
    collective_s: float
    per_axis_s: Dict[str, float]
    dominant: str
    model_flops: float
    hlo_flops: float
    useful_ratio: float              # model_flops / executed flops
    step_time_s: float               # max of the three terms (overlap bound)
    roofline_fraction: float         # compute_s / step_time_s

    def summary(self) -> str:
        return (f"compute={self.compute_s*1e3:.2f}ms "
                f"memory={self.memory_s*1e3:.2f}ms "
                f"collective={self.collective_s*1e3:.2f}ms "
                f"dominant={self.dominant} "
                f"frac={self.roofline_fraction:.3f} "
                f"useful={self.useful_ratio:.3f}")


def roofline(report: CostReport, system: ComposedSystem,
             chip: Optional[ChipSpec] = None) -> Roofline:
    """The three roofline terms for one compiled cell on one fabric."""
    chip = chip or system.chip
    n = report.n_devices
    flops_exec = max(report.flops_analytic,
                     report.flops_hlo * n)   # HLO figure is per device
    compute_s = flops_exec / (n * chip.peak_flops_bf16)
    memory_hlo_s = report.hbm_bytes / chip.hbm_bw   # per-device, no fusion
    memory_s = (report.hbm_bytes_analytic / chip.hbm_bw
                if report.hbm_bytes_analytic > 0 else memory_hlo_s)
    per_axis: Dict[str, float] = {}
    for axis, wire in report.per_axis_wire_bytes().items():
        if axis in system.fabric.axis_links:
            # hop-aware path price (== wire / bandwidth on 1-hop axes)
            per_axis[axis] = system.fabric.axis_time(axis, wire)
        else:
            link, hops = system.fabric.slowest_path()
            per_axis[axis] = (wire / link.bandwidth
                              + (hops - 1) * link.latency)
    collective_s = sum(per_axis.values())
    dominant = max(
        (("compute", compute_s), ("memory", memory_s),
         ("collective", collective_s)), key=lambda kv: kv[1])[0]
    step = max(compute_s, memory_s, collective_s)
    return Roofline(
        compute_s=compute_s, memory_s=memory_s, memory_hlo_s=memory_hlo_s,
        collective_s=collective_s,
        per_axis_s=per_axis, dominant=dominant,
        model_flops=report.model_flops, hlo_flops=flops_exec,
        useful_ratio=report.model_flops / max(flops_exec, 1.0),
        step_time_s=step,
        roofline_fraction=(report.model_flops / (n * chip.peak_flops_bf16))
        / max(step, 1e-30))


# ---------------------------------------------------------------------------
# measured-cost calibration
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class CalibratedCost:
    """Measured-cost layer over the analytic model.

    The analytic terms price every composition from first principles; this
    layer folds *measurements* back in, in priority order:

      1. ``cell_step_s`` — an exact measured step time for one
         (arch, shape, mesh) cell (dry-run artifact, bench run, or the
         cluster's own telemetry).  Replaces the whole step estimate.
      2. ``kernel_speedup`` — measured default/best ratios from the
         tuned-config registry (``kernels.autotune``).  Scales the
         analytic *compute* term of every workload whose block pattern
         uses that kernel family: tuned kernels execute the same FLOPs in
         measurably less time, and the scheduler/simulator should price
         that in.

    Construct explicitly (tests, benches) or via ``from_registry()`` to
    pull the speedups out of the active tuned-config registry.
    """
    cell_step_s: Dict[str, float] = dataclasses.field(default_factory=dict)
    kernel_speedup: Dict[str, float] = dataclasses.field(
        default_factory=dict)

    @staticmethod
    def cell_key(arch: str, shape_name: str, mesh_label: str) -> str:
        return f"{arch}|{shape_name}|{mesh_label}"

    @classmethod
    def from_registry(cls, registry=None) -> "CalibratedCost":
        """Speedups measured by the autotuner (empty when untuned)."""
        from repro.kernels import registry as kreg
        return cls(kernel_speedup=kreg.kernel_speedups(registry))

    def __bool__(self) -> bool:
        return bool(self.cell_step_s or self.kernel_speedup)

    # ----------------------------------------------------------- queries --
    def step_override(self, arch: str, shape_name: str,
                      mesh_label: str) -> Optional[float]:
        return self.cell_step_s.get(
            self.cell_key(arch, shape_name, mesh_label))

    def _block_speedup(self, kernel: str, kind: str) -> float:
        s = self.kernel_speedup.get(kernel, 1.0)
        if kind == "train" and kernel == "flash_attention":
            # the training path runs fwd + bwd kernels; average the
            # measured ratios when both were tuned
            s = (s + self.kernel_speedup.get("flash_attention_bwd", s)) / 2
        return max(s, 1e-9)

    def compute_scale(self, cfg: ModelConfig, shape: ShapeConfig) -> float:
        """Multiplier on the analytic compute term, FLOPs-weighted: a
        tuned kernel only accelerates the *core* FLOPs it executes
        (attention scores, SSD recurrence, RG-LRU scan) — projections,
        FFN, and logits are untouched XLA matmuls and keep weight 1.0.
        Returns scaled_flops / total_flops over the whole forward."""
        if not self.kernel_speedup:
            return 1.0
        B = shape.global_batch
        S = 1 if shape.kind == "decode" else shape.seq_len
        kind = shape.kind
        d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        total = scaled = 0.0
        for blk in cfg.pattern:
            core, s = 0.0, 1.0
            if blk in (ATTN, ATTN_LOCAL):
                w = cfg.local_window if blk == ATTN_LOCAL else 0
                cache = shape.seq_len if kind == "decode" else 0
                full = _attn_flops(cfg, B, S, window=w, kind=kind,
                                   cache_len=cache)
                proj = 2 * B * S * d * (H + 2 * K) * hd \
                    + 2 * B * S * H * hd * d
                core = max(0.0, full - proj)
                s = self._block_speedup("flash_attention", kind)
            elif blk == SSM:
                full = _ssm_flops(cfg, B, S, kind)
                sc = cfg.ssm
                d_in = sc.expand * d
                z = 2 * d_in + 2 * sc.n_groups * sc.d_state \
                    + d_in // sc.head_dim
                proj = 2 * B * S * d * z + 2 * B * S * d_in * d
                core = max(0.0, full - proj)
                s = self._block_speedup("ssd", kind)
            elif blk == RGLRU:
                full = _rglru_flops(cfg, B, S, kind)
                r = cfg.rglru
                core = min(full, 10.0 * B * S * (r.lru_width or d))
                s = self._block_speedup("rglru", kind)
            else:
                full = 0.0
            blk_total = full + _ffn_flops(cfg, B * S)
            total += blk_total
            scaled += blk_total - core + core / s
        logits = 2.0 * B * S * d * cfg.padded_vocab    # unscaled
        total += logits
        scaled += logits
        return scaled / total if total > 0 else 1.0

    def measure_cell(self, arch: str, shape_name: str, mesh_label: str,
                     step_s: float) -> None:
        """Record a measured step time (the feedback edge of the loop)."""
        self.cell_step_s[self.cell_key(arch, shape_name, mesh_label)] = \
            float(step_s)

    # -------------------------------------------------------- persistence --
    def to_json(self) -> Dict[str, Any]:
        return {"cell_step_s": dict(self.cell_step_s),
                "kernel_speedup": dict(self.kernel_speedup)}

    @classmethod
    def from_json(cls, js: Mapping[str, Any]) -> "CalibratedCost":
        return cls(cell_step_s=dict(js.get("cell_step_s", {})),
                   kernel_speedup=dict(js.get("kernel_speedup", {})))


def kv_bytes_per_token(cfg: ModelConfig, dtype_bytes: int = 2) -> float:
    """KV-cache bytes appended per token across every attention layer —
    the unit of serving KV traffic (page writes locally, cache-slice
    ownership transfers on the wire in the flash-decode layout)."""
    per_layer = 2 * cfg.n_kv_heads * cfg.head_dim * dtype_bytes
    n_attn = sum(1 for b in cfg.pattern if b in (ATTN, ATTN_LOCAL))
    return float(per_layer * n_attn)


def serving_throughput(cfg: ModelConfig, shape: ShapeConfig,
                       step_s: float) -> Dict[str, float]:
    """Token throughput and KV traffic of one decode replica stepping at
    ``step_s`` (pass a ``CalibratedCost``-priced step time to price from
    measurements).  ``tokens_per_s`` is the replica's saturated decode
    rate — ``global_batch`` sequences advance one token per step."""
    step_s = max(step_s, 1e-30)
    toks = shape.global_batch / step_s
    kv_tok = kv_bytes_per_token(cfg)
    return {
        "tokens_per_s": toks,
        "tpot_s": step_s,
        "kv_write_bytes_per_s": toks * kv_tok,
        # each decode step re-reads every sequence's history from HBM
        "kv_read_bytes_per_s": toks * kv_tok * shape.seq_len,
    }


def predict_step_time(report: CostReport, system: ComposedSystem,
                      overlap: float = 1.0) -> float:
    """Step-time prediction on a given composed fabric.

    ``overlap=1`` -> perfect compute/comm overlap (max of terms);
    ``overlap=0`` -> fully serial (sum).  The paper's DDP baseline achieves
    partial overlap; we report both bounds in the benchmarks.
    """
    r = roofline(report, system)
    serial = r.compute_s + r.memory_s + r.collective_s
    overlapped = max(r.compute_s, r.memory_s, r.collective_s)
    return overlap * overlapped + (1 - overlap) * serial


def price_on_fabrics(report: CostReport,
                     systems: Mapping[str, ComposedSystem],
                     overlap: float = 0.5) -> Dict[str, float]:
    """The paper's Fig-11 experiment: one program, many fabrics."""
    return {name: predict_step_time(report, sys_, overlap)
            for name, sys_ in systems.items()}


# ---------------------------------------------------------------------------
# extraction from a compiled executable
# ---------------------------------------------------------------------------
def extract(compiled, *, arch: str, shape_name: str,
            mesh_axes: Mapping[str, int], flops_analytic: float,
            model_fl: float, hlo_text: Optional[str] = None,
            hbm_analytic: float = 0.0) -> CostReport:
    """Build a CostReport from a ``jax`` compiled executable."""
    ca = {}
    try:
        c = compiled.cost_analysis()
        ca = c[0] if isinstance(c, (list, tuple)) else (c or {})
    except Exception:
        ca = {}
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    peak = None
    try:
        mem = compiled.memory_analysis()
        if mem is not None:
            peak = float(getattr(mem, "temp_size_in_bytes", 0) +
                         getattr(mem, "argument_size_in_bytes", 0) +
                         getattr(mem, "output_size_in_bytes", 0) -
                         getattr(mem, "alias_size_in_bytes", 0))
    except Exception:
        peak = None
    text = hlo_text
    if text is None:
        try:
            text = compiled.as_text()
        except Exception:
            text = ""
    colls = parse_hlo_collectives(text, mesh_axes) if text else []
    return CostReport(
        arch=arch, shape=shape_name, mesh=dict(mesh_axes),
        flops_hlo=flops, flops_analytic=flops_analytic,
        model_flops=model_fl, hbm_bytes=hbm, peak_memory=peak,
        hbm_bytes_analytic=hbm_analytic, collectives=colls)
