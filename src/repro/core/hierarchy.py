"""Hierarchical / compressed gradient exchange across the composed fabric.

The paper's fixed 8-GPU topology only allows flat NCCL allreduce.  At
production scale the composed fabric is *hierarchical* — fast intra-pod ICI
("local"), slow cross-pod links ("switch"/DCN) — and the right collective is
fast-domain-first:

    reduce-scatter (fast axes)  ->  all-reduce (slow axis, 1/F payload)
        ->  all-gather (fast axes)

which shrinks slow-fabric traffic by the fast-domain size F.  On top, the
slow hop can ride int8 error-feedback compression (beyond-paper; see
``repro.optim.compress``), cutting wire bytes another ~4x.

These helpers run inside a ``shard_map`` whose *manual* axes include the
slow axis (the trainer opens such a context when
``policy.hierarchical_allreduce`` or ``grad_compression`` is set); the fast
axes stay on GSPMD auto-sharding.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.optim.compress import int8_decode, int8_encode


def allreduce_flat(tree: Any, axis: str) -> Any:
    """Plain psum over the slow axis (the paper's NCCL-allreduce analogue)."""
    return jax.tree.map(lambda g: jax.lax.psum(g, axis), tree)


def allreduce_int8_ef(tree: Any, residual: Any, axis: str
                      ) -> Tuple[Any, Any]:
    """Int8 error-feedback all-reduce over ``axis``.

    For each leaf: add the carried residual, quantize to int8 against a
    globally-agreed scale (one scalar pmax), exchange int8 (all-gather —
    1 byte/elem on the wire instead of 4), sum in int32, and carry the
    local quantization error into the next step.  Returns
    (mean-reduced tree, new residual tree).
    """
    n = jax.lax.psum(1, axis)

    def leaf(g, r):
        y = g.astype(jnp.float32) + r
        q, scale = int8_encode(y, lambda m: jax.lax.pmax(m, axis))
        gathered = jax.lax.all_gather(q, axis)          # (n, ...) int8 wire
        total = jnp.sum(gathered.astype(jnp.int32), axis=0)
        out = int8_decode(total, scale) / n
        new_r = y - int8_decode(q.astype(jnp.int32), scale)
        return out.astype(g.dtype), new_r

    flat, treedef = jax.tree.flatten(tree)
    rflat = jax.tree.leaves(residual)
    outs, news = [], []
    for g, r in zip(flat, rflat):
        o, nr = leaf(g, r)
        outs.append(o)
        news.append(nr)
    return jax.tree.unflatten(treedef, outs), jax.tree.unflatten(treedef, news)


def init_residual(tree: Any) -> Any:
    """Zero error-feedback residuals matching the (sharded) grad pytree."""
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), tree)


def hierarchical_time(nbytes: float, fast_n: int, slow_n: int,
                      fast_bw: float, slow_bw: float,
                      compress: float = 1.0) -> float:
    """Analytic cost of the hierarchical exchange for ``nbytes`` of grads.

    reduce-scatter(fast) + all-gather(fast) + all-reduce(slow on 1/F payload
    x compress).  Used by the cost model / Fig-16 math.
    """
    t_fast = 2.0 * (fast_n - 1) / fast_n * nbytes / fast_bw
    shard = nbytes / max(fast_n, 1) * compress
    t_slow = 2.0 * (slow_n - 1) / slow_n * shard / slow_bw
    return t_fast + t_slow


def flat_time(nbytes: float, total_n: int, slow_bw: float) -> float:
    """Flat ring allreduce over the slowest link (the paper's baseline)."""
    return 2.0 * (total_n - 1) / total_n * nbytes / slow_bw
