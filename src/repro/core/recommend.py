"""Topology recommendation — the paper's stated future work, implemented.

    "...build a system framework that can take the input of various
     configured runs, and recommend the optimal system level topology
     for AI and HPC workloads."  (paper §VI)

Two modes:

  * **measured** — given dry-run artifacts for several compositions
    (``dryrun.py --mesh-shape ...`` outputs), rank them by predicted
    step time (max of the roofline terms).
  * **analytic** — no artifacts needed: a closed-form wire model ranks
    candidate (dp, tp) factorizations of the chip budget.  The model is
    deliberately coarse (documented term by term below) but reproduces
    the measured ordering on every cell we profiled (§Perf): it exists
    to pre-screen compositions so only the top few need a compile.

Hard feasibility constraints (each learned from a measured regression):
  * ``batch % dp == 0``        — otherwise GSPMD replicates the batch
                                 (command-r prefill at (64,4): 9 s -> 87 s);
  * per-device memory estimate — params+opt shards, activations, caches
    must fit HBM;
  * MoE: ``n_experts % tp == 0`` for the EP layout.
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os
from typing import Dict, List, Optional, Sequence, Tuple

from repro.configs import get_config
from repro.configs.base import ModelConfig, PolicyConfig, ShapeConfig, SHAPES
from repro.core import costmodel
from repro.core.costmodel import CalibratedCost
from repro.core.topology import (DEFAULT_LINKS, ChipSpec, ICI_BW, LinkClass,
                                 Topology)


@dataclasses.dataclass
class Candidate:
    shape: Tuple[int, ...]            # (dp, tp) or (pod, dp, tp)
    step_s: float                     # predicted step time
    terms: Dict[str, float]
    feasible: bool
    why: str = ""
    # bytes crossing one device's link per step, per mesh axis — the input
    # to cluster-level per-link traffic accounting (repro.cluster.telemetry)
    wire_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def label(self) -> str:
        return "x".join(str(x) for x in self.shape)


# ---------------------------------------------------------------------------
# analytic wire model (coarse, per-device seconds)
# ---------------------------------------------------------------------------
def _estimate(cfg: ModelConfig, shape: ShapeConfig, dp: int, tp: int,
              pods: int = 1, chip: ChipSpec = ChipSpec(),
              dcn_bw: float = 6.25e9,
              topology: Optional[Topology] = None,
              domain_chips: int = 0) -> Candidate:
    n = pods * dp * tp
    B = shape.global_batch
    mesh_shape = (pods, dp, tp) if pods > 1 else (dp, tp)
    dp_total = pods * dp

    # -------- feasibility --------
    if B % dp_total:
        return Candidate(mesh_shape, float("inf"), {}, False,
                         f"batch {B} % dp {dp_total} != 0")
    if cfg.moe is not None and tp > 1 and cfg.moe.n_experts % tp:
        return Candidate(mesh_shape, float("inf"), {}, False,
                         f"experts {cfg.moe.n_experts} % tp {tp} != 0")

    P = cfg.param_count()
    serve = shape.kind != "train"
    pbytes = 2 if serve else 4
    # params per device: serve = TP-only; train = ZeRO-3 over dp x tp
    p_dev = P * pbytes / (tp if serve else n)
    opt_dev = 0 if serve else 2 * P * 4 / n
    S = 1 if shape.kind == "decode" else shape.seq_len
    T_loc = (B // dp_total) * S
    act_dev = 4 * T_loc * cfg.d_model * 2 * (2 if shape.kind == "train"
                                             else 1)
    kv = 2 * cfg.n_kv_heads * cfg.head_dim
    n_attn = sum(1 for b in cfg.pattern if b == "attn")
    cache_dev = (shape.seq_len * kv * n_attn * (B // dp_total) * 2 / tp
                 if shape.kind == "decode" else 0)
    mem = p_dev + opt_dev + act_dev + cache_dev
    if mem > chip.hbm_bytes * 0.95:
        return Candidate(mesh_shape, float("inf"), {}, False,
                         f"memory {mem/2**30:.1f} GiB > HBM")

    # -------- terms --------
    flops = costmodel.step_flops(cfg, shape, PolicyConfig())
    compute = flops / (n * chip.peak_flops_bf16)
    memory = costmodel.analytic_hbm_bytes(
        cfg, shape, PolicyConfig(
            dp_axes=("pod", "data") if pods > 1 else ("data",)),
        dict(zip(("pod", "data", "model") if pods > 1 else
                 ("data", "model"), mesh_shape))) / chip.hbm_bw

    passes = 3 if shape.kind == "train" else 1
    wire_dp = wire_tp = pod_wire = 0.0
    if shape.kind == "train":
        # ZeRO-3 param gathers (bf16 on the wire) + grad reduce
        wire_dp += passes * (n - 1) / n * P * 2
        wire_dp += 2 * (dp - 1) / dp * P * 2
    # row-parallel / EP activation reductions over tp per layer
    if tp > 1:
        n_red = 2 * cfg.n_layers * (3 if shape.kind == "train" else 1)
        wire_tp += n_red * 2 * (tp - 1) / tp * T_loc * cfg.d_model * 2
    coll = (wire_dp + wire_tp) / ICI_BW
    if topology is not None and domain_chips > 0:
        # multi-tier admission hint: a candidate whose per-pod mesh
        # cannot fit one drawer (``domain_chips`` chips) must span the
        # composed fabric — derate its collective term by the topology's
        # cross-drawer bandwidth scale and charge the extra hop latency,
        # so admission ranks drawer-sized candidates above spanning ones
        # *before* placement.  The flat single-switch topology passes no
        # hint (scale 1, 1 hop) and prices exactly the legacy estimate.
        n_local = dp * tp
        n_dom = -(-n_local // domain_chips)       # drawers spanned
        if n_dom > 1:
            span = n_dom - 1
            flows = min(domain_chips, n_local)
            scale = topology.bw_scale(LinkClass.SWITCH, span, flows)
            hops = topology.hops(LinkClass.SWITCH, span)
            coll = ((wire_dp + wire_tp) / (ICI_BW * max(scale, 1e-9))
                    + (hops - 1) * DEFAULT_LINKS[LinkClass.SWITCH].latency)
    if pods > 1 and shape.kind == "train":
        pod_wire = 2 * (pods - 1) / pods * P * 2 / dp   # hierarchical
        coll += pod_wire / dcn_bw

    step = max(compute, memory, coll)
    wire = {"data": wire_dp, "model": wire_tp}
    if pods > 1:
        wire["pod"] = pod_wire
    return Candidate(mesh_shape, step,
                     {"compute": compute, "memory": memory,
                      "collective": coll}, True, wire_bytes=wire)


# ---------------------------------------------------------------------------
# measured-cost calibration hook
# ---------------------------------------------------------------------------
_calibration: Optional[CalibratedCost] = None


def set_calibration(cal: Optional[CalibratedCost]) -> None:
    """Install a process-wide CalibratedCost; every ranking that is not
    handed an explicit one (recommend, scheduler admission, cluster
    simulator pricing) will layer it over the analytic terms."""
    global _calibration
    _calibration = cal


def get_calibration() -> Optional[CalibratedCost]:
    return _calibration


def calibrate_candidate(cand: Candidate, cfg: ModelConfig, arch: str,
                        shape_name: str, shape: ShapeConfig,
                        cal: Optional[CalibratedCost]) -> Candidate:
    """Re-price one analytic candidate from measurements (no-op without
    a calibration layer or for infeasible candidates)."""
    if cal is None or not cal or not cand.feasible:
        return cand
    measured = cal.step_override(arch, shape_name, cand.label)
    terms = dict(cand.terms)
    if measured is not None:
        terms["measured"] = measured
        return dataclasses.replace(cand, step_s=measured, terms=terms)
    scale = cal.compute_scale(cfg, shape)
    if scale == 1.0:
        return cand
    terms["compute"] = terms.get("compute", 0.0) * scale
    step = max(terms.get("compute", 0.0), terms.get("memory", 0.0),
               terms.get("collective", 0.0))
    return dataclasses.replace(cand, step_s=step, terms=terms)


def candidates(n_chips: int = 256, pods: int = 1
               ) -> List[Tuple[int, int]]:
    out = []
    tp = 1
    while tp <= n_chips:
        if n_chips % tp == 0:
            out.append((n_chips // tp, tp))
        tp *= 2
    return out


def recommend(arch: str, shape_name: str, *, n_chips: int = 256,
              pods: int = 1, top: int = 3,
              calibration: Optional[CalibratedCost] = None,
              topology: Optional[Topology] = None,
              domain_chips: int = 0) -> List[Candidate]:
    """Analytic ranking of compositions for one workload.

    When a ``calibration`` layer is supplied (or installed process-wide
    via ``set_calibration``) the analytic terms are re-priced from
    measurements before ranking — measured cells override the whole step,
    tuned-kernel speedups scale the compute term.  ``topology`` +
    ``domain_chips`` (chips per drawer) apply the multi-tier admission
    derate to candidates that must span drawers.
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    cal = calibration if calibration is not None else get_calibration()
    cands = [calibrate_candidate(
                 _estimate(cfg, shape, dp, tp, pods,
                           topology=topology, domain_chips=domain_chips),
                 cfg, arch, shape_name, shape, cal)
             for dp, tp in candidates(n_chips, pods)]
    cands.sort(key=lambda c: c.step_s)
    return cands[:top]


def recommend_from_measurements(results_dirs: Sequence[str], arch: str,
                                shape_name: str) -> Optional[Candidate]:
    """Best measured composition among available dry-run artifacts."""
    best: Optional[Candidate] = None
    for d in results_dirs:
        for path in glob.glob(os.path.join(d, f"{arch}__{shape_name}__*.json")):
            with open(path) as f:
                js = json.load(f)
            rl = js["roofline"]
            c = Candidate(tuple(js["mesh"].values()), rl["step_time_s"],
                          {"compute": rl["compute_s"],
                           "memory": rl["memory_s"],
                           "collective": rl["collective_s"]}, True,
                          why=path)
            if best is None or c.step_s < best.step_s:
                best = c
    return best
