"""Composable-fabric topology model (the Falcon-4016 analogue, TPU-native).

The paper's object of study is a *pool* of devices behind a switching fabric
with heterogeneous link classes (NVLink local vs PCIe-switch "falcon" links,
Table IV).  On TPU the same object is a fleet of chips joined by link classes
of very different bandwidth:

  * ``LOCAL``    — intra-pod ICI (the NVLink analogue)
  * ``SWITCH``   — optically-switched / cross-drawer ICI at the paper's
                   measured falcon-to-falcon ratio (the Falcon PCIe analogue)
  * ``HOST``     — chip <-> host staging (the falcon-to-local ratio)
  * ``DCN``      — data-center network between pods

This module is pure data + arithmetic (no jax device state): it defines the
link classes, the device pool, and the ``FabricSpec`` that ``compose.py``
turns into logical meshes.  All bandwidth constants derive from the v5e
hardware targets given for this project, scaled by the paper's measured
Table IV ratios so the *relative* fabric economics of the paper carry over.
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import Dict, FrozenSet, List, Mapping, Optional, Sequence, Tuple


# ---------------------------------------------------------------------------
# Hardware constants (TPU v5e targets for this project)
# ---------------------------------------------------------------------------
PEAK_FLOPS_BF16 = 197e12          # FLOP/s per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link, intra-pod (LOCAL class)

# Paper Table IV (GB/s bidirectional): L-L 72.37, F-L 19.64, F-F 24.47.
# We carry the measured *ratios* onto the TPU link classes.
PAPER_LL_BW = 72.37
PAPER_FL_BW = 19.64
PAPER_FF_BW = 24.47

SWITCH_RATIO = PAPER_FF_BW / PAPER_LL_BW       # ~0.338
HOST_RATIO = PAPER_FL_BW / PAPER_LL_BW         # ~0.271

# Paper Table IV P2P write latency (us): L-L 1.85, F-L 2.66, F-F 2.08.
PAPER_LL_LAT = 1.85e-6
PAPER_FL_LAT = 2.66e-6
PAPER_FF_LAT = 2.08e-6


class LinkClass(str, enum.Enum):
    """A class of interconnect with fixed bandwidth/latency character."""
    LOCAL = "local"        # intra-pod ICI          (paper: NVLink L-L)
    SWITCH = "switch"      # switched/composed ICI  (paper: Falcon F-F)
    HOST = "host"          # chip<->host staging    (paper: F-L)
    DCN = "dcn"            # cross-pod network


@dataclasses.dataclass(frozen=True)
class LinkSpec:
    """Bandwidth/latency of one link class (per chip, per direction)."""
    cls: LinkClass
    bandwidth: float               # bytes/s per chip on this fabric
    latency: float                 # seconds, per hop

    def time(self, nbytes: float, hops: int = 1) -> float:
        return nbytes / self.bandwidth + hops * self.latency


# Default link table: LOCAL carries full ICI speed; SWITCH/HOST carry the
# paper's measured fabric ratios; DCN is the conventional 6.25 GB/s/chip
# cross-pod figure.
DEFAULT_LINKS: Dict[LinkClass, LinkSpec] = {
    LinkClass.LOCAL: LinkSpec(LinkClass.LOCAL, ICI_BW, PAPER_LL_LAT),
    LinkClass.SWITCH: LinkSpec(LinkClass.SWITCH, ICI_BW * SWITCH_RATIO,
                               PAPER_FF_LAT),
    LinkClass.HOST: LinkSpec(LinkClass.HOST, ICI_BW * HOST_RATIO,
                             PAPER_FL_LAT),
    LinkClass.DCN: LinkSpec(LinkClass.DCN, 6.25e9, 10e-6),
}


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    """Compute/memory character of one accelerator chip."""
    name: str = "tpu-v5e"
    peak_flops_bf16: float = PEAK_FLOPS_BF16
    hbm_bytes: float = 16e9
    hbm_bw: float = HBM_BW
    vmem_bytes: float = 128 * 2 ** 20


def partitioned_bw(device_bw: float, link: LinkSpec,
                   n_lessees: int = 1) -> float:
    """Per-lessee storage bandwidth: the device's sustained rate, capped
    by its attach fabric, split equally across concurrent lessees.  The
    single sharing formula used by ``StorageSpec``, ``StorageTranche``
    (repro.data.storage) and ``StorageModel`` (repro.data.pipeline)."""
    return min(device_bw, link.bandwidth) / max(1, n_lessees)


@dataclasses.dataclass(frozen=True)
class StorageSpec:
    """A storage tier (the paper's local vs falcon-attached NVMe)."""
    name: str
    read_bw: float                 # bytes/s sustained sequential read
    attach: LinkClass              # which fabric it sits behind

    def effective_read_bw(self, links: Mapping[LinkClass, LinkSpec]) -> float:
        """Read bandwidth after the attach fabric's ceiling."""
        return partitioned_bw(self.read_bw, links[self.attach])


# NVMe constants: 4TB enterprise NVMe ~3.2 GB/s sequential read (paper's
# Intel SSDPEDKX040T7 class device).
LOCAL_NVME = StorageSpec("local-nvme", 3.2e9, LinkClass.LOCAL)
SWITCH_NVME = StorageSpec("falcon-nvme", 3.2e9, LinkClass.SWITCH)


# ---------------------------------------------------------------------------
# Device pool (what the management plane owns)
# ---------------------------------------------------------------------------
class LeaseError(RuntimeError):
    """A device was claimed while already leased (exclusive-claim violation)."""


@dataclasses.dataclass(frozen=True)
class Device:
    """One poolable accelerator.

    ``fabric``: which link class connects it to its neighbours in the same
    domain.  ``domain``: failure/locality domain id (a "drawer" / pod slice);
    devices in the same domain talk over ``fabric``; devices in different
    domains talk over the slower of the two fabrics (or DCN across pods).
    """
    uid: int
    fabric: LinkClass
    domain: int
    healthy: bool = True
    chip: ChipSpec = ChipSpec()


# ---------------------------------------------------------------------------
# Topology: how the link classes are physically wired
# ---------------------------------------------------------------------------
def link_class_between(a: Device, b: Device,
                       links: Optional[Mapping[LinkClass, LinkSpec]] = None
                       ) -> LinkClass:
    """Canonical Table IV link-class lookup for one device pair.

    Same domain + same fabric rides the fabric itself; mixed fabrics
    within a domain cross the host root complex (F-L).  The composable
    switch physically spans drawers, so cross-domain SWITCH stays on the
    switch fabric; local ICI does not span drawers, so cross-domain
    LOCAL rides the DCN.  A pair that crosses *both* the host complex
    and the pod boundary traverses the two paths in series and is priced
    at the slower of HOST and DCN — cross-domain traffic that leaves the
    composed fabric can never be priced faster than the inter-pod
    network.  (The pre-topology lookup returned HOST for cross-domain
    mixed-fabric pairs, pricing them ~3x faster than the DCN.)
    """
    tbl = links if links is not None else DEFAULT_LINKS
    if a.domain == b.domain:
        return a.fabric if a.fabric == b.fabric else LinkClass.HOST
    if a.fabric != b.fabric:
        return min((LinkClass.HOST, LinkClass.DCN),
                   key=lambda c: tbl[c].bandwidth)
    return a.fabric if a.fabric == LinkClass.SWITCH else LinkClass.DCN


@dataclasses.dataclass(frozen=True)
class Topology:
    """How the pool's link classes are physically wired.

    The base class *is* the flat ``single_switch`` fabric this model has
    always priced: every path is one traversal of the link class the
    Table IV lookup assigns, at that link's full bandwidth.  Subclasses
    (``repro.core.fabrics``) override the two wiring hooks to model
    multi-tier fabrics:

      * ``hops(cls, span)``      — switch traversals for a path whose
        endpoints are ``span`` domain ids apart (0 = same drawer);
        pricing charges ``(hops - 1)`` *extra* hops of link latency so a
        1-hop path is exactly the legacy cost.
      * ``bw_scale(cls, span, flows)`` — bandwidth derate (<= 1.0) for
        that path when ``flows`` chips in one drawer drive it
        concurrently (oversubscribed uplinks, cascade taper).
    """
    name: str = "single_switch"

    # ------------------------------------------------- wiring hooks ------
    def hops(self, cls: LinkClass, span: int) -> int:
        return 1

    def bw_scale(self, cls: LinkClass, span: int, flows: int = 1) -> float:
        return 1.0

    # ---------------------------------------------- path resolution ------
    @staticmethod
    def effective(link: LinkSpec, scale: float) -> LinkSpec:
        """``link`` derated to ``scale`` of its bandwidth (1.0 = as-is)."""
        if scale >= 1.0:
            return link
        return dataclasses.replace(link, bandwidth=link.bandwidth * scale)

    def path(self, links: Mapping[LinkClass, LinkSpec], a: Device,
             b: Device) -> Tuple[LinkSpec, int]:
        """Effective ``(link, hops)`` for traffic a<->b; feed the hop
        count to ``LinkSpec.time(nbytes, hops)``."""
        cls = link_class_between(a, b, links)
        span = abs(a.domain - b.domain)
        link = self.effective(links[cls], self.bw_scale(cls, span))
        return link, self.hops(cls, span)


SINGLE_SWITCH = Topology()


@dataclasses.dataclass
class DevicePool:
    """The pool of composable devices + storage (the chassis inventory).

    The pool is mutable: devices can fail (``mark_failed``), be repaired,
    attached or detached — ``compose.py`` snapshots the healthy set when
    building a ``ComposedSystem``.

    Leases make composition *exclusive*: ``compose()`` claims its devices
    under the composition's name, so two concurrent systems can never hold
    the same chip (the control plane's invariant; see ``repro.cluster``).
    ``leases`` maps device uid -> holder name.
    """
    devices: List[Device]
    storage: List[StorageSpec] = dataclasses.field(
        default_factory=lambda: [LOCAL_NVME, SWITCH_NVME])
    links: Dict[LinkClass, LinkSpec] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_LINKS))
    leases: Dict[int, str] = dataclasses.field(default_factory=dict)
    # how the link classes are wired (None = the flat single-switch model)
    topology: Optional[Topology] = None

    @property
    def topo(self) -> Topology:
        return self.topology if self.topology is not None else SINGLE_SWITCH

    # ------------------------------------------------------------- query --
    def healthy(self) -> List[Device]:
        return [d for d in self.devices if d.healthy]

    def available(self) -> List[Device]:
        """Healthy devices not claimed by any lease (composable right now)."""
        return [d for d in self.devices
                if d.healthy and d.uid not in self.leases]

    # ------------------------------------------------------------- lease --
    def lease(self, uids: Sequence[int], holder: str) -> None:
        """Exclusively claim ``uids`` for ``holder``.

        Atomic: either every uid is claimed or none is.  A uid already held
        (by anyone, including ``holder`` itself — leases don't stack) raises
        ``LeaseError``, as does a duplicated uid within the claim (one chip
        cannot back two mesh slots).
        """
        if len(set(uids)) != len(uids):
            dups = sorted({u for u in uids if list(uids).count(u) > 1})
            raise LeaseError(
                f"holder {holder!r} claims duplicate uid(s) {dups[:8]}")
        taken = [u for u in uids if u in self.leases]
        if taken:
            owners = sorted({self.leases[u] for u in taken})
            raise LeaseError(
                f"{len(taken)} device(s) already leased (by {owners}); "
                f"holder {holder!r} cannot claim {sorted(taken)[:8]}...")
        for u in uids:
            self.leases[u] = holder

    def release(self, uids: Sequence[int]) -> None:
        """Release leases on ``uids`` (idempotent)."""
        for u in uids:
            self.leases.pop(u, None)

    def release_holder(self, holder: str) -> List[int]:
        """Release every lease held by ``holder``; returns the freed uids."""
        freed = [u for u, h in self.leases.items() if h == holder]
        for u in freed:
            del self.leases[u]
        return freed

    def leased_by(self, holder: str) -> List[int]:
        return [u for u, h in self.leases.items() if h == holder]

    def by_fabric(self, cls: LinkClass) -> List[Device]:
        return [d for d in self.healthy() if d.fabric == cls]

    def domains(self) -> Dict[int, List[Device]]:
        out: Dict[int, List[Device]] = {}
        for d in self.healthy():
            out.setdefault(d.domain, []).append(d)
        return out

    # ----------------------------------------------------------- mutate ---
    def mark_failed(self, uids: Sequence[int]) -> None:
        bad = set(uids)
        self.devices = [
            dataclasses.replace(d, healthy=False) if d.uid in bad else d
            for d in self.devices]

    def repair(self, uids: Sequence[int]) -> None:
        good = set(uids)
        self.devices = [
            dataclasses.replace(d, healthy=True) if d.uid in good else d
            for d in self.devices]

    def attach(self, n: int, fabric: LinkClass, domain: int) -> List[int]:
        """Hot-add ``n`` devices on ``fabric`` (paper: attach resource)."""
        start = max((d.uid for d in self.devices), default=-1) + 1
        new = [Device(start + i, fabric, domain) for i in range(n)]
        self.devices.extend(new)
        return [d.uid for d in new]

    def detach(self, uids: Sequence[int]) -> None:
        drop = set(uids)
        self.devices = [d for d in self.devices if d.uid not in drop]
        for u in drop:
            self.leases.pop(u, None)

    # ------------------------------------------------------------ fabric --
    def path(self, a: Device, b: Device) -> Tuple[LinkSpec, int]:
        """Effective ``(link, hops)`` for traffic a<->b under the pool's
        topology — the hop-count-aware form of ``link_between``."""
        return self.topo.path(self.links, a, b)

    def link_between(self, a: Device, b: Device) -> LinkSpec:
        """Effective link for traffic a<->b (the Table IV lookup)."""
        return self.path(a, b)[0]


def _split_across(n: int, pods: int) -> List[int]:
    """``n`` devices over ``pods`` domains, remainder on the leading pods
    (so every device the caller asked for is actually built)."""
    base, extra = divmod(n, pods)
    return [base + (1 if p < extra else 0) for p in range(pods)]


def make_pool(n_local: int = 256, n_switch: int = 256,
              pods: int = 2,
              topology: Optional[Topology] = None) -> DevicePool:
    """Build the production pool: ``pods`` domains of local-fabric chips plus
    an equal tranche of switch-attached (composable) chips.

    The single-pod production mesh (16x16=256) draws from one local domain;
    the multi-pod mesh (2x16x16=512) spans two domains over the DCN/pod axis
    — the TPU rendering of "host + falcon drawers".  Counts that do not
    divide over ``pods`` spread the remainder across the leading pods (the
    old build silently dropped up to ``pods - 1`` devices per fabric).
    """
    devs: List[Device] = []
    uid = itertools.count()
    for p, cnt in enumerate(_split_across(n_local, pods)):
        devs += [Device(next(uid), LinkClass.LOCAL, p) for _ in range(cnt)]
    for p, cnt in enumerate(_split_across(n_switch, pods)):
        devs += [Device(next(uid), LinkClass.SWITCH, p) for _ in range(cnt)]
    assert len(devs) == n_local + n_switch, \
        f"pool built {len(devs)} devices; requested {n_local + n_switch}"
    return DevicePool(devs, topology=topology)


# ---------------------------------------------------------------------------
# FabricSpec: the axis -> link-class map of a composed mesh
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class AxisPath:
    """Resolved path for one mesh axis: the link class it rides, the
    switch traversals one message crosses, and the bandwidth derate the
    pool's topology imposes on that span (1.0 = full link speed)."""
    link: LinkClass
    hops: int = 1
    bw_scale: float = 1.0


@dataclasses.dataclass(frozen=True)
class FabricSpec:
    """Which link class each logical mesh axis rides on.

    This is the heart of the paper's experiment: the *same* program priced
    on different fabrics.  ``axis_links["data"] = LinkClass.SWITCH`` is the
    falconGPUs configuration; ``LOCAL`` everywhere is localGPUs.

    ``axis_hops``/``axis_bw_scale`` carry the pool topology's path
    resolution (``repro.core.fabrics``): axes absent from either map ride
    one full-speed hop, so a spec built without them prices exactly the
    flat single-switch fabric.
    """
    axis_links: Mapping[str, LinkClass]
    links: Mapping[LinkClass, LinkSpec] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_LINKS))
    storage: StorageSpec = LOCAL_NVME
    axis_hops: Mapping[str, int] = dataclasses.field(default_factory=dict)
    axis_bw_scale: Mapping[str, float] = dataclasses.field(
        default_factory=dict)

    def bandwidth(self, axis: str) -> float:
        return (self.links[self.axis_links[axis]].bandwidth
                * self.axis_bw_scale.get(axis, 1.0))

    def latency(self, axis: str) -> float:
        return self.links[self.axis_links[axis]].latency

    def hops(self, axis: str) -> int:
        return self.axis_hops.get(axis, 1)

    def link(self, axis: str) -> LinkSpec:
        return Topology.effective(self.links[self.axis_links[axis]],
                                  self.axis_bw_scale.get(axis, 1.0))

    def axis_time(self, axis: str, nbytes: float) -> float:
        """Wire time for ``nbytes`` on ``axis``: derated bandwidth plus
        one link latency per hop *beyond the first*, so a 1-hop
        full-speed axis prices exactly ``nbytes / bandwidth``."""
        return (nbytes / self.bandwidth(axis)
                + (self.hops(axis) - 1) * self.latency(axis))

    def with_axis(self, axis: str, cls: LinkClass) -> "FabricSpec":
        m = dict(self.axis_links)
        m[axis] = cls
        return dataclasses.replace(self, axis_links=m)

    def slowest(self) -> LinkSpec:
        return min((self.link(a) for a in self.axis_links),
                   key=lambda l: l.bandwidth)

    def slowest_path(self) -> Tuple[LinkSpec, int]:
        """Worst axis's effective ``(link, hops)`` — the conservative
        price for traffic not attributed to a specific axis."""
        axis = min(self.axis_links, key=lambda a: self.bandwidth(a))
        return self.link(axis), self.hops(axis)
