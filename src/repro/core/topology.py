"""Composable-fabric topology model (the Falcon-4016 analogue, TPU-native).

The paper's object of study is a *pool* of devices behind a switching fabric
with heterogeneous link classes (NVLink local vs PCIe-switch "falcon" links,
Table IV).  On TPU the same object is a fleet of chips joined by link classes
of very different bandwidth:

  * ``LOCAL``    — intra-pod ICI (the NVLink analogue)
  * ``SWITCH``   — optically-switched / cross-drawer ICI at the paper's
                   measured falcon-to-falcon ratio (the Falcon PCIe analogue)
  * ``HOST``     — chip <-> host staging (the falcon-to-local ratio)
  * ``DCN``      — data-center network between pods

This module is pure data + arithmetic (no jax device state): it defines the
link classes, the device pool, and the ``FabricSpec`` that ``compose.py``
turns into logical meshes.  All bandwidth constants derive from the v5e
hardware targets given for this project, scaled by the paper's measured
Table IV ratios so the *relative* fabric economics of the paper carry over.
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import Dict, FrozenSet, List, Mapping, Optional, Sequence, Tuple


# ---------------------------------------------------------------------------
# Hardware constants (TPU v5e targets for this project)
# ---------------------------------------------------------------------------
PEAK_FLOPS_BF16 = 197e12          # FLOP/s per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link, intra-pod (LOCAL class)

# Paper Table IV (GB/s bidirectional): L-L 72.37, F-L 19.64, F-F 24.47.
# We carry the measured *ratios* onto the TPU link classes.
PAPER_LL_BW = 72.37
PAPER_FL_BW = 19.64
PAPER_FF_BW = 24.47

SWITCH_RATIO = PAPER_FF_BW / PAPER_LL_BW       # ~0.338
HOST_RATIO = PAPER_FL_BW / PAPER_LL_BW         # ~0.271

# Paper Table IV P2P write latency (us): L-L 1.85, F-L 2.66, F-F 2.08.
PAPER_LL_LAT = 1.85e-6
PAPER_FL_LAT = 2.66e-6
PAPER_FF_LAT = 2.08e-6


class LinkClass(str, enum.Enum):
    """A class of interconnect with fixed bandwidth/latency character."""
    LOCAL = "local"        # intra-pod ICI          (paper: NVLink L-L)
    SWITCH = "switch"      # switched/composed ICI  (paper: Falcon F-F)
    HOST = "host"          # chip<->host staging    (paper: F-L)
    DCN = "dcn"            # cross-pod network


@dataclasses.dataclass(frozen=True)
class LinkSpec:
    """Bandwidth/latency of one link class (per chip, per direction)."""
    cls: LinkClass
    bandwidth: float               # bytes/s per chip on this fabric
    latency: float                 # seconds, per hop

    def time(self, nbytes: float, hops: int = 1) -> float:
        return nbytes / self.bandwidth + hops * self.latency


# Default link table: LOCAL carries full ICI speed; SWITCH/HOST carry the
# paper's measured fabric ratios; DCN is the conventional 6.25 GB/s/chip
# cross-pod figure.
DEFAULT_LINKS: Dict[LinkClass, LinkSpec] = {
    LinkClass.LOCAL: LinkSpec(LinkClass.LOCAL, ICI_BW, PAPER_LL_LAT),
    LinkClass.SWITCH: LinkSpec(LinkClass.SWITCH, ICI_BW * SWITCH_RATIO,
                               PAPER_FF_LAT),
    LinkClass.HOST: LinkSpec(LinkClass.HOST, ICI_BW * HOST_RATIO,
                             PAPER_FL_LAT),
    LinkClass.DCN: LinkSpec(LinkClass.DCN, 6.25e9, 10e-6),
}


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    """Compute/memory character of one accelerator chip."""
    name: str = "tpu-v5e"
    peak_flops_bf16: float = PEAK_FLOPS_BF16
    hbm_bytes: float = 16e9
    hbm_bw: float = HBM_BW
    vmem_bytes: float = 128 * 2 ** 20


def partitioned_bw(device_bw: float, link: LinkSpec,
                   n_lessees: int = 1) -> float:
    """Per-lessee storage bandwidth: the device's sustained rate, capped
    by its attach fabric, split equally across concurrent lessees.  The
    single sharing formula used by ``StorageSpec``, ``StorageTranche``
    (repro.data.storage) and ``StorageModel`` (repro.data.pipeline)."""
    return min(device_bw, link.bandwidth) / max(1, n_lessees)


@dataclasses.dataclass(frozen=True)
class StorageSpec:
    """A storage tier (the paper's local vs falcon-attached NVMe)."""
    name: str
    read_bw: float                 # bytes/s sustained sequential read
    attach: LinkClass              # which fabric it sits behind

    def effective_read_bw(self, links: Mapping[LinkClass, LinkSpec]) -> float:
        """Read bandwidth after the attach fabric's ceiling."""
        return partitioned_bw(self.read_bw, links[self.attach])


# NVMe constants: 4TB enterprise NVMe ~3.2 GB/s sequential read (paper's
# Intel SSDPEDKX040T7 class device).
LOCAL_NVME = StorageSpec("local-nvme", 3.2e9, LinkClass.LOCAL)
SWITCH_NVME = StorageSpec("falcon-nvme", 3.2e9, LinkClass.SWITCH)


# ---------------------------------------------------------------------------
# Device pool (what the management plane owns)
# ---------------------------------------------------------------------------
class LeaseError(RuntimeError):
    """A device was claimed while already leased (exclusive-claim violation)."""


@dataclasses.dataclass(frozen=True)
class Device:
    """One poolable accelerator.

    ``fabric``: which link class connects it to its neighbours in the same
    domain.  ``domain``: failure/locality domain id (a "drawer" / pod slice);
    devices in the same domain talk over ``fabric``; devices in different
    domains talk over the slower of the two fabrics (or DCN across pods).
    """
    uid: int
    fabric: LinkClass
    domain: int
    healthy: bool = True
    chip: ChipSpec = ChipSpec()


@dataclasses.dataclass
class DevicePool:
    """The pool of composable devices + storage (the chassis inventory).

    The pool is mutable: devices can fail (``mark_failed``), be repaired,
    attached or detached — ``compose.py`` snapshots the healthy set when
    building a ``ComposedSystem``.

    Leases make composition *exclusive*: ``compose()`` claims its devices
    under the composition's name, so two concurrent systems can never hold
    the same chip (the control plane's invariant; see ``repro.cluster``).
    ``leases`` maps device uid -> holder name.
    """
    devices: List[Device]
    storage: List[StorageSpec] = dataclasses.field(
        default_factory=lambda: [LOCAL_NVME, SWITCH_NVME])
    links: Dict[LinkClass, LinkSpec] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_LINKS))
    leases: Dict[int, str] = dataclasses.field(default_factory=dict)

    # ------------------------------------------------------------- query --
    def healthy(self) -> List[Device]:
        return [d for d in self.devices if d.healthy]

    def available(self) -> List[Device]:
        """Healthy devices not claimed by any lease (composable right now)."""
        return [d for d in self.devices
                if d.healthy and d.uid not in self.leases]

    # ------------------------------------------------------------- lease --
    def lease(self, uids: Sequence[int], holder: str) -> None:
        """Exclusively claim ``uids`` for ``holder``.

        Atomic: either every uid is claimed or none is.  A uid already held
        (by anyone, including ``holder`` itself — leases don't stack) raises
        ``LeaseError``, as does a duplicated uid within the claim (one chip
        cannot back two mesh slots).
        """
        if len(set(uids)) != len(uids):
            dups = sorted({u for u in uids if list(uids).count(u) > 1})
            raise LeaseError(
                f"holder {holder!r} claims duplicate uid(s) {dups[:8]}")
        taken = [u for u in uids if u in self.leases]
        if taken:
            owners = sorted({self.leases[u] for u in taken})
            raise LeaseError(
                f"{len(taken)} device(s) already leased (by {owners}); "
                f"holder {holder!r} cannot claim {sorted(taken)[:8]}...")
        for u in uids:
            self.leases[u] = holder

    def release(self, uids: Sequence[int]) -> None:
        """Release leases on ``uids`` (idempotent)."""
        for u in uids:
            self.leases.pop(u, None)

    def release_holder(self, holder: str) -> List[int]:
        """Release every lease held by ``holder``; returns the freed uids."""
        freed = [u for u, h in self.leases.items() if h == holder]
        for u in freed:
            del self.leases[u]
        return freed

    def leased_by(self, holder: str) -> List[int]:
        return [u for u, h in self.leases.items() if h == holder]

    def by_fabric(self, cls: LinkClass) -> List[Device]:
        return [d for d in self.healthy() if d.fabric == cls]

    def domains(self) -> Dict[int, List[Device]]:
        out: Dict[int, List[Device]] = {}
        for d in self.healthy():
            out.setdefault(d.domain, []).append(d)
        return out

    # ----------------------------------------------------------- mutate ---
    def mark_failed(self, uids: Sequence[int]) -> None:
        bad = set(uids)
        self.devices = [
            dataclasses.replace(d, healthy=False) if d.uid in bad else d
            for d in self.devices]

    def repair(self, uids: Sequence[int]) -> None:
        good = set(uids)
        self.devices = [
            dataclasses.replace(d, healthy=True) if d.uid in good else d
            for d in self.devices]

    def attach(self, n: int, fabric: LinkClass, domain: int) -> List[int]:
        """Hot-add ``n`` devices on ``fabric`` (paper: attach resource)."""
        start = max((d.uid for d in self.devices), default=-1) + 1
        new = [Device(start + i, fabric, domain) for i in range(n)]
        self.devices.extend(new)
        return [d.uid for d in new]

    def detach(self, uids: Sequence[int]) -> None:
        drop = set(uids)
        self.devices = [d for d in self.devices if d.uid not in drop]
        for u in drop:
            self.leases.pop(u, None)

    # ------------------------------------------------------------ fabric --
    def link_between(self, a: Device, b: Device) -> LinkSpec:
        """Effective link for traffic a<->b (the Table IV lookup)."""
        if a.domain == b.domain and a.fabric == b.fabric:
            return self.links[a.fabric]
        if a.fabric != b.fabric:
            # crossing fabrics goes through the host root complex (F-L)
            return self.links[LinkClass.HOST]
        # same fabric, different domain: pod boundary -> DCN
        return self.links[LinkClass.DCN]


def make_pool(n_local: int = 256, n_switch: int = 256,
              pods: int = 2) -> DevicePool:
    """Build the production pool: ``pods`` domains of local-fabric chips plus
    an equal tranche of switch-attached (composable) chips.

    The single-pod production mesh (16x16=256) draws from one local domain;
    the multi-pod mesh (2x16x16=512) spans two domains over the DCN/pod axis
    — the TPU rendering of "host + falcon drawers".
    """
    devs: List[Device] = []
    uid = itertools.count()
    per_pod = n_local // pods
    for p in range(pods):
        devs += [Device(next(uid), LinkClass.LOCAL, p)
                 for _ in range(per_pod)]
    per_pod_sw = n_switch // pods
    for p in range(pods):
        devs += [Device(next(uid), LinkClass.SWITCH, p)
                 for _ in range(per_pod_sw)]
    return DevicePool(devs)


# ---------------------------------------------------------------------------
# FabricSpec: the axis -> link-class map of a composed mesh
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class FabricSpec:
    """Which link class each logical mesh axis rides on.

    This is the heart of the paper's experiment: the *same* program priced
    on different fabrics.  ``axis_links["data"] = LinkClass.SWITCH`` is the
    falconGPUs configuration; ``LOCAL`` everywhere is localGPUs.
    """
    axis_links: Mapping[str, LinkClass]
    links: Mapping[LinkClass, LinkSpec] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_LINKS))
    storage: StorageSpec = LOCAL_NVME

    def bandwidth(self, axis: str) -> float:
        return self.links[self.axis_links[axis]].bandwidth

    def latency(self, axis: str) -> float:
        return self.links[self.axis_links[axis]].latency

    def link(self, axis: str) -> LinkSpec:
        return self.links[self.axis_links[axis]]

    def with_axis(self, axis: str, cls: LinkClass) -> "FabricSpec":
        m = dict(self.axis_links)
        m[axis] = cls
        return dataclasses.replace(self, axis_links=m)

    def slowest(self) -> LinkSpec:
        return min((self.links[c] for c in self.axis_links.values()),
                   key=lambda l: l.bandwidth)
