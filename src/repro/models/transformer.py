"""Composable decoder stacks.

A model's ``block_pattern`` (e.g. Griffin's (rglru, rglru, attn_local)
repeating) is compiled into *segments*: the smallest repeating unit is
``lax.scan``-ned over its repeat count (keeping HLO size ~O(unit), essential
for 48-layer models), and any remainder prefix becomes a second short
segment. Per-slot parameters are stacked along a leading layer axis.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (ATTN, ATTN_LOCAL, RGLRU, SSM, ModelConfig)
from repro.models import attention, layers, moe, rglru, ssm


# ---------------------------------------------------------------------------
# run context (how to execute; orthogonal to the params)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ParallelCtx:
    """Mesh-aware execution context (None mesh = single device)."""
    mesh: Any = None
    dp_axes: Tuple[str, ...] = ("data",)
    tp_axis: Optional[str] = "model"
    fsdp_experts: bool = True


@dataclasses.dataclass(frozen=True)
class RunCtx:
    compute_dtype: Any = jnp.bfloat16
    attn_impl: str = "xla"            # xla | full | pallas
    attn_blocks: Tuple[int, int] = (512, 512)
    moe_impl: str = "sorted"          # dense | sorted | ep
    moe_capacity: Optional[int] = None
    remat: str = "block"              # none | block
    cache_capacity: int = 0
    pctx: ParallelCtx = ParallelCtx()


# ---------------------------------------------------------------------------
# segment planning
# ---------------------------------------------------------------------------
def plan_segments(pattern: Sequence[str]) -> List[Tuple[Tuple[str, ...], int]]:
    """[(unit, repeats), ...] — unit*repeats (+ prefix remainder) == pattern."""
    pattern = tuple(pattern)
    L = len(pattern)
    for u in range(1, L + 1):
        unit = pattern[:u]
        k = L // u
        if unit * k == pattern[:u * k] and pattern[u * k:] == unit[:L - u * k]:
            segs = [(unit, k)]
            rem = pattern[u * k:]
            if rem:
                segs.append((rem, 1))
            return segs
    raise AssertionError("unreachable")


# ---------------------------------------------------------------------------
# single block
# ---------------------------------------------------------------------------
def _has_ffn(cfg: ModelConfig) -> bool:
    return cfg.d_ff > 0 or cfg.moe is not None


def init_block(key, cfg: ModelConfig, blk: str, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm1": layers.init_norm(cfg.norm, cfg.d_model, dtype)}
    if blk in (ATTN, ATTN_LOCAL):
        p["attn"] = attention.init_attention(ks[0], cfg, dtype)
    elif blk == SSM:
        p["ssm"] = ssm.init_ssm(ks[0], cfg, dtype)
    elif blk == RGLRU:
        p["rglru"] = rglru.init_rglru(ks[0], cfg, dtype)
    else:
        raise ValueError(blk)
    if _has_ffn(cfg):
        if not cfg.parallel_residual:
            p["norm2"] = layers.init_norm(cfg.norm, cfg.d_model, dtype)
        if cfg.moe is not None:
            p["moe"] = moe.init_moe(ks[1], cfg, dtype)
        else:
            p["mlp"] = layers.init_mlp(ks[1], cfg.d_model, cfg.d_ff,
                                       cfg.act, dtype)
    return p


def apply_block(p, x, blk: str, cfg: ModelConfig, ctx: RunCtx, *,
                positions, cache=None, kv_mask=None):
    """Returns (x, new_cache, aux)."""
    cd = ctx.compute_dtype
    h = layers.apply_norm(p["norm1"], x, cfg.norm, cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)

    if blk in (ATTN, ATTN_LOCAL):
        has_mesh = ctx.pctx.mesh is not None
        batch_axes = tuple(ctx.pctx.dp_axes) if has_mesh else ()
        tp = ctx.pctx.tp_axis
        head_axis = (tp if has_mesh and tp in getattr(
            ctx.pctx.mesh, "shape", {})
            and cfg.n_heads % ctx.pctx.mesh.shape[tp] == 0 else None)
        mix, new_cache = attention.apply_attention(
            p["attn"], h, cfg, local=(blk == ATTN_LOCAL),
            positions=positions, compute_dtype=cd,
            impl=("full" if ctx.attn_impl == "full" else "xla"),
            cache=cache, blocks=ctx.attn_blocks, kv_mask=kv_mask,
            cache_capacity=ctx.cache_capacity, batch_axes=batch_axes,
            head_axis=head_axis, mesh=ctx.pctx.mesh,
            tp_axis=ctx.pctx.tp_axis)
    elif blk == SSM:
        mix, new_cache = ssm.apply_ssm(
            p["ssm"], h, cfg, compute_dtype=cd, cache=(
                cache if isinstance(cache, dict) else None),
            build_cache=(cache == "init"), pctx=ctx.pctx,
            token_mask=kv_mask)
    elif blk == RGLRU:
        has_mesh = ctx.pctx.mesh is not None
        mix, new_cache = rglru.apply_rglru(
            p["rglru"], h, cfg, compute_dtype=cd, cache=(
                cache if isinstance(cache, dict) else None),
            build_cache=(cache == "init"),
            batch_axes=(tuple(ctx.pctx.dp_axes) if has_mesh else ()),
            model_axis=(ctx.pctx.tp_axis if has_mesh else None),
            token_mask=kv_mask)
    else:
        raise ValueError(blk)

    if not _has_ffn(cfg):
        return x + mix.astype(x.dtype), new_cache, aux

    if cfg.parallel_residual:
        if cfg.moe is not None:
            f, aux = moe.apply_moe(p["moe"], h, cfg, compute_dtype=cd,
                                   impl=ctx.moe_impl, pctx=ctx.pctx,
                                   capacity=ctx.moe_capacity)
        else:
            f = layers.apply_mlp(p["mlp"], h, cfg.act, cd)
        return x + (mix + f).astype(x.dtype), new_cache, aux

    x = x + mix.astype(x.dtype)
    h2 = layers.apply_norm(p["norm2"], x, cfg.norm, cfg.norm_eps)
    if cfg.moe is not None:
        f, aux = moe.apply_moe(p["moe"], h2, cfg, compute_dtype=cd,
                               impl=ctx.moe_impl, pctx=ctx.pctx,
                               capacity=ctx.moe_capacity)
    else:
        f = layers.apply_mlp(p["mlp"], h2, cfg.act, cd)
    return x + f.astype(x.dtype), new_cache, aux


# ---------------------------------------------------------------------------
# cache scaffolding
# ---------------------------------------------------------------------------
def init_block_cache(cfg: ModelConfig, blk: str, batch: int, max_seq: int,
                     dtype=jnp.bfloat16):
    if blk in (ATTN, ATTN_LOCAL):
        return attention.init_decode_cache(
            cfg, batch, max_seq, local=(blk == ATTN_LOCAL), dtype=dtype)
    if blk == SSM:
        return ssm.init_ssm_cache(cfg, batch)
    if blk == RGLRU:
        return rglru.init_rglru_cache(cfg, batch)
    raise ValueError(blk)


def init_stack_cache(cfg: ModelConfig, batch: int, max_seq: int,
                     dtype=jnp.bfloat16):
    """Caches stacked to mirror the segment structure of the params."""
    caches = {}
    for si, (unit, k) in enumerate(plan_segments(cfg.pattern)):
        seg = {}
        for slot, blk in enumerate(unit):
            one = init_block_cache(cfg, blk, batch, max_seq, dtype)
            seg[f"slot{slot}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (k,) + a.shape)
                if k > 1 else a, one)
        caches[f"seg{si}"] = seg
    return caches


# ---------------------------------------------------------------------------
# stack init / apply
# ---------------------------------------------------------------------------
def init_stack(key, cfg: ModelConfig, dtype=jnp.float32):
    params = {}
    segs = plan_segments(cfg.pattern)
    keys = jax.random.split(key, len(segs))
    for si, (unit, k) in enumerate(segs):
        seg_p = {}
        slot_keys = jax.random.split(keys[si], len(unit))
        for slot, blk in enumerate(unit):
            lkeys = jax.random.split(slot_keys[slot], k)
            per_layer = [init_block(lkeys[i], cfg, blk, dtype)
                         for i in range(k)]
            seg_p[f"slot{slot}"] = (
                jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer)
                if k > 1 else per_layer[0])
        params[f"seg{si}"] = seg_p
    return params


def apply_stack(params, x, cfg: ModelConfig, ctx: RunCtx, *,
                positions, caches=None, kv_mask=None):
    """Returns (x, new_caches|None, aux_sum).

    ``caches``: None (training), "init" (prefill -> build caches), or the
    stacked cache pytree (decode).
    """
    segs = plan_segments(cfg.pattern)
    aux_total = jnp.zeros((), jnp.float32)
    new_caches: Optional[Dict[str, Any]] = None if caches is None else {}

    for si, (unit, k) in enumerate(segs):
        seg_p = params[f"seg{si}"]
        seg_c = None
        if isinstance(caches, dict):
            seg_c = caches[f"seg{si}"]

        def unit_body(x_aux, slot_params_caches, unit=unit):
            xx, aux = x_aux
            slot_p, slot_c = slot_params_caches
            out_caches = {}
            for slot, blk in enumerate(unit):
                c_in = (slot_c[f"slot{slot}"] if slot_c is not None
                        else ("init" if caches == "init" else None))
                xx, nc, a = apply_block(
                    slot_p[f"slot{slot}"], xx, blk, cfg, ctx,
                    positions=positions, cache=c_in, kv_mask=kv_mask)
                if nc is not None:
                    out_caches[f"slot{slot}"] = nc
                aux = aux + a
            return (xx, aux), (out_caches if out_caches else None)

        body = unit_body
        if ctx.remat == "block":
            body = jax.checkpoint(unit_body, prevent_cse=False)

        if k == 1:
            (x, aux_total), seg_new_c = body(
                (x, aux_total), (seg_p, seg_c))
        else:
            def scan_body(carry, xs):
                return body(carry, xs)
            (x, aux_total), seg_new_c = jax.lax.scan(
                scan_body, (x, aux_total), (seg_p, seg_c))
        if new_caches is not None and seg_new_c is not None:
            new_caches[f"seg{si}"] = seg_new_c

    return x, new_caches, aux_total
