"""Shared neural-net building blocks (pure functions over param pytrees).

Conventions:
  * params are nested dicts of jnp arrays; init_* functions build them.
  * compute dtype is passed explicitly (bf16 for TPU); norms/softmax
    accumulate in fp32.
  * weights are stored in ``param_dtype`` (fp32 default; ZeRO keeps masters).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def dense_init(key, shape, dtype=jnp.float32, scale: Optional[float] = None):
    """Truncated-normal fan-in init (matches common LM practice)."""
    fan_in = shape[0] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def init_norm(kind: str, d: int, dtype=jnp.float32):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    raise ValueError(kind)


def apply_norm(params, x, kind: str, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * params["scale"].astype(jnp.float32)
        return y.astype(dt)
    if kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
        return y.astype(dt)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# rotary / positional embeddings
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, fraction: float, theta: float):
    rot_dim = int(head_dim * fraction) // 2 * 2
    inv = 1.0 / (theta ** (np.arange(0, rot_dim, 2, dtype=np.float32) / rot_dim))
    return rot_dim, jnp.asarray(inv)


def apply_rope(x, positions, *, fraction: float = 1.0, theta: float = 10000.0):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    rot_dim, inv = rope_frequencies(d, fraction, theta)
    if rot_dim == 0:
        return x
    x_rot, x_pass = x[..., :rot_dim], x[..., rot_dim:]
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., S, rot/2)
    cos = jnp.cos(ang)[..., None, :].astype(x.dtype)          # (..., S, 1, rot/2)
    sin = jnp.sin(ang)[..., None, :].astype(x.dtype)
    x1, x2 = jnp.split(x_rot, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out, x_pass], axis=-1) if rot_dim < d else out


def sinusoidal_positions(positions, d_model: int, dtype=jnp.float32):
    half = d_model // 2
    freq = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# ---------------------------------------------------------------------------
# MLP (dense FFN)
# ---------------------------------------------------------------------------
def init_mlp(key, d_model: int, d_ff: int, act: str, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    if act in ("swiglu", "geglu"):
        return {
            "wi": dense_init(ks[0], (d_model, d_ff), dtype),
            "wg": dense_init(ks[1], (d_model, d_ff), dtype),
            "wo": dense_init(ks[2], (d_ff, d_model), dtype),
        }
    return {
        "wi": dense_init(ks[0], (d_model, d_ff), dtype),
        "wo": dense_init(ks[2], (d_ff, d_model), dtype),
    }


def apply_mlp(params, x, act: str, compute_dtype=jnp.bfloat16):
    x = x.astype(compute_dtype)
    wi = params["wi"].astype(compute_dtype)
    wo = params["wo"].astype(compute_dtype)
    h = x @ wi
    if act == "swiglu":
        h = jax.nn.silu(h) * (x @ params["wg"].astype(compute_dtype))
    elif act == "geglu":
        h = jax.nn.gelu(h) * (x @ params["wg"].astype(compute_dtype))
    elif act == "gelu":
        h = jax.nn.gelu(h)
    else:
        raise ValueError(act)
    return h @ wo


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------
def init_embedding(key, vocab: int, d_model: int, dtype=jnp.float32):
    return {"table": embed_init(key, (vocab, d_model), dtype)}


def embed_tokens(params, tokens, compute_dtype=jnp.bfloat16):
    return params["table"].astype(compute_dtype)[tokens]


def unembed(params_or_table, x, compute_dtype=jnp.bfloat16):
    table = (params_or_table["table"]
             if isinstance(params_or_table, dict) else params_or_table)
    return x.astype(compute_dtype) @ table.astype(compute_dtype).T


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------
def _gold_logit(logits, labels):
    """logits[..., labels] via masked reduction (partition-friendly: no
    gather over the — possibly vocab-sharded — last dim)."""
    V = logits.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                    logits.ndim - 1)
    hit = iota == labels[..., None]
    return jnp.sum(jnp.where(hit, logits, 0.0), axis=-1)


def softmax_xent(logits, labels, mask=None):
    """Mean next-token cross entropy; logits (..., V) fp-any, labels int."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = _gold_logit(logits, labels)
    nll = lse - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def chunked_softmax_xent(x, embed_table, labels, *, chunk: int,
                         compute_dtype=jnp.bfloat16, mask=None):
    """Cross entropy without materializing the full (T, V) logits.

    x: (B, S, D) final hidden states; embed_table: (V, D).
    Scans over sequence chunks; each chunk computes (B, chunk, V) logits,
    reduces to per-token NLL, and discards them.  Cuts peak logits memory by
    S/chunk — essential for vocab 200k+ at 1M tokens/step.
    """
    B, S, D = x.shape
    assert S % chunk == 0, (S, chunk)
    n = S // chunk
    xs = x.reshape(B, n, chunk, D).swapaxes(0, 1)          # (n, B, c, D)
    ls = labels.reshape(B, n, chunk).swapaxes(0, 1)        # (n, B, c)
    if mask is None:
        ms = jnp.ones((n, B, chunk), jnp.float32)
    else:
        ms = mask.reshape(B, n, chunk).swapaxes(0, 1).astype(jnp.float32)
    table = embed_table.astype(compute_dtype)

    def body(carry, inp):
        xc, lc, mc = inp
        logits = (xc.astype(compute_dtype) @ table.T).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = _gold_logit(logits, lc)
        nll = (lse - gold) * mc
        return (carry[0] + jnp.sum(nll), carry[1] + jnp.sum(mc)), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())),
                                 (xs, ls, ms))
    return tot / jnp.maximum(cnt, 1.0)
