"""Attention: projections + three execution paths.

Paths:
  * ``full``       — materializes (S, T) scores; oracle + short sequences.
  * ``flash_xla``  — two-level blocked scan (online softmax), pure JAX. Never
                     materializes more than one (q_block, kv_block) score
                     tile; lowers/compiles on any backend. This mirrors the
                     Pallas kernel in ``repro.kernels.flash_attention`` and is
                     the dry-run implementation.
  * ``decode``     — single-token attention over a (possibly ring-buffered)
                     KV cache.

All paths support GQA (H = K * G query groups), causal masking, and sliding
windows. Shapes: q (B, S, H, D); k/v (B, T, Kh, D).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.jaxcompat import shard_map


NEG_INF = -1e30


def _fit_block(block: int, dim: int) -> int:
    """Largest tile <= ``block`` that divides ``dim`` (bounded: at most
    ``block`` decrements).  Mirrors kernels.registry.fit_block without a
    cross-layer import."""
    b = max(1, min(int(block), int(dim)))
    while dim % b:
        b -= 1
    return b


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------
def init_attention(key, cfg: ModelConfig, dtype=jnp.float32):
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": layers.dense_init(ks[0], (d, H, hd), dtype),
        "wk": layers.dense_init(ks[1], (d, K, hd), dtype),
        "wv": layers.dense_init(ks[2], (d, K, hd), dtype),
        "wo": layers.dense_init(ks[3], (H, hd, d), dtype,
                                scale=1.0 / math.sqrt(H * hd)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dtype)
        p["bk"] = jnp.zeros((K, hd), dtype)
        p["bv"] = jnp.zeros((K, hd), dtype)
    if cfg.qk_norm:
        p["q_norm"] = layers.init_norm("layernorm", hd, dtype)
        p["k_norm"] = layers.init_norm("layernorm", hd, dtype)
    return p


# ---------------------------------------------------------------------------
# core attention math
# ---------------------------------------------------------------------------
def _mask_value(q_pos, k_pos, causal: bool, window: int):
    """Additive mask for (…, Sq, Tk) given absolute positions."""
    m = jnp.zeros(q_pos.shape[:-1] + (q_pos.shape[-1], k_pos.shape[-1]),
                  jnp.float32)
    diff = q_pos[..., :, None] - k_pos[..., None, :]
    if causal:
        m = jnp.where(diff < 0, NEG_INF, m)
    if window > 0:
        m = jnp.where(diff >= window, NEG_INF, m)
    return m


def full_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                   kv_mask=None, softcap=0.0):
    """Oracle path. q (B,S,H,D), k/v (B,T,K,D)."""
    B, S, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, S, K, G, D)
    s = jnp.einsum("bskgd,btkd->bkgst", qg, k,
                   preferred_element_type=jnp.float32)
    s = s / math.sqrt(D)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.arange(S) + q_offset
    k_pos = jnp.arange(T)
    s = s + _mask_value(q_pos, k_pos, causal, window)
    if kv_mask is not None:  # (B, T) True = attend
        s = jnp.where(kv_mask[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bkgst,btkd->bskgd", p, v)
    return o.reshape(B, S, H, D)


def flash_attention_xla(q, k, v, *, causal=True, window=0, q_offset=0,
                        q_block=512, kv_block=512, softcap=0.0,
                        batch_axes=(), head_axis=None):
    """Blocked online-softmax attention (pure JAX, scan over tiles).

    Peak score memory = (B, H, q_block, kv_block) fp32 regardless of S, T.

    GQA is handled by repeating K/V to the full H heads up front: a
    (K, G) reshape would destroy a head sharding whenever tp does not
    divide K (kv=8 heads on a 16-way model axis forced per-tile
    all-gathers — 2.2 TiB/step measured on command-r).  The repeat keeps
    every grid tensor sharded on H (``head_axis`` pins it) and costs only
    the broadcast KV tile in VMEM.
    """
    B, S, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    # fit, don't assert: tuned/default tiles come from the step builder's
    # build-time shape, but a served prompt can be any length <= capacity
    q_block = _fit_block(q_block, S)
    kv_block = _fit_block(kv_block, T)
    nq, nk = S // q_block, T // kv_block
    scale = 1.0 / math.sqrt(D)

    def pin(x, hdim):
        x = _constrain_batch(x, batch_axes, 0)
        if head_axis is not None and x.shape[hdim] % 2 == 0:
            from jax.sharding import PartitionSpec as P
            entries = [None] * x.ndim
            if batch_axes:
                entries[0] = (tuple(batch_axes) if len(batch_axes) > 1
                              else batch_axes[0])
            entries[hdim] = head_axis
            try:
                x = jax.lax.with_sharding_constraint(x, P(*entries))
            except (ValueError, RuntimeError):
                pass
        return x

    kr = jnp.repeat(k, G, axis=2) if G > 1 else k      # (B, T, H, D)
    vr = jnp.repeat(v, G, axis=2) if G > 1 else v
    qg = q.reshape(B, nq, q_block, H, D).transpose(1, 0, 3, 2, 4)
    # qg: (nq, B, H, qb, D)
    kb = kr.reshape(B, nk, kv_block, H, D).transpose(1, 0, 3, 2, 4)
    vb = vr.reshape(B, nk, kv_block, H, D).transpose(1, 0, 3, 2, 4)
    # kb/vb: (nk, B, H, kvb, D)

    def q_step(_, qi_and_block):
        qi, qblk = qi_and_block  # qblk (B,H,qb,D)
        qblk = pin(qblk, 1)
        q_pos = qi * q_block + jnp.arange(q_block) + q_offset

        def kv_step(carry, kj_and_kv):
            m, l, acc = carry
            kj, kblk, vblk = kj_and_kv
            kblk = pin(kblk, 1)
            s = jnp.einsum("bhqd,bhtd->bhqt", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            s = pin(s, 1)
            if softcap > 0:
                s = softcap * jnp.tanh(s / softcap)
            k_pos = kj * kv_block + jnp.arange(kv_block)
            s = s + _mask_value(q_pos, k_pos, causal, window)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = pin(l * corr + jnp.sum(p, axis=-1), 1)
            m_new = pin(m_new, 1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqt,bhtd->bhqd", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32)
            acc_new = pin(acc_new, 1)
            return (m_new, l_new, acc_new), None

        m0 = pin(jnp.full((B, H, q_block), NEG_INF, jnp.float32), 1)
        l0 = pin(jnp.zeros((B, H, q_block), jnp.float32), 1)
        a0 = pin(jnp.zeros((B, H, q_block, D), jnp.float32), 1)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), kb, vb))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    _, ob = jax.lax.scan(q_step, None, (jnp.arange(nq), qg))
    # ob: (nq, B, H, qb, D) -> (B, S, H, D)
    return ob.transpose(1, 0, 3, 2, 4).reshape(B, S, H, D)


def _constrain_batch(x, batch_axes, dim: int):
    """Pin the batch dim's sharding (None = no-op).

    GSPMD's backward propagation through nested scans can drift to a
    batch-replicated layout (measured: full-batch fp32 score tiles
    all-reduced over 'data' 320x/step); constraining the batch dim of the
    scan operands/carries inside the body prevents the drift.
    """
    if not batch_axes:
        return x
    from jax.sharding import PartitionSpec as P
    entries = [None] * x.ndim
    entries[dim] = tuple(batch_axes) if len(batch_axes) > 1 else \
        batch_axes[0]
    try:
        return jax.lax.with_sharding_constraint(x, P(*entries))
    except (ValueError, RuntimeError):
        return x


def local_flash_xla(q, k, v, *, window: int, causal=True, softcap=0.0,
                    q_block=512, kv_block=512, batch_axes=(),
                    head_axis=None):
    """O(S·window) sliding-window flash attention.

    Per q block i, only a STATIC-length key span of ``window + q_block``
    (rounded up to kv_block) ending at the block's last key can be in
    range; the span is ``dynamic_slice``d from a front-padded K/V and
    flash-tiled, so peak score memory stays one (q_block, kv_block) tile
    and executed FLOPs are S·(window + q_block) per head instead of the
    full S².  Invalid (padding) keys carry position < 0 and are masked.
    """
    B, S, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    bq = min(q_block, S)
    if S % bq:
        return flash_attention_xla(q, k, v, causal=causal, window=window,
                                   softcap=softcap, batch_axes=batch_axes,
                                   head_axis=head_axis)
    span = window + bq
    bk = min(kv_block, span)
    span = -(-span // bk) * bk              # round up to kv tiles
    if span >= T:                           # no savings: plain flash
        return flash_attention_xla(q, k, v, causal=causal, window=window,
                                   q_block=q_block, kv_block=kv_block,
                                   softcap=softcap, batch_axes=batch_axes,
                                   head_axis=head_axis)
    pad = span - bq                         # front padding (invalid keys)
    nq = S // bq
    nk = span // bk
    scale = 1.0 / math.sqrt(D)

    def pin(x, hdim):
        x = _constrain_batch(x, batch_axes, 0)
        if head_axis is not None and x.ndim > hdim:
            from jax.sharding import PartitionSpec as P
            entries = [None] * x.ndim
            if batch_axes:
                entries[0] = (tuple(batch_axes) if len(batch_axes) > 1
                              else batch_axes[0])
            entries[hdim] = head_axis
            try:
                x = jax.lax.with_sharding_constraint(x, P(*entries))
            except (ValueError, RuntimeError):
                pass
        return x

    kr = jnp.repeat(k, G, axis=2) if G > 1 else k      # (B, T, H, D)
    vr = jnp.repeat(v, G, axis=2) if G > 1 else v
    kp = jnp.pad(kr, ((0, 0), (pad, 0), (0, 0), (0, 0)))
    vp = jnp.pad(vr, ((0, 0), (pad, 0), (0, 0), (0, 0)))
    qg = q.reshape(B, nq, bq, H, D).transpose(1, 0, 3, 2, 4)
    # qg: (nq, B, H, bq, D); kp/vp: (B, pad+T, H, D)

    def q_step(_, qi_and_block):
        qi, qblk = qi_and_block
        qblk = pin(qblk, 1)
        q_pos = qi * bq + jnp.arange(bq)
        ks = jax.lax.dynamic_slice_in_dim(kp, qi * bq, span, axis=1)
        vs = jax.lax.dynamic_slice_in_dim(vp, qi * bq, span, axis=1)
        kb = ks.reshape(B, nk, bk, H, D).transpose(1, 0, 3, 2, 4)
        vb = vs.reshape(B, nk, bk, H, D).transpose(1, 0, 3, 2, 4)

        def kv_step(carry, kj_and_kv):
            m, l, acc = carry
            kj, kblk, vblk = kj_and_kv
            kblk = pin(kblk, 1)
            s = jnp.einsum("bhqd,bhtd->bhqt", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            s = pin(s, 1)
            if softcap > 0:
                s = softcap * jnp.tanh(s / softcap)
            k_pos = qi * bq + kj * bk + jnp.arange(bk) - pad
            diff = q_pos[:, None] - k_pos[None, :]
            msk = jnp.where(k_pos < 0, NEG_INF, 0.0)[None, :]
            if causal:
                msk = jnp.where(diff < 0, NEG_INF, msk)
            msk = jnp.where(diff >= window, NEG_INF, msk)
            s = s + msk
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = pin(l * corr + jnp.sum(p, axis=-1), 1)
            m_new = pin(m_new, 1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqt,bhtd->bhqd", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32)
            acc_new = pin(acc_new, 1)
            return (m_new, l_new, acc_new), None

        m0 = pin(jnp.full((B, H, bq), NEG_INF, jnp.float32), 1)
        l0 = pin(jnp.zeros((B, H, bq), jnp.float32), 1)
        a0 = pin(jnp.zeros((B, H, bq, D), jnp.float32), 1)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      (jnp.arange(nk), kb, vb))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    _, ob = jax.lax.scan(q_step, None, (jnp.arange(nq), qg))
    return ob.transpose(1, 0, 3, 2, 4).reshape(B, S, H, D)


def chunk_decode_attention(q, k_cache, v_cache, cache_pos, q_pos, *,
                           window=0, softcap=0.0):
    """Multi-token attention of a prompt *chunk* against a KV cache.

    q (B,S,H,D) is a contiguous chunk of new tokens at absolute positions
    ``q_pos`` (B,S); the caches (B,W,K,D) already contain the chunk's own
    K/V (written by the caller) plus all earlier history, with ``cache_pos``
    (B,W) giving each slot's absolute position (-1 = empty).  Masking is
    purely positional — a query attends to every valid slot at a position
    <= its own (and within ``window``) — so the result is bit-identical to
    one-shot prefill over the same tokens regardless of how the prompt was
    chunked.  This is the chunked-prefill primitive of the serving stack.
    """
    B, S, H, D = q.shape
    K = k_cache.shape[2]
    G = H // K
    qg = q.reshape(B, S, K, G, D)
    s = jnp.einsum("bskgd,btkd->bkgst", qg, k_cache,
                   preferred_element_type=jnp.float32) / math.sqrt(D)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    valid = (cache_pos >= 0)[:, None, :]                  # (B,1,W)
    diff = q_pos[:, :, None] - cache_pos[:, None, :]      # (B,S,W)
    keep = valid & (diff >= 0)
    if window > 0:
        keep = keep & (diff < window)
    s = jnp.where(keep[:, None, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
    o = jnp.einsum("bkgst,btkd->bskgd", p, v_cache)
    return o.reshape(B, S, H, D)


def decode_attention(q, k_cache, v_cache, cache_pos, *, window=0,
                     softcap=0.0):
    """q (B,1,H,D); caches (B,W,K,D); cache_pos (B,W) absolute positions of
    each cache slot (-1 = empty). Works for both full and ring-buffer caches.
    """
    B, _, H, D = q.shape
    K = k_cache.shape[2]
    G = H // K
    qg = q.reshape(B, K, G, D)
    s = jnp.einsum("bkgd,btkd->bkgt", qg, k_cache,
                   preferred_element_type=jnp.float32) / math.sqrt(D)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    valid = (cache_pos >= 0)
    if window > 0:
        cur = jnp.max(cache_pos, axis=-1, keepdims=True)
        valid = valid & (cur - cache_pos < window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
    o = jnp.einsum("bkgt,btkd->bkgd", p, v_cache)
    return o.reshape(B, 1, H, D)


def sharded_decode(q, k_new, v_new, cache, positions, *, mesh, dp_axes,
                   tp_axis, window=0, softcap=0.0):
    """Flash-decode under shard_map: batch over dp, cache LENGTH over tp.

    Each model rank holds a slice of the (B, W, K, D) history; the new
    token is written into whichever rank owns its slot (ring-buffer slot
    for windowed layers); attention computes local partial max/sum-exp
    and combines with one tiny psum triplet over tp — no rank ever
    materializes the full cache (32k x 128 x 40L would blow HBM) and no
    gather/scatter crosses the wire.

    Returns (out (B,1,H,D), new_cache).  Falls back to the dense path
    when the mesh/shapes don't divide.
    """
    B, _, H, D = q.shape
    W = cache["k"].shape[1]
    K = cache["k"].shape[2]
    G = H // K
    tp = mesh.shape.get(tp_axis, 1) if tp_axis else 1
    dp = tuple(a for a in dp_axes if mesh.shape.get(a, 1) > 1)
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    if (tp > 1 and (W % tp or W < 2 * tp)) or (n_dp > 1 and B % n_dp):
        return None                      # caller uses the dense path

    from jax.sharding import PartitionSpec as Pspec
    dp_e = (dp if len(dp) > 1 else dp[0]) if dp else None
    tp_e = tp_axis if tp > 1 else None
    s_q = Pspec(dp_e, None, None, None)
    s_kv = Pspec(dp_e, tp_e, None, None)
    s_pos = Pspec(dp_e, tp_e)
    s_cur = Pspec(dp_e, None)

    def body(ql, knl, vnl, ck, cv, cp, cur):
        Bl = ql.shape[0]
        Wl = ck.shape[1]
        r = jax.lax.axis_index(tp_axis) if tp > 1 else 0
        slot_g = (cur[:, 0] % W) if window > 0 else cur[:, 0]
        slot_l = slot_g - r * Wl
        ok = (slot_l >= 0) & (slot_l < Wl)
        safe = jnp.clip(slot_l, 0, Wl - 1)
        bidx = jnp.arange(Bl)
        old_k = ck[bidx, safe]
        old_v = cv[bidx, safe]
        old_p = cp[bidx, safe]
        ck = ck.at[bidx, safe].set(
            jnp.where(ok[:, None, None], knl[:, 0].astype(ck.dtype), old_k))
        cv = cv.at[bidx, safe].set(
            jnp.where(ok[:, None, None], vnl[:, 0].astype(cv.dtype), old_v))
        cp = cp.at[bidx, safe].set(
            jnp.where(ok, cur[:, 0].astype(cp.dtype), old_p))

        qg = ql.reshape(Bl, K, G, D)
        s = jnp.einsum("bkgd,btkd->bkgt", qg, ck,
                       preferred_element_type=jnp.float32) / math.sqrt(D)
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        valid = cp >= 0
        if window > 0:
            valid = valid & (cur[:, :1] - cp < window)
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        m_l = jnp.max(s, axis=-1)                         # (B,K,G)
        p = jnp.exp(s - m_l[..., None])
        p = jnp.where(valid[:, None, None, :], p, 0.0)
        l_l = jnp.sum(p, axis=-1)
        acc_l = jnp.einsum("bkgt,btkd->bkgd", p.astype(cv.dtype), cv,
                           preferred_element_type=jnp.float32)
        if tp > 1:
            m = jax.lax.pmax(m_l, tp_axis)
            f = jnp.exp(m_l - m)
            l = jax.lax.psum(l_l * f, tp_axis)
            acc = jax.lax.psum(acc_l * f[..., None], tp_axis)
        else:
            l, acc = l_l, acc_l
        out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(ql.dtype)
        return out.reshape(Bl, 1, H, D), ck, cv, cp

    manual = frozenset(dp) | ({tp_axis} if tp > 1 else set())
    if not manual:
        return None
    try:
        am = jax.sharding.get_abstract_mesh()
        already = frozenset(
            a for a, t in zip(getattr(am, "axis_names", ()),
                              getattr(am, "axis_types", ()))
            if "Manual" in str(t))
    except Exception:
        already = frozenset()
    out, ck, cv, cp = shard_map(
        body, mesh=None if already else mesh,
        axis_names=manual - already if already else manual,
        in_specs=(s_q, s_q, s_q, s_kv, s_kv, s_pos, s_cur),
        out_specs=(s_q, s_kv, s_kv, s_pos), check_vma=False,
    )(q, k_new, v_new, cache["k"], cache["v"], cache["pos"], positions)
    return out, {"k": ck, "v": cv, "pos": cp}


def sharded_flash(q, k, v, *, mesh, dp_axes, tp_axis, causal=True,
                  window=0, softcap=0.0, q_block=512, kv_block=512):
    """Flash attention under an explicit ``shard_map``: batch over the dp
    axes, heads over the tp axis — every tensor inside the scan is a plain
    local array, so GSPMD cannot drift (pin-based constraints still left
    2560 per-tile all-gathers in the backward of nested scans; manual
    sharding removes them by construction).

    GQA KV heads are repeated to H *before* sharding; if tp does not
    divide H, heads are zero-padded up to the next multiple (the padded
    heads compute garbage that is sliced off — bounded waste, vs. the
    16x redundant compute of batch-only sharding or per-tile gathers).
    """
    B, S, H, D = q.shape
    K = k.shape[2]
    G = H // K
    tp = mesh.shape.get(tp_axis, 1) if tp_axis else 1
    dp = tuple(a for a in dp_axes if mesh.shape.get(a, 1) > 1)
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    if (n_dp > 1 and B % n_dp) or S % q_block:
        # fall back to the pin-based jit path
        fn = local_flash_xla if window > 0 else flash_attention_xla
        kwargs = dict(causal=causal, softcap=softcap,
                      batch_axes=dp, q_block=q_block, kv_block=kv_block)
        if window > 0:
            return fn(q, k, v, window=window, **kwargs)
        return fn(q, k, v, window=window, **kwargs)

    kr = jnp.repeat(k, G, axis=2) if G > 1 else k
    vr = jnp.repeat(v, G, axis=2) if G > 1 else v
    Hp = -(-H // tp) * tp
    if Hp != H:
        padh = ((0, 0), (0, 0), (0, Hp - H), (0, 0))
        q = jnp.pad(q, padh)
        kr = jnp.pad(kr, padh)
        vr = jnp.pad(vr, padh)

    from jax.sharding import PartitionSpec as P
    dp_entry = (dp if len(dp) > 1 else dp[0]) if dp else None
    spec = P(dp_entry, None, tp_axis if tp > 1 else None, None)

    def body(ql, kl, vl):
        if window > 0:
            return local_flash_xla(ql, kl, vl, window=window,
                                   causal=causal, softcap=softcap,
                                   q_block=q_block, kv_block=kv_block)
        return flash_attention_xla(ql, kl, vl, causal=causal,
                                   window=0, softcap=softcap,
                                   q_block=q_block, kv_block=kv_block)

    manual = frozenset(dp) | ({tp_axis} if tp > 1 else set())
    if not manual:                      # degenerate 1x1 mesh: run local
        return body(q, kr, vr)[:, :, :H]
    try:
        am = jax.sharding.get_abstract_mesh()
        already = frozenset(
            a for a, t in zip(getattr(am, "axis_names", ()),
                              getattr(am, "axis_types", ()))
            if "Manual" in str(t))
    except Exception:
        already = frozenset()
    out = shard_map(body, mesh=None if already else mesh,
                        axis_names=manual - already if already else manual,
                        in_specs=(spec, spec, spec), out_specs=spec,
                        check_vma=False)(q, kr, vr)
    return out[:, :, :H]


# ---------------------------------------------------------------------------
# block-level apply (projections + path dispatch + cache management)
# ---------------------------------------------------------------------------
def project_qkv(params, x, cfg: ModelConfig, positions, compute_dtype):
    cd = compute_dtype
    x = x.astype(cd)
    q = jnp.einsum("bsd,dhe->bshe", x, params["wq"].astype(cd))
    k = jnp.einsum("bsd,dke->bske", x, params["wk"].astype(cd))
    v = jnp.einsum("bsd,dke->bske", x, params["wv"].astype(cd))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(cd)
        k = k + params["bk"].astype(cd)
        v = v + params["bv"].astype(cd)
    if cfg.qk_norm:
        q = layers.apply_norm(params["q_norm"], q, "layernorm", cfg.norm_eps)
        k = layers.apply_norm(params["k_norm"], k, "layernorm", cfg.norm_eps)
    if cfg.pos_embedding == "rope":
        q = layers.apply_rope(q, positions, fraction=cfg.rope_fraction,
                              theta=cfg.rope_theta)
        k = layers.apply_rope(k, positions, fraction=cfg.rope_fraction,
                              theta=cfg.rope_theta)
    return q, k, v


def apply_attention(params, x, cfg: ModelConfig, *, local: bool,
                    positions, compute_dtype=jnp.bfloat16, impl="xla",
                    cache=None, blocks=(512, 512), kv_mask=None,
                    cache_capacity: int = 0, batch_axes=(),
                    head_axis=None, mesh=None, tp_axis=None):
    """Returns (out (B,S,d_model), new_cache_or_None).

    cache (decode): dict(k=(B,W,K,D), v=(B,W,K,D), pos=(B,W) int32).
    For prefill (cache is the string "init"), returns the filled cache.
    """
    window = cfg.local_window if local else 0
    B = x.shape[0]
    cd = compute_dtype

    if cache is not None and not isinstance(cache, str):
        S = x.shape[1]
        if S > 1:
            # ---- chunked prefill: S new tokens appended to the cache ----
            q, k_new, v_new = project_qkv(params, x, cfg, positions, cd)
            W = cache["k"].shape[1]
            bidx = jnp.arange(B)[:, None]
            if window > 0:
                # attend over [pre-write ring ∥ full chunk] — a ring write
                # first would drop keys that early chunk queries still need
                # whenever S > W; then apply the ring rule (last min(S, W)
                # tokens survive, slot = pos % W), matching
                # build_cache_from_prefill / the single-token decode write
                o = chunk_decode_attention(
                    q,
                    jnp.concatenate([cache["k"],
                                     k_new.astype(cache["k"].dtype)], 1),
                    jnp.concatenate([cache["v"],
                                     v_new.astype(cache["v"].dtype)], 1),
                    jnp.concatenate([cache["pos"], positions], 1),
                    positions, window=window, softcap=cfg.logit_softcap)
                m = min(S, W)
                slots = positions[:, -m:] % W
                k_cache = cache["k"].at[bidx, slots].set(
                    k_new[:, -m:].astype(cache["k"].dtype))
                v_cache = cache["v"].at[bidx, slots].set(
                    v_new[:, -m:].astype(cache["v"].dtype))
                pos_cache = cache["pos"].at[bidx, slots].set(
                    positions[:, -m:])
            else:
                k_cache = cache["k"].at[bidx, positions].set(
                    k_new.astype(cache["k"].dtype))
                v_cache = cache["v"].at[bidx, positions].set(
                    v_new.astype(cache["v"].dtype))
                pos_cache = cache["pos"].at[bidx, positions].set(positions)
                o = chunk_decode_attention(q, k_cache, v_cache, pos_cache,
                                           positions, window=window,
                                           softcap=cfg.logit_softcap)
            out = jnp.einsum("bshe,hed->bsd", o.astype(cd),
                             params["wo"].astype(cd))
            return out, {"k": k_cache, "v": v_cache, "pos": pos_cache}
        # ---- decode: single new token at absolute position `positions` ----
        q, k_new, v_new = project_qkv(params, x, cfg, positions, cd)
        if mesh is not None:
            res = sharded_decode(q, k_new, v_new, cache, positions,
                                 mesh=mesh, dp_axes=batch_axes,
                                 tp_axis=tp_axis, window=window,
                                 softcap=cfg.logit_softcap)
            if res is not None:
                o, new_cache = res
                out = jnp.einsum("bshe,hed->bsd", o.astype(cd),
                                 params["wo"].astype(cd))
                return out, new_cache
        W = cache["k"].shape[1]
        slot = (positions[:, 0] % W) if window > 0 else positions[:, 0]
        bidx = jnp.arange(B)
        k_cache = cache["k"].at[bidx, slot].set(k_new[:, 0])
        v_cache = cache["v"].at[bidx, slot].set(v_new[:, 0])
        pos_cache = cache["pos"].at[bidx, slot].set(positions[:, 0])
        o = decode_attention(q, k_cache, v_cache, pos_cache, window=window,
                             softcap=cfg.logit_softcap)
        out = jnp.einsum("bshe,hed->bsd", o.astype(cd),
                         params["wo"].astype(cd))
        return out, {"k": k_cache, "v": v_cache, "pos": pos_cache}

    q, k, v = project_qkv(params, x, cfg, positions, cd)
    if impl == "full":
        o = full_attention(q, k, v, causal=cfg.causal, window=window,
                           kv_mask=kv_mask, softcap=cfg.logit_softcap)
    elif mesh is not None:
        # manual-sharding path: no collectives inside the tile scans
        o = sharded_flash(q, k, v, mesh=mesh, dp_axes=batch_axes,
                          tp_axis=tp_axis, causal=cfg.causal,
                          window=window, softcap=cfg.logit_softcap,
                          q_block=blocks[0], kv_block=blocks[1])
    elif window > 0:
        # sliding-span O(S·w) flash path for windowed blocks
        o = local_flash_xla(q, k, v, window=window, causal=cfg.causal,
                            softcap=cfg.logit_softcap,
                            q_block=blocks[0], kv_block=blocks[1],
                            batch_axes=batch_axes, head_axis=head_axis)
    else:
        o = flash_attention_xla(q, k, v, causal=cfg.causal, window=window,
                                q_block=blocks[0], kv_block=blocks[1],
                                softcap=cfg.logit_softcap,
                                batch_axes=batch_axes, head_axis=head_axis)
    out = jnp.einsum("bshe,hed->bsd", o.astype(cd), params["wo"].astype(cd))

    new_cache = None
    if cache == "init":
        new_cache = build_cache_from_prefill(
            k, v, positions, window=window, capacity=cache_capacity,
            kv_mask=kv_mask)
    return out, new_cache


def build_cache_from_prefill(k, v, positions, *, window: int,
                             capacity: int = 0, kv_mask=None):
    """Turn prefill K/V into a decode cache.

    Full attention: cache slot = absolute position (capacity >= S + decode
    budget). Local attention: ring buffer of size ``window``; slot = pos %
    window (matching the decode-side write rule).

    ``kv_mask`` (B, S) bool, True = real token (pow2-bucketed prefill):
    right-padded entries must not enter the cache.  Full caches mark the
    padded slots empty (``pos = -1``); ring caches gather the last
    ``window`` *real* tokens per batch row instead of the array tail —
    the tail itself is padding, and a masked scatter at ``-1 % W`` would
    clobber a live slot.
    """
    B, S = k.shape[0], k.shape[1]
    pos = jnp.broadcast_to(positions, (B, S)).astype(jnp.int32)
    if window > 0:
        W = window
        if kv_mask is not None:
            # slot w holds the newest real index p ≡ w (mod W); per-batch
            # lengths make this a gather, matching the decode write rule
            L = kv_mask.astype(jnp.int32).sum(axis=1)          # (B,)
            w_ids = jnp.arange(W)[None, :]                      # (1, W)
            p = (L[:, None] - 1) - ((L[:, None] - 1 - w_ids) % W)
            valid = p >= 0
            pc = jnp.clip(p, 0)
            gather = lambda a: jnp.take_along_axis(
                a, pc.reshape(B, W, *([1] * (a.ndim - 2))), axis=1)
            cache_k = jnp.where(valid.reshape(B, W, 1, 1), gather(k), 0)
            cache_v = jnp.where(valid.reshape(B, W, 1, 1), gather(v), 0)
            cache_p = jnp.where(valid, jnp.take_along_axis(pos, pc, 1), -1)
            return {"k": cache_k.astype(k.dtype),
                    "v": cache_v.astype(v.dtype), "pos": cache_p}
        m = min(S, W)
        slots = (jnp.arange(S - m, S) % W)
        cache_k = jnp.zeros((B, W) + k.shape[2:], k.dtype).at[:, slots].set(k[:, -m:])
        cache_v = jnp.zeros((B, W) + v.shape[2:], v.dtype).at[:, slots].set(v[:, -m:])
        cache_p = jnp.full((B, W), -1, jnp.int32).at[:, slots].set(pos[:, -m:])
        return {"k": cache_k, "v": cache_v, "pos": cache_p}
    if kv_mask is not None:
        pos = jnp.where(kv_mask, pos, -1)       # padded slots stay empty
    cap = max(capacity, S)
    if cap == S:
        return {"k": k, "v": v, "pos": pos.astype(jnp.int32)}
    cache_k = jnp.zeros((B, cap) + k.shape[2:], k.dtype).at[:, :S].set(k)
    cache_v = jnp.zeros((B, cap) + v.shape[2:], v.dtype).at[:, :S].set(v)
    cache_p = jnp.full((B, cap), -1, jnp.int32).at[:, :S].set(pos)
    return {"k": cache_k, "v": cache_v, "pos": cache_p}


def init_decode_cache(cfg: ModelConfig, batch: int, max_seq: int, *,
                      local: bool, dtype=jnp.bfloat16):
    W = min(cfg.local_window, max_seq) if local else max_seq
    K, D = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, W, K, D), dtype),
        "v": jnp.zeros((batch, W, K, D), dtype),
        "pos": jnp.full((batch, W), -1, jnp.int32),
    }
