"""Mixture-of-Experts FFN.

Three execution paths:
  * ``dense``  — computes every expert for every token, weighted by gates.
                 O(E) FLOPs; the numerical oracle for tests and tiny configs.
  * ``sorted`` — dropless-with-capacity sort-based dispatch (MegaBlocks-style
                 gather/scatter, no one-hot matmuls).  Runs per data shard
                 with expert weights gathered (the paper's "ZeRO-3 sharded
                 training" baseline: parameters sharded, gathered per layer).
  * ``ep``     — expert parallelism via ``shard_map`` over the model axis:
                 expert weights stay sharded (E over model, d over data);
                 every model rank computes its local experts for the data
                 shard's tokens and partial outputs are psum-combined.
                 (beyond-paper optimization; see EXPERIMENTS.md §Perf).

Shared experts are fused into one wide MLP (a sum of independent MLPs is
exactly a block-diagonal wide MLP).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.jaxcompat import shard_map


def init_moe(key, cfg: ModelConfig, dtype=jnp.float32):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    p = {
        "router": layers.dense_init(ks[0], (d, m.n_experts), jnp.float32),
        "wi": layers.dense_init(ks[1], (m.n_experts, d, m.d_ff_expert), dtype),
        "wg": layers.dense_init(ks[2], (m.n_experts, d, m.d_ff_expert), dtype),
        "wo": layers.dense_init(ks[3], (m.n_experts, m.d_ff_expert, d), dtype),
    }
    if m.n_shared_experts:
        p["shared"] = layers.init_mlp(
            ks[4], d, m.n_shared_experts * m.d_ff_shared, cfg.act, dtype)
    return p


def route(x2d, router_w, top_k: int):
    """x2d (T, d) -> gates (T, k) fp32 (renormalized), idx (T, k) int32."""
    logits = x2d.astype(jnp.float32) @ router_w.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    # aux load-balance loss (Switch): E * sum_e f_e * p_e
    E = logits.shape[-1]
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce)
    return gates, idx.astype(jnp.int32), aux


def _expert_ffn(xe, wi, wg, wo, act: str):
    """xe (E, C, d); weights (E, d, f)/(E, f, d) -> (E, C, d)."""
    h = jnp.einsum("ecd,edf->ecf", xe, wi)
    if act == "swiglu":
        h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", xe, wg)
    elif act == "geglu":
        h = jax.nn.gelu(h) * jnp.einsum("ecd,edf->ecf", xe, wg)
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("ecf,efd->ecd", h, wo)


def default_capacity(T: int, E: int, k: int, cf: float) -> int:
    c = int(math.ceil(T * k / E * cf))
    return max(4, min(T, c))


def moe_sorted(params, x2d, cfg: ModelConfig, *, compute_dtype=jnp.bfloat16,
               capacity: Optional[int] = None, expert_slice=None):
    """Sort-based dropless-with-capacity dispatch on one token shard.

    ``expert_slice``: optional (start, count) restricting computation to a
    contiguous expert range (used by the EP path); tokens routed to other
    experts contribute zero here.
    """
    m = cfg.moe
    cd = compute_dtype
    T, d = x2d.shape
    E, k = m.n_experts, m.top_k
    gates, idx, aux = route(x2d, params["router"], k)

    C = capacity if capacity is not None else default_capacity(
        T, E, k, m.capacity_factor)

    eid = idx.reshape(-1)                       # (T*k,)
    tid = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
    gv = gates.reshape(-1)

    order = jnp.argsort(eid)                    # stable
    eid_s, tid_s, gv_s = eid[order], tid[order], gv[order]
    counts = jnp.zeros((E,), jnp.int32).at[eid_s].add(1)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(T * k, dtype=jnp.int32) - offsets[eid_s]
    keep = pos < C

    if expert_slice is not None:
        e0, en = expert_slice
        if params["wi"].shape[0] == en:
            # weights are already the local [e0, e0+en) slice (EP shard)
            wi, wg, wo = params["wi"], params["wg"], params["wo"]
        else:
            wi = jax.lax.dynamic_slice_in_dim(params["wi"], e0, en, 0)
            wg = jax.lax.dynamic_slice_in_dim(params["wg"], e0, en, 0)
            wo = jax.lax.dynamic_slice_in_dim(params["wo"], e0, en, 0)
        keep = keep & (eid_s >= e0) & (eid_s < e0 + en)
        erow = eid_s - e0
        n_local = en
    else:
        wi, wg, wo = params["wi"], params["wg"], params["wo"]
        erow = eid_s
        n_local = E

    safe_e = jnp.where(keep, erow, 0)
    safe_p = jnp.where(keep, pos, C)            # C -> dropped (mode="drop")
    xe = jnp.zeros((n_local, C, d), cd).at[safe_e, safe_p].set(
        x2d[tid_s].astype(cd) * keep[:, None].astype(cd), mode="drop")
    ye = _expert_ffn(xe, wi.astype(cd), wg.astype(cd), wo.astype(cd), cfg.act)
    contrib = ye[safe_e, jnp.minimum(safe_p, C - 1)] * \
        (gv_s * keep.astype(jnp.float32))[:, None].astype(cd)
    y = jnp.zeros((T, d), cd).at[tid_s].add(contrib)
    return y, aux


def moe_dense(params, x2d, cfg: ModelConfig, compute_dtype=jnp.bfloat16):
    """Oracle: all experts for all tokens, gate-weighted."""
    m = cfg.moe
    cd = compute_dtype
    gates, idx, aux = route(x2d, params["router"], m.top_k)
    full_gates = jnp.zeros((x2d.shape[0], m.n_experts), jnp.float32)
    full_gates = full_gates.at[
        jnp.arange(x2d.shape[0])[:, None], idx].add(gates)
    xe = jnp.broadcast_to(x2d.astype(cd)[None],
                          (m.n_experts,) + x2d.shape)
    ye = _expert_ffn(xe, params["wi"].astype(cd), params["wg"].astype(cd),
                     params["wo"].astype(cd), cfg.act)   # (E, T, d)
    y = jnp.einsum("etd,te->td", ye, full_gates.astype(cd))
    return y, aux


def apply_moe(params, x, cfg: ModelConfig, *, compute_dtype=jnp.bfloat16,
              impl: str = "sorted", pctx=None, capacity: Optional[int] = None):
    """x (B, S, d) -> (B, S, d). Adds shared-expert path if configured."""
    B, S, d = x.shape
    x2d = x.reshape(B * S, d)
    if impl == "dense":
        y2d, aux = moe_dense(params, x2d, cfg, compute_dtype)
    elif impl == "ep" and pctx is not None and pctx.mesh is not None:
        y2d, aux = _moe_ep(params, x2d, cfg, compute_dtype, pctx, capacity)
    else:
        y2d, aux = moe_sorted(params, x2d, cfg, compute_dtype=compute_dtype,
                              capacity=capacity)
    y = y2d.reshape(B, S, d)
    if cfg.moe.n_shared_experts:
        y = y + layers.apply_mlp(params["shared"], x, cfg.act, compute_dtype)
    return y, aux


# ---------------------------------------------------------------------------
# expert parallelism (shard_map over the model/tp axis)
# ---------------------------------------------------------------------------
def _moe_ep(params, x2d, cfg, compute_dtype, pctx, capacity):
    """EP: experts sharded over ``pctx.tp_axis``; tokens replicated over it.

    Every model rank computes its E/n_tp local experts for the data shard's
    tokens; partial outputs psum over the tp axis. Expert weights may carry
    an extra FSDP sharding over the data axes (gathered inside).
    """
    from jax.sharding import PartitionSpec as P
    mesh = pctx.mesh
    tp = pctx.tp_axis
    n_tp = mesh.shape[tp]
    m = cfg.moe
    assert m.n_experts % n_tp == 0, (m.n_experts, n_tp)
    e_local = m.n_experts // n_tp

    dp = tuple(pctx.dp_axes)
    n_dp_total = 1
    for a in dp:
        n_dp_total *= mesh.shape[a]

    def _fsdp_dim(shape):
        """Mirror core.policy: FSDP-shard the largest divisible non-E dim."""
        cands = [(shape[d], d) for d in (1, 2)
                 if shape[d] % n_dp_total == 0 and shape[d] >= n_dp_total]
        return max(cands)[1] if cands else None

    dims = {k: (_fsdp_dim(params[k].shape) if pctx.fsdp_experts else None)
            for k in ("wi", "wg", "wo")}

    def w_sp(k):
        ent = [tp, None, None]
        if dims[k] is not None:
            ent[dims[k]] = dp if len(dp) > 1 else dp[0]
        return P(*ent)

    x_spec = P(dp)           # (T, d): T sharded over dp, replicated over tp
    w_spec = {"router": P(), "wi": w_sp("wi"), "wg": w_sp("wg"),
              "wo": w_sp("wo")}
    eparams = {k: params[k] for k in ("router", "wi", "wg", "wo")}

    def body(ep, xs):
        gathered = {}
        for k in ("wi", "wg", "wo"):
            w = ep[k]
            if dims[k] is not None:
                w = jax.lax.all_gather(w, dp, axis=dims[k], tiled=True)
            gathered[k] = w
        ep = dict(ep, **gathered)
        rank = jax.lax.axis_index(tp)
        T = xs.shape[0]
        cap = capacity if capacity is not None else default_capacity(
            T, m.n_experts, m.top_k, m.capacity_factor)
        y, aux = moe_sorted(
            ep, xs, cfg, compute_dtype=compute_dtype, capacity=cap,
            expert_slice=(rank * e_local, e_local))
        y = jax.lax.psum(y, tp)
        # aux varies over dp shards and is duplicated over tp: global mean.
        n_dp = 1
        for a in dp:
            n_dp *= mesh.shape[a]
        aux = jax.lax.psum(aux, (tp,) + dp) / (n_tp * n_dp)
        return y, aux

    # inside a manual-axis region (the compressed pod exchange) the mesh
    # argument must be omitted so the context mesh (with its Manual axes)
    # is used; manualize only the axes this shard_map owns.
    kwargs = dict(in_specs=(w_spec, x_spec), out_specs=(x_spec, P()),
                  check_vma=False)
    try:
        am = jax.sharding.get_abstract_mesh()
        in_manual = am is not None and any(
            "Manual" in str(t) for t in getattr(am, "axis_types", ()))
    except Exception:
        in_manual = False
    if in_manual:
        own = frozenset(dp + (tp,)) - frozenset(
            a for a, t in zip(am.axis_names, am.axis_types)
            if "Manual" in str(t))
        return shard_map(body, axis_names=own, **kwargs)(eparams, x2d)
    return shard_map(body, mesh=mesh, **kwargs)(eparams, x2d)
