"""Mamba-2 (SSD, state-space duality) mixer — arXiv:2405.21060.

Training/prefill uses the chunked SSD algorithm as a ``lax.scan`` over
sequence chunks (quadratic attention-like math within a chunk; a rank-N
recurrent state carries information between chunks). This exactly mirrors
the Pallas kernel tiling in ``repro.kernels.ssd``. Decode is the linear
recurrence h <- exp(dt·A) h + dt·B⊗x.

Sharding design (the §Perf-driven layout): the input projections are
SPLIT per stream (z / x / B / C / dt) with per-stream causal convs —
mathematically identical to the fused in_proj+conv (depthwise convs are
channel-independent), but each output is independently shardable: the
fused layout's z/xbc/dt split points do not align with a model-axis
sharding of the fused dim, which forced 1.6 GiB all-to-alls per layer
(2.1 TiB/step on the 16x16 mesh).  The SSD core itself runs under
``shard_map`` (batch over dp, heads over tp — mamba2's H=48 = 16x3) so
no collective can appear inside the chunk scan.

Shapes: x (B, S, H, P); dt (B, S, H); A (H,); B/C (B, S, G, N); state
(B, H, N, P). H heads in G groups (heads share B/C within a group).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.jaxcompat import shard_map


# ---------------------------------------------------------------------------
# chunked SSD core
# ---------------------------------------------------------------------------
def ssd_chunked(x, dt, A, Bm, Cm, *, chunk: int, h0=None):
    """Returns (y (B,S,H,P), h_final (B,H,N,P)). All math fp32."""
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    hpg = H // G
    S_orig = S
    if S % chunk:
        # pad with dt=0 steps: decay=1 and zero input -> state is unchanged
        pad = chunk - S % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        S = S + pad
    nc = S // chunk

    x = x.astype(jnp.float32)
    dt = dt.astype(jnp.float32)
    A = A.astype(jnp.float32)
    Bm = Bm.astype(jnp.float32)
    Cm = Cm.astype(jnp.float32)

    xs = x.reshape(Bsz, nc, chunk, H, P).swapaxes(0, 1)
    dts = dt.reshape(Bsz, nc, chunk, H).swapaxes(0, 1)
    Bs = Bm.reshape(Bsz, nc, chunk, G, N).swapaxes(0, 1)
    Cs = Cm.reshape(Bsz, nc, chunk, G, N).swapaxes(0, 1)

    if h0 is None:
        h0 = jnp.zeros((Bsz, H, N, P), jnp.float32)

    idx = jnp.arange(chunk)
    causal = (idx[:, None] >= idx[None, :])  # (L, L)

    def step(h, inp):
        xc, dtc, Bc, Cc = inp            # (B,L,H,P), (B,L,H), (B,L,G,N)
        a = dtc * A                       # (B,L,H) log-decay (negative)
        acum = jnp.cumsum(a, axis=1)      # (B,L,H)
        # intra-chunk (attention-like dual form)
        CB = jnp.einsum("blgn,bmgn->bglm", Cc, Bc)   # (B,G,L,L)
        CB = jnp.repeat(CB, hpg, axis=1)             # (B,H,L,L)
        decay = jnp.exp(
            jnp.clip(acum[:, :, None, :] - acum[:, None, :, :], -60.0, 0.0))
        decay = jnp.where(causal[None, :, :, None], decay, 0.0)  # (B,L,L,H)
        W = CB.transpose(0, 2, 3, 1) * decay * dtc[:, None, :, :]
        y_intra = jnp.einsum("blmh,bmhp->blhp", W, xc)
        # inter-chunk (contribution of incoming state)
        Ch = jnp.broadcast_to(Cc[:, :, :, None, :],
                              (Bsz, chunk, G, hpg, N)).reshape(
            Bsz, chunk, H, N)
        y_inter = jnp.exp(acum)[..., None] * jnp.einsum(
            "blhn,bhnp->blhp", Ch, h)
        # state update
        rest = jnp.exp(jnp.clip(acum[:, -1:, :] - acum, -60.0, None))
        Bh = jnp.broadcast_to(Bc[:, :, :, None, :],
                              (Bsz, chunk, G, hpg, N)).reshape(
            Bsz, chunk, H, N)
        contrib = jnp.einsum("bmhn,bmhp->bhnp",
                             Bh * (dtc * rest)[..., None], xc)
        h_next = jnp.exp(acum[:, -1, :])[..., None, None] * h + contrib
        return h_next, y_intra + y_inter

    h_final, ys = jax.lax.scan(step, h0, (xs, dts, Bs, Cs))
    y = ys.swapaxes(0, 1).reshape(Bsz, S, H, P)
    return y[:, :S_orig], h_final


def ssd_sharded(x, dt, A, Bm, Cm, *, chunk: int, mesh, dp_axes, tp_axis):
    """SSD core under shard_map: batch over dp, heads over tp.

    Inside the manual region every tensor is local, so the chunk scan
    can emit no collectives.  Requires H % tp == 0 (mamba2: 48 = 16x3);
    falls back to the plain path otherwise.  B/C (grouped, G=1) are
    replicated over tp; dt/A/D head-tensors are tp-sliced at entry.
    """
    B, S, H, P = x.shape
    tp = mesh.shape.get(tp_axis, 1) if tp_axis else 1
    dp = tuple(a for a in dp_axes if mesh.shape.get(a, 1) > 1)
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    if (tp > 1 and H % tp) or (n_dp > 1 and B % n_dp):
        return ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)

    from jax.sharding import PartitionSpec as Pspec
    dp_e = (dp if len(dp) > 1 else dp[0]) if dp else None
    tp_e = tp_axis if tp > 1 else None
    sx = Pspec(dp_e, None, tp_e, None)
    sdt = Pspec(dp_e, None, tp_e)
    sA = Pspec(tp_e)
    sBC = Pspec(dp_e, None, None, None)
    sy = Pspec(dp_e, None, tp_e, None)
    sh = Pspec(dp_e, tp_e, None, None)

    def body(xl, dtl, Al, Bl, Cl):
        return ssd_chunked(xl, dtl, Al, Bl, Cl, chunk=chunk)

    manual = frozenset(dp) | ({tp_axis} if tp > 1 else set())
    if not manual:
        return ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
    try:
        am = jax.sharding.get_abstract_mesh()
        already = frozenset(
            a for a, t in zip(getattr(am, "axis_names", ()),
                              getattr(am, "axis_types", ()))
            if "Manual" in str(t))
    except Exception:
        already = frozenset()
    return shard_map(
        body, mesh=None if already else mesh,
        axis_names=manual - already if already else manual,
        in_specs=(sx, sdt, sA, sBC, sBC),
        out_specs=(sy, sh), check_vma=False,
    )(x, dt, A, Bm, Cm)


def ssd_decode_step(x, dt, A, Bm, Cm, h):
    """One token. x (B,H,P); dt (B,H); B/C (B,G,N); h (B,H,N,P)."""
    H, G = x.shape[1], Bm.shape[1]
    hpg = H // G
    x = x.astype(jnp.float32)
    dt = dt.astype(jnp.float32)
    a = jnp.exp(dt * A.astype(jnp.float32))                 # (B,H)
    Bh = jnp.broadcast_to(Bm.astype(jnp.float32)[:, :, None, :],
                          (x.shape[0], G, hpg, Bm.shape[-1])
                          ).reshape(x.shape[0], H, -1)       # (B,H,N)
    Ch = jnp.broadcast_to(Cm.astype(jnp.float32)[:, :, None, :],
                          (x.shape[0], G, hpg, Cm.shape[-1])
                          ).reshape(x.shape[0], H, -1)
    h_new = a[..., None, None] * h + \
        (dt[..., None] * Bh)[..., None] * x[:, :, None, :]   # (B,H,N,P)
    y = jnp.einsum("bhn,bhnp->bhp", Ch, h_new)
    return y, h_new


# ---------------------------------------------------------------------------
# causal depthwise conv1d (+ cache)
# ---------------------------------------------------------------------------
def causal_conv1d(x, w, cache=None, length=None):
    """x (B, S, C); w (K, C) depthwise. Returns (y, new_cache (B,K-1,C)).

    Implemented as K shift-and-multiply taps rather than
    ``conv_general_dilated``: a depthwise conv is opaque to the SPMD
    partitioner (its backward triggers "involuntary full rematerialization"
    — replicating the activations over the data axis and poisoning the
    sharding of everything downstream, measured at +100GiB/step of
    spurious all-reduce on the 16x16 mesh).  K static slices + FMAs are
    elementwise ops GSPMD shards perfectly, and at K=4 they cost the same
    FLOPs the conv would.

    ``length`` (B,) int32: real (unpadded) sequence lengths.  When given,
    ``new_cache`` holds the K-1 inputs *preceding position length* rather
    than the tail of the (possibly right-padded) array — required by the
    pow2-bucketed prefill, whose padded columns must not leak into the
    decode-side conv state.
    """
    K = w.shape[0]
    S = x.shape[1]
    if cache is not None:
        x_pad = jnp.concatenate([cache.astype(x.dtype), x], axis=1)
    else:
        x_pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    y = None
    for j in range(K):
        tap = jax.lax.slice_in_dim(x_pad, j, j + S, axis=1) \
            * w[j].astype(x.dtype)
        y = tap if y is None else y + tap
    if K <= 1:
        return y, None
    if length is None:
        return y, x_pad[:, -(K - 1):]
    # x_pad index of real position p is p + K - 1, so the tail inputs at
    # positions [length-K+1, length-1] sit at x_pad[length .. length+K-2]
    idx = length[:, None] + jnp.arange(K - 1)[None, :]
    new_cache = jnp.take_along_axis(x_pad, idx[:, :, None], axis=1)
    return y, new_cache


# ---------------------------------------------------------------------------
# full Mamba-2 block (split projections; see module docstring)
# ---------------------------------------------------------------------------
def init_ssm(key, cfg: ModelConfig, dtype=jnp.float32):
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    H = d_in // s.head_dim
    gn = s.n_groups * s.d_state
    ks = jax.random.split(key, 10)
    lo, hi = s.a_init_range
    A = lo + (hi - lo) * jax.random.uniform(ks[0], (H,))
    return {
        "in_z": layers.dense_init(ks[1], (d, d_in), dtype),
        "in_x": layers.dense_init(ks[2], (d, d_in), dtype),
        "in_b": layers.dense_init(ks[3], (d, gn), dtype),
        "in_c": layers.dense_init(ks[4], (d, gn), dtype),
        "in_dt": layers.dense_init(ks[5], (d, H), dtype),
        "conv_x_w": (jax.random.normal(ks[6], (s.d_conv, d_in)) /
                     math.sqrt(s.d_conv)).astype(dtype),
        "conv_x_b": jnp.zeros((d_in,), dtype),
        "conv_b_w": (jax.random.normal(ks[7], (s.d_conv, gn)) /
                     math.sqrt(s.d_conv)).astype(dtype),
        "conv_b_b": jnp.zeros((gn,), dtype),
        "conv_c_w": (jax.random.normal(ks[8], (s.d_conv, gn)) /
                     math.sqrt(s.d_conv)).astype(dtype),
        "conv_c_b": jnp.zeros((gn,), dtype),
        "A_log": jnp.log(A).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[9], (H,)) *
                    (math.log(0.1) - math.log(1e-3)) + math.log(1e-3)))
        ).astype(jnp.float32),
        "norm": layers.init_norm("rmsnorm", d_in, dtype),
        "out_proj": layers.dense_init(ks[0], (d_in, d), dtype),
    }


def apply_ssm(params, x, cfg: ModelConfig, *, compute_dtype=jnp.bfloat16,
              cache: Optional[dict] = None, build_cache: bool = False,
              pctx=None, token_mask=None):
    """x (B,S,d_model) -> (y, new_cache|None).

    cache = {"conv_x"/"conv_b"/"conv_c": (B,K-1,*), "state": (B,H,N,P)}.
    ``token_mask`` (B,S) bool, True = real token: right-padded positions
    get dt = 0 (decay 1, zero input — state passes through unchanged, the
    same trick ``ssd_chunked`` uses for its own chunk padding), and the
    conv caches are rebuilt from the true tail.
    """
    s = cfg.ssm
    cd = compute_dtype
    B, S, _ = x.shape
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    gn = s.n_groups * s.d_state
    xc = x.astype(cd)

    z = xc @ params["in_z"].astype(cd)
    xs = xc @ params["in_x"].astype(cd)
    bs = xc @ params["in_b"].astype(cd)
    cs = xc @ params["in_c"].astype(cd)
    dt = xc @ params["in_dt"].astype(cd)

    lengths = None
    if token_mask is not None and cache is None:
        lengths = token_mask.astype(jnp.int32).sum(axis=1)

    cx = cache["conv_x"] if cache is not None else None
    cb = cache["conv_b"] if cache is not None else None
    cc = cache["conv_c"] if cache is not None else None
    xs, ncx = causal_conv1d(xs, params["conv_x_w"], cache=cx,
                            length=lengths)
    bs, ncb = causal_conv1d(bs, params["conv_b_w"], cache=cb,
                            length=lengths)
    cs, ncc = causal_conv1d(cs, params["conv_c_w"], cache=cc,
                            length=lengths)
    xs = jax.nn.silu(xs + params["conv_x_b"].astype(xs.dtype))
    bs = jax.nn.silu(bs + params["conv_b_b"].astype(bs.dtype))
    cs = jax.nn.silu(cs + params["conv_c_b"].astype(cs.dtype))

    xin = xs.reshape(B, S, H, s.head_dim)
    Bm = bs.reshape(B, S, s.n_groups, s.d_state)
    Cm = cs.reshape(B, S, s.n_groups, s.d_state)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    if lengths is not None:
        dtv = jnp.where(token_mask[:, :, None], dtv, 0.0)
    A = -jnp.exp(params["A_log"])

    if cache is not None:
        y, h_new = ssd_decode_step(xin[:, 0], dtv[:, 0], A, Bm[:, 0],
                                   Cm[:, 0], cache["state"])
        y = y[:, None]
        new_cache = {"conv_x": ncx, "conv_b": ncb, "conv_c": ncc,
                     "state": h_new}
    else:
        if pctx is not None and pctx.mesh is not None:
            y, h_final = ssd_sharded(xin, dtv, A, Bm, Cm, chunk=s.chunk,
                                     mesh=pctx.mesh, dp_axes=pctx.dp_axes,
                                     tp_axis=pctx.tp_axis)
        else:
            y, h_final = ssd_chunked(xin, dtv, A, Bm, Cm, chunk=s.chunk)
        new_cache = ({"conv_x": ncx, "conv_b": ncb, "conv_c": ncc,
                      "state": h_final} if build_cache else None)

    y = y + params["D"][:, None] * xin.astype(jnp.float32)
    y = y.reshape(B, S, d_in).astype(cd)
    y = layers.apply_norm(params["norm"], y * jax.nn.silu(z), "rmsnorm",
                          cfg.norm_eps)
    out = y.astype(cd) @ params["out_proj"].astype(cd)
    return out, new_cache


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    gn = s.n_groups * s.d_state
    return {
        "conv_x": jnp.zeros((batch, s.d_conv - 1, d_in), dtype),
        "conv_b": jnp.zeros((batch, s.d_conv - 1, gn), dtype),
        "conv_c": jnp.zeros((batch, s.d_conv - 1, gn), dtype),
        "state": jnp.zeros((batch, H, s.d_state, s.head_dim), jnp.float32),
    }
