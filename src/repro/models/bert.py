"""BERT-base / BERT-large + SQuAD QA head (the paper's NLP benchmarks).

Reuses the transformer substrate with ``causal=False`` (bidirectional),
learned positions, post-LN-free GELU blocks per the published config.
The QA fine-tuning head maps final hidden states to span start/end logits
(SQuAD v1.1), which is exactly the workload the paper times in Fig 9-16.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers, lm
from repro.models.transformer import RunCtx


def init_bert_qa(key, cfg: ModelConfig, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    p = lm.init_lm(k1, cfg, dtype)
    p["segment_embed"] = layers.embed_init(k2, (2, cfg.d_model), dtype)
    p["qa_head"] = {
        "w": layers.dense_init(k3, (cfg.d_model, 2), dtype),
        "b": jnp.zeros((2,), dtype),
    }
    return p


def forward_qa(params, tokens, cfg: ModelConfig, ctx: RunCtx, *,
               segments=None, attn_mask=None):
    """tokens (B, S) -> (start_logits, end_logits) each (B, S)."""
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    hidden, _, _ = lm.forward(params, tokens, cfg, ctx,
                              positions=positions, kv_mask=attn_mask,
                              return_hidden=True)
    if segments is not None:
        hidden = hidden + params["segment_embed"].astype(hidden.dtype)[
            segments]
    logits = (hidden.astype(jnp.float32)
              @ params["qa_head"]["w"].astype(jnp.float32)
              + params["qa_head"]["b"].astype(jnp.float32))
    return logits[..., 0], logits[..., 1]


def qa_loss(params, batch, cfg: ModelConfig, ctx: RunCtx):
    """batch: tokens (B,S), start/end (B,) int32, optional mask (B,S)."""
    start_l, end_l = forward_qa(params, batch["tokens"], cfg, ctx,
                                segments=batch.get("segments"),
                                attn_mask=batch.get("mask"))

    def span_nll(logit, pos):
        logp = jax.nn.log_softmax(logit, axis=-1)
        iota = jax.lax.broadcasted_iota(jnp.int32, logp.shape, 1)
        return -jnp.sum(jnp.where(iota == pos[:, None], logp, 0.0), axis=-1)

    loss = jnp.mean(span_nll(start_l, batch["start"])
                    + span_nll(end_l, batch["end"])) / 2.0
    return loss, {"loss": loss}
