"""The paper's vision benchmarks (Table II) in pure functional JAX.

  * ResNet-50     — 25.6M params (bottleneck v1.5, [3,4,6,3])
  * MobileNetV2   — 3.4M params (inverted residuals, width 1.0)
  * YOLOv5-L      — 47M-class CSP detector *analog*: CSPDarknet-L backbone
                    + PAN-style neck + anchor heads, parameterized to match
                    the published parameter count/depth class.  NMS
                    post-processing is outside the training step, exactly
                    as in the paper's throughput measurements.

BatchNorm runs in batch-stats mode (training characterization only — the
paper measures training throughput, never eval accuracy).  All models
expose ``init(key) -> params`` and ``apply(params, images) -> logits`` and
a classification/detection loss for the benchmark train step.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.paper_bench import VisionConfig


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------
def conv_init(key, kh, kw, cin, cout, dtype=jnp.float32):
    fan_in = kh * kw * cin
    std = math.sqrt(2.0 / fan_in)
    return (jax.random.normal(key, (kh, kw, cin, cout), jnp.float32)
            * std).astype(dtype)


def conv(x, w, stride=1, groups=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)


def bn_init(c, dtype=jnp.float32):
    return {"scale": jnp.ones((c,), dtype), "bias": jnp.zeros((c,), dtype)}


def bn(params, x, eps=1e-5):
    mu = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return y * params["scale"] + params["bias"]


def conv_bn(key, cin, cout, k=3, dtype=jnp.float32):
    return {"w": conv_init(key, k, k, cin, cout, dtype),
            "bn": bn_init(cout, dtype)}


def apply_conv_bn(p, x, stride=1, act=jax.nn.relu, groups=1):
    y = bn(p["bn"], conv(x, p["w"], stride, groups))
    return act(y) if act is not None else y


# ---------------------------------------------------------------------------
# ResNet-50
# ---------------------------------------------------------------------------
_R50_STAGES = ((64, 3, 1), (128, 4, 2), (256, 6, 2), (512, 3, 2))


def init_resnet50(key, num_classes=1000, dtype=jnp.float32):
    ks = iter(jax.random.split(key, 256))
    p: Dict[str, Any] = {
        "stem": {"w": conv_init(next(ks), 7, 7, 3, 64, dtype),
                 "bn": bn_init(64, dtype)}}
    cin = 64
    for si, (width, blocks, stride) in enumerate(_R50_STAGES):
        stage = []
        for bi in range(blocks):
            s = stride if bi == 0 else 1
            blk = {
                "c1": conv_bn(next(ks), cin, width, 1, dtype),
                "c2": conv_bn(next(ks), width, width, 3, dtype),
                "c3": conv_bn(next(ks), width, width * 4, 1, dtype),
            }
            if bi == 0:
                blk["proj"] = conv_bn(next(ks), cin, width * 4, 1, dtype)
            stage.append(blk)
            cin = width * 4
        p[f"stage{si}"] = stage
    p["fc"] = {"w": (jax.random.normal(next(ks), (cin, num_classes))
                     * 0.01).astype(dtype),
               "b": jnp.zeros((num_classes,), dtype)}
    return p


def apply_resnet50(p, x):
    y = apply_conv_bn(p["stem"], x, stride=2)
    y = jax.lax.reduce_window(y, -jnp.inf, jax.lax.max, (1, 3, 3, 1),
                              (1, 2, 2, 1), "SAME")
    for si, (width, blocks, stride) in enumerate(_R50_STAGES):
        for bi, blk in enumerate(p[f"stage{si}"]):
            s = stride if bi == 0 else 1
            h = apply_conv_bn(blk["c1"], y)
            h = apply_conv_bn(blk["c2"], h, stride=s)
            h = apply_conv_bn(blk["c3"], h, act=None)
            sc = apply_conv_bn(blk["proj"], y, stride=s, act=None) \
                if "proj" in blk else y
            y = jax.nn.relu(h + sc)
    y = jnp.mean(y, axis=(1, 2))
    return y @ p["fc"]["w"] + p["fc"]["b"]


# ---------------------------------------------------------------------------
# MobileNetV2
# ---------------------------------------------------------------------------
# (expansion t, out channels c, repeats n, stride s) — the published table
_MBV2 = ((1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
         (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1))


def init_mobilenetv2(key, num_classes=1000, dtype=jnp.float32):
    ks = iter(jax.random.split(key, 256))
    p: Dict[str, Any] = {"stem": conv_bn(next(ks), 3, 32, 3, dtype)}
    cin = 32
    blocks = []
    for t, c, n, s in _MBV2:
        for i in range(n):
            hidden = cin * t
            blk = {}
            if t != 1:
                blk["expand"] = conv_bn(next(ks), cin, hidden, 1, dtype)
            blk["dw"] = conv_bn(next(ks), 1, hidden, 3, dtype)
            blk["dw"]["w"] = conv_init(next(ks), 3, 3, 1, hidden, dtype)
            blk["project"] = conv_bn(next(ks), hidden, c, 1, dtype)
            blocks.append(blk)
            cin = c
    p["blocks"] = blocks
    p["head"] = conv_bn(next(ks), cin, 1280, 1, dtype)
    p["fc"] = {"w": (jax.random.normal(next(ks), (1280, num_classes))
                     * 0.01).astype(dtype),
               "b": jnp.zeros((num_classes,), dtype)}
    return p


def _mbv2_strides():
    out = []
    for t, c, n, s in _MBV2:
        out += [s] + [1] * (n - 1)
    return out


def apply_mobilenetv2(p, x):
    relu6 = lambda v: jnp.minimum(jax.nn.relu(v), 6.0)
    y = apply_conv_bn(p["stem"], x, stride=2, act=relu6)
    for blk, stride in zip(p["blocks"], _mbv2_strides()):
        inp = y
        h = apply_conv_bn(blk["expand"], y, act=relu6) if "expand" in blk \
            else y
        hidden = h.shape[-1]
        h = relu6(bn(blk["dw"]["bn"],
                     conv(h, blk["dw"]["w"], stride, groups=hidden)))
        h = apply_conv_bn(blk["project"], h, act=None)
        if stride == 1 and inp.shape[-1] == h.shape[-1]:
            h = h + inp
        y = h
    y = apply_conv_bn(p["head"], y, act=relu6)
    y = jnp.mean(y, axis=(1, 2))
    return y @ p["fc"]["w"] + p["fc"]["b"]


# ---------------------------------------------------------------------------
# YOLOv5-L analog (CSP backbone + PAN neck + anchor heads)
# ---------------------------------------------------------------------------
def _csp_block(ks, cin, cout, n, dtype):
    """C3 block: split, n bottlenecks on one path, concat, fuse."""
    mid = cout // 2
    blk = {"cv1": conv_bn(next(ks), cin, mid, 1, dtype),
           "cv2": conv_bn(next(ks), cin, mid, 1, dtype),
           "cv3": conv_bn(next(ks), 2 * mid, cout, 1, dtype),
           "m": [{"a": conv_bn(next(ks), mid, mid, 1, dtype),
                  "b": conv_bn(next(ks), mid, mid, 3, dtype)}
                 for _ in range(n)]}
    return blk


def _apply_csp(blk, x, shortcut=True):
    silu = jax.nn.silu
    a = apply_conv_bn(blk["cv1"], x, act=silu)
    for m in blk["m"]:
        h = apply_conv_bn(m["a"], a, act=silu)
        h = apply_conv_bn(m["b"], h, act=silu)
        a = a + h if shortcut else h
    b = apply_conv_bn(blk["cv2"], x, act=silu)
    return apply_conv_bn(blk["cv3"], jnp.concatenate([a, b], -1), act=silu)


# YOLOv5-L: depth_multiple=1.0, width_multiple=1.0
_Y5L_W = (64, 128, 256, 512, 1024)
_Y5L_D = (3, 6, 9, 3)


def init_yolov5l(key, num_classes=80, dtype=jnp.float32):
    ks = iter(jax.random.split(key, 1024))
    W, D = _Y5L_W, _Y5L_D
    p: Dict[str, Any] = {"stem": conv_bn(next(ks), 3, W[0], 6, dtype)}
    # backbone
    for i in range(4):
        p[f"down{i}"] = conv_bn(next(ks), W[i], W[i + 1], 3, dtype)
        p[f"csp{i}"] = _csp_block(ks, W[i + 1], W[i + 1], D[i], dtype)
    p["sppf"] = {"cv1": conv_bn(next(ks), W[4], W[4] // 2, 1, dtype),
                 "cv2": conv_bn(next(ks), W[4] * 2, W[4], 1, dtype)}
    # PAN neck
    p["up1_cv"] = conv_bn(next(ks), W[4], W[3], 1, dtype)
    p["up1_csp"] = _csp_block(ks, W[3] * 2, W[3], D[3], dtype)
    p["up2_cv"] = conv_bn(next(ks), W[3], W[2], 1, dtype)
    p["up2_csp"] = _csp_block(ks, W[2] * 2, W[2], D[3], dtype)
    p["dn1_cv"] = conv_bn(next(ks), W[2], W[2], 3, dtype)
    p["dn1_csp"] = _csp_block(ks, W[2] + W[2], W[3], D[3], dtype)
    p["dn2_cv"] = conv_bn(next(ks), W[3], W[3], 3, dtype)
    p["dn2_csp"] = _csp_block(ks, W[3] + W[3], W[4], D[3], dtype)
    # detect heads: 3 anchors x (5 + classes) per scale
    no = 3 * (5 + num_classes)
    for i, c in enumerate((W[2], W[3], W[4])):
        p[f"head{i}"] = {"w": conv_init(next(ks), 1, 1, c, no, dtype),
                         "b": jnp.zeros((no,), dtype)}
    return p


def apply_yolov5l(p, x):
    silu = jax.nn.silu
    y = apply_conv_bn(p["stem"], x, stride=2, act=silu)
    feats = []
    for i in range(4):
        y = apply_conv_bn(p[f"down{i}"], y, stride=2, act=silu)
        y = _apply_csp(p[f"csp{i}"], y)
        feats.append(y)
    # SPPF
    h = apply_conv_bn(p["sppf"]["cv1"], y, act=silu)
    pool = lambda v: jax.lax.reduce_window(
        v, -jnp.inf, jax.lax.max, (1, 5, 5, 1), (1, 1, 1, 1), "SAME")
    p1 = pool(h); p2 = pool(p1); p3 = pool(p2)
    y = apply_conv_bn(p["sppf"]["cv2"],
                      jnp.concatenate([h, p1, p2, p3], -1), act=silu)
    c3, c4 = feats[1], feats[2]
    # top-down
    u1 = apply_conv_bn(p["up1_cv"], y, act=silu)
    up = jax.image.resize(u1, (u1.shape[0], u1.shape[1] * 2,
                               u1.shape[2] * 2, u1.shape[3]), "nearest")
    f4 = _apply_csp(p["up1_csp"], jnp.concatenate([up, c4], -1),
                    shortcut=False)
    u2 = apply_conv_bn(p["up2_cv"], f4, act=silu)
    up = jax.image.resize(u2, (u2.shape[0], u2.shape[1] * 2,
                               u2.shape[2] * 2, u2.shape[3]), "nearest")
    f3 = _apply_csp(p["up2_csp"], jnp.concatenate([up, c3], -1),
                    shortcut=False)
    # bottom-up
    d1 = apply_conv_bn(p["dn1_cv"], f3, stride=2, act=silu)
    f4b = _apply_csp(p["dn1_csp"], jnp.concatenate([d1, u2], -1),
                     shortcut=False)
    d2 = apply_conv_bn(p["dn2_cv"], f4b, stride=2, act=silu)
    f5b = _apply_csp(p["dn2_csp"], jnp.concatenate([d2, u1], -1),
                     shortcut=False)
    outs = []
    for i, f in enumerate((f3, f4b, f5b)):
        o = conv(f, p[f"head{i}"]["w"]) + p[f"head{i}"]["b"]
        outs.append(o)
    return outs


# ---------------------------------------------------------------------------
# registry + losses
# ---------------------------------------------------------------------------
VISION_MODELS = {
    "resnet50": (init_resnet50, apply_resnet50),
    "mobilenetv2": (init_mobilenetv2, apply_mobilenetv2),
    "yolov5l": (init_yolov5l, apply_yolov5l),
}


def init_vision(key, cfg: VisionConfig, dtype=jnp.float32):
    init, _ = VISION_MODELS[cfg.arch]
    return init(key, cfg.num_classes, dtype)


def apply_vision(params, images, cfg: VisionConfig):
    _, apply = VISION_MODELS[cfg.arch]
    return apply(params, images)


def classification_loss(params, batch, cfg: VisionConfig):
    logits = apply_vision(params, batch["images"], cfg)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    nll = -jnp.take_along_axis(logp, batch["labels"][:, None], -1)[:, 0]
    return jnp.mean(nll)


def detection_loss(params, batch, cfg: VisionConfig):
    """Dense objectness/box/class surrogate (training-throughput workload
    only — matches the paper's measurement, which never inspects mAP)."""
    outs = apply_yolov5l(params, batch["images"])
    loss = 0.0
    for o, tgt in zip(outs, batch["targets"]):
        loss = loss + jnp.mean(jnp.square(o.astype(jnp.float32) - tgt))
    return loss


def vision_loss(params, batch, cfg: VisionConfig):
    if cfg.arch == "yolov5l":
        return detection_loss(params, batch, cfg)
    return classification_loss(params, batch, cfg)


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params)
               if hasattr(x, "size"))
