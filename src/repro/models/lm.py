"""LM wrapper: embeddings -> stack -> final norm -> head (+ losses).

Handles the three input modes of the assigned pool:
  * tokens       — usual LM (int32 token ids)
  * embeddings   — VLM/audio stubs: ``input_specs()`` feeds precomputed
                   patch/frame embeddings (B, S, d_model) straight to the
                   stack (the modality frontend is out of scope per the
                   assignment); labels remain token ids for the LM head.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers, transformer
from repro.models.transformer import RunCtx


def init_lm(key, cfg: ModelConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {
        "embed": layers.init_embedding(ks[0], cfg.padded_vocab, cfg.d_model,
                                       dtype),
        "stack": transformer.init_stack(ks[1], cfg, dtype),
        "final_norm": layers.init_norm(cfg.norm, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        p["head"] = {"table": layers.embed_init(
            ks[2], (cfg.padded_vocab, cfg.d_model), dtype)}
    if cfg.pos_embedding == "learned":
        p["pos_embed"] = layers.embed_init(ks[3], (cfg.max_seq, cfg.d_model),
                                           dtype)
    return p


def embed_inputs(params, inputs, cfg: ModelConfig, ctx: RunCtx, positions):
    cd = ctx.compute_dtype
    if cfg.input_mode == "embeddings":
        x = inputs.astype(cd)
    else:
        x = layers.embed_tokens(params["embed"], inputs, cd)
    if cfg.pos_embedding == "sinusoidal":
        x = x + layers.sinusoidal_positions(positions, cfg.d_model, cd)
    elif cfg.pos_embedding == "learned":
        x = x + params["pos_embed"].astype(cd)[positions]
    return x


def head_table(params, cfg: ModelConfig):
    return (params["embed"]["table"] if cfg.tie_embeddings
            else params["head"]["table"])


def forward(params, inputs, cfg: ModelConfig, ctx: RunCtx, *,
            positions=None, caches=None, kv_mask=None,
            return_hidden: bool = False):
    """Returns (logits_or_hidden, new_caches, aux)."""
    B = inputs.shape[0]
    S = inputs.shape[1]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    x = embed_inputs(params, inputs, cfg, ctx, positions)
    x, new_caches, aux = transformer.apply_stack(
        params["stack"], x, cfg, ctx, positions=positions, caches=caches,
        kv_mask=kv_mask)
    x = layers.apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    if return_hidden:
        return x, new_caches, aux
    logits = layers.unembed(head_table(params, cfg), x, ctx.compute_dtype)
    return logits, new_caches, aux


def lm_loss(params, batch, cfg: ModelConfig, ctx: RunCtx, *,
            xent_chunk: int = 0, aux_weight: float = 0.01):
    """batch: {"inputs": tokens|embeds, "labels": (B,S) int32,
    optional "mask": (B,S)}. Returns (loss, metrics)."""
    hidden, _, aux = forward(params, batch["inputs"], cfg, ctx,
                             return_hidden=True)
    table = head_table(params, cfg)
    mask = batch.get("mask")
    if xent_chunk and hidden.shape[1] % xent_chunk == 0:
        xent = layers.chunked_softmax_xent(
            hidden, table, batch["labels"], chunk=xent_chunk,
            compute_dtype=ctx.compute_dtype, mask=mask)
    else:
        logits = layers.unembed(table, hidden, ctx.compute_dtype)
        xent = layers.softmax_xent(logits, batch["labels"], mask)
    loss = xent + aux_weight * aux
    return loss, {"loss": loss, "xent": xent, "aux": aux}
