"""RG-LRU recurrent block (Griffin / RecurrentGemma) — arXiv:2402.19427.

Recurrence (per channel):
    r_t = sigmoid(W_a x_t + b_a)            # recurrence gate
    i_t = sigmoid(W_x x_t + b_x)            # input gate
    log a_t = -c * softplus(Lambda) * r_t
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill uses ``jax.lax.associative_scan`` (log-depth parallel scan);
decode is the single-step recurrence. Gates are block-diagonal (8 blocks), as
in Griffin. The full recurrent block is:
    x -> [linear -> gelu]  (gate branch)
      -> [linear -> causal conv1d -> RG-LRU] (recurrent branch)
    y = gate * recurrent -> linear out
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.models.ssm import causal_conv1d

N_GATE_BLOCKS = 8


def init_rglru(key, cfg: ModelConfig, dtype=jnp.float32):
    r = cfg.rglru
    d = cfg.d_model
    w = r.lru_width or d
    nb = N_GATE_BLOCKS
    assert w % nb == 0
    ks = jax.random.split(key, 7)
    # Lambda init so that a^c in [0.9, 0.999] at r=1 (Griffin appendix)
    u = jax.random.uniform(ks[0], (w,), minval=0.9 ** 2, maxval=0.999 ** 2)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / (2 * r.c)))
    return {
        "in_gate": layers.dense_init(ks[1], (d, w), dtype),
        "in_rec": layers.dense_init(ks[2], (d, w), dtype),
        "conv_w": (jax.random.normal(ks[3], (r.d_conv, w)) /
                   math.sqrt(r.d_conv)).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "wa": layers.dense_init(ks[4], (nb, w // nb, w // nb), jnp.float32),
        "ba": jnp.zeros((w,), jnp.float32),
        "wx": layers.dense_init(ks[5], (nb, w // nb, w // nb), jnp.float32),
        "bx": jnp.zeros((w,), jnp.float32),
        "lam": lam.astype(jnp.float32),
        "out_proj": layers.dense_init(ks[6], (w, d), dtype),
    }


def _block_diag(x, w, b, batch_axes=(), model_axis=None):
    """x (..., W) with W = nb * bs; w (nb, bs, bs)."""
    nb, bs, _ = w.shape

    def pin(t):
        if not batch_axes and model_axis is None:
            return t
        from jax.sharding import PartitionSpec as P
        entries = [None] * t.ndim
        if batch_axes:
            entries[0] = (tuple(batch_axes) if len(batch_axes) > 1
                          else batch_axes[0])
        # NOTE: do NOT pin the bs sub-dim — a W-contiguous model shard and
        # a per-block bs shard are different layouts; forcing the latter
        # costs an all-to-all per gate (measured +2.6s/step; §Perf log)
        try:
            return jax.lax.with_sharding_constraint(t, P(*entries))
        except (ValueError, RuntimeError):
            return t

    xb = x.reshape(x.shape[:-1] + (nb, bs))
    y = jnp.einsum("...nb,nbc->...nc", xb, w)
    return y.reshape(x.shape[:-1] + (nb * bs,)) + b


def rglru_gates(params, x, c: float, batch_axes=(), model_axis=None):
    """x (B,S,W) fp32 -> (log_a (B,S,W), gated_in (B,S,W)).

    The block-diag einsum reshapes W -> (nb, bs); pinning the bs sub-dim
    to the model axis keeps the gate matmul a local-partial + reduce
    instead of a full re-layout of the (B,S,W) fp32 stream."""
    xf = x.astype(jnp.float32)
    xf = _constrain_bw(xf, batch_axes, model_axis)
    r = jax.nn.sigmoid(_block_diag(xf, params["wa"], params["ba"],
                                   batch_axes, model_axis))
    i = jax.nn.sigmoid(_block_diag(xf, params["wx"], params["bx"],
                                   batch_axes, model_axis))
    log_a = -c * jax.nn.softplus(params["lam"]) * r
    a2 = jnp.exp(2.0 * log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-12)) * (i * xf)
    return (_constrain_bw(log_a, batch_axes, model_axis),
            _constrain_bw(gated, batch_axes, model_axis))


def rglru_scan(log_a, gated, h0=None):
    """Parallel linear recurrence via associative scan over S."""
    a = jnp.exp(log_a)
    b = gated
    if h0 is not None:
        # fold the initial state into the first step
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(l, r_):
        a1, b1 = l
        a2, b2 = r_
        return a1 * a2, a2 * b1 + b2

    _, hs = jax.lax.associative_scan(combine, (a, b), axis=1)
    return hs


def _constrain_bw(x, batch_axes, model_axis):
    """Pin (batch, ..., width) sharding inside the chunk scan."""
    if not batch_axes and model_axis is None:
        return x
    from jax.sharding import PartitionSpec as P
    entries = [None] * x.ndim
    if batch_axes:
        entries[0] = (tuple(batch_axes) if len(batch_axes) > 1
                      else batch_axes[0])
    if model_axis is not None:
        entries[-1] = model_axis
    try:
        return jax.lax.with_sharding_constraint(x, P(*entries))
    except (ValueError, RuntimeError):
        return x


def rglru_scan_chunked(log_a, gated, *, chunk: int = 256, h0=None,
                       batch_axes=(), model_axis=None):
    """Sequence-chunked linear recurrence (sharding-friendly).

    A whole-sequence ``associative_scan`` makes GSPMD re-lay out the full
    (B, S, W) fp32 tensor at every log-step — measured as ~10 GiB/device
    all-gathers on the 16x16 mesh (an OOM on real HBM).  Scanning chunks
    of ``chunk`` tokens keeps the parallel scan inside a (B, c, W) block
    whose batch/width shardings are pinned; the carry is the (B, W) state.
    """
    B, S, W = log_a.shape
    c = min(chunk, S)
    if S % c:
        return rglru_scan(log_a, gated, h0=h0)
    nc = S // c
    la = log_a.reshape(B, nc, c, W).swapaxes(0, 1)
    gg = gated.reshape(B, nc, c, W).swapaxes(0, 1)
    h_init = jnp.zeros((B, W), jnp.float32) if h0 is None else h0

    def body(h, inp):
        la_c, g_c = inp
        la_c = _constrain_bw(la_c, batch_axes, model_axis)
        g_c = _constrain_bw(g_c, batch_axes, model_axis)
        hs = rglru_scan(la_c, g_c, h0=h)
        hs = _constrain_bw(hs, batch_axes, model_axis)
        return hs[:, -1], hs

    h_final, hs = jax.lax.scan(body, h_init, (la, gg))
    return hs.swapaxes(0, 1).reshape(B, S, W)


def rglru_decode_step(log_a, gated, h):
    return jnp.exp(log_a) * h + gated


def apply_rglru(params, x, cfg: ModelConfig, *, compute_dtype=jnp.bfloat16,
                cache: Optional[dict] = None, build_cache: bool = False,
                batch_axes=(), model_axis=None, token_mask=None):
    """x (B,S,d_model) -> (y, new_cache|None).

    cache = {"conv": (B,K-1,W), "state": (B,W) fp32}.
    ``token_mask`` (B,S) bool, True = real token: right-padded positions
    become identity recurrence steps (a = 1, input contribution 0), so
    the cached state is exactly the state after the last real token; the
    conv cache is rebuilt from the true tail.
    """
    r = cfg.rglru
    cd = compute_dtype
    gate = jax.nn.gelu(x.astype(cd) @ params["in_gate"].astype(cd))
    rec = x.astype(cd) @ params["in_rec"].astype(cd)
    lengths = None
    if token_mask is not None and cache is None:
        lengths = token_mask.astype(jnp.int32).sum(axis=1)
    conv_cache = cache["conv"] if cache is not None else None
    rec, new_conv = causal_conv1d(rec, params["conv_w"], cache=conv_cache,
                                  length=lengths)
    rec = rec + params["conv_b"].astype(rec.dtype)
    log_a, gated = rglru_gates(params, rec, r.c, batch_axes, model_axis)
    if lengths is not None:
        keep = token_mask[:, :, None]
        log_a = jnp.where(keep, log_a, 0.0)    # a = 1: state unchanged
        gated = jnp.where(keep, gated, 0.0)    # no padded input folded in

    if cache is not None:
        h = rglru_decode_step(log_a[:, 0], gated[:, 0], cache["state"])
        hs = h[:, None]
        new_cache = {"conv": new_conv, "state": h}
    else:
        hs = rglru_scan_chunked(log_a, gated, batch_axes=batch_axes,
                                model_axis=model_axis)
        new_cache = ({"conv": new_conv, "state": hs[:, -1]}
                     if build_cache else None)

    hs = _constrain_bw(hs, batch_axes, model_axis)
    gate = _constrain_bw(gate, batch_axes, model_axis)
    prod = _constrain_bw(hs.astype(cd) * gate, batch_axes, model_axis)
    y = prod @ params["out_proj"].astype(cd)
    y = _constrain_bw(y, batch_axes, None)
    return y, new_cache


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    r = cfg.rglru
    w = r.lru_width or cfg.d_model
    return {
        "conv": jnp.zeros((batch, r.d_conv - 1, w), dtype),
        "state": jnp.zeros((batch, w), jnp.float32),
    }
