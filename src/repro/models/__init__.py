"""Model zoo: pure-JAX functional models (params = pytrees of arrays)."""
