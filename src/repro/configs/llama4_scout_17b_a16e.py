"""llama4-scout-17b-a16e [moe] — MoE, early fusion.

48L d_model=5120, 40H (GQA kv=8), d_ff=8192, vocab=202048, MoE 16e top-1
(+ shared expert, Llama-4 style). hf:meta-llama/Llama-4-Scout-17B-16E.
"""
from repro.configs.base import ModelConfig, MoEConfig, ATTN

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    block_pattern=(ATTN,) * 48,
    act="swiglu",
    norm="rmsnorm",
    rope_theta=500000.0,
    moe=MoEConfig(n_experts=16, top_k=1, d_ff_expert=8192,
                  n_shared_experts=1, d_ff_shared=8192),
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
