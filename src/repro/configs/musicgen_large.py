"""musicgen-large [audio] — decoder-only over EnCodec tokens, arXiv:2306.05284.
48L d_model=2048, 32H (kv=32 -> full MHA), d_ff=8192, vocab=2048 (codebook).

The EnCodec frontend is a STUB: ``input_specs()`` provides precomputed frame
embeddings (sum of the 4 codebook embeddings, as in the delay-pattern
interleaving). Sinusoidal positions per the paper.
"""
from repro.configs.base import ModelConfig, ATTN

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    block_pattern=(ATTN,) * 48,
    act="gelu",
    norm="layernorm",
    pos_embedding="sinusoidal",
    input_mode="embeddings",
    source="arXiv:2306.05284",
)
