"""The paper's own five DL benchmarks (Table II), re-implemented in JAX.

| benchmark    | domain | params | depth |
|--------------|--------|--------|-------|
| MobileNetV2  | vision |  3.4M  |  53   |
| ResNet-50    | vision | 25.6M  |  50   |
| YOLOv5-L     | vision |   47M  | 392   |
| BERT-base    | NLP QA |  110M  |  12   |
| BERT-large   | NLP QA |  340M  |  24   |

The vision models use ``VisionConfig`` (see ``repro.models.vision``); BERT
reuses ``ModelConfig`` with ``causal=False`` + learned positions
(see ``repro.models.bert``). Paper batch sizes from §V-C-1 are recorded so the
benchmark harness reproduces the paper's exact workload points.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

from repro.configs.base import ModelConfig, ATTN


@dataclasses.dataclass(frozen=True)
class VisionConfig:
    name: str
    arch: str                  # resnet50 | mobilenetv2 | yolov5l
    image_size: int
    num_classes: int
    width_mult: float = 1.0


RESNET50 = VisionConfig("resnet50", "resnet50", 224, 1000)
MOBILENETV2 = VisionConfig("mobilenetv2", "mobilenetv2", 224, 1000)
YOLOV5L = VisionConfig("yolov5l", "yolov5l", 640, 80)

BERT_BASE = ModelConfig(
    name="bert-base",
    family="nlp-encoder",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=30522,
    block_pattern=(ATTN,) * 12,
    act="gelu",
    norm="layernorm",
    causal=False,
    pos_embedding="learned",
    qkv_bias=True,
    tie_embeddings=True,
    max_seq=512,
    source="arXiv:1810.04805",
)

BERT_LARGE = dataclasses.replace(
    BERT_BASE,
    name="bert-large",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    block_pattern=(ATTN,) * 24,
)

# Paper §V-C-1 workload points (per-benchmark batch size & seq/image size).
@dataclasses.dataclass(frozen=True)
class PaperWorkload:
    name: str
    batch_size: int        # per the paper (global, 8 GPUs)
    seq_or_img: int
    params_paper: float    # parameter count claimed by paper Table II
    domain: str


PAPER_WORKLOADS: Tuple[PaperWorkload, ...] = (
    PaperWorkload("mobilenetv2", 64, 224, 3.4e6, "vision"),
    PaperWorkload("resnet50", 128, 224, 25.6e6, "vision"),
    PaperWorkload("yolov5l", 88, 640, 47e6, "vision"),
    PaperWorkload("bert-base", 96, 384, 110e6, "nlp"),
    PaperWorkload("bert-large", 48, 384, 340e6, "nlp"),
)
