"""moonshot-v1-16b-a3b [moe] — kimi/moonlight, 64e top-6.

48L d_model=2048, 16H (GQA kv=16 -> full MHA), d_ff=1408 per expert,
vocab=163840, MoE 64e top-6 + 2 shared experts (DeepSeek-style fine-grained).
hf:moonshotai/Moonlight-16B-A3B.
"""
from repro.configs.base import ModelConfig, MoEConfig, ATTN

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=163840,
    block_pattern=(ATTN,) * 48,
    act="swiglu",
    norm="rmsnorm",
    rope_theta=50000.0,
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408,
                  n_shared_experts=2, d_ff_shared=1408),
    source="hf:moonshotai/Moonlight-16B-A3B",
)
