"""qwen2-0.5b [dense] — GQA, QKV bias. 24L d_model=896, 14H (GQA kv=2),
d_ff=4864, vocab=151936. arXiv:2407.10671."""
from repro.configs.base import ModelConfig, ATTN

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151936,
    block_pattern=(ATTN,) * 24,
    act="swiglu",
    norm="rmsnorm",
    qkv_bias=True,
    rope_theta=1000000.0,
    tie_embeddings=True,
    source="arXiv:2407.10671",
)
