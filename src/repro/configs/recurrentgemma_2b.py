"""recurrentgemma-2b [hybrid] — RG-LRU + local attn, 1:2 (Griffin pattern:
two recurrent blocks then one local-attention block). arXiv:2402.19427.
26L d_model=2560, 10H (MQA kv=1), d_ff=7680, vocab=256000, window=2048."""
from repro.configs.base import ModelConfig, RGLRUConfig, RGLRU, ATTN_LOCAL

# (R, R, A) repeated; 26 = 8*3 + 2 -> trailing (R, R)
_PATTERN = tuple((RGLRU, RGLRU, ATTN_LOCAL) * 8) + (RGLRU, RGLRU)
assert len(_PATTERN) == 26

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab_size=256000,
    block_pattern=_PATTERN,
    act="geglu",
    norm="rmsnorm",
    local_window=2048,
    rope_theta=10000.0,
    tie_embeddings=True,
    rglru=RGLRUConfig(lru_width=2560, d_conv=4),
    source="arXiv:2402.19427",
)
