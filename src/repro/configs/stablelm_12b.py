"""stablelm-12b [dense] — 40L d_model=5120, 32H (GQA kv=8), d_ff=13824,
vocab=100352; partial rotary (25%), LayerNorm, parallel residual per the
StableLM-2 family. hf:stabilityai/stablelm-2-12b."""
from repro.configs.base import ModelConfig, ATTN

CONFIG = ModelConfig(
    name="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=13824,
    vocab_size=100352,
    block_pattern=(ATTN,) * 40,
    act="swiglu",
    norm="layernorm",
    rope_fraction=0.25,
    parallel_residual=True,
    qk_norm=True,           # stablelm-2-12b uses per-head qk layernorm
    rope_theta=10000.0,
    source="hf:stabilityai/stablelm-2-12b",
)
