"""Configuration dataclasses for models, shapes, and parallelism policies.

Every assigned architecture gets a ``ModelConfig`` in its own module under
``repro.configs``; the registry in ``repro.configs.__init__`` maps arch ids
(``--arch mamba2-780m``) to configs.  Shape sets (train_4k / prefill_32k /
decode_32k / long_500k) are global for the LM family, per the assignment.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# ---------------------------------------------------------------------------
# Block types that a transformer stack can be composed of.
# ---------------------------------------------------------------------------
ATTN = "attn"                # global (causal) attention
ATTN_LOCAL = "attn_local"    # sliding-window attention
SSM = "ssm"                  # Mamba-2 SSD mixer
RGLRU = "rglru"              # RG-LRU recurrent block (Griffin)

BLOCK_TYPES = (ATTN, ATTN_LOCAL, SSM, RGLRU)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    d_ff_shared: int = 0            # per shared expert
    router_jitter: float = 0.0
    # capacity factor for dropless-ish dispatch accounting (dense einsum path
    # computes all experts; EP path uses capacity buckets)
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256                # SSD chunk length
    a_init_range: Tuple[float, float] = (1.0, 16.0)


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int = 0              # 0 -> d_model
    d_conv: int = 4
    c: float = 8.0                  # recurrent gate sharpness constant


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                 # 0 -> d_model // n_heads
    block_pattern: Tuple[str, ...] = ()   # () -> all ATTN
    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0      # partial rotary (stablelm)
    local_window: int = 2048        # for ATTN_LOCAL blocks
    logit_softcap: float = 0.0
    causal: bool = True             # False -> bidirectional encoder (BERT)
    # ffn / norm details
    act: str = "swiglu"             # swiglu | geglu | gelu
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    norm_eps: float = 1e-5
    parallel_residual: bool = False # attn & ffn from same normed input
    tie_embeddings: bool = False
    pos_embedding: str = "rope"     # rope | sinusoidal | none
    # sub-configs
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    # io
    input_mode: str = "tokens"      # tokens | embeddings (vlm/audio stubs)
    max_seq: int = 524_288
    # provenance
    source: str = ""

    # ---------------------------------------------------------- derived ----
    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, 128)

    @property
    def pattern(self) -> Tuple[str, ...]:
        if self.block_pattern:
            assert len(self.block_pattern) == self.n_layers
            return self.block_pattern
        return (ATTN,) * self.n_layers

    @property
    def attention_free(self) -> bool:
        return all(b in (SSM, RGLRU) for b in self.pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True if no block attends over unbounded (full-seq) context."""
        return all(b != ATTN for b in self.pattern)

    # ------------------------------------------------------ param counts ---
    def param_count(self) -> int:
        """Analytic parameter count (physical, incl. vocab padding)."""
        d, hd = self.d_model, self.head_dim
        n_embed = self.padded_vocab * d
        total = n_embed if self.tie_embeddings else 2 * n_embed
        for blk in self.pattern:
            total += 2 * d  # two norms per block (or one for pure mixers)
            if blk in (ATTN, ATTN_LOCAL):
                qkv = d * (self.n_heads + 2 * self.n_kv_heads) * hd
                if self.qkv_bias:
                    qkv += (self.n_heads + 2 * self.n_kv_heads) * hd
                total += qkv + self.n_heads * hd * d
            elif blk == SSM:
                total += self._ssm_params()
            elif blk == RGLRU:
                total += self._rglru_params()
            if blk in (ATTN, ATTN_LOCAL, SSM, RGLRU):
                total += self._ffn_params(blk)
        return total

    def _ffn_params(self, blk: str) -> int:
        d = self.d_model
        if self.moe is not None:
            m = self.moe
            per = 3 * d * m.d_ff_expert if self.act in ("swiglu", "geglu") \
                else 2 * d * m.d_ff_expert
            shared = m.n_shared_experts * (
                3 * d * m.d_ff_shared if self.act in ("swiglu", "geglu")
                else 2 * d * m.d_ff_shared)
            router = d * m.n_experts
            return m.n_experts * per + shared + router
        if self.d_ff == 0:
            return 0
        mult = 3 if self.act in ("swiglu", "geglu") else 2
        return mult * self.d_model * self.d_ff

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE top-k instead of all experts)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        total = self.param_count()
        per = (3 if self.act in ("swiglu", "geglu") else 2) * self.d_model * m.d_ff_expert
        n_moe_layers = sum(1 for b in self.pattern if b in (ATTN, ATTN_LOCAL, SSM, RGLRU))
        total -= n_moe_layers * (m.n_experts - m.top_k) * per
        return total

    def _ssm_params(self) -> int:
        assert self.ssm is not None
        s, d = self.ssm, self.d_model
        d_in = s.expand * d
        nheads = d_in // s.head_dim
        in_proj = d * (2 * d_in + 2 * s.n_groups * s.d_state + nheads)
        conv = s.d_conv * (d_in + 2 * s.n_groups * s.d_state)
        extra = 2 * nheads + d_in   # A_log, D, dt_bias-ish + norm gate
        out_proj = d_in * d
        return in_proj + conv + extra + out_proj

    def _rglru_params(self) -> int:
        assert self.rglru is not None
        r, d = self.rglru, self.d_model
        w = r.lru_width or d
        # in: two branches d->w; conv; rg-lru gates (2 * w * w/heads... use
        # diagonal-block gates: 2 dense w->w per Griffin's block-diag approx)
        return d * w * 2 + r.d_conv * w + 2 * w * w // 8 + 2 * w + w * d


# ---------------------------------------------------------------------------
# Shapes (assignment: LM family, seq_len x global_batch)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def applicable_shapes(cfg: ModelConfig) -> Sequence[ShapeConfig]:
    """All four shapes, minus long_500k for pure full-attention archs."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.sub_quadratic:
        out.append(LONG_500K)
    return out


# ---------------------------------------------------------------------------
# Parallelism / execution policy
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PolicyConfig:
    """How a model is laid out on a composed mesh.

    ``fsdp_axis`` shards parameters/optimizer state (ZeRO-3 analogue);
    ``dp_axes`` shard the batch; ``tp_axis`` (same physical axis as fsdp by
    default on the 2D mesh) shards experts (EP) and, when enabled, FFN/head
    dims (TP).  The paper's software-optimization ladder maps to:
      DP        -> zero_stage=0, no fsdp (params replicated)
      DDP       -> zero_stage=0 with bucketed/overlapped grad psum
      mixed     -> compute_dtype=bf16
      sharded   -> zero_stage=3 (fsdp_axis active)
    """
    dp_axes: Tuple[str, ...] = ("data",)
    fsdp_axes: Tuple[str, ...] = ("data",)
    tp_axis: Optional[str] = "model"
    ep: bool = True                 # experts over tp_axis
    tp_ffn: bool = False            # Megatron-style FFN TP (perf option)
    tp_attn_heads: bool = False     # head TP where divisible (perf option)
    sp: bool = False                # shard sequence over tp_axis in mixers
    zero_stage: int = 3             # 0|1|3
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"
    accum_dtype: str = "float32"
    remat: str = "block"            # none | block | full
    grad_accum: int = 1
    hierarchical_allreduce: bool = True   # fast-domain first (multi-pod)
    grad_compression: str = "none"  # none | int8_ef
    attn_impl: str = "xla"          # xla (chunked flash, CPU-lowerable) | pallas
    scan_layers: bool = True
    offload_activations: bool = False


DEFAULT_POLICY = PolicyConfig()
