"""llava-next-mistral-7b [vlm] — anyres tiling; the Mistral-7B backbone only.
32L d_model=4096, 32H (GQA kv=8), d_ff=14336, vocab=32000.

Per the assignment, the vision frontend (anyres patch tiling + projector) is a
STUB: ``input_specs()`` feeds precomputed patch/text embeddings directly into
the backbone (``input_mode="embeddings"``).
hf:llava-hf/llava-v1.6-mistral-7b-hf."""
from repro.configs.base import ModelConfig, ATTN

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    block_pattern=(ATTN,) * 32,
    act="swiglu",
    norm="rmsnorm",
    rope_theta=1000000.0,
    input_mode="embeddings",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
