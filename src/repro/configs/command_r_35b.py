"""command-r-35b [dense] — GQA, no-bias, parallel residual.
40L d_model=8192, 64H (GQA kv=8), d_ff=22528, vocab=256000.
hf:CohereForAI/c4ai-command-r-v01."""
from repro.configs.base import ModelConfig, ATTN

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab_size=256000,
    block_pattern=(ATTN,) * 40,
    act="swiglu",
    norm="layernorm",     # cohere uses LayerNorm (no bias)
    parallel_residual=True,
    rope_theta=8000000.0,
    tie_embeddings=True,
    qkv_bias=False,
    source="hf:CohereForAI/c4ai-command-r-v01",
)
