"""mamba2-780m [ssm] — SSD (state-space duality), arXiv:2405.21060.

48L d_model=1536, attention-free (d_ff=0: the SSD mixer is the whole block),
vocab=50280, ssm_state=128.
"""
from repro.configs.base import ModelConfig, SSMConfig, SSM

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=48,            # d_inner(=2*1536=3072) / head_dim(64)
    n_kv_heads=48,
    d_ff=0,
    vocab_size=50280,
    block_pattern=(SSM,) * 48,
    norm="rmsnorm",
    pos_embedding="none",
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1,
                  chunk=256),
    source="arXiv:2405.21060",
)
