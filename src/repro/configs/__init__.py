"""Architecture registry: ``--arch <id>`` -> ModelConfig.

``get_config(arch)`` resolves any assigned architecture; ``reduced(cfg)``
produces the small same-family config used by CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import (  # noqa: F401 (re-export)
    ATTN, ATTN_LOCAL, RGLRU, SSM,
    DEFAULT_POLICY, LONG_500K, DECODE_32K, PREFILL_32K, TRAIN_4K, SHAPES,
    ModelConfig, MoEConfig, PolicyConfig, RGLRUConfig, SSMConfig, ShapeConfig,
    applicable_shapes,
)

from repro.configs.mamba2_780m import CONFIG as _mamba2
from repro.configs.llama4_scout_17b_a16e import CONFIG as _llama4
from repro.configs.moonshot_v1_16b_a3b import CONFIG as _moonshot
from repro.configs.llama3_2_3b import CONFIG as _llama32
from repro.configs.command_r_35b import CONFIG as _commandr
from repro.configs.qwen2_0_5b import CONFIG as _qwen2
from repro.configs.stablelm_12b import CONFIG as _stablelm
from repro.configs.llava_next_mistral_7b import CONFIG as _llava
from repro.configs.musicgen_large import CONFIG as _musicgen
from repro.configs.recurrentgemma_2b import CONFIG as _rgemma
from repro.configs.paper_bench import BERT_BASE, BERT_LARGE

REGISTRY: Dict[str, ModelConfig] = {
    c.name: c
    for c in (
        _mamba2, _llama4, _moonshot, _llama32, _commandr, _qwen2, _stablelm,
        _llava, _musicgen, _rgemma, BERT_BASE, BERT_LARGE,
    )
}

ASSIGNED_ARCHS = (
    "mamba2-780m",
    "llama4-scout-17b-a16e",
    "moonshot-v1-16b-a3b",
    "llama3.2-3b",
    "command-r-35b",
    "qwen2-0.5b",
    "stablelm-12b",
    "llava-next-mistral-7b",
    "musicgen-large",
    "recurrentgemma-2b",
)


def get_config(arch: str) -> ModelConfig:
    try:
        return REGISTRY[arch]
    except KeyError:
        raise KeyError(
            f"unknown arch {arch!r}; known: {sorted(REGISTRY)}") from None


def reduced(cfg: ModelConfig, n_layers: int = 2, width_div: int = 8,
            vocab: int = 512) -> ModelConfig:
    """Small same-family config for CPU smoke tests.

    Keeps the block pattern *shape* (first ``n_layers`` entries of the real
    pattern, so hybrids keep their mixed block types), shrinks widths and
    vocab, keeps head_dim MXU-ish (>= 8).
    """
    d_model = max(64, cfg.d_model // width_div)
    n_heads = max(2, cfg.n_heads // 4)
    while d_model % n_heads:
        n_heads -= 1
    n_kv = max(1, min(cfg.n_kv_heads, n_heads))
    while n_heads % n_kv:
        n_kv -= 1
    d_head = max(8, d_model // n_heads)
    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(
            cfg.moe, n_experts=max(4, cfg.moe.n_experts // 8),
            top_k=min(cfg.moe.top_k, 2),
            d_ff_expert=max(32, cfg.moe.d_ff_expert // width_div),
            d_ff_shared=max(32, cfg.moe.d_ff_shared // width_div)
            if cfg.moe.n_shared_experts else 0)
    ssm = None
    if cfg.ssm is not None:
        ssm = dataclasses.replace(cfg.ssm, d_state=16, head_dim=16, chunk=32)
    rglru = None
    if cfg.rglru is not None:
        rglru = dataclasses.replace(cfg.rglru, lru_width=d_model)
    pattern = cfg.pattern[:n_layers]
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-reduced",
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        d_head=d_head,
        d_ff=0 if cfg.d_ff == 0 else max(64, cfg.d_ff // width_div),
        vocab_size=vocab,
        block_pattern=pattern,
        local_window=64,
        max_seq=2048,
        moe=moe, ssm=ssm, rglru=rglru,
    )
