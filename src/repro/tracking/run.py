"""Run lifecycle: the wandb-style ``init -> log -> finish`` tracking API.

A :class:`Run` is one tracked execution of a producer (a training loop,
a serving session, a cluster-sim replay, a ``--bench`` invocation).  It
owns an **append-only JSONL event stream** at
``<dir>/<run_id>/events.jsonl``; every line is one self-describing JSON
record:

  * ``{"kind": "run", ...}``      — header: schema version, run id,
    project, tags, config snapshot, git SHA, wall-clock start;
  * ``{"kind": "metrics", ...}``  — one logged step: monotonic ``step``,
    wall-clock ``t``, flat ``metrics`` dict;
  * ``{"kind": "system", ...}``   — a system-metric sample (process
    RSS/CPU from the pluggable samplers plus any harness-reported
    counters such as simulated AUU or KV-page occupancy);
  * ``{"kind": "event", ...}``    — a discrete event mirror (the cluster
    simulator's evict/shrink/gang/storage stream), with optional
    simulated-time ``sim_t``;
  * ``{"kind": "summary", ...}``  — the final summary row (also appended
    to the ``BENCH_*`` trajectory by the bench harness);
  * ``{"kind": "finish", ...}``   — terminator with exit status.

Invariants:

  * **Monotonic steps** — ``log(..., step=n)`` never moves the step
    counter backwards; records are appended in call order and flushed
    per line, so a crashed run leaves a readable prefix.
  * **Deterministic ids under injection** — ``run_id`` is a pure
    function of (project, clock, seed) when both ``clock`` and ``seed``
    are injected (tests pin this); the default uses wall time and
    ``os.urandom`` entropy.
  * **One current run per process** — ``init()`` installs the run as the
    process-wide current run (``current_run()``), mirroring the
    ``wandb.run`` global; producers resolve it as their default tracker
    so a bench invocation's stream transparently collects the simulator
    and engine telemetry produced under it.
"""
from __future__ import annotations

import json
import os
import random
import subprocess
import time
from typing import Callable, Dict, Iterable, List, Mapping, Optional

SCHEMA_VERSION = 1

_CURRENT: Optional["Run"] = None


def git_sha(root: Optional[str] = None) -> str:
    """Short commit SHA of the repo containing ``root`` ("" if no git)."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=root or os.getcwd(), capture_output=True, text=True,
            timeout=10)
        return out.stdout.strip() if out.returncode == 0 else ""
    except (OSError, subprocess.SubprocessError):
        return ""


def make_run_id(project: str, t: float, seed: Optional[int] = None) -> str:
    """``<project-slug>-<utc-stamp>-<suffix>``; pure in (project, t, seed)
    when ``seed`` is given (the deterministic-test contract)."""
    slug = project.replace("/", "-").replace(" ", "_")
    stamp = time.strftime("%Y%m%d-%H%M%S", time.gmtime(t))
    rng = random.Random(seed if seed is not None
                        else int.from_bytes(os.urandom(8), "big"))
    suffix = "".join(rng.choice("0123456789abcdef") for _ in range(6))
    return f"{slug}-{stamp}-{suffix}"


class Run:
    """One tracked run: JSONL event stream + config snapshot + summary."""

    def __init__(self, project: str,
                 config: Optional[Mapping[str, object]] = None,
                 tags: Iterable[str] = (), *,
                 dir: str = os.path.join("results", "runs"),
                 run_id: Optional[str] = None,
                 clock: Optional[Callable[[], float]] = None,
                 seed: Optional[int] = None,
                 samplers: Optional[List[object]] = None,
                 sha: Optional[str] = None):
        self.project = project
        self.config: Dict[str, object] = dict(config or {})
        self.tags = tuple(tags)
        self.clock = clock or time.time
        t0 = self.clock()
        self.id = run_id or make_run_id(project, t0, seed)
        self.dir = os.path.join(dir, self.id)
        self.git_sha = git_sha() if sha is None else sha
        self.samplers = list(samplers) if samplers is not None else []
        self.step = 0
        self.summary: Dict[str, object] = {}
        self.finished = False
        os.makedirs(self.dir, exist_ok=True)
        self._path = os.path.join(self.dir, "events.jsonl")
        self._f = open(self._path, "a")
        self._emit({
            "kind": "run", "schema_version": SCHEMA_VERSION,
            "run_id": self.id, "project": self.project,
            "tags": list(self.tags), "t": t0, "git_sha": self.git_sha,
            "config": self.config,
        })

    # ------------------------------------------------------------- stream --
    @property
    def path(self) -> str:
        return self._path

    def _emit(self, record: Mapping[str, object]) -> None:
        if self.finished:
            return
        self._f.write(json.dumps(record, default=str,
                                 separators=(",", ":")) + "\n")
        self._f.flush()

    def _bump(self, step: Optional[int]) -> int:
        # monotonic: an explicit step may only move the counter forward
        if step is not None and step > self.step:
            self.step = step
        else:
            self.step += 1
        return self.step

    # ---------------------------------------------------------------- api --
    def log(self, metrics: Mapping[str, object],
            step: Optional[int] = None) -> int:
        """Append one step row; returns the (monotonic) step recorded."""
        n = self._bump(step)
        self._emit({"kind": "metrics", "step": n, "t": self.clock(),
                    "metrics": dict(metrics)})
        return n

    def log_event(self, name: str, data: Optional[Mapping[str, object]] = None,
                  sim_t: Optional[float] = None) -> None:
        """Append one discrete event (the simulator telemetry mirror)."""
        rec: Dict[str, object] = {"kind": "event", "event": name,
                                  "step": self.step, "t": self.clock(),
                                  "data": dict(data or {})}
        if sim_t is not None:
            rec["sim_t"] = sim_t
        self._emit(rec)

    def log_system(self, counters: Optional[Mapping[str, float]] = None
                   ) -> Dict[str, float]:
        """Sample every pluggable sampler, merge harness-reported
        ``counters``, and append one system record."""
        sample: Dict[str, float] = {}
        for s in self.samplers:
            sample.update(s.sample())
        sample.update(dict(counters or {}))
        if sample:
            self._emit({"kind": "system", "step": self.step,
                        "t": self.clock(), "metrics": sample})
        return sample

    def log_summary(self, summary: Mapping[str, object]) -> None:
        """Merge into the final summary row (written again at finish)."""
        self.summary.update(summary)
        self._emit({"kind": "summary", "t": self.clock(),
                    "schema_version": SCHEMA_VERSION,
                    "summary": dict(self.summary)})

    def finish(self, status: str = "ok") -> None:
        if self.finished:
            return
        if self.summary:
            self._emit({"kind": "summary", "t": self.clock(),
                        "schema_version": SCHEMA_VERSION,
                        "summary": dict(self.summary)})
        self._emit({"kind": "finish", "t": self.clock(), "status": status,
                    "step": self.step})
        self.finished = True
        self._f.close()
        global _CURRENT
        if _CURRENT is self:
            _CURRENT = None

    # ------------------------------------------------------ context mgmt --
    def __enter__(self) -> "Run":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.finish("ok" if exc_type is None else "error")


def init(project: str, config: Optional[Mapping[str, object]] = None,
         tags: Iterable[str] = (), **kwargs) -> Run:
    """Create a :class:`Run` and install it as the process-wide current
    run (``wandb.init`` semantics); ``finish()`` uninstalls it."""
    global _CURRENT
    run = Run(project, config, tags, **kwargs)
    _CURRENT = run
    return run


def current_run() -> Optional[Run]:
    """The process-wide active run, or None (producers' default tracker)."""
    if _CURRENT is not None and _CURRENT.finished:
        return None
    return _CURRENT


def read_events(path: str) -> List[Dict[str, object]]:
    """Parse an ``events.jsonl`` stream (whole-file convenience reader)."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out
