"""Direction-aware perf-regression gate over BENCH trajectories.

The gate compares each trajectory's **newest** row to a baseline formed
from the **median** of a trailing window of prior rows (median, not
mean, so one noisy CI run cannot poison the baseline).  Per metric:

  * ``direction: "down"`` — lower is better; a regression is
    ``latest > baseline * (1 + band)`` (p95-wait-up is a regression);
  * ``direction: "up"``   — higher is better; a regression is
    ``latest < baseline * (1 - band)`` (throughput-down is a
    regression);
  * ``direction: "info"`` — recorded in the trajectory, never gated.

The noise band defaults to ±10% and can be overridden per metric via
``band`` in the trajectory's metric spec.  A trajectory with a single
row (fresh baseline) or an empty window always passes — there is
nothing to regress against yet.
"""
from __future__ import annotations

import statistics
from dataclasses import dataclass
from typing import Dict, List, Mapping, Optional

from . import trajectory as traj_mod

DEFAULT_BAND = 0.10
DEFAULT_WINDOW = 5


@dataclass
class Verdict:
    """One metric's comparison against its trailing-window baseline."""
    bench: str
    metric: str
    direction: str
    latest: Optional[float]
    baseline: Optional[float]
    band: float
    n_baseline: int
    regressed: bool = False
    note: str = ""

    @property
    def delta_pct(self) -> Optional[float]:
        if self.latest is None or not self.baseline:
            return None
        return 100.0 * (self.latest - self.baseline) / abs(self.baseline)


def check_trajectory(traj: Mapping[str, object], *,
                     window: int = DEFAULT_WINDOW,
                     band: float = DEFAULT_BAND) -> List[Verdict]:
    """Gate one trajectory; returns a Verdict per (gated or info) metric."""
    bench = traj.get("bench", "?")
    spec: Dict[str, Mapping[str, object]] = dict(traj.get("metrics", {}))
    rows = list(traj.get("rows", []))
    verdicts: List[Verdict] = []
    if not rows:
        return verdicts
    latest = rows[-1]
    base_rows = traj_mod.window_rows(traj, window)
    for name, m in spec.items():
        direction = str(m.get("direction", "info"))
        mband = float(m.get("band", band))
        cur = latest.get("metrics", {}).get(name)
        cur = float(cur) if cur is not None else None
        history = [float(r["metrics"][name]) for r in base_rows
                   if name in r.get("metrics", {})]
        base = statistics.median(history) if history else None
        v = Verdict(bench=bench, metric=name, direction=direction,
                    latest=cur, baseline=base, band=mband,
                    n_baseline=len(history))
        if direction == "info":
            v.note = "info (not gated)"
        elif cur is None:
            v.regressed = True
            v.note = "metric missing from latest row"
        elif base is None:
            v.note = "fresh baseline"
        elif base == 0.0:
            # zero baseline: any worsening movement at all is flagged
            v.regressed = (cur > 0.0) if direction == "down" else (cur < 0.0)
            v.note = "zero baseline"
        elif direction == "down":
            v.regressed = cur > base * (1.0 + mband)
        elif direction == "up":
            v.regressed = cur < base * (1.0 - mband)
        verdicts.append(v)
    return verdicts


def update_baseline(traj: Dict[str, object]) -> Dict[str, object]:
    """Anchor the baseline at the newest row (accept an intentional perf
    change): prior rows stop contributing to the trailing window."""
    rows = list(traj.get("rows", []))
    if rows:
        traj["baseline_run_id"] = rows[-1].get("run_id")
    return traj


def format_table(verdicts: List[Verdict]) -> str:
    """Readable fixed-width report naming every offending metric."""
    header = (f"{'bench':<14} {'metric':<34} {'dir':<5} "
              f"{'baseline':>12} {'latest':>12} {'delta':>8}  status")
    lines = [header, "-" * len(header)]
    for v in verdicts:
        def fmt(x: Optional[float]) -> str:
            return f"{x:.4g}" if x is not None else "-"
        delta = v.delta_pct
        dstr = f"{delta:+.1f}%" if delta is not None else "-"
        if v.regressed:
            status = f"REGRESSED (band ±{v.band:.0%})"
        elif v.direction == "info":
            status = "info"
        else:
            status = v.note or f"ok (band ±{v.band:.0%})"
        lines.append(f"{v.bench:<14} {v.metric:<34} {v.direction:<5} "
                     f"{fmt(v.baseline):>12} {fmt(v.latest):>12} "
                     f"{dstr:>8}  {status}")
    return "\n".join(lines)
