"""Per-bench perf trajectories: ``results/BENCH_<bench>.json``.

A trajectory is the cross-PR history of one benchmark's summary rows —
the data the perf gate (:mod:`repro.tracking.gate`) regresses against.
Top-level shape (documented in docs/artifacts.md, pinned by
tests/test_artifacts.py):

    {
      "schema_version": 1,
      "bench": "cluster_sim",
      "metrics": {"makespan_s": {"direction": "down", "band": 0.10}, ...},
      "baseline_run_id": null | "<run_id>",
      "rows": [
        {"run_id": "...", "git_sha": "...", "ts": 1754700000.0,
         "metrics": {"makespan_s": 1234.5, ...}},
        ...
      ]
    }

``metrics`` is the gate spec: ``direction`` is ``"down"`` (lower is
better — regressions are increases), ``"up"`` (higher is better), or
``"info"`` (recorded, never gated — e.g. wall-clock on shared CI
runners); ``band`` optionally overrides the gate's noise band for that
metric.  ``baseline_run_id`` anchors the trailing window: rows at or
before the anchor are excluded, so ``--update-baseline`` can accept an
intentional perf change without rewriting history.

Appends are **idempotent per run id** (re-running a bench under the same
run id replaces its row instead of duplicating it) and atomic
(temp-file + ``os.replace``).
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Mapping, Optional

SCHEMA_VERSION = 1

Spec = Mapping[str, Mapping[str, object]]


def path_for(bench: str, results_dir: str = "results") -> str:
    return os.path.join(results_dir, f"BENCH_{bench}.json")


def load(path: str) -> Dict[str, object]:
    with open(path) as f:
        return json.load(f)


def _write_atomic(path: str, traj: Mapping[str, object]) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(traj, f, indent=2, sort_keys=False)
        f.write("\n")
    os.replace(tmp, path)


def new_trajectory(bench: str, spec: Spec) -> Dict[str, object]:
    return {
        "schema_version": SCHEMA_VERSION,
        "bench": bench,
        "metrics": {k: dict(v) for k, v in spec.items()},
        "baseline_run_id": None,
        "rows": [],
    }


def append_summary(path: str, bench: str, spec: Spec, *,
                   run_id: str, git_sha: str, ts: float,
                   metrics: Mapping[str, float]) -> Dict[str, object]:
    """Append (or idempotently replace) one summary row.

    Re-invoking with a ``run_id`` already present replaces that row in
    place — a retried bench never double-counts.  The metric spec is
    refreshed on every append so direction/band changes ship with the
    code that defines them.
    """
    if os.path.exists(path):
        traj = load(path)
    else:
        traj = new_trajectory(bench, spec)
    traj["schema_version"] = SCHEMA_VERSION
    traj["bench"] = bench
    traj["metrics"] = {k: dict(v) for k, v in spec.items()}
    traj.setdefault("baseline_run_id", None)
    row = {"run_id": run_id, "git_sha": git_sha, "ts": ts,
           "metrics": {k: metrics[k] for k in spec if k in metrics}}
    rows: List[Dict[str, object]] = list(traj.get("rows", []))
    for i, r in enumerate(rows):
        if r.get("run_id") == run_id:
            rows[i] = row
            break
    else:
        rows.append(row)
    traj["rows"] = rows
    _write_atomic(path, traj)
    return traj


def window_rows(traj: Mapping[str, object], window: int,
                *, exclude_last: bool = True) -> List[Dict[str, object]]:
    """The trailing baseline window: up to ``window`` rows preceding the
    newest one, starting after ``baseline_run_id`` (when set)."""
    rows: List[Dict[str, object]] = list(traj.get("rows", []))
    anchor: Optional[str] = traj.get("baseline_run_id")
    if anchor is not None:
        for i, r in enumerate(rows):
            if r.get("run_id") == anchor:
                rows = rows[i:]
                break
    if exclude_last and rows:
        rows = rows[:-1]
    return rows[-window:]
