"""repro.tracking — the wandb-style run-tracking plane.

Public surface::

    import repro.tracking as tracking

    run = tracking.init("cluster_sim", config={...}, tags=("bench",))
    run.log({"loss": 2.31}, step=10)
    run.log_system({"sim.auu": 0.42})
    run.log_summary({"makespan_s": 1234.5})
    run.finish()

Producers (trainer, serve engine, cluster simulator, bench harness)
resolve :func:`current_run` as their default tracker, so running them
under a ``tracking.init(...)`` scope transparently mirrors their
telemetry into the run's ``events.jsonl``.  Trajectories
(``results/BENCH_<bench>.json``) and the regression gate live in
:mod:`repro.tracking.trajectory` / :mod:`repro.tracking.gate`;
``scripts/check_perf.py`` is the CI front-end.
"""
from .run import (SCHEMA_VERSION, Run, current_run, git_sha, init,
                  make_run_id, read_events)
from .sampler import CounterSampler, ProcSampler
from . import gate, trajectory

__all__ = [
    "SCHEMA_VERSION", "Run", "init", "current_run", "git_sha",
    "make_run_id", "read_events", "ProcSampler", "CounterSampler",
    "gate", "trajectory",
]
