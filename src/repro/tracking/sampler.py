"""Pluggable system-metric samplers for the tracking plane.

A sampler is any object with a ``sample() -> dict[str, float]`` method;
:meth:`repro.tracking.run.Run.log_system` merges every attached
sampler's dict into one ``{"kind": "system"}`` record.  Two built-ins:

  * :class:`ProcSampler` — process RSS and CPU time scraped from
    ``/proc/self`` (no psutil dependency; degrades to an empty sample on
    platforms without procfs).
  * :class:`CounterSampler` — adapts harness-reported counters (simulated
    AUU, per-link byte rates, KV-page occupancy ...) into the sampler
    protocol: the harness pushes values, ``sample()`` snapshots them.
"""
from __future__ import annotations

import os
import time
from typing import Callable, Dict, Mapping, Optional


class ProcSampler:
    """Process RSS / CPU via ``/proc`` (Linux) — zero-dependency psutil.

    Emits:
      * ``proc.rss_mb``       — resident set size (MiB), from
        ``/proc/self/status`` ``VmRSS``;
      * ``proc.cpu_s``        — cumulative user+system CPU seconds, from
        ``/proc/self/stat`` utime/stime;
      * ``proc.cpu_pct``      — CPU% over the interval since the previous
        sample (0.0 on the first sample).
    """

    def __init__(self, clock: Optional[Callable[[], float]] = None):
        self.clock = clock or time.time
        self._hz = os.sysconf("SC_CLK_TCK") if hasattr(os, "sysconf") else 100
        self._last_cpu_s: Optional[float] = None
        self._last_t: Optional[float] = None

    def _rss_mb(self) -> Optional[float]:
        try:
            with open("/proc/self/status") as f:
                for line in f:
                    if line.startswith("VmRSS:"):
                        return float(line.split()[1]) / 1024.0  # kB -> MiB
        except OSError:
            pass
        return None

    def _cpu_s(self) -> Optional[float]:
        try:
            with open("/proc/self/stat") as f:
                raw = f.read()
            # field 2 (comm) may contain spaces; split after the closing ')'
            fields = raw.rsplit(")", 1)[1].split()
            utime, stime = int(fields[11]), int(fields[12])
            return (utime + stime) / float(self._hz)
        except (OSError, IndexError, ValueError):
            return None

    def sample(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        rss = self._rss_mb()
        if rss is not None:
            out["proc.rss_mb"] = round(rss, 3)
        cpu = self._cpu_s()
        if cpu is not None:
            out["proc.cpu_s"] = round(cpu, 4)
            now = self.clock()
            if self._last_cpu_s is not None and self._last_t is not None \
                    and now > self._last_t:
                pct = 100.0 * (cpu - self._last_cpu_s) / (now - self._last_t)
                out["proc.cpu_pct"] = round(max(0.0, pct), 2)
            else:
                out["proc.cpu_pct"] = 0.0
            self._last_cpu_s, self._last_t = cpu, now
        return out


class CounterSampler:
    """Harness-reported counters behind the sampler protocol.

    The owning harness calls :meth:`update` whenever its simulated
    counters move (AUU, per-link byte rates, KV-page occupancy);
    ``sample()`` returns the latest snapshot, prefixed for namespacing.
    """

    def __init__(self, prefix: str = "sim",
                 initial: Optional[Mapping[str, float]] = None):
        self.prefix = prefix
        self._counters: Dict[str, float] = dict(initial or {})

    def update(self, counters: Mapping[str, float]) -> None:
        self._counters.update(counters)

    def sample(self) -> Dict[str, float]:
        return {f"{self.prefix}.{k}": v for k, v in self._counters.items()}
