"""Composable-cluster control plane.

The paper composes ONE system at a time by hand.  This package lifts that
to the operating point the composable-infrastructure pitch actually
targets (and that Takano & Suzaki's disaggregation manager automates for
real clouds): many tenants sharing one device pool, each job leased an
exclusive slice, composed on the fabric that matches its placement, and
re-composed elastically when devices fail.

  * ``lease``     — exclusive claim/release with domain-aware placement
  * ``scheduler`` — multi-tenant job queue: admission, backfill,
                    preempt-to-shrink on failure
  * ``simulator`` — trace-driven discrete-event cluster simulation
  * ``telemetry`` — per-link traffic, utilization/AUU, recompose overhead
"""
from repro.cluster.lease import LeaseManager, PlacementPlan, plan_placement
from repro.cluster.scheduler import Job, Scheduler, ServeJob
from repro.cluster.simulator import (ClusterSimulator, JobTemplate,
                                     ServiceConfig, TraceConfig, run_trace)
from repro.cluster.telemetry import ClusterEvent, ServingStats, Telemetry

__all__ = [
    "ClusterEvent", "ClusterSimulator", "Job", "JobTemplate", "LeaseManager",
    "PlacementPlan", "Scheduler", "ServeJob", "ServiceConfig",
    "ServingStats", "Telemetry", "TraceConfig", "plan_placement",
    "run_trace",
]
