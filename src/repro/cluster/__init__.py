"""Composable-cluster control plane.

The paper composes ONE system at a time by hand.  This package lifts that
to the operating point the composable-infrastructure pitch actually
targets (and that Takano & Suzaki's disaggregation manager automates for
real clouds): many tenants sharing one device pool, each job leased an
exclusive slice, composed on the fabric that matches its placement, and
re-composed elastically when devices fail.

  * ``lease``     — exclusive claim/release with domain-aware placement,
                    multi-pod gang co-selection (``plan_gang``) and
                    all-or-nothing gang claims (``acquire_gang``)
  * ``scheduler`` — multi-tenant job queue with pluggable policies
                    (``easy`` | ``fair_share`` | ``priority_preempt``):
                    admission, backfill, policy preemption, elastic
                    preempt-to-shrink on failure
  * ``simulator`` — trace-driven discrete-event cluster simulation
  * ``faults``    — deterministic fault injection (device / domain /
                    link / tranche faults with detection latency) and
                    the recovery plane: retry budgets, graceful
                    degradation, regrow-after-repair, serve failover
  * ``telemetry`` — per-link traffic, utilization/AUU, fairness + gang
                    stats, recompose overhead, availability + recovery

See ``docs/architecture.md`` for the subsystem map and
``docs/telemetry.md`` for the full event/telemetry schema.
"""
from repro.cluster.faults import (FAULT_KINDS, FaultInjector, FaultPlan,
                                  FaultSpec)
from repro.cluster.lease import (GangPlan, LeaseManager, PlacementPlan,
                                 plan_gang, plan_placement)
from repro.cluster.scheduler import (POLICIES, EasyPolicy, FairSharePolicy,
                                     Job, Policy, PriorityPreemptPolicy,
                                     Scheduler, ServeJob, make_policy)
from repro.cluster.simulator import (ClusterSimulator, JobTemplate,
                                     ServiceConfig, TraceConfig, run_trace)
from repro.cluster.telemetry import ClusterEvent, ServingStats, Telemetry

__all__ = [
    "ClusterEvent", "ClusterSimulator", "EasyPolicy", "FAULT_KINDS",
    "FairSharePolicy", "FaultInjector", "FaultPlan", "FaultSpec",
    "GangPlan", "Job", "JobTemplate", "LeaseManager", "POLICIES",
    "PlacementPlan", "Policy", "PriorityPreemptPolicy", "Scheduler",
    "ServeJob", "ServiceConfig", "ServingStats", "Telemetry", "TraceConfig",
    "make_policy", "plan_gang", "plan_placement", "run_trace",
]
