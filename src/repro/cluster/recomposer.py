"""Live recomposition plane: re-shape running jobs as demand shifts.

The paper's core claim is that composable infrastructure lets the pool
"mix and match" resources *dynamically*; until this module, our
composition was frozen at admission — a job kept the exact device set
and tranche it was composed with until it finished, failed, or was
preempted.  The ``Recomposer`` is the pool-side manager (Takano &
Suzaki's disaggregated accelerator manager, Altintas et al.'s dynamic
composability) that closes the gap.  On every scheduler tick it:

  * **attaches** idle devices to running jobs below their submitted
    width — ``Scheduler.regrow_shrunk`` generalized beyond fault
    repair, so repaired capacity rejoins shrunk jobs instead of idling
    — but only while the queue is empty (queued admissions outrank
    widening running work);
  * **detaches** devices from over-provisioned jobs to admit queued
    work sooner (*shrink-to-admit*) — priced with the existing analytic
    model: the halved donors' slowdown and the head job's earlier start
    are projected through ``recommend._estimate`` + the EASY
    reservation, and the pass only fires when the projected makespan
    strictly improves;
  * **migrates** a job's storage lease to a less-loaded tranche when
    contention makes the target's effective per-lessee bandwidth
    strictly better (by ``migrate_bw_factor``) — the composable switch
    re-attaches the same drawer over a different path, so the cost is
    the re-derived contended stalls, not a data copy.

All three actions run through the existing ``train/elastic`` +
``compose()/recompose()`` path: attach re-places hop-aware
(``Scheduler._recompose_placed`` -> ``plan_placement``), every
recompose is atomic (a partial claim rolls back like ``acquire_gang``),
and changed jobs flow back to the simulator through ``policy_victims``
(restore-priced completion events) and ``stall_dirty`` (contention
re-pricing).

Determinism: the tick is rng-free and the passes iterate scheduler
state in insertion order, so a trace with a ``RecomposeConfig`` is
bit-identical per seed — and a trace *without* one never constructs a
``Recomposer`` at all, keeping every legacy report bit-identical.

Only ``Job.elastic`` jobs are touched; ``cooldown_s`` hysteresis keeps
one job from being re-shaped on consecutive ticks (attach/detach
thrash would churn checkpoint restores for nothing).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.cluster.lease import path_maps, plan_placement
from repro.cluster.scheduler import Job, Scheduler
from repro.configs import get_config
from repro.configs.base import SHAPES
from repro.core import recommend
from repro.core.compose import CompositionError
from repro.core.topology import DevicePool


@dataclasses.dataclass(frozen=True)
class RecomposeConfig:
    """Knobs of the live recomposition plane (``TraceConfig.recompose``;
    ``None`` there disables the plane entirely — no ticks, no events,
    no report section: legacy traces stay bit-identical)."""
    interval_s: float = 30.0         # scheduler tick period
    attach: bool = True              # widen shrunk elastic jobs
    detach: bool = True              # shrink-to-admit queued work
    migrate: bool = True             # tranche migration under contention
    # hysteresis: a job re-shaped within the last cooldown_s is left
    # alone (prevents attach/detach ping-pong across ticks)
    cooldown_s: float = 60.0
    # shrink-to-admit fires only when the projected makespan improves
    # by more than this margin (seconds)
    min_makespan_gain_s: float = 0.0
    # migrate fires only when the target tranche's effective per-lessee
    # bandwidth beats the current one by at least this factor
    migrate_bw_factor: float = 1.25


class Recomposer:
    """Pool-side recomposition manager driven by simulator ticks."""

    def __init__(self, scheduler: Scheduler, cfg: RecomposeConfig):
        self.scheduler = scheduler
        self.cfg = cfg
        self._last_t: Dict[str, float] = {}      # job -> last action time

    # ------------------------------------------------------------- ticks --
    def tick(self, now: float) -> List[Job]:
        """One recomposition pass; returns the re-shaped jobs (they are
        also queued on ``Scheduler.policy_victims`` / ``stall_dirty``
        for the simulator's ordinary re-pricing paths)."""
        changed: List[Job] = []
        if self.cfg.attach:
            changed += self._attach_pass(now)
        if self.cfg.detach:
            changed += self._detach_pass(now)
        if self.cfg.migrate:
            changed += self._migrate_pass(now)
        return changed

    def _cooled(self, name: str, now: float) -> bool:
        last = self._last_t.get(name)
        return last is None or now - last >= self.cfg.cooldown_s

    # ------------------------------------------------------------ attach --
    def _attach_step_s(self, job: Job) -> Optional[float]:
        """Pure projection of ``job``'s repriced step time after a
        widen-to-budget attach: plan the placement on a read-only pool
        view with the job's own claim freed, then reprice the best
        full-budget candidate on that placement's actual paths — the
        exact math ``attach_job`` will apply, without mutating
        anything.  None when no feasible widened placement exists."""
        sched = self.scheduler
        plan = sched.plan_job(job)
        if plan is None:
            return None
        dp, tp = plan.shape[-2], plan.shape[-1]
        pool = sched.pool
        view = DevicePool(
            devices=pool.devices, links=pool.links,
            leases={u: h for u, h in pool.leases.items()
                    if h != job.system.name},
            topology=pool.topology)
        try:
            placed = plan_placement(view, dp, tp)
        except CompositionError:
            return None
        links, hops, scale = path_maps(placed.axis_paths)
        fab = dataclasses.replace(job.system.fabric, axis_links=links,
                                  axis_hops=hops, axis_bw_scale=scale)
        return sched._repriced(
            plan, dataclasses.replace(job.system, fabric=fab)).step_s

    def _attach_pass(self, now: float) -> List[Job]:
        """Widen running elastic jobs below their submitted width from
        idle capacity — only while no admissible job is queued (a
        widened job would otherwise take the exact devices the queue
        head is reserving), and only when the analytic model projects
        the widened job finishing earlier net of its checkpoint
        restore (a regrown mesh forced onto a slower fabric can lose
        to the narrow one it replaces)."""
        sched = self.scheduler
        if any(j.not_before_t <= now for j in sched.queue):
            return []
        changed: List[Job] = []
        for job in list(sched.running):
            if not job.elastic or job.n_pods > 1 or job.system is None:
                continue
            if job.system.n_devices >= job.n_chips:
                continue
            if not self._cooled(job.name, now):
                continue
            if (len(sched.pool.available())
                    < job.n_chips - job.system.n_devices):
                continue
            new_step = self._attach_step_s(job)
            if new_step is None:
                continue
            rem = job.remaining_steps()
            projected = (sched.restore_s(job)
                         + rem * (new_step + job.input_stall_s))
            if projected >= rem * job.step_s:
                continue             # wider but slower (or not worth the
                                     # restore): keep the narrow mesh
            if sched.attach_job(job, now):
                self._last_t[job.name] = now
                changed.append(job)
        return changed

    # ------------------------------------------------------------ detach --
    def _halved(self, job: Job) -> Optional[recommend.Candidate]:
        """Analytic plan for ``job`` at half its data axis (None when
        the halved mesh is infeasible)."""
        cfg = get_config(job.arch)
        shape = SHAPES[job.shape_name]
        dp, tp = job.dp_tp
        cand = recommend.calibrate_candidate(
            recommend._estimate(cfg, shape, dp // 2, tp),
            cfg, job.arch, job.shape_name, shape,
            self.scheduler.calibration)
        return cand if cand.feasible else None

    def _detach_pass(self, now: float) -> List[Job]:
        """Shrink-to-admit: halve enough over-provisioned elastic donors
        that the queue head fits now — but only when the projected
        makespan (donors slowed, head started early) strictly beats
        leaving everyone alone (head waits for the EASY reservation)."""
        sched = self.scheduler
        queue = [j for j in sched.policy.order(sched, now)
                 if j.not_before_t <= now]
        if not queue:
            return []
        head = queue[0]
        if head.n_pods > 1:
            return []                # gang admission needs whole domains
        need = head.n_chips - len(sched.pool.available())
        if need <= 0:
            return []                # fits already: poll() will start it
        donors: List[Tuple[Job, recommend.Candidate]] = []
        for j in sched.running:
            if not j.elastic or j.n_pods > 1 or j.system is None:
                continue
            if not self._cooled(j.name, now) or j.dp_tp[0] < 2:
                continue
            cand = self._halved(j)
            if cand is not None:
                donors.append((j, cand))
        donors.sort(key=lambda row: (-row[0].system.n_devices,
                                     row[0].name))
        chosen: List[Tuple[Job, recommend.Candidate]] = []
        freed = 0
        for j, cand in donors:
            if freed >= need:
                break
            chosen.append((j, cand))
            freed += j.system.n_devices // 2
        if freed < need:
            return []                # halving everyone still won't fit it
        # analytic pricing: without the detach the head starts at the
        # EASY reservation; with it the head starts now and every donor
        # runs its remaining steps at the halved-mesh step time
        t_free = sched._reservation_t(head.n_chips, now)
        head_restore = sched.est_restore_for(head)
        base_end = (t_free + head_restore + head.est_duration_s()
                    if t_free != float("inf") else float("inf"))
        base = max([base_end] + [j.est_end_t for j in sched.running])
        donor_names = {j.name for j, _ in chosen}
        ends = [now + head_restore + head.est_duration_s()]
        for j, cand in chosen:
            ends.append(now + sched.restore_s(j)
                        + j.remaining_steps()
                        * (cand.step_s + j.input_stall_s))
        ends += [j.est_end_t for j in sched.running
                 if j.name not in donor_names]
        if max(ends) + self.cfg.min_makespan_gain_s >= base:
            return []                # no projected win: leave donors be
        changed: List[Job] = []
        for j, _ in chosen:
            if sched.detach_job(j, now):
                self._last_t[j.name] = now
                changed.append(j)
        return changed

    # ----------------------------------------------------------- migrate --
    def _migrate_pass(self, now: float) -> List[Job]:
        """Move elastic jobs to a strictly-better storage tranche: the
        best candidate's projected per-lessee bandwidth (with the job
        counted in) must beat the current tranche's by
        ``migrate_bw_factor``."""
        sched = self.scheduler
        storage = sched.storage
        changed: List[Job] = []
        for job in list(sched.running):
            if (not job.elastic or job.io is None or job.system is None
                    or job.system.tranche is None):
                continue
            if not self._cooled(job.name, now):
                continue
            cur = job.system.tranche
            cur_bw = storage.read_bw(cur)
            cap = sched._storage_request(job)
            best_name, best_bw = "", 0.0
            for name, tr in sorted(storage.tranches.items()):
                if name == cur or storage.exclusively_held(name):
                    continue
                if storage.capacity_used(name) + cap > tr.capacity_bytes:
                    continue
                bw = tr.effective_read_bw(storage.links,
                                          storage.n_lessees(name) + 1)
                if bw > best_bw:
                    best_name, best_bw = name, bw
            if not best_name or best_bw < self.cfg.migrate_bw_factor * cur_bw:
                continue
            if sched.migrate_tranche(job, now, best_name):
                self._last_t[job.name] = now
                changed.append(job)
        return changed
