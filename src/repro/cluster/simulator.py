"""Trace-driven discrete-event simulation of the composable cluster.

The paper measures one composed system at a time; the simulator runs the
*cluster*: Poisson job arrivals drawn from a template mix over the
``configs/`` registry, scheduled by ``cluster.scheduler`` onto a shared
``DevicePool``, with injected device failures and repairs driving the
elastic recompose path.  Everything is priced analytically (no jax
device state), so a 512-chip, dozens-of-jobs trace simulates in well
under a second and is fully deterministic for a given seed.

Time accounting per event pop:

  1. accrue progress for every running job since the last event —
     steps completed and per-axis wire bytes (candidate ``wire_bytes``
     x devices), attributed to the link class its composition actually
     rides (this is Fig 12 per fabric, cluster-wide);
  2. apply the event (arrival / completion / failure / repair);
  3. let the scheduler start whatever now fits, pushing completion
     events at ``now + restore_overhead + remaining_steps x step_s``;
  4. integrate occupancy into telemetry (utilization + AUU).

Recomposition overhead models the checkpoint round-trip: parameter
bytes over the composition's storage tier — priced at the tranche's
*contended* per-lessee bandwidth (``Scheduler.restore_s``) — plus the
compose latency: the operational cost of the paper's attach/detach knob.

Gang jobs (``JobTemplate.n_pods > 1``) replay deterministically like
everything else: gang start/stop events carry the member domains and
DCN hop span, the gang's pod-axis collective traffic is attributed to
the DCN link class through the same incremental per-link rate
accumulators, and policy evictions/shrinks (``TraceConfig.policy``)
re-price victims' completion events exactly like failure preemptions.

Invariants:

  * **Determinism** — ``report()`` is bit-identical for a given
    ``TraceConfig`` (wall-clock telemetry deliberately lives outside
    it); the rng is consumed in a fixed order (batch trace, then
    failures, then services), so adding gang/policy fields does not
    shift pre-existing traces.
  * **Stall re-derivation** — whenever the scheduler marks a running
    job's input stall dirty, the simulator re-schedules its completion:
    progress already made is accrued at the *old* effective step time,
    remaining steps at the new one (``_resync_stalls``).
  * **Event epochs** — every completion/rate event carries the job's
    epoch; preemption, shrink, and recompose bump it, so stale events
    are dropped instead of double-completing.
"""
from __future__ import annotations

import dataclasses
import heapq
import random
import time
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

from repro.cluster.faults import FaultInjector, FaultPlan
from repro.cluster.recomposer import Recomposer, RecomposeConfig
from repro.cluster.scheduler import (DONE, QUEUED, REJECTED, RUNNING, Job,
                                     Scheduler, ServeJob)
from repro.cluster.telemetry import ServingStats, Telemetry
from repro.configs import get_config
from repro.configs.base import SHAPES
from repro.core import costmodel
from repro.core.topology import LinkClass, Topology, make_pool
from repro.data.pipeline import IOWorkload
from repro.data.storage import StoragePool, StorageTranche, make_storage_pool


@dataclasses.dataclass(frozen=True)
class JobTemplate:
    """One row of the trace mix."""
    arch: str
    shape_name: str
    n_chips: int
    steps: int
    weight: float = 1.0
    # explicit I/O shape (None -> lm_io_workload(arch, shape) at submit);
    # input-heavy mixes use this to stress the storage tranches
    io: Optional[IOWorkload] = None
    # gang scheduling / policy knobs: n_pods > 1 makes every job drawn
    # from this template a multi-pod gang; tenant feeds fair-share
    # accounting; priority feeds the queue order + priority_preempt
    n_pods: int = 1
    tenant: str = ""
    priority: int = 0
    # anti-thrash eviction budget forwarded to Job.max_evictions
    max_evictions: int = 3
    # live-recomposition opt-in forwarded to Job.elastic: the Recomposer
    # may widen, shrink-to-admit, or tranche-migrate these jobs mid-run
    elastic: bool = False


# A mixed train/serve diet over small-to-mid archs: feasible on modest
# chip budgets, heterogeneous enough to exercise backfill.
DEFAULT_TEMPLATES: Tuple[JobTemplate, ...] = (
    JobTemplate("qwen2-0.5b", "train_4k", 16, 20, weight=3),
    JobTemplate("mamba2-780m", "train_4k", 32, 12, weight=2),
    JobTemplate("llama3.2-3b", "train_4k", 64, 8, weight=2),
    JobTemplate("llama3.2-3b", "prefill_32k", 16, 40, weight=2),
    JobTemplate("llama3.2-3b", "decode_32k", 64, 300, weight=2),   # mem-bound
    JobTemplate("stablelm-12b", "prefill_32k", 32, 20, weight=1),
    # collective-bound MoE train: spans locality cliques, stresses the
    # composed fabric and shows up as accelerator under-utilization
    JobTemplate("moonshot-v1-16b-a3b", "train_4k", 128, 6, weight=1),
)


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """One logical inference service in the serving-trace mode.

    ``n_replicas`` ``ServeJob`` tenants are submitted at ``start_t`` and
    lease ``chips_per_replica`` each through the ordinary admission path;
    ``n_requests`` request arrivals (``poisson`` paced at
    ``arrival_rate_hz`` or one ``burst``) are routed to the least-loaded
    running replica.  Requests draw one of ``n_prefixes`` shared prompt
    prefixes, so per-replica prefix caches warm up over the trace — the
    cluster-level analogue of the engine's prefix-hash reuse.
    """
    name: str = "chat"
    arch: str = "llama3.2-3b"
    shape_name: str = "decode_32k"
    n_replicas: int = 2
    chips_per_replica: int = 16
    n_requests: int = 200
    arrival_rate_hz: float = 2.0
    arrival: str = "poisson"           # poisson | burst
    prompt_len: int = 2048
    max_new: int = 128
    n_prefixes: int = 8
    prefix_len: int = 1024             # shared tokens within prompt_len
    prefill_chunk: int = 512
    start_t: float = 0.0
    priority: int = 10                 # serve replicas outrank batch jobs
    ttft_slo_s: float = 5.0
    tpot_slo_s: float = 0.5
    # resilience (all off by default — legacy traces are bit-identical):
    # a request not finished within request_timeout_s of (re)issue is
    # pulled back and re-routed up to max_request_retries times with
    # exponential backoff; past the budget it fails terminally.
    request_timeout_s: float = 0.0     # 0 = no timeout
    max_request_retries: int = 2
    retry_backoff_s: float = 0.5
    # replica health checks: every health_check_s the service probes its
    # replicas and fails over the requests of any replica sitting on
    # unhealthy devices — ahead of the cluster-level fault detection
    health_check_s: float = 0.0        # 0 = no health checks
    # SLO-driven autoscaling (off by default — legacy traces are
    # bit-identical): every autoscale_interval_s the service compares
    # queued requests per admitting replica and windowed SLO attainment
    # against targets and grows/shrinks the replica set through the
    # ordinary scheduler path — scale-up leases chips like any other
    # composition (priced: lease + DCN + tranche), scale-down drains the
    # least-loaded replica and releases its lease once idle.
    autoscale: bool = False
    autoscale_interval_s: float = 2.0
    min_replicas: int = 0              # 0 -> n_replicas
    max_replicas: int = 0              # 0 -> 4 * n_replicas
    scale_up_queue: float = 4.0        # queued reqs per admitting replica
    scale_down_queue: float = 0.5
    slo_target: float = 0.99           # window attainment below -> grow


class _Replica:
    """Runtime state of one running ServeJob replica."""

    __slots__ = ("job", "active", "queue", "prefixes", "hit_tokens",
                 "miss_tokens", "served", "out_tokens")

    def __init__(self, job: ServeJob):
        self.job = job
        self.active: set = set()
        self.queue: deque = deque()
        self.prefixes: Dict[int, float] = {}    # prefix -> cached-from time
        self.hit_tokens = 0
        self.miss_tokens = 0
        self.served = 0
        self.out_tokens = 0

    def load(self) -> int:
        return len(self.active) + len(self.queue)


class _Service:
    """Runtime state of one ServiceConfig across its replicas."""

    def __init__(self, cfg: ServiceConfig):
        self.cfg = cfg
        self.stats = ServingStats()
        self.replicas: List[ServeJob] = []
        self.backlog: deque = deque()
        self.requests: Dict[int, Dict[str, object]] = {}
        self.remaining = cfg.n_requests
        # autoscale state (inert unless cfg.autoscale)
        self.next_replica = cfg.n_replicas   # next scale-up's replica id
        self.scale_ups = 0
        self.scale_downs = 0
        self.scaling_down: set = set()       # names draining to retire
        self.windows: List[Dict[str, object]] = []   # per-tick samples
        self.win_ok = 0                      # SLO-met since last tick
        self.win_n = 0                       # completed since last tick


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    n_jobs: int = 20
    arrival_rate_hz: float = 0.05          # Poisson arrivals, jobs/second
    seed: int = 0
    n_local: int = 256
    n_switch: int = 256
    pods: int = 2
    templates: Tuple[JobTemplate, ...] = DEFAULT_TEMPLATES
    # device-failure injection points.  Two row shapes are accepted:
    #   (t_down, n)        — legacy: n devices fail at t_down and are
    #                        repaired repair_after_s later (bit-for-bit
    #                        the original behavior);
    #   (t_down, t_up, n)  — explicit repair time; t_up = None or inf
    #                        means the devices stay dead forever.
    failures: Tuple[Tuple[float, ...], ...] = ((120.0, 12),)
    repair_after_s: float = 300.0
    backfill: bool = True
    compose_latency_s: float = 2.08e-6 * 64   # switch reprogram, Table IV
    # optional measured-cost layer (core.costmodel.CalibratedCost): jobs
    # are admitted and priced from measurements instead of pure analytics
    calibration: Optional[object] = None
    # serving-trace mode: long-lived ServeJob tenants + request arrivals
    # alongside the batch-job trace (empty tuple = batch-only, unchanged)
    services: Tuple[ServiceConfig, ...] = ()
    # storage inventory: explicit tranche set, or None for the default
    # make_storage_pool() (4 local + 2 switch-attached NVMe tranches)
    storage_tranches: Optional[Tuple[StorageTranche, ...]] = None
    # scheduling policy (see cluster.scheduler.POLICIES) and per-tenant
    # fair-share weights as (tenant, weight) pairs (frozen-hashable)
    policy: str = "easy"
    tenant_weights: Tuple[Tuple[str, float], ...] = ()
    # deterministic arrivals appended after the Poisson trace: explicit
    # (arrival_time_s, template) pairs consume no rng, so skewed-tenant
    # and gang scenarios can be scripted exactly
    arrivals: Tuple[Tuple[float, JobTemplate], ...] = ()
    # fault-injection plane (cluster.faults): None = off; FaultPlan() is
    # behaviorally identical to None (no events, no rng draws), so the
    # legacy determinism contract is unchanged either way
    faults: Optional[FaultPlan] = None
    # fabric wiring model (core.fabrics.Topology): None = the flat
    # single-switch fabric, bit-identical to every pre-topology trace
    topology: Optional[Topology] = None
    # live recomposition plane (cluster.recomposer): None = off — no
    # ticks, no rng draws, no report section, so every legacy trace
    # stays bit-identical.  With a RecomposeConfig, elastic jobs are
    # attach-widened / shrunk-to-admit / tranche-migrated on ticks.
    recompose: Optional[RecomposeConfig] = None


def restore_overhead_s(job: Job,
                       scheduler: Optional[Scheduler] = None) -> float:
    """Checkpoint round-trip cost of (re)forming ``job``'s composition.

    With a ``scheduler``, the restore read is priced at the contended
    per-lessee bandwidth of the tranche the job actually holds
    (``Scheduler.restore_s``); without one it falls back to the job's
    uncontended tier estimate (the backfill guard's placement-unknown
    view)."""
    if scheduler is not None:
        return scheduler.restore_s(job)
    return job.est_restore_s()


class ClusterSimulator:
    """Discrete-event loop over a shared pool; deterministic per seed.

    ``tracker`` is an optional ``repro.tracking.Run``; when omitted the
    process-wide current run (``tracking.current_run()``) is used, so a
    simulation executed under ``tracking.init(...)`` — e.g. by
    ``benchmarks/run.py --bench`` — transparently mirrors its telemetry
    event stream (evicts, shrinks, gang spans, storage stalls) and
    occupancy summary into the run's ``events.jsonl``.  The mirror runs
    after the event loop drains and never touches ``report()``, so the
    bit-determinism contract is unchanged.
    """

    def __init__(self, cfg: TraceConfig, tracker: object = None):
        self.cfg = cfg
        self.tracker = tracker
        self.pool = make_pool(n_local=cfg.n_local, n_switch=cfg.n_switch,
                              pods=cfg.pods, topology=cfg.topology)
        self.telemetry = Telemetry(len(self.pool.devices))
        storage = (StoragePool(list(cfg.storage_tranches), self.pool.links)
                   if cfg.storage_tranches is not None
                   else make_storage_pool(links=self.pool.links))
        self.scheduler = Scheduler(self.pool, self.telemetry,
                                   backfill=cfg.backfill,
                                   calibration=cfg.calibration,
                                   storage=storage, policy=cfg.policy,
                                   tenant_weights=dict(cfg.tenant_weights))
        # policy preemptions checkpoint at exact progress: let the
        # scheduler pull lazy step accrual up to the eviction time
        self.scheduler.sync_progress = self._sync_steps
        # pre-create per-tranche stats so occupancy spans the whole trace
        for tr in storage.tranches.values():
            self.telemetry.tranche_stats(tr.name, tr.attach.value)
        self.rng = random.Random(cfg.seed)
        self.jobs: Dict[str, Job] = {}
        self.services: Dict[str, _Service] = {}
        self.replicas: Dict[str, _Replica] = {}     # running ServeJobs only
        # fault plane: injector when a plan is configured; ``draining``
        # replicas stop admitting requests (graceful planned detach)
        self.faults: Optional[FaultInjector] = (
            FaultInjector(self, cfg.faults) if cfg.faults is not None
            else None)
        # live recomposition plane: only constructed when configured, so
        # legacy traces carry zero recomposer state (and no report key)
        self.recomposer: Optional[Recomposer] = None
        if cfg.recompose is not None:
            self.recomposer = Recomposer(self.scheduler, cfg.recompose)
            self.telemetry.recompose_enabled = True
        self.draining: set = set()
        self._done_reps: Dict[str, Dict[str, object]] = {}
        self._heap: List[Tuple[float, int, str, object]] = []
        self._seq = 0
        self._now = 0.0
        # incremental per-link traffic accounting: instead of scanning
        # every running job's wire_bytes dict at every event, each job's
        # bytes/sec contribution is folded into ``_link_rate`` when it
        # starts stepping and removed when it stops/recomposes; accrual
        # is then O(#link classes) per event
        self._link_rate: Dict[LinkClass, float] = {}
        self._job_rate: Dict[str, Dict[LinkClass, float]] = {}
        # per-tranche storage accounting on the same incremental pattern:
        # tranche -> [read B/s, write B/s, stall s/s] while jobs step
        self._store_rate: Dict[str, List[float]] = {}
        self._job_store_rate: Dict[str, Tuple[str, float, float, float]] = {}
        self._accrue_t = 0.0
        self.wall_s = 0.0           # wall-clock of the last run() call
        self.events_per_s = 0.0

    # ------------------------------------------------------------- events --
    def _push(self, t: float, kind: str, payload: object = None) -> None:
        heapq.heappush(self._heap, (t, self._seq, kind, payload))
        self._seq += 1

    def _gen_trace(self) -> None:
        t = 0.0
        weights = [tpl.weight for tpl in self.cfg.templates]
        def add_job(t_arr: float, tpl: JobTemplate, who: str) -> None:
            i = len(self.jobs)
            job = Job(name=f"job-{i:03d}-{who}-{tpl.shape_name}",
                      arch=tpl.arch, shape_name=tpl.shape_name,
                      n_chips=tpl.n_chips, steps=tpl.steps, io=tpl.io,
                      n_pods=tpl.n_pods, tenant=tpl.tenant,
                      priority=tpl.priority,
                      max_evictions=tpl.max_evictions,
                      elastic=tpl.elastic)
            self.jobs[job.name] = job
            self._push(t_arr, "arrival", job.name)

        for _ in range(self.cfg.n_jobs):
            t += self.rng.expovariate(self.cfg.arrival_rate_hz)
            tpl = self.rng.choices(self.cfg.templates, weights=weights)[0]
            add_job(t, tpl, tpl.arch)
        # scripted arrivals (gang / skewed-tenant scenarios): appended
        # after the Poisson trace and rng-free, so batch-only configs
        # consume the rng identically with or without them
        for t_arr, tpl in self.cfg.arrivals:
            add_job(t_arr, tpl, tpl.tenant or tpl.arch)
        for row in self.cfg.failures:
            if len(row) == 2:
                # legacy (t_down, n): payload stays a bare int so the
                # fail handler's repair push is bit-identical
                t_down, n = row
                self._push(t_down, "fail", n)
            else:
                t_down, t_up, n = row
                self._push(t_down, "fail", ("at", t_up, int(n)))
        # serving trace: replicas arrive as jobs, requests as events.
        # Generated after the batch trace so batch-only configs consume
        # the rng identically to pre-serving versions (stable seeds).
        for svc_cfg in self.cfg.services:
            svc = _Service(svc_cfg)
            self.services[svc_cfg.name] = svc
            for i in range(svc_cfg.n_replicas):
                job = self._make_replica_job(svc, i)
                self._push(svc_cfg.start_t, "arrival", job.name)
            t = svc_cfg.start_t
            for rid in range(svc_cfg.n_requests):
                if svc_cfg.arrival == "poisson":
                    t += self.rng.expovariate(svc_cfg.arrival_rate_hz)
                svc.requests[rid] = {
                    "submit_t": t,
                    "prefix": self.rng.randrange(svc_cfg.n_prefixes),
                    "attempt": 0,
                }
                self._push(t, "req", (svc_cfg.name, rid))
        # replica health-check ticks (rng-free; 0 = off, legacy-identical)
        for svc_cfg in self.cfg.services:
            if svc_cfg.health_check_s > 0:
                self._push(svc_cfg.start_t + svc_cfg.health_check_s,
                           "health", svc_cfg.name)
        # autoscaler ticks (rng-free; off by default, legacy-identical)
        for svc_cfg in self.cfg.services:
            if svc_cfg.autoscale and svc_cfg.autoscale_interval_s > 0:
                self._push(svc_cfg.start_t + svc_cfg.autoscale_interval_s,
                           "autoscale", svc_cfg.name)
        # live-recomposition ticks (rng-free; None = off, legacy-identical)
        if (self.recomposer is not None
                and self.cfg.recompose.interval_s > 0):
            self._push(self.cfg.recompose.interval_s, "recompose_tick")
        # fault plane last: its (optional) MTBF schedule consumes the rng
        # only after every legacy draw, so pre-fault traces replay
        # identically with faults=None or an empty FaultPlan
        if self.faults is not None:
            self.faults.push_schedule()

    # ------------------------------------------------------------ accrual --
    def _job_link_rate(self, job: Job) -> Dict[LinkClass, float]:
        """bytes/sec this job puts on each link class while stepping.
        A payload crossing a k-hop path occupies k link segments, so
        multi-tier axes accrue ``hops x`` the wire bytes (1x on the
        flat fabric — the legacy accounting)."""
        rates: Dict[LinkClass, float] = {}
        if job.system is None or job.plan is None:
            return rates
        per_step = job.system.n_devices / max(job.step_s, 1e-30)
        for axis, nbytes in job.plan.wire_bytes.items():
            if nbytes <= 0 or axis not in job.system.fabric.axis_links:
                continue
            link = job.system.fabric.axis_links[axis]
            hops = job.system.fabric.hops(axis)
            rates[link] = rates.get(link, 0.0) + nbytes * hops * per_step
        return rates

    def _rate_on(self, job: Job) -> None:
        self._rate_off(job.name)
        rates = self._job_link_rate(job)
        if rates:
            self._job_rate[job.name] = rates
            for link, r in rates.items():
                self._link_rate[link] = self._link_rate.get(link, 0.0) + r
        if (job.io is not None and job.system is not None
                and job.system.tranche is not None):
            step = max(job.step_s, 1e-30)
            row = (job.system.tranche,
                   job.io.mean_step_read_bytes() / step,
                   job.io.mean_step_write_bytes() / step,
                   job.input_stall_s / step)
            self._job_store_rate[job.name] = row
            acc = self._store_rate.setdefault(row[0], [0.0, 0.0, 0.0])
            for i in range(3):
                acc[i] += row[1 + i]

    def _rate_off(self, name: str) -> None:
        for link, r in self._job_rate.pop(name, {}).items():
            self._link_rate[link] -= r
        row = self._job_store_rate.pop(name, None)
        if row is not None:
            acc = self._store_rate[row[0]]
            for i in range(3):
                acc[i] -= row[1 + i]

    def _accrue(self, now: float) -> None:
        """Integrate link traffic and per-tranche storage I/O up to
        ``now`` (O(#links + #tranches), not O(jobs))."""
        dt = now - self._accrue_t
        if dt > 0:
            for link, rate in self._link_rate.items():
                if rate > 0:
                    self.telemetry.add_link_traffic(link, rate * dt)
            for tranche, (rr, wr, sr) in self._store_rate.items():
                if rr > 0 or wr > 0 or sr > 0:
                    self.telemetry.tranche_stats(tranche).add_io(
                        rr * dt, wr * dt, sr * dt)
        self._accrue_t = max(self._accrue_t, now)

    def _sync_steps(self, job: Job, now: float,
                    step_s: Optional[float] = None) -> None:
        """Bring one job's ``steps_done`` up to ``now`` (lazy: called only
        when an event actually needs the figure — checkpoint on failure,
        preemption, shrink re-planning).  ``step_s`` overrides the job's
        current rate (used when a stall change already overwrote it)."""
        t0 = max(job.progress_t, job.start_t)
        if now <= t0:
            return
        d_steps = min((now - t0) / max(step_s or job.step_s, 1e-30),
                      job.remaining_steps())
        job.steps_done += d_steps
        job.progress_t = now

    def _observe(self, now: float) -> None:
        self.telemetry.observe(
            now, n_leased=len(self.pool.leases),
            busy_equiv=self.scheduler.busy_equiv(),
            n_healthy=len(self.pool.healthy()))
        storage = self.scheduler.storage
        for name in storage.tranches:
            self.telemetry.tranche_stats(name).observe(
                now, storage.n_lessees(name))

    def _schedule_completion(self, job: Job, now: float,
                             overhead: float = 0.0) -> None:
        if overhead > 0:
            self.telemetry.add_recomposition(overhead)
        start = now + overhead + self.cfg.compose_latency_s
        job.progress_t = start          # stepping resumes after the restore
        # link traffic begins when stepping does, not at lease time: the
        # rate event folds the job's bytes/sec into the accumulators then
        self._push(start, "rate", (job.name, job.epoch))
        self._push(start + job.est_duration_s(), "complete",
                   (job.name, job.epoch))

    def _reschedule_victim(self, job: Job, now: float) -> None:
        """A running job lost devices (failure recompose/preempt or
        policy shrink/evict): its old traffic rates come off and, if it
        kept running in a smaller shape, its events re-price after the
        checkpoint restore; an evicted replica's load re-routes."""
        self._rate_off(job.name)
        if isinstance(job, ServeJob):
            if job.state == RUNNING:          # shrunk in place: serve on
                if job.name in self.replicas:
                    self.draining.discard(job.name)   # healthy again
                    self._push(now + restore_overhead_s(job, self.scheduler),
                               "rate", (job.name, job.epoch))
                else:
                    # a health-check failover retired the old incarnation;
                    # the recomposed replica re-registers and re-admits
                    self._replica_started(job, now)
            else:                              # preempted: re-route load
                self._reassign_replica_requests(job, now)
        elif job.state == RUNNING:            # shrunk in place
            self._schedule_completion(
                job, now, restore_overhead_s(job, self.scheduler))

    def _start_newly_scheduled(self, now: float) -> None:
        started = self.scheduler.poll(now)
        names = {j.name for j in started}
        victims = self.scheduler.drain_policy_victims()
        for job in victims:
            if job.name in names:
                # evicted and restarted within one poll: only the stale
                # rates come off; the started loop below reschedules it
                self._rate_off(job.name)
                continue
            self._reschedule_victim(job, now)
        for job in started:
            if isinstance(job, ServeJob):
                self._replica_started(job, now)
                continue
            # a preempted job resuming from a checkpoint pays the restore
            # (read back at the contended bandwidth of its new tranche)
            overhead = restore_overhead_s(job, self.scheduler)
            self._schedule_completion(job, now, overhead)
        self._resync_stalls(now, exclude=names | {j.name for j in victims})

    def _resync_stalls(self, now: float, exclude=frozenset()) -> None:
        """Tranche contention changed: re-schedule the completion of every
        running job whose input stall moved.  Progress already made is
        accrued at the *old* effective step time; the remaining steps are
        re-priced at the new one.  Jobs in ``exclude`` just had their
        events (re)scheduled by the caller and are skipped."""
        for job, old_stall in self.scheduler.drain_stall_dirty():
            if job.name in exclude or job.state != RUNNING:
                continue
            if isinstance(job, ServeJob):
                # no completion event to move — refresh the rate row so
                # traffic/stall accrual follows the new contention (the
                # per-request pricing reads job.step_s live)
                self._rate_off(job.name)
                self._push(now, "rate", (job.name, job.epoch))
                continue
            self._sync_steps(job, now,
                             step_s=job.plan.step_s + old_stall)
            self._rate_off(job.name)
            job.epoch += 1           # invalidates the stale completion
            self._schedule_completion(job, now)

    # ------------------------------------------------- live recomposition --
    def _recompose_tick(self, now: float) -> bool:
        """Periodic (rng-free) recomposition pass: sync lazy progress so
        the Recomposer prices exact remaining work, let it act, then
        route the re-shaped jobs through the ordinary re-pricing paths
        (``policy_victims`` -> restore-priced completion events,
        ``stall_dirty`` -> contention resync).  Re-pushes itself only
        while other events remain, so the heap always drains.  Returns
        whether anything was re-shaped — a no-op tick must not advance
        the simulation clock (``run`` skips its bookkeeping), or an
        idle tail of ticks would inflate makespan past the last real
        completion."""
        for job in self.scheduler.running:
            self._sync_steps(job, now)
        changed = self.recomposer.tick(now)
        if changed:
            self._start_newly_scheduled(now)
        if self._heap:
            self._push(now + self.cfg.recompose.interval_s,
                       "recompose_tick")
        return bool(changed)

    # ------------------------------------------------------------- serving --
    def _make_replica_job(self, svc: _Service, i: int) -> ServeJob:
        """Build and register replica ``i``'s ServeJob (trace-time
        replicas and autoscale scale-ups share the sizing formula)."""
        scfg = svc.cfg
        steps_est = -(-scfg.n_requests * (
            scfg.max_new
            + scfg.prompt_len // max(scfg.prefill_chunk, 1))
            // max(scfg.n_replicas
                   * SHAPES[scfg.shape_name].global_batch, 1))
        job = ServeJob(
            name=f"{scfg.name}/r{i}", arch=scfg.arch,
            shape_name=scfg.shape_name,
            n_chips=scfg.chips_per_replica, steps=steps_est,
            priority=scfg.priority, service=scfg.name,
            tenant=scfg.name,
            replica=i, ttft_slo_s=scfg.ttft_slo_s,
            tpot_slo_s=scfg.tpot_slo_s,
            prefill_chunk=scfg.prefill_chunk)
        svc.replicas.append(job)
        self.jobs[job.name] = job
        return job

    def _replica_started(self, job: ServeJob, now: float) -> None:
        """A serve replica came up: open its runtime state, start its
        collective traffic, and drain the service backlog onto it.  No
        completion event — replicas run until their request trace drains."""
        job.progress_t = now
        self.draining.discard(job.name)     # a fresh incarnation admits
        old = self.replicas.get(job.name)
        if old is not None:
            # evicted and restarted within one poll: bank the retiring
            # incarnation's counters before replacing it
            self._stash_counters(old)
        self.replicas[job.name] = _Replica(job)
        self._push(now + self.cfg.compose_latency_s, "rate",
                   (job.name, job.epoch))
        svc = self.services[job.service]
        for _ in range(len(svc.backlog)):       # overflow re-queues on reps
            self._route_request(svc, svc.backlog.popleft(), now)

    def _route_request(self, svc: _Service, rid: int, now: float) -> None:
        """Least-loaded routing over the service's running replicas.
        Draining replicas (planned detach announced) stop admitting —
        unless every live replica is draining, in which case degraded
        service beats stranding the request."""
        live = [self.replicas[j.name] for j in svc.replicas
                if j.state == RUNNING and j.name in self.replicas]
        admitting = [r for r in live if r.job.name not in self.draining]
        live = admitting or live
        if not live:
            svc.backlog.append(rid)
            return
        rep = min(live, key=lambda r: (r.load(), r.job.replica))
        if len(rep.active) < rep.job.capacity:
            self._begin_request(rep, svc, rid, now)
        else:
            rep.queue.append(rid)
            svc.requests[rid]["replica"] = rep.job.name

    def _begin_request(self, rep: _Replica, svc: _Service, rid: int,
                       now: float) -> None:
        """Price one request on the replica: chunked prefill (cheaper on
        a prefix-cache hit) then ``max_new`` decode steps at the
        replica's calibrated step time."""
        req = svc.requests[rid]
        scfg = svc.cfg
        step_s = rep.job.step_s
        # a prefix is reusable only once some request's prefill of it has
        # FINISHED (mirrors the engine registering pages after prefill) —
        # concurrent burst arrivals on a cold prefix all miss
        ready = rep.prefixes.get(req["prefix"])
        hit = ready is not None and ready <= now
        cached = scfg.prefix_len if hit else 0
        rep.hit_tokens += cached
        rep.miss_tokens += scfg.prompt_len - cached
        n_chunks = -(-(scfg.prompt_len - cached)
                     // max(rep.job.prefill_chunk, 1))
        t_first = now + n_chunks * step_s
        if ready is None or t_first < ready:
            rep.prefixes[req["prefix"]] = t_first
        t_done = t_first + scfg.max_new * step_s
        req.update(replica=rep.job.name, start_t=now, cached=cached,
                   t_first=t_first, t_done=t_done, tpot=step_s,
                   slo=(rep.job.ttft_slo_s, rep.job.tpot_slo_s))
        rep.active.add(rid)
        self._push(t_done, "req_done",
                   (scfg.name, rid, req["attempt"]))

    def _finish_request(self, svc: _Service, rid: int, now: float) -> None:
        req = svc.requests[rid]
        req["done"] = True              # timeouts stop tracking it
        scfg = svc.cfg
        rep = self.replicas.get(req.get("replica"))
        if rep is not None:
            rep.active.discard(rid)
            rep.served += 1
            rep.out_tokens += scfg.max_new
            # KV traffic: uncached prompt + generated tokens append cache
            # pages over the replica's model-axis fabric
            links = rep.job.system.fabric.axis_links
            link = links.get("model") or next(iter(links.values()))
            nbytes = ((scfg.prompt_len - req["cached"]) + scfg.max_new) \
                * costmodel.kv_bytes_per_token(get_config(scfg.arch))
            self.telemetry.add_link_traffic(link, nbytes)
            while (rep.queue and len(rep.active) < rep.job.capacity
                   and rep.job.name not in self.draining):
                self._begin_request(rep, svc, rep.queue.popleft(), now)
        ttft = req["t_first"] - req["submit_t"]
        ttft_slo, tpot_slo = req["slo"]       # the serving replica's SLOs
        slo_ok = ttft <= ttft_slo and req["tpot"] <= tpot_slo
        svc.win_n += 1                        # autoscaler's rolling window
        svc.win_ok += slo_ok
        svc.stats.add_request(
            t_done=now, wait_s=req["start_t"] - req["submit_t"],
            ttft_s=ttft, tpot_s=req["tpot"],
            prompt_tokens=scfg.prompt_len, cached_tokens=req["cached"],
            output_tokens=scfg.max_new, slo_ok=slo_ok)
        svc.remaining -= 1
        if svc.remaining == 0:
            self._finish_service(svc, now)

    def _finish_service(self, svc: _Service, now: float) -> None:
        """Request trace drained: replicas complete and give their pools
        back — the re-aggregation moment composability exists for."""
        for job in svc.replicas:
            if job.state == RUNNING:
                self._rate_off(job.name)
                rep = self.replicas.pop(job.name, None)
                if rep is not None:
                    self._stash_counters(rep)
                self.scheduler.on_complete(job, now)
            elif job.state == QUEUED:
                # preempted and never restarted before the trace drained
                self.scheduler.complete_queued(
                    job, now, "service drained while queued")
        self._start_newly_scheduled(now)

    def _reassign_replica_requests(self, job: ServeJob, now: float) -> None:
        """A replica was preempted: its in-flight and queued requests go
        back to the service for re-routing (a fresh attempt invalidates
        their scheduled completions)."""
        self.draining.discard(job.name)
        rep = self.replicas.pop(job.name, None)
        if rep is None:
            return
        self._stash_counters(rep)
        svc = self.services[job.service]
        svc.scaling_down.discard(job.name)   # preemption cancels the drain
        for rid in sorted(rep.active) + list(rep.queue):
            req = svc.requests[rid]
            req["attempt"] += 1
            req.pop("replica", None)
            self._route_request(svc, rid, now)

    # --------------------------------------------------- serve resilience --
    def _arm_timeout(self, svc: _Service, rid: int, now: float) -> None:
        """Start (or restart, on a retry) the per-request deadline."""
        t_out = svc.cfg.request_timeout_s
        if t_out <= 0:
            return
        deadline = now + t_out
        svc.requests[rid]["deadline"] = deadline
        self._push(deadline, "req_timeout", (svc.cfg.name, rid, deadline))

    def _expire_request(self, svc: _Service, rid: int, deadline: float,
                        now: float) -> None:
        """Per-request timeout fired: pull the request back from wherever
        it sits (replica batch, replica queue, service backlog) and retry
        it with exponential backoff; past the retry budget it fails."""
        req = svc.requests[rid]
        if (req.get("done") or req.get("failed")
                or req.get("deadline") != deadline):
            return                      # finished, failed, or re-armed
        svc.stats.requests_timed_out += 1
        req["attempt"] += 1             # invalidates a scheduled req_done
        rep = self.replicas.get(req.get("replica"))
        if rep is not None:
            if rid in rep.active:
                rep.active.discard(rid)
                while (rep.queue and len(rep.active) < rep.job.capacity
                       and rep.job.name not in self.draining):
                    self._begin_request(rep, svc, rep.queue.popleft(), now)
            elif rid in rep.queue:
                rep.queue.remove(rid)
        req.pop("replica", None)
        if rid in svc.backlog:
            svc.backlog.remove(rid)
        retries = req.get("retries", 0)
        if retries < svc.cfg.max_request_retries:
            req["retries"] = retries + 1
            svc.stats.request_retries += 1
            backoff = svc.cfg.retry_backoff_s * (2.0 ** retries)
            self._push(now + backoff, "req_retry", (svc.cfg.name, rid))
        else:
            req["failed"] = True
            svc.stats.requests_failed += 1
            svc.remaining -= 1
            if svc.remaining == 0:
                self._finish_service(svc, now)

    def _health_check(self, svc: _Service, now: float) -> None:
        """Periodic replica probe: a running replica sitting on unhealthy
        devices has its load failed over to its siblings immediately —
        ahead of the cluster-level fault detection latency."""
        if svc.remaining <= 0:
            return                      # trace drained: stop probing
        healthy = {d.uid: d.healthy for d in self.pool.devices}
        for job in svc.replicas:
            if (job.state != RUNNING or job.system is None
                    or job.name not in self.replicas
                    or job.name in self.draining):
                continue
            if all(healthy.get(u, False) for u in job.system.device_uids):
                continue
            self.telemetry.log(now, "detect", job.name,
                               "health-check failover")
            self._reassign_replica_requests(job, now)
            # the cluster-level detect hasn't fired yet, so the job still
            # reads RUNNING — quarantine it from routing until it restarts
            self.draining.add(job.name)
        self._push(now + svc.cfg.health_check_s, "health", svc.cfg.name)

    # --------------------------------------------------------- autoscaling --
    def _autoscale_tick(self, svc: _Service, now: float) -> None:
        """Periodic (rng-free) scale decision: retire replicas whose
        planned drain finished, sample the window, then compare queued
        requests per admitting replica and windowed SLO attainment
        against the config targets.  Scale-up submits a new ServeJob
        through the ordinary admission path (the lease is priced like
        any other composition); scale-down marks the least-loaded
        replica draining — it stops admitting, finishes its in-flight
        work, and gives its chips back at a later tick."""
        if svc.remaining <= 0:
            return                      # trace drained: stop ticking
        if not any(j.state in (QUEUED, RUNNING) for j in svc.replicas):
            return      # every replica rejected/retired: the service is
                        # stranded and ticking forever would never drain
        cfg = svc.cfg
        self._retire_drained(svc, now)
        live = [j for j in svc.replicas
                if j.state == RUNNING and j.name in self.replicas]
        admitting = [j for j in live if j.name not in self.draining]
        queued = (sum(len(self.replicas[j.name].queue) for j in admitting)
                  + len(svc.backlog))
        per_rep = queued / max(len(admitting), 1)
        att = svc.win_ok / svc.win_n if svc.win_n else 1.0
        # replicas already requested count against the cap, so a slow
        # lease (queued scale-up) does not trigger a second one
        alive = [j for j in svc.replicas
                 if j.state in (QUEUED, RUNNING)
                 and j.name not in svc.scaling_down]
        svc.windows.append({
            "t": now, "attainment": att, "completed": svc.win_n,
            "queued_per_replica": per_rep, "replicas": len(alive)})
        svc.win_ok = svc.win_n = 0
        lo = cfg.min_replicas or cfg.n_replicas
        hi = cfg.max_replicas or 4 * cfg.n_replicas
        pressured = per_rep > cfg.scale_up_queue or att < cfg.slo_target
        # a rejected replica means the shape is analytically infeasible
        # on this pool — growth is permanently off, not retried forever
        can_grow = not any(j.state == REJECTED for j in svc.replicas)
        if pressured and can_grow and len(alive) < hi:
            self._scale_up(svc, now)
        elif (not pressured and per_rep < cfg.scale_down_queue
                and len(admitting) > lo and len(alive) > lo):
            self._scale_down(svc, admitting, now)
        self._push(now + cfg.autoscale_interval_s, "autoscale", cfg.name)

    def _retire_drained(self, svc: _Service, now: float) -> None:
        """Release the lease of any planned-drain replica that emptied:
        the scale-down's second half — chips return to the pool through
        ``on_complete`` exactly like a finished job."""
        for name in sorted(svc.scaling_down):
            job = self.jobs[name]
            rep = self.replicas.get(name)
            if job.state != RUNNING or rep is None:
                # preempted/failed mid-drain: the restart path already
                # re-routed its load; drop the drain plan
                svc.scaling_down.discard(name)
                self.draining.discard(name)
                continue
            if rep.load() > 0:
                continue                # still finishing in-flight work
            self._rate_off(name)
            self.replicas.pop(name)
            self._stash_counters(rep)
            self.draining.discard(name)
            svc.scaling_down.discard(name)
            self.telemetry.log(now, "autoscale", name,
                               "scale-down: drained, lease released")
            self.scheduler.on_complete(job, now)
            self._start_newly_scheduled(now)

    def _scale_up(self, svc: _Service, now: float) -> None:
        job = self._make_replica_job(svc, svc.next_replica)
        svc.next_replica += 1
        svc.scale_ups += 1
        self.telemetry.log(now, "autoscale", job.name,
                           f"scale-up: +1 replica for {svc.cfg.name}")
        self.scheduler.submit(job, now)
        self._start_newly_scheduled(now)

    def _scale_down(self, svc: _Service, admitting: List[ServeJob],
                    now: float) -> None:
        job = min(admitting,
                  key=lambda j: (self.replicas[j.name].load(), -j.replica))
        svc.scale_downs += 1
        svc.scaling_down.add(job.name)
        self.draining.add(job.name)     # stops admitting immediately
        self.telemetry.log(now, "autoscale", job.name,
                           "scale-down: draining")

    # ---------------------------------------------------------------- run --
    def run(self) -> Dict[str, object]:
        wall0 = time.perf_counter()
        self._gen_trace()
        self._observe(0.0)
        while self._heap:
            now, _, kind, payload = heapq.heappop(self._heap)
            if kind == "recompose_tick":
                if not self._heap and self.scheduler.all_done():
                    # trailing no-op tick scheduled before the trace
                    # drained: skip it before it can extend makespan
                    continue
                self._accrue(now)
                if self._recompose_tick(now):
                    self._now = now
                    self.scheduler.manager.check_exclusive()
                    self._observe(now)
                continue
            if self.recomposer is not None and kind in ("rate", "complete"):
                job = self.jobs[payload[0]]
                if job.state != RUNNING or job.epoch != payload[1]:
                    # epoch-stale no-op: with live recomposition every
                    # attach/detach strands one of these, and letting it
                    # advance the clock would bill the recomposed trace
                    # for time nothing ran (legacy traces keep the old
                    # accounting for bit-identity)
                    continue
            self._now = now
            self._accrue(now)
            if kind == "arrival":
                job = self.jobs[payload]
                self.scheduler.submit(job, now)
                self._start_newly_scheduled(now)
            elif kind == "rate":
                name, epoch = payload
                job = self.jobs[name]
                if job.state == RUNNING and job.epoch == epoch:
                    self._rate_on(job)
            elif kind == "complete":
                name, epoch = payload
                job = self.jobs[name]
                if job.state == RUNNING and job.epoch == epoch:
                    self._rate_off(name)
                    self.scheduler.on_complete(job, now)
                    self._start_newly_scheduled(now)
            elif kind == "req":
                svc_name, rid = payload
                svc = self.services[svc_name]
                svc.stats.requests_submitted += 1
                svc.stats.mark(now)
                self._route_request(svc, rid, now)
                self._arm_timeout(svc, rid, now)
            elif kind == "req_timeout":
                svc_name, rid, deadline = payload
                self._expire_request(self.services[svc_name], rid,
                                     deadline, now)
            elif kind == "req_retry":
                svc = self.services[payload[0]]
                req = svc.requests[payload[1]]
                if not (req.get("done") or req.get("failed")):
                    self._route_request(svc, payload[1], now)
                    self._arm_timeout(svc, payload[1], now)
            elif kind == "health":
                self._health_check(self.services[payload], now)
            elif kind == "autoscale":
                self._autoscale_tick(self.services[payload], now)
            elif kind == "req_done":
                svc_name, rid, attempt = payload
                svc = self.services[svc_name]
                if svc.requests[rid]["attempt"] == attempt:
                    self._finish_request(svc, rid, now)
            elif kind == "fail":
                # failure handling needs exact steps_done (checkpoint
                # boundaries, shrink re-planning): sync every running job
                # before the scheduler mutates them — failures are rare,
                # so this scan is off the per-event hot path
                for job in self.scheduler.running:
                    self._sync_steps(job, now)
                healthy = [d.uid for d in self.pool.healthy()]
                if isinstance(payload, tuple):
                    # explicit-repair row ("at", t_up, n): t_up None/inf
                    # means the devices stay dead forever
                    _, t_up, n_req = payload
                else:
                    t_up, n_req = now + self.cfg.repair_after_s, \
                        int(payload)
                n = min(n_req, len(healthy))
                down = self.rng.sample(healthy, n)
                changed = self.scheduler.on_failure(down, now)
                for job in changed:
                    self._reschedule_victim(job, now)
                # changed jobs were just rescheduled (restore overhead
                # included); only their co-tenants need a stall resync
                self._resync_stalls(now, exclude={j.name for j in changed})
                if t_up is not None and t_up != float("inf"):
                    self._push(t_up, "repair", down)
                self._start_newly_scheduled(now)
            elif kind == "repair":
                self.pool.repair(list(payload))
                self.telemetry.log(now, "repair", "",
                                   f"{len(payload)} device(s) back")
                self._start_newly_scheduled(now)
            elif kind == "fault":
                self.faults.on_fault(payload, now)
            elif kind == "detect":
                self.faults.on_detect(payload, now)
            elif kind == "fault_clear":
                self.faults.on_clear(payload, now)
            elif kind == "drain":
                self.faults.on_drain(payload, now)
            elif kind == "poll":
                # a retry-backoff gate opened: let the queue re-poll
                self._start_newly_scheduled(now)
            self.scheduler.manager.check_exclusive()
            self._observe(now)
        # jobs can legitimately remain queued when the heap drains (e.g.
        # permanent capacity loss); report() surfaces them as "stranded"
        self.wall_s = time.perf_counter() - wall0
        self.events_per_s = (len(self.telemetry.events) / self.wall_s
                             if self.wall_s > 0 else 0.0)
        rep = self.report()
        self._mirror_to_tracker(rep)
        return rep

    def _mirror_to_tracker(self, rep: Dict[str, object]) -> None:
        """Mirror the finished trace into the active tracking run (no-op
        without one): the control-plane event stream as ``event``
        records keyed by simulated time, plus one ``system`` sample of
        the harness counters (AUU, per-link byte rates, pool util)."""
        from repro import tracking
        run = self.tracker or tracking.current_run()
        if run is None:
            return
        for ev in self.telemetry.events:
            if ev.kind in ("submit", "start", "complete"):
                continue        # high-volume steady-state; keep the stream
                                # focused on recomposition-plane events
            run.log_event(f"sim.{ev.kind}",
                          {"job": ev.job, "detail": ev.detail}, sim_t=ev.t)
        counters = {"sim.auu": rep["auu"],
                    "sim.pool_utilization": rep["pool_utilization"]}
        for link, gbps in rep["link_traffic_gbps"].items():
            counters[f"sim.link_gbps.{link}"] = gbps
        for name, st in rep["storage"].items():
            counters[f"sim.storage_stall_s.{name}"] = st["input_stall_s"]
        run.log_system(counters)
        run.log({
            "makespan_s": rep["makespan_s"],
            "auu": rep["auu"],
            "pool_utilization": rep["pool_utilization"],
            "jobs_evicted": rep["jobs"]["evicted"],
            "jobs_shrunk": rep["jobs"]["shrunk"],
            "gangs_started": rep["gangs"]["started"],
            "recompositions": rep["recomposition"]["count"],
            "sim_wall_s": self.wall_s,
        })

    # ------------------------------------------------------------- report --
    def report(self) -> Dict[str, object]:
        rep = self.telemetry.report()
        sched = self.scheduler
        rep["jobs"]["stranded"] = len(sched.queue) + len(sched.running)
        rep["makespan_s"] = self._now
        rep["calibrated"] = bool(self.scheduler.calibration)
        # NOTE: wall_s / events_per_s are deliberately NOT in this dict —
        # report() must be bit-deterministic per seed; the bench layer
        # (benchmarks/cluster_sim) attaches the wall-time telemetry.
        rep["recompositions_per_job"] = {
            j.name: j.recompositions for j in sched.done
            if j.recompositions}
        rep["policy"] = self.scheduler.policy.name
        rep["config"] = {
            "n_jobs": self.cfg.n_jobs,
            "pool_devices": len(self.pool.devices),
            "arrival_rate_hz": self.cfg.arrival_rate_hz,
            "failures": list(self.cfg.failures),
            "seed": self.cfg.seed,
            "policy": self.cfg.policy,
            "n_scripted_arrivals": len(self.cfg.arrivals),
            "n_scripted_faults": (0 if self.cfg.faults is None
                                  else len(self.cfg.faults.faults)),
        }
        if self.services:
            rep["serving"] = {
                name: self._service_report(svc)
                for name, svc in self.services.items()}
        return rep

    def _service_report(self, svc: _Service) -> Dict[str, object]:
        out = svc.stats.report()
        out["requests"]["stranded"] = svc.remaining
        out["replicas"] = {}
        for job in svc.replicas:
            row: Dict[str, object] = {"state": job.state,
                                      "recompositions": job.recompositions}
            if job.plan is not None and job.plan.feasible:
                row["rated_tokens_per_s"] = job.tokens_per_s
            row.update(self._replica_counters(job.name))
            out["replicas"][job.name] = row
        if svc.cfg.autoscale:
            reps = [w["replicas"] for w in svc.windows]
            out["autoscale"] = {
                "scale_ups": svc.scale_ups,
                "scale_downs": svc.scale_downs,
                "peak_replicas": max(reps, default=svc.cfg.n_replicas),
                "final_replicas": len(
                    [j for j in svc.replicas if j.state == RUNNING]),
                "windows": svc.windows,
            }
        return out

    def _stash_counters(self, rep: _Replica) -> None:
        """Fold a retiring incarnation's counters into the durable tally
        (a preempted replica restarts with a cold cache, but its served
        work still counts)."""
        d = self._done_reps.setdefault(
            rep.job.name, {"served": 0, "output_tokens": 0,
                           "hit_tokens": 0, "miss_tokens": 0})
        d["served"] += rep.served
        d["output_tokens"] += rep.out_tokens
        d["hit_tokens"] += rep.hit_tokens
        d["miss_tokens"] += rep.miss_tokens

    def _replica_counters(self, name: str) -> Dict[str, object]:
        """Served/hit counters for a replica across all incarnations."""
        tally = dict(self._done_reps.get(
            name, {"served": 0, "output_tokens": 0,
                   "hit_tokens": 0, "miss_tokens": 0}))
        rep = self.replicas.get(name)
        if rep is not None:
            tally["served"] += rep.served
            tally["output_tokens"] += rep.out_tokens
            tally["hit_tokens"] += rep.hit_tokens
            tally["miss_tokens"] += rep.miss_tokens
        tot = tally["hit_tokens"] + tally["miss_tokens"]
        return {"served": tally["served"],
                "output_tokens": tally["output_tokens"],
                "cache_hit_rate": tally["hit_tokens"] / tot if tot else 0.0}


def run_trace(cfg: Optional[TraceConfig] = None) -> Dict[str, object]:
    """One-call entry point used by benchmarks and examples."""
    return ClusterSimulator(cfg or TraceConfig()).run()
