"""Trace-driven discrete-event simulation of the composable cluster.

The paper measures one composed system at a time; the simulator runs the
*cluster*: Poisson job arrivals drawn from a template mix over the
``configs/`` registry, scheduled by ``cluster.scheduler`` onto a shared
``DevicePool``, with injected device failures and repairs driving the
elastic recompose path.  Everything is priced analytically (no jax
device state), so a 512-chip, dozens-of-jobs trace simulates in well
under a second and is fully deterministic for a given seed.

Time accounting per event pop:

  1. accrue progress for every running job since the last event —
     steps completed and per-axis wire bytes (candidate ``wire_bytes``
     x devices), attributed to the link class its composition actually
     rides (this is Fig 12 per fabric, cluster-wide);
  2. apply the event (arrival / completion / failure / repair);
  3. let the scheduler start whatever now fits, pushing completion
     events at ``now + restore_overhead + remaining_steps x step_s``;
  4. integrate occupancy into telemetry (utilization + AUU).

Recomposition overhead models the checkpoint round-trip: parameter
bytes over the composition's storage tier, plus the compose latency —
the operational cost of the paper's attach/detach knob.
"""
from __future__ import annotations

import dataclasses
import heapq
import random
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.cluster.scheduler import RUNNING, Job, Scheduler
from repro.cluster.telemetry import Telemetry
from repro.core.topology import LinkClass, make_pool


@dataclasses.dataclass(frozen=True)
class JobTemplate:
    """One row of the trace mix."""
    arch: str
    shape_name: str
    n_chips: int
    steps: int
    weight: float = 1.0


# A mixed train/serve diet over small-to-mid archs: feasible on modest
# chip budgets, heterogeneous enough to exercise backfill.
DEFAULT_TEMPLATES: Tuple[JobTemplate, ...] = (
    JobTemplate("qwen2-0.5b", "train_4k", 16, 20, weight=3),
    JobTemplate("mamba2-780m", "train_4k", 32, 12, weight=2),
    JobTemplate("llama3.2-3b", "train_4k", 64, 8, weight=2),
    JobTemplate("llama3.2-3b", "prefill_32k", 16, 40, weight=2),
    JobTemplate("llama3.2-3b", "decode_32k", 64, 300, weight=2),   # mem-bound
    JobTemplate("stablelm-12b", "prefill_32k", 32, 20, weight=1),
    # collective-bound MoE train: spans locality cliques, stresses the
    # composed fabric and shows up as accelerator under-utilization
    JobTemplate("moonshot-v1-16b-a3b", "train_4k", 128, 6, weight=1),
)


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    n_jobs: int = 20
    arrival_rate_hz: float = 0.05          # Poisson arrivals, jobs/second
    seed: int = 0
    n_local: int = 256
    n_switch: int = 256
    pods: int = 2
    templates: Tuple[JobTemplate, ...] = DEFAULT_TEMPLATES
    # (time_s, n_devices) injection points; repaired after repair_after_s
    failures: Tuple[Tuple[float, int], ...] = ((120.0, 12),)
    repair_after_s: float = 300.0
    backfill: bool = True
    compose_latency_s: float = 2.08e-6 * 64   # switch reprogram, Table IV
    # optional measured-cost layer (core.costmodel.CalibratedCost): jobs
    # are admitted and priced from measurements instead of pure analytics
    calibration: Optional[object] = None


def restore_overhead_s(job: Job) -> float:
    """Checkpoint round-trip cost of (re)forming ``job``'s composition —
    the same estimate the scheduler's backfill guard uses."""
    return job.est_restore_s()


class ClusterSimulator:
    """Discrete-event loop over a shared pool; deterministic per seed."""

    def __init__(self, cfg: TraceConfig):
        self.cfg = cfg
        self.pool = make_pool(n_local=cfg.n_local, n_switch=cfg.n_switch,
                              pods=cfg.pods)
        self.telemetry = Telemetry(len(self.pool.devices))
        self.scheduler = Scheduler(self.pool, self.telemetry,
                                   backfill=cfg.backfill,
                                   calibration=cfg.calibration)
        self.rng = random.Random(cfg.seed)
        self.jobs: Dict[str, Job] = {}
        self._heap: List[Tuple[float, int, str, object]] = []
        self._seq = 0
        self._now = 0.0
        # incremental per-link traffic accounting: instead of scanning
        # every running job's wire_bytes dict at every event, each job's
        # bytes/sec contribution is folded into ``_link_rate`` when it
        # starts stepping and removed when it stops/recomposes; accrual
        # is then O(#link classes) per event
        self._link_rate: Dict[LinkClass, float] = {}
        self._job_rate: Dict[str, Dict[LinkClass, float]] = {}
        self._accrue_t = 0.0
        self.wall_s = 0.0           # wall-clock of the last run() call
        self.events_per_s = 0.0

    # ------------------------------------------------------------- events --
    def _push(self, t: float, kind: str, payload: object = None) -> None:
        heapq.heappush(self._heap, (t, self._seq, kind, payload))
        self._seq += 1

    def _gen_trace(self) -> None:
        t = 0.0
        weights = [tpl.weight for tpl in self.cfg.templates]
        for i in range(self.cfg.n_jobs):
            t += self.rng.expovariate(self.cfg.arrival_rate_hz)
            tpl = self.rng.choices(self.cfg.templates, weights=weights)[0]
            job = Job(name=f"job-{i:03d}-{tpl.arch}-{tpl.shape_name}",
                      arch=tpl.arch, shape_name=tpl.shape_name,
                      n_chips=tpl.n_chips, steps=tpl.steps)
            self.jobs[job.name] = job
            self._push(t, "arrival", job.name)
        for t_fail, n in self.cfg.failures:
            self._push(t_fail, "fail", n)

    # ------------------------------------------------------------ accrual --
    def _job_link_rate(self, job: Job) -> Dict[LinkClass, float]:
        """bytes/sec this job puts on each link class while stepping."""
        rates: Dict[LinkClass, float] = {}
        if job.system is None or job.plan is None:
            return rates
        per_step = job.system.n_devices / max(job.step_s, 1e-30)
        for axis, nbytes in job.plan.wire_bytes.items():
            if nbytes <= 0 or axis not in job.system.fabric.axis_links:
                continue
            link = job.system.fabric.axis_links[axis]
            rates[link] = rates.get(link, 0.0) + nbytes * per_step
        return rates

    def _rate_on(self, job: Job) -> None:
        self._rate_off(job.name)
        rates = self._job_link_rate(job)
        if not rates:
            return
        self._job_rate[job.name] = rates
        for link, r in rates.items():
            self._link_rate[link] = self._link_rate.get(link, 0.0) + r

    def _rate_off(self, name: str) -> None:
        for link, r in self._job_rate.pop(name, {}).items():
            self._link_rate[link] -= r

    def _accrue(self, now: float) -> None:
        """Integrate link traffic up to ``now`` (O(#links), not O(jobs))."""
        dt = now - self._accrue_t
        if dt > 0:
            for link, rate in self._link_rate.items():
                if rate > 0:
                    self.telemetry.add_link_traffic(link, rate * dt)
        self._accrue_t = max(self._accrue_t, now)

    def _sync_steps(self, job: Job, now: float) -> None:
        """Bring one job's ``steps_done`` up to ``now`` (lazy: called only
        when an event actually needs the figure — checkpoint on failure,
        preemption, shrink re-planning)."""
        t0 = max(job.progress_t, job.start_t)
        if now <= t0:
            return
        d_steps = min((now - t0) / max(job.step_s, 1e-30),
                      job.remaining_steps())
        job.steps_done += d_steps
        job.progress_t = now

    def _observe(self, now: float) -> None:
        self.telemetry.observe(
            now, n_leased=len(self.pool.leases),
            busy_equiv=self.scheduler.busy_equiv(),
            n_healthy=len(self.pool.healthy()))

    def _schedule_completion(self, job: Job, now: float,
                             overhead: float = 0.0) -> None:
        if overhead > 0:
            self.telemetry.add_recomposition(overhead)
        start = now + overhead + self.cfg.compose_latency_s
        job.progress_t = start          # stepping resumes after the restore
        # link traffic begins when stepping does, not at lease time: the
        # rate event folds the job's bytes/sec into the accumulators then
        self._push(start, "rate", (job.name, job.epoch))
        self._push(start + job.est_duration_s(), "complete",
                   (job.name, job.epoch))

    def _start_newly_scheduled(self, now: float) -> None:
        for job in self.scheduler.poll(now):
            # a preempted job resuming from a checkpoint pays the restore
            overhead = restore_overhead_s(job)
            self._schedule_completion(job, now, overhead)

    # ---------------------------------------------------------------- run --
    def run(self) -> Dict[str, object]:
        wall0 = time.perf_counter()
        self._gen_trace()
        self._observe(0.0)
        while self._heap:
            now, _, kind, payload = heapq.heappop(self._heap)
            self._now = now
            self._accrue(now)
            if kind == "arrival":
                job = self.jobs[payload]
                self.scheduler.submit(job, now)
                self._start_newly_scheduled(now)
            elif kind == "rate":
                name, epoch = payload
                job = self.jobs[name]
                if job.state == RUNNING and job.epoch == epoch:
                    self._rate_on(job)
            elif kind == "complete":
                name, epoch = payload
                job = self.jobs[name]
                if job.state == RUNNING and job.epoch == epoch:
                    self._rate_off(name)
                    self.scheduler.on_complete(job, now)
                    self._start_newly_scheduled(now)
            elif kind == "fail":
                # failure handling needs exact steps_done (checkpoint
                # boundaries, shrink re-planning): sync every running job
                # before the scheduler mutates them — failures are rare,
                # so this scan is off the per-event hot path
                for job in self.scheduler.running:
                    self._sync_steps(job, now)
                healthy = [d.uid for d in self.pool.healthy()]
                n = min(int(payload), len(healthy))
                down = self.rng.sample(healthy, n)
                changed = self.scheduler.on_failure(down, now)
                for job in changed:
                    self._rate_off(job.name)      # re-enabled at restart
                    if job.state == RUNNING:      # shrunk in place
                        self._schedule_completion(
                            job, now, restore_overhead_s(job))
                self._push(now + self.cfg.repair_after_s, "repair", down)
                self._start_newly_scheduled(now)
            elif kind == "repair":
                self.pool.repair(list(payload))
                self.telemetry.log(now, "repair", "",
                                   f"{len(payload)} device(s) back")
                self._start_newly_scheduled(now)
            self.scheduler.manager.check_exclusive()
            self._observe(now)
        # jobs can legitimately remain queued when the heap drains (e.g.
        # permanent capacity loss); report() surfaces them as "stranded"
        self.wall_s = time.perf_counter() - wall0
        self.events_per_s = (len(self.telemetry.events) / self.wall_s
                             if self.wall_s > 0 else 0.0)
        return self.report()

    # ------------------------------------------------------------- report --
    def report(self) -> Dict[str, object]:
        rep = self.telemetry.report()
        sched = self.scheduler
        rep["jobs"]["stranded"] = len(sched.queue) + len(sched.running)
        rep["makespan_s"] = self._now
        rep["calibrated"] = bool(self.scheduler.calibration)
        # NOTE: wall_s / events_per_s are deliberately NOT in this dict —
        # report() must be bit-deterministic per seed; the bench layer
        # (benchmarks/cluster_sim) attaches the wall-time telemetry.
        rep["recompositions_per_job"] = {
            j.name: j.recompositions for j in sched.done
            if j.recompositions}
        rep["config"] = {
            "n_jobs": self.cfg.n_jobs,
            "pool_devices": len(self.pool.devices),
            "arrival_rate_hz": self.cfg.arrival_rate_hz,
            "failures": list(self.cfg.failures),
            "seed": self.cfg.seed,
        }
        return rep


def run_trace(cfg: Optional[TraceConfig] = None) -> Dict[str, object]:
    """One-call entry point used by benchmarks and examples."""
    return ClusterSimulator(cfg or TraceConfig()).run()
