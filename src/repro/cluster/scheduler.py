"""Multi-tenant scheduler over the shared composable pool.

Jobs (train / prefill / decode, drawn from the ``configs/`` registry)
queue for slices of the device pool.  For each job the scheduler:

  1. **admits** it only if the analytic model (``core.recommend``) finds a
     feasible (dp, tp) factorization of the requested chip budget —
     batch divisibility, MoE expert divisibility, and the per-device HBM
     estimate are all checked, so a 35B train job asking for 2 chips is
     rejected at submit time instead of OOMing at compose time;
  2. **places** it with domain-aware leasing (``cluster.lease``): the tp
     axis stays inside a locality clique when possible, and the per-axis
     link classes of the composition follow from where the free devices
     actually are (localGPUs / hybridGPUs / falconGPUs emerge from pool
     state);
  3. **starts** it via ``core.compose`` — which claims an exclusive lease
     on the devices, so two jobs can never hold the same chip;
  4. on device failure, **preempts-to-shrink** using ``train.elastic``
     semantics: same-shape recompose from spares when they exist, halve
     the data axis when they don't, re-queue the job when even a 1-wide
     mesh no longer fits.

Queue policy is **pluggable** (``Policy``): ``easy`` is priority FIFO
with EASY backfill — the head job reserves the earliest time enough
devices free up (running jobs expose analytic end-time estimates), and
a later job may jump ahead only if it fits the free pool *and* its
estimated finish does not push past the reservation.  ``fair_share``
orders the queue by per-tenant weighted deficit (device-seconds
consumed divided by tenant weight — the least-served tenant goes
first), and ``priority_preempt`` extends ``easy`` with policy-driven
preemption: a higher-priority head may shrink or evict lower-priority
running jobs (including whole gangs) through the ``train/elastic``
checkpoint-resume path.

Jobs with ``n_pods > 1`` are **gangs**: an all-or-nothing multi-pod
composition over the DCN axis (``lease.plan_gang``), admitted with the
pod axis priced on the DCN links (``recommend._estimate(pods=...)``
reusing ``Candidate.wire_bytes``/``CalibratedCost``).

Invariants:

  * **Atomic composition** — a job either holds its full device claim
    (all gang members) plus a storage tranche, or nothing: a conflict
    anywhere rolls the whole claim back (``CompositionError``), the job
    stays queued, and the conflict is counted.
  * **Stall re-derivation** — whenever tranche contention changes
    (start / complete / preempt / shrink), every running job's
    ``input_stall_s`` is re-derived (``update_stalls``) and changed
    jobs are queued on ``stall_dirty`` for the simulator to re-price.
  * **Gangs are all-or-nothing at runtime too** — losing any member
    device preempts the whole gang (no cross-pod shrink).
  * **Checkpoint-boundary resume** — preemption and policy shrink floor
    ``steps_done`` to the last integer step; the restore cost is priced
    against the *contended* tranche bandwidth (``restore_s``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

from repro.cluster.lease import (GangPlan, LeaseManager, derive_axis_paths,
                                 domain_counts, hosting_domains, path_maps,
                                 plan_gang, plan_placement, plan_tranche)
from repro.cluster.telemetry import Telemetry
from repro.configs import get_config
from repro.configs.base import SHAPES
from repro.core import recommend
from repro.core.compose import (ComposedSystem, CompositionError, compose,
                                recompose, release)
from repro.core.topology import Device, DevicePool, LinkClass
from repro.data.pipeline import (IOWorkload, StorageModel, lm_io_workload,
                                 workload_stall)
from repro.data.storage import StoragePool, make_storage_pool
from repro.train import elastic

QUEUED, RUNNING, DONE, REJECTED = "queued", "running", "done", "rejected"
# terminal state for jobs whose fault-retry budget is exhausted (the
# fault-injection plane's capped retry-with-backoff; see cluster.faults)
FAILED = "failed"


@dataclasses.dataclass
class Job:
    """One tenant workload: an (arch, shape) cell plus a chip budget."""
    name: str
    arch: str
    shape_name: str                  # train_4k | prefill_32k | decode_32k
    n_chips: int
    steps: int = 10
    priority: int = 0
    # lifecycle (filled by the scheduler)
    state: str = QUEUED
    submit_t: float = 0.0
    queued_t: float = 0.0            # last time the job entered the queue
    start_t: float = 0.0
    progress_t: float = 0.0          # last time steps_done was brought up
    end_t: float = 0.0
    plan: Optional[recommend.Candidate] = None
    system: Optional[ComposedSystem] = None
    run: Optional[elastic.ElasticRun] = None
    steps_done: float = 0.0
    recompositions: int = 0
    epoch: int = 0                   # bumped on every shape change/preempt
    why_rejected: str = ""
    # storage: the job's I/O shape (defaulted from the arch/shape cell at
    # submit) and the contended input stall on its leased tranche (updated
    # by the scheduler as co-tenants come and go)
    io: Optional[IOWorkload] = None
    input_stall_s: float = 0.0
    # gang scheduling: n_pods > 1 requests an all-or-nothing multi-pod
    # composition over the DCN axis; gang_domains records the member
    # locality domains of the current placement
    n_pods: int = 1
    gang_domains: Tuple[int, ...] = ()
    # fairness accounting tenant; "" bills the job to its own name
    tenant: str = ""
    # anti-thrash: policy evictions consumed / allowed.  A job at its
    # budget is pinned runnable — priority_preempt stops considering it
    # a victim (counted as ``evictions_suppressed`` in telemetry) — so a
    # low-priority job repeatedly evicted by arriving gangs still
    # finishes.  Failure preemptions do not consume budget.
    evictions: int = 0
    max_evictions: int = 3
    # fault-recovery budget (cluster.faults): fault-driven preemptions
    # consume retries with exponential backoff; past ``max_retries`` the
    # job fails permanently (state FAILED).  Legacy failure preemptions
    # (TraceConfig.failures) do not consume this budget.
    retries: int = 0
    max_retries: int = 3
    not_before_t: float = 0.0        # backoff gate: poll() skips until then
    fault_t: float = -1.0            # injection time of the pending fault
    #                                  (-1 = none); cleared at restart when
    #                                  the recovery-time sample is taken
    # live recomposition (cluster.recomposer) opt-in: only elastic jobs
    # may be attach-widened, shrunk-to-admit, or tranche-migrated
    # mid-run; the default keeps every legacy job frozen at admission
    elastic: bool = False

    @property
    def kind(self) -> str:
        return SHAPES[self.shape_name].kind

    @property
    def tenant_key(self) -> str:
        return self.tenant or self.name

    @property
    def dp_tp(self) -> Tuple[int, int]:
        assert self.plan is not None
        return self.plan.shape[-2], self.plan.shape[-1]

    @property
    def step_s(self) -> float:
        """Effective step time: the CalibratedCost-priced plan step plus
        the contended input stall of the job's storage tranche."""
        assert self.plan is not None
        return self.plan.step_s + self.input_stall_s

    @property
    def tranche(self) -> Optional[str]:
        return self.system.tranche if self.system is not None else None

    def remaining_steps(self) -> float:
        return max(0.0, self.steps - self.steps_done)

    def est_duration_s(self) -> float:
        return self.remaining_steps() * self.step_s

    def est_restore_s(self) -> float:
        """Checkpoint-restore cost a resumed job pays before stepping:
        the fp32 parameters read back over the composition's storage tier
        (NVMe-class estimate while queued, placement unknown)."""
        if self.steps_done <= 0:
            return 0.0
        from repro.core.topology import LOCAL_NVME
        pbytes = get_config(self.arch).param_count() * 4.0
        if self.system is not None:
            return pbytes / self.system.fabric.storage.effective_read_bw(
                self.system.fabric.links)
        return pbytes / LOCAL_NVME.read_bw

    @property
    def est_end_t(self) -> float:
        # anchored at the last progress accrual, not start_t: remaining
        # steps shrink as steps_done grows, so start_t-anchoring would
        # drift the estimate earlier and earlier while the job runs
        return max(self.progress_t, self.start_t) + self.est_duration_s()


@dataclasses.dataclass
class ServeJob(Job):
    """One serving *replica*: a long-lived inference tenant.

    A logical service runs ``n_replicas`` of these, each leasing its own
    pool slice through the ordinary admission path (the shape cell —
    ``decode_32k`` by default — prices the replica analytically, and
    ``calibrate_candidate`` folds measured step times / tuned-kernel
    speedups in, so token throughput is CalibratedCost-priced).  Unlike a
    training job, a replica does not finish after ``steps`` — the
    simulator completes it when its service's request trace drains;
    ``steps`` only feeds the scheduler's EASY-backfill end-time estimate.
    """
    service: str = ""                # logical service this replica serves
    replica: int = 0
    ttft_slo_s: float = 2.0
    tpot_slo_s: float = 0.5
    prefill_chunk: int = 512         # chunked-prefill tokens per step

    @property
    def capacity(self) -> int:
        """Concurrent sequences in the decode batch."""
        return SHAPES[self.shape_name].global_batch

    def throughput(self) -> Dict[str, float]:
        """CalibratedCost-priced serving rates on the replica's actual
        placement (``plan.step_s`` is re-priced at start time)."""
        from repro.core import costmodel
        return costmodel.serving_throughput(
            get_config(self.arch), SHAPES[self.shape_name], self.step_s)

    @property
    def tokens_per_s(self) -> float:
        return self.throughput()["tokens_per_s"]


# ---------------------------------------------------------------------------
# pluggable scheduling policies
# ---------------------------------------------------------------------------
class Policy:
    """Queue-ordering / preemption policy plugged into ``Scheduler``.

    ``order`` returns the queue in service order for this poll (the
    first element is the head the backfill reservation protects); it
    must be a pure, deterministic function of scheduler state.
    ``make_room`` may preempt running work to fit ``job`` and returns
    True iff it freed at least one device (the scheduler then
    re-evaluates the queue); the base policy never preempts.
    """

    name = "policy"

    def order(self, sched: "Scheduler", now: float) -> List[Job]:
        raise NotImplementedError

    def make_room(self, sched: "Scheduler", job: Job, now: float) -> bool:
        return False


class EasyPolicy(Policy):
    """Priority FIFO with EASY backfill — the original (PR 1) behavior,
    bit-for-bit: order by (-priority, submit time); never preempt."""

    name = "easy"

    def order(self, sched: "Scheduler", now: float) -> List[Job]:
        return sorted(sched.queue, key=lambda j: (-j.priority, j.submit_t))


class FairSharePolicy(Policy):
    """Per-tenant weighted deficit ordering.

    Each tenant accrues usage as device-seconds of running leases
    (``Scheduler.tenant_usage``); the queue is ordered by
    ``usage / weight`` ascending — the tenant that has consumed the
    least of its entitlement goes first — with (-priority, submit time)
    breaking ties, so a flooding tenant cannot starve light tenants the
    way plain FIFO does.  Unknown tenants weigh 1.0.
    """

    name = "fair_share"

    def __init__(self, tenant_weights: Optional[Mapping[str, float]] = None):
        self.weights = {k: float(v)
                        for k, v in dict(tenant_weights or {}).items()}

    def deficit(self, sched: "Scheduler", tenant: str) -> float:
        w = max(self.weights.get(tenant, 1.0), 1e-9)
        return sched.tenant_usage.get(tenant, 0.0) / w

    def order(self, sched: "Scheduler", now: float) -> List[Job]:
        sched._accrue_usage(now)
        return sorted(sched.queue,
                      key=lambda j: (self.deficit(sched, j.tenant_key),
                                     -j.priority, j.submit_t))


class PriorityPreemptPolicy(Policy):
    """EASY ordering plus policy preemption: when the head does not fit,
    strictly-lower-priority running jobs are shrunk (halve the data
    axis, when that alone covers the shortfall and the halved mesh is
    feasible) or evicted whole — lowest priority first, then youngest —
    through the ``train/elastic`` checkpoint-resume path.  Gangs are
    evicted atomically (no cross-pod shrink)."""

    name = "priority_preempt"

    def order(self, sched: "Scheduler", now: float) -> List[Job]:
        return sorted(sched.queue, key=lambda j: (-j.priority, j.submit_t))

    def make_room(self, sched: "Scheduler", job: Job, now: float) -> bool:
        """Preempt lower-priority work for ``job`` — but only when the
        candidate evictions can actually make it placeable.  Evicting
        victims for a head that stays blocked anyway (e.g. pinned by an
        equal-priority job) would let backfill restart the victim and
        the next poll iteration evict it again: a livelock at one
        simulated timestamp.

        Victims at their eviction budget (``Job.max_evictions``) are
        pinned runnable: excluded from candidacy (and counted in
        ``telemetry.jobs_evictions_suppressed``), so repeated arrivals
        cannot thrash one low-priority job forever."""
        candidates = [r for r in sched.running if r.priority < job.priority]
        pinned = sum(1 for r in candidates
                     if r.evictions >= r.max_evictions)
        if pinned:
            sched.telemetry.jobs_evictions_suppressed += pinned
        victims = sorted(
            (r for r in candidates if r.evictions < r.max_evictions),
            key=lambda r: (r.priority, -r.start_t, r.name))
        if not victims:
            return False
        if job.n_pods > 1:
            return self._make_room_for_gang(sched, job, victims, now)
        need = job.n_chips - len(sched.pool.available())
        if need <= 0:
            return False
        if need > sum(v.system.n_devices for v in victims
                      if v.system is not None):
            return False         # head cannot fit even if every victim goes
        acted = False
        for victim in victims:
            if need <= 0:
                break
            held = victim.system.n_devices if victim.system else 0
            freed = 0
            if need <= held // 2:
                freed = sched.preempt_to_shrink(victim, now)
            if freed == 0:
                freed = sched.evict(victim, now, for_job=job.name)
            need -= freed
            acted = acted or freed > 0
        return acted

    @staticmethod
    def _make_room_for_gang(sched: "Scheduler", job: Job,
                            victims: List[Job], now: float) -> bool:
        """Free whole member cliques for a gang head.

        A gang blocked by domain *fragmentation* can have enough free
        chips in total (raw shortfall <= 0) while no ``n_pods`` domains
        hold a full member each, so room is made per-domain: target the
        ``n_pods`` large-enough domains needing the fewest evictions and
        evict victims holding devices there until each member fits.
        Shrink is skipped — a recompose may relocate the victim's claim,
        so only eviction reliably frees chips in the chosen domain.
        """
        per_pod = job.n_chips // job.n_pods
        dom_of = {d.uid: d.domain for d in sched.pool.devices}
        healthy = domain_counts([d for d in sched.pool.devices if d.healthy])
        victim_in: Dict[int, int] = {}
        for v in victims:
            for u in (v.system.device_uids if v.system is not None else ()):
                victim_in[dom_of[u]] = victim_in.get(dom_of[u], 0) + 1

        def free_in(dom: int) -> int:
            return sum(1 for d in sched.pool.available()
                       if d.domain == dom)

        # a domain is a viable member host only if evicting every victim
        # there would actually complete a clique — otherwise the gang
        # stays blocked and the evictions just thrash (livelock guard)
        eligible = [dom for dom, cap in healthy.items()
                    if cap >= per_pod
                    and free_in(dom) + victim_in.get(dom, 0) >= per_pod]
        if len(eligible) < job.n_pods:
            return False
        targets = sorted(eligible,
                         key=lambda dom: (max(0, per_pod - free_in(dom)),
                                          dom))[:job.n_pods]
        acted = False
        for dom in targets:
            for victim in victims:
                if free_in(dom) >= per_pod:
                    break
                if victim.state != RUNNING or victim.system is None:
                    continue
                if not any(dom_of[u] == dom
                           for u in victim.system.device_uids):
                    continue
                freed = sched.evict(victim, now, for_job=job.name)
                acted = acted or freed > 0
        return acted


POLICIES = ("easy", "fair_share", "priority_preempt")


def make_policy(name: str,
                tenant_weights: Optional[Mapping[str, float]] = None
                ) -> Policy:
    """Policy factory used by ``Scheduler`` and ``TraceConfig``."""
    if name == "easy":
        return EasyPolicy()
    if name == "fair_share":
        return FairSharePolicy(tenant_weights)
    if name == "priority_preempt":
        return PriorityPreemptPolicy()
    raise ValueError(f"unknown policy {name!r}; known: {POLICIES}")


class Scheduler:
    """Policy-driven multi-tenant scheduler with elastic failure handling."""

    def __init__(self, pool: DevicePool, telemetry: Optional[Telemetry] = None,
                 backfill: bool = True, calibration=None,
                 storage: Optional[StoragePool] = None,
                 policy: "Policy | str" = "easy",
                 tenant_weights: Optional[Mapping[str, float]] = None):
        self.pool = pool
        self.telemetry = telemetry or Telemetry(len(pool.devices))
        self.backfill = backfill
        # measured-cost layer (core.costmodel.CalibratedCost): admission
        # and pricing use calibrated step times when measurements exist.
        # None defers to recommend.get_calibration() at use time, so a
        # later set_calibration() reaches already-built schedulers.
        self._calibration = calibration
        # storage tranches are first-class: every started job holds one
        # (admission-to-run requires the lease; see _start)
        self.storage = storage if storage is not None else \
            make_storage_pool(links=pool.links)
        self.manager = LeaseManager(pool, self.storage)
        self.queue: List[Job] = []
        self.running: List[Job] = []
        self.done: List[Job] = []
        self.rejected: List[Job] = []
        self.failed: List[Job] = []      # retry budget exhausted (terminal)
        # jobs whose contended input stall changed while running, keyed by
        # name with the stall value before the FIRST undrained change —
        # the simulator drains this to re-schedule completion events (the
        # old stall prices progress already made).  Keyed (not a list) so
        # it stays bounded by the running set even when nothing drains it;
        # entries are dropped when a job stops running.
        self.stall_dirty: Dict[str, Tuple[Job, float]] = {}
        # pluggable queue policy (see Policy subclasses above)
        self.policy = policy if isinstance(policy, Policy) \
            else make_policy(policy, tenant_weights)
        # fair-share bookkeeping: tenant -> device-seconds of running
        # leases, integrated lazily up to _usage_t
        self.tenant_usage: Dict[str, float] = {}
        self._usage_t = 0.0
        # jobs the policy shrank or evicted this poll, drained by the
        # simulator (mirrors stall_dirty) to fix rates/events
        self.policy_victims: List[Job] = []
        # optional hook the simulator installs so policy preemptions see
        # exact steps_done before checkpointing (lazy progress accrual)
        self.sync_progress: Optional[Callable[[Job, float], None]] = None

    @property
    def calibration(self):
        return self._calibration if self._calibration is not None \
            else recommend.get_calibration()

    # ------------------------------------------------------------- admit --
    def _candidates_for(self, job: Job, n_chips: Optional[int] = None
                        ) -> List[recommend.Candidate]:
        cfg = get_config(job.arch)
        shape = SHAPES[job.shape_name]
        n = n_chips or job.n_chips
        # under a multi-tier topology, admission derates the collective
        # term for candidates that must span drawers (the flat fabric
        # passes no hint — the legacy admission path, bit-for-bit)
        topo_kw = {}
        if self.pool.topo.name != "single_switch":
            topo_kw = dict(
                topology=self.pool.topo,
                domain_chips=max(domain_counts(self.pool.devices).values(),
                                 default=0))
        if job.n_pods > 1:
            # gang admission: (dp, tp) factorizations of the per-pod
            # budget, with the pod axis's collective traffic priced on
            # the pool's actual DCN links (Candidate.wire_bytes["pod"])
            dcn_bw = self.pool.links[LinkClass.DCN].bandwidth
            return [recommend.calibrate_candidate(
                        recommend._estimate(cfg, shape, dp, tp,
                                            pods=job.n_pods, dcn_bw=dcn_bw,
                                            **topo_kw),
                        cfg, job.arch, job.shape_name, shape,
                        self.calibration)
                    for dp, tp in recommend.candidates(n // job.n_pods)]
        return [recommend.calibrate_candidate(
                    recommend._estimate(cfg, shape, dp, tp, **topo_kw),
                    cfg, job.arch, job.shape_name, shape, self.calibration)
                for dp, tp in recommend.candidates(n)]

    @staticmethod
    def _best(cands: List[recommend.Candidate]
              ) -> Optional[recommend.Candidate]:
        feasible = sorted((c for c in cands if c.feasible),
                          key=lambda c: c.step_s)
        return feasible[0] if feasible else None

    def plan_job(self, job: Job, n_chips: Optional[int] = None
                 ) -> Optional[recommend.Candidate]:
        """Best feasible (dp, tp) candidate at the given chip budget."""
        return self._best(self._candidates_for(job, n_chips))

    def _with_axis_paths(self, system: ComposedSystem, tp: int
                         ) -> ComposedSystem:
        """Re-derive the per-axis link class, hop count and bandwidth
        derate from the system's *actual* claim and fold them into its
        fabric — the spare devices of an elastic recompose may sit on a
        different fabric (or a more distant drawer) than the original
        selection.  A no-op when nothing changed."""
        links, hops, scale = path_maps(
            derive_axis_paths(self.pool, system.device_uids, tp))
        fab = system.fabric
        if (dict(fab.axis_links) != links or dict(fab.axis_hops) != hops
                or dict(fab.axis_bw_scale) != scale):
            system = dataclasses.replace(
                system, fabric=dataclasses.replace(
                    fab, axis_links=links, axis_hops=hops,
                    axis_bw_scale=scale))
        return system

    @staticmethod
    def _repriced(plan: recommend.Candidate, system: ComposedSystem
                  ) -> recommend.Candidate:
        """Re-price the collective term on the fabric the job actually got.

        The admission-time estimate assumes full-speed ICI on every axis;
        once placed, each axis's wire bytes are priced on the real path —
        derated link bandwidth plus one link latency per hop beyond the
        first (``FabricSpec.axis_time``; exactly ``nbytes / bandwidth``
        on the flat 1-hop fabric) — so a switch-, cascade- or
        DCN-spanning placement runs measurably slower, which is the
        paper's local-vs-falcon gap at cluster level.
        """
        coll = 0.0
        for axis, nbytes in plan.wire_bytes.items():
            if nbytes <= 0:
                continue
            if axis in system.fabric.axis_links:
                coll += system.fabric.axis_time(axis, nbytes)
            else:
                link, hops = system.fabric.slowest_path()
                coll += nbytes / link.bandwidth + (hops - 1) * link.latency
        terms = dict(plan.terms)
        terms["collective"] = coll
        step = max(terms.get("compute", 0.0), terms.get("memory", 0.0), coll)
        if "measured" in terms:
            # a measured cell step already includes compute+memory; only a
            # slower-than-assumed fabric can push it higher
            step = max(terms["measured"], coll)
        return dataclasses.replace(plan, step_s=step, terms=terms)

    def submit(self, job: Job, now: float = 0.0) -> bool:
        """Admission control; returns False (and records why) on rejection."""
        self.telemetry.jobs_submitted += 1
        job.submit_t = now
        job.queued_t = now
        if job.io is None:
            job.io = lm_io_workload(get_config(job.arch),
                                    SHAPES[job.shape_name])
        max_tranche = max((t.capacity_bytes
                           for t in self.storage.tranches.values()),
                          default=0.0)
        if job.n_chips > len(self.pool.devices):
            job.state = REJECTED
            job.why_rejected = (f"requests {job.n_chips} chips; pool has "
                                f"{len(self.pool.devices)}")
        elif job.n_pods > 1 and job.n_chips % job.n_pods:
            job.state = REJECTED
            job.why_rejected = (f"{job.n_chips} chips do not divide over "
                                f"{job.n_pods} gang pods")
        elif job.n_pods > 1 and (gang_why := self._gang_impossible(job)):
            # a gang that can never place (more pods than the pool has
            # domains, or a member clique larger than every domain) must
            # reject at submit instead of stranding at the queue head
            job.state = REJECTED
            job.why_rejected = gang_why
        elif self._storage_request(job) > max_tranche:
            # a dataset no tranche can EVER host must reject at submit,
            # not livelock at the head of the queue raising storage
            # conflicts on every poll
            job.state = REJECTED
            job.why_rejected = (
                f"dataset {self._storage_request(job) / 1e12:.2f} TB "
                f"exceeds every tranche (largest "
                f"{max_tranche / 1e12:.2f} TB)")
        else:
            cands = self._candidates_for(job)
            plan = self._best(cands)
            if plan is None:
                job.state = REJECTED
                job.why_rejected = ("no feasible (dp,tp) at "
                                    f"{job.n_chips} chips: "
                                    + "; ".join(c.why for c in cands[:3]))
            else:
                job.plan = plan
        if job.state == REJECTED:
            self.rejected.append(job)
            self.telemetry.jobs_rejected += 1
            self.telemetry.log(now, "reject", job.name, job.why_rejected)
            return False
        self.queue.append(job)
        self.telemetry.log(now, "submit", job.name,
                           f"{job.arch}/{job.shape_name} x{job.n_chips}")
        return True

    def _gang_impossible(self, job: Job) -> str:
        """Why a gang can never place on this pool ("" = it can): the
        static analogue of ``_fits_now``'s per-domain rule."""
        per_pod = job.n_chips // job.n_pods
        hosts = len(hosting_domains(self.pool.devices, per_pod))
        if hosts < job.n_pods:
            n_domains = len(domain_counts(self.pool.devices))
            return (f"gang needs {job.n_pods} domains of {per_pod} chips; "
                    f"only {hosts} of {n_domains} domains are large "
                    "enough")
        return ""

    # ------------------------------------------------------------- start --
    def _storage_request(self, job: Job) -> float:
        return job.io.dataset_bytes() if job.io is not None else 0.0

    def _start(self, job: Job, now: float) -> bool:
        dp, tp = job.dp_tp
        gang: Optional[GangPlan] = None
        try:
            if job.n_pods > 1:
                # all-or-nothing gang: co-select one pod-sized clique per
                # member domain, minimizing the DCN hop span; the whole
                # selection (every member + the tranche) is claimed in
                # one atomic compose() below
                gang = plan_gang(self.pool, job.n_pods, dp, tp)
                uids, paths = gang.uids, gang.axis_paths
                names: Tuple[str, ...] = ("pod", "data", "model")
                sizes: Tuple[int, ...] = (job.n_pods, dp, tp)
            else:
                plan = plan_placement(self.pool, dp, tp)
                uids, paths = plan.uids, plan.axis_paths
                names, sizes = ("data", "model"), (dp, tp)
            axis_links, axis_hops, axis_scale = path_maps(paths)
            # a composition is devices + storage: running requires an NVMe
            # tranche lease alongside the chip claim, placed local-first
            # (plan_tranche) and claimed atomically inside compose()
            domain = {d.uid: d.domain for d in self.pool.devices}[uids[0]]
            tranche = plan_tranche(
                self.storage, capacity_bytes=self._storage_request(job),
                prefer_domain=domain)
            job.system = compose(
                self.pool, job.name, names, sizes,
                axis_links, uids=uids,
                storage_pool=self.storage, tranche=tranche.name,
                storage_capacity=self._storage_request(job),
                axis_hops=axis_hops, axis_bw_scale=axis_scale)
        except CompositionError as e:
            # capacity was checked before calling; reaching here means a
            # genuine claim conflict — count it and leave the job queued
            self.manager.conflicts += 1
            self.telemetry.lease_conflicts += 1
            self.telemetry.log(now, "conflict", job.name, str(e))
            return False
        self.manager.adopt(job.system, now)
        job.plan = self._repriced(job.plan, job.system)
        job.state = RUNNING
        job.start_t = now
        job.progress_t = now
        job.gang_domains = gang.domains if gang is not None else ()
        job.run = elastic.ElasticRun(job.system, ckpt_dir="")
        self.running.append(job)
        st = self.telemetry.tranche_stats(tranche.name, tranche.attach.value)
        st.leases_granted += 1
        self.update_stalls()
        # wait = time spent in the queue since the last (re)queueing; run
        # time before a preemption is not wait
        self.telemetry.job_waited(now - job.queued_t, job.tenant_key)
        if job.fault_t >= 0.0:
            # recovery-time sample: fault injection -> back on devices,
            # including the checkpoint restore the restart is about to
            # pay (detect + decide + restore)
            self.telemetry.recovery_s.append(
                (now - job.fault_t) + self.restore_s(job))
            job.fault_t = -1.0
        detail = (f"mesh={'x'.join(str(s) for s in sizes)} links=" +
                  ",".join(f"{a}:{c.value}"
                           for a, c in job.system.fabric.axis_links.items()))
        detail += (f" tranche={tranche.name}"
                   f"({self.storage.n_lessees(tranche.name)} lessees)")
        if isinstance(job, ServeJob):
            detail += f" serve={job.tokens_per_s:.0f}tok/s"
        self.telemetry.log(now, "start", job.name, detail)
        if gang is not None:
            self.telemetry.gang_started(gang.dcn_hops)
            self.telemetry.log(
                now, "gang", job.name,
                f"start pods={job.n_pods} domains="
                + ",".join(str(d) for d in gang.domains)
                + f" span={gang.dcn_hops}")
        return True

    # ----------------------------------------------------- storage stalls --
    def stall_for(self, job: Job) -> float:
        """Contended per-step input stall of ``job`` on its tranche."""
        if (job.io is None or job.system is None
                or job.system.tranche is None):
            return 0.0
        model = StorageModel.for_tranche(self.storage, job.system.tranche)
        return workload_stall(job.io, model, job.plan.step_s)

    def update_stalls(self) -> List[Job]:
        """Re-derive every running job's input stall under the current
        tranche contention; jobs whose stall changed are queued on
        ``stall_dirty`` (drained by the simulator to re-schedule their
        completion events) and returned."""
        changed: List[Job] = []
        for job in self.running:
            stall = self.stall_for(job)
            if abs(stall - job.input_stall_s) > 1e-12:
                self.stall_dirty.setdefault(job.name,
                                            (job, job.input_stall_s))
                job.input_stall_s = stall
                changed.append(job)
        return changed

    def drain_stall_dirty(self) -> List[Tuple[Job, float]]:
        out = list(self.stall_dirty.values())
        self.stall_dirty.clear()
        return out

    def restore_s(self, job: Job) -> float:
        """Checkpoint-restore time on the job's *actual* storage.

        A resumed job reads its fp32 parameters back through the tranche
        it holds — at the tranche's **contended** per-lessee bandwidth
        (``StoragePool.read_bw``), not the uncontended tier rate
        ``Job.est_restore_s`` assumes: a restore on a shared drawer
        contends with its co-tenants' input streams exactly like the
        steady-state reads do.  Falls back to the job's own uncontended
        estimate while it holds no tranche (still queued).
        """
        if job.steps_done <= 0:
            return 0.0
        if job.system is not None and job.system.tranche is not None:
            pbytes = get_config(job.arch).param_count() * 4.0
            return pbytes / self.storage.read_bw(job.system.tranche)
        return job.est_restore_s()

    def est_restore_for(self, job: Job) -> float:
        """Policy-aware restore estimate for a job being *considered*
        (the backfill guard's view).

        A queued preempted job holds no tranche, but the tranche a
        restart would lease is knowable — ``plan_tranche`` is the same
        deterministic selection ``_start`` will make — and the restore
        read contends with that tranche's existing lessees *plus the
        restarting job itself*.  ``Job.est_restore_s``'s uncontended
        tier rate under-prices exactly when the pool's tranches are
        shared, letting backfill start restores that overrun the head
        job's reservation.  Falls back to the job's own estimate when
        no tranche currently fits (admission will conflict anyway).
        """
        if job.steps_done <= 0:
            return 0.0
        if job.system is not None and job.system.tranche is not None:
            return self.restore_s(job)
        try:
            tranche = plan_tranche(
                self.storage, capacity_bytes=self._storage_request(job))
        except CompositionError:
            return job.est_restore_s()
        pbytes = get_config(job.arch).param_count() * 4.0
        bw = tranche.effective_read_bw(
            self.storage.links, self.storage.n_lessees(tranche.name) + 1)
        return pbytes / bw

    # ---------------------------------------------------------- fairness --
    def _accrue_usage(self, now: float) -> None:
        """Integrate running device-seconds per tenant up to ``now`` —
        the fair-share deficit input.  Lazy and idempotent (dt = 0 on
        repeated calls at one event time)."""
        dt = now - self._usage_t
        if dt > 0:
            for job in self.running:
                if job.system is not None:
                    key = job.tenant_key
                    self.tenant_usage[key] = (
                        self.tenant_usage.get(key, 0.0)
                        + dt * job.system.n_devices)
        self._usage_t = max(self._usage_t, now)

    # ---------------------------------------------------------- schedule --
    @staticmethod
    def _fits_now(job: Job, free: List[Device]) -> bool:
        """Can ``job`` be placed from the ``free`` devices right now?
        Plain jobs fit by count; a gang additionally needs ``n_pods``
        distinct domains with a full member clique free in each (mirrors
        ``plan_gang``'s eligibility rule, without planning)."""
        if job.n_pods <= 1:
            return job.n_chips <= len(free)
        per_pod = job.n_chips // job.n_pods
        return len(hosting_domains(free, per_pod)) >= job.n_pods

    def _reservation_t(self, need: int, now: float) -> float:
        """Earliest time ``need`` devices can be free, from running jobs'
        analytic end-time estimates (EASY reservation for the head job)."""
        free = len(self.pool.available())
        if free >= need:
            return now
        for job in sorted(self.running, key=lambda j: j.est_end_t):
            free += job.system.n_devices if job.system else 0
            if free >= need:
                return max(now, job.est_end_t)
        return float("inf")

    def poll(self, now: float) -> List[Job]:
        """Start every job the policy admits right now; returns them."""
        started: List[Job] = []
        self._accrue_usage(now)
        while True:
            order = self.policy.order(self, now)
            # backoff gate (cluster.faults): a retrying job is invisible to
            # this poll until its not_before_t — it neither starts nor
            # holds the head reservation.  0.0 (the default) always passes,
            # so legacy traces order identically.
            order = [j for j in order if j.not_before_t <= now]
            if not order:
                break
            head = order[0]
            free = self.pool.available()
            picked: Optional[Job] = None
            if self._fits_now(head, free):
                picked = head
            else:
                if self.policy.make_room(self, head, now):
                    continue    # devices were freed: re-evaluate the queue
                if self.backfill:
                    reserve_t = self._reservation_t(head.n_chips, now)
                    for job in order[1:]:
                        # restore priced policy-aware (est_restore_for):
                        # a backfilled restart reads its checkpoint at the
                        # contended bandwidth of the tranche it will
                        # actually lease, not the uncontended tier rate
                        if (self._fits_now(job, free)
                                and now + self.est_restore_for(job)
                                + job.est_duration_s() <= reserve_t):
                            picked = job
                            break
            if picked is None or not self._start(picked, now):
                break
            self.queue.remove(picked)
            started.append(picked)
        return started

    def drain_policy_victims(self) -> List[Job]:
        """Jobs the policy shrank or evicted since the last drain (the
        simulator re-prices their traffic rates and completion events)."""
        out = list(self.policy_victims)
        self.policy_victims.clear()
        return out

    # ---------------------------------------------------------- complete --
    def on_complete(self, job: Job, now: float) -> None:
        assert job.state == RUNNING
        self._accrue_usage(now)
        job.steps_done = job.steps
        job.state = DONE
        job.end_t = now
        if job.n_pods > 1:
            self.telemetry.log(now, "gang", job.name, "stop")
        self.running.remove(job)
        self.done.append(job)
        release(self.pool, job.system)
        self.manager.release(job.name)       # devices + storage tranche
        self.stall_dirty.pop(job.name, None)
        self.update_stalls()                 # co-tenants speed back up
        self.telemetry.jobs_completed += 1
        self.telemetry.log(now, "complete", job.name,
                           f"ran {now - job.start_t:.1f}s")

    def complete_queued(self, job: Job, now: float, why: str = "") -> None:
        """Complete a job straight from the queue (it holds no devices) —
        e.g. a preempted serve replica whose service drained before it
        could restart.  Keeps the bookkeeping identical to on_complete."""
        assert job.state == QUEUED
        self.queue.remove(job)
        job.steps_done = job.steps
        job.state = DONE
        job.end_t = now
        self.done.append(job)
        self.telemetry.jobs_completed += 1
        self.telemetry.log(now, "complete", job.name,
                           why or "completed from queue")

    # ----------------------------------------------------------- failure --
    def on_failure(self, failed_uids: Sequence[int], now: float
                   ) -> List[Job]:
        """Handle device failures; returns every job that was recomposed
        or preempted (the caller must re-estimate completion times)."""
        self._accrue_usage(now)
        self.pool.mark_failed(failed_uids)
        self.telemetry.log(now, "fail", "",
                           f"{len(failed_uids)} device(s) down")
        failed = set(failed_uids)
        changed: List[Job] = []
        for job in list(self.running):
            hit = failed & set(job.system.device_uids)
            if not hit:
                continue
            if job.n_pods > 1:
                # a gang is all-or-nothing at runtime too: losing any
                # member device preempts the whole gang (a cross-pod
                # shrink would break the pod-symmetric mesh)
                self._preempt(job, now)
                changed.append(job)
                continue
            old_shape = job.system.axis_sizes
            try:
                new_sys = elastic.handle_failure(
                    job.run, self.pool, sorted(hit),
                    step=int(job.steps_done), shrink_axis="data")
            except CompositionError:
                self._preempt(job, now)
                changed.append(job)
                continue
            if new_sys.axis_sizes != old_shape:
                dp, tp = new_sys.axis_sizes[-2], new_sys.axis_sizes[-1]
                cfg = get_config(job.arch)
                new_plan = recommend.calibrate_candidate(
                    recommend._estimate(cfg, SHAPES[job.shape_name], dp, tp),
                    cfg, job.arch, job.shape_name,
                    SHAPES[job.shape_name], self.calibration)
                if not new_plan.feasible:
                    # fits the pool by count but not by memory (e.g. the
                    # halved mesh can't hold the optimizer shards): the
                    # job cannot run in this shape — give everything back
                    job.run.system = new_sys
                    self._preempt(job, now)
                    changed.append(job)
                    continue
                job.plan = new_plan
            # the spare devices may sit on a different fabric than the
            # original claim: re-derive the per-axis paths so pricing
            # and traffic attribution follow the actual hardware
            new_sys = self._with_axis_paths(new_sys, new_sys.axis_sizes[-1])
            job.system = new_sys
            job.run.system = new_sys
            job.plan = self._repriced(job.plan, new_sys)
            self.manager.forget(job.name)
            self.manager.adopt(new_sys, now)
            job.recompositions += 1
            job.epoch += 1               # invalidates scheduled completions
            changed.append(job)
            self.telemetry.log(
                now, "recompose", job.name,
                f"{old_shape}->{new_sys.axis_sizes} after {len(hit)} loss")
        self.update_stalls()         # shrunk meshes re-derive their stalls
        return changed

    def _preempt(self, job: Job, now: float,
                 why: str = "pool too small; requeued") -> None:
        """Release everything and requeue the job (failure shrink
        impossible, or a policy eviction — ``why`` says which)."""
        self._accrue_usage(now)
        if job.n_pods > 1:
            self.telemetry.log(now, "gang", job.name, "stop (preempted)")
        elastic.preempt(job.run, self.pool, step=int(job.steps_done))
        self.manager.release(job.name)       # devices + storage tranche
        self.running.remove(job)
        job.system = None
        job.run = None
        job.state = QUEUED
        job.epoch += 1
        job.gang_domains = ()
        job.input_stall_s = 0.0
        self.stall_dirty.pop(job.name, None)
        self.update_stalls()
        # resume from last "checkpointed" step boundary, re-planned at the
        # original budget (a stale shrunken plan would desync poll()'s
        # n_chips gate from the mesh _start() actually composes)
        job.steps_done = float(int(job.steps_done))
        job.plan = self.plan_job(job) or job.plan
        job.queued_t = now
        self.queue.append(job)
        self.telemetry.jobs_preempted += 1
        self.telemetry.log(now, "preempt", job.name, why)

    # ------------------------------------------------- fault recovery -----
    def apply_retry_budget(self, job: Job, now: float, *,
                           base_backoff_s: float = 5.0) -> bool:
        """Charge one fault-driven restart against ``job``'s retry budget.

        Called by the fault plane after a fault preempted ``job`` back to
        the queue.  Within budget the job gets an exponential-backoff
        gate (``not_before_t = now + base * 2^(retries-1)``) and a
        ``retry`` event; past ``max_retries`` it fails permanently.
        Returns True iff the job is still retryable.
        """
        if job.state != QUEUED:
            return True
        job.retries += 1
        if job.retries > job.max_retries:
            self.fail_permanently(
                job, now, f"retry budget exhausted "
                f"({job.max_retries} fault restarts)")
            return False
        backoff = base_backoff_s * (2.0 ** (job.retries - 1))
        job.not_before_t = now + backoff
        self.telemetry.retries_scheduled += 1
        self.telemetry.log(now, "retry", job.name,
                           f"attempt {job.retries}/{job.max_retries} "
                           f"backoff {backoff:.1f}s")
        return True

    def fail_permanently(self, job: Job, now: float, why: str) -> None:
        """Terminal fault failure: the job leaves the queue for good."""
        assert job.state == QUEUED
        self.queue.remove(job)
        job.state = FAILED
        job.end_t = now
        job.why_rejected = why
        job.fault_t = -1.0
        self.failed.append(job)
        self.telemetry.jobs_failed += 1
        self.telemetry.log(now, "fail", job.name, why)

    def regrow_shrunk(self, now: float) -> List[Job]:
        """Grow failure-shrunk jobs back toward their submitted budget.

        Called by the fault plane after a repair returns capacity (the
        ``train.elastic`` regrow path); legacy traces never call this,
        so repaired devices keep their PR-1 sit-idle-until-leased
        behavior bit-for-bit.  Returns the regrown jobs (the simulator
        re-prices their rates and completion events).
        """
        regrown: List[Job] = []
        for job in list(self.running):
            if job.n_pods > 1 or job.system is None:
                continue
            if job.system.n_devices >= job.n_chips:
                continue
            if (len(self.pool.available())
                    < job.n_chips - job.system.n_devices):
                continue
            plan = self.plan_job(job)        # at the original budget
            if plan is None:
                continue
            dp, tp = plan.shape[-2], plan.shape[-1]
            if self.sync_progress is not None:
                self.sync_progress(job, now)
            self._accrue_usage(now)
            old_shape = job.system.axis_sizes
            try:
                new_sys = recompose(self.pool, job.system,
                                    axis_sizes=(dp, tp))
            except CompositionError:
                continue             # recompose restored the old claim
            new_sys = self._with_axis_paths(new_sys, tp)
            job.system = new_sys
            if job.run is not None:
                elastic.regrow(job.run, new_sys, step=int(job.steps_done))
            job.plan = self._repriced(plan, new_sys)
            self.manager.forget(job.name)
            self.manager.adopt(new_sys, now)
            job.steps_done = float(int(job.steps_done))
            job.recompositions += 1
            job.epoch += 1           # invalidates scheduled completions
            self.telemetry.log(now, "recompose", job.name,
                               f"{old_shape}->{new_sys.axis_sizes} "
                               "(regrow after repair)")
            self.policy_victims.append(job)
            regrown.append(job)
        if regrown:
            self.update_stalls()
        return regrown

    # --------------------------------------------- live recomposition -----
    def _recompose_placed(self, system: ComposedSystem, dp: int, tp: int
                          ) -> ComposedSystem:
        """``core.compose.recompose`` with hop-aware selection: the old
        claim is released into the candidate set and the new mesh is
        chosen by ``plan_placement``'s clique-major, hop-sorted rule —
        so a live attach never picks a far drawer over an idle
        same-domain chip the way the default domain-major re-lease can.
        Atomic like ``acquire_gang``: any failure restores the old
        claim exactly and re-raises ``CompositionError``."""
        old = [u for u in system.device_uids
               if self.pool.leases.get(u) == system.name]
        self.pool.release(old)
        try:
            plan = plan_placement(self.pool, dp, tp)
            links, hops, scale = path_maps(plan.axis_paths)
            return compose(self.pool, system.name, system.axis_names,
                           (dp, tp), links, system.fabric.storage,
                           uids=plan.uids, tranche=system.tranche,
                           axis_hops=hops, axis_bw_scale=scale)
        except CompositionError:
            present = {d.uid for d in self.pool.devices}
            self.pool.lease([u for u in old if u in present], system.name)
            raise

    def attach_job(self, job: Job, now: float) -> bool:
        """Live-attach idle devices to one running elastic job below its
        submitted width — ``regrow_shrunk`` generalized beyond fault
        repair (the Recomposer's widen action), with the replacement
        mesh selected hop-aware (``_recompose_placed``).  Returns True
        iff the job was widened; the caller drains ``policy_victims``
        to re-price its traffic rates and completion event."""
        if job.n_pods > 1 or job.system is None:
            return False
        if job.system.n_devices >= job.n_chips:
            return False
        if (len(self.pool.available())
                < job.n_chips - job.system.n_devices):
            return False
        plan = self.plan_job(job)            # at the original budget
        if plan is None:
            return False
        dp, tp = plan.shape[-2], plan.shape[-1]
        if self.sync_progress is not None:
            self.sync_progress(job, now)
        self._accrue_usage(now)
        old_shape = job.system.axis_sizes
        old_n = job.system.n_devices
        try:
            new_sys = self._recompose_placed(job.system, dp, tp)
        except CompositionError:
            return False             # old claim restored; nothing changed
        new_sys = self._with_axis_paths(new_sys, tp)
        job.system = new_sys
        if job.run is not None:
            elastic.regrow(job.run, new_sys, step=int(job.steps_done))
        job.plan = self._repriced(plan, new_sys)
        self.manager.forget(job.name)
        self.manager.adopt(new_sys, now)
        job.steps_done = float(int(job.steps_done))
        job.recompositions += 1
        job.epoch += 1               # invalidates scheduled completions
        self.telemetry.attaches += 1
        self.telemetry.devices_recomposed += new_sys.n_devices - old_n
        self.telemetry.log(now, "attach", job.name,
                           f"{old_shape}->{new_sys.axis_sizes} "
                           f"(+{new_sys.n_devices - old_n} devices)")
        self.policy_victims.append(job)
        self.update_stalls()
        return True

    def detach_job(self, job: Job, now: float) -> int:
        """Live-detach half a running elastic job's data axis so queued
        work can admit sooner (the Recomposer's shrink-to-admit action).
        Same mechanics as ``preempt_to_shrink`` but attributed to the
        recomposition plane; returns the devices freed (0 when the job
        cannot shrink)."""
        if job.n_pods > 1 or job.system is None:
            return 0
        dp, tp = job.dp_tp
        if dp < 2:
            return 0
        cfg = get_config(job.arch)
        new_plan = recommend.calibrate_candidate(
            recommend._estimate(cfg, SHAPES[job.shape_name], dp // 2, tp),
            cfg, job.arch, job.shape_name, SHAPES[job.shape_name],
            self.calibration)
        if not new_plan.feasible:
            return 0
        if self.sync_progress is not None:
            self.sync_progress(job, now)
        self._accrue_usage(now)
        old_n = job.system.n_devices
        old_shape = job.system.axis_sizes
        try:
            new_sys = recompose(self.pool, job.system,
                                axis_sizes=(dp // 2, tp))
        except CompositionError:
            return 0                 # recompose restored the old claim
        new_sys = self._with_axis_paths(new_sys, tp)
        job.system = new_sys
        if job.run is not None:
            job.run.system = new_sys
        job.plan = self._repriced(new_plan, new_sys)
        self.manager.forget(job.name)
        self.manager.adopt(new_sys, now)
        job.steps_done = float(int(job.steps_done))
        job.recompositions += 1
        job.epoch += 1               # invalidates scheduled completions
        freed = old_n - new_sys.n_devices
        self.telemetry.detaches += 1
        self.telemetry.devices_recomposed += freed
        self.telemetry.log(now, "detach", job.name,
                           f"{old_shape}->{new_sys.axis_sizes} "
                           f"(shrink-to-admit, -{freed} devices)")
        self.policy_victims.append(job)
        self.update_stalls()
        return freed

    def migrate_tranche(self, job: Job, now: float, target: str) -> bool:
        """Move a running job's storage lease to ``target`` (the
        Recomposer's tranche-migrate action).  Attach-then-detach so a
        conflict on the target leaves the old lease untouched (atomic);
        the composable switch re-attaches the same drawer over a
        different path, so no data copy is modeled — the cost (and the
        gain) shows up as the re-derived contended stalls on both
        tranches (``update_stalls`` -> ``stall_dirty``)."""
        if job.system is None or job.system.tranche is None:
            return False
        old = job.system.tranche
        if target == old:
            return False
        try:
            self.storage.lease(target, job.name,
                               capacity_bytes=self._storage_request(job))
        except CompositionError:
            return False             # target full/exclusive: no change
        self.storage.release_tranche(job.name, old)
        tr = self.storage.tranches[target]
        self._accrue_usage(now)
        job.system = dataclasses.replace(
            job.system, tranche=target,
            fabric=dataclasses.replace(job.system.fabric,
                                       storage=tr.spec()))
        if job.run is not None:
            job.run.system = job.system
        st = self.telemetry.tranche_stats(target, tr.attach.value)
        st.leases_granted += 1
        self.telemetry.migrations += 1
        self.telemetry.log(now, "migrate", job.name,
                           f"{old}->{target} "
                           f"({self.storage.n_lessees(target)} lessees)")
        self.update_stalls()
        return True

    # ------------------------------------------------- policy preemption --
    def evict(self, job: Job, now: float, for_job: str = "") -> int:
        """Policy-driven full preemption of a running job (the
        ``priority_preempt`` eviction path).  The victim checkpoints at
        the last integer step, releases devices + tranche, and requeues;
        returns the number of devices freed."""
        if self.sync_progress is not None:
            self.sync_progress(job, now)
        freed = job.system.n_devices if job.system is not None else 0
        why = f"preempted for {for_job or 'higher priority'}"
        self._preempt(job, now, why=why)
        job.evictions += 1
        self.telemetry.jobs_evicted += 1
        self.telemetry.log(now, "evict", job.name, why)
        self.policy_victims.append(job)
        return freed

    def preempt_to_shrink(self, job: Job, now: float) -> int:
        """Halve a running victim's data axis in place, freeing half its
        devices for a higher-priority job; returns the devices freed (0
        when the victim cannot shrink: gangs, dp == 1, infeasible halved
        mesh, or a recompose conflict)."""
        if job.n_pods > 1 or job.system is None:
            return 0
        dp, tp = job.dp_tp
        if dp < 2:
            return 0
        cfg = get_config(job.arch)
        new_plan = recommend.calibrate_candidate(
            recommend._estimate(cfg, SHAPES[job.shape_name], dp // 2, tp),
            cfg, job.arch, job.shape_name, SHAPES[job.shape_name],
            self.calibration)
        if not new_plan.feasible:
            return 0
        if self.sync_progress is not None:
            self.sync_progress(job, now)
        self._accrue_usage(now)
        old_n = job.system.n_devices
        old_shape = job.system.axis_sizes
        try:
            new_sys = recompose(self.pool, job.system,
                                axis_sizes=(dp // 2, tp))
        except CompositionError:
            return 0                 # recompose restored the old claim
        new_sys = self._with_axis_paths(new_sys, tp)
        job.system = new_sys
        if job.run is not None:
            job.run.system = new_sys
        job.plan = self._repriced(new_plan, new_sys)
        self.manager.forget(job.name)
        self.manager.adopt(new_sys, now)
        # resume from the checkpoint boundary in the halved shape
        job.steps_done = float(int(job.steps_done))
        job.recompositions += 1
        job.epoch += 1               # invalidates scheduled completions
        self.telemetry.jobs_shrunk += 1
        self.telemetry.log(now, "shrink", job.name,
                           f"{old_shape}->{new_sys.axis_sizes} "
                           "(policy preempt-to-shrink)")
        self.policy_victims.append(job)
        self.update_stalls()
        return old_n - new_sys.n_devices

    # ----------------------------------------------------------- queries --
    def busy_equiv(self) -> float:
        """Device-equivalents doing useful compute right now (for AUU)."""
        total = 0.0
        for job in self.running:
            t = job.plan.terms
            # cap at 1: a measured step faster than the analytic compute
            # bound means the chips are saturated, not >100% busy
            frac = min(1.0, t.get("compute", 0.0) / max(job.step_s, 1e-30))
            total += job.system.n_devices * frac
        return total

    def all_done(self) -> bool:
        return not self.queue and not self.running
