"""Deterministic fault-injection plane for the cluster simulator.

The paper's composable-infrastructure pitch is that resources attach and
detach dynamically — which means the fabric can also do it *to* you: a
PCIe switch drops a drawer, a link flaps, an NVMe tranche browns out.
Takano & Suzaki's disaggregated-accelerator letter makes failure handling
of pooled accelerators a first-class concern; this module gives the
simulator the correlated fault modes and the recovery machinery the
legacy ``TraceConfig.failures`` knob (whole-device, scripted, instant
detection) cannot express.

Fault kinds (``FaultSpec.kind``):

  * ``device_down``      — ``n`` random healthy chips fail at ``t``
                           (repaired at ``t_clear``; inf = never);
  * ``device_flaky``     — the same chips flap down/up ``flaps`` times,
                           one cycle every ``period_s``;
  * ``link_degrade``     — one link class keeps ``frac`` of its
                           bandwidth: running jobs are *repriced* through
                           the incremental rate accumulators and keep
                           running at the degraded step time (graceful
                           degradation, no eviction);
  * ``domain_outage``    — every chip behind one locality domain (the
                           composable-infra failure unit: a drawer / one
                           side of the switch) goes down at once;
  * ``pod_loss``         — alias of ``domain_outage`` aimed at gangs: the
                           scheduler preempts any gang with a member in
                           the domain whole (all-or-nothing at runtime);
  * ``tranche_brownout`` — an NVMe tranche keeps ``frac`` of its
                           bandwidth; tenants keep running with their
                           stalls re-derived (``update_stalls``);
  * ``tranche_fail``     — the tranche is lost: holders are preempted to
                           restart on other storage, and ``plan_tranche``
                           stops offering it until ``t_clear``.

Detection-latency model: a fault happens at ``t`` but the control plane
reacts at ``t + detect_s``.  In the window the victims are *hung* — they
make no progress (their ``progress_t`` is pushed past the window so the
lazy accrual adds nothing) and move no bytes — so recovery time is
``detect + decide + restore``, sampled into ``telemetry.recovery_s``
when the victim is back on devices.

Recovery side:

  * **retry budgets** — every fault-driven preemption charges the
    victim's ``Job.retries`` with exponential backoff
    (``Scheduler.apply_retry_budget``); past ``max_retries`` the job
    fails permanently (terminal state FAILED — a new outcome in
    scheduler/telemetry).  Legacy ``TraceConfig.failures`` preemptions
    never consume the budget.
  * **graceful degradation** — ``link_degrade`` / ``tranche_brownout``
    re-price instead of evict.
  * **regrow** — after a repair returns capacity, failure-shrunk jobs
    recompose back toward their submitted budget
    (``Scheduler.regrow_shrunk`` -> ``train.elastic.regrow``).
  * **graceful drain** — a fault with ``notice_s > 0`` announces itself:
    serve replicas on the doomed devices stop admitting new requests and
    finish their in-flight work before the hit.

Schedules are scripted (``FaultPlan.faults``) or MTBF-seeded from the
trace rng (``FaultPlan.mtbf_s``).  All fault draws are consumed AFTER
every existing trace draw (batch arrivals, legacy failures, services),
so legacy traces — and any ``TraceConfig`` with ``faults=None`` — stay
bit-identical.

Invariants:

  * ``FaultPlan()`` (empty) is behaviorally identical to ``faults=None``:
    no events, no rng draws, bit-identical ``report()``.
  * Faults never touch the rng unless they fire (victim sampling happens
    at event time, after the trace is fully generated).
  * A cleared fault restores exactly what it took: link bandwidths and
    tranche specs return to their pre-fault values.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.cluster.scheduler import QUEUED, RUNNING, ServeJob
from repro.core.topology import LinkClass, LinkSpec

FAULT_KINDS = ("device_down", "device_flaky", "link_degrade",
               "domain_outage", "pod_loss", "tranche_brownout",
               "tranche_fail")

# kinds that take chips down (the scheduler's on_failure path)
_DEVICE_KINDS = ("device_down", "device_flaky", "domain_outage", "pod_loss")

_INF = float("inf")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scripted fault.  Unused fields are ignored per kind."""
    kind: str
    t: float                            # injection time (simulated s)
    n: int = 1                          # chips (device_down / device_flaky)
    domain: int = 0                     # target (domain_outage / pod_loss)
    link: str = "switch"                # LinkClass value (link_degrade)
    frac: float = 0.5                   # surviving bandwidth fraction
    tranche: str = ""                   # target (tranche_* kinds)
    t_clear: float = _INF               # when the fault clears (inf = never)
    flaps: int = 3                      # device_flaky down/up cycles
    period_s: float = 60.0              # device_flaky cycle period
    detect_s: float = 1.0               # detection latency
    notice_s: float = 0.0               # planned-detach drain notice

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; known: {FAULT_KINDS}")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """The trace's fault schedule + recovery knobs.

    ``faults`` is the scripted part; ``mtbf_s > 0`` additionally draws a
    Poisson schedule of ``device_down`` faults (mean time between
    failures ``mtbf_s``, repaired after ``mttr_s``) over ``horizon_s``
    from the trace rng — consumed after all existing draws.
    """
    faults: Tuple[FaultSpec, ...] = ()
    mtbf_s: float = 0.0
    mttr_s: float = 120.0
    horizon_s: float = 0.0
    mtbf_n: int = 1                     # chips per MTBF-drawn fault
    detect_s: float = 1.0               # detection latency for MTBF faults
    # recovery knobs
    retry_backoff_s: float = 5.0        # base of the exponential backoff
    max_retries: Optional[int] = None   # override Job.max_retries when set
    regrow: bool = True                 # regrow shrunk jobs after repair

    def schedule(self, rng) -> Tuple[FaultSpec, ...]:
        """Scripted faults + the MTBF draw (in injection order)."""
        out = list(self.faults)
        if self.mtbf_s > 0 and self.horizon_s > 0:
            t = 0.0
            while True:
                t += rng.expovariate(1.0 / self.mtbf_s)
                if t >= self.horizon_s:
                    break
                out.append(FaultSpec(
                    "device_down", t, n=self.mtbf_n,
                    t_clear=t + self.mttr_s, detect_s=self.detect_s))
        return tuple(sorted(out, key=lambda f: (f.t, f.kind, f.domain)))


class FaultInjector:
    """Applies a ``FaultPlan`` to a running ``ClusterSimulator``.

    The simulator owns the event loop; this object owns the fault
    semantics.  Event payloads are ``(spec, uids, flaps_left)`` tuples —
    ``uids`` is None until victims are sampled at injection time, so the
    rng is only consumed by faults that actually fire.
    """

    def __init__(self, sim, plan: FaultPlan):
        self.sim = sim
        self.plan = plan
        self._orig_links: Dict[LinkClass, LinkSpec] = {}
        self._orig_tranches: Dict[str, object] = {}

    # ----------------------------------------------------------- schedule --
    def push_schedule(self) -> None:
        """Queue every fault (and drain notice) onto the event heap.
        Called from ``_gen_trace`` after all legacy draws."""
        for spec in self.plan.schedule(self.sim.rng):
            if spec.notice_s > 0:
                self.sim._push(max(0.0, spec.t - spec.notice_s),
                               "drain", spec)
            self.sim._push(spec.t, "fault", (spec, None, spec.flaps))

    # ------------------------------------------------------------- inject --
    def on_fault(self, payload, now: float) -> None:
        spec, uids, flaps_left = payload
        tel = self.sim.telemetry
        tel.faults_injected += 1
        if spec.kind in _DEVICE_KINDS:
            uids = list(uids) if uids is not None \
                else self._device_victims(spec)
            tel.log(now, "fault", "",
                    f"{spec.kind}: {len(uids)} device(s) "
                    f"(detect in {spec.detect_s:.1f}s)")
            if uids:
                self._hang_devices(spec, uids, now)
                self.sim._push(now + spec.detect_s, "detect",
                               (spec, tuple(uids)))
                t_clear = self._clear_time(spec, now)
                if t_clear < _INF:
                    self.sim._push(t_clear, "fault_clear",
                                   (spec, tuple(uids), flaps_left))
        elif spec.kind == "link_degrade":
            cls = LinkClass(spec.link)
            self._scale_link(cls, spec.frac)
            tel.log(now, "fault", "",
                    f"link_degrade: {cls.value} at {spec.frac:.0%} bandwidth")
            self._reprice_running(now)
            self.sim._push(now + spec.detect_s, "detect", (spec, None))
            if spec.t_clear < _INF:
                self.sim._push(spec.t_clear, "fault_clear",
                               (spec, None, flaps_left))
        elif spec.kind == "tranche_brownout":
            name = self._tranche_name(spec)
            if name is not None:
                self._scale_tranche(name, spec.frac)
                tel.log(now, "fault", "",
                        f"tranche_brownout: {name} at {spec.frac:.0%} "
                        "bandwidth")
                self._reprice_stalls(now)
                self.sim._push(now + spec.detect_s, "detect", (spec, None))
                if spec.t_clear < _INF:
                    self.sim._push(spec.t_clear, "fault_clear",
                                   (spec, None, flaps_left))
        elif spec.kind == "tranche_fail":
            name = self._tranche_name(spec)
            if name is not None:
                tel.log(now, "fault", "",
                        f"tranche_fail: {name} lost "
                        f"(detect in {spec.detect_s:.1f}s)")
                self._hang_tranche(name, now, spec)
                self.sim._push(now + spec.detect_s, "detect",
                               (spec, (name,)))
                if spec.t_clear < _INF:
                    self.sim._push(spec.t_clear, "fault_clear",
                                   (spec, (name,), flaps_left))

    # ------------------------------------------------------------- detect --
    def on_detect(self, payload, now: float) -> None:
        spec, target = payload
        sim, tel = self.sim, self.sim.telemetry
        tel.faults_detect_s.append(spec.detect_s)
        if spec.kind in _DEVICE_KINDS:
            tel.log(now, "detect", "",
                    f"{spec.kind}: {len(target)} device(s) confirmed down")
            changed = sim.scheduler.on_failure(list(target), now)
            self._recover(changed, spec, now)
        elif spec.kind == "tranche_fail":
            name = target[0]
            tel.log(now, "detect", "", f"tranche_fail: {name} confirmed")
            changed = self._evacuate_tranche(name, now)
            self._recover(changed, spec, now)
        else:
            # degradations need no scheduler action — the detect event
            # just closes the timeline (monitoring noticed the slowdown)
            tel.log(now, "detect", "", f"{spec.kind} observed")

    def _recover(self, changed, spec: FaultSpec, now: float) -> None:
        """Post-detection recovery: re-price survivors, charge retry
        budgets of the preempted, schedule their backoff wakeups."""
        sim = self.sim
        for job in changed:
            sim._reschedule_victim(job, now)
            if job.state == RUNNING:
                # shrunk in place: it is already recovered — sample the
                # fault->recompose time (the restore overhead was just
                # added by _reschedule_victim's completion pricing)
                if job.fault_t >= 0.0:
                    sim.telemetry.recovery_s.append(
                        (now - job.fault_t)
                        + sim.scheduler.restore_s(job))
                    job.fault_t = -1.0
            elif job.state == QUEUED:
                if job.fault_t < 0.0:
                    job.fault_t = spec.t
                if self.plan.max_retries is not None:
                    job.max_retries = self.plan.max_retries
                if sim.scheduler.apply_retry_budget(
                        job, now,
                        base_backoff_s=self.plan.retry_backoff_s):
                    # wake the queue when the backoff gate opens
                    sim._push(job.not_before_t, "poll", None)
        sim._resync_stalls(now, exclude={j.name for j in changed})
        sim._start_newly_scheduled(now)

    # -------------------------------------------------------------- clear --
    def on_clear(self, payload, now: float) -> None:
        spec, target, flaps_left = payload
        sim, tel = self.sim, self.sim.telemetry
        if spec.kind in _DEVICE_KINDS:
            sim.pool.repair(list(target))
            tel.log(now, "repair", "",
                    f"{spec.kind}: {len(target)} device(s) back")
            if self.plan.regrow:
                sim.scheduler.regrow_shrunk(now)
            sim._start_newly_scheduled(now)
            if spec.kind == "device_flaky" and flaps_left > 1:
                sim._push(now + spec.period_s, "fault",
                          (spec, tuple(target), flaps_left - 1))
        elif spec.kind == "link_degrade":
            cls = LinkClass(spec.link)
            orig = self._orig_links.pop(cls, None)
            if orig is not None:
                sim.pool.links[cls] = orig
                sim.scheduler.storage.links[cls] = orig
            tel.log(now, "repair", "",
                    f"link_degrade: {cls.value} restored")
            self._reprice_running(now)
        elif spec.kind == "tranche_brownout":
            name = self._tranche_name(spec)
            # a tranche that *failed* mid-brownout is out of the
            # inventory: leave the saved original for the tranche_fail
            # clear to restore (resurrecting it here would bring it
            # back early, without a lease slot)
            if name in sim.scheduler.storage.tranches:
                orig = self._orig_tranches.pop(name, None)
                if orig is not None:
                    sim.scheduler.storage.tranches[name] = orig
                tel.log(now, "repair", "",
                        f"tranche_brownout: {name} restored")
                self._reprice_stalls(now)
        elif spec.kind == "tranche_fail":
            name = target[0]
            orig = self._orig_tranches.pop(name, None)
            if orig is not None:
                storage = sim.scheduler.storage
                storage.tranches[name] = orig
                storage._leases.setdefault(name, {})
            tel.log(now, "repair", "", f"tranche_fail: {name} back")
            sim._start_newly_scheduled(now)

    # -------------------------------------------------------------- drain --
    def on_drain(self, spec: FaultSpec, now: float) -> None:
        """Planned detach announced: serve replicas on the doomed devices
        stop admitting (the router skips them) and finish in-flight work;
        their queued requests re-route immediately."""
        sim, tel = self.sim, self.sim.telemetry
        doomed = self._doomed_uids(spec)
        if not doomed:
            return
        drained = 0
        for name, rep in list(sim.replicas.items()):
            job = rep.job
            if job.state != RUNNING or job.system is None:
                continue
            if not doomed & set(job.system.device_uids):
                continue
            sim.draining.add(name)
            drained += 1
            svc = sim.services[job.service]
            # queued (not yet begun) requests re-route right away;
            # in-flight ones finish on the still-healthy replica
            for rid in list(rep.queue):
                rep.queue.remove(rid)
                svc.requests[rid].pop("replica", None)
                sim._route_request(svc, rid, now)
        if drained:
            tel.drains += drained
            tel.log(now, "drain", "",
                    f"{spec.kind} in {spec.notice_s:.0f}s: {drained} "
                    "replica(s) draining")

    # ------------------------------------------------------------ helpers --
    def _device_victims(self, spec: FaultSpec) -> List[int]:
        pool = self.sim.pool
        if spec.kind in ("domain_outage", "pod_loss"):
            return [d.uid for d in pool.healthy() if d.domain == spec.domain]
        healthy = [d.uid for d in pool.healthy()]
        n = min(spec.n, len(healthy))
        return self.sim.rng.sample(healthy, n) if n > 0 else []

    def _doomed_uids(self, spec: FaultSpec) -> set:
        pool = self.sim.pool
        if spec.kind in ("domain_outage", "pod_loss"):
            return {d.uid for d in pool.healthy()
                    if d.domain == spec.domain}
        return set()        # random victims are unknowable in advance

    @staticmethod
    def _clear_time(spec: FaultSpec, now: float) -> float:
        if spec.kind == "device_flaky":
            down = (spec.t_clear - spec.t) if spec.t_clear < _INF \
                else spec.period_s / 2.0
            return now + down
        return spec.t_clear

    def _hang_devices(self, spec: FaultSpec, uids: List[int],
                      now: float) -> None:
        """Devices die NOW; the scheduler learns at ``now + detect_s``.
        Victim jobs hang in the window: progress frozen (``progress_t``
        pushed past it), traffic off, stale completions invalidated."""
        sim = self.sim
        for job in sim.scheduler.running:
            sim._sync_steps(job, now)
        sim.pool.mark_failed(uids)
        hit = set(uids)
        for job in sim.scheduler.running:
            if job.system is None or not hit & set(job.system.device_uids):
                continue
            sim._rate_off(job.name)
            job.epoch += 1              # drops any scheduled completion
            job.progress_t = now + spec.detect_s
            if job.fault_t < 0.0:
                job.fault_t = now
            self._hang_serve(job)

    def _hang_tranche(self, name: str, now: float,
                      spec: FaultSpec) -> None:
        """Tranche data is unreachable from ``now``; holders hang until
        the detect event preempts them onto other storage."""
        sim = self.sim
        for job in sim.scheduler.running:
            if job.system is None or job.system.tranche != name:
                continue
            sim._sync_steps(job, now)
            sim._rate_off(job.name)
            job.epoch += 1
            job.progress_t = now + spec.detect_s
            if job.fault_t < 0.0:
                job.fault_t = now
            self._hang_serve(job)

    def _hang_serve(self, job) -> None:
        """A serve replica's devices just died: its in-flight decodes
        halt mid-stream (their scheduled completions are invalidated by
        bumping the attempt counter) and the router quarantines it.
        Only timeouts / health checks / the cluster-level detect can get
        those requests moving again — which is exactly the resilience
        story chaos_bench measures."""
        sim = self.sim
        if not isinstance(job, ServeJob):
            return
        rep = sim.replicas.get(job.name)
        if rep is None:
            return
        svc = sim.services[job.service]
        for rid in rep.active:
            svc.requests[rid]["attempt"] += 1
        sim.draining.add(job.name)

    def _evacuate_tranche(self, name: str, now: float):
        """Detect: preempt every holder, then withdraw the tranche from
        the inventory so ``plan_tranche`` stops offering it."""
        sim = self.sim
        storage = sim.scheduler.storage
        changed = []
        for job in list(sim.scheduler.running):
            if job.system is not None and job.system.tranche == name:
                sim.scheduler._preempt(job, now, why=f"tranche {name} failed")
                changed.append(job)
        tr = storage.tranches.pop(name, None)
        if tr is not None:
            # setdefault: a brownout may already hold the true original
            # spec — the popped entry would be the browned-out copy
            self._orig_tranches.setdefault(name, tr)
            # leases were released by the preemptions above; withdraw the
            # slot so check_invariants stops iterating it
            storage._leases.pop(name, None)
        return changed

    def _scale_link(self, cls: LinkClass, frac: float) -> None:
        sim = self.sim
        orig = self._orig_links.setdefault(cls, sim.pool.links[cls])
        degraded = dataclasses.replace(
            orig, bandwidth=orig.bandwidth * max(frac, 1e-9))
        sim.pool.links[cls] = degraded
        sim.scheduler.storage.links[cls] = degraded

    def _scale_tranche(self, name: str, frac: float) -> None:
        storage = self.sim.scheduler.storage
        if name not in storage.tranches:
            return          # failed out of the inventory; nothing to brown
        orig = self._orig_tranches.setdefault(name, storage.tranches[name])
        storage.tranches[name] = dataclasses.replace(
            orig, read_bw=orig.read_bw * max(frac, 1e-9),
            write_bw=orig.write_bw * max(frac, 1e-9))

    def _tranche_name(self, spec: FaultSpec) -> Optional[str]:
        storage = self.sim.scheduler.storage
        if spec.tranche:
            return spec.tranche if (spec.tranche in storage.tranches
                                    or spec.tranche in self._orig_tranches) \
                else None
        names = sorted(storage.tranches)
        return names[0] if names else None

    def _reprice_running(self, now: float) -> None:
        """Link bandwidth moved: every running job's fabric snapshot is
        rebuilt on the live link table and its plan re-priced — progress
        already made accrues at the old step time, remaining work at the
        new one (graceful degradation: nobody is evicted)."""
        sim = self.sim
        sched = sim.scheduler
        repriced = []
        for job in list(sched.running):
            if job.system is None or job.plan is None:
                continue
            sim._sync_steps(job, now)
            fabric = dataclasses.replace(job.system.fabric,
                                         links=dict(sim.pool.links))
            job.system = dataclasses.replace(job.system, fabric=fabric)
            if job.run is not None:
                job.run.system = job.system
            job.plan = sched._repriced(job.plan, job.system)
            repriced.append(job)
        sched.update_stalls()           # storage attach rides links too
        sched.stall_dirty.clear()       # folded into the reschedule below
        for job in repriced:
            sim._rate_off(job.name)
            job.epoch += 1
            if isinstance(job, ServeJob):
                sim._push(now, "rate", (job.name, job.epoch))
            else:
                sim._schedule_completion(job, now)

    def _reprice_stalls(self, now: float) -> None:
        """Tranche bandwidth moved: re-derive stalls and let the
        simulator's ordinary stall resync re-price the tenants."""
        self.sim.scheduler.update_stalls()
        self.sim._resync_stalls(now)
