"""Cluster telemetry: the paper's Figs 10-12 lifted to cluster level.

Every control-plane action emits a ``ClusterEvent``; between events the
``Telemetry`` object integrates time-weighted occupancy, so the report
can state:

  * **pool utilization** — leased device-seconds / healthy device-seconds
    (Fig 10's GPU-util bar, aggregated over tenants);
  * **AUU** — accelerator under-utilization: the fraction of *leased*
    device-time not spent in useful compute (1 - AU in MLPerf-Storage
    terms; each job's compute fraction comes from its analytic roofline
    terms, so fabric-bound jobs show up as under-utilization exactly as
    the paper's falcon configs do);
  * **per-link-class traffic** — bytes moved over LOCAL / SWITCH / HOST /
    DCN links (Fig 12's sustained-traffic measurement, by fabric);
  * **recomposition overhead** — count and seconds spent re-forming
    systems after failures (Fig 11's switch-overhead, made operational).

Event schema (``ClusterEvent``): ``t`` (simulated seconds), ``kind`` (one
of ``EVENT_KINDS`` below), ``job`` (job name or "" for pool-level
events), and ``detail`` (human-readable payload).
``Telemetry.report()`` returns a JSON-serializable dict with the schema
used by ``benchmarks/cluster_sim`` — the canonical field-by-field
reference is ``docs/telemetry.md``.

Invariants:

  * ``observe(t, ...)`` integrates the *previous* occupancy over
    ``[last_t, t]``; callers must invoke it after every state change
    with the post-change values, and ``t`` never moves backwards.
  * Every control-plane action logs exactly one event with a ``kind``
    from ``EVENT_KINDS`` (asserted in ``log``); policy evictions log
    both the generic ``preempt`` and the attributing ``evict`` event.
  * Per-tenant wait samples (``job_waited``) and gang spans
    (``gang_started``) are append-only counters — ``report()`` is a
    pure function of them, so two identical traces report identically.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

from repro.core.topology import LinkClass

EVENT_KINDS = ("submit", "reject", "start", "complete", "fail", "repair",
               "recompose", "preempt", "conflict", "storage", "evict",
               "shrink", "gang", "fault", "detect", "retry", "drain",
               "autoscale", "attach", "detach", "migrate")


@dataclasses.dataclass(frozen=True)
class ClusterEvent:
    t: float
    kind: str
    job: str = ""
    detail: str = ""


def _percentile(sorted_xs: List[float], q: float) -> float:
    """Nearest-rank percentile on a pre-sorted list (0 <= q <= 100)."""
    if not sorted_xs:
        return 0.0
    k = max(0, min(len(sorted_xs) - 1,
                   math.ceil(q / 100.0 * len(sorted_xs)) - 1))
    return sorted_xs[k]


class ServingStats:
    """Per-request serving telemetry: TTFT / TPOT / queue wait / cache
    hits, aggregated to the report schema shared by the serve engine
    (wall-clock), the cluster simulator's serving-trace mode (simulated
    time), and ``benchmarks/serve_bench``."""

    def __init__(self):
        self.ttft_s: List[float] = []
        self.tpot_s: List[float] = []
        self.wait_s: List[float] = []
        self.requests_submitted = 0
        self.requests_rejected = 0
        self.requests_completed = 0
        self.requests_timed_out = 0     # per-request deadline expiries
        self.requests_failed = 0        # retries exhausted (terminal)
        self.request_retries = 0        # re-route / re-issue attempts
        self.slo_met = 0
        self.prompt_tokens = 0
        self.cached_tokens = 0
        self.output_tokens = 0
        self._t0: Optional[float] = None
        self._t1: Optional[float] = None

    def mark(self, t: float) -> None:
        """Extend the observation span to ``t``."""
        if self._t0 is None:
            self._t0 = t
        self._t1 = t if self._t1 is None else max(self._t1, t)

    def add_request(self, *, t_done: float, wait_s: float, ttft_s: float,
                    tpot_s: float, prompt_tokens: int, cached_tokens: int,
                    output_tokens: int, slo_ok: bool) -> None:
        self.mark(t_done)
        self.requests_completed += 1
        self.wait_s.append(wait_s)
        self.ttft_s.append(ttft_s)
        if tpot_s > 0:
            self.tpot_s.append(tpot_s)
        self.prompt_tokens += prompt_tokens
        self.cached_tokens += cached_tokens
        self.output_tokens += output_tokens
        self.slo_met += bool(slo_ok)

    @property
    def span_s(self) -> float:
        if self._t0 is None or self._t1 is None:
            return 0.0
        return self._t1 - self._t0

    @staticmethod
    def _dist(xs: List[float]) -> Dict[str, float]:
        s = sorted(xs)
        return {"p50": _percentile(s, 50.0), "p99": _percentile(s, 99.0),
                "mean": sum(s) / len(s) if s else 0.0}

    def report(self) -> Dict[str, object]:
        span = max(self.span_s, 1e-12)
        return {
            "requests": {
                "submitted": self.requests_submitted,
                "completed": self.requests_completed,
                "rejected": self.requests_rejected,
                "timed_out": self.requests_timed_out,
                "failed": self.requests_failed,
                "retries": self.request_retries,
            },
            "failed_request_rate": (self.requests_failed
                                    / max(self.requests_submitted, 1)),
            "ttft_s": self._dist(self.ttft_s),
            "tpot_s": self._dist(self.tpot_s),
            "queue_wait_s": self._dist(self.wait_s),
            "slo_attainment": (self.slo_met
                               / max(self.requests_completed, 1)),
            "throughput_tok_s": self.output_tokens / span,
            "requests_per_s": self.requests_completed / span,
            "cache_hit_rate": (self.cached_tokens
                               / max(self.prompt_tokens, 1)),
            "output_tokens": self.output_tokens,
            "span_s": self.span_s,
        }


class StorageStats:
    """Per-tranche storage telemetry: time-weighted lessee occupancy,
    bytes moved, and accumulated input-stall seconds — the MLPerf-Storage
    view (AU degradation comes exactly from these stalls) lifted to the
    tranche the jobs actually lease."""

    def __init__(self, name: str, attach: str = ""):
        self.name = name
        self.attach = attach
        self.read_bytes = 0.0
        self.write_bytes = 0.0
        self.stall_s = 0.0              # input-stall seconds across tenants
        self.leases_granted = 0
        self.peak_lessees = 0
        # time-weighted lessee integral
        self._t: Optional[float] = None
        self._t0: Optional[float] = None
        self._n = 0
        self._lessee_area = 0.0         # lessee-seconds

    def observe(self, t: float, n_lessees: int) -> None:
        if self._t is None:
            self._t = self._t0 = t
        dt = t - self._t
        if dt > 0:
            self._lessee_area += dt * self._n
            self._t = t
        self._n = n_lessees
        self.peak_lessees = max(self.peak_lessees, n_lessees)

    def add_io(self, read_bytes: float = 0.0, write_bytes: float = 0.0,
               stall_s: float = 0.0) -> None:
        self.read_bytes += read_bytes
        self.write_bytes += write_bytes
        self.stall_s += stall_s

    @property
    def span_s(self) -> float:
        if self._t is None or self._t0 is None:
            return 0.0
        return self._t - self._t0

    def mean_lessees(self) -> float:
        span = self.span_s
        return self._lessee_area / span if span > 0 else 0.0

    def report(self) -> Dict[str, object]:
        return {
            "attach": self.attach,
            "leases_granted": self.leases_granted,
            "peak_lessees": self.peak_lessees,
            "mean_lessees": self.mean_lessees(),
            "read_gb": self.read_bytes / 1e9,
            "write_gb": self.write_bytes / 1e9,
            "input_stall_s": self.stall_s,
        }


class Telemetry:
    """Integrates occupancy over simulated time and accumulates counters."""

    def __init__(self, n_devices_total: int):
        self.n_devices_total = n_devices_total
        self.events: List[ClusterEvent] = []
        self.link_traffic_bytes: Dict[str, float] = {
            c.value: 0.0 for c in LinkClass}
        self.waits_s: List[float] = []
        self.recompositions = 0
        self.recompose_overhead_s = 0.0
        self.lease_conflicts = 0
        self.jobs_submitted = 0
        self.jobs_completed = 0
        self.jobs_rejected = 0
        self.jobs_preempted = 0
        self.jobs_evicted = 0           # policy-driven preemptions (subset)
        self.jobs_shrunk = 0            # policy-driven preempt-to-shrink
        self.jobs_evictions_suppressed = 0   # victims pinned at budget
        self.jobs_failed = 0            # retry budget exhausted (terminal)
        # fault-injection plane (cluster.faults): counters + recovery
        # samples.  recovery = fault injection -> victim back on devices
        # (detect + decide + restore), one sample per fault-hit restart.
        self.faults_injected = 0
        self.faults_detect_s: List[float] = []   # detection latencies
        self.recovery_s: List[float] = []        # fault -> restart samples
        self.retries_scheduled = 0      # backoff retries granted
        self.drains = 0                 # graceful drains honoured
        # live recomposition plane (cluster.recomposer): widen / shrink /
        # tranche-migrate actions taken on running jobs, plus the device
        # delta they moved (attached + detached device count).
        self.attaches = 0
        self.detaches = 0
        self.migrations = 0
        self.devices_recomposed = 0
        # set by the simulator when a RecomposeConfig is active; gates the
        # ``recompose`` report section so legacy (recompose=None) reports
        # stay bit-identical (same pattern as the serving autoscale block)
        self.recompose_enabled = False
        self.storage: Dict[str, StorageStats] = {}   # tranche -> stats
        # gang scheduling: one span sample per gang start (DCN hop span)
        self.gang_spans: List[int] = []
        # fairness: queue-wait samples keyed by tenant (insertion order
        # follows first wait per tenant -> deterministic report)
        self.waits_by_tenant: Dict[str, List[float]] = {}
        # time-weighted integrals
        self._t: Optional[float] = None
        self._t0: Optional[float] = None
        self._n_leased = 0
        self._busy_equiv = 0.0          # sum over jobs: n_dev * compute_frac
        self._n_healthy = n_devices_total
        self._leased_area = 0.0         # device-seconds under lease
        self._busy_area = 0.0           # device-seconds of useful compute
        self._healthy_area = 0.0        # device-seconds of healthy capacity

    # -------------------------------------------------------------- events --
    def log(self, t: float, kind: str, job: str = "",
            detail: str = "") -> None:
        assert kind in EVENT_KINDS, kind
        self.events.append(ClusterEvent(t, kind, job, detail))

    # ----------------------------------------------------------- occupancy --
    def observe(self, t: float, *, n_leased: int, busy_equiv: float,
                n_healthy: int) -> None:
        """Advance the clock to ``t`` and record the new occupancy.

        The *previous* occupancy is integrated over [last_t, t]; call this
        after every state change with the post-change values.
        """
        if self._t is None:
            self._t = self._t0 = t
        dt = t - self._t
        if dt > 0:
            self._leased_area += dt * self._n_leased
            self._busy_area += dt * self._busy_equiv
            self._healthy_area += dt * self._n_healthy
            self._t = t
        self._n_leased = n_leased
        self._busy_equiv = busy_equiv
        self._n_healthy = n_healthy

    # ------------------------------------------------------------ counters --
    def add_link_traffic(self, link: LinkClass, nbytes: float) -> None:
        self.link_traffic_bytes[link.value] += nbytes

    def job_waited(self, seconds: float, tenant: str = "") -> None:
        self.waits_s.append(seconds)
        if tenant:
            self.waits_by_tenant.setdefault(tenant, []).append(seconds)

    def gang_started(self, span: int) -> None:
        self.gang_spans.append(span)

    def add_recomposition(self, overhead_s: float) -> None:
        self.recompositions += 1
        self.recompose_overhead_s += overhead_s

    def tranche_stats(self, name: str, attach: str = "") -> StorageStats:
        st = self.storage.get(name)
        if st is None:
            st = self.storage[name] = StorageStats(name, attach)
        return st

    # -------------------------------------------------------------- report --
    @property
    def span_s(self) -> float:
        if self._t is None or self._t0 is None:
            return 0.0
        return self._t - self._t0

    def pool_utilization(self) -> float:
        """Leased device-seconds over healthy device-seconds."""
        if self._healthy_area <= 0:
            return 0.0
        return self._leased_area / self._healthy_area

    def auu(self) -> float:
        """Accelerator under-utilization among leased device-time."""
        if self._leased_area <= 0:
            return 0.0
        return max(0.0, 1.0 - self._busy_area / self._leased_area)

    def availability(self) -> float:
        """Healthy device-seconds over total device-seconds: the fraction
        of pool capacity that survived the fault schedule."""
        span = self.span_s
        if span <= 0 or self.n_devices_total <= 0:
            return 1.0
        return self._healthy_area / (self.n_devices_total * span)

    def goodput_fraction(self) -> float:
        """Useful-compute device-seconds over *healthy* device-seconds —
        how much of the surviving capacity did real work (availability
        strips dead capacity; this strips idle + overhead on top)."""
        if self._healthy_area <= 0:
            return 0.0
        return min(1.0, self._busy_area / self._healthy_area)

    def fault_recovery(self) -> Dict[str, float]:
        s = sorted(self.recovery_s)
        return {
            "samples": len(s),
            "mean_s": sum(s) / len(s) if s else 0.0,
            "p95_s": _percentile(s, 95.0),
            "max_s": s[-1] if s else 0.0,
        }

    @staticmethod
    def _wait_dist(xs: List[float]) -> Dict[str, float]:
        s = sorted(xs)
        return {"p50": _percentile(s, 50.0), "p95": _percentile(s, 95.0),
                "p99": _percentile(s, 99.0),
                "mean": sum(s) / len(s) if s else 0.0}

    def fairness(self) -> Dict[str, object]:
        """Per-tenant queue-wait distributions plus the scalar the policy
        sweep compares: the mean over tenants of each tenant's p95 wait
        (tenant-weighted, so a flooding tenant cannot drown the small
        tenants' experience the way a job-weighted p95 would)."""
        tenants = {t: dict(wait_s=self._wait_dist(w), n_waits=len(w))
                   for t, w in sorted(self.waits_by_tenant.items())}
        p95s = [row["wait_s"]["p95"] for row in tenants.values()]
        return {
            "tenants": tenants,
            "tenant_p95_wait_mean_s": sum(p95s) / len(p95s) if p95s else 0.0,
        }

    def report(self) -> Dict[str, object]:
        waits = sorted(self.waits_s)
        span = max(self.span_s, 1e-12)
        spans = self.gang_spans
        rep: Dict[str, object] = {
            "span_s": self.span_s,
            "pool_utilization": self.pool_utilization(),
            "auu": self.auu(),
            "accelerator_utilization": 1.0 - self.auu(),
            "link_traffic_gb": {
                k: v / 1e9 for k, v in self.link_traffic_bytes.items()},
            "link_traffic_gbps": {
                k: v / 1e9 / span
                for k, v in self.link_traffic_bytes.items()},
            "recomposition": {
                "count": self.recompositions,
                "overhead_s": self.recompose_overhead_s,
                "overhead_frac": self.recompose_overhead_s / span,
            },
            "job_wait_s": {
                "p50": _percentile(waits, 50.0),
                "p99": _percentile(waits, 99.0),
                "mean": sum(waits) / len(waits) if waits else 0.0,
            },
            "jobs": {
                "submitted": self.jobs_submitted,
                "completed": self.jobs_completed,
                "rejected": self.jobs_rejected,
                "preempted": self.jobs_preempted,
                "evicted": self.jobs_evicted,
                "shrunk": self.jobs_shrunk,
                "evictions_suppressed": self.jobs_evictions_suppressed,
                "failed": self.jobs_failed,
            },
            "faults": {
                "injected": self.faults_injected,
                "availability": self.availability(),
                "goodput_fraction": self.goodput_fraction(),
                "detect_s_mean": (sum(self.faults_detect_s)
                                  / len(self.faults_detect_s)
                                  if self.faults_detect_s else 0.0),
                "recovery": self.fault_recovery(),
                "retries_scheduled": self.retries_scheduled,
                "drains": self.drains,
            },
            "gangs": {
                "started": len(spans),
                "max_span": max(spans) if spans else 0,
                "mean_span": sum(spans) / len(spans) if spans else 0.0,
            },
            "fairness": self.fairness(),
            "lease_conflicts": self.lease_conflicts,
            "n_events": len(self.events),
            "storage": {name: st.report()
                        for name, st in sorted(self.storage.items())},
        }
        if self.recompose_enabled:
            rep["recompose"] = {
                "attaches": self.attaches,
                "detaches": self.detaches,
                "migrations": self.migrations,
                "devices_recomposed": self.devices_recomposed,
            }
        return rep
