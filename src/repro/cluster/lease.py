"""Pool leasing: exclusive device claims with domain-aware placement.

``DevicePool`` enforces the raw invariant (no uid is leased twice);
this module adds the *placement policy* on top: which devices a job
should claim, and which link class each mesh axis consequently rides
on.  The rule mirrors how ``compose()`` lays out axes — the innermost
(model/tp) axis is kept inside a single locality clique whenever the
pool allows it, so tensor-parallel collectives ride the fast fabric
and only the data axis spans the composed switch:

  * every tp-group inside one (domain, LOCAL) clique  -> model on LOCAL
  * tp-groups intact but on switch-attached devices   -> model on SWITCH
  * data axis within one clique                       -> data on LOCAL
  * data axis spanning domains or fabrics             -> data on SWITCH

This is the paper's Table III spectrum (localGPUs / hybridGPUs /
falconGPUs) derived from *where the free devices actually are* instead
of fixed by hand.

Multi-pod **gang** placement extends the same policy over the DCN axis:
``plan_gang`` co-selects ``n_pods`` pod-sized chip cliques — each member
mesh confined to a single locality domain — choosing the set of domains
that minimizes the DCN hop span, and ``LeaseManager.acquire_gang``
claims them all-or-nothing.

Invariants (enforced here and in ``DevicePool`` / ``StoragePool``):

  * **Exclusive device claims** — a uid is never leased twice; an
    overlapping claim raises ``LeaseError`` / ``CompositionError`` and
    leaves the pool untouched (``DevicePool.lease`` is atomic).
  * **All-or-nothing gang claims** — ``acquire_gang`` claims member
    cliques one at a time but rolls back every already-claimed member
    if any later member conflicts, so a failed gang acquisition leaves
    the pool exactly as it was.
  * **Plans never mutate the pool** — ``plan_placement`` / ``plan_gang``
    / ``plan_tranche`` only read pool state; a plan that cannot be
    covered raises ``CompositionError`` without side effects.
  * **Release is symmetric** — ``LeaseManager.release(holder)`` frees
    the holder's devices *and* storage tranches in one call.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.compose import CompositionError, ComposedSystem
from repro.core.topology import (AxisPath, Device, DevicePool, LeaseError,
                                 LinkClass)
from repro.data.storage import StoragePool, StorageTranche

# bandwidth ordering used to pick the "worst" link a span needs
_LINK_RANK = {LinkClass.LOCAL: 0, LinkClass.SWITCH: 1, LinkClass.HOST: 2,
              LinkClass.DCN: 3}

# worst-first ordering over resolved paths: link class, then extra hops,
# then deeper bandwidth derate (all equal under the flat topology, so the
# class alone decides — the legacy rule)
_PATH_RANK = (lambda p: (_LINK_RANK[p.link], p.hops, -p.bw_scale))


@dataclasses.dataclass(frozen=True)
class PlacementPlan:
    """A concrete device selection for a (dp, tp) mesh, plus the link
    class each axis must be priced on given that selection."""
    uids: Tuple[int, ...]
    axis_links: Dict[str, LinkClass]
    n_domains: int
    fabrics: Tuple[LinkClass, ...]        # distinct device fabrics used
    note: str = ""
    # hop counts / bandwidth derates the pool topology adds per axis
    axis_paths: Dict[str, AxisPath] = dataclasses.field(default_factory=dict)

    @property
    def label(self) -> str:
        return "+".join(sorted(f.value for f in set(self.fabrics)))


def _span_link(pool: DevicePool, c: Sequence[Device]) -> LinkClass:
    """Worst link a set of devices needs to talk (Table IV semantics):
    one clique -> its own fabric; mixed fabrics -> host root complex;
    same fabric across domains -> the composable switch spans drawers,
    but local ICI does not, so cross-domain LOCAL rides the DCN.  Mixed
    fabrics *across* domains traverse the host complex and the pod
    network in series: priced at the slower of the two, so a cross-pod
    span never beats the DCN."""
    fabrics = {x.fabric for x in c}
    cross_domain = len({x.domain for x in c}) > 1
    if len(fabrics) > 1:
        if cross_domain:
            return min((LinkClass.HOST, LinkClass.DCN),
                       key=lambda k: pool.links[k].bandwidth)
        return LinkClass.HOST
    f = next(iter(fabrics))
    if not cross_domain:
        return f
    return f if f == LinkClass.SWITCH else LinkClass.DCN


def _span_path(pool: DevicePool, c: Sequence[Device]) -> AxisPath:
    """``_span_link`` plus the hop count and bandwidth derate the pool's
    topology assigns that span.  ``flows`` is the worst per-drawer
    concurrency: every chip of the span's densest domain drives its
    cross-drawer link at once during a collective."""
    cls = _span_link(pool, c)
    doms = [x.domain for x in c]
    span = max(doms) - min(doms)
    flows = max(doms.count(d) for d in set(doms)) if span else 1
    topo = pool.topo
    return AxisPath(cls, topo.hops(cls, span),
                    topo.bw_scale(cls, span, flows))


def derive_axis_paths(pool: DevicePool, uids: Sequence[int], tp: int
                      ) -> Dict[str, AxisPath]:
    """Resolved path per mesh axis implied by an *actual* device
    selection: the link class (exactly ``derive_axis_links``) plus the
    hop count and bandwidth derate the pool's topology adds."""
    dev = {d.uid: d for d in pool.devices}
    chosen = [dev[u] for u in uids]
    chunks = [chosen[i:i + tp] for i in range(0, len(chosen), tp)]
    model = max((_span_path(pool, c) for c in chunks), key=_PATH_RANK)
    data = model if len(chunks) == 1 else _span_path(pool, chosen)
    return {"data": data, "model": model}


def path_maps(paths: Dict[str, AxisPath]
              ) -> Tuple[Dict[str, LinkClass], Dict[str, int],
                         Dict[str, float]]:
    """``(axis_links, axis_hops, axis_bw_scale)`` for ``FabricSpec``.
    Default entries (1 hop, full speed) are elided so a flat topology
    builds the exact legacy spec."""
    links = {a: p.link for a, p in paths.items()}
    hops = {a: p.hops for a, p in paths.items() if p.hops != 1}
    scale = {a: p.bw_scale for a, p in paths.items() if p.bw_scale != 1.0}
    return links, hops, scale


def derive_axis_links(pool: DevicePool, uids: Sequence[int], tp: int
                      ) -> Dict[str, LinkClass]:
    """Link class per mesh axis implied by an *actual* device selection.

    ``compose()`` reshapes the claim row-major, so consecutive runs of
    ``tp`` uids form the tensor-parallel groups.  Used both when planning
    a placement and after an elastic recompose, whose spare devices may
    sit on a different fabric than the original claim.
    """
    return {a: p.link
            for a, p in derive_axis_paths(pool, uids, tp).items()}


def _cliques(free: Sequence[Device]) -> List[List[Device]]:
    """Free devices grouped into locality cliques (same domain + fabric),
    LOCAL-fabric cliques first, largest first within a fabric class."""
    by_key: Dict[Tuple[int, LinkClass], List[Device]] = {}
    for d in free:
        by_key.setdefault((d.domain, d.fabric), []).append(d)
    groups = sorted(by_key.values(),
                    key=lambda g: (_LINK_RANK[g[0].fabric], -len(g),
                                   g[0].domain))
    return groups


def plan_placement(pool: DevicePool, dp: int, tp: int,
                   prefer_fabric: Optional[LinkClass] = None
                   ) -> PlacementPlan:
    """Choose ``dp*tp`` available devices and derive per-axis link classes.

    Selection is clique-major in whole tp-sized chunks: each tp-group is
    carved from a single clique while any clique has room, so the model
    axis stays on the clique's fabric; the data axis degrades to SWITCH
    as soon as the selection spans cliques.  Under a multi-tier topology
    the cliques after the first are re-ordered by hop distance from the
    anchor clique's drawer, so a spanning selection prefers the nearest
    drawers (a no-op on the flat fabric, where every cross-drawer path
    is one hop).  Raises ``CompositionError`` when the available pool
    cannot cover the request.
    """
    n = dp * tp
    free = pool.available()
    if len(free) < n:
        raise CompositionError(
            f"placement needs {n} devices; only {len(free)} available "
            f"({len(pool.healthy())} healthy, "
            f"{len(pool.leases)} leased)")
    groups = _cliques(free)
    if prefer_fabric is not None:
        groups.sort(key=lambda g: (g[0].fabric != prefer_fabric,
                                   _LINK_RANK[g[0].fabric], -len(g)))
    if len(groups) > 1:
        topo = pool.topo
        anchor = groups[0][0].domain
        groups[1:] = sorted(groups[1:], key=lambda g: (
            (g[0].fabric != prefer_fabric) if prefer_fabric is not None
            else False,
            _LINK_RANK[g[0].fabric],
            topo.hops(g[0].fabric, abs(g[0].domain - anchor)),
            -len(g), g[0].domain))

    picked: List[Device] = []
    gi = 0
    while len(picked) < n and gi < len(groups):
        g = groups[gi]
        # carve whole tp-groups out of this clique while it has room
        while len(g) >= tp and len(picked) < n:
            picked.extend(g[:tp])
            g = g[tp:]
        groups[gi] = g
        gi += 1
    if len(picked) < n:
        # remainder: tp-groups must straddle cliques (model axis degrades)
        rest = [d for g in groups for d in g]
        picked.extend(rest[:n - len(picked)])

    uids = tuple(d.uid for d in picked)
    axis_paths = derive_axis_paths(pool, uids, tp)
    domains = {d.domain for d in picked}
    fabrics = {d.fabric for d in picked}
    note = (f"{len(domains)} domain(s), "
            f"{'+'.join(sorted(f.value for f in fabrics))}")
    return PlacementPlan(uids, {a: p.link for a, p in axis_paths.items()},
                         len(domains),
                         tuple(sorted(fabrics, key=_LINK_RANK.get)), note,
                         axis_paths)


# ---------------------------------------------------------------------------
# multi-pod gang placement (the DCN axis)
# ---------------------------------------------------------------------------
def domain_counts(devices: Sequence[Device]) -> Dict[int, int]:
    """Device count per locality domain over any device iterable."""
    out: Dict[int, int] = {}
    for d in devices:
        out[d.domain] = out.get(d.domain, 0) + 1
    return out


def hosting_domains(devices: Sequence[Device], n_member: int) -> List[int]:
    """Domains (sorted) with at least ``n_member`` of ``devices`` — THE
    gang-member eligibility rule, shared by planning (``plan_gang``),
    fit-checking (``Scheduler._fits_now``), admission
    (``Scheduler._gang_impossible``), and policy preemption, so the
    four views of "can this domain host a member clique?" cannot
    desync."""
    return sorted(dom for dom, n in domain_counts(devices).items()
                  if n >= n_member)


@dataclasses.dataclass(frozen=True)
class GangPlan:
    """A co-selected placement for an ``n_pods``-member gang.

    Each member is a full ``(dp, tp)`` mesh confined to one locality
    domain; members talk to each other over the DCN ("pod") axis.
    ``uids`` concatenates the members pod-major, which is exactly the
    row-major order ``compose()`` expects for a ``(pod, data, model)``
    mesh.
    """
    members: Tuple[PlacementPlan, ...]
    domains: Tuple[int, ...]             # one locality domain per member
    axis_links: Dict[str, LinkClass]     # pod -> DCN + worst member links
    dcn_hops: int                        # domain-id span of the gang
    # topology-resolved path per axis (worst member path + the pod span)
    axis_paths: Dict[str, AxisPath] = dataclasses.field(default_factory=dict)

    @property
    def uids(self) -> Tuple[int, ...]:
        return tuple(u for m in self.members for u in m.uids)

    @property
    def n_pods(self) -> int:
        return len(self.members)


def plan_gang(pool: DevicePool, n_pods: int, dp: int, tp: int,
              prefer_fabric: Optional[LinkClass] = None) -> GangPlan:
    """Co-select ``n_pods`` pod-sized chip cliques for one gang job.

    Each member mesh (``dp * tp`` chips) is carved from a single
    locality domain with ``plan_placement``'s clique-major rule, so the
    intra-member axes ride the member's own fabric and only the gang's
    "pod" axis crosses the DCN.  The member domains are chosen to
    minimize the DCN hop span (``max(domain) - min(domain)`` over the
    eligible domains, ties to the lowest ids — deterministic), i.e. the
    gang lands on the closest set of pods that can each host a member.

    Pure planning: the pool is only read.  Raises ``CompositionError``
    when fewer than ``n_pods`` domains can host a member.
    """
    if n_pods < 2:
        raise CompositionError(f"a gang needs n_pods >= 2; got {n_pods}")
    n_member = dp * tp
    free = pool.available()
    eligible = hosting_domains(free, n_member)
    if len(eligible) < n_pods:
        raise CompositionError(
            f"gang needs {n_pods} domains with {n_member} free devices "
            f"each; only {len(eligible)} of "
            f"{len(domain_counts(free))} qualify")
    # minimal-span window over the sorted eligible domain ids: the DCN
    # hop distance between domains a and b is |a - b| (pods are laid out
    # linearly on the inter-pod network), so the contiguous window with
    # the smallest id span is the closest co-selection
    windows = [eligible[i:i + n_pods]
               for i in range(len(eligible) - n_pods + 1)]
    chosen = min(windows, key=lambda w: (w[-1] - w[0], w[0]))
    members = []
    for dom in chosen:
        sub = DevicePool(
            devices=[d for d in pool.devices if d.domain == dom],
            links=pool.links, leases=pool.leases, topology=pool.topology)
        members.append(plan_placement(sub, dp, tp, prefer_fabric))
    span = chosen[-1] - chosen[0]
    topo = pool.topo
    paths: Dict[str, AxisPath] = {
        # every member's dp*tp chips cross the pod boundary at once
        "pod": AxisPath(LinkClass.DCN, topo.hops(LinkClass.DCN, span),
                        topo.bw_scale(LinkClass.DCN, span, dp * tp))}
    for axis in ("data", "model"):
        paths[axis] = max((m.axis_paths[axis] for m in members),
                          key=_PATH_RANK)
    links = {a: p.link for a, p in paths.items()}
    return GangPlan(tuple(members), tuple(chosen), links, span, paths)


def plan_tranche(storage: StoragePool, *, capacity_bytes: float = 0.0,
                 prefer_domain: Optional[int] = None) -> StorageTranche:
    """Choose the NVMe tranche a new tenant should attach.

    Mirrors ``plan_placement``'s locality preference on the storage axis:
    an *idle* local tranche in the placement's domain first (the paper's
    localNVMe), then any idle local, then an idle switch-attached one,
    and only then the least-contended shared tranche — co-location splits
    bandwidth, so it is the placement of last resort.  Raises
    ``CompositionError`` when no tranche has the capacity headroom.
    """
    def fits(t: StorageTranche) -> bool:
        return (not storage.exclusively_held(t.name)
                and storage.capacity_used(t.name) + capacity_bytes
                <= t.capacity_bytes)

    candidates = [t for t in storage.tranches.values() if fits(t)]
    if not candidates:
        raise CompositionError(
            f"no tranche can host {capacity_bytes / 1e9:.1f} GB "
            f"({len(storage.tranches)} tranches, all full or "
            "exclusively held)")
    return min(candidates, key=lambda t: (
        storage.n_lessees(t.name),                       # idle first
        _LINK_RANK[t.attach],                            # local fabric
        t.domain != prefer_domain if prefer_domain is not None else False,
        t.name))                                         # deterministic


# ---------------------------------------------------------------------------
# lease lifecycle bookkeeping (job-facing view over DevicePool.leases)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Lease:
    lease_id: int
    holder: str
    uids: Tuple[int, ...]
    t_acquired: float


class LeaseManager:
    """Tracks the pool's active leases as first-class objects.

    ``compose()`` performs the actual claim inside the pool; the manager
    records who holds what since when, counts conflicts (claims that
    raised), and answers utilization queries for telemetry.  When built
    with a ``StoragePool``, NVMe tranches are pooled alongside devices:
    ``acquire_tranche`` attaches a holder, and ``release`` frees the
    holder's devices *and* storage in one call.
    """

    def __init__(self, pool: DevicePool,
                 storage: Optional[StoragePool] = None):
        self.pool = pool
        self.storage = storage
        self._leases: Dict[int, Lease] = {}      # lease_id -> Lease; a
        self._next_id = 0                        # holder may hold several
        self.conflicts = 0

    def _record(self, holder: str, uids: Tuple[int, ...],
                now: float) -> Lease:
        lease = Lease(self._next_id, holder, uids, now)
        self._next_id += 1
        self._leases[lease.lease_id] = lease
        return lease

    # ------------------------------------------------------------ claims --
    def adopt(self, system: ComposedSystem, now: float = 0.0) -> Lease:
        """Record a lease for a system ``compose()`` already claimed."""
        for u in system.device_uids:
            if self.pool.leases.get(u) != system.name:
                raise LeaseError(
                    f"device {u} is not leased to {system.name!r}; "
                    "adopt() requires a composed (claimed) system")
        return self._record(system.name, system.device_uids, now)

    def acquire(self, holder: str, uids: Sequence[int],
                now: float = 0.0) -> Lease:
        """Directly claim explicit uids (storage tiers, spare tranches)."""
        self.pool.lease(uids, holder)
        return self._record(holder, tuple(uids), now)

    def acquire_gang(self, holder: str, gang: GangPlan,
                     now: float = 0.0) -> Lease:
        """All-or-nothing claim of every member clique in ``gang``.

        Members are claimed one at a time (each member claim is itself
        atomic inside the pool); if any member conflicts, every member
        already claimed for this gang is released before raising, so a
        failed acquisition leaves the pool bit-identical to before the
        call.  Raises ``CompositionError`` on any conflict.
        """
        claimed: List[int] = []
        try:
            for m in gang.members:
                self.pool.lease(m.uids, holder)
                claimed.extend(m.uids)
        except LeaseError as e:
            self.pool.release(claimed)           # roll back partial claim
            self.conflicts += 1
            raise CompositionError(
                f"gang claim for {holder!r} rolled back "
                f"({len(claimed)} device(s) released): {e}") from e
        return self._record(holder, gang.uids, now)

    def acquire_tranche(self, holder: str, tranche: str, *,
                        capacity_bytes: float = 0.0,
                        now: float = 0.0):
        """Attach ``holder`` to an NVMe tranche (requires a storage pool);
        double-claims raise ``CompositionError`` inside the pool."""
        if self.storage is None:
            raise CompositionError(
                "LeaseManager has no StoragePool; cannot lease tranche "
                f"{tranche!r}")
        return self.storage.lease(tranche, holder,
                                  capacity_bytes=capacity_bytes, now=now)

    def release(self, holder: str) -> List[int]:
        self.forget(holder)
        if self.storage is not None:
            self.storage.release(holder)
        return self.pool.release_holder(holder)

    def forget(self, holder: str) -> None:
        """Drop the manager's records only — pool leases stay intact (used
        when a recompose already re-leased under the same holder)."""
        for lid in [l.lease_id for l in self._leases.values()
                    if l.holder == holder]:
            del self._leases[lid]

    # ----------------------------------------------------------- queries --
    def active(self) -> List[Lease]:
        return sorted(self._leases.values(), key=lambda l: l.lease_id)

    def holder_of(self, uid: int) -> Optional[str]:
        return self.pool.leases.get(uid)

    def n_leased(self) -> int:
        return len(self.pool.leases)

    def utilization(self) -> float:
        """Leased fraction of the healthy pool (instantaneous)."""
        healthy = len(self.pool.healthy())
        if healthy == 0:
            return 0.0
        leased_healthy = sum(1 for d in self.pool.devices
                             if d.healthy and d.uid in self.pool.leases)
        return leased_healthy / healthy

    def check_exclusive(self) -> None:
        """Invariant: every lease's uids are disjoint and pool-backed;
        the storage pool (when present) is never oversubscribed."""
        seen: Dict[int, str] = {}
        for lease in self._leases.values():
            for u in lease.uids:
                if u in seen and self.pool.leases.get(u) is not None:
                    raise LeaseError(
                        f"uid {u} held by both {seen[u]!r} and "
                        f"{lease.holder!r}")
                seen[u] = lease.holder
        if self.storage is not None:
            self.storage.check_invariants()
