from repro.data.pipeline import (Prefetcher, StorageModel,  # noqa: F401
                                 SyntheticDataset, input_stall, make_batch)
