from repro.data.pipeline import (IOTraceGenerator, IOWorkload,  # noqa: F401
                                 IO_WORKLOADS, Prefetcher, StorageModel,
                                 SyntheticDataset, input_stall,
                                 lm_io_workload, make_batch, workload_stall)
from repro.data.storage import (StorageLease, StoragePool,  # noqa: F401
                                StorageTranche, make_storage_pool)
