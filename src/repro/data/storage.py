"""NVMe tranches: storage as a first-class composable resource.

The paper's §V-3 experiment (Fig 15/16) composes the *storage* side of a
workload — the same NVMe device attached either host-local or behind the
Falcon switch — and measures the input-path impact.  The cluster control
plane so far leased only GPU pools; this module gives storage the same
treatment, following the disaggregated-resource model (Takano & Suzaki's
accelerator manager, MLPerf-Storage's AU accounting):

  * ``StorageTranche``  — one leasable slice of pooled NVMe: capacity,
    sustained read/write bandwidth, and the fabric it attaches through
    (``LinkClass.LOCAL`` = host NVMe, ``LinkClass.SWITCH`` = the paper's
    falcon-attached drawer).
  * ``StoragePool``     — the chassis storage inventory.  Unlike device
    leases (exclusive: one chip, one tenant), tranches are *shared* by
    default — the composable switch is exactly what lets N hosts attach
    one drawer — and the tranche's bandwidth is partitioned equally
    across its concurrent lessees.  The invariants are: a holder never
    claims the same tranche twice, an ``exclusive`` claim tolerates no
    co-tenants, and capacity is never oversubscribed; violations raise
    ``CompositionError`` just like a device double-claim.

A composition is then *devices + storage*: ``core.compose.compose()``
accepts a ``(storage_pool, tranche)`` pair and leases the tranche under
the composition's name, and ``repro.cluster`` admission requires a
storage lease before a job may start (see ``cluster.scheduler``).

Invariants:

  * **Atomic claims** — ``StoragePool.lease`` either records the lease
    or raises ``CompositionError`` leaving the pool untouched; inside
    ``compose(..., storage_pool=, tranche=)`` a storage conflict rolls
    the device claim back too, so a composition is never half-formed.
  * **CompositionError conditions** — unknown tranche; a double claim
    by the same holder (storage leases don't stack); an exclusive
    claim meeting existing lessees, or any claim meeting an exclusive
    lease; capacity oversubscription.
  * **Equal partitioning** — a tranche's read/write bandwidth divides
    equally across its current lessees after the attach fabric's
    ceiling (``topology.partitioned_bw``); there is no QoS weighting
    yet (ROADMAP follow-up).
  * **Stall re-derivation** — consumers must re-derive input stalls
    whenever ``n_lessees`` changes on a tranche; the cluster scheduler
    does this on every start/complete/preempt/shrink
    (``Scheduler.update_stalls``), and checkpoint *restores* are priced
    at the same contended per-lessee bandwidth
    (``Scheduler.restore_s``), not the uncontended tier rate.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Tuple

from repro.core.compose import CompositionError
from repro.core.topology import (DEFAULT_LINKS, LinkClass, LinkSpec,
                                 StorageSpec, partitioned_bw)

# NVMe constants (Intel SSDPEDKX040T7-class device, as in core.topology):
# 4 TB, ~3.2 GB/s sustained sequential read, ~1.9 GB/s sequential write.
NVME_CAPACITY = 4e12
NVME_READ_BW = 3.2e9
NVME_WRITE_BW = 1.9e9


@dataclasses.dataclass(frozen=True)
class StorageTranche:
    """One leasable slice of pooled NVMe."""
    name: str
    capacity_bytes: float = NVME_CAPACITY
    read_bw: float = NVME_READ_BW          # bytes/s sustained sequential
    write_bw: float = NVME_WRITE_BW
    attach: LinkClass = LinkClass.LOCAL    # fabric between device and hosts
    domain: int = 0                        # locality domain of the drawer

    def spec(self) -> StorageSpec:
        """The legacy single-tenant view (``FabricSpec.storage``)."""
        return StorageSpec(self.name, self.read_bw, self.attach)

    def effective_read_bw(self, links: Mapping[LinkClass, LinkSpec],
                          n_lessees: int = 1) -> float:
        """Per-lessee read bandwidth (see ``topology.partitioned_bw``)."""
        return partitioned_bw(self.read_bw, links[self.attach], n_lessees)

    def effective_write_bw(self, links: Mapping[LinkClass, LinkSpec],
                           n_lessees: int = 1) -> float:
        return partitioned_bw(self.write_bw, links[self.attach], n_lessees)


@dataclasses.dataclass(frozen=True)
class StorageLease:
    """One holder's claim on one tranche."""
    tranche: str
    holder: str
    capacity_bytes: float = 0.0
    exclusive: bool = False
    t_acquired: float = 0.0


class StoragePool:
    """Shared tranche inventory with per-tranche lessee accounting."""

    def __init__(self, tranches: List[StorageTranche],
                 links: Optional[Dict[LinkClass, LinkSpec]] = None):
        names = [t.name for t in tranches]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tranche names: {sorted(names)}")
        self.tranches: Dict[str, StorageTranche] = {t.name: t
                                                    for t in tranches}
        self.links = dict(links or DEFAULT_LINKS)
        # tranche -> holder -> lease (insertion-ordered: deterministic)
        self._leases: Dict[str, Dict[str, StorageLease]] = {
            t.name: {} for t in tranches}

    # ------------------------------------------------------------- claims --
    def lease(self, tranche: str, holder: str, *,
              capacity_bytes: float = 0.0, exclusive: bool = False,
              now: float = 0.0) -> StorageLease:
        """Attach ``holder`` to ``tranche``.

        Raises ``CompositionError`` on: unknown tranche, a double claim by
        the same holder (one job, one mount), an exclusive conflict in
        either direction, or capacity oversubscription.  Atomic: a raised
        claim leaves the pool untouched.
        """
        tr = self.tranches.get(tranche)
        if tr is None:
            raise CompositionError(
                f"unknown tranche {tranche!r}; pool has "
                f"{sorted(self.tranches)}")
        held = self._leases[tranche]
        if holder in held:
            raise CompositionError(
                f"holder {holder!r} already holds tranche {tranche!r} "
                "(storage leases don't stack)")
        if any(l.exclusive for l in held.values()):
            owner = next(h for h, l in held.items() if l.exclusive)
            raise CompositionError(
                f"tranche {tranche!r} is exclusively held by {owner!r}")
        if exclusive and held:
            raise CompositionError(
                f"exclusive claim on {tranche!r} conflicts with "
                f"{len(held)} existing lessee(s): {sorted(held)}")
        used = sum(l.capacity_bytes for l in held.values())
        if used + capacity_bytes > tr.capacity_bytes:
            raise CompositionError(
                f"tranche {tranche!r} capacity exceeded: "
                f"{(used + capacity_bytes) / 1e12:.2f} TB requested of "
                f"{tr.capacity_bytes / 1e12:.2f} TB")
        lease = StorageLease(tranche, holder, capacity_bytes, exclusive, now)
        held[holder] = lease
        return lease

    def release(self, holder: str) -> List[str]:
        """Release every tranche ``holder`` is attached to (idempotent);
        returns the tranche names freed."""
        freed = []
        for name, held in self._leases.items():
            if held.pop(holder, None) is not None:
                freed.append(name)
        return freed

    def release_tranche(self, holder: str, tranche: str) -> bool:
        """Release ``holder``'s claim on one tranche only — a live
        migrate detaches the old drawer while keeping the new lease it
        just took (``release`` would drop both).  Idempotent; returns
        whether a lease was actually dropped."""
        return self._leases[tranche].pop(holder, None) is not None

    # ------------------------------------------------------------ queries --
    def n_lessees(self, tranche: str) -> int:
        return len(self._leases[tranche])

    def lessees(self, tranche: str) -> Tuple[str, ...]:
        return tuple(self._leases[tranche])

    def tranches_of(self, holder: str) -> List[str]:
        return [name for name, held in self._leases.items()
                if holder in held]

    def capacity_used(self, tranche: str) -> float:
        return sum(l.capacity_bytes
                   for l in self._leases[tranche].values())

    def exclusively_held(self, tranche: str) -> bool:
        return any(l.exclusive for l in self._leases[tranche].values())

    def read_bw(self, tranche: str) -> float:
        """Current per-lessee read bandwidth under the live contention."""
        return self.tranches[tranche].effective_read_bw(
            self.links, max(1, self.n_lessees(tranche)))

    def write_bw(self, tranche: str) -> float:
        return self.tranches[tranche].effective_write_bw(
            self.links, max(1, self.n_lessees(tranche)))

    def by_attach(self, cls: LinkClass) -> List[StorageTranche]:
        return [t for t in self.tranches.values() if t.attach == cls]

    def check_invariants(self) -> None:
        """No holder twice on a tranche (structural), no oversubscription,
        no shared tenancy under an exclusive lease."""
        for name, held in self._leases.items():
            tr = self.tranches[name]
            used = sum(l.capacity_bytes for l in held.values())
            if used > tr.capacity_bytes:
                raise CompositionError(
                    f"tranche {name!r} oversubscribed: {used:.3g} > "
                    f"{tr.capacity_bytes:.3g}")
            if any(l.exclusive for l in held.values()) and len(held) > 1:
                raise CompositionError(
                    f"tranche {name!r} shared under an exclusive lease")

    def stats(self) -> Dict[str, Dict[str, object]]:
        return {
            name: {
                "attach": tr.attach.value,
                "n_lessees": self.n_lessees(name),
                "capacity_used_frac": (self.capacity_used(name)
                                       / max(tr.capacity_bytes, 1.0)),
                "per_lessee_read_bw": self.read_bw(name),
            }
            for name, tr in self.tranches.items()}


def make_storage_pool(n_local: int = 4, n_switch: int = 2, *,
                      domains: int = 2,
                      capacity_bytes: float = NVME_CAPACITY,
                      read_bw: float = NVME_READ_BW,
                      write_bw: float = NVME_WRITE_BW,
                      links: Optional[Dict[LinkClass, LinkSpec]] = None
                      ) -> StoragePool:
    """The production storage inventory: ``n_local`` host-local tranches
    spread round-robin over ``domains`` plus ``n_switch`` switch-attached
    (composable) tranches — mirroring ``core.topology.make_pool``."""
    tranches = [
        StorageTranche(f"local-nvme-{i}", capacity_bytes, read_bw, write_bw,
                       LinkClass.LOCAL, domain=i % max(domains, 1))
        for i in range(n_local)]
    tranches += [
        StorageTranche(f"falcon-nvme-{i}", capacity_bytes, read_bw,
                       write_bw, LinkClass.SWITCH,
                       domain=i % max(domains, 1))
        for i in range(n_switch)]
    return StoragePool(tranches, links)
