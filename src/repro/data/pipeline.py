"""Input pipeline: sharded synthetic batches + storage I/O workload model.

The paper's storage experiment (§V-3, Fig 15/16) varies where the NVMe
sits (local vs falcon-attached) and measures the effect on training step
time.  The pipeline here reproduces that apparatus:

  * ``SyntheticDataset``   — deterministic token batches (seeded per step
    and per data shard, so every host generates exactly its shard without
    coordination — the scalable pattern at 1000+ nodes).
  * ``IOWorkload``/``IOTraceGenerator`` — MLPerf-Storage (DLIO)-style
    I/O description and trace: per-sample record-size distributions,
    per-epoch shuffled reads, and periodic checkpoint write bursts, so
    storage is priced against what a training job actually reads rather
    than a flat bytes-per-sample constant.
  * ``StorageModel``       — prices reads/writes against a storage tier
    (``StorageSpec``: bandwidth + attach fabric), with the tranche's
    bandwidth partitioned across concurrent lessees (see
    ``repro.data.storage``) so co-located tenants contend exactly like
    Fig 15's shared falcon drawer.
  * ``Prefetcher``         — double-buffering: the read of batch t+1
    overlaps the compute of batch t; effective input stall =
    max(0, read_time - step_time), the standard overlap law the paper's
    localNVMe/falconNVMe deltas follow.
  * straggler duplication  — see train/elastic.py StragglerPolicy.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.topology import (DEFAULT_LINKS, LinkClass, StorageSpec,
                                 partitioned_bw)


# ---------------------------------------------------------------------------
# synthetic data
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SyntheticDataset:
    """Deterministic LM batches: tokens ~ Zipf-ish over the vocab."""
    cfg: ModelConfig
    shape: ShapeConfig
    seed: int = 0

    def batch_at(self, step: int, *, shard: int = 0, n_shards: int = 1
                 ) -> Dict[str, np.ndarray]:
        """The (shard)th slice of the global batch for ``step``."""
        B = self.shape.global_batch // n_shards
        S = self.shape.seq_len if self.shape.kind == "train" else \
            self.shape.seq_len
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, shard]))
        V = self.cfg.vocab_size
        # zipf-flavoured ids (clipped); cheap and stationary
        raw = rng.zipf(1.3, size=(B, S + 1))
        toks = np.minimum(raw - 1, V - 1).astype(np.int32)
        if self.cfg.input_mode == "embeddings":
            x = rng.standard_normal(
                (B, S, self.cfg.d_model)).astype(np.float32)
            return {"inputs": x, "labels": toks[:, 1:S + 1]}
        return {"inputs": toks[:, :S], "labels": toks[:, 1:S + 1]}

    def batch_bytes(self) -> int:
        B, S = self.shape.global_batch, self.shape.seq_len
        if self.cfg.input_mode == "embeddings":
            return B * S * self.cfg.d_model * 4 + B * S * 4
        return B * (S + 1) * 4


# ---------------------------------------------------------------------------
# MLPerf-Storage-style I/O workloads (the DLIO workload-config shape)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class IOWorkload:
    """DLIO-style I/O description of one training workload.

    ``record_bytes``/``record_stdev`` mirror DLIO's
    ``record_length_bytes``(+``_stdev``): per-sample sizes are drawn once
    from a clipped normal and are a fixed property of the dataset;
    per-epoch shuffling reorders which sizes each step reads.
    ``checkpoint_bytes`` every ``checkpoint_every`` steps models the
    paper's Fig-9 checkpoint dips as periodic write bursts.
    """
    name: str
    record_bytes: float                  # mean bytes per sample record
    record_stdev: float = 0.0
    batch_size: int = 1                  # samples read per step (global)
    samples_per_epoch: int = 1024
    checkpoint_bytes: float = 0.0
    checkpoint_every: int = 0            # steps between bursts; 0 = never

    @property
    def steps_per_epoch(self) -> int:
        return max(1, self.samples_per_epoch // max(self.batch_size, 1))

    def mean_step_read_bytes(self) -> float:
        return self.batch_size * self.record_bytes

    def mean_step_write_bytes(self) -> float:
        if self.checkpoint_every <= 0:
            return 0.0
        return self.checkpoint_bytes / self.checkpoint_every

    def dataset_bytes(self) -> float:
        return self.samples_per_epoch * self.record_bytes


# The paper's five benchmarks as I/O workloads (replaces the former flat
# SAMPLE_BYTES dict).  Record stats: ImageNet JPEG ~110KB (long-tailed;
# stdev ~40KB), COCO 640px ~300KB +- 120KB, tokenized SQuAD ~6KB +- 1KB.
# Batch sizes are the paper's §V-C-1 points; checkpoints are one fp32
# model snapshot per epoch (DLIO's epochs_between_checkpoints=1).
def _paper_io(name: str, rec: float, stdev: float, batch: int,
              samples: int, params: float) -> IOWorkload:
    steps = max(1, samples // batch)
    return IOWorkload(name, rec, stdev, batch, samples,
                      checkpoint_bytes=params * 4.0,
                      checkpoint_every=steps)


IO_WORKLOADS: Dict[str, IOWorkload] = {
    w.name: w for w in (
        _paper_io("mobilenetv2", 110e3, 40e3, 64, 1_281_167, 3.4e6),
        _paper_io("resnet50", 110e3, 40e3, 128, 1_281_167, 25.6e6),
        _paper_io("yolov5l", 300e3, 120e3, 88, 118_287, 47e6),
        _paper_io("bert-base", 6e3, 1e3, 96, 88_524, 110e6),
        _paper_io("bert-large", 6e3, 1e3, 48, 88_524, 340e6),
    )}


def lm_io_workload(cfg: ModelConfig, shape: ShapeConfig, *,
                   samples_per_epoch: int = 1 << 20,
                   checkpoint_every: int = 50) -> IOWorkload:
    """The I/O shape of one LM job from the ``configs/`` registry.

    Tokenized records are fixed-size (stdev 0); embedding-mode archs read
    precomputed patch/frame embeddings.  Serving shapes read per-token
    (decode) or per-prompt (prefill) — no dataset sweep, no checkpoints.
    """
    S = shape.seq_len
    if shape.kind == "decode":
        rec = 4.0                        # one token id per seq per step
    elif cfg.input_mode == "embeddings":
        rec = S * cfg.d_model * 4.0 + S * 4.0
    else:
        rec = (S + 1) * 4.0
    train = shape.kind == "train"
    return IOWorkload(
        f"{cfg.name}/{shape.name}", rec, 0.0, shape.global_batch,
        samples_per_epoch,
        checkpoint_bytes=cfg.param_count() * 4.0 if train else 0.0,
        checkpoint_every=checkpoint_every if train else 0)


class IOTraceGenerator:
    """Deterministic MLPerf-Storage-style I/O trace for one workload.

    Per-sample record sizes are drawn once (clipped normal, fixed by
    ``seed``); every epoch reads the whole dataset in a fresh shuffled
    order (``file_shuffle: seed`` semantics), so the same seed yields a
    bit-identical trace and different epochs reorder the same sizes.
    """

    _MIN_FRAC = 0.05                     # record floor (DLIO resize)

    def __init__(self, workload: IOWorkload, seed: int = 0):
        self.w = workload
        self.seed = seed
        self._sizes: Optional[np.ndarray] = None
        self._epoch: Optional[int] = None
        self._order: Optional[np.ndarray] = None

    # ------------------------------------------------------------ dataset --
    def record_sizes(self) -> np.ndarray:
        """(samples_per_epoch,) bytes per sample — a dataset property."""
        if self._sizes is None:
            w = self.w
            rng = np.random.default_rng(
                np.random.SeedSequence([self.seed, 0xB17E5]))
            if w.record_stdev > 0:
                raw = rng.normal(w.record_bytes, w.record_stdev,
                                 size=w.samples_per_epoch)
                self._sizes = np.maximum(raw,
                                         w.record_bytes * self._MIN_FRAC)
            else:
                self._sizes = np.full(w.samples_per_epoch,
                                      float(w.record_bytes))
        return self._sizes

    def epoch_order(self, epoch: int) -> np.ndarray:
        """Shuffled sample ids for ``epoch`` (cached for the last epoch)."""
        if self._epoch != epoch:
            rng = np.random.default_rng(
                np.random.SeedSequence([self.seed, 1 + epoch]))
            self._order = rng.permutation(self.w.samples_per_epoch)
            self._epoch = epoch
        return self._order

    # -------------------------------------------------------------- trace --
    def step_read_bytes(self, step: int) -> float:
        """Bytes the global batch reads at ``step`` (shuffled-epoch)."""
        w = self.w
        spe = w.steps_per_epoch
        order = self.epoch_order(step // spe)
        i = (step % spe) * w.batch_size
        ids = order[i:i + w.batch_size]
        return float(self.record_sizes()[ids].sum())

    def step_write_bytes(self, step: int) -> float:
        """Checkpoint burst bytes written *at the end of* ``step``."""
        w = self.w
        if w.checkpoint_every > 0 and (step + 1) % w.checkpoint_every == 0:
            return float(w.checkpoint_bytes)
        return 0.0

    def read_trace(self, n_steps: int, start: int = 0) -> np.ndarray:
        return np.asarray([self.step_read_bytes(start + t)
                           for t in range(n_steps)])


# ---------------------------------------------------------------------------
# storage tier pricing (the Fig-15 instrument)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class StorageModel:
    """Prices reads/writes against one storage tier.

    ``n_lessees`` > 1 partitions the tier's bandwidth equally across
    co-located tenants (the tranche-sharing model of
    ``repro.data.storage``); the default of 1 is the legacy
    single-tenant behaviour.
    """
    tier: StorageSpec
    links: Dict[LinkClass, Any] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_LINKS))
    n_lessees: int = 1
    write_bw: float = 1.9e9              # NVMe-class sequential write

    @classmethod
    def for_tranche(cls, pool, tranche: str) -> "StorageModel":
        """Bound to a ``StoragePool`` tranche under its live contention."""
        tr = pool.tranches[tranche]
        return cls(tr.spec(), dict(pool.links),
                   max(1, pool.n_lessees(tranche)), tr.write_bw)

    def read_time(self, nbytes: float) -> float:
        link = self.links[self.tier.attach]
        bw = partitioned_bw(self.tier.read_bw, link, self.n_lessees)
        return nbytes / bw + link.latency

    def write_time(self, nbytes: float) -> float:
        if nbytes <= 0:
            return 0.0
        link = self.links[self.tier.attach]
        bw = partitioned_bw(self.write_bw, link, self.n_lessees)
        return nbytes / bw + link.latency


def input_stall(read_s: float, step_s: float, *, prefetch: int = 2) -> float:
    """Per-step input stall with ``prefetch``-deep double buffering."""
    if prefetch >= 1:
        return max(0.0, read_s - step_s)
    return read_s


def workload_stall(io: IOWorkload, model: StorageModel, step_s: float, *,
                   prefetch: int = 2) -> float:
    """Expected per-step stall of ``io`` on ``model``'s (possibly
    contended) tier: prefetch-overlapped reads plus amortized checkpoint
    write bursts (writes block the step — the paper's Fig-9 dips)."""
    stall = input_stall(model.read_time(io.mean_step_read_bytes()), step_s,
                        prefetch=prefetch)
    if io.checkpoint_every > 0:
        stall += model.write_time(io.checkpoint_bytes) / io.checkpoint_every
    return stall


# ---------------------------------------------------------------------------
# host-side prefetcher (CPU-simulated; deterministic)
# ---------------------------------------------------------------------------
class Prefetcher:
    """Synchronous double-buffer: ``next()`` returns batch t while batch
    t+1 is 'in flight' (flight time tracked analytically, not slept)."""

    def __init__(self, ds: SyntheticDataset, storage: StorageModel, *,
                 shard: int = 0, n_shards: int = 1, depth: int = 2):
        self.ds = ds
        self.storage = storage
        self.shard = shard
        self.n_shards = n_shards
        self.depth = depth
        self._step = 0
        self._read_s = storage.read_time(ds.batch_bytes() / n_shards)

    @property
    def read_time_s(self) -> float:
        return self._read_s

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        b = self.ds.batch_at(self._step, shard=self.shard,
                             n_shards=self.n_shards)
        self._step += 1
        return b


def make_batch(cfg: ModelConfig, shape: ShapeConfig, *, step: int = 0,
               seed: int = 0) -> Dict[str, jnp.ndarray]:
    """One full global batch as jnp arrays (train/prefill kinds)."""
    ds = SyntheticDataset(cfg, shape, seed)
    return {k: jnp.asarray(v) for k, v in ds.batch_at(step).items()}
