"""Input pipeline: sharded synthetic batches + storage-tier timing model.

The paper's storage experiment (§V-3, Fig 15/16) varies where the NVMe
sits (local vs falcon-attached) and measures the effect on training step
time.  The pipeline here reproduces that apparatus:

  * ``SyntheticDataset``   — deterministic token batches (seeded per step
    and per data shard, so every host generates exactly its shard without
    coordination — the scalable pattern at 1000+ nodes).
  * ``StorageModel``       — prices each batch read against a storage tier
    (``StorageSpec``: bandwidth + attach fabric) so benchmarks can compare
    local vs composed NVMe exactly like Fig 15.
  * ``Prefetcher``         — double-buffering: the read of batch t+1
    overlaps the compute of batch t; effective input stall =
    max(0, read_time - step_time), the standard overlap law the paper's
    localNVMe/falconNVMe deltas follow.
  * straggler duplication  — see train/elastic.py StragglerPolicy.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.topology import StorageSpec, LinkClass, DEFAULT_LINKS


# ---------------------------------------------------------------------------
# synthetic data
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SyntheticDataset:
    """Deterministic LM batches: tokens ~ Zipf-ish over the vocab."""
    cfg: ModelConfig
    shape: ShapeConfig
    seed: int = 0

    def batch_at(self, step: int, *, shard: int = 0, n_shards: int = 1
                 ) -> Dict[str, np.ndarray]:
        """The (shard)th slice of the global batch for ``step``."""
        B = self.shape.global_batch // n_shards
        S = self.shape.seq_len if self.shape.kind == "train" else \
            self.shape.seq_len
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, shard]))
        V = self.cfg.vocab_size
        # zipf-flavoured ids (clipped); cheap and stationary
        raw = rng.zipf(1.3, size=(B, S + 1))
        toks = np.minimum(raw - 1, V - 1).astype(np.int32)
        if self.cfg.input_mode == "embeddings":
            x = rng.standard_normal(
                (B, S, self.cfg.d_model)).astype(np.float32)
            return {"inputs": x, "labels": toks[:, 1:S + 1]}
        return {"inputs": toks[:, :S], "labels": toks[:, 1:S + 1]}

    def batch_bytes(self) -> int:
        B, S = self.shape.global_batch, self.shape.seq_len
        if self.cfg.input_mode == "embeddings":
            return B * S * self.cfg.d_model * 4 + B * S * 4
        return B * (S + 1) * 4


# ---------------------------------------------------------------------------
# storage tier pricing (the Fig-15 instrument)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class StorageModel:
    tier: StorageSpec
    links: Dict[LinkClass, Any] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_LINKS))

    def read_time(self, nbytes: float) -> float:
        bw = self.tier.effective_read_bw(self.links)
        return nbytes / bw + self.links[self.tier.attach].latency


def input_stall(read_s: float, step_s: float, *, prefetch: int = 2) -> float:
    """Per-step input stall with ``prefetch``-deep double buffering."""
    if prefetch >= 1:
        return max(0.0, read_s - step_s)
    return read_s


# ---------------------------------------------------------------------------
# host-side prefetcher (CPU-simulated; deterministic)
# ---------------------------------------------------------------------------
class Prefetcher:
    """Synchronous double-buffer: ``next()`` returns batch t while batch
    t+1 is 'in flight' (flight time tracked analytically, not slept)."""

    def __init__(self, ds: SyntheticDataset, storage: StorageModel, *,
                 shard: int = 0, n_shards: int = 1, depth: int = 2):
        self.ds = ds
        self.storage = storage
        self.shard = shard
        self.n_shards = n_shards
        self.depth = depth
        self._step = 0
        self._read_s = storage.read_time(ds.batch_bytes() / n_shards)

    @property
    def read_time_s(self) -> float:
        return self._read_s

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        b = self.ds.batch_at(self._step, shard=self.shard,
                             n_shards=self.n_shards)
        self._step += 1
        return b


def make_batch(cfg: ModelConfig, shape: ShapeConfig, *, step: int = 0,
               seed: int = 0) -> Dict[str, jnp.ndarray]:
    """One full global batch as jnp arrays (train/prefill kinds)."""
    ds = SyntheticDataset(cfg, shape, seed)
    return {k: jnp.asarray(v) for k, v in ds.batch_at(step).items()}
