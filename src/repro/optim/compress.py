"""Int8 error-feedback gradient compression (beyond-paper optimization).

The paper mitigates the slow composed fabric with mixed precision and ZeRO
(§V-4).  The next rung on the same ladder — not available in its 2021 stack
— is lossy gradient compression with error feedback (1-bit Adam / PowerSGD
family).  We implement the simplest robust member: symmetric per-tensor
int8 with a globally-agreed scale and local error carry, applied only to
the *slow* (cross-pod) hop where bandwidth is 8x scarcer.

Error feedback guarantees the quantization error is re-injected next step,
so the compression is unbiased over time (Karimireddy et al., 2019).
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp


def int8_encode(x: jnp.ndarray,
                global_max: Callable[[jnp.ndarray], jnp.ndarray] = lambda m: m
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Quantize fp32 -> int8 against a (collectively agreed) scale.

    ``global_max``: hook to maximize the scale across participants (pmax
    over the reduction axis) so every rank uses the same grid.
    """
    m = jnp.max(jnp.abs(x))
    m = global_max(m)
    scale = jnp.maximum(m, 1e-30) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decode(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def ef_compress_leaf(g: jnp.ndarray, residual: jnp.ndarray,
                     global_max=lambda m: m
                     ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """(grad, residual) -> (int8 payload, scale, new residual)."""
    y = g.astype(jnp.float32) + residual
    q, scale = int8_encode(y, global_max)
    new_r = y - int8_decode(q, scale)
    return q, scale, new_r


def compression_ratio(dtype=jnp.float32) -> float:
    """Wire-byte ratio of int8 vs the uncompressed dtype."""
    return jnp.dtype(dtype).itemsize / 1.0
