"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ScheduleConfig:
    kind: str = "cosine"            # cosine | linear | constant
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_ratio: float = 0.1


def lr_at(step, cfg: ScheduleConfig):
    s = jnp.asarray(step, jnp.float32)
    warm = cfg.peak_lr * jnp.minimum(1.0, s / jnp.maximum(cfg.warmup_steps, 1))
    if cfg.kind == "constant":
        return warm
    frac = jnp.clip((s - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    if cfg.kind == "linear":
        decay = 1.0 - (1.0 - cfg.min_ratio) * frac
    else:  # cosine
        decay = cfg.min_ratio + (1.0 - cfg.min_ratio) * 0.5 * (
            1.0 + jnp.cos(jnp.pi * frac))
    return jnp.where(s < cfg.warmup_steps, warm, cfg.peak_lr * decay)
