"""AdamW with decoupled weight decay — functional, pytree-native.

Kept deliberately framework-free (no optax dependency): the optimizer state
is a plain pytree so ZeRO sharding is just a PartitionSpec on each moment
(see ``repro.core.policy.opt_state_specs``) and checkpointing is the same
code path as parameters.

Moments are stored in ``accum_dtype`` (fp32 default).  When
``param_dtype=float32`` and ``compute_dtype=bfloat16`` this is exactly the
paper's mixed-precision recipe: bf16 compute, fp32 master weights + states.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0          # global-norm clip; 0 disables
    accum_dtype: Any = jnp.float32


class AdamWState(NamedTuple):
    step: jnp.ndarray               # () int32
    m: Any                          # pytree like params
    v: Any
    master: Any = None              # fp32 master weights (bf16-param mode)


def init(params: Any, cfg: AdamWConfig = AdamWConfig(), *,
         master_weights: bool = False) -> AdamWState:
    """``master_weights=True`` keeps fp32 masters in the (ZeRO-sharded)
    optimizer state so params can live in bf16 — halving gradient
    reductions and ZeRO-3 parameter gathers on the wire (true
    mixed-precision, the paper's §V-4 'mixed' rung done properly)."""
    zeros = lambda p: jnp.zeros(p.shape, cfg.accum_dtype)
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params) \
        if master_weights else None
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params),
                      master=master)


def global_norm(tree: Any) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads: Any, max_norm: float
                        ) -> Tuple[Any, jnp.ndarray]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def _decay_mask(path) -> bool:
    """Decay applies to >=2D weights only (no norms/biases/scalars)."""
    return True


def apply(params: Any, grads: Any, state: AdamWState,
          cfg: AdamWConfig = AdamWConfig(), *,
          lr: Optional[jnp.ndarray] = None
          ) -> Tuple[Any, AdamWState, Dict[str, jnp.ndarray]]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    if cfg.grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    lr_t = cfg.lr if lr is None else lr
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, w32):
        g32 = g.astype(cfg.accum_dtype)
        m_new = cfg.b1 * m + (1 - cfg.b1) * g32
        v_new = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        mhat = m_new / b1c
        vhat = v_new / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        base = w32 if w32 is not None else p.astype(cfg.accum_dtype)
        decay = cfg.weight_decay * base if p.ndim >= 2 else 0.0
        w_new = base - lr_t * (delta + decay)
        return w_new.astype(p.dtype), m_new, v_new, w_new

    if state.master is None:
        out = jax.tree.map(lambda p, g, m, v: upd(p, g, m, v, None),
                           params, grads, state.m, state.v)
    else:
        out = jax.tree.map(upd, params, grads, state.m, state.v,
                           state.master)
    pick = lambda i: jax.tree.map(lambda t: t[i], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
    new_params, new_m, new_v = pick(0), pick(1), pick(2)
    new_master = pick(3) if state.master is not None else None
    return new_params, AdamWState(step, new_m, new_v, new_master), {
        "grad_norm": gnorm, "lr": jnp.asarray(lr_t, jnp.float32)}
