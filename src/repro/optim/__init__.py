"""Optimizers + distributed-optimization tricks (ZeRO sharding lives in
``repro.core.policy`` as PartitionSpecs; compression in ``compress``)."""
from repro.optim.adamw import (AdamWConfig, AdamWState, apply,  # noqa: F401
                               clip_by_global_norm, global_norm, init)
from repro.optim.schedule import ScheduleConfig, lr_at  # noqa: F401
