"""Version compatibility shims for the jax API surface.

The model/trainer code targets the modern ``jax.shard_map`` entry point
(``mesh=None`` for the ambient mesh, ``axis_names`` for the manual set,
``check_vma``).  Older jax releases only ship
``jax.experimental.shard_map.shard_map(f, mesh, in_specs, out_specs,
check_rep, auto)``; this module bridges the two so the same source runs
on both.
"""
from __future__ import annotations

import jax

_HAS_NEW = hasattr(jax, "shard_map")
if not _HAS_NEW:
    from jax.experimental.shard_map import shard_map as _legacy_shard_map


def shard_map(f, mesh=None, *, in_specs, out_specs,
              axis_names=frozenset(), check_vma=True):
    """``jax.shard_map`` with graceful fallback to the experimental API.

    On the legacy API ``axis_names`` maps to its complement (``auto``)
    and ``check_vma`` to ``check_rep``.  The ``mesh=None`` ambient-mesh
    form requires the modern API (callers only use it when re-entering
    an already-manual region, which the legacy API cannot express).
    """
    if _HAS_NEW:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=axis_names,
                             check_vma=check_vma)
    if mesh is None:
        raise NotImplementedError(
            "ambient-mesh shard_map (mesh=None) requires jax.shard_map; "
            "this jax only has the experimental API")
    auto = frozenset(mesh.axis_names) - frozenset(axis_names) \
        if axis_names else frozenset()
    return _legacy_shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=check_vma,
                             auto=auto)
