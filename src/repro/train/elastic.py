"""Elastic execution: failure -> recompose -> restore -> continue.

This is the composable system's operational payoff (paper §II-C "devices
can be allocated and re-allocated dynamically"): when devices fail, the
pool is re-composed into a smaller (or re-fabric'd) system and training
resumes from the latest atomic checkpoint — parameters reshard on restore,
so no part of the job is tied to the dead composition.

Straggler mitigation: the data pipeline re-issues a shard when a simulated
host exceeds the straggler deadline (tail-latency duplication, the standard
mitigation at pod scale); the cost model prices stragglers through the
per-axis latency term.  Both are exercised by tests/test_elastic.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.compose import ComposedSystem, CompositionError, recompose, \
    shrink_to_pool
from repro.core.topology import DevicePool
from repro.train import checkpoint


@dataclasses.dataclass
class ElasticEvent:
    step: int
    kind: str                      # "failure" | "recompose" | "restore"
    detail: str = ""


@dataclasses.dataclass
class ElasticRun:
    """Bookkeeping for one elastic training run."""
    system: ComposedSystem
    ckpt_dir: str
    events: List[ElasticEvent] = dataclasses.field(default_factory=list)

    def log(self, step: int, kind: str, detail: str = "") -> None:
        self.events.append(ElasticEvent(step, kind, detail))


def handle_failure(run: ElasticRun, pool: DevicePool,
                   failed_uids: Sequence[int], *, step: int,
                   shrink_axis: str = "data") -> ComposedSystem:
    """Mark devices failed, recompose (shrinking ``shrink_axis`` if the
    pool no longer covers the old shape), and return the new system.

    The caller then rebuilds mesh + jitted step for the new system and
    restores the latest checkpoint under the new sharding.
    """
    pool.mark_failed(failed_uids)
    run.log(step, "failure", f"uids={list(failed_uids)}")
    try:
        new_sys = recompose(pool, run.system)
        detail = "same-shape recompose (spare devices)"
    except CompositionError:
        new_sys = shrink_to_pool(pool, run.system, shrink_axis)
        detail = (f"shrunk {shrink_axis}: "
                  f"{dict(zip(new_sys.axis_names, new_sys.axis_sizes))}")
    run.log(step, "recompose", detail)
    run.system = new_sys
    return new_sys


def preempt(run: ElasticRun, pool: DevicePool, *, step: int,
            detail: str = "") -> None:
    """Give the composition back to the pool (job preempted / unschedulable).

    When even a 1-wide mesh no longer fits the pool, the job's devices
    must return to the shared inventory so other tenants can claim them;
    the job itself re-queues and later resumes from its checkpoint via
    the normal ``recompose -> restore`` path.
    """
    pool.release(run.system.device_uids)
    run.log(step, "preempt", detail or "released composition to pool")


def regrow(run: ElasticRun, new_system: ComposedSystem, *, step: int,
           detail: str = "") -> ComposedSystem:
    """Adopt a larger recomposed system after a repair returned capacity.

    The inverse of the ``handle_failure`` shrink: the cluster scheduler
    recomposes a failure-shrunk job back toward its submitted budget
    (``Scheduler.regrow_shrunk``) and the run resumes from its last
    checkpoint boundary under the wider sharding.
    """
    run.system = new_system
    run.log(step, "recompose",
            detail or (f"regrow after repair: "
                       f"{dict(zip(new_system.axis_names, new_system.axis_sizes))}"))
    return new_system


def resume(run: ElasticRun, like_state: Any, mesh, specs) -> Tuple[Any, int]:
    """Restore the latest checkpoint onto the (possibly new) mesh."""
    state, step = checkpoint.restore(run.ckpt_dir, like_state, mesh=mesh,
                                     specs=specs)
    run.log(step, "restore", f"onto {dict(mesh.shape)}")
    return state, step


# ---------------------------------------------------------------------------
# straggler mitigation (data-path duplication)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class StragglerPolicy:
    """Duplicate a shard read when it exceeds ``deadline_factor`` x median.

    At 1000+ nodes the slowest host dominates step time; issuing a backup
    read after the deadline caps the tail at ~2x the median read.  The
    pipeline consults ``should_duplicate`` per shard; see data/pipeline.py.
    """
    deadline_factor: float = 2.0
    max_duplicates: int = 1

    def should_duplicate(self, elapsed: float, median: float,
                         already: int) -> bool:
        return (already < self.max_duplicates
                and elapsed > self.deadline_factor * max(median, 1e-9))

    def expected_tail_time(self, median: float, p999: float) -> float:
        """Tail-read completion bound under duplication."""
        return min(p999, self.deadline_factor * median + median)
