from repro.train.trainer import (TrainState, init_state,  # noqa: F401
                                 jit_train_step, make_loss_fn, make_run_ctx,
                                 make_train_step, state_specs)
