"""Fault-tolerant checkpointing: atomic, resharding-on-restore.

Requirements at 1000+ nodes (system prompt) rendered here:

  * **Atomicity** — write to ``<dir>/tmp.<step>``, fsync, then rename to
    ``<dir>/step_<n>``; a crash mid-write never corrupts the latest
    checkpoint.  A ``DONE`` marker file guards partially-renamed dirs.
  * **Resharding restore** — checkpoints store *logical* (unsharded)
    arrays; ``restore(..., mesh, specs)`` device_puts each array under the
    new mesh/specs, so a job restarted on a *different composition* (fewer
    pods, swapped fabric — the elastic path) loads the same checkpoint.
  * **GC** — keep the newest ``keep`` checkpoints.

Storage format: one ``.npz`` per pytree (flattened paths -> arrays) — no
external deps, portable, testable.  A production deployment would swap the
file driver for a distributed object store; the interface (save/restore/
latest_step) is what the rest of the framework depends on.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
from typing import Any, Dict, List, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _treedef_of(tree: Any):
    return jax.tree_util.tree_structure(tree)


def save(ckpt_dir: str, step: int, tree: Any, *, keep: int = 3,
         extra: Optional[Mapping[str, Any]] = None) -> str:
    """Atomically persist ``tree`` (gathered to host) as step ``step``."""
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    tmp = tempfile.mkdtemp(prefix=f"tmp.{step}.", dir=ckpt_dir)
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        meta = {"step": int(step), "keys": sorted(flat)}
        if extra:
            meta["extra"] = dict(extra)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        with open(os.path.join(tmp, "DONE"), "w") as f:
            f.write("ok")
            f.flush()
            os.fsync(f.fileno())
        final = os.path.join(ckpt_dir, f"step_{step:010d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = all_steps(ckpt_dir)
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:010d}"),
                      ignore_errors=True)
    # sweep orphaned tmp dirs from crashed writers
    for name in os.listdir(ckpt_dir):
        if name.startswith("tmp."):
            shutil.rmtree(os.path.join(ckpt_dir, name), ignore_errors=True)


def all_steps(ckpt_dir: str) -> List[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = re.match(r"step_(\d+)$", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "DONE")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, like: Any, *, step: Optional[int] = None,
            mesh=None, specs: Any = None) -> Tuple[Any, int]:
    """Load a checkpoint into the structure of ``like``.

    ``mesh``/``specs``: optional target sharding — each restored array is
    device_put under ``NamedSharding(mesh, spec)``, which is what makes
    restore-onto-a-different-composition (elastic recovery) work.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:010d}")
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    spec_leaves = (jax.tree.leaves(
        specs, is_leaf=lambda s: s is None or hasattr(s, "_asdict")
        or isinstance(s, jax.sharding.PartitionSpec))
        if specs is not None else [None] * len(leaves_like))
    if specs is not None and len(spec_leaves) != len(leaves_like):
        spec_leaves = [None] * len(leaves_like)
    out = []
    for i, (pth, leaf) in enumerate(leaves_like):
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in pth)
        arr = data[key]
        if arr.shape != tuple(leaf.shape):
            raise ValueError(
                f"checkpoint leaf {key}: shape {arr.shape} != {leaf.shape}")
        if mesh is not None and spec_leaves[i] is not None:
            sh = jax.sharding.NamedSharding(mesh, spec_leaves[i])
            out.append(jax.device_put(jnp.asarray(arr, leaf.dtype), sh))
        else:
            out.append(jnp.asarray(arr, leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out), step


def meta(ckpt_dir: str, step: int) -> Dict[str, Any]:
    with open(os.path.join(ckpt_dir, f"step_{step:010d}", "meta.json")) as f:
        return json.load(f)
