"""Training step builder: model + policy -> jit-able, shardable train_step.

The step builder realizes the paper's §V-4 software ladder plus the
beyond-paper rungs:

  * zero_stage=0, fp32            -> "DP"    (params+states replicated)
  * zero_stage=0, hierarchical    -> "DDP"   (overlappable bucketed reduce)
  * compute_dtype=bf16            -> "mixed precision"
  * zero_stage=1/3                -> "sharded training" (ZeRO)
  * grad_compression="int8_ef"    -> int8 EF on the slow pod axis
  * grad_accum>1                  -> microbatch scan (memory headroom)

All distribution is expressed as PartitionSpecs (from ``core.policy``) on a
single jit program; the only explicit ``shard_map`` is the optional
manual-pod gradient exchange (hierarchical/compressed), with every other
axis left on GSPMD auto sharding.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, PolicyConfig, ShapeConfig
from repro.core import hierarchy, policy as pol
from repro.models import lm
from repro.models.transformer import ParallelCtx, RunCtx
from repro.optim import adamw, schedule
from repro.jaxcompat import shard_map


# ---------------------------------------------------------------------------
# state
# ---------------------------------------------------------------------------
class TrainState:
    """Plain pytree container: params + optimizer state (+ EF residual)."""

    def __init__(self, params, opt, ef_residual=None):
        self.params = params
        self.opt = opt
        self.ef_residual = ef_residual

    def tree_flatten(self):
        return (self.params, self.opt, self.ef_residual), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    TrainState, TrainState.tree_flatten, TrainState.tree_unflatten)


def _dt(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


def resolve_attn_blocks(cfg: ModelConfig, policy: PolicyConfig,
                        seq_len: Optional[int], *,
                        decode: bool = False,
                        batch: Optional[int] = None) -> Tuple[int, int]:
    """Shape-keyed tuned-config lookup for the step builders' attention
    tiles (the XLA flash path): measured (q_block, kv_block) when the
    registry has the bucket, the historical (512, 512) otherwise.

    ``decode=True`` keys the (B, 1, cache_len) decode shape instead of
    the square prefill shape — ``seq_len`` is then the cache length and
    ``batch`` the decode batch bucket — so serving decode steps resolve
    their own tuned cells rather than borrowing prefill tiles."""
    from repro.kernels import registry as kreg
    if not seq_len:
        return RunCtx.attn_blocks        # class default — no shape known
    g = max(1, cfg.n_heads // max(cfg.n_kv_heads, 1))
    if decode:
        return kreg.decode_attention_blocks(
            batch or 1, seq_len, cfg.head_dim, g,
            _dt(policy.compute_dtype), cfg.causal, 0,
            defaults=(1, RunCtx.attn_blocks[1]))
    return kreg.attention_blocks(
        seq_len, seq_len, cfg.head_dim, g,
        _dt(policy.compute_dtype), cfg.causal, 0,
        defaults=RunCtx.attn_blocks, kernel="flash_attention_xla")


def make_run_ctx(cfg: ModelConfig, policy: PolicyConfig,
                 mesh=None, *, seq_len: Optional[int] = None,
                 decode: bool = False,
                 batch: Optional[int] = None) -> RunCtx:
    moe_impl = "sorted"
    if (cfg.moe is not None and policy.ep and mesh is not None
            and policy.tp_axis in getattr(mesh, "shape", {})
            and mesh.shape[policy.tp_axis] > 1
            and cfg.moe.n_experts % mesh.shape[policy.tp_axis] == 0):
        moe_impl = "ep"
    return RunCtx(
        compute_dtype=_dt(policy.compute_dtype),
        attn_impl=policy.attn_impl,
        attn_blocks=resolve_attn_blocks(cfg, policy, seq_len,
                                        decode=decode, batch=batch),
        moe_impl=moe_impl,
        remat=policy.remat,
        pctx=ParallelCtx(mesh=mesh, dp_axes=policy.dp_axes,
                         tp_axis=policy.tp_axis,
                         fsdp_experts=(policy.zero_stage >= 3)),
    )


def init_state(key, cfg: ModelConfig, policy: PolicyConfig,
               optcfg: adamw.AdamWConfig, *, n_pods: int = 1) -> TrainState:
    params = lm.init_lm(key, cfg, dtype=_dt(policy.param_dtype))
    opt = adamw.init(params, optcfg,
                     master_weights=(policy.param_dtype == "bfloat16"))
    ef = None
    if policy.grad_compression == "int8_ef" and n_pods > 1:
        ef = jax.tree.map(
            lambda p: jnp.zeros((n_pods,) + p.shape, jnp.float32), params)
    return TrainState(params, opt, ef)


def state_specs(state: TrainState, cfg: ModelConfig, policy: PolicyConfig,
                mesh_axes: Mapping[str, int]) -> TrainState:
    """PartitionSpecs for a TrainState (params, adam moments, residual)."""
    pspec = pol.param_specs(state.params, cfg, policy, mesh_axes)
    mspec = pol.opt_state_specs(state.params, cfg, policy, mesh_axes)
    opt_spec = adamw.AdamWState(
        step=P(), m=mspec, v=mspec,
        master=(mspec if state.opt.master is not None else None))
    ef_spec = None
    if state.ef_residual is not None:
        ef_spec = jax.tree.map(
            lambda s: P(*(("pod",) + tuple(s))), mspec)
    return TrainState(pspec, opt_spec, ef_spec)


# ---------------------------------------------------------------------------
# loss / grads
# ---------------------------------------------------------------------------
def make_loss_fn(cfg: ModelConfig, policy: PolicyConfig, mesh=None,
                 seq_len: Optional[int] = None) -> Callable:
    ctx = make_run_ctx(cfg, policy, mesh, seq_len=seq_len)
    big_vocab = cfg.padded_vocab >= 32_768

    def loss_fn(params, batch):
        chunk = 0
        if big_vocab:
            S = batch["labels"].shape[1]
            for c in (512, 256, 128, 64, 1):
                if S % c == 0:
                    chunk = c
                    break
        return lm.lm_loss(params, batch, cfg, ctx, xent_chunk=chunk)

    return loss_fn


def _accum_grads(loss_fn, params, batch, n_accum: int):
    """Microbatch gradient accumulation via scan (constant memory)."""
    if n_accum <= 1:
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return grads, loss, metrics

    def reshape(x):
        return x.reshape((n_accum, x.shape[0] // n_accum) + x.shape[1:])

    micro = jax.tree.map(reshape, batch)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def body(carry, mb):
        acc, loss_acc = carry
        (loss, metrics), g = jax.value_and_grad(
            loss_fn, has_aux=True)(params, mb)
        acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), acc, g)
        return (acc, loss_acc + loss), metrics

    (grads, loss_sum), metrics = jax.lax.scan(body, (zeros, 0.0), micro)
    grads = jax.tree.map(lambda g: g / n_accum, grads)
    last_metrics = jax.tree.map(lambda m: m[-1], metrics)
    return grads, loss_sum / n_accum, last_metrics


# ---------------------------------------------------------------------------
# the step
# ---------------------------------------------------------------------------
def make_train_step(cfg: ModelConfig, policy: PolicyConfig,
                    optcfg: adamw.AdamWConfig = adamw.AdamWConfig(),
                    schedcfg: Optional[schedule.ScheduleConfig] = None,
                    mesh=None,
                    shape: Optional[ShapeConfig] = None) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    Lowers/compiles under any mesh; all sharding comes from in/out specs
    (see ``launch.dryrun`` / ``launch.train``).  ``shape`` keys the
    tuned-config lookup for the attention tiles; None keeps defaults.
    """
    seq_len = shape.seq_len if shape is not None else None
    loss_fn = make_loss_fn(cfg, policy, mesh, seq_len=seq_len)
    mesh_axes = dict(getattr(mesh, "shape", {})) if mesh is not None else {}
    use_pod_exchange = (
        "pod" in mesh_axes and mesh_axes["pod"] > 1
        and (policy.grad_compression == "int8_ef"))

    def optimizer_update(state: TrainState, grads, metrics, loss):
        lr = None
        if schedcfg is not None:
            lr = schedule.lr_at(state.opt.step, schedcfg)
        params, opt, om = adamw.apply(state.params, grads, state.opt,
                                      optcfg, lr=lr)
        metrics = dict(metrics, **om, loss=loss)
        return params, opt, metrics

    if not use_pod_exchange:
        def train_step(state: TrainState, batch):
            grads, loss, metrics = _accum_grads(
                loss_fn, state.params, batch, policy.grad_accum)
            params, opt, metrics = optimizer_update(
                state, grads, metrics, loss)
            return TrainState(params, opt, state.ef_residual), metrics
        return train_step

    # ---- manual-pod exchange: grads computed per pod, then int8-EF ----
    # inside the manual-pod region the batch is per-pod: dp excludes pod.
    # ep=False: a nested shard_map under a partially-manual mesh trips the
    # jax 0.8 MLIR verifier; the EP layout and the compressed exchange are
    # therefore mutually exclusive for now (documented in DESIGN.md).
    pod_policy = dataclasses.replace(
        policy, dp_axes=tuple(a for a in policy.dp_axes if a != "pod"),
        ep=False)
    pod_loss_fn = make_loss_fn(cfg, pod_policy, mesh, seq_len=seq_len)

    def train_step(state: TrainState, batch):

        def pod_body(params, ef, pod_batch):
            grads, loss, metrics = _accum_grads(
                pod_loss_fn, params, pod_batch, policy.grad_accum)
            ef_local = jax.tree.map(lambda r: r[0], ef)
            grads, ef_new = hierarchy.allreduce_int8_ef(
                grads, ef_local, "pod")
            loss = jax.lax.pmean(loss, "pod")
            metrics = jax.tree.map(lambda x: jax.lax.pmean(x, "pod"),
                                   metrics)
            ef_new = jax.tree.map(lambda r: r[None], ef_new)
            return grads, ef_new, loss, metrics

        n_batch = jax.tree.leaves(batch)[0].shape[0]
        bspec = jax.tree.map(
            lambda x: P(*(("pod",) + (None,) * (x.ndim - 1))), batch)
        ef_spec = jax.tree.map(lambda r: P("pod"), state.ef_residual)
        gspec = jax.tree.map(lambda p: P(), state.params)
        grads, ef_new, loss, metrics = shard_map(
            pod_body, mesh=mesh,
            in_specs=(gspec, ef_spec, bspec),
            out_specs=(gspec, ef_spec, P(), jax.tree.map(
                lambda _: P(), {"loss": 0, "xent": 0, "aux": 0})),
            axis_names=frozenset({"pod"}), check_vma=False,
        )(state.params, state.ef_residual, batch)
        params, opt, metrics = optimizer_update(state, grads, metrics, loss)
        return TrainState(params, opt, ef_new), metrics

    return train_step


# ---------------------------------------------------------------------------
# jit wiring (specs in/out) — shared by launch.train and launch.dryrun
# ---------------------------------------------------------------------------
def jit_train_step(train_step, state: TrainState, cfg: ModelConfig,
                   policy: PolicyConfig, mesh, example_batch):
    mesh_axes = dict(mesh.shape)
    sspec = state_specs(state, cfg, policy, mesh_axes)
    bspec = pol.batch_specs(example_batch, policy, mesh_axes)
    in_shardings = (TrainState(sspec.params, sspec.opt, sspec.ef_residual),
                    bspec)
    out_shardings = (in_shardings[0], None)
    return jax.jit(train_step,
                   in_shardings=jax.tree.map(
                       lambda s: jax.sharding.NamedSharding(mesh, s)
                       if s is not None else None, in_shardings,
                       is_leaf=lambda x: isinstance(x, P) or x is None),
                   out_shardings=jax.tree.map(
                       lambda s: jax.sharding.NamedSharding(mesh, s)
                       if s is not None else None, out_shardings,
                       is_leaf=lambda x: isinstance(x, P) or x is None))


# ---------------------------------------------------------------------------
# run tracking — per-step loss / step_s / tokens-per-s into repro.tracking
# ---------------------------------------------------------------------------
class StepTracker:
    """Adapter from the training loop to the tracking plane.

    Call :meth:`step` once per optimizer step with the step's metrics
    dict; it derives wall-clock ``step_s`` and ``tokens_per_s`` from an
    injectable clock and logs one tracking row per step (plus a system
    sample every ``system_every`` steps).  All methods are no-ops when
    no run is active, so the loop needs no tracking conditionals.
    """

    def __init__(self, tokens_per_step: int, run=None, *,
                 clock=None, system_every: int = 50):
        import time as _time
        from repro import tracking
        self.run = run if run is not None else tracking.current_run()
        self.tokens_per_step = tokens_per_step
        self.clock = clock or _time.time
        self.system_every = max(int(system_every), 1)
        self._last_t: Optional[float] = None
        self._n = 0
        self._loss: Optional[float] = None
        self._tok_s = 0.0

    def step(self, step: int, metrics: Mapping[str, Any]) -> None:
        if self.run is None:
            return
        now = self.clock()
        row: Dict[str, Any] = {
            k: float(v) for k, v in metrics.items()
            if isinstance(v, (int, float)) or hasattr(v, "item")}
        if self._last_t is not None:
            step_s = now - self._last_t
            row["step_s"] = step_s
            row["tokens_per_s"] = (self.tokens_per_step / step_s
                                   if step_s > 0 else 0.0)
            self._tok_s = row["tokens_per_s"]
        self._last_t = now
        self._n += 1
        self._loss = row.get("loss", self._loss)
        self.run.log(row, step=step + 1)
        if self._n % self.system_every == 0:
            self.run.log_system()

    def summary(self) -> Dict[str, Any]:
        """Final-row metrics; also merged into the run summary."""
        out: Dict[str, Any] = {"steps": self._n,
                               "tokens_per_s": self._tok_s}
        if self._loss is not None:
            out["final_loss"] = self._loss
        if self.run is not None:
            self.run.log_summary(out)
        return out
