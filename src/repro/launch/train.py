"""End-to-end training driver.

Runs a real training loop (synthetic data, AdamW, checkpoints, elastic
restart) on whatever devices exist — single CPU for the examples/tests,
the production mesh on real hardware.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
      --preset 100m --steps 200 --batch 8 --seq 256 --ckpt /tmp/ck
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
      --reduced --steps 20 --resume auto
  PYTHONPATH=src python -m repro.launch.train --arch mamba2-780m \
      --reduced --steps 30 --fail-at 12   # simulated failure + elastic resume
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.configs.base import ModelConfig, PolicyConfig, ShapeConfig
from repro.data import SyntheticDataset, make_batch
from repro.optim import AdamWConfig, ScheduleConfig
from repro.train import checkpoint, trainer


def preset_100m(cfg: ModelConfig) -> ModelConfig:
    """~100M-param same-family config (the deliverable-(b) target size)."""
    return dataclasses.replace(
        reduced(cfg, n_layers=min(12, cfg.n_layers), width_div=4,
                vocab=32768),
        name=cfg.name + "-100m")


def build(args):
    cfg = get_config(args.arch)
    if args.preset == "100m":
        cfg = preset_100m(cfg)
    elif args.reduced:
        cfg = reduced(cfg)
    policy = PolicyConfig(
        compute_dtype=args.dtype, remat=args.remat,
        attn_impl="xla", zero_stage=args.zero,
        grad_accum=args.grad_accum)
    optcfg = AdamWConfig(lr=args.lr)
    schedcfg = ScheduleConfig(peak_lr=args.lr, warmup_steps=args.warmup,
                              total_steps=args.steps)
    return cfg, policy, optcfg, schedcfg


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--preset", default="", choices=["", "100m"])
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=False)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--remat", default="block")
    ap.add_argument("--zero", type=int, default=3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", default="", choices=["", "auto"])
    ap.add_argument("--fail-at", type=int, default=0,
                    help="simulate a crash at this step (elastic test)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--track", action="store_true",
                    help="record the run via repro.tracking "
                         "(results/runs/<run_id>/events.jsonl)")
    args = ap.parse_args()

    cfg, policy, optcfg, schedcfg = build(args)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")

    run = None
    if args.track:
        from repro import tracking
        run = tracking.init(
            f"train-{args.arch}",
            config={"arch": args.arch, "preset": args.preset,
                    "steps": args.steps, "batch": args.batch,
                    "seq": args.seq, "lr": args.lr, "dtype": args.dtype,
                    "zero": args.zero, "grad_accum": args.grad_accum},
            tags=("train",), samplers=[tracking.ProcSampler()])
        print(f"tracking run {run.id} -> {run.path}")
    print(f"training {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"batch {args.batch} x seq {args.seq}, {args.steps} steps")

    state = trainer.init_state(jax.random.PRNGKey(0), cfg, policy, optcfg)
    start = 0
    if args.resume == "auto" and args.ckpt and \
            checkpoint.latest_step(args.ckpt) is not None:
        state, start = checkpoint.restore(args.ckpt, state)
        print(f"resumed from step {start}")

    step_fn = jax.jit(trainer.make_train_step(cfg, policy, optcfg,
                                              schedcfg, shape=shape))
    ds = SyntheticDataset(cfg, shape)
    stepper = trainer.StepTracker(shape.tokens, run)
    t0 = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in ds.batch_at(step).items()}
        state, metrics = step_fn(state, batch)
        stepper.step(step, metrics)
        if args.ckpt and (step + 1) % args.ckpt_every == 0:
            checkpoint.save(args.ckpt, step + 1, state)
        if args.fail_at and step + 1 == args.fail_at:
            if args.ckpt:
                checkpoint.save(args.ckpt, step + 1, state)
            print(f"simulated failure at step {step + 1} — restart with "
                  f"--resume auto")
            if run is not None:
                stepper.summary()
                run.finish("failed")
            return 17
        if (step + 1) % args.log_every == 0 or step == start:
            toks = shape.tokens * (step + 1 - start)
            print(f"step {step + 1:5d}  loss {float(metrics['loss']):.4f}"
                  f"  grad_norm {float(metrics['grad_norm']):.3f}"
                  f"  tok/s {toks / (time.time() - t0):.0f}")
    if args.ckpt:
        checkpoint.save(args.ckpt, args.steps, state)
    if run is not None:
        stepper.summary()
        run.finish()
    print(f"done in {time.time() - t0:.1f}s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
