"""Serving driver: batched requests through the async serving engine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
      --requests 8 --max-new 16      # paged engine, continuous batching
  PYTHONPATH=src python -m repro.launch.serve --no-fused ...  # legacy
  PYTHONPATH=src python -m repro.launch.serve --no-reduced ...  # full

The paged engine warms up (pre-compiles its jit traces) before serving
so TTFT/TPOT percentiles measure steady state; compile time is printed
separately (``--no-warmup`` to skip).

Requests whose prompt + decode budget exceed ``--max-seq`` are rejected
up front (exit code 2) — the engine never truncates silently.

``--request-timeout SECONDS`` puts a deadline on every request: instead
of hanging on a wedged engine, requests past the deadline are cancelled,
a per-request timeout report is printed, and the driver exits 3.
"""
from __future__ import annotations

import argparse
import time
from collections import deque

import jax

from repro.configs import get_config, reduced
from repro.configs.base import PolicyConfig
from repro.models import lm
from repro.serve import AsyncServeEngine, ServeRequest


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--prefill-chunk", type=int, default=32)
    ap.add_argument("--sched", default="slo",
                    choices=["slo", "priority", "fcfs"])
    ap.add_argument("--mode", default="auto",
                    choices=["auto", "paged", "dense"])
    ap.add_argument("--fused", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="continuous batching: fuse prefill chunks and "
                         "decode rows into one iteration (--no-fused "
                         "falls back to alternating batches)")
    ap.add_argument("--warmup", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="pre-compile the paged step's jit traces so "
                         "reported latencies are steady-state")
    ap.add_argument("--request-timeout", type=float, default=0.0,
                    help="per-request deadline in seconds (0 = none); "
                         "timed-out requests are cancelled and reported "
                         "instead of hanging the driver")
    args = ap.parse_args()

    if args.prompt_len + args.max_new > args.max_seq:
        print(f"error: prompt ({args.prompt_len}) + max-new "
              f"({args.max_new}) tokens exceed --max-seq ({args.max_seq}); "
              f"raise --max-seq or shorten the request")
        return 2

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    policy = PolicyConfig(compute_dtype="float32", remat="none",
                          attn_impl="full")
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    eng = AsyncServeEngine(
        cfg, params, policy, n_slots=args.slots, max_seq=args.max_seq,
        page_size=args.page_size, prefill_chunk=args.prefill_chunk,
        sched_policy=args.sched, mode=args.mode, fused=args.fused,
        request_timeout_s=args.request_timeout)
    if args.warmup and eng.mode == "paged":
        print(f"warmup: compiled paged step in {eng.warmup():.1f}s")

    pending = deque(
        ServeRequest(i, list(map(int, jax.random.randint(
            jax.random.PRNGKey(i), (args.prompt_len,), 0,
            cfg.vocab_size))), max_new=args.max_new)
        for i in range(args.requests))
    reqs = list(pending)
    t0 = time.time()
    while pending:
        req = pending.popleft()
        if not eng.submit(req):
            print(f"error: request {req.rid} rejected: {req.why_rejected}")
            return 2
    eng.run()
    dt = time.time() - t0

    rep = eng.report()
    done = sum(r.done for r in reqs)
    print(f"served {done}/{len(reqs)} requests in {dt:.1f}s "
          f"[{rep['mode']} mode"
          f"{', fused' if rep.get('fused') else ''}] "
          f"tput={rep['throughput_tok_s']:.1f} tok/s "
          f"ttft_p50={rep['ttft_s']['p50']*1e3:.0f}ms "
          f"tpot_p50={rep['tpot_s']['p50']*1e3:.0f}ms "
          f"compile={rep['compile_s']:.1f}s")
    if "kv_pages" in rep:
        kv = rep["kv_pages"]
        print(f"kv pages: {kv['n_pages']}x{kv['page_size']}tok "
              f"hit_rate={kv['hit_rate']*100:.0f}% "
              f"evictions={kv['evictions']}")
    for r in reqs[:3]:
        print(f"  req {r.rid}: {r.out[:8]}...")
    if eng.sched.cancelled:
        print(f"error: {len(eng.sched.cancelled)}/{len(reqs)} requests "
              f"timed out (--request-timeout {args.request_timeout:g}s):")
        for r in eng.sched.cancelled:
            print(f"  req {r.rid}: {r.why_rejected} "
                  f"({len(r.out)}/{r.max_new} tokens generated)")
        return 3
    return 0 if done == len(reqs) else 1


if __name__ == "__main__":
    raise SystemExit(main())
