"""Serving driver: batched requests through the continuous-batching engine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
      --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.configs.base import PolicyConfig
from repro.models import lm
from repro.serve import Request, ServeEngine


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    policy = PolicyConfig(compute_dtype="float32", remat="none",
                          attn_impl="full")
    key = jax.random.PRNGKey(0)
    params = lm.init_lm(key, cfg)
    eng = ServeEngine(cfg, params, policy, n_slots=args.slots,
                      max_seq=args.max_seq)

    reqs = [Request(i, jax.random.randint(jax.random.PRNGKey(i),
                                          (args.prompt_len,), 0,
                                          cfg.vocab_size),
                    max_new=args.max_new)
            for i in range(args.requests)]
    pending = list(reqs)
    t0 = time.time()
    decoded = 0
    while pending or any(r is not None for r in eng.slot_req):
        while pending and eng.add_request(pending[0]):
            pending.pop(0)
        decoded += eng.step()
    dt = time.time() - t0
    done = sum(r.done or len(r.out) >= r.max_new for r in reqs)
    print(f"served {done}/{len(reqs)} requests, {decoded} decode steps "
          f"in {dt:.1f}s ({decoded / max(dt, 1e-9):.1f} tok-steps/s)")
    for r in reqs[:3]:
        print(f"  req {r.rid}: {r.out[:8]}...")
    return 0 if done == len(reqs) else 1


if __name__ == "__main__":
    raise SystemExit(main())
