"""ShapeDtypeStruct stand-ins for every model input/state (no allocation).

``input_specs(arch, shape)`` returns exactly what the lowered step consumes:

  * train/prefill — {"inputs": (B, S) int32 | (B, S, d) f32, "labels": ...}
  * decode        — (tokens (B,1), positions (B,1), caches pytree)

plus ``state_structs`` (params + optimizer) via ``jax.eval_shape`` over the
real initializers — weak-type-correct, shardable, zero bytes allocated.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import ModelConfig, PolicyConfig, ShapeConfig, SHAPES
from repro.models import lm, transformer
from repro.optim import adamw
from repro.train import trainer


def batch_structs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Train/prefill batch stand-ins."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.input_mode == "embeddings":
        inputs = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.float32)
    else:
        inputs = jax.ShapeDtypeStruct((B, S), jnp.int32)
    return {"inputs": inputs,
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}


def decode_structs(cfg: ModelConfig, shape: ShapeConfig,
                   cache_dtype=jnp.bfloat16) -> Tuple[Any, Any, Any]:
    """(tokens, positions, caches) stand-ins for one decode step with a
    cache of ``shape.seq_len`` history."""
    B = shape.global_batch
    if cfg.input_mode == "embeddings":
        tokens = jax.ShapeDtypeStruct((B, 1, cfg.d_model), jnp.float32)
    else:
        tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    positions = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    caches = jax.eval_shape(
        lambda: transformer.init_stack_cache(cfg, B, shape.seq_len,
                                             cache_dtype))
    return tokens, positions, caches


def state_structs(cfg: ModelConfig, policy: PolicyConfig,
                  optcfg: adamw.AdamWConfig = adamw.AdamWConfig(),
                  *, n_pods: int = 1) -> Any:
    """TrainState stand-in via eval_shape over the real initializers."""
    return jax.eval_shape(
        lambda: trainer.init_state(jax.random.PRNGKey(0), cfg, policy,
                                   optcfg, n_pods=n_pods))


def param_structs(cfg: ModelConfig, policy: PolicyConfig) -> Any:
    dt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[
        policy.param_dtype]
    return jax.eval_shape(
        lambda: lm.init_lm(jax.random.PRNGKey(0), cfg, dtype=dt))


def input_specs(arch: str, shape_name: str, policy: PolicyConfig,
                *, n_pods: int = 1) -> Dict[str, Any]:
    """Everything the (arch x shape) step consumes, as structs.

    Returns {"kind": "train"|"prefill"|"decode", plus the stand-ins}.
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return {"kind": "train",
                "state": state_structs(cfg, policy, n_pods=n_pods),
                "batch": batch_structs(cfg, shape)}
    if shape.kind == "prefill":
        return {"kind": "prefill",
                "params": param_structs(cfg, policy),
                "batch": batch_structs(cfg, shape)}
    tokens, positions, caches = decode_structs(cfg, shape)
    return {"kind": "decode", "params": param_structs(cfg, policy),
            "tokens": tokens, "positions": positions, "caches": caches}
