"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state; the dry-run sets
``--xla_force_host_platform_device_count=512`` before any jax import and
then calls it.
"""
from __future__ import annotations

from typing import Optional, Sequence


def make_production_mesh(*, multi_pod: bool = False, devices=None):
    """Single-pod (16,16) ("data","model") or multi-pod (2,16,16)
    ("pod","data","model") mesh over the first N available devices."""
    import jax
    import numpy as np
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devs = list(devices if devices is not None else jax.devices())
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devs)} — run "
            "under launch/dryrun.py (which forces 512 host devices) or on "
            "real hardware")
    if len(devs) == n:
        return jax.make_mesh(shape, axes, devices=devs)
    arr = np.asarray(devs[:n]).reshape(shape)
    return jax.sharding.Mesh(arr, axes)


def make_mesh(shape: Sequence[int], axes: Sequence[str], devices=None):
    """Arbitrary mesh over the first prod(shape) devices (perf experiments
    use this to try alternative axis splits)."""
    import jax
    import numpy as np
    n = int(np.prod(list(shape)))
    devs = list(devices if devices is not None else jax.devices())[:n]
    if len(devs) < n:
        raise RuntimeError(f"need {n} devices, have {len(devs)}")
    arr = np.asarray(devs).reshape(tuple(shape))
    return jax.sharding.Mesh(arr, tuple(axes))
