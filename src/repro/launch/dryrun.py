import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. builds the jitted step (train / prefill / decode) with in/out
     shardings from the policy engine,
  3. ``.lower(**input_specs)`` -> ``.compile()``  (ShapeDtypeStructs only —
     no arrays are ever allocated),
  4. prints ``memory_analysis()`` (proves the cell fits 16 GB/chip) and
     ``cost_analysis()`` (FLOPs/bytes),
  5. parses the compiled HLO for collectives and writes a JSON CostReport
     consumed by benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --mesh multi --zero 3
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (ASSIGNED_ARCHS, SHAPES, applicable_shapes,
                           get_config)
from repro.configs.base import PolicyConfig
from repro.core import costmodel, policy as pol
from repro.core.compose import production_system
from repro.launch import specs as specs_lib
from repro.launch.mesh import make_production_mesh
from repro.serve import engine
from repro.train import trainer


def _ns(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s) if s is not None else None,
        spec_tree,
        is_leaf=lambda x: isinstance(x, P) or x is None)


def make_policy(args, multi_pod: bool) -> PolicyConfig:
    dp = ("pod", "data") if multi_pod else ("data",)
    return PolicyConfig(
        dp_axes=dp,
        fsdp_axes=("data",),
        tp_axis="model",
        zero_stage=args.zero,
        compute_dtype=args.dtype,
        param_dtype=getattr(args, "param_dtype", "float32"),
        remat=args.remat,
        attn_impl="xla",
        grad_accum=args.grad_accum,
        grad_compression=args.compress,
    )


def lower_cell(arch: str, shape_name: str, mesh, policy: PolicyConfig,
               *, donate: bool = True):
    """Build + lower + compile one cell. Returns (lowered, compiled, meta)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_axes = dict(mesh.shape)
    n_pods = mesh_axes.get("pod", 1)
    if shape.kind == "decode":
        # serving layout: weights stationary (TP-only, bf16, no ZeRO) —
        # ZeRO-3 decode re-gathers the whole model for every token
        # (measured 100-490 ms/token of pure weight traffic)
        policy = dataclasses.replace(policy, zero_stage=0,
                                     param_dtype="bfloat16")
    ins = specs_lib.input_specs(arch, shape_name, policy, n_pods=n_pods)

    if ins["kind"] == "train":
        step = trainer.make_train_step(cfg, policy, mesh=mesh, shape=shape)
        sspec = trainer.state_specs(ins["state"], cfg, policy, mesh_axes)
        bspec = pol.batch_specs(ins["batch"], policy, mesh_axes)
        jf = jax.jit(step,
                     in_shardings=(_ns(mesh, dataclasses_asdict(sspec)),
                                   _ns(mesh, bspec)),
                     out_shardings=(_ns(mesh, dataclasses_asdict(sspec)),
                                    None),
                     donate_argnums=(0,) if donate else ())
        with mesh:
            lowered = jf.lower(ins["state"], ins["batch"])
        flops = costmodel.step_flops(cfg, shape, policy)
    elif ins["kind"] == "prefill":
        step = engine.make_prefill_step(cfg, policy,
                                        cache_capacity=shape.seq_len,
                                        mesh=mesh)
        pspec = pol.param_specs(ins["params"], cfg, policy, mesh_axes)
        bspec = pol.batch_specs(ins["batch"], policy, mesh_axes)
        cspec_out = None   # let GSPMD lay out the produced caches
        jf = jax.jit(step,
                     in_shardings=(_ns(mesh, pspec),
                                   _ns(mesh, bspec["inputs"])),
                     out_shardings=None)
        with mesh:
            lowered = jf.lower(ins["params"], ins["batch"]["inputs"])
        flops = (costmodel.forward_flops(cfg, shape, with_logits=False)
                 + 2 * shape.global_batch * cfg.d_model * cfg.padded_vocab)
    else:  # decode
        step = engine.make_decode_step(cfg, policy, mesh=mesh,
                                       max_seq=shape.seq_len,
                                       batch=shape.global_batch)
        pspec = pol.param_specs(ins["params"], cfg, policy, mesh_axes)
        cspec = pol.cache_specs(ins["caches"], policy, mesh_axes)
        tspec = pol.batch_specs(
            {"t": ins["tokens"], "p": ins["positions"]}, policy, mesh_axes)
        jf = jax.jit(step,
                     in_shardings=(_ns(mesh, pspec), _ns(mesh, cspec),
                                   _ns(mesh, tspec["t"]),
                                   _ns(mesh, tspec["p"])),
                     out_shardings=(None, _ns(mesh, cspec)),
                     donate_argnums=(1,) if donate else ())
        with mesh:
            lowered = jf.lower(ins["params"], ins["caches"], ins["tokens"],
                               ins["positions"])
        flops = costmodel.forward_flops(cfg, shape)

    compiled = lowered.compile()
    report = costmodel.extract(
        compiled, arch=arch, shape_name=shape_name, mesh_axes=mesh_axes,
        flops_analytic=flops,
        model_fl=costmodel.model_flops(cfg, shape),
        hbm_analytic=costmodel.analytic_hbm_bytes(cfg, shape, policy,
                                                  mesh_axes))
    return lowered, compiled, report


def dataclasses_asdict(state_spec):
    """TrainState spec -> same TrainState (already a pytree); identity
    hook kept for clarity at the call site."""
    return state_spec


def report_to_json(report: costmodel.CostReport, compiled,
                   wall_s: float) -> Dict[str, Any]:
    mem: Dict[str, Any] = {}
    try:
        m = compiled.memory_analysis()
        if m is not None:
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes"):
                v = getattr(m, k, None)
                if v is not None:
                    mem[k] = int(v)
    except Exception:
        pass
    colls: Dict[str, Dict[str, float]] = {}
    for op in report.collectives:
        key = op.kind
        c = colls.setdefault(key, {"count": 0, "wire_bytes": 0.0})
        c["count"] += op.trip_count
        c["wire_bytes"] += op.wire_bytes
    return {
        "arch": report.arch, "shape": report.shape, "mesh": report.mesh,
        "flops_hlo_per_device": report.flops_hlo,
        "flops_analytic_total": report.flops_analytic,
        "model_flops": report.model_flops,
        "hbm_bytes_per_device": report.hbm_bytes,
        "memory_analysis": mem,
        "collectives_by_kind": colls,
        "per_axis_wire_bytes": report.per_axis_wire_bytes(),
        "collective_wire_bytes_total": report.collective_bytes_total(),
        "compile_wall_s": wall_s,
    }


def run_cell(arch: str, shape_name: str, mesh_kind: str, args,
             out_dir: str) -> Optional[Dict[str, Any]]:
    multi = mesh_kind == "multi"
    t0 = time.time()
    tag = f"{arch}__{shape_name}__{mesh_kind}"
    out_path = os.path.join(out_dir, tag + ".json")
    if args.skip_existing and os.path.exists(out_path):
        print(f"[skip] {tag} (cached)")
        with open(out_path) as f:
            return json.load(f)
    try:
        if getattr(args, "mesh_shape", ""):
            from repro.launch.mesh import make_mesh
            sizes = tuple(int(x) for x in args.mesh_shape.split(","))
            names = (("pod", "data", "model") if len(sizes) == 3
                     else ("data", "model"))
            mesh = make_mesh(sizes, names)
        else:
            mesh = make_production_mesh(multi_pod=multi)
        policy = make_policy(args, multi)
        lowered, compiled, report = lower_cell(arch, shape_name, mesh,
                                               policy)
        wall = time.time() - t0
        js = report_to_json(report, compiled, wall)
        # the roofline needs the fabric: price on the localGPUs system
        system = production_system(multi_pod=multi)
        rl = costmodel.roofline(report, system)
        js["hbm_bytes_analytic"] = report.hbm_bytes_analytic
        js["roofline"] = {
            "compute_s": rl.compute_s, "memory_s": rl.memory_s,
            "memory_hlo_s": rl.memory_hlo_s,
            "collective_s": rl.collective_s, "per_axis_s": rl.per_axis_s,
            "dominant": rl.dominant, "useful_ratio": rl.useful_ratio,
            "roofline_fraction": rl.roofline_fraction,
            "step_time_s": rl.step_time_s,
        }
        os.makedirs(out_dir, exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(js, f, indent=1)
        mem_gb = js["memory_analysis"].get("argument_size_in_bytes", 0) / 2**30
        print(f"[ok]   {tag}: compile {wall:.1f}s | args/dev "
              f"{mem_gb:.2f}GiB | {rl.summary()}")
        return js
    except Exception as e:  # noqa: BLE001 — report every failing cell
        print(f"[FAIL] {tag}: {type(e).__name__}: {e}")
        if args.verbose:
            traceback.print_exc()
        return None


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--zero", type=int, default=3)
    ap.add_argument("--dtype", default="bfloat16")
    ap.add_argument("--param-dtype", default="float32",
                    choices=["float32", "bfloat16"],
                    help="bfloat16 = bf16 params + fp32 master weights "
                         "(halves grad reductions and ZeRO gathers)")
    ap.add_argument("--remat", default="block")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--compress", default="none")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action=argparse.BooleanOptionalAction,
                    default=False)
    ap.add_argument("--verbose", action=argparse.BooleanOptionalAction,
                    default=False)
    ap.add_argument("--mesh-shape", default="",
                    help="logical re-composition of the same chips, e.g. "
                         "'64,4' (data,model) — the paper's recompose knob")
    args = ap.parse_args()

    archs = list(ASSIGNED_ARCHS) if args.arch == "all" else [args.arch]
    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])
    n_ok = n_fail = 0
    for arch in archs:
        cfg = get_config(arch)
        shapes = ([s.name for s in applicable_shapes(cfg)]
                  if args.shape == "all" else [args.shape])
        for shape_name in shapes:
            for mesh_kind in meshes:
                r = run_cell(arch, shape_name, mesh_kind, args, args.out)
                n_ok += r is not None
                n_fail += r is None
    print(f"\ndry-run complete: {n_ok} ok, {n_fail} failed")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
