from repro.serve.engine import (Request, ServeEngine,  # noqa: F401
                                greedy_sample, init_caches, make_decode_step,
                                make_prefill_step)
