from repro.serve.engine import (AsyncServeEngine, Request,  # noqa: F401
                                ServeEngine, greedy_sample, init_caches,
                                make_decode_step, make_prefill_step)
from repro.serve.kvcache import (BlockTable, PageError,  # noqa: F401
                                 PagePool)
from repro.serve.scheduler import (SLO, RequestScheduler,  # noqa: F401
                                   ServeRequest)
