"""Paged KV-cache manager: fixed-size pages from a shared pool.

The dense serving cache allocates ``n_slots x max_seq`` tokens of K/V up
front and scatters whole per-sequence caches into slots
(``ServeEngine._prefill_into_slot``).  This module replaces that with the
standard production layout:

  * **physical storage** — one page pool per attention layer, shaped
    ``(n_pages + 1, page_size, K, D)`` (the ``+1`` row is a scratch page
    that absorbs masked writes).  The pytree mirrors
    ``transformer.init_stack_cache`` exactly — scanned segments carry a
    leading layer dim — so a page id addresses that page's tokens across
    *all* layers at once, like a vLLM block;
  * **block tables** — each sequence owns an ordered list of page ids;
    the dense ``(B, W, K, D)`` view the model consumes exists only
    *inside* the jitted step (``gather_dense``: one XLA gather), never in
    host memory;
  * **prefix reuse** — pages are immutable once full; full prompt pages
    are registered under a chain hash (page ``i``'s key folds page
    ``i-1``'s) as soon as the prompt's prefill completes, so a request
    sharing a prompt prefix re-links the existing pages (refcount++) and
    prefill starts at the first uncached token — even while the
    registering request is still decoding.  Sharing granularity is whole
    pages, which makes copy-on-write unnecessary: only the (exclusively
    owned) non-full tail page of a sequence is ever written;
  * **free-list recycling** — released pages return to the free list;
    hashed pages whose refcount drops to zero are *retained* in an LRU
    cache and evicted only when the free list runs dry, so a hot system
    prompt stays resident across requests.

Only positional (full-attention) caches page cleanly — ring buffers and
recurrent state are not length-indexed — so ``PagePool`` requires an
all-``attn`` block pattern; ``AsyncServeEngine`` falls back to dense
slot caches for the other families.
"""
from __future__ import annotations

import re
from collections import OrderedDict, deque
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, ModelConfig
from repro.models import transformer


class PageError(RuntimeError):
    """Pool exhausted (or a sequence outgrew its table)."""


def cache_batch_dim(path, segs) -> int:
    """Batch/page dim of a stack-cache leaf: 1 under a scanned (stacked)
    segment — those carry a leading layer dim — else 0."""
    for p in path:
        key = str(getattr(p, "key", ""))
        m = re.match(r"seg(\d+)$", key)
        if m:
            si = int(m.group(1))
            return 1 if si < len(segs) and segs[si][1] > 1 else 0
    return 0


def _is_pos_leaf(path) -> bool:
    return str(getattr(path[-1], "key", "")) == "pos"


# ---------------------------------------------------------------------------
# device-side views (pure functions; the engine jits them with the model)
# ---------------------------------------------------------------------------
def gather_dense(pages, tables, segs):
    """Materialize the dense per-sequence cache view from the pool.

    ``tables`` (B, P) int32 page ids (pad unused entries with the scratch
    page — its ``pos`` rows stay -1, so padded slots mask out).  Returns
    the ``(B, P*page_size, ...)``-batched cache pytree the decode/chunk
    paths consume.
    """
    def g(path, leaf):
        bd = cache_batch_dim(path, segs)
        out = jnp.take(leaf, tables, axis=bd)     # (..., B, P, ps, rest)
        sh = out.shape
        return out.reshape(sh[:bd + 1] + (sh[bd + 1] * sh[bd + 2],)
                           + sh[bd + 3:])
    return jax.tree_util.tree_map_with_path(g, pages)


def scatter_tokens(pages, dense, tables, positions, valid, page_size, segs,
                   trash: int):
    """Write the tokens at ``positions`` (B, S) from the dense view back
    into their pages; entries with ``valid`` False (padding rows/tails)
    are routed to the scratch page with ``pos=-1`` so pool state is
    untouched.  Slot == absolute position (full-attention layout)."""
    B, S = positions.shape
    bidx = jnp.arange(B)[:, None]
    page = jnp.where(valid,
                     tables[bidx, positions // page_size],
                     jnp.int32(trash))
    off = positions % page_size

    def s(path, pleaf, dleaf):
        bd = cache_batch_dim(path, segs)
        if _is_pos_leaf(path):
            val = jnp.where(valid, positions, -1).astype(pleaf.dtype)
            if bd == 1:
                val = jnp.broadcast_to(val, (pleaf.shape[0],) + val.shape)
                return pleaf.at[:, page, off].set(val)
            return pleaf.at[page, off].set(val)
        if bd == 1:                                # (L, B, W, rest)
            val = dleaf[:, bidx, positions]        # (L, B, S, rest)
            return pleaf.at[:, page, off].set(val.astype(pleaf.dtype))
        val = dleaf[bidx, positions]               # (B, S, rest)
        return pleaf.at[page, off].set(val.astype(pleaf.dtype))

    return jax.tree_util.tree_map_with_path(s, pages, dense)


def scatter_slot(caches, one, slot: int, segs):
    """Write a single-sequence cache pytree into batch slot ``slot`` of a
    dense slot-cache pytree — the dense engines' prefill scatter (shared
    by ``ServeEngine`` and ``AsyncServeEngine``'s dense mode)."""
    def put(path, c_all, c_one):
        bd = cache_batch_dim(path, segs)
        idx = tuple([slice(None)] * bd + [slice(slot, slot + 1)])
        return c_all.at[idx].set(c_one.astype(c_all.dtype))
    return jax.tree_util.tree_map_with_path(put, caches, one)


# ---------------------------------------------------------------------------
# host-side accounting
# ---------------------------------------------------------------------------
class BlockTable:
    """One sequence's ordered page ids + logical token length."""

    __slots__ = ("pages", "n_tokens")

    def __init__(self, pages: Optional[List[int]] = None, n_tokens: int = 0):
        self.pages = list(pages or [])
        self.n_tokens = n_tokens

    def __len__(self) -> int:
        return len(self.pages)


class PagePool:
    """Shared page pool: device arrays + free list + prefix-hash table."""

    def __init__(self, cfg: ModelConfig, *, n_pages: int, page_size: int = 16,
                 dtype=jnp.float32):
        if any(b != ATTN for b in cfg.pattern):
            raise ValueError(
                "PagePool requires an all-'attn' block pattern; "
                f"{cfg.name} has {sorted(set(cfg.pattern))} "
                "(use the dense slot engine for ring/recurrent caches)")
        self.cfg = cfg
        self.page_size = int(page_size)
        self.n_pages = int(n_pages)
        self.trash = self.n_pages                  # scratch row
        self.segs = transformer.plan_segments(cfg.pattern)
        self.pages = transformer.init_stack_cache(
            cfg, self.n_pages + 1, self.page_size, dtype)
        self.free: deque = deque(range(self.n_pages))
        self.ref = [0] * self.n_pages
        self.page_hash: List[Optional[int]] = [None] * self.n_pages
        # exact (prev_hash, tokens) key per hashed page: hits verify the
        # token content, so a 64-bit chain-hash collision degrades to a
        # miss instead of silently re-linking the wrong KV pages
        self.page_key: List[Optional[Tuple]] = [None] * self.n_pages
        self.by_hash: Dict[int, int] = {}          # hash -> page (live)
        self.retained: "OrderedDict[int, int]" = OrderedDict()  # LRU, ref==0
        # stats
        self.hit_tokens = 0
        self.miss_tokens = 0
        self.evictions = 0
        self.allocations = 0
        self.peak_in_use = 0           # high-water mark of in_use

    # ------------------------------------------------------------- sizing --
    def pages_for(self, n_tokens: int) -> int:
        return -(-max(n_tokens, 1) // self.page_size)

    @property
    def n_free(self) -> int:
        return len(self.free) + len(self.retained)

    @property
    def in_use(self) -> int:
        return self.n_pages - self.n_free

    def utilization(self) -> float:
        return self.in_use / max(self.n_pages, 1)

    def hit_rate(self) -> float:
        tot = self.hit_tokens + self.miss_tokens
        return self.hit_tokens / tot if tot else 0.0

    # -------------------------------------------------------- page lifecycle
    def _evict_one(self) -> int:
        if not self.retained:
            raise PageError(f"page pool exhausted ({self.n_pages} pages)")
        h, page = self.retained.popitem(last=False)   # LRU
        self.by_hash.pop(h, None)
        self.page_hash[page] = None
        self.page_key[page] = None
        self.evictions += 1
        return page

    def _note_usage(self) -> None:
        """Record the in-use high-water mark (the serve_bench artifact
        samples ``stats()`` post-drain, where ``in_use`` is always 0 —
        peak is the occupancy number that actually means something)."""
        if self.in_use > self.peak_in_use:
            self.peak_in_use = self.in_use

    def _take_page(self) -> int:
        page = self.free.popleft() if self.free else self._evict_one()
        self.ref[page] = 1
        self.allocations += 1
        self._note_usage()
        return page

    def allocate(self, n: int) -> List[int]:
        """``n`` fresh exclusive pages (evicting retained LRU pages as
        needed); raises PageError when the pool cannot satisfy it."""
        if n > self.n_free:
            raise PageError(
                f"need {n} pages, {self.n_free} available "
                f"({self.n_pages} total)")
        out = [self._take_page() for _ in range(n)]
        self._reset_pos(out)
        return out

    def release(self, table: BlockTable) -> None:
        """Drop one reference per page; hashed full pages are retained
        (LRU) for prefix reuse, the rest return to the free list."""
        for page in table.pages:
            self.ref[page] -= 1
            if self.ref[page] > 0:
                continue
            h = self.page_hash[page]
            if h is not None:
                self.retained[h] = page
                self.retained.move_to_end(h)
            else:
                self.free.append(page)
        table.pages = []
        table.n_tokens = 0

    def _reset_pos(self, page_ids: Sequence[int]) -> None:
        """Clear stale ``pos`` rows of recycled pages (device write).  K/V
        contents can stay — ``pos == -1`` masks them."""
        idx = jnp.asarray(list(page_ids), jnp.int32)

        def r(path, leaf):
            if not _is_pos_leaf(path):
                return leaf
            if cache_batch_dim(path, self.segs) == 1:
                return leaf.at[:, idx].set(-1)
            return leaf.at[idx].set(-1)

        self.pages = jax.tree_util.tree_map_with_path(r, self.pages)

    # ---------------------------------------------------------- prefix reuse
    @staticmethod
    def _chain(prev: int, toks: Tuple[int, ...]) -> int:
        return hash((prev, toks))

    def match_prefix(self, prompt: Sequence[int]) -> Tuple[List[int], int]:
        """Longest run of already-cached *full* pages covering the prompt's
        head.  Returns (page ids, n_cached_tokens); the returned pages are
        referenced (the caller owns one ref each) and counted as hits.

        Never matches the prompt's final page even when the prompt length
        is an exact page multiple: the last page must stay writable for
        the decode tail, and shared pages are immutable.
        """
        ps = self.page_size
        toks = [int(t) for t in prompt]
        pages: List[int] = []
        h = 0
        n_full = (len(toks) - 1) // ps             # final page excluded
        prev = 0
        for i in range(n_full):
            key = (prev, tuple(toks[i * ps:(i + 1) * ps]))
            h = self._chain(*key)
            page = self.by_hash.get(h)
            if page is None or self.page_key[page] != key:
                break                              # miss (or hash collision)
            # a referenced page must not sit in the eviction LRU — a
            # retained hit revives it out of the evictable set
            self.retained.pop(h, None)
            self.ref[page] += 1
            pages.append(page)
            prev = h
        self._note_usage()             # retained revivals raise in_use too
        self.hit_tokens += len(pages) * ps
        self.miss_tokens += len(toks) - len(pages) * ps
        return pages, len(pages) * ps

    def register_prefix(self, prompt: Sequence[int], table: BlockTable
                        ) -> None:
        """Hash the prompt's full pages (call once the prompt's prefill
        completes — they are immutable from then on) so later requests
        can re-link them (idempotent; first registration wins)."""
        ps = self.page_size
        toks = [int(t) for t in prompt]
        prev = 0
        for i in range((len(toks) - 1) // ps):
            key = (prev, tuple(toks[i * ps:(i + 1) * ps]))
            h = self._chain(*key)
            page = table.pages[i]
            if h not in self.by_hash and self.page_hash[page] is None:
                self.by_hash[h] = page
                self.page_hash[page] = h
                self.page_key[page] = key
            prev = h

    # ------------------------------------------------------------- sequences
    def open_sequence(self, prompt: Sequence[int], max_new: int
                      ) -> Tuple[BlockTable, int]:
        """Block table for prompt + decode budget, reusing cached prefix
        pages.  Returns (table, n_cached_tokens); raises PageError (with
        the reused refs rolled back) when the pool cannot host it."""
        reused, n_cached = self.match_prefix(prompt)
        need = self.pages_for(len(prompt) + max_new) - len(reused)
        try:
            fresh = self.allocate(need)
        except PageError:
            self.release(BlockTable(reused))
            # undo the optimistic hit accounting: the request never ran
            self.hit_tokens -= n_cached
            self.miss_tokens -= len(prompt) - n_cached
            raise
        return BlockTable(reused + fresh, n_cached), n_cached

    def close_sequence(self, prompt: Sequence[int], table: BlockTable
                       ) -> None:
        """Register the prompt's pages for reuse, then drop the refs."""
        self.register_prefix(prompt, table)
        self.release(table)

    def padded_table(self, table: BlockTable, width: int) -> jnp.ndarray:
        """(width,) int32 page ids padded with the scratch page."""
        row = table.pages[:width] + [self.trash] * (width - len(table))
        return jnp.asarray(row, jnp.int32)

    def stats(self) -> Dict[str, float]:
        return {
            "n_pages": self.n_pages,
            "page_size": self.page_size,
            "in_use": self.in_use,
            "retained": len(self.retained),
            "utilization": self.utilization(),
            "peak_in_use": self.peak_in_use,
            "peak_utilization": self.peak_in_use / max(self.n_pages, 1),
            "hit_tokens": self.hit_tokens,
            "miss_tokens": self.miss_tokens,
            "hit_rate": self.hit_rate(),
            "evictions": self.evictions,
            "allocations": self.allocations,
        }
