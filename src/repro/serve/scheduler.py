"""SLO-aware request scheduler: admission, chunked prefill, fairness.

Mirrors the cluster scheduler's shapes one level down: where
``cluster.scheduler`` admits *jobs* onto device pools, this admits
*requests* onto an engine's page pool and decode slots.

  * **admission queue** — requests wait until a decode slot and enough
    pages exist; prompts longer than the engine capacity are rejected at
    submit time (the request-level analogue of the cluster scheduler's
    analytic admission check);
  * **SLOs** — every request carries TTFT/TPOT targets.  Under the
    ``slo`` policy the prefill order is earliest-TTFT-deadline-first and
    admission order is (deadline, priority, arrival); ``priority`` and
    ``fcfs`` mirror the cluster queue's priority-FIFO ordering;
  * **chunked prefill** — long prompts are split into fixed
    ``prefill_chunk``-token chunks; each engine iteration runs at most
    ``prefill_batch`` chunks *alongside* the decode batch, so a 32k
    prompt no longer monopolizes a step and decode TPOT stays flat
    (Sarathi-style stall-free batching);
  * **token-budget packing** — ``iteration_plan()`` builds the fused
    iteration the continuous-batching engine runs: every decode row
    first (one token each — decode is never starved), then prefill
    chunks in policy order until ``token_budget`` new tokens are packed,
    clipping the last chunk to whatever budget remains.  A long prompt
    therefore spends many iterations trickling through the budget while
    queued short requests keep hitting their TTFT deadlines.

The scheduler owns ordering and lifecycle state; the engine owns device
steps and the page pool.  Per-request metrics (queue wait, TTFT, TPOT,
cached-token fraction) are recorded here and aggregated by
``cluster.telemetry.ServingStats``.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional, Sequence, Tuple

WAITING, PREFILL, DECODE, DONE, REJECTED, TIMED_OUT = (
    "waiting", "prefill", "decode", "done", "rejected", "timed_out")

POLICIES = ("slo", "priority", "fcfs")


@dataclasses.dataclass(frozen=True)
class SLO:
    """Per-request latency targets (seconds)."""
    ttft_s: float = 1.0               # time to first token
    tpot_s: float = 0.25              # time per output token


@dataclasses.dataclass
class ServeRequest:
    """One inference request moving through the serving stack."""
    rid: int
    prompt: Sequence[int]             # token ids (any int sequence)
    max_new: int = 16
    slo: SLO = SLO()
    priority: int = 0
    # lifecycle (scheduler/engine-owned)
    state: str = WAITING
    out: List[int] = dataclasses.field(default_factory=list)
    n_cached: int = 0                 # prompt tokens served from the pool
    prefilled: int = 0                # prompt tokens computed or cached
    table: Optional[object] = None    # kvcache.BlockTable (paged) | slot id
    t_submit: float = 0.0
    t_admit: float = 0.0
    t_first: float = 0.0              # first generated token
    t_last: float = 0.0
    why_rejected: str = ""

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def done(self) -> bool:
        return self.state == DONE

    def ttft_deadline(self) -> float:
        return self.t_submit + self.slo.ttft_s

    # ------------------------------------------------------------ metrics --
    def queue_wait_s(self) -> float:
        return max(0.0, self.t_admit - self.t_submit)

    def ttft_s(self) -> float:
        return max(0.0, self.t_first - self.t_submit)

    def tpot_s(self) -> float:
        if len(self.out) <= 1:
            return 0.0
        return max(0.0, (self.t_last - self.t_first)) / (len(self.out) - 1)

    def slo_met(self) -> bool:
        ok = self.ttft_s() <= self.slo.ttft_s
        if len(self.out) > 1:
            ok = ok and self.tpot_s() <= self.slo.tpot_s
        return ok


class RequestScheduler:
    """Admission + per-iteration work selection for the serve engine."""

    def __init__(self, *, max_slots: int = 8, max_prompt: int = 512,
                 prefill_chunk: int = 64, prefill_batch: int = 2,
                 token_budget: Optional[int] = None, policy: str = "slo"):
        if policy not in POLICIES:
            raise ValueError(f"policy {policy!r} not in {POLICIES}")
        self.max_slots = max_slots
        self.max_prompt = max_prompt
        self.prefill_chunk = prefill_chunk
        self.prefill_batch = prefill_batch
        # fused-iteration packing cap: decode rows (1 token each) plus
        # prefill chunks must fit this many new tokens per iteration
        self.token_budget = (token_budget if token_budget is not None
                             else prefill_batch * prefill_chunk + max_slots)
        self.policy = policy
        self.waiting: Deque[ServeRequest] = deque()
        self.active: List[ServeRequest] = []      # PREFILL or DECODE
        self.finished: List[ServeRequest] = []
        self.rejected: List[ServeRequest] = []
        self.cancelled: List[ServeRequest] = []   # timed out / aborted

    # -------------------------------------------------------------- submit --
    def _reject(self, req: ServeRequest, why: str) -> bool:
        req.state = REJECTED
        req.why_rejected = why
        self.rejected.append(req)
        return False

    def submit(self, req: ServeRequest, now: float = 0.0) -> bool:
        """Admission check: the whole request (prompt + decode budget)
        must fit the engine capacity ``max_prompt``; never truncate."""
        req.t_submit = now
        if req.prompt_len == 0:
            return self._reject(req, "empty prompt")
        if req.max_new < 1:
            # the engine emits the first token from the prefill's last
            # hidden state, so a 0-token budget cannot be honored
            return self._reject(req, f"max_new {req.max_new} < 1")
        if req.prompt_len + req.max_new > self.max_prompt:
            return self._reject(
                req, f"prompt {req.prompt_len} + max_new {req.max_new} "
                     f"exceeds engine capacity {self.max_prompt}")
        req.state = WAITING
        self.waiting.append(req)
        return True

    # ------------------------------------------------------------ ordering --
    def _key(self, req: ServeRequest):
        if self.policy == "slo":
            return (req.ttft_deadline(), -req.priority, req.t_submit)
        if self.policy == "priority":
            return (-req.priority, req.t_submit, req.rid)
        return (req.t_submit, req.rid)

    # ----------------------------------------------------------- admission --
    def admit(self, now: float, try_open) -> List[ServeRequest]:
        """Admit waiting requests while slots and pages allow.

        ``try_open(req)`` is the engine callback that claims cache space
        (pages or a dense slot) and returns True on success; on False the
        head request keeps waiting (no backfill past a starved head —
        request sizes are near-uniform, so EASY-style reservations don't
        pay for themselves here).
        """
        admitted: List[ServeRequest] = []
        while self.waiting and len(self.active) < self.max_slots:
            head = min(self.waiting, key=self._key)
            if not try_open(head):
                break
            self.waiting.remove(head)
            head.state = PREFILL
            head.t_admit = now
            head.prefilled = head.n_cached
            self.active.append(head)
            admitted.append(head)
        return admitted

    # ------------------------------------------------------ work selection --
    def prefill_work(self) -> List[ServeRequest]:
        """Up to ``prefill_batch`` requests that still owe prompt tokens,
        in policy order — the chunk batch for this iteration."""
        owing = [r for r in self.active
                 if r.state == PREFILL and r.prefilled < r.prompt_len]
        owing.sort(key=self._key)
        return owing[:self.prefill_batch]

    def decode_work(self) -> List[ServeRequest]:
        return [r for r in self.active if r.state == DECODE]

    def iteration_plan(self) -> List[Tuple[ServeRequest, int]]:
        """The fused continuous-batching iteration: ``(request, n_new)``
        rows mixing decode and prefill in ONE batch.

        Decode rows always ride (one token each; a long prompt can never
        stall them past the budget), then prefill chunks pack the
        remaining ``token_budget`` in policy order — the last chunk is
        clipped to the budget, so TTFT-critical short prompts behind a
        long one still start this iteration.
        """
        plan: List[Tuple[ServeRequest, int]] = [
            (r, 1) for r in self.decode_work() if r.out]
        budget = self.token_budget - len(plan)
        owing = [r for r in self.active
                 if r.state == PREFILL and r.prefilled < r.prompt_len]
        owing.sort(key=self._key)
        for r in owing:
            if budget <= 0:
                break
            n = min(self.chunk_for(r), budget)
            plan.append((r, n))
            budget -= n
        return plan

    # ------------------------------------------------------------ lifecycle --
    def chunk_for(self, req: ServeRequest) -> int:
        """Tokens of ``req``'s next prefill chunk (<= prefill_chunk)."""
        return min(self.prefill_chunk, req.prompt_len - req.prefilled)

    def note_prefilled(self, req: ServeRequest, n_tokens: int,
                       now: float) -> None:
        req.prefilled += n_tokens
        if req.prefilled >= req.prompt_len:
            req.state = DECODE

    def note_token(self, req: ServeRequest, token: int, now: float) -> bool:
        """Record one generated token; returns True when the request just
        finished (the engine then releases its cache space)."""
        if not req.out:
            req.t_first = now
        req.t_last = now
        req.out.append(int(token))
        if len(req.out) >= req.max_new:
            req.state = DONE
            self.active.remove(req)
            self.finished.append(req)
            return True
        return False

    def cancel(self, req: ServeRequest, why: str = "cancelled") -> bool:
        """Pull a live request out of the scheduler (deadline expiry or
        client abort).  Returns True if it was still live; the engine
        then releases whatever cache space the request held — withOUT
        registering its half-written prefix pages for reuse."""
        if req in self.waiting:
            self.waiting.remove(req)
        elif req in self.active:
            self.active.remove(req)
        else:
            return False
        req.state = TIMED_OUT
        req.why_rejected = why
        self.cancelled.append(req)
        return True

    # -------------------------------------------------------------- queries --
    def all_done(self) -> bool:
        return not self.waiting and not self.active

    def n_pending(self) -> int:
        return len(self.waiting) + len(self.active)
