"""Serving engine: prefill + decode steps with distributed KV/state caches.

The decode path is what the ``decode_32k`` / ``long_500k`` cells lower:
one new token per sequence against a cache of ``seq_len`` history.  Cache
placement follows ``core.policy.cache_specs``:

  * batch over the dp axes,
  * attention cache *length* over the tp axis (flash-decode layout: each
    model rank holds a slice of history; the softmax combines partial
    max/sum via the collectives GSPMD inserts for the sharded reduction —
    no rank ever materializes the full cache, which for 32k x 128 x 40L
    would blow past HBM),
  * SSM / RG-LRU state channels over the tp axis.

Two engines sit on top of the jitted steps:

  * ``ServeEngine`` — the dense-slot baseline: sequences occupy slots of
    a fixed-size batch with per-slot ``max_seq``-wide caches.  Kept as
    the reference implementation (the paged path must match its logits
    bit-for-bit at fp32) and as the execution mode for architectures
    whose caches don't page (ring buffers, recurrent state);
  * ``AsyncServeEngine`` — the production shape: a paged KV cache
    (``serve.kvcache``: shared page pool, block tables, prefix-hash
    reuse), an SLO-aware request scheduler (``serve.scheduler``) with
    chunked prefill interleaved against the decode batch, decode-step
    batching keyed by the tuned-config registry's (B, 1, cache_len)
    buckets, and per-request telemetry
    (``cluster.telemetry.ServingStats``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.cluster.telemetry import ServingStats
from repro.configs.base import ATTN, ModelConfig, PolicyConfig, ShapeConfig
from repro.core import policy as pol
from repro.kernels.registry import bucket_pow2
from repro.models import lm, transformer
from repro.models.transformer import RunCtx
from repro.serve import kvcache
from repro.serve.scheduler import (DECODE, PREFILL, RequestScheduler,
                                   ServeRequest)
from repro.train.trainer import make_run_ctx


# ---------------------------------------------------------------------------
# step builders (jit-able; used by launch.dryrun and ServeEngine)
# ---------------------------------------------------------------------------
def make_prefill_step(cfg: ModelConfig, policy: PolicyConfig, *,
                      cache_capacity: int, mesh=None,
                      bucketed: bool = False) -> Callable:
    """prefill(params, tokens) -> (last-token logits, caches).

    The attention tiles come from the tuned-config registry keyed by the
    prefill length (= cache capacity); defaults on a registry miss.

    ``bucketed=True`` returns ``prefill(params, tokens, length)`` for
    pow2-padded prompts: ``tokens`` (B, S_bucket) right-padded, ``length``
    (B,) int32 real lengths.  Padded columns are masked end to end —
    attention caches mark them empty, recurrent/SSM state passes through
    them unchanged — and the logits are read at ``length - 1``, so one
    trace serves every prompt length in the bucket.
    """
    ctx = dataclasses.replace(
        make_run_ctx(cfg, policy, mesh, seq_len=cache_capacity),
        cache_capacity=cache_capacity)

    def prefill(params, tokens):
        hidden, caches, _ = lm.forward(params, tokens, cfg, ctx,
                                       caches="init", return_hidden=True)
        last = hidden[:, -1:]
        logits = lm.head_table(params, cfg)
        out = (last.astype(ctx.compute_dtype)
               @ logits.astype(ctx.compute_dtype).T)
        return out, caches

    def prefill_bucketed(params, tokens, length):
        B, S = tokens.shape[0], tokens.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        mask = positions < length[:, None]
        hidden, caches, _ = lm.forward(params, tokens, cfg, ctx,
                                       positions=positions, caches="init",
                                       kv_mask=mask, return_hidden=True)
        last = hidden[jnp.arange(B), length - 1][:, None]
        logits = lm.head_table(params, cfg)
        out = (last.astype(ctx.compute_dtype)
               @ logits.astype(ctx.compute_dtype).T)
        return out, caches

    return prefill_bucketed if bucketed else prefill


def make_decode_step(cfg: ModelConfig, policy: PolicyConfig, mesh=None,
                     max_seq: Optional[int] = None,
                     batch: Optional[int] = None) -> Callable:
    """decode(params, caches, tokens, positions) -> (logits, caches).

    tokens (B, 1) int32 (or (B, 1, d) embeddings); positions (B, 1) int32.
    ``max_seq`` (the cache length) and ``batch`` key the tuned-config
    lookup at the (B, 1, cache_len) decode bucket.
    """
    ctx = make_run_ctx(cfg, policy, mesh, seq_len=max_seq, decode=True,
                       batch=batch)

    def decode(params, caches, tokens, positions):
        logits, new_caches, _ = lm.forward(params, tokens, cfg, ctx,
                                           positions=positions,
                                           caches=caches)
        return logits, new_caches

    return decode


def init_caches(cfg: ModelConfig, batch: int, max_seq: int,
                dtype=jnp.bfloat16):
    return transformer.init_stack_cache(cfg, batch, max_seq, dtype)


def greedy_sample(logits: jnp.ndarray) -> jnp.ndarray:
    return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]


# ---------------------------------------------------------------------------
# slot-based continuous batching
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Request:
    rid: int
    prompt: jnp.ndarray            # (S,) int32
    max_new: int = 16
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Minimal continuous-batching server over the decode step.

    Slots are prefilling/decoding independently: a finished sequence frees
    its slot immediately (no head-of-line blocking).  Single-host demo
    semantics; the jitted steps themselves are the production artifacts.
    """

    def __init__(self, cfg: ModelConfig, params, policy: PolicyConfig, *,
                 n_slots: int = 4, max_seq: int = 512, mesh=None):
        self.cfg = cfg
        self.params = params
        self.policy = policy
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.ctx_dtype = jnp.bfloat16 \
            if policy.compute_dtype == "bfloat16" else jnp.float32
        self.decode = jax.jit(make_decode_step(cfg, policy, mesh,
                                               max_seq=max_seq))
        self.prefill = jax.jit(
            make_prefill_step(cfg, policy, cache_capacity=max_seq,
                              mesh=mesh))
        self.caches = init_caches(cfg, n_slots, max_seq, self.ctx_dtype)
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.slot_pos = jnp.zeros((n_slots,), jnp.int32)
        self.slot_tok = jnp.zeros((n_slots, 1), jnp.int32)

    # -- batched-prefill note: per-slot prefill keeps the demo simple; the
    # -- benchmark harness lowers the full-batch prefill step instead.
    def add_request(self, req: Request) -> bool:
        for s, cur in enumerate(self.slot_req):
            if cur is None:
                self._prefill_into_slot(s, req)
                return True
        return False

    def _prefill_into_slot(self, s: int, req: Request) -> None:
        toks = req.prompt[None, :]
        logits, caches = self.prefill(self.params, toks)
        nxt = greedy_sample(logits)
        # scatter the single-sequence cache into slot s; scanned segments
        # carry a leading layer-stack dim, so batch is dim 1 there
        segs = transformer.plan_segments(self.cfg.pattern)
        self.caches = kvcache.scatter_slot(self.caches, caches, s, segs)
        self.slot_req[s] = req
        self.slot_pos = self.slot_pos.at[s].set(req.prompt.shape[0])
        self.slot_tok = self.slot_tok.at[s].set(nxt[0])
        req.out.append(int(nxt[0, 0]))

    def step(self) -> int:
        """One decode step for all active slots; returns #active."""
        active = [s for s, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        pos = self.slot_pos[:, None]
        logits, self.caches = self.decode(
            self.params, self.caches, self.slot_tok, pos)
        nxt = greedy_sample(logits)
        self.slot_tok = nxt
        self.slot_pos = self.slot_pos + jnp.asarray(
            [1 if self.slot_req[s] is not None else 0
             for s in range(self.n_slots)], jnp.int32)
        for s in active:
            req = self.slot_req[s]
            req.out.append(int(nxt[s, 0]))
            if len(req.out) >= req.max_new:
                req.done = True
                self.slot_req[s] = None
        return len(active)


# ---------------------------------------------------------------------------
# AsyncServeEngine: paged KV cache + SLO scheduler + chunked prefill
# ---------------------------------------------------------------------------
class AsyncServeEngine:
    """Production-shaped serving engine.

    One ``step()`` is one engine iteration.  In the default **fused**
    mode (true continuous batching) admission is followed by a SINGLE
    jitted step over a mixed batch: every decode row (one token each)
    plus prefill chunks packed up to the scheduler's ``token_budget``
    (``RequestScheduler.iteration_plan``) — prefill never runs as a
    separate step that stalls decode, and a long prompt trickles through
    the budget while queued short requests keep making their TTFT
    deadlines.  ``fused=False`` keeps the legacy two-step iteration (one
    batched prefill-chunk step, then one batched decode step) as the
    comparison/equivalence baseline; both orderings produce bit-identical
    fp32 logits per request because masking is purely positional.

    ``warmup()`` pre-compiles the paged step's jit traces so latency
    percentiles measure steady state; the compile time is reported
    separately (``report()["compile_s"]``).

    Execution modes:
      * ``paged``  — all-attention architectures: block tables over a
        shared page pool; the dense cache view exists only inside the
        jitted step (one gather), new K/V scatters straight back to the
        pool.  Prefill is *only* chunk steps — a prefix-cache hit simply
        starts the first chunk at the first uncached token;
      * ``dense``  — ring-buffer / recurrent-state architectures: per-slot
        dense caches (the ``ServeEngine`` layout) under the same
        scheduler, admission, and telemetry; no paging or prefix reuse.

    ``mode="auto"`` picks per architecture.  ``clock`` is injectable for
    deterministic tests (defaults to ``time.monotonic``).

    ``tracker`` is an optional ``repro.tracking.Run`` (default: the
    process-wide ``tracking.current_run()``); with one active, every
    ``track_every`` engine iterations one windowed metrics row is logged
    (TTFT/TPOT percentiles so far, queue depth, SLO attainment,
    window throughput) plus a system sample of KV-page occupancy.
    """

    def __init__(self, cfg: ModelConfig, params, policy: PolicyConfig, *,
                 n_slots: int = 4, max_seq: int = 512, page_size: int = 16,
                 n_pages: Optional[int] = None, prefill_chunk: int = 64,
                 prefill_batch: int = 2, token_budget: Optional[int] = None,
                 fused: bool = True, sched_policy: str = "slo",
                 mode: str = "auto", mesh=None, clock=None,
                 tracker=None, track_every: int = 16,
                 request_timeout_s: float = 0.0):
        self.cfg = cfg
        self.params = params
        self.policy = policy
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.prefill_chunk = prefill_chunk
        self.fused = fused
        self.request_timeout_s = request_timeout_s
        self._draining = False
        self.clock = clock or time.monotonic
        self.ctx_dtype = jnp.bfloat16 \
            if policy.compute_dtype == "bfloat16" else jnp.float32
        if mode == "auto":
            mode = "paged" if all(b == ATTN for b in cfg.pattern) \
                else "dense"
        self.mode = mode
        self.segs = transformer.plan_segments(cfg.pattern)
        self.sched = RequestScheduler(
            max_slots=n_slots, max_prompt=max_seq,
            prefill_chunk=prefill_chunk, prefill_batch=prefill_batch,
            token_budget=token_budget, policy=sched_policy)
        self.stats = ServingStats()
        self.compile_s = 0.0           # accumulated warmup() compile time
        self._util_sum = 0.0           # sum of per-iteration utilization
        # decode-shape bucket from the tuned-config registry vocabulary:
        # jit cache keys and block lookups share it
        ctx = make_run_ctx(cfg, policy, mesh, seq_len=max_seq, decode=True,
                           batch=n_slots)
        self.ctx = dataclasses.replace(ctx, cache_capacity=max_seq)
        self._iters = 0
        self.tracker = tracker
        self.track_every = max(int(track_every), 1)
        self._win_completed = 0
        self._win_tokens = 0
        self._win_t: Optional[float] = None
        if self.mode == "paged":
            self.pool = kvcache.PagePool(
                cfg,
                n_pages=n_pages or n_slots * (-(-max_seq // page_size)),
                page_size=page_size, dtype=self.ctx_dtype)
            self._paged_step = jax.jit(self._paged_step_fn,
                                       donate_argnums=(1,))
        else:
            self.pool = None
            self.caches = init_caches(cfg, n_slots, max_seq, self.ctx_dtype)
            self.slot_req: List[Optional[ServeRequest]] = [None] * n_slots
            # pow2-bucketed one-shot prefill: prompts are right-padded to
            # the next power of two, so the trace count is O(log max_seq)
            # instead of one retrace per distinct prompt length
            self.prefill = jax.jit(make_prefill_step(
                cfg, policy, cache_capacity=max_seq, mesh=mesh,
                bucketed=True))
            self.decode = jax.jit(make_decode_step(
                cfg, policy, mesh, max_seq=max_seq, batch=n_slots))

    # ------------------------------------------------------------ plumbing --
    def now(self) -> float:
        return self.clock()

    def submit(self, req: ServeRequest) -> bool:
        """Admission-queue a request; False = rejected (with reason in
        ``req.why_rejected`` — the scheduler owns the capacity check)."""
        now = self.now()
        self.stats.mark(now)
        self.stats.requests_submitted += 1
        if self._draining:
            req.t_submit = now
            req.state = "rejected"
            req.why_rejected = "engine draining (planned detach)"
            self.sched.rejected.append(req)
            self.stats.requests_rejected += 1
            return False
        ok = self.sched.submit(req, now)
        if not ok:
            self.stats.requests_rejected += 1
        return ok

    def drain(self) -> None:
        """Planned detach announced: stop admitting new requests and let
        the in-flight ones finish (``run()`` then returns once the
        admitted population drains)."""
        self._draining = True

    def _expire_timeouts(self, now: float) -> None:
        """Cancel every request older than ``request_timeout_s`` and give
        its cache space back.  Half-written prefix pages are NOT
        registered for reuse — a timed-out prompt must not poison the
        prefix cache."""
        if self.request_timeout_s <= 0:
            return
        for req in (list(self.sched.waiting) + list(self.sched.active)):
            if now - req.t_submit <= self.request_timeout_s:
                continue
            was_active = req.state in (PREFILL, DECODE)
            if not self.sched.cancel(
                    req, f"timed out after {self.request_timeout_s:g}s"):
                continue
            self.stats.requests_timed_out += 1
            self.stats.requests_failed += 1
            if was_active and req.table is not None:
                if self.mode == "paged":
                    self.pool.release(req.table)
                else:
                    self.slot_req[req.table] = None
                req.table = None

    def _try_open(self, req: ServeRequest) -> bool:
        if self.mode == "paged":
            try:
                table, n_cached = self.pool.open_sequence(
                    req.prompt, req.max_new)
            except kvcache.PageError:
                return False
            req.table, req.n_cached = table, n_cached
            return True
        for s, cur in enumerate(self.slot_req):
            if cur is None:
                self.slot_req[s] = req
                req.table = s
                return True
        return False

    def _finish(self, req: ServeRequest, now: float) -> None:
        if self.mode == "paged":
            self.pool.close_sequence(req.prompt, req.table)
            req.table = None
        else:
            self.slot_req[req.table] = None
        self.stats.add_request(
            t_done=now, wait_s=req.queue_wait_s(), ttft_s=req.ttft_s(),
            tpot_s=req.tpot_s(), prompt_tokens=req.prompt_len,
            cached_tokens=req.n_cached, output_tokens=len(req.out),
            slo_ok=req.slo_met())

    # ------------------------------------------------------- paged stepping --
    def _paged_step_fn(self, params, pages, tables, toks, positions, valid,
                       last_idx):
        """One jitted paged step (chunk prefill when S>1, decode at S=1):
        gather the dense view, run the stack, scatter the new K/V back to
        the pool, return greedy next tokens at ``last_idx``."""
        dense = kvcache.gather_dense(pages, tables, self.segs)
        hidden, new_caches, _ = lm.forward(
            params, toks, self.cfg, self.ctx, positions=positions,
            caches=dense, return_hidden=True)
        pages = kvcache.scatter_tokens(
            pages, new_caches, tables, positions, valid,
            self.pool.page_size, self.segs, self.pool.trash)
        h = hidden[jnp.arange(toks.shape[0]), last_idx]
        table_w = lm.head_table(params, self.cfg)
        logits = (h.astype(self.ctx.compute_dtype)
                  @ table_w.astype(self.ctx.compute_dtype).T)
        return jnp.argmax(logits, -1).astype(jnp.int32), logits, pages

    def _table_width(self, reqs: List[ServeRequest]) -> int:
        """Bucketed block-table width for this batch (shared jit key)."""
        need = max(len(r.table) for r in reqs)
        cap = self.pool.pages_for(self.max_seq)
        return min(bucket_pow2(need, floor=1), cap)

    def _run_paged(self, reqs: List[ServeRequest], toks, positions, valid,
                   last_idx):
        """Returns (next tokens, last-position logits) for the live rows
        (padding rows stripped)."""
        P = self._table_width(reqs)
        B = len(reqs)
        Bpad = min(bucket_pow2(B, floor=1), self.n_slots)
        pad = Bpad - B
        tables = jnp.stack(
            [self.pool.padded_table(r.table, P) for r in reqs]
            + [jnp.full((P,), self.pool.trash, jnp.int32)] * pad)
        if pad:
            zcol = jnp.zeros((pad, toks.shape[1]), jnp.int32)
            toks = jnp.concatenate([toks, zcol])
            positions = jnp.concatenate([positions, zcol])
            valid = jnp.concatenate(
                [valid, jnp.zeros((pad, valid.shape[1]), bool)])
            last_idx = jnp.concatenate([last_idx, zcol[:, 0]])
        nxt, logits, self.pool.pages = self._paged_step(
            self.params, self.pool.pages, tables, toks, positions, valid,
            last_idx)
        return nxt, logits[:B]

    def _paged_prefill_chunks(self, now: float) -> int:
        work = self.sched.prefill_work()
        if not work:
            return 0
        C = self.prefill_chunk
        toks, poss, vals, last = [], [], [], []
        for r in work:
            n = self.sched.chunk_for(r)
            row = [int(t) for t in r.prompt[r.prefilled:r.prefilled + n]]
            row += [0] * (C - n)
            toks.append(row)
            poss.append(list(range(r.prefilled, r.prefilled + C)))
            vals.append([i < n for i in range(C)])
            last.append(n - 1)
        nxt, _ = self._run_paged(
            work, jnp.asarray(toks, jnp.int32), jnp.asarray(poss, jnp.int32),
            jnp.asarray(vals, bool), jnp.asarray(last, jnp.int32))
        jax.block_until_ready(nxt)
        now = self.now()        # token timestamps see the finished step
        done_tokens = 0
        for i, r in enumerate(work):
            n = self.sched.chunk_for(r)
            done_tokens += n
            r.table.n_tokens = r.prefilled + n
            self.sched.note_prefilled(r, n, now)
            if r.state == DECODE:
                # prompt complete: register its full pages now — they are
                # immutable from this point, so concurrent shared-prefix
                # requests can hit them while this one is still decoding —
                # and the chunk's last hidden IS the first generated token
                # (no separate "first decode" step)
                self.pool.register_prefix(r.prompt, r.table)
                if self.sched.note_token(r, int(nxt[i]), now):
                    self._finish(r, now)
        return done_tokens

    def _paged_fused(self, now: float) -> int:
        """True continuous batching: ONE jitted step over a mixed batch
        of decode rows (width-1) and prefill chunks, per the scheduler's
        token-budget ``iteration_plan``.  Row width pads to 1 (pure
        decode) or ``prefill_chunk`` (any prefill present) so the trace
        count stays O(log n_slots) x 2; padded columns carry positions
        AFTER the row's valid tokens (causal masking excludes them) and
        their K/V scatter lands on the scratch page — each row's logits
        are bit-identical to the unfused two-step path."""
        plan = self.sched.iteration_plan()
        if not plan:
            return 0
        pure_decode = all(r.state == DECODE for r, _ in plan)
        W = 1 if pure_decode else self.prefill_chunk
        toks, poss, vals, last = [], [], [], []
        for r, n in plan:
            if r.state == DECODE:
                p0 = r.prompt_len + len(r.out) - 1
                toks.append([r.out[-1]] + [0] * (W - 1))
                poss.append([p0 + i for i in range(W)])
                vals.append([True] + [False] * (W - 1))
                last.append(0)
            else:
                row = [int(t) for t in r.prompt[r.prefilled:r.prefilled + n]]
                toks.append(row + [0] * (W - n))
                poss.append(list(range(r.prefilled, r.prefilled + W)))
                vals.append([i < n for i in range(W)])
                last.append(n - 1)
        nxt, _ = self._run_paged(
            [r for r, _ in plan], jnp.asarray(toks, jnp.int32),
            jnp.asarray(poss, jnp.int32), jnp.asarray(vals, bool),
            jnp.asarray(last, jnp.int32))
        jax.block_until_ready(nxt)
        now = self.now()        # token timestamps see the finished step
        done_tokens = 0
        for i, (r, n) in enumerate(plan):
            done_tokens += n
            if r.state == DECODE:
                r.table.n_tokens += 1
                if self.sched.note_token(r, int(nxt[i]), now):
                    self._finish(r, now)
                continue
            r.table.n_tokens = r.prefilled + n
            self.sched.note_prefilled(r, n, now)
            if r.state == DECODE:
                # prompt complete: register its (now immutable) full
                # pages and take the chunk's last hidden as the first
                # generated token, exactly like the unfused chunk path
                self.pool.register_prefix(r.prompt, r.table)
                if self.sched.note_token(r, int(nxt[i]), now):
                    self._finish(r, now)
        return done_tokens

    def warmup(self, max_tokens: Optional[int] = None) -> float:
        """Pre-compile the paged step's jit traces: every pow2 batch
        bucket x row width (1 and ``prefill_chunk``) at the table width
        serving ``max_tokens`` (default ``max_seq``).  Rows are
        all-invalid — K/V writes land on the scratch page, so pool state,
        request stats, and the prefix cache are untouched.  Returns the
        compile seconds (also accumulated into ``self.compile_s`` and
        reported separately so latency percentiles measure steady
        state)."""
        if self.mode != "paged":
            return 0.0
        t0 = time.perf_counter()
        cap = self.pool.pages_for(self.max_seq)
        P = min(bucket_pow2(self.pool.pages_for(max_tokens or self.max_seq),
                            floor=1), cap)
        sizes = sorted({min(bucket_pow2(b, floor=1), self.n_slots)
                        for b in range(1, self.n_slots + 1)})
        nxt = None
        for B in sizes:
            for W in (1, self.prefill_chunk):
                tables = jnp.full((B, P), self.pool.trash, jnp.int32)
                zeros = jnp.zeros((B, W), jnp.int32)
                nxt, _, self.pool.pages = self._paged_step(
                    self.params, self.pool.pages, tables, zeros, zeros,
                    jnp.zeros((B, W), bool), jnp.zeros((B,), jnp.int32))
        if nxt is not None:
            jax.block_until_ready(nxt)
        dt = time.perf_counter() - t0
        self.compile_s += dt
        return dt

    def _paged_decode(self, now: float) -> int:
        work = [r for r in self.sched.decode_work() if r.out]
        if not work:
            return 0
        toks = jnp.asarray([[r.out[-1]] for r in work], jnp.int32)
        pos = jnp.asarray(
            [[r.prompt_len + len(r.out) - 1] for r in work], jnp.int32)
        valid = jnp.ones((len(work), 1), bool)
        last = jnp.zeros((len(work),), jnp.int32)
        nxt, _ = self._run_paged(work, toks, pos, valid, last)
        jax.block_until_ready(nxt)
        now = self.now()        # token timestamps see the finished step
        for i, r in enumerate(work):
            r.table.n_tokens += 1
            if self.sched.note_token(r, int(nxt[i]), now):
                self._finish(r, now)
        return len(work)

    # ------------------------------------------------------- dense stepping --
    def _dense_prefill(self, now: float) -> int:
        work = self.sched.prefill_work()
        if not work:
            return 0
        done = 0
        for req in work[:1]:          # one-shot prefill, one request/iter
            s = req.table
            L = req.prompt_len
            # pad to the pow2 bucket (capped at capacity): every length in
            # the bucket shares one compiled trace
            Spad = min(bucket_pow2(L, floor=16), self.max_seq)
            row = list(map(int, req.prompt)) + [0] * (Spad - L)
            toks = jnp.asarray([row], jnp.int32)
            length = jnp.asarray([L], jnp.int32)
            logits, one = self.prefill(self.params, toks, length)
            nxt = greedy_sample(logits)
            self.caches = kvcache.scatter_slot(self.caches, one, s,
                                               self.segs)
            done += req.prompt_len
            self.sched.note_prefilled(req, req.prompt_len, now)
            if self.sched.note_token(req, int(nxt[0, 0]), now):
                self._finish(req, now)
        return done

    def _dense_decode(self, now: float) -> int:
        work = [r for r in self.sched.decode_work() if r.out]
        if not work:
            return 0
        toks = [[0]] * self.n_slots
        pos = [[0]] * self.n_slots
        for r in work:
            toks[r.table] = [r.out[-1]]
            pos[r.table] = [r.prompt_len + len(r.out) - 1]
        logits, self.caches = self.decode(
            self.params, self.caches, jnp.asarray(toks, jnp.int32),
            jnp.asarray(pos, jnp.int32))
        nxt = greedy_sample(logits)
        for r in list(work):
            if self.sched.note_token(r, int(nxt[r.table, 0]), now):
                self._finish(r, now)
        return len(work)

    # ---------------------------------------------------------------- loop --
    def step(self) -> int:
        """One engine iteration; returns tokens processed (prefill +
        decode) so callers can loop ``while eng.step() or not
        eng.sched.all_done()``."""
        now = self.now()
        self._iters += 1
        self._expire_timeouts(now)
        self.sched.admit(now, self._try_open)
        if self.mode == "paged":
            if self.fused:
                n = self._paged_fused(now)
            else:
                n = self._paged_prefill_chunks(now)
                n += self._paged_decode(now)
            self._util_sum += self.pool.utilization()
        else:
            n = self._dense_prefill(now)
            n += self._dense_decode(now)
        if self._iters % self.track_every == 0:
            self._track_window(now)
        return n

    def _track_window(self, now: float) -> None:
        """Log one windowed metrics row to the active tracking run."""
        from repro import tracking
        run = self.tracker or tracking.current_run()
        if run is None:
            return
        s = self.stats
        dt = now - self._win_t if self._win_t is not None else 0.0
        row = {
            "iter": self._iters,
            "queue_depth": len(self.sched.waiting),
            "active": len(self.sched.active),
            "completed": s.requests_completed,
            "window_completed": s.requests_completed - self._win_completed,
            "window_tok_s": ((s.output_tokens - self._win_tokens) / dt
                             if dt > 0 else 0.0),
            "slo_attainment": s.slo_met / max(s.requests_completed, 1),
        }
        if s.ttft_s:
            row["ttft_p50_s"] = ServingStats._dist(s.ttft_s)["p50"]
        if s.tpot_s:
            row["tpot_p50_s"] = ServingStats._dist(s.tpot_s)["p50"]
        run.log(row, step=self._iters)
        if self.pool is not None:
            kv = self.pool.stats()
            run.log_system({"kv.pages_in_use": kv["in_use"],
                            "kv.hit_rate": kv["hit_rate"]})
        self._win_completed = s.requests_completed
        self._win_tokens = s.output_tokens
        self._win_t = now

    def run(self, max_iters: int = 1_000_000) -> None:
        """Drive until every submitted request finished or nothing moves."""
        for _ in range(max_iters):
            if self.sched.all_done():
                return
            if self.step() == 0 and not self.sched.active:
                return            # starved: nothing admitted, nothing runs

    # -------------------------------------------------------------- report --
    def report(self) -> Dict[str, Any]:
        rep = self.stats.report()
        rep["mode"] = self.mode
        rep["fused"] = self.fused
        rep["iterations"] = self._iters
        rep["compile_s"] = self.compile_s
        if self.pool is not None:
            kv = self.pool.stats()
            # mean occupancy over engine iterations; "utilization" alone
            # is the post-drain sample (always 0 once requests finished)
            kv["mean_utilization"] = self._util_sum / max(self._iters, 1)
            rep["kv_pages"] = kv
        return rep
