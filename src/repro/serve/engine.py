"""Serving engine: prefill + decode steps with distributed KV/state caches.

The decode path is what the ``decode_32k`` / ``long_500k`` cells lower:
one new token per sequence against a cache of ``seq_len`` history.  Cache
placement follows ``core.policy.cache_specs``:

  * batch over the dp axes,
  * attention cache *length* over the tp axis (flash-decode layout: each
    model rank holds a slice of history; the softmax combines partial
    max/sum via the collectives GSPMD inserts for the sharded reduction —
    no rank ever materializes the full cache, which for 32k x 128 x 40L
    would blow past HBM),
  * SSM / RG-LRU state channels over the tp axis.

``ServeEngine`` adds slot-based continuous batching on top: sequences
occupy slots of a fixed-size batch; finished sequences free their slot for
the next request (the standard production serving shape).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, PolicyConfig, ShapeConfig
from repro.core import policy as pol
from repro.models import lm, transformer
from repro.models.transformer import RunCtx
from repro.train.trainer import make_run_ctx


# ---------------------------------------------------------------------------
# step builders (jit-able; used by launch.dryrun and ServeEngine)
# ---------------------------------------------------------------------------
def make_prefill_step(cfg: ModelConfig, policy: PolicyConfig, *,
                      cache_capacity: int, mesh=None) -> Callable:
    """prefill(params, tokens) -> (last-token logits, caches).

    The attention tiles come from the tuned-config registry keyed by the
    prefill length (= cache capacity); defaults on a registry miss."""
    ctx = dataclasses.replace(
        make_run_ctx(cfg, policy, mesh, seq_len=cache_capacity),
        cache_capacity=cache_capacity)

    def prefill(params, tokens):
        hidden, caches, _ = lm.forward(params, tokens, cfg, ctx,
                                       caches="init", return_hidden=True)
        last = hidden[:, -1:]
        logits = lm.head_table(params, cfg)
        out = (last.astype(ctx.compute_dtype)
               @ logits.astype(ctx.compute_dtype).T)
        return out, caches

    return prefill


def make_decode_step(cfg: ModelConfig, policy: PolicyConfig, mesh=None,
                     max_seq: Optional[int] = None) -> Callable:
    """decode(params, caches, tokens, positions) -> (logits, caches).

    tokens (B, 1) int32 (or (B, 1, d) embeddings); positions (B, 1) int32.
    ``max_seq`` (the cache length) keys the tuned-config lookup.
    """
    ctx = make_run_ctx(cfg, policy, mesh, seq_len=max_seq)

    def decode(params, caches, tokens, positions):
        logits, new_caches, _ = lm.forward(params, tokens, cfg, ctx,
                                           positions=positions,
                                           caches=caches)
        return logits, new_caches

    return decode


def init_caches(cfg: ModelConfig, batch: int, max_seq: int,
                dtype=jnp.bfloat16):
    return transformer.init_stack_cache(cfg, batch, max_seq, dtype)


def greedy_sample(logits: jnp.ndarray) -> jnp.ndarray:
    return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]


# ---------------------------------------------------------------------------
# slot-based continuous batching
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Request:
    rid: int
    prompt: jnp.ndarray            # (S,) int32
    max_new: int = 16
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Minimal continuous-batching server over the decode step.

    Slots are prefilling/decoding independently: a finished sequence frees
    its slot immediately (no head-of-line blocking).  Single-host demo
    semantics; the jitted steps themselves are the production artifacts.
    """

    def __init__(self, cfg: ModelConfig, params, policy: PolicyConfig, *,
                 n_slots: int = 4, max_seq: int = 512, mesh=None):
        self.cfg = cfg
        self.params = params
        self.policy = policy
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.ctx_dtype = jnp.bfloat16 \
            if policy.compute_dtype == "bfloat16" else jnp.float32
        self.decode = jax.jit(make_decode_step(cfg, policy, mesh,
                                               max_seq=max_seq))
        self.prefill = jax.jit(
            make_prefill_step(cfg, policy, cache_capacity=max_seq,
                              mesh=mesh))
        self.caches = init_caches(cfg, n_slots, max_seq, self.ctx_dtype)
        self.slot_req: List[Optional[Request]] = [None] * n_slots
        self.slot_pos = jnp.zeros((n_slots,), jnp.int32)
        self.slot_tok = jnp.zeros((n_slots, 1), jnp.int32)

    # -- batched-prefill note: per-slot prefill keeps the demo simple; the
    # -- benchmark harness lowers the full-batch prefill step instead.
    def add_request(self, req: Request) -> bool:
        for s, cur in enumerate(self.slot_req):
            if cur is None:
                self._prefill_into_slot(s, req)
                return True
        return False

    def _prefill_into_slot(self, s: int, req: Request) -> None:
        toks = req.prompt[None, :]
        logits, caches = self.prefill(self.params, toks)
        nxt = greedy_sample(logits)
        # scatter the single-sequence cache into slot s; scanned segments
        # carry a leading layer-stack dim, so batch is dim 1 there
        segs = transformer.plan_segments(self.cfg.pattern)

        def put(path, c_all, c_one):
            bdim = _batch_dim(path, segs)
            idx = tuple([slice(None)] * bdim + [slice(s, s + 1)])
            return c_all.at[idx].set(c_one.astype(c_all.dtype))

        self.caches = jax.tree_util.tree_map_with_path(
            put, self.caches, caches)
        self.slot_req[s] = req
        self.slot_pos = self.slot_pos.at[s].set(req.prompt.shape[0])
        self.slot_tok = self.slot_tok.at[s].set(nxt[0])
        req.out.append(int(nxt[0, 0]))

    def step(self) -> int:
        """One decode step for all active slots; returns #active."""
        active = [s for s, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        pos = self.slot_pos[:, None]
        logits, self.caches = self.decode(
            self.params, self.caches, self.slot_tok, pos)
        nxt = greedy_sample(logits)
        self.slot_tok = nxt
        self.slot_pos = self.slot_pos + jnp.asarray(
            [1 if self.slot_req[s] is not None else 0
             for s in range(self.n_slots)], jnp.int32)
        for s in active:
            req = self.slot_req[s]
            req.out.append(int(nxt[s, 0]))
            if len(req.out) >= req.max_new:
                req.done = True
                self.slot_req[s] = None
        return len(active)


def _batch_dim(path, segs) -> int:
    """Cache-leaf batch dim: 1 for scanned (stacked) segments, else 0."""
    import re
    for p in path:
        key = str(getattr(p, "key", ""))
        m = re.match(r"seg(\d+)$", key)
        if m:
            si = int(m.group(1))
            return 1 if si < len(segs) and segs[si][1] > 1 else 0
    return 0
