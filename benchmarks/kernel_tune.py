"""Kernel-autotune smoke sweep: the perf-trajectory artifact for kernels.

Runs the tiny CI shape grid through ``repro.kernels.autotune``, persists
the winners to ``results/tuned_configs.json``, and reports per-cell
best-config + measured us/call.  The report also demonstrates the
measured-cost feedback edge: a ``CalibratedCost`` built from the fresh
sweep re-prices a ``recommend()`` ranking, so the artifact shows the
analytic-vs-calibrated step times side by side.

``run.py --bench kernel_tune`` writes the JSON to
``results/kernel_tune.json``.
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import jax

from repro.core import recommend
from repro.core.costmodel import CalibratedCost
from repro.kernels import autotune
from repro.kernels import registry as kreg

ITERS = 2
DEMO_ARCH, DEMO_SHAPE, DEMO_CHIPS = "qwen2-0.5b", "train_4k", 64


# Perf-trajectory spec for results/BENCH_kernel_tune.json (see
# docs/tracking.md).  This bench measures wall-clock kernel timings, so
# everything host-dependent is info-only; only sweep coverage is gated.
TRAJECTORY = {
    "n_cases": {"direction": "up"},
    "n_non_default": {"direction": "info"},
    "registry_size": {"direction": "info"},
    "kernel_speedup_mean": {"direction": "info"},
    "sweep_wall_s": {"direction": "info"},
}


def trajectory_row(rep: Dict[str, object]) -> Dict[str, float]:
    """Flatten one report() into the gated summary-row metrics."""
    speedups = rep["kernel_speedup"] or {}   # per-kernel dict -> scalar
    row = {k: float(rep[k]) for k in TRAJECTORY if k in rep}
    row["kernel_speedup_mean"] = (
        sum(speedups.values()) / len(speedups) if speedups else 1.0)
    return row


def _recommend_demo(cal: CalibratedCost) -> Dict[str, object]:
    """Analytic vs calibrated top-3 for one cell (the feedback loop)."""
    plain = recommend.recommend(DEMO_ARCH, DEMO_SHAPE, n_chips=DEMO_CHIPS,
                                top=3, calibration=CalibratedCost())
    cald = recommend.recommend(DEMO_ARCH, DEMO_SHAPE, n_chips=DEMO_CHIPS,
                               top=3, calibration=cal)
    return {
        "arch": DEMO_ARCH, "shape": DEMO_SHAPE, "n_chips": DEMO_CHIPS,
        "analytic": [{"mesh": c.label, "step_s": c.step_s} for c in plain],
        "calibrated": [{"mesh": c.label, "step_s": c.step_s}
                       for c in cald],
    }


def report() -> Dict[str, object]:
    t0 = time.perf_counter()
    registry, results = autotune.sweep(autotune.SMOKE_CASES, iters=ITERS,
                                       path=kreg.DEFAULT_PATH)
    sweep_s = time.perf_counter() - t0
    cal = CalibratedCost.from_registry(registry)
    cells = [r.to_json() for r in results]
    n_non_default = sum(
        1 for r in results
        if r.entry.blocks != autotune.default_blocks(r.case))
    return {
        "bench": "kernel_tune",
        "backend": jax.default_backend(),
        "iters": ITERS,
        "sweep_wall_s": sweep_s,
        "n_cases": len(results),
        "n_non_default": n_non_default,
        "registry_path": registry.path,
        "registry_size": len(registry),
        "kernel_speedup": cal.kernel_speedup,
        "cells": cells,
        "recommend_demo": _recommend_demo(cal),
    }


def run() -> List[Tuple[str, float, str]]:
    rep = report()
    rows = []
    for cell in rep["cells"]:
        rows.append((f"kernel_tune/{cell['kernel']}", cell["us"],
                     f"best={cell['best']} default={cell['default']} "
                     f"x{cell['speedup']:.2f}"))
    demo = rep["recommend_demo"]
    rows.append((
        "kernel_tune/summary", rep["sweep_wall_s"] * 1e6,
        f"cases={rep['n_cases']} non_default={rep['n_non_default']} "
        f"calibrated_top={demo['calibrated'][0]['mesh']} "
        f"analytic_top={demo['analytic'][0]['mesh']}"))
    return rows
