"""Table II: our re-implementations hit the published model characteristics."""
from __future__ import annotations

import time
from typing import List, Tuple

import jax

from repro.configs.paper_bench import (BERT_BASE, BERT_LARGE, MOBILENETV2,
                                       RESNET50, YOLOV5L)
from repro.models import vision


def run() -> List[Tuple[str, float, str]]:
    rows = []
    key = jax.random.PRNGKey(0)
    expected = {"mobilenetv2": 3.4e6, "resnet50": 25.6e6, "yolov5l": 47e6}
    for cfg in (MOBILENETV2, RESNET50, YOLOV5L):
        t0 = time.perf_counter()
        params = vision.init_vision(key, cfg)
        n = vision.param_count(params)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"table2/{cfg.name}", us,
                     f"params={n/1e6:.2f}M paper={expected[cfg.name]/1e6:.1f}M "
                     f"err={abs(n-expected[cfg.name])/expected[cfg.name]*100:.1f}%"))
    for cfg, exp in ((BERT_BASE, 110e6), (BERT_LARGE, 340e6)):
        t0 = time.perf_counter()
        n = cfg.param_count()
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"table2/{cfg.name}", us,
                     f"params={n/1e6:.2f}M paper={exp/1e6:.0f}M "
                     f"err={abs(n-exp)/exp*100:.1f}% depth={cfg.n_layers}"))
    return rows
