"""Beyond-paper: measured per-workload recomposition wins.

Reads the optimized-cell artifacts (results/optimized/*.json, produced
by ``dryrun.py --mesh-shape ...``) and the matching production-mesh
baselines, and prints the recomposition gain — the paper's
attach/detach knob applied to the logical mesh.
"""
from __future__ import annotations

import glob
import json
import os
import time
from typing import List, Tuple

OPT_DIR = os.environ.get("OPT_RESULTS", "results/optimized")
BASE_DIR = os.environ.get("DRYRUN_RESULTS", "results/dryrun")


def run() -> List[Tuple[str, float, str]]:
    rows = []
    files = sorted(glob.glob(os.path.join(OPT_DIR, "*.json")))
    if not files:
        return [("recompose/missing", 0.0,
                 f"no optimized artifacts under {OPT_DIR}")]
    for path in files:
        t0 = time.perf_counter()
        with open(path) as f:
            opt = json.load(f)
        base_path = os.path.join(BASE_DIR, os.path.basename(path))
        base = None
        if os.path.exists(base_path):
            with open(base_path) as f:
                base = json.load(f)
        us = (time.perf_counter() - t0) * 1e6
        o = opt["roofline"]
        tag = os.path.basename(path)[:-5]
        mesh = "x".join(str(v) for v in opt["mesh"].values())
        if base is not None:
            b = base["roofline"]
            gain = b["step_time_s"] / max(o["step_time_s"], 1e-12)
            rows.append((f"recompose/{tag}", us,
                         f"mesh={mesh} step {b['step_time_s']*1e3:.0f}ms"
                         f"->{o['step_time_s']*1e3:.0f}ms ({gain:.1f}x) "
                         f"frac {b['roofline_fraction']:.3f}->"
                         f"{o['roofline_fraction']:.3f}"))
        else:
            rows.append((f"recompose/{tag}", us,
                         f"mesh={mesh} step={o['step_time_s']*1e3:.0f}ms "
                         f"frac={o['roofline_fraction']:.3f}"))
    return rows
